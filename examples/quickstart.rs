//! Quickstart: simulate a small cluster under every speculative-execution
//! policy and print the comparison table.
//!
//!     cargo run --release --example quickstart
//!
//! This is the five-minute tour: one workload, seven policies, the paper's
//! two metrics (job flowtime, resource consumption) side by side.

use specsim::cluster::generator::generate;
use specsim::cluster::sim::Simulator;
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::metrics::report::{self, SummaryRow};
use specsim::scheduler::{self, SchedulerKind};

fn main() -> Result<(), String> {
    // a 300-machine cluster at the paper's "lightly loaded" utilization
    let mut cfg = SimConfig::default();
    cfg.machines = 300;
    cfg.horizon = 300.0;
    cfg.use_runtime = false; // pure-rust solver; run `make artifacts` + drop
                             // this line to exercise the PJRT path
    let workload_cfg = WorkloadConfig::paper(0.6);

    println!(
        "cluster: {} machines, horizon {}, Poisson lambda 0.6, Pareto(alpha=2)\n",
        cfg.machines, cfg.horizon
    );
    let mut rows = Vec::new();
    for kind in SchedulerKind::all() {
        cfg.scheduler = kind;
        // identical workload for every policy (pre-sampled durations)
        let workload = generate(&workload_cfg, cfg.horizon, cfg.seed);
        let sched = scheduler::build(&cfg, &workload_cfg)?;
        let res = Simulator::new(cfg.clone(), workload, sched).run();
        rows.push(SummaryRow::from_result(&res));
    }
    print!("{}", report::summary_table(&rows));
    println!("\nReading the table: sca/sda should show the lowest mean flowtime");
    println!("(the paper's Fig. 2), clone_all the highest resource, naive zero backups.");
    Ok(())
}
