//! Cutoff-threshold driver (Sec. III-B): compute lambda^U analytically and
//! sweep the arrival rate across it, showing blanket cloning flip from a
//! win to a loss — the boundary between the SCA/SDA regime and the ESE
//! regime.  The empirical sweep is an `ExperimentSpec` grid (2 policies x
//! 5 load fractions) run on the parallel engine.
//!
//!     cargo run --release --example threshold_sweep
//!     SPECSIM_THREADS=1 cargo run --release --example threshold_sweep

use std::path::Path;

use specsim::analysis::threshold;
use specsim::figures::{threshold as fig, Scale};

fn main() -> Result<(), String> {
    // the paper's cluster
    let rep = threshold::cutoff_lambda(3000, 50.5, 2.5, 2.0);
    println!("paper set-up (M=3000, E[m]=50.5, E[s]=2.5, alpha=2):");
    println!("  omega stability bound (Thm 1) = {:.4}", rep.omega_stability);
    println!("  omega cutoff                  = {:.4}", rep.omega_cutoff);
    println!("  lambda^U                      = {:.2} jobs/unit", rep.lambda_cutoff);
    println!(
        "  -> lambda=6 (Fig 2) is LIGHTLY loaded; lambda=30/40 (Fig 6) HEAVILY loaded\n"
    );
    // alpha > 2: the cutoff moves inside the stable region
    for alpha in [2.5, 3.0, 4.0] {
        let r = threshold::cutoff_lambda(3000, 50.5, 2.5, alpha);
        println!(
            "alpha={alpha}: omega_cutoff={:.4} (stability {:.4}) lambda^U={:.2}",
            r.omega_cutoff, r.omega_stability, r.lambda_cutoff
        );
    }
    println!();
    let threads = specsim::util::env_or("SPECSIM_THREADS", 0);
    fig::run(Path::new("results"), "artifacts", Scale(0.5), threads)?;
    println!("\nCSVs: results/threshold_analytic.csv, results/threshold_empirical.csv");
    Ok(())
}
