//! Fig. 6 driver: the heavily loaded experiment — ESE vs Mantri at
//! lambda in {30, 40} (M = 3000 full scale), reporting the flowtime and
//! resource CMFs and the headline "~18% lower flowtime at equal resource".
//!
//!     cargo run --release --example heavily_loaded
//!     SPECSIM_SCALE=0.1 cargo run --release --example heavily_loaded
//!     SPECSIM_THREADS=1 cargo run --release --example heavily_loaded
//!
//! The experiment is a declarative spec: 2 policies x 2 arrival rates x
//! 3 seeds, run in parallel on the experiment engine.

use std::path::Path;

use specsim::experiment::Runner;
use specsim::figures::{fig6, Scale};
use specsim::util::env_or;

fn main() -> Result<(), String> {
    let scale = Scale(env_or("SPECSIM_SCALE", 1.0));
    let mut spec = fig6::spec(scale);
    spec.threads = env_or("SPECSIM_THREADS", 0);
    println!(
        "running Fig. 6 at scale {} — {} grid cells (SPECSIM_SCALE / SPECSIM_THREADS to change)\n",
        scale.0,
        spec.cell_count()
    );
    let sweep = Runner::run(&spec)?;
    fig6::write_outputs(&sweep, Path::new("results"))?;
    println!("\nCSV series under results/fig6*_cmf_lambda{{30,40}}.csv");
    Ok(())
}
