//! Fig. 6 driver: the heavily loaded experiment — ESE vs Mantri at
//! lambda in {30, 40} (M = 3000 full scale), reporting the flowtime and
//! resource CMFs and the headline "~18% lower flowtime at equal resource".
//!
//!     cargo run --release --example heavily_loaded
//!     SPECSIM_SCALE=0.1 cargo run --release --example heavily_loaded

use std::path::Path;

use specsim::figures::{fig6, Scale};

fn main() -> Result<(), String> {
    let scale = std::env::var("SPECSIM_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .map(Scale)
        .unwrap_or(Scale::full());
    println!("running Fig. 6 at scale {} (SPECSIM_SCALE to change)\n", scale.0);
    fig6::run(Path::new("results"), "artifacts", scale)?;
    println!("\nCSV series under results/fig6*_cmf_lambda{{30,40}}.csv");
    Ok(())
}
