//! Live-serving demo: spin up the coordinator master (own thread, paced
//! scheduling slots, watermark backpressure) and drive it with a bursty
//! Poisson client — the deployable face of the library.  Python is nowhere
//! on this path; with artifacts built, SCA's P2 solves go through PJRT.
//!
//!     cargo run --release --example serve

use std::time::Duration;

use specsim::config::SimConfig;
use specsim::coordinator::backpressure::Backpressure;
use specsim::coordinator::master::{Master, Submission};
use specsim::scheduler::SchedulerKind;
use specsim::stats::Pcg64;

fn main() -> Result<(), String> {
    let mut cfg = SimConfig::default();
    cfg.machines = 128;
    cfg.horizon = f64::INFINITY;
    cfg.scheduler = SchedulerKind::Sda;
    cfg.use_runtime = false;

    let mut master = Master::new(cfg);
    master.tick = Duration::from_millis(1); // 1 ms of wall time per slot
    master.backpressure = Backpressure::from_capacity(128, 4.0, 12.0);
    let metrics = master.metrics.clone();
    let handle = master.spawn()?;

    println!("master up: 128 machines, SDA policy, 1ms slots");
    let mut rng = Pcg64::new(7, 0);
    let (mut accepted, mut throttled, mut rejected) = (0u32, 0u32, 0u32);
    // two phases: steady trickle, then a burst that trips backpressure
    for phase in 0..2 {
        let (jobs, pause_ms) = if phase == 0 { (150, 2.0) } else { (400, 0.05) };
        for _ in 0..jobs {
            std::thread::sleep(Duration::from_secs_f64(
                rng.exponential(1000.0 / pause_ms) ,
            ));
            let sub = Submission {
                num_tasks: rng.uniform_u64(1, 40) as u32,
                mean_duration: rng.uniform_f64(1.0, 4.0),
                alpha: 2.0,
            };
            match handle.submit(sub)? {
                specsim::coordinator::master::SubmitResult::Accepted { throttled: t, .. } => {
                    accepted += 1;
                    throttled += t as u32;
                }
                specsim::coordinator::master::SubmitResult::Rejected => rejected += 1,
            }
        }
        println!(
            "phase {phase}: accepted={accepted} throttled={throttled} rejected={rejected} \
             queued_tasks={} busy={}",
            metrics.gauge("queued_tasks").get(),
            metrics.gauge("busy_machines").get()
        );
    }
    println!("draining...");
    let report = handle.shutdown()?;
    println!(
        "completed {} jobs over {} slots; utilization {:.3}; rejected {}",
        report.completed.len(),
        report.slots,
        report.utilization,
        report.rejected
    );
    let mean_flow = report.completed.iter().map(|r| r.flowtime).sum::<f64>()
        / report.completed.len().max(1) as f64;
    println!("mean flowtime: {mean_flow:.2} virtual time units");
    println!("\n--- final metrics ---\n{}", metrics.render());
    Ok(())
}
