//! Live-serving demo: spin up a 2-shard coordinator deployment (one master
//! thread per shard, paced scheduling slots, watermark backpressure, hash
//! routing) and drive it with a bursty Poisson client — the deployable face
//! of the library.  Python is nowhere on this path; with artifacts built,
//! SCA's P2 solves go through PJRT.
//!
//!     cargo run --release --example serve

use std::time::Duration;

use specsim::config::{RoutePolicy, ServeConfig, SimConfig};
use specsim::coordinator::backpressure::Backpressure;
use specsim::coordinator::master::{Submission, SubmitResult};
use specsim::coordinator::shard::ShardedMaster;
use specsim::scheduler::SchedulerKind;
use specsim::stats::Pcg64;

fn main() -> Result<(), String> {
    let mut cfg = SimConfig::default();
    cfg.machines = 128;
    cfg.horizon = f64::INFINITY;
    cfg.scheduler = SchedulerKind::Sda;
    cfg.use_runtime = false;

    let serve = ServeConfig { shards: 2, route: RoutePolicy::Hash, ..Default::default() };
    let mut sharded = ShardedMaster::new(cfg, serve);
    sharded.tick = Duration::from_millis(1); // 1 ms of wall time per slot
    sharded.backpressure = Some(Backpressure::from_capacity(64, 4.0, 12.0));
    sharded.sample_every = Some(Duration::from_millis(50));
    let handle = sharded.spawn()?;

    println!("deployment up: 2 shards x 64 machines, SDA policy, hash routing, 1ms slots");
    let mut rng = Pcg64::new(7, 0);
    let (mut accepted, mut throttled, mut rejected) = (0u32, 0u32, 0u32);
    // two phases: steady trickle, then a burst that trips backpressure
    for phase in 0..2 {
        let (jobs, pause_ms) = if phase == 0 { (150, 2.0) } else { (400, 0.05) };
        for _ in 0..jobs {
            std::thread::sleep(Duration::from_secs_f64(rng.exponential(1000.0 / pause_ms)));
            let sub = Submission {
                num_tasks: rng.uniform_u64(1, 40) as u32,
                mean_duration: rng.uniform_f64(1.0, 4.0),
                alpha: 2.0,
            };
            match handle.submit(sub)? {
                (_, SubmitResult::Accepted { throttled: t, .. }) => {
                    accepted += 1;
                    throttled += t as u32;
                }
                (_, SubmitResult::Rejected) => rejected += 1,
            }
        }
        let queued: i64 =
            (0..handle.shards()).map(|s| handle.metrics(s).gauge("queued_tasks").get()).sum();
        let busy: i64 =
            (0..handle.shards()).map(|s| handle.metrics(s).gauge("busy_machines").get()).sum();
        println!(
            "phase {phase}: accepted={accepted} throttled={throttled} rejected={rejected} \
             queued_tasks={queued} busy={busy}"
        );
    }
    println!("draining...");
    let report = handle.shutdown()?;
    println!(
        "completed {} jobs over {} slots; utilization {:.3}; rejected {}",
        report.completed(),
        report.slots(),
        report.utilization(),
        report.rejected()
    );
    let n_done: usize = report.shards.iter().map(|r| r.completed.len()).sum();
    let mean_flow = report
        .shards
        .iter()
        .flat_map(|r| r.completed.iter())
        .map(|r| r.flowtime)
        .sum::<f64>()
        / n_done.max(1) as f64;
    println!("mean flowtime: {mean_flow:.2} virtual time units");
    print!("\n--- per-shard breakdown ---\n{}", report.table());
    if let Some(series) = &report.series {
        println!("\nsampled {} metric snapshots; aggregate at shutdown:", series.len());
        let agg = series.aggregate_latest();
        for (name, v) in &agg.counters {
            println!("  {name:<24} {v}");
        }
    }
    Ok(())
}
