//! End-to-end driver (Fig. 2): the paper's lightly loaded experiment —
//! SCA and SDA against the Mantri baseline on the full multi-job workload,
//! producing the flowtime/resource CMFs and the headline "~60% lower mean
//! flowtime" comparison.  Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example lightly_loaded            # full scale
//!     SPECSIM_SCALE=0.1 cargo run --release --example lightly_loaded
//!     SPECSIM_THREADS=1 cargo run --release --example lightly_loaded
//!
//! Full scale matches the paper: M = 3000, lambda = 6, horizon 1500,
//! 3 seeds (~27000 jobs).  The experiment is a declarative spec — the grid
//! (3 policies x 3 seeds) runs on the parallel engine, one worker per core
//! unless SPECSIM_THREADS pins it.  Requires `make artifacts` for the PJRT
//! path (falls back to the pure-rust solver with a warning otherwise).

use std::path::Path;

use specsim::experiment::Runner;
use specsim::figures::{fig2, Scale};
use specsim::util::env_or;

fn main() -> Result<(), String> {
    let scale = Scale(env_or("SPECSIM_SCALE", 1.0));
    let mut spec = fig2::spec(scale);
    spec.threads = env_or("SPECSIM_THREADS", 0);
    println!(
        "running Fig. 2 at scale {} — {} grid cells (SPECSIM_SCALE / SPECSIM_THREADS to change)\n",
        scale.0,
        spec.cell_count()
    );
    let sweep = Runner::run(&spec)?;
    fig2::write_outputs(&sweep, Path::new("results"))?;
    println!("\nCSV series: results/fig2a_flowtime_cmf.csv, results/fig2b_resource_cmf.csv");
    Ok(())
}
