//! End-to-end driver (Fig. 2): the paper's lightly loaded experiment —
//! SCA and SDA against the Mantri baseline on the full multi-job workload,
//! producing the flowtime/resource CMFs and the headline "~60% lower mean
//! flowtime" comparison.  Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example lightly_loaded            # full scale
//!     SPECSIM_SCALE=0.1 cargo run --release --example lightly_loaded
//!
//! Full scale matches the paper: M = 3000, lambda = 6, horizon 1500,
//! 3 seeds (~27000 jobs).  Requires `make artifacts` for the PJRT path
//! (falls back to the pure-rust solver with a warning otherwise).

use std::path::Path;

use specsim::figures::{fig2, Scale};

fn main() -> Result<(), String> {
    let scale = std::env::var("SPECSIM_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .map(Scale)
        .unwrap_or(Scale::full());
    println!("running Fig. 2 at scale {} (SPECSIM_SCALE to change)\n", scale.0);
    fig2::run(Path::new("results"), "artifacts", scale)?;
    println!("\nCSV series: results/fig2a_flowtime_cmf.csv, results/fig2b_resource_cmf.csv");
    Ok(())
}
