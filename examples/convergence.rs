//! Fig. 1 driver: convergence of the gradient-projection solver on the
//! paper's 4-job instance, printed as an iteration table and written to
//! results/fig1_convergence.csv.  When artifacts are present the same
//! trace is pulled from the AOT-compiled JAX module and diffed against
//! the rust solver.
//!
//!     cargo run --release --example convergence

use std::path::Path;

use specsim::figures::{fig1, Scale};

fn main() -> Result<(), String> {
    fig1::run(Path::new("results"), "artifacts", Scale::full(), 0)?;
    // print a compact view of the trace
    let trace = fig1::rust_trace();
    println!("\niter   c_l1     c_l2     c_l3     c_l4");
    for k in [0usize, 1, 2, 5, 10, 20, 50, 100, 200, trace.len() - 1] {
        let c = &trace[k];
        println!(
            "{k:>4}  {:>6.3}  {:>6.3}  {:>6.3}  {:>6.3}",
            c[0], c[1], c[2], c[3]
        );
    }
    match fig1::pjrt_trace("artifacts") {
        Ok(pjrt) => {
            let (a, b) = (trace.last().unwrap(), pjrt.last().unwrap());
            println!("\npjrt final:  [{:.3}, {:.3}, {:.3}, {:.3}]", b[0], b[1], b[2], b[3]);
            let max_diff = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max);
            println!("max |rust - pjrt| at convergence: {max_diff:.4}");
        }
        Err(e) => println!("\n(pjrt trace unavailable: {e})"),
    }
    Ok(())
}
