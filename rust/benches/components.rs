//! Component micro-benchmarks (`cargo bench --bench components`): the hot
//! paths of each layer — simulator event throughput, P2 solver latency
//! (rust and PJRT), quadrature kernels, RNG, event queue, machine pool.
//! These numbers anchor EXPERIMENTS.md §Perf.

use specsim::cluster::generator::generate;
use specsim::cluster::sim::Simulator;
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::opt::gradient::{GradientSolver, P2Job, P2Problem};
use specsim::opt::pareto_math;
use specsim::runtime::solver::PjrtP2;
use specsim::scheduler::budget::P2Backend;
use specsim::scheduler::{self, SchedulerKind};
use specsim::stats::{Pareto, Pcg64};
use specsim::util::bench::run;

fn batch_problem(b: usize) -> P2Problem {
    let jobs: Vec<P2Job> = (0..b)
        .map(|i| P2Job {
            mu: 1.0 + (i % 3) as f64 * 0.5,
            m: 5.0 + (i % 20) as f64,
            age: (i % 7) as f64,
        })
        .collect();
    let total: f64 = jobs.iter().map(|j| j.m).sum();
    P2Problem { jobs, n_avail: total * 2.0, gamma: 0.01, r: 8.0, alpha: 2.0 }
}

fn sim_events(
    kind: SchedulerKind,
    machines: usize,
    lambda: f64,
    horizon: f64,
    sched_index: bool,
) -> (u64, f64) {
    let mut cfg = SimConfig::default();
    cfg.machines = machines;
    cfg.horizon = horizon;
    cfg.use_runtime = false;
    cfg.scheduler = kind;
    cfg.sched_index = sched_index;
    let wl = WorkloadConfig::paper(lambda);
    let workload = generate(&wl, cfg.horizon, 1);
    let tasks: u64 = workload.specs.iter().map(|s| s.num_tasks as u64).sum();
    let sched = scheduler::build(&cfg, &wl).unwrap();
    let t0 = std::time::Instant::now();
    let res = Simulator::new(cfg, workload, sched).run();
    let dt = t0.elapsed().as_secs_f64();
    (tasks + res.speculative_launches, dt)
}

fn main() {
    println!("== L3: simulator throughput (SchedIndex hot path vs naive scans) ==");
    for (kind, label) in [
        (SchedulerKind::Naive, "naive"),
        (SchedulerKind::Sda, "sda"),
        (SchedulerKind::Ese, "ese"),
        (SchedulerKind::Sca, "sca(rust)"),
        (SchedulerKind::Mantri, "mantri"),
    ] {
        let (copies, dt) = sim_events(kind, 1000, 2.0, 500.0, true);
        let (_, dt_scan) = sim_events(kind, 1000, 2.0, 500.0, false);
        println!(
            "{label:<12} {copies:>8} task-copies in {dt:>7.3}s  -> {:>10.0} copies/s \
             (scan: {dt_scan:>7.3}s, {:>5.2}x)",
            copies as f64 / dt,
            dt_scan / dt
        );
    }
    println!("(full grid with events/sec + JSON artifact: specsim bench)");
    println!("\n== L3: P2 solver latency (per scheduling slot) ==");
    let mut solver = GradientSolver::default();
    let p64 = batch_problem(64);
    run("rust gradient, B=64 (cold cache)", 0, 1, || {
        GradientSolver::default().solve(&p64).c.len()
    });
    run("rust gradient, B=64 (warm cache)", 2, 20, || {
        solver.solve(&p64).c.len()
    });
    let p8 = batch_problem(8);
    run("rust gradient, B=8 (warm cache)", 2, 50, || solver.solve(&p8).c.len());
    match PjrtP2::load("artifacts") {
        Ok(mut pjrt) => {
            run("pjrt p2_solver, B=64", 2, 20, || pjrt.solve(&p64).len());
            run("pjrt p2_solver, B=8", 2, 20, || pjrt.solve(&p8).len());
        }
        Err(e) => println!("pjrt p2_solver: SKIP ({e})"),
    }
    println!("\n== L1-math twins: quadrature ==");
    run("flow_integral (1024-pt)", 10, 200, || {
        pareto_math::flow_integral(4.0, 50.0)
    });
    run("ese_resource (512x128)", 2, 20, || pareto_math::ese_resource(2.0, 1.7));
    run("sda_tau", 5, 100, || pareto_math::sda_tau(2.0, 0.1, 1.7, 2.0));

    println!("\n== substrates ==");
    let mut rng = Pcg64::new(1, 0);
    run("pcg64 1e6 samples", 2, 20, || {
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        acc
    });
    let pareto = Pareto::new(1.0, 2.0);
    run("pareto 1e6 samples", 2, 10, || {
        let mut acc = 0.0;
        for _ in 0..1_000_000 {
            acc += pareto.sample(&mut rng);
        }
        acc
    });
    run("event queue 1e5 push+pop", 2, 20, || {
        let mut q = specsim::cluster::event::EventQueue::new();
        for i in 0..100_000u32 {
            q.push(
                (i % 977) as f64,
                specsim::cluster::event::Event::Arrival(specsim::cluster::job::JobId(i)),
            );
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        n
    });
}
