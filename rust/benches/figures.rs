//! Per-figure benchmark harness (`cargo bench --bench figures`): runs every
//! paper-figure driver at a reduced scale, timing each and printing the
//! same rows/series the paper reports.  Every driver routes through the
//! parallel experiment engine; set SPECSIM_BENCH_THREADS to compare worker
//! counts (default: one per core).  The full-scale regeneration is
//! `make figures` / `specsim figure all`.

use std::path::Path;
use std::time::Instant;

use specsim::figures::{self, Scale};

fn main() {
    let out = Path::new("results/bench");
    let artifacts = "artifacts";
    let scale = Scale(0.1);
    let threads: usize = specsim::util::env_or("SPECSIM_BENCH_THREADS", 0);
    println!(
        "== figure regeneration at scale {} ({} workers) ==\n",
        scale.0,
        if threads == 0 { "per-core".to_string() } else { threads.to_string() }
    );
    let figs: [(&str, fn(&Path, &str, Scale, usize) -> Result<(), String>); 7] = [
        ("fig1_convergence", figures::fig1::run),
        ("fig2_lightly_loaded", figures::fig2::run),
        ("fig3_sda_sigma", figures::fig3::run),
        ("fig4_sigma_curves", figures::fig4::run),
        ("fig5_single_job", figures::fig5::run),
        ("fig6_heavily_loaded", figures::fig6::run),
        ("threshold", figures::threshold::run),
    ];
    let mut timings = Vec::new();
    for (name, f) in figs {
        let t0 = Instant::now();
        if let Err(e) = f(out, artifacts, scale, threads) {
            println!("{name}: FAILED ({e})");
            continue;
        }
        let dt = t0.elapsed();
        timings.push((name, dt));
        println!("-- {name}: {dt:?}\n");
    }
    println!("== timing summary ==");
    for (name, dt) in &timings {
        println!("{name:<24} {dt:?}");
    }
}
