//! Ablation benches (`cargo bench --bench ablation`): the design choices
//! DESIGN.md §6 calls out — slot granularity, detection fraction s_i,
//! Mantri's kill rule, the small-job cloning gate in ESE, and the P2 batch
//! cap — each declared as an `ExperimentSpec` whose policy axis is the
//! swept knob (a patched variant per value) and run on the parallel
//! engine, all values of one sweep concurrently.

use specsim::config::{SimConfig, WorkloadConfig};
use specsim::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner};
use specsim::scheduler::SchedulerKind;

fn base_cfg() -> SimConfig {
    let mut c = SimConfig::default();
    c.machines = 400;
    c.horizon = 400.0;
    c.use_runtime = false;
    c
}

/// Run one knob sweep: each `(label, variant)` pair is a policy-axis point
/// on the shared workload.
fn sweep(title: &str, wl: &WorkloadConfig, policies: Vec<PolicyVariant>) {
    println!("== {title} ==");
    let mut spec = ExperimentSpec::new(title, base_cfg());
    spec.policies = policies;
    spec.loads = vec![LoadPoint::new("fixed", f64::NAN, wl.clone())];
    let sweep = match Runner::run(&spec) {
        Ok(s) => s,
        Err(e) => {
            println!("  FAILED ({e})");
            return;
        }
    };
    for (pi, (label, _)) in sweep.policies.iter().enumerate() {
        let res = sweep.merged(pi, 0);
        println!(
            "{label:<28} mean_ft={:>7.3} mean_res={:>7.4} backups={:>7} util={:.3}",
            res.mean_flowtime(),
            res.mean_resource(),
            res.speculative_launches,
            res.utilization
        );
    }
}

fn main() {
    let light = WorkloadConfig::paper(0.8);
    let heavy = WorkloadConfig::paper(5.0);

    sweep(
        "slot granularity (SDA, light load)",
        &light,
        [0.25, 0.5, 1.0, 2.0, 4.0]
            .into_iter()
            .map(|dt| {
                PolicyVariant::patched(format!("slot_dt={dt}"), SchedulerKind::Sda, move |c| {
                    c.slot_dt = dt
                })
                .at_x(dt)
            })
            .collect(),
    );

    sweep(
        "detection fraction s_i (SDA, light load)",
        &light,
        [0.05, 0.1, 0.2, 0.4, 0.6]
            .into_iter()
            .map(|s| {
                PolicyVariant::patched(format!("detect_frac={s}"), SchedulerKind::Sda, move |c| {
                    c.detect_frac = s
                })
                .at_x(s)
            })
            .collect(),
    );

    println!("\n(Mantri kill rule: expected no-op here — with the blind estimator,");
    println!(" duplication at e > 2E[x] always fires before kill-eligibility at");
    println!(" e > 3E[x]; the rule only matters when the cluster stays saturated");
    println!(" for >E[x] at a stretch)");
    sweep(
        "Mantri kill rule (heavy load)",
        &heavy,
        [false, true]
            .into_iter()
            .map(|kill| {
                PolicyVariant::patched(
                    format!("mantri_kill={kill}"),
                    SchedulerKind::Mantri,
                    move |c| c.mantri_kill = kill,
                )
            })
            .collect(),
    );

    println!("\n(ESE small-job gate: at full saturation level 3 sees idle ~ 0, so");
    println!(" the gate rarely fires — its benefit shows at moderate overload,");
    println!(" cf. fig6 @30)");
    sweep(
        "ESE small-job cloning gate (heavy load)",
        &heavy,
        [0.0, 0.05, 0.1, 0.2, 0.4]
            .into_iter()
            .map(|eta| {
                PolicyVariant::patched(format!("eta_small={eta}"), SchedulerKind::Ese, move |c| {
                    c.sigma = Some(1.7);
                    c.eta_small = eta;
                })
                .at_x(eta)
            })
            .collect(),
    );

    sweep(
        "ESE sigma (heavy load; analysis optimum ~1.7)",
        &heavy,
        [1.0, 1.7, 2.5, 4.0]
            .into_iter()
            .map(|sigma| PolicyVariant::with_sigma(SchedulerKind::Ese, sigma))
            .collect(),
    );

    sweep(
        "SCA P2 batch cap (light load)",
        &light,
        [8usize, 16, 32, 64]
            .into_iter()
            .map(|batch| {
                PolicyVariant::patched(format!("p2_batch={batch}"), SchedulerKind::Sca, move |c| {
                    c.p2_batch = batch
                })
                .at_x(batch as f64)
            })
            .collect(),
    );

    sweep(
        "LATE speculative cap (light load)",
        &light,
        [0.02, 0.1, 0.3]
            .into_iter()
            .map(|cap| {
                PolicyVariant::patched(format!("late_cap={cap}"), SchedulerKind::Late, move |c| {
                    c.late_speculative_cap = cap
                })
                .at_x(cap)
            })
            .collect(),
    );
}
