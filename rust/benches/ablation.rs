//! Ablation benches (`cargo bench --bench ablation`): the design choices
//! DESIGN.md §6 calls out — slot granularity, detection fraction s_i,
//! Mantri's kill rule, the small-job cloning gate in ESE, and the P2 batch
//! cap — each swept on a fixed workload with the figure-style summary.

use specsim::cluster::generator::generate;
use specsim::cluster::sim::{SimResult, Simulator};
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::scheduler::{self, SchedulerKind};

fn base_cfg() -> SimConfig {
    let mut c = SimConfig::default();
    c.machines = 400;
    c.horizon = 400.0;
    c.use_runtime = false;
    c
}

fn run(cfg: &SimConfig, wl: &WorkloadConfig) -> SimResult {
    let workload = generate(wl, cfg.horizon, cfg.seed);
    let sched = scheduler::build(cfg, wl).unwrap();
    Simulator::new(cfg.clone(), workload, sched).run()
}

fn row(label: &str, res: &SimResult) {
    println!(
        "{label:<28} mean_ft={:>7.3} mean_res={:>7.4} backups={:>7} util={:.3}",
        res.mean_flowtime(),
        res.mean_resource(),
        res.speculative_launches,
        res.utilization
    );
}

fn main() {
    let light = WorkloadConfig::paper(0.8);
    let heavy = WorkloadConfig::paper(5.0);

    println!("== slot granularity (SDA, light load) ==");
    for dt in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let mut c = base_cfg();
        c.scheduler = SchedulerKind::Sda;
        c.slot_dt = dt;
        row(&format!("slot_dt={dt}"), &run(&c, &light));
    }

    println!("\n== detection fraction s_i (SDA, light load) ==");
    for s in [0.05, 0.1, 0.2, 0.4, 0.6] {
        let mut c = base_cfg();
        c.scheduler = SchedulerKind::Sda;
        c.detect_frac = s;
        row(&format!("detect_frac={s}"), &run(&c, &light));
    }

    println!("\n== Mantri kill rule (heavy load) ==");
    println!("(expected no-op here: with the blind estimator, duplication at");
    println!(" e > 2E[x] always fires before kill-eligibility at e > 3E[x] —");
    println!(" measured 0 kill-eligible occurrences; the rule only matters");
    println!(" when the cluster stays saturated for >E[x] at a stretch)");
    for kill in [false, true] {
        let mut c = base_cfg();
        c.scheduler = SchedulerKind::Mantri;
        c.mantri_kill = kill;
        row(&format!("mantri_kill={kill}"), &run(&c, &heavy));
    }

    println!("\n== ESE small-job cloning gate (heavy load) ==");
    println!("(at full saturation level 3 sees idle ~ 0, so the gate rarely");
    println!(" fires — its benefit shows at moderate overload, cf. fig6 @30)");
    for eta in [0.0, 0.05, 0.1, 0.2, 0.4] {
        let mut c = base_cfg();
        c.scheduler = SchedulerKind::Ese;
        c.sigma = Some(1.7);
        c.eta_small = eta;
        row(&format!("eta_small={eta}"), &run(&c, &heavy));
    }

    println!("\n== ESE sigma (heavy load; analysis optimum ~1.7) ==");
    for sigma in [1.0, 1.7, 2.5, 4.0] {
        let mut c = base_cfg();
        c.scheduler = SchedulerKind::Ese;
        c.sigma = Some(sigma);
        row(&format!("sigma={sigma}"), &run(&c, &heavy));
    }

    println!("\n== SCA P2 batch cap (light load) ==");
    for batch in [8, 16, 32, 64] {
        let mut c = base_cfg();
        c.scheduler = SchedulerKind::Sca;
        c.p2_batch = batch;
        row(&format!("p2_batch={batch}"), &run(&c, &light));
    }

    println!("\n== LATE speculative cap (light load) ==");
    for cap in [0.02, 0.1, 0.3] {
        let mut c = base_cfg();
        c.scheduler = SchedulerKind::Late;
        c.late_speculative_cap = cap;
        row(&format!("late_cap={cap}"), &run(&c, &light));
    }
}
