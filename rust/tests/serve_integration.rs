//! Integration tests for the sharded serve plane (DESIGN.md §15):
//! overload shedding under each routing policy, whole-deployment
//! determinism, and bitwise 1-shard parity with the plain `Master` —
//! plus the self-healing supervisor (DESIGN.md §17): crashed shards
//! respawn and replay their in-flight ledger, down shards are excluded
//! from routing until recovery, and exhausted budgets / shed watermarks
//! yield structured `Shed` verdicts instead of errors or hangs.
//!
//! Every test uses the long-tick trick: with an hour-long tick no slot
//! boundary fires while submissions stream in, so the per-shard
//! `queued_tasks` gauge stays frozen, admission is a pure function of the
//! submission order, and the post-shutdown drain runs at full CPU.

use std::time::{Duration, Instant};

use specsim::config::{RoutePolicy, ServeConfig, SimConfig};
use specsim::coordinator::backpressure::Backpressure;
use specsim::coordinator::master::{Master, Submission, SubmitResult};
use specsim::coordinator::shard::{ShardedHandle, ShardedMaster};
use specsim::scheduler::SchedulerKind;
use specsim::stats::Pcg64;

/// Crash shard `shard` and wait for its liveness flag to drop (the crash
/// message is asynchronous).
fn crash_and_wait(handle: &ShardedHandle, shard: usize) {
    handle.inject_crash(shard).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while handle.shard_alive(shard) && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(!handle.shard_alive(shard), "shard {shard} never died");
}

fn base_cfg(machines: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.machines = machines;
    cfg.horizon = f64::INFINITY;
    cfg.use_runtime = false;
    cfg.scheduler = SchedulerKind::Sda;
    cfg
}

/// A 2-shard deployment with tight watermarks and frozen slots, ready to
/// be flooded.
fn flood_deployment(route: RoutePolicy) -> ShardedMaster {
    let mut sm = ShardedMaster::new(
        base_cfg(8),
        ServeConfig { shards: 2, route, ..Default::default() },
    );
    sm.tick = Duration::from_secs(3600);
    sm.drain_slots = 50;
    sm.backpressure = Some(Backpressure::new(8, 16));
    sm
}

fn same_sub() -> Submission {
    Submission { num_tasks: 4, mean_duration: 5.0, alpha: 2.0 }
}

#[test]
fn hash_flood_confines_rejects_to_one_shard() {
    // identical submissions hash to one shard, so the flood must trip that
    // shard's high watermark while the other shard never sees traffic
    let handle = flood_deployment(RoutePolicy::Hash).spawn().unwrap();
    let subs = vec![same_sub(); 200];
    let results = handle.submit_batch(&subs).unwrap();
    let hot = results[0].0;
    assert!(results.iter().all(|&(s, _)| s == hot), "hash pins one shard");
    let accepted = results.iter().filter(|(_, r)| r.is_accepted()).count();
    assert_eq!(accepted, 4, "4 jobs x 4 tasks reach high watermark 16");
    let rep = handle.shutdown().unwrap();
    assert_eq!(rep.rejected(), 196);
    assert_eq!(rep.shards[hot].rejected, 196, "rejects stay on the hot shard");
    let cold = 1 - hot;
    assert_eq!(rep.shards[cold].rejected, 0);
    assert_eq!(rep.shards[cold].completed.len(), 0, "cold shard saw nothing");
}

#[test]
fn p2c_flood_spreads_rejects_across_shards() {
    // with frozen gauges p2c ties on every comparison and degrades to a
    // uniform first draw, so the same flood lands on both shards and both
    // trip their watermarks
    let handle = flood_deployment(RoutePolicy::P2c).spawn().unwrap();
    let subs = vec![same_sub(); 300];
    let results = handle.submit_batch(&subs).unwrap();
    let to_shard_1 = results.iter().filter(|&&(s, _)| s == 1).count();
    assert!(to_shard_1 > 0 && to_shard_1 < 300, "p2c spreads the flood");
    let rep = handle.shutdown().unwrap();
    assert!(rep.shards[0].rejected > 0, "shard 0 must shed load");
    assert!(rep.shards[1].rejected > 0, "shard 1 must shed load");
    let accepted = results.iter().filter(|(_, r)| r.is_accepted()).count();
    assert_eq!(accepted as u64 + rep.rejected(), 300);
}

/// Varied workload for the determinism runs.
fn varied_subs(n: usize) -> Vec<Submission> {
    let mut rng = Pcg64::new(5, 77);
    (0..n)
        .map(|_| Submission {
            num_tasks: rng.uniform_u64(1, 8) as u32,
            mean_duration: rng.uniform_f64(1.0, 2.0),
            alpha: 2.0,
        })
        .collect()
}

#[test]
fn same_seed_and_policy_replays_identical_shard_decisions() {
    for route in [RoutePolicy::Hash, RoutePolicy::P2c] {
        let run = || -> Vec<(usize, bool)> {
            let handle = flood_deployment(route).spawn().unwrap();
            let results = handle.submit_batch(&varied_subs(60)).unwrap();
            let out =
                results.iter().map(|&(shard, r)| (shard, r.is_accepted())).collect();
            let _ = handle.shutdown();
            out
        };
        assert_eq!(
            run(),
            run(),
            "same seed + {route} routing must replay the exact per-shard \
             accept/reject sequence"
        );
    }
}

/// The headline fault-tolerance bar: a batch that lands on a crashed
/// master is not lost — the supervisor respawns the shard, replays the
/// in-flight ledger, and every submission is accepted and completes.
#[test]
fn crashed_shard_restarts_and_replays_the_inflight_ledger() {
    let mut sm = ShardedMaster::new(base_cfg(16), ServeConfig::default());
    sm.tick = Duration::from_micros(200);
    let handle = sm.spawn().unwrap();
    crash_and_wait(&handle, 0);
    assert_eq!(handle.metrics(0).counter("master_panics").get(), 1);
    // the next routed batch hits the corpse: the supervisor must respawn
    // the shard and replay the ledger, never surfacing the crash
    let subs: Vec<Submission> = (0..20)
        .map(|_| Submission { num_tasks: 5, mean_duration: 1.0, alpha: 2.0 })
        .collect();
    let results = handle.submit_batch(&subs).unwrap();
    assert_eq!(results.len(), 20);
    assert!(
        results.iter().all(|(_, r)| r.is_accepted()),
        "the replayed ledger must be admitted in full: {results:?}"
    );
    assert!(handle.shard_alive(0), "the supervisor must have respawned the shard");
    assert_eq!(handle.restarts(0), 1);
    assert_eq!(handle.metrics(0).counter("master_restarts").get(), 1);
    let rep = handle.shutdown().unwrap();
    assert_eq!(rep.panicked(), 0, "the respawned shard drains cleanly");
    assert_eq!(rep.completed(), 20, "no accepted submission is lost to the crash");
}

/// Routing degrades gracefully around a dead shard: picks that would land
/// on it divert to live shards (no shed, no error), and the shard is only
/// resurrected when the delivery path actually needs it — after which it
/// is re-included in the picks.
#[test]
fn down_shard_is_excluded_from_routing_and_recovery_reincludes_it() {
    let mut sm = ShardedMaster::new(
        base_cfg(32),
        ServeConfig { shards: 2, ..Default::default() },
    );
    sm.tick = Duration::from_secs(3600);
    sm.drain_slots = 50;
    let handle = sm.spawn().unwrap();
    // identical submissions pin one shard under hash routing
    let results = handle.submit_batch(&vec![same_sub(); 10]).unwrap();
    let hot = results[0].0;
    assert!(results.iter().all(|&(s, r)| s == hot && r.is_accepted()));
    let cold = 1 - hot;

    crash_and_wait(&handle, hot);
    let diverted = handle.submit_batch(&vec![same_sub(); 10]).unwrap();
    assert!(
        diverted.iter().all(|&(s, r)| s == cold && r.is_accepted()),
        "picks must probe past the dead shard to the live one: {diverted:?}"
    );
    assert_eq!(handle.restarts(hot), 0, "an excluded shard is not restarted");

    // with *every* shard down the router falls back to the raw pick, which
    // forces the supervisor to resurrect that shard and replay the batch
    crash_and_wait(&handle, cold);
    let (shard, result) = handle.submit(same_sub()).unwrap();
    assert_eq!(shard, hot, "the raw hash pick is the restart target");
    assert!(result.is_accepted(), "the resurrected shard admits the replay");
    assert!(handle.shard_alive(hot));
    assert_eq!(handle.restarts(hot), 1);
    // recovery re-includes the shard: the same shape routes to it again
    let again = handle.submit_batch(&vec![same_sub(); 5]).unwrap();
    assert!(
        again.iter().all(|&(s, r)| s == hot && r.is_accepted()),
        "a recovered shard takes its hash traffic back: {again:?}"
    );
    let rep = handle.shutdown().unwrap();
    assert_eq!(rep.panicked(), 1, "only the still-dead cold shard is a tombstone");
}

/// Exhausting the restart budget sheds the in-flight ledger with one
/// structured verdict per submission — never an `Err`, never a hang.
#[test]
fn exhausted_restart_budget_sheds_the_ledger_with_structured_rejects() {
    let mut sm = ShardedMaster::new(base_cfg(8), ServeConfig::default());
    sm.tick = Duration::from_secs(3600);
    sm.drain_slots = 50;
    sm.max_restarts = 0;
    let handle = sm.spawn().unwrap();
    crash_and_wait(&handle, 0);
    let results = handle.submit_batch(&vec![same_sub(); 7]).unwrap();
    assert_eq!(results.len(), 7);
    assert!(
        results.iter().all(|&(_, r)| r == SubmitResult::Shed),
        "an abandoned shard sheds, it does not error: {results:?}"
    );
    assert_eq!(handle.metrics(0).counter("jobs_shed").get(), 7);
    assert!(!handle.shard_alive(0));
    let rep = handle.shutdown().unwrap();
    assert_eq!(rep.panicked(), 1, "the abandoned shard reports a tombstone");
    assert_eq!(rep.completed(), 0);
}

/// The shed watermark is a front-door fast path: a shard whose backlog
/// gauge reads past it sheds instantly (no channel round trip), and
/// dropping back below the mark restores normal admission.
#[test]
fn shed_watermark_sheds_past_the_mark_and_readmits_below_it() {
    let mut sm = ShardedMaster::new(base_cfg(8), ServeConfig::default());
    sm.tick = Duration::from_secs(3600);
    sm.drain_slots = 50;
    sm.shed_watermark = Some(100);
    let handle = sm.spawn().unwrap();
    // freeze the backlog gauge above the mark (the long tick means the
    // master never rewrites it mid-test)
    handle.metrics(0).gauge("queued_tasks").set(1000);
    let results = handle.submit_batch(&vec![same_sub(); 5]).unwrap();
    assert!(
        results.iter().all(|&(_, r)| r == SubmitResult::Shed),
        "overload must shed with a structured verdict: {results:?}"
    );
    assert_eq!(handle.metrics(0).counter("jobs_shed").get(), 5);
    handle.metrics(0).gauge("queued_tasks").set(0);
    let results = handle.submit_batch(&vec![same_sub(); 3]).unwrap();
    assert!(
        results.iter().all(|(_, r)| r.is_accepted()),
        "below the mark the front door reopens: {results:?}"
    );
    let _ = handle.shutdown();
}

#[test]
fn single_shard_is_bit_identical_to_plain_master() {
    // same cfg, same seed, same frozen-slot submissions: the 1-shard
    // deployment must produce the plain master's exact job records
    let subs = varied_subs(20);
    let mut master = Master::new(base_cfg(16));
    master.tick = Duration::from_secs(3600);
    master.drain_slots = 10_000;
    let handle = master.spawn().unwrap();
    let plain_results = handle.submit_batch(subs.clone()).unwrap();
    let plain = handle.shutdown().unwrap();

    let mut sm = ShardedMaster::new(base_cfg(16), ServeConfig::default());
    sm.tick = Duration::from_secs(3600);
    sm.drain_slots = 10_000;
    let handle = sm.spawn().unwrap();
    assert_eq!(handle.shards(), 1);
    let sharded_results = handle.submit_batch(&subs).unwrap();
    let sharded = handle.shutdown().unwrap();

    assert_eq!(
        plain_results,
        sharded_results.iter().map(|&(_, r)| r).collect::<Vec<_>>(),
        "admission decisions must match"
    );
    assert!(sharded_results.iter().all(|&(s, _)| s == 0));
    assert_eq!(sharded.shards.len(), 1);
    assert_eq!(plain.machines, sharded.shards[0].machines);
    assert_eq!(plain.rejected, sharded.shards[0].rejected);
    assert_eq!(
        plain.completed, sharded.shards[0].completed,
        "1-shard deployment must replay the plain master's job records bitwise"
    );
    assert!(!plain.completed.is_empty(), "the parity set must be non-trivial");
}
