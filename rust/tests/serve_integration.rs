//! Integration tests for the sharded serve plane (DESIGN.md §15):
//! overload shedding under each routing policy, whole-deployment
//! determinism, and bitwise 1-shard parity with the plain `Master`.
//!
//! Every test uses the long-tick trick: with an hour-long tick no slot
//! boundary fires while submissions stream in, so the per-shard
//! `queued_tasks` gauge stays frozen, admission is a pure function of the
//! submission order, and the post-shutdown drain runs at full CPU.

use std::time::Duration;

use specsim::config::{RoutePolicy, ServeConfig, SimConfig};
use specsim::coordinator::backpressure::Backpressure;
use specsim::coordinator::master::{Master, Submission};
use specsim::coordinator::shard::ShardedMaster;
use specsim::scheduler::SchedulerKind;
use specsim::stats::Pcg64;

fn base_cfg(machines: usize) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.machines = machines;
    cfg.horizon = f64::INFINITY;
    cfg.use_runtime = false;
    cfg.scheduler = SchedulerKind::Sda;
    cfg
}

/// A 2-shard deployment with tight watermarks and frozen slots, ready to
/// be flooded.
fn flood_deployment(route: RoutePolicy) -> ShardedMaster {
    let mut sm = ShardedMaster::new(
        base_cfg(8),
        ServeConfig { shards: 2, route, ..Default::default() },
    );
    sm.tick = Duration::from_secs(3600);
    sm.drain_slots = 50;
    sm.backpressure = Some(Backpressure::new(8, 16));
    sm
}

fn same_sub() -> Submission {
    Submission { num_tasks: 4, mean_duration: 5.0, alpha: 2.0 }
}

#[test]
fn hash_flood_confines_rejects_to_one_shard() {
    // identical submissions hash to one shard, so the flood must trip that
    // shard's high watermark while the other shard never sees traffic
    let handle = flood_deployment(RoutePolicy::Hash).spawn().unwrap();
    let subs = vec![same_sub(); 200];
    let results = handle.submit_batch(&subs).unwrap();
    let hot = results[0].0;
    assert!(results.iter().all(|&(s, _)| s == hot), "hash pins one shard");
    let accepted = results.iter().filter(|(_, r)| r.is_accepted()).count();
    assert_eq!(accepted, 4, "4 jobs x 4 tasks reach high watermark 16");
    let rep = handle.shutdown().unwrap();
    assert_eq!(rep.rejected(), 196);
    assert_eq!(rep.shards[hot].rejected, 196, "rejects stay on the hot shard");
    let cold = 1 - hot;
    assert_eq!(rep.shards[cold].rejected, 0);
    assert_eq!(rep.shards[cold].completed.len(), 0, "cold shard saw nothing");
}

#[test]
fn p2c_flood_spreads_rejects_across_shards() {
    // with frozen gauges p2c ties on every comparison and degrades to a
    // uniform first draw, so the same flood lands on both shards and both
    // trip their watermarks
    let handle = flood_deployment(RoutePolicy::P2c).spawn().unwrap();
    let subs = vec![same_sub(); 300];
    let results = handle.submit_batch(&subs).unwrap();
    let to_shard_1 = results.iter().filter(|&&(s, _)| s == 1).count();
    assert!(to_shard_1 > 0 && to_shard_1 < 300, "p2c spreads the flood");
    let rep = handle.shutdown().unwrap();
    assert!(rep.shards[0].rejected > 0, "shard 0 must shed load");
    assert!(rep.shards[1].rejected > 0, "shard 1 must shed load");
    let accepted = results.iter().filter(|(_, r)| r.is_accepted()).count();
    assert_eq!(accepted as u64 + rep.rejected(), 300);
}

/// Varied workload for the determinism runs.
fn varied_subs(n: usize) -> Vec<Submission> {
    let mut rng = Pcg64::new(5, 77);
    (0..n)
        .map(|_| Submission {
            num_tasks: rng.uniform_u64(1, 8) as u32,
            mean_duration: rng.uniform_f64(1.0, 2.0),
            alpha: 2.0,
        })
        .collect()
}

#[test]
fn same_seed_and_policy_replays_identical_shard_decisions() {
    for route in [RoutePolicy::Hash, RoutePolicy::P2c] {
        let run = || -> Vec<(usize, bool)> {
            let handle = flood_deployment(route).spawn().unwrap();
            let results = handle.submit_batch(&varied_subs(60)).unwrap();
            let out =
                results.iter().map(|&(shard, r)| (shard, r.is_accepted())).collect();
            let _ = handle.shutdown();
            out
        };
        assert_eq!(
            run(),
            run(),
            "same seed + {route} routing must replay the exact per-shard \
             accept/reject sequence"
        );
    }
}

#[test]
fn single_shard_is_bit_identical_to_plain_master() {
    // same cfg, same seed, same frozen-slot submissions: the 1-shard
    // deployment must produce the plain master's exact job records
    let subs = varied_subs(20);
    let mut master = Master::new(base_cfg(16));
    master.tick = Duration::from_secs(3600);
    master.drain_slots = 10_000;
    let handle = master.spawn().unwrap();
    let plain_results = handle.submit_batch(subs.clone()).unwrap();
    let plain = handle.shutdown().unwrap();

    let mut sm = ShardedMaster::new(base_cfg(16), ServeConfig::default());
    sm.tick = Duration::from_secs(3600);
    sm.drain_slots = 10_000;
    let handle = sm.spawn().unwrap();
    assert_eq!(handle.shards(), 1);
    let sharded_results = handle.submit_batch(&subs).unwrap();
    let sharded = handle.shutdown().unwrap();

    assert_eq!(
        plain_results,
        sharded_results.iter().map(|&(_, r)| r).collect::<Vec<_>>(),
        "admission decisions must match"
    );
    assert!(sharded_results.iter().all(|&(s, _)| s == 0));
    assert_eq!(sharded.shards.len(), 1);
    assert_eq!(plain.machines, sharded.shards[0].machines);
    assert_eq!(plain.rejected, sharded.shards[0].rejected);
    assert_eq!(
        plain.completed, sharded.shards[0].completed,
        "1-shard deployment must replay the plain master's job records bitwise"
    );
    assert!(!plain.completed.is_empty(), "the parity set must be non-trivial");
}
