//! Integration tests for the estimator subsystem on the server-dependent
//! slowdown axis: SDA must relaunch a copy stuck on a *degraded* host
//! (hidden slowdown — a real straggler) while the speed-aware estimator
//! suppresses the false positive a merely slow-*class* host would raise;
//! and the `--slowdown` scenario must separate Mantri from ESE in a sweep.

use specsim::cluster::job::{JobId, JobSpec, TaskRef};
use specsim::cluster::machine::{MachineClass, SlowdownConfig};
use specsim::cluster::sim::{Cluster, Simulator, Workload};
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::estimator;
use specsim::experiment::{ClusterScenario, ExperimentSpec, LoadPoint, PolicyVariant, Runner};
use specsim::metrics::report;
use specsim::scheduler::budget::CapBudget;
use specsim::scheduler::rule::{Sda, SpeculationRule};
use specsim::scheduler::SchedulerKind;
use specsim::stats::Pareto;

fn task0() -> TaskRef {
    TaskRef { job: JobId(0), task: 0 }
}

/// One job with a single task of controlled work (`E[x]` = 1), launched at
/// t = 0 on the first machine of the configured cluster.
fn one_task_cluster(cfg: SimConfig, work: f64) -> Cluster {
    let dist = Pareto::from_mean(1.0, 2.0);
    let wl = Workload {
        specs: vec![JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: 1 }],
        first_durations: vec![vec![work]],
    };
    let sched = specsim::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
    let mut sim = Simulator::new(cfg, wl, sched);
    assert!(sim.cluster.launch_copy(task0()));
    sim.cluster
}

/// Drive the copy to its reveal by hand (deterministic, no event loop) and
/// return (stragglers detected, copies of the task afterwards).  The SDA
/// decision core is the pipeline's `rule::Sda`, wired to the estimator
/// the pipeline would select for `cfg` and its Theorem-3 `cap2` budget.
fn reveal_under_sda(cfg: &SimConfig, mut cl: Cluster, sda: &mut Sda, at: f64) -> (u64, usize) {
    let est = estimator::for_policy(cfg, true);
    let budget = CapBudget { copies: 2 };
    cl.clock = at;
    let cid = cl.arena.copy_id(cl.tid(task0()), 0);
    cl.arena.set_revealed(cid);
    sda.on_reveal(&mut cl, est.as_ref(), &budget, task0());
    (sda.detected, cl.n_copies(task0()) as usize)
}

/// A slow-*class* host (advertised speed 0.5, healthy): the copy's
/// wall-clock remaining looks 2x inflated, but the speed-aware estimator
/// normalizes by the public class speed and correctly stays quiet, while
/// the unit-naive estimator raises a false positive.
#[test]
fn speed_aware_sda_suppresses_slow_class_false_positive() {
    let base = {
        let mut cfg = SimConfig::default();
        // machine 0 (allocated first) is the slow class
        cfg.set_machine_classes(vec![MachineClass::new(1, 0.5), MachineClass::new(4, 1.0)]);
        cfg.sigma = Some(1.0); // threshold = sigma * E[x] = 1 work unit
        cfg.use_runtime = false;
        cfg
    };
    // work 1.0 on a 0.5x host: wall duration 2.0; at t = 0.2 the true
    // remaining work is 0.9 (< 1) but the raw wall-clock remaining is 1.8
    let aware = {
        let mut s = Sda::new(&base, 2.0);
        reveal_under_sda(&base, one_task_cluster(base.clone(), 1.0), &mut s, 0.2)
    };
    assert_eq!(aware, (0, 1), "speed-aware SDA must not speculate on a slow-class host");
    let naive_units = {
        let mut cfg = base;
        cfg.speed_aware = false;
        let mut s = Sda::new(&cfg, 2.0);
        reveal_under_sda(&cfg, one_task_cluster(cfg.clone(), 1.0), &mut s, 0.2)
    };
    assert_eq!(
        naive_units,
        (1, 2),
        "the unit-naive estimator conflates class speed with straggling"
    );
}

/// A *degraded* host (hidden 4x slowdown on a speed-1 class): the revealed
/// remaining time is genuinely inflated, the speed-aware estimator cannot
/// (and must not) explain it away, and SDA relaunches.
#[test]
fn sda_relaunches_copy_stuck_on_slowed_host() {
    let mut cfg = SimConfig::default();
    cfg.machines = 5;
    // frac = 1.0: every machine degraded, so the test is deterministic
    cfg.slowdown = Some(SlowdownConfig::new(1.0, 4.0));
    cfg.sigma = Some(1.0);
    cfg.use_runtime = false;
    let mut sda = Sda::new(&cfg, 2.0);
    // work 1.0 at effective speed 1/4: wall duration 4.0; at t = 0.4 the
    // apparent remaining work is 3.6 >> 1 — a detectable straggler
    let cl = one_task_cluster(cfg.clone(), 1.0);
    assert_eq!(cl.copy(task0(), 0).duration, 4.0);
    let (detected, copies) = reveal_under_sda(&cfg, cl, &mut sda, 0.4);
    assert_eq!(detected, 1, "SDA must detect the slowed host's straggler");
    assert_eq!(copies, 2, "SDA must have launched a backup copy");
    assert_eq!(sda.backups, 1);
}

fn slowdown_spec(threads: usize) -> ExperimentSpec {
    let mut cfg = SimConfig::default();
    cfg.machines = 100;
    cfg.horizon = 150.0;
    cfg.use_runtime = false;
    cfg.mantri_srpt = true; // like-for-like baseline (see fig6.rs)
    let mut spec = ExperimentSpec::new("slowdown", cfg);
    spec.scenario = ClusterScenario::homogeneous().with_slowdown(SlowdownConfig::new(0.3, 4.0));
    spec.policies = vec![
        PolicyVariant::kind(SchedulerKind::Mantri),
        PolicyVariant::kind(SchedulerKind::Ese),
    ];
    spec.loads = vec![LoadPoint::lambda(0.5)];
    spec.seeds = vec![1];
    spec.threads = threads;
    spec
}

/// The acceptance bar for the `--slowdown` axis: the same degraded cluster
/// produces different flowtime under Mantri (blind) and ESE
/// (checkpoint-instrumented), and the sweep stays deterministic across
/// worker counts.
#[test]
fn slowdown_separates_mantri_from_ese() {
    let sweep = Runner::run(&slowdown_spec(1)).unwrap();
    let mantri = sweep.merged(0, 0);
    let ese = sweep.merged(1, 0);
    assert!(!mantri.completed.is_empty());
    assert!(!ese.completed.is_empty());
    assert!(
        (mantri.mean_flowtime() - ese.mean_flowtime()).abs() > 1e-9,
        "slowdown should separate mantri ({}) from ese ({})",
        mantri.mean_flowtime(),
        ese.mean_flowtime()
    );
    // parallel determinism must hold on the slowdown axis too
    let a = report::sweep_csv(&sweep);
    let b = report::sweep_csv(&Runner::run(&slowdown_spec(4)).unwrap());
    assert_eq!(a, b);
}

/// Slowing 30% of the machines 4x must hurt: the naive baseline's mean
/// flowtime strictly increases relative to the healthy cluster.
#[test]
fn slowdown_degrades_the_naive_baseline() {
    let run = |slowdown: Option<SlowdownConfig>| {
        let mut spec = slowdown_spec(2);
        spec.scenario = ClusterScenario::default();
        if let Some(sd) = slowdown {
            spec.scenario = spec.scenario.with_slowdown(sd);
        }
        spec.policies = vec![PolicyVariant::kind(SchedulerKind::Naive)];
        Runner::run(&spec).unwrap().merged(0, 0).mean_flowtime()
    };
    let healthy = run(None);
    let degraded = run(Some(SlowdownConfig::new(0.3, 4.0)));
    assert!(
        degraded > healthy,
        "degraded cluster should be slower: {degraded} vs {healthy}"
    );
}

/// Satellite (ON/OFF flips): stage the exact degraded-then-recovered
/// history the simulator pins in its `flip_retimes_running_copy_exactly`
/// test — degrade 4x at t = 1, reveal on the re-timed checkpoint at
/// t = 5, recover at t = 6 — and show the estimator crossover at the
/// recovery flip's re-detect.  The advertised-speed SDA trusts the
/// now-healthy host (5.75 work units remaining < threshold 10) and stays
/// quiet; the observed-speed SDA projects by the host's measured
/// lifetime throughput (0.375x advertised, so 15.33 units) and
/// relaunches.  This is the in-flight rescheduling the flip axis buys.
#[test]
fn observed_speed_sda_relaunches_after_recovery_where_advertised_does_not() {
    let base = {
        let mut cfg = SimConfig::default();
        cfg.machines = 2;
        cfg.detect_frac = 0.25;
        cfg.sigma = Some(10.0); // threshold = 10 work units (E[x] = 1)
        cfg.use_runtime = false;
        // frac 0 + zero rates: nothing starts degraded and no dwell
        // stream exists — the flips below are driven by hand
        cfg.slowdown = Some(SlowdownConfig::new(0.0, 4.0));
        cfg
    };
    let dist = Pareto::from_mean(1.0, 2.0);
    let wl = Workload {
        specs: vec![JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: 1 }],
        first_durations: vec![vec![8.0]],
    };
    let sched = specsim::scheduler::build(&base, &WorkloadConfig::paper(1.0)).unwrap();
    let mut driver = specsim::scheduler::build(&base, &WorkloadConfig::paper(1.0)).unwrap();
    let mut cl = Simulator::new(base.clone(), wl, sched).cluster;
    cl.advance_to(0.0, driver.as_mut()); // the arrival fires
    assert!(cl.launch_copy(task0()));
    cl.advance_to(1.0, driver.as_mut());
    assert_eq!(cl.flip_machine(0), None, "unrevealed copies never re-detect");
    cl.advance_to(5.0, driver.as_mut()); // the re-timed checkpoint reveals
    assert!(cl.copy(task0(), 0).revealed);
    cl.advance_to(6.0, driver.as_mut());
    assert_eq!(
        cl.flip_machine(0),
        Some(task0()),
        "the recovery flip must hand the revealed copy back to the detector"
    );
    let budget = CapBudget { copies: 2 };
    let advertised = estimator::for_policy(&base, true);
    assert_eq!(advertised.name(), "speed_aware");
    let mut sda = Sda::new(&base, 2.0);
    sda.on_reveal(&mut cl, advertised.as_ref(), &budget, task0());
    assert_eq!(
        (sda.detected, cl.n_copies(task0())),
        (0, 1),
        "advertised-speed SDA trusts the recovered host"
    );
    let mut obs_cfg = base.clone();
    obs_cfg.observed_speed = true;
    let observed = estimator::for_policy(&obs_cfg, true);
    assert_eq!(observed.name(), "speed_aware_observed");
    let mut sda = Sda::new(&obs_cfg, 2.0);
    sda.on_reveal(&mut cl, observed.as_ref(), &budget, task0());
    assert_eq!(
        (sda.detected, cl.n_copies(task0())),
        (1, 2),
        "observed-speed SDA distrusts the host's track record and relaunches"
    );
    assert_eq!(sda.backups, 1);
}

/// Satellite (ON/OFF flips): at zero flip rates every estimator variant
/// collapses onto the same run, bit for bit, on the paper's homogeneous
/// healthy cluster — no dwell stream exists, every copy keeps epoch 0,
/// the observed-throughput stamp equals the advertised speed exactly
/// (eta = 1), and the blind/advertised distinction is vacuous at unit
/// class speed.
#[test]
fn estimator_variants_coincide_at_zero_flip_rates() {
    let run = |speed_aware: bool, observed: bool| {
        let mut cfg = SimConfig::default();
        cfg.machines = 50;
        cfg.horizon = 150.0;
        cfg.seed = 11;
        cfg.scheduler = SchedulerKind::Sda;
        cfg.use_runtime = false;
        cfg.speed_aware = speed_aware;
        cfg.observed_speed = observed;
        cfg.slowdown = Some(SlowdownConfig::new(0.0, 4.0)); // zero rates
        let wl_cfg = WorkloadConfig::paper(0.5);
        let wl = specsim::cluster::generator::generate(&wl_cfg, cfg.horizon, cfg.seed);
        let sched = specsim::scheduler::build_for(&cfg, &wl_cfg, Some(&wl)).unwrap();
        Simulator::new(cfg, wl, sched).run()
    };
    let blind_units = run(false, false); // the plain revealed estimator
    let advertised = run(true, false);
    let observed = run(true, true);
    assert!(!advertised.completed.is_empty());
    for (label, res) in [("blind", &blind_units), ("observed", &observed)] {
        assert_eq!(res.completed.len(), advertised.completed.len(), "{label}");
        assert_eq!(res.events_processed, advertised.events_processed, "{label}");
        assert_eq!(res.speculative_launches, advertised.speculative_launches, "{label}");
        assert_eq!(
            res.total_machine_time.to_bits(),
            advertised.total_machine_time.to_bits(),
            "{label}"
        );
        for (a, b) in res.completed.iter().zip(&advertised.completed) {
            assert_eq!(a.flowtime.to_bits(), b.flowtime.to_bits(), "{label}");
            assert_eq!(a.resource.to_bits(), b.resource.to_bits(), "{label}");
        }
    }
}

/// On a heterogeneous cluster the `speed_aware` toggle changes ESE's
/// speculation behaviour: unit-naive estimates read every slow-class copy
/// as a straggler.
#[test]
fn speed_awareness_changes_ese_under_heterogeneity() {
    let mut cfg = SimConfig::default();
    cfg.horizon = 150.0;
    cfg.use_runtime = false;
    let mut spec = ExperimentSpec::new("hetero-aware", cfg);
    spec.scenario = ClusterScenario::heterogeneous(vec![
        MachineClass::new(60, 1.0),
        MachineClass::new(60, 0.4),
    ]);
    spec.policies = vec![
        PolicyVariant::kind(SchedulerKind::Ese),
        PolicyVariant::patched("ese_naive_units", SchedulerKind::Ese, |c| c.speed_aware = false),
    ];
    spec.loads = vec![LoadPoint::lambda(0.5)];
    spec.seeds = vec![2];
    spec.threads = 2;
    let sweep = Runner::run(&spec).unwrap();
    let aware = sweep.merged(0, 0);
    let naive_units = sweep.merged(1, 0);
    assert!(!aware.completed.is_empty());
    assert!(!naive_units.completed.is_empty());
    assert!(
        aware.speculative_launches != naive_units.speculative_launches
            || (aware.mean_flowtime() - naive_units.mean_flowtime()).abs() > 1e-12,
        "speed awareness should change ESE behaviour on a heterogeneous cluster \
         (speculative: {} vs {}, flowtime: {} vs {})",
        aware.speculative_launches,
        naive_units.speculative_launches,
        aware.mean_flowtime(),
        naive_units.mean_flowtime()
    );
}
