//! End-to-end simulation integration tests: the paper's qualitative claims
//! at reduced scale, cross-scheduler invariants, and trace replay.

use specsim::cluster::generator::generate;
use specsim::cluster::sim::{SimResult, Simulator};
use specsim::cluster::trace;
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::scheduler::{self, SchedulerKind};

fn cfg(machines: usize, horizon: f64) -> SimConfig {
    let mut c = SimConfig::default();
    c.machines = machines;
    c.horizon = horizon;
    c.use_runtime = false; // pure-rust everywhere: no artifact dependency
    c
}

fn run(cfg: &SimConfig, wl: &WorkloadConfig, kind: SchedulerKind, seed: u64) -> SimResult {
    let mut c = cfg.clone();
    c.scheduler = kind;
    c.seed = seed;
    let workload = generate(wl, c.horizon, seed);
    let sched = scheduler::build(&c, wl).unwrap();
    Simulator::new(c, workload, sched).run()
}

/// Paper Fig. 2 shape at 1/3 scale: SCA beats Mantri on mean flowtime by a
/// wide margin in the lightly loaded regime, SDA by a smaller one.
///
/// Note on magnitudes: the paper reports ~60% for both SCA and SDA against
/// its Mantri baseline, whose CMF is close to no-speculation (80% of jobs
/// within ~17 units).  Our Mantri implements the published rule with exact
/// remaining times after the detection checkpoint, making it a much
/// stronger baseline — so the reproduced gaps are ~45-50% (SCA) and ~5-15%
/// (SDA).  See EXPERIMENTS.md for the full discussion.
///
/// Scale matters for SCA: the P2 cloning branch needs `sum m_i < N(l)` to
/// engage; tiny clusters starve it (single-copy fallbacks reintroduce the
/// Pareto tail), so this test runs M = 1000.
#[test]
fn lightly_loaded_sca_sda_beat_mantri() {
    let cfg = cfg(1000, 300.0);
    let wl = WorkloadConfig::paper(2.0); // same omega as the paper's lambda=6 @ M=3000
    let mantri = run(&cfg, &wl, SchedulerKind::Mantri, 1);
    let sca = run(&cfg, &wl, SchedulerKind::Sca, 1);
    let sda = run(&cfg, &wl, SchedulerKind::Sda, 1);
    assert!(mantri.completed.len() > 300);
    let (m, s, d) = (mantri.mean_flowtime(), sca.mean_flowtime(), sda.mean_flowtime());
    assert!(s < m * 0.7, "sca {s} vs mantri {m}: expected a deep cut");
    assert!(d < m * 0.97, "sda {d} vs mantri {m}");
    // and SCA pays more resource than Mantri for that speed (paper Fig. 2b)
    assert!(sca.mean_resource() > mantri.mean_resource() * 1.2);
}

/// Paper Fig. 6 shape: under heavy load ESE beats Mantri on flowtime at
/// comparable resource.
#[test]
fn heavily_loaded_ese_beats_mantri() {
    let mut c = cfg(300, 400.0);
    c.sigma = Some(1.7);
    c.mantri_srpt = true; // like-for-like baseline (see fig6.rs)
    let wl = WorkloadConfig::paper(4.0); // same omega as lambda=40 @ M=3000
    let mantri = run(&c, &wl, SchedulerKind::Mantri, 1);
    let ese = run(&c, &wl, SchedulerKind::Ese, 1);
    let (m, e) = (mantri.mean_flowtime(), ese.mean_flowtime());
    assert!(e < m, "ese {e} vs mantri {m}");
    let (mr, er) = (mantri.mean_resource(), ese.mean_resource());
    assert!(
        (er / mr - 1.0).abs() < 0.35,
        "resource should be comparable: ese {er} vs mantri {mr}"
    );
}

/// Every scheduler on the same workload: conservation invariants hold.
#[test]
fn all_schedulers_conserve() {
    let cfg = cfg(150, 200.0);
    let wl = WorkloadConfig::paper(0.8);
    for kind in SchedulerKind::all() {
        let res = run(&cfg, &wl, kind, 3);
        assert!(!res.completed.is_empty(), "{kind:?} completed nothing");
        assert!(res.utilization > 0.0 && res.utilization <= 1.0, "{kind:?}");
        for r in &res.completed {
            assert!(r.flowtime > 0.0, "{kind:?}: non-positive flowtime");
            assert!(r.resource > 0.0, "{kind:?}: free lunch");
            assert!(r.finish <= res.horizon + 1e-9, "{kind:?}: late record");
            // a job cannot consume less than one pass over its tasks at the
            // Pareto scale (gamma * m * mu lower-bounds resource)
            let floor = 0.01 * r.num_tasks as f64 * r.mean_duration * 0.5;
            assert!(r.resource >= floor * 0.99, "{kind:?}: resource {r:?}");
        }
    }
}

/// The speculation hierarchy: naive launches no backups; everything else
/// launches at least some under a straggler-prone workload.
#[test]
fn speculation_volume_ordering() {
    let cfg = cfg(400, 300.0);
    let wl = WorkloadConfig::paper(0.5);
    let naive = run(&cfg, &wl, SchedulerKind::Naive, 5);
    let sda = run(&cfg, &wl, SchedulerKind::Sda, 5);
    let clone_all = run(&cfg, &wl, SchedulerKind::CloneAll, 5);
    assert_eq!(naive.speculative_launches, 0);
    assert!(sda.speculative_launches > 0);
    // blanket cloning speculates far more than detection-based SDA
    assert!(clone_all.speculative_launches > 5 * sda.speculative_launches);
}

/// Trace replay: identical workload -> identical result.
#[test]
fn trace_replay_is_deterministic() {
    let c = cfg(100, 100.0);
    let wl = WorkloadConfig::paper(0.5);
    let workload = generate(&wl, c.horizon, 9);
    let dir = std::env::temp_dir().join("specsim_replay_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("wl.csv");
    trace::save(&workload, &path).unwrap();

    let direct = {
        let mut cc = c.clone();
        cc.scheduler = SchedulerKind::Sda;
        let sched = scheduler::build(&cc, &wl).unwrap();
        Simulator::new(cc, workload, sched).run()
    };
    let replayed = {
        let mut cc = c.clone();
        cc.scheduler = SchedulerKind::Sda;
        let wl2 = WorkloadConfig::trace(path.to_string_lossy().into_owned());
        let workload2 = generate(&wl2, c.horizon, 9);
        let sched = scheduler::build(&cc, &wl2).unwrap();
        Simulator::new(cc, workload2, sched).run()
    };
    assert_eq!(direct.completed.len(), replayed.completed.len());
    for (a, b) in direct.completed.iter().zip(&replayed.completed) {
        assert_eq!(a.job, b.job);
        assert!((a.flowtime - b.flowtime).abs() < 1e-9);
        assert!((a.resource - b.resource).abs() < 1e-9);
    }
}

/// Fig. 5 shape: for a single huge job, ESE at sigma ~ 1.7 uses less
/// resource than no-backup, and a too-small sigma wastes resource.
#[test]
fn single_job_sigma_shape() {
    let mut c = cfg(100, 10_000.0);
    let wl = WorkloadConfig::SingleJob { tasks: 2000, mean: 1.0, alpha: 2.0 };
    let naive = run(&c, &wl, SchedulerKind::Naive, 2);
    c.sigma = Some(1.7);
    let ese_opt = run(&c, &wl, SchedulerKind::Ese, 2);
    c.sigma = Some(0.3);
    let ese_tiny = run(&c, &wl, SchedulerKind::Ese, 2);
    let n = naive.total_machine_time;
    let opt = ese_opt.total_machine_time;
    let tiny = ese_tiny.total_machine_time;
    assert!(opt < n, "ESE@1.7 should save resource: {opt} vs naive {n}");
    assert!(tiny > opt, "sigma=0.3 over-speculates: {tiny} vs {opt}");
    // and the job finishes sooner with speculation
    assert!(
        ese_opt.completed[0].flowtime < naive.completed[0].flowtime,
        "flowtime should improve"
    );
}

/// Event-queue hygiene: under CloneAll at heavy load every completed task
/// kills a sibling whose `CopyFinish` (and sometimes `Checkpoint`) would
/// otherwise sit in the heap for its full sampled Pareto duration.  With
/// stale-entry compaction the heap must track *active* copies: its peak
/// is bounded by twice the live-event ceiling
/// (pending arrivals + 2 events per busy machine — slot boundaries no
/// longer live in the heap), plus the compaction floor — independent of
/// how many copies were ever launched and killed.
#[test]
fn clone_all_heap_tracks_active_copies() {
    let mut c = cfg(100, 400.0);
    c.clone_strict = true; // always 2 copies: maximal kill volume
    let wl = WorkloadConfig::paper(0.6); // heavy for M = 100 (omega ~ 0.76)
    let workload = generate(&wl, c.horizon, 11);
    let jobs = workload.specs.len();
    c.scheduler = SchedulerKind::CloneAll;
    let sched = scheduler::build(&c, &wl).unwrap();
    let res = Simulator::new(c, workload, sched).run();
    assert!(res.speculative_launches > 500, "want heavy kill traffic");
    // live events <= jobs (arrivals queued up-front) + 2 per machine
    // (CopyFinish + young Checkpoint); compaction keeps
    // stale <= max(live, 64), so peak <= 2 * live_ceiling + 64 + margin
    let live_ceiling = jobs + 2 * 100;
    assert!(
        res.peak_event_queue <= 2 * live_ceiling + 80,
        "heap peak {} vs live ceiling {} (launched {} backups): stale \
         CopyFinish entries are accumulating",
        res.peak_event_queue,
        live_ceiling,
        res.speculative_launches
    );
    assert!(res.events_processed > 0);
}

/// Slot-granularity ablation: finer slots must not break anything and
/// should not change the qualitative ordering.
#[test]
fn slot_dt_ablation_stable() {
    let wl = WorkloadConfig::paper(0.5);
    let mut means = Vec::new();
    for dt in [0.5, 1.0, 2.0] {
        let mut c = cfg(200, 150.0);
        c.slot_dt = dt;
        let res = run(&c, &wl, SchedulerKind::Sda, 4);
        assert!(!res.completed.is_empty());
        means.push(res.mean_flowtime());
    }
    // coarser slots wait longer to schedule: flowtime weakly increases
    assert!(means[0] <= means[2] * 1.5, "{means:?}");
}
