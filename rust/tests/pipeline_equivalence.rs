//! The policy-pipeline + wakeup-planner acceptance suite.
//!
//! The pre-redesign scheduler monoliths (and their `legacy_sched` flag)
//! are deleted — CI ran the byte-identical pipeline-vs-monolith proof
//! green, per the ROADMAP directive — so this suite now pins the pipeline
//! two ways:
//!
//! 1. **Wakeup equivalence** (the PR-5 tentpole bar) — with the identical
//!    spec, the demand-driven wakeup planner (`wakeup = true`, the
//!    default) and the retired fire-every-slot polling loop
//!    (`wakeup = false`) must serialize byte-identical sweep CSVs — same
//!    launches, same tie-breaks, same everything — across every canonical
//!    policy, the ablation variants, two composed specs, and all four
//!    scenario axes, on both `sched_index` paths.
//! 2. **Snapshot pin** — the canonical sweep CSV is compared against a
//!    committed snapshot (`tests/snapshots/canonical_sweep.csv`), so a
//!    behavioral drift in the pipeline itself (not just a divergence
//!    between two in-process modes) fails loudly.  On a checkout without
//!    the snapshot the test *blesses* it (writes the file and passes,
//!    with a warning): commit the blessed file — CI uploads it as the
//!    `sweep-snapshots` artifact — to arm the pin.
//!
//! 3. **Flip matrix** (the PR-7 tentpole bar) — with the ON/OFF Markov
//!    slowdown process enabled (`SlowdownConfig::with_rates`), the
//!    kill/re-insert traffic of `SlowdownFlip` events must leave every
//!    mode pair byte-identical too: {wakeup} x {sched_index} x
//!    {calendar, binary-heap} x worker counts, plus the guarantee that
//!    rate-(0,0) runs are bitwise the static scenario (which is what
//!    keeps the snapshot in (2) valid).
//!
//! 4. **Churn matrix** (the PR-10 tentpole bar) — same shape as (3) for
//!    the crash/recovery fault model (`ChurnConfig`): machine crashes
//!    kill resident copies, crashed-out tasks relaunch from zero, and
//!    every {wakeup} x {sched_index} x {calendar, binary-heap} x worker
//!    pair must still serialize the byte-identical sweep CSV; zero-rate
//!    churn must be bitwise the no-churn run, which is what keeps the
//!    committed snapshot in (2) valid across the churn PR.
//!
//! Plus the pipeline-composition tests that never depended on the
//! monoliths: novel compositions sweep end-to-end, and the est-srpt
//! ordering genuinely diverges from mean-field SRPT.

use specsim::cluster::event::EventQueueKind;
use specsim::cluster::machine::{ChurnConfig, MachineClass, SlowdownConfig};
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::experiment::{
    ClusterScenario, ExperimentSpec, LoadPoint, PolicyVariant, Runner,
};
use specsim::metrics::report;
use specsim::scheduler::SchedulerKind;

/// The seven canonical kinds, the ablation variants whose knobs the
/// compositions fold in, and two composed specs (the ISSUE's wakeup
/// equivalence grid: 7 canonical + 2 composed).
fn canonical_policies() -> Vec<PolicyVariant> {
    let mut policies: Vec<PolicyVariant> =
        SchedulerKind::all().into_iter().map(PolicyVariant::kind).collect();
    policies.push(PolicyVariant::patched("mantri_srpt", SchedulerKind::Mantri, |c| {
        c.mantri_srpt = true;
    }));
    policies.push(PolicyVariant::patched("mantri_kill", SchedulerKind::Mantri, |c| {
        c.mantri_kill = true;
    }));
    policies.push(PolicyVariant::patched("sda_unit_naive", SchedulerKind::Sda, |c| {
        c.speed_aware = false;
    }));
    policies.push(PolicyVariant::patched("clone3", SchedulerKind::CloneAll, |c| {
        c.clone_copies = 3;
    }));
    policies.push(PolicyVariant::patched("clone_strict", SchedulerKind::CloneAll, |c| {
        c.clone_strict = true;
    }));
    policies.push(PolicyVariant::policy("fifo+sda").unwrap());
    policies.push(PolicyVariant::policy("est-srpt+mantri").unwrap());
    policies
}

fn equivalence_spec(
    name: &str,
    scenario: ClusterScenario,
    loads: Vec<LoadPoint>,
    threads: usize,
) -> ExperimentSpec {
    let mut base = SimConfig::default();
    base.machines = 100;
    base.horizon = 100.0;
    base.use_runtime = false;
    let mut spec = ExperimentSpec::new(name, base);
    spec.scenario = scenario;
    spec.policies = canonical_policies();
    spec.loads = loads;
    spec.seeds = vec![7];
    spec.threads = threads;
    spec
}

fn csv_with_wakeup(spec: &ExperimentSpec, wakeup: bool) -> String {
    let mut spec = spec.clone();
    spec.base.wakeup = wakeup;
    report::sweep_csv(&Runner::run(&spec).unwrap())
}

/// The acceptance bar: the wakeup planner is byte-identical to the polled
/// slot loop across {light, near-capacity} loads and every scenario axis.
#[test]
fn wakeup_sweeps_byte_identical_to_polled_loop() {
    let scenarios: Vec<(&str, ClusterScenario, Vec<LoadPoint>)> = vec![
        (
            "homogeneous",
            ClusterScenario::homogeneous(),
            vec![LoadPoint::lambda(0.4), LoadPoint::lambda(0.75)],
        ),
        (
            "machine-classes",
            ClusterScenario::heterogeneous(vec![
                MachineClass::new(60, 1.0),
                MachineClass::new(40, 0.5),
            ]),
            vec![LoadPoint::lambda(0.5)],
        ),
        (
            "slowdown",
            ClusterScenario::homogeneous().with_slowdown(SlowdownConfig::new(0.2, 3.0)),
            vec![LoadPoint::lambda(0.5)],
        ),
        (
            "bursty",
            ClusterScenario::homogeneous(),
            vec![LoadPoint::new("bursty0.5", 0.5, WorkloadConfig::bursty_paper(0.5, 3.0))],
        ),
    ];
    for (name, scenario, loads) in scenarios {
        let spec = equivalence_spec(name, scenario, loads, 2);
        let polled = csv_with_wakeup(&spec, false);
        let planned = csv_with_wakeup(&spec, true);
        assert!(polled.lines().count() > spec.policies.len(), "{name}: empty sweep?");
        assert_eq!(
            planned, polled,
            "{name}: the wakeup planner diverged from the polled slot loop"
        );
    }
}

/// The equivalence must also hold on the naive-scan query path (the
/// planner's per-rule horizons enumerate candidates on both paths) and
/// on a finer slot grid, where skipping is the common case.
#[test]
fn wakeup_equivalence_holds_on_the_scan_path_and_fine_grids_too() {
    let mut spec = equivalence_spec(
        "scan",
        ClusterScenario::homogeneous(),
        vec![LoadPoint::lambda(0.6)],
        2,
    );
    spec.base.sched_index = false;
    assert_eq!(csv_with_wakeup(&spec, true), csv_with_wakeup(&spec, false));
    let mut fine = equivalence_spec(
        "fine-grid",
        ClusterScenario::homogeneous(),
        vec![LoadPoint::lambda(0.4)],
        2,
    );
    fine.base.slot_dt = 0.1;
    assert_eq!(csv_with_wakeup(&fine, true), csv_with_wakeup(&fine, false));
}

/// The committed-snapshot pin replacing the deleted monoliths as the
/// pipeline's external reference.  Present snapshot = byte-identical or
/// fail.  Missing snapshot: with `SPECSIM_REQUIRE_SNAPSHOT` set (the CI
/// test step) the pin **fails instead of self-blessing** — a checkout
/// must carry the committed reference; without it (local runs, and the
/// CI bootstrap step that generates the first snapshot) the test blesses
/// the file and passes with a warning, so it can be committed from the
/// `sweep-snapshots` artifact.  See `tests/snapshots/README.md`.
#[test]
fn canonical_sweep_matches_committed_snapshot() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/snapshots/canonical_sweep.csv");
    let spec = equivalence_spec(
        "snapshot",
        ClusterScenario::homogeneous(),
        vec![LoadPoint::lambda(0.4), LoadPoint::lambda(0.75)],
        2,
    );
    let current = report::sweep_csv(&Runner::run(&spec).unwrap());
    match std::fs::read_to_string(path) {
        Ok(snapshot) => assert_eq!(
            current, snapshot,
            "canonical sweep drifted from the committed snapshot {path}; if the \
             change is intentional, delete the file and re-run to re-bless"
        ),
        Err(_) if std::env::var_os("SPECSIM_REQUIRE_SNAPSHOT").is_some() => {
            panic!(
                "canonical sweep snapshot missing at {path} and \
                 SPECSIM_REQUIRE_SNAPSHOT is set: refusing to self-bless — \
                 commit the sweep-snapshots CI artifact (or run the test once \
                 without the variable) to restore the pin"
            );
        }
        Err(_) => {
            report::write_file(path, &current).expect("bless the snapshot");
            eprintln!(
                "warning: blessed missing canonical sweep snapshot at {path} — \
                 commit it to arm the pin"
            );
        }
    }
}

/// The PR-7 tentpole bar: with the ON/OFF flip process churning hosts
/// mid-copy (kill/re-insert of stale finishes + checkpoints, re-timed
/// durations, re-fired reveals), every combination of
/// {wakeup planner, polled loop} x {sched-index, naive scan} x
/// {calendar, binary-heap} serializes the byte-identical sweep CSV, and
/// the worker count doesn't leak into the bytes either.
#[test]
fn flip_sweeps_byte_identical_across_backend_wakeup_index_and_threads() {
    let scenario = ClusterScenario::heterogeneous(vec![
        MachineClass::new(60, 1.0),
        MachineClass::new(40, 0.5),
    ])
    .with_slowdown(SlowdownConfig::new(0.2, 3.0).with_rates(0.5, 1.0));
    let spec = equivalence_spec("flips", scenario, vec![LoadPoint::lambda(0.5)], 2);
    let run = |queue: EventQueueKind, wakeup: bool, sched_index: bool, threads: usize| {
        let mut s = spec.clone();
        s.base.event_queue = queue;
        s.base.wakeup = wakeup;
        s.base.sched_index = sched_index;
        s.threads = threads;
        report::sweep_csv(&Runner::run(&s).unwrap())
    };
    let reference = run(EventQueueKind::Calendar, true, true, 2);
    assert!(reference.lines().count() > spec.policies.len(), "empty flip sweep?");
    for queue in [EventQueueKind::Calendar, EventQueueKind::BinaryHeap] {
        for wakeup in [true, false] {
            for sched_index in [true, false] {
                if queue == EventQueueKind::Calendar && wakeup && sched_index {
                    continue; // the reference itself
                }
                assert_eq!(
                    run(queue, wakeup, sched_index, 2),
                    reference,
                    "{queue:?} wakeup={wakeup} sched_index={sched_index} diverged \
                     from the calendar/planner/index reference under flips"
                );
            }
        }
    }
    for threads in [1, 4] {
        assert_eq!(
            run(EventQueueKind::BinaryHeap, false, false, threads),
            reference,
            "worker count {threads} leaked into the flip sweep bytes"
        );
    }
}

/// Zero rates must be *exactly* the static slowdown scenario: the flip
/// machinery (dedicated seed stream, per-machine dwell sampling, epoch
/// columns) may not perturb a run in which no flip ever fires — this is
/// what keeps the committed canonical snapshot valid across the PR.
#[test]
fn zero_flip_rates_are_byte_identical_to_the_static_slowdown_scenario() {
    let loads = vec![LoadPoint::lambda(0.5)];
    let static_spec = equivalence_spec(
        "static-slowdown",
        ClusterScenario::homogeneous().with_slowdown(SlowdownConfig::new(0.2, 3.0)),
        loads.clone(),
        2,
    );
    let zero_rate_spec = equivalence_spec(
        "zero-rate-flips",
        ClusterScenario::homogeneous()
            .with_slowdown(SlowdownConfig::new(0.2, 3.0).with_rates(0.0, 0.0)),
        loads,
        2,
    );
    let static_csv = report::sweep_csv(&Runner::run(&static_spec).unwrap());
    let zero_csv = report::sweep_csv(&Runner::run(&zero_rate_spec).unwrap());
    assert!(static_csv.lines().count() > static_spec.policies.len());
    assert_eq!(
        zero_csv, static_csv,
        "rate (0,0) flips must be indistinguishable from the static scenario"
    );
}

/// The PR-10 tentpole bar: with machines crashing and recovering mid-run
/// (killed resident copies, stranded-ledger settlement, restart-from-zero
/// relaunches draining ahead of fired slots), every combination of
/// {wakeup planner, polled loop} x {sched-index, naive scan} x
/// {calendar, binary-heap} serializes the byte-identical sweep CSV —
/// including the appended loss columns — and the worker count doesn't
/// leak into the bytes either.
#[test]
fn churn_sweeps_byte_identical_across_backend_wakeup_index_and_threads() {
    let mut spec = equivalence_spec(
        "churn",
        ClusterScenario::homogeneous(),
        vec![LoadPoint::lambda(0.5)],
        2,
    );
    spec.base.churn = Some(ChurnConfig::new(40.0, 10.0));
    let run = |queue: EventQueueKind, wakeup: bool, sched_index: bool, threads: usize| {
        let mut s = spec.clone();
        s.base.event_queue = queue;
        s.base.wakeup = wakeup;
        s.base.sched_index = sched_index;
        s.threads = threads;
        report::sweep_csv(&Runner::run(&s).unwrap())
    };
    let reference = run(EventQueueKind::Calendar, true, true, 2);
    assert!(reference.lines().count() > spec.policies.len(), "empty churn sweep?");
    let header = reference.lines().next().unwrap();
    assert!(
        header.ends_with("machines_failed,copies_lost,work_lost"),
        "churn-enabled sweeps must serialize the loss columns: {header}"
    );
    // the fault model must actually bite for the matrix to mean anything
    let sweep = Runner::run(&spec).unwrap();
    let total_lost: u64 =
        (0..sweep.policies.len()).map(|pi| sweep.merged(pi, 0).copies_lost).sum();
    assert!(total_lost > 0, "MTTF 40 over horizon 100 must kill running copies");
    for queue in [EventQueueKind::Calendar, EventQueueKind::BinaryHeap] {
        for wakeup in [true, false] {
            for sched_index in [true, false] {
                if queue == EventQueueKind::Calendar && wakeup && sched_index {
                    continue; // the reference itself
                }
                assert_eq!(
                    run(queue, wakeup, sched_index, 2),
                    reference,
                    "{queue:?} wakeup={wakeup} sched_index={sched_index} diverged \
                     from the calendar/planner/index reference under churn"
                );
            }
        }
    }
    for threads in [1, 4] {
        assert_eq!(
            run(EventQueueKind::BinaryHeap, false, false, threads),
            reference,
            "worker count {threads} leaked into the churn sweep bytes"
        );
    }
}

/// Zero-rate churn must be *exactly* the no-churn run: the churn machinery
/// (dedicated seed stream, primary-copy column, relaunch backlog) may not
/// perturb a run in which no machine ever fails — and the CSV keeps the
/// pre-churn column set, which is what keeps the committed canonical
/// snapshot valid across the churn PR.
#[test]
fn zero_rate_churn_is_byte_identical_to_the_no_churn_sweep() {
    let loads = vec![LoadPoint::lambda(0.5)];
    let plain =
        equivalence_spec("no-churn", ClusterScenario::homogeneous(), loads.clone(), 2);
    let mut zero = equivalence_spec("zero-churn", ClusterScenario::homogeneous(), loads, 2);
    zero.base.churn = Some(ChurnConfig::new(0.0, 0.0));
    let plain_csv = report::sweep_csv(&Runner::run(&plain).unwrap());
    let zero_csv = report::sweep_csv(&Runner::run(&zero).unwrap());
    assert!(plain_csv.lines().count() > plain.policies.len());
    assert!(
        !plain_csv.lines().next().unwrap().contains("copies_lost"),
        "disabled churn keeps the pre-churn column set"
    );
    assert_eq!(
        zero_csv, plain_csv,
        "churn (0,0) must be indistinguishable from no churn, byte for byte"
    );
}

/// Novel compositions — pipelines with no canonical name — run end-to-end
/// through the sweep engine and land as distinct labeled CSV rows.
#[test]
fn novel_compositions_sweep_end_to_end() {
    let mut base = SimConfig::default();
    base.machines = 100;
    base.horizon = 150.0;
    base.use_runtime = false;
    let mut spec = ExperimentSpec::new("novel", base);
    spec.policies = vec![
        PolicyVariant::policy("fifo+sda").unwrap(),
        PolicyVariant::policy("est-srpt+mantri").unwrap(),
    ];
    spec.loads = vec![LoadPoint::lambda(0.4), LoadPoint::lambda(0.75)];
    spec.seeds = vec![1];
    spec.threads = 2;
    let sweep = Runner::run(&spec).unwrap();
    let csv = report::sweep_csv(&sweep);
    let fifo_sda: Vec<&str> = csv.lines().filter(|l| l.starts_with("fifo+sda,")).collect();
    let est_mantri: Vec<&str> =
        csv.lines().filter(|l| l.starts_with("est-srpt+mantri,")).collect();
    assert_eq!(fifo_sda.len(), 2, "one row per load:\n{csv}");
    assert_eq!(est_mantri.len(), 2, "one row per load:\n{csv}");
    for pi in 0..2 {
        for li in 0..2 {
            let res = sweep.merged(pi, li);
            assert!(!res.completed.is_empty(), "({pi},{li}) completed nothing");
        }
    }
    // both compositions actually speculate (sda reveals / mantri δ-tests)
    assert!(sweep.merged(0, 1).speculative_launches > 0);
    assert!(sweep.merged(1, 1).speculative_launches > 0);
    // and the two pipelines are genuinely different policies
    assert_ne!(fifo_sda[1], est_mantri[1].replace("est-srpt+mantri,", "fifo+sda,"));
}

/// The estimate-driven ordering must *matter*: once reveals refine the
/// level-2 keys, `est-srpt+sda` schedules differently from the mean-field
/// `srpt+sda` on a congested cluster (same workload, same seed).
#[test]
fn est_ordering_diverges_from_mean_field_srpt() {
    let mut base = SimConfig::default();
    base.machines = 100;
    base.horizon = 150.0;
    base.use_runtime = false;
    let mut spec = ExperimentSpec::new("est-vs-mean", base);
    spec.policies = vec![
        PolicyVariant::policy("srpt+sda").unwrap(),
        PolicyVariant::policy("est-srpt+sda").unwrap(),
    ];
    // near capacity: queues build, so level-2 order decides real launches
    spec.loads = vec![LoadPoint::lambda(0.75)];
    spec.seeds = vec![1, 2, 3];
    spec.threads = 2;
    let sweep = Runner::run(&spec).unwrap();
    let mean_field = sweep.merged(0, 0);
    let est = sweep.merged(1, 0);
    assert!(!mean_field.completed.is_empty());
    assert!(!est.completed.is_empty());
    assert!(
        (mean_field.mean_flowtime() - est.mean_flowtime()).abs() > 1e-12
            || mean_field.speculative_launches != est.speculative_launches,
        "est-srpt should change scheduling under congestion (flowtime {} vs {})",
        mean_field.mean_flowtime(),
        est.mean_flowtime()
    );
    // `srpt+sda` is byte-identical to the canonical `sda` (same pipeline,
    // different label): the composition grammar adds labels, not drift
    let mut canon = ExperimentSpec::new("canon", {
        let mut b = SimConfig::default();
        b.machines = 100;
        b.horizon = 150.0;
        b.use_runtime = false;
        b
    });
    canon.policies = vec![PolicyVariant::kind(SchedulerKind::Sda)];
    canon.loads = vec![LoadPoint::lambda(0.75)];
    canon.seeds = vec![1, 2, 3];
    canon.threads = 2;
    let canon_sweep = Runner::run(&canon).unwrap();
    let canon_res = canon_sweep.merged(0, 0);
    assert_eq!(canon_res.completed.len(), mean_field.completed.len());
    assert_eq!(canon_res.total_machine_time, mean_field.total_machine_time);
    assert_eq!(canon_res.speculative_launches, mean_field.speculative_launches);
}

/// Satellite (PR 4): `clone_copies` is configurable and the copy count
/// bites — 3-way cloning burns measurably more machine time than 2-way on
/// an uncongested cluster.
#[test]
fn clone_copies_knob_changes_resource_use() {
    let run_with = |copies: u32| {
        let mut base = SimConfig::default();
        base.machines = 2000;
        base.horizon = 100.0;
        base.use_runtime = false;
        base.clone_copies = copies;
        let mut spec = ExperimentSpec::new("clone-k", base);
        spec.policies = vec![PolicyVariant::kind(SchedulerKind::CloneAll)];
        spec.loads = vec![LoadPoint::lambda(0.5)];
        spec.seeds = vec![5];
        spec.threads = 1;
        Runner::run(&spec).unwrap().merged(0, 0)
    };
    let two = run_with(2);
    let three = run_with(3);
    assert!(two.speculative_launches > 0);
    assert!(
        three.speculative_launches > two.speculative_launches,
        "3-way cloning should launch more backups: {} vs {}",
        three.speculative_launches,
        two.speculative_launches
    );
    assert!(three.total_machine_time > two.total_machine_time);
}
