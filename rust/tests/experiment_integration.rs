//! Integration tests for the experiment engine: determinism under
//! parallelism (the acceptance bar for every sweep the figures run), the
//! scenario axes (heterogeneous machine speeds, bursty arrivals), and the
//! **index-equivalence suite** — the indexed scheduler hot paths
//! (`sched_index = true`, the default) must produce byte-identical
//! `sweep_csv` tables to the retained naive-scan reference across every
//! policy, scenario axis and worker count.

use specsim::cluster::machine::{MachineClass, SlowdownConfig};
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::experiment::{
    ClusterScenario, ExperimentSpec, LoadPoint, PolicyVariant, Runner,
};
use specsim::metrics::report;
use specsim::scheduler::SchedulerKind;

fn small_base() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.machines = 120;
    cfg.horizon = 120.0;
    cfg.use_runtime = false; // pure-rust everywhere: no artifact dependency
    cfg
}

fn grid_spec(threads: usize) -> ExperimentSpec {
    let mut spec = ExperimentSpec::new("det", small_base());
    spec.policies = vec![
        PolicyVariant::kind(SchedulerKind::Naive),
        PolicyVariant::kind(SchedulerKind::Sda),
        PolicyVariant::with_sigma(SchedulerKind::Ese, 1.7),
    ];
    spec.loads = vec![LoadPoint::lambda(0.3), LoadPoint::lambda(0.6)];
    spec.seeds = vec![1, 2];
    spec.threads = threads;
    spec
}

/// The tentpole guarantee: the serialized sweep table is byte-identical
/// whatever the worker count, because every cell's RNG streams depend only
/// on (config, workload, seed) and cells never share mutable state.
#[test]
fn sweep_rows_identical_across_worker_counts() {
    let reference = report::sweep_csv(&Runner::run(&grid_spec(1)).unwrap());
    assert!(reference.lines().count() > 12, "grid should have 12 cells + header");
    for threads in [2, 4, 8] {
        let parallel = report::sweep_csv(&Runner::run(&grid_spec(threads)).unwrap());
        assert_eq!(
            reference, parallel,
            "threads={threads} produced different rows than threads=1"
        );
    }
}

/// Same grid, bursty arrivals: parallel determinism must hold on the new
/// scenario axis too.
#[test]
fn bursty_sweep_deterministic_and_distinct_from_poisson() {
    // identical label/x for both arrival processes so the CSVs can only
    // differ through the simulated results themselves
    let bursty_spec = |threads| {
        let mut spec = grid_spec(threads);
        spec.loads = vec![LoadPoint::new(
            "load",
            0.6,
            WorkloadConfig::bursty_paper(0.6, 3.0),
        )];
        spec
    };
    let a = report::sweep_csv(&Runner::run(&bursty_spec(1)).unwrap());
    let b = report::sweep_csv(&Runner::run(&bursty_spec(4)).unwrap());
    assert_eq!(a, b);
    // and the bursty rows differ from the Poisson rows at the same rate
    let mut poisson_spec = grid_spec(1);
    poisson_spec.loads =
        vec![LoadPoint::new("load", 0.6, WorkloadConfig::paper(0.6))];
    let p = report::sweep_csv(&Runner::run(&poisson_spec).unwrap());
    assert_ne!(a, p, "bursty arrivals should change the results");
}

/// Heterogeneous machine speeds scale copy durations: a uniformly-2x
/// cluster halves the single job's flowtime and machine time exactly.
#[test]
fn heterogeneous_speeds_scale_copy_durations() {
    let run_at = |speed: f64| {
        let mut spec = ExperimentSpec::new("hetero", small_base());
        spec.base.horizon = 4000.0;
        spec.scenario =
            ClusterScenario::heterogeneous(vec![MachineClass::new(120, speed)]);
        spec.policies = vec![PolicyVariant::kind(SchedulerKind::Naive)];
        spec.loads = vec![LoadPoint::new(
            "single",
            1.0,
            WorkloadConfig::SingleJob { tasks: 120, mean: 1.0, alpha: 2.0 },
        )];
        spec.seeds = vec![9];
        spec.threads = 1;
        Runner::run(&spec).unwrap()
    };
    let slow = run_at(1.0).merged(0, 0);
    let fast = run_at(2.0).merged(0, 0);
    assert_eq!(slow.completed.len(), 1);
    assert_eq!(fast.completed.len(), 1);
    assert!(
        (fast.completed[0].flowtime - slow.completed[0].flowtime / 2.0).abs() < 1e-9,
        "2x cluster should halve the flowtime: {} vs {}",
        fast.completed[0].flowtime,
        slow.completed[0].flowtime
    );
    assert!(
        (fast.total_machine_time - slow.total_machine_time / 2.0).abs() < 1e-6,
        "2x cluster should halve machine time"
    );
}

/// A mixed cluster must sit strictly between all-slow and all-fast.
#[test]
fn mixed_cluster_between_homogeneous_extremes() {
    let run_with = |classes: Vec<MachineClass>| {
        let mut spec = ExperimentSpec::new("mix", small_base());
        spec.base.horizon = 4000.0;
        spec.scenario = ClusterScenario::heterogeneous(classes);
        spec.policies = vec![PolicyVariant::kind(SchedulerKind::Naive)];
        spec.loads = vec![LoadPoint::new(
            "single",
            1.0,
            WorkloadConfig::SingleJob { tasks: 120, mean: 1.0, alpha: 2.0 },
        )];
        spec.seeds = vec![9];
        spec.threads = 2;
        Runner::run(&spec).unwrap().merged(0, 0).total_machine_time
    };
    let slow = run_with(vec![MachineClass::new(120, 1.0)]);
    let fast = run_with(vec![MachineClass::new(120, 2.0)]);
    let mixed =
        run_with(vec![MachineClass::new(60, 1.0), MachineClass::new(60, 2.0)]);
    assert!(fast < mixed && mixed < slow, "fast {fast} < mixed {mixed} < slow {slow}");
}

// ----- index-equivalence suite ------------------------------------------
//
// The tentpole guarantee of the SchedIndex subsystem: with the identical
// spec, `sched_index = true` (incremental indices) and `sched_index =
// false` (the retained naive scans) must serialize byte-identical sweep
// tables — same launches, same tie-breaks, same everything.

/// Every scheduler kind plus the ablation variants that exercise the
/// extra index paths: Mantri's SRPT baseline (level-2/3 through the
/// index), Mantri's kill rule (kill_copy + relaunch on a candidate task),
/// the unit-naive estimator row, and composed pipelines — including
/// est-srpt ones, whose level-2 twin is re-keyed at the reveal/kill/
/// finish mutation points and must still match the `sched_index = false`
/// scan fallback exactly (the re-key contract's auto-fallback guarantee).
fn equivalence_policies() -> Vec<PolicyVariant> {
    let mut policies: Vec<PolicyVariant> =
        SchedulerKind::all().into_iter().map(PolicyVariant::kind).collect();
    policies.push(PolicyVariant::patched("mantri_srpt", SchedulerKind::Mantri, |c| {
        c.mantri_srpt = true;
    }));
    policies.push(PolicyVariant::patched("mantri_kill", SchedulerKind::Mantri, |c| {
        c.mantri_kill = true;
    }));
    policies.push(PolicyVariant::patched("sda_unit_naive", SchedulerKind::Sda, |c| {
        c.speed_aware = false;
    }));
    for spec in ["fifo+sda", "est-srpt+sda", "est-srpt+mantri", "est-srpt+ese*cap2"] {
        policies.push(PolicyVariant::policy(spec).unwrap());
    }
    policies
}

fn equivalence_spec(
    name: &str,
    scenario: ClusterScenario,
    loads: Vec<LoadPoint>,
    threads: usize,
) -> ExperimentSpec {
    let mut base = SimConfig::default();
    base.machines = 100;
    base.horizon = 100.0;
    base.use_runtime = false;
    let mut spec = ExperimentSpec::new(name, base);
    spec.scenario = scenario;
    spec.policies = equivalence_policies();
    spec.loads = loads;
    spec.seeds = vec![7];
    spec.threads = threads;
    spec
}

fn csv_with_index(spec: &ExperimentSpec, sched_index: bool) -> String {
    let mut spec = spec.clone();
    spec.base.sched_index = sched_index;
    report::sweep_csv(&Runner::run(&spec).unwrap())
}

/// All policies × {light, near-capacity} × every scenario axis: the
/// indexed sweep table is byte-identical to the naive-scan reference.
#[test]
fn indexed_sweeps_byte_identical_to_scan_reference() {
    // capacity at M = 100 for the paper mix is ~0.79 jobs/unit: 0.4 is
    // light, 0.75 is near-threshold (queues build, level 3 stays busy)
    let scenarios: Vec<(&str, ClusterScenario, Vec<LoadPoint>)> = vec![
        (
            "homogeneous",
            ClusterScenario::homogeneous(),
            vec![LoadPoint::lambda(0.4), LoadPoint::lambda(0.75)],
        ),
        (
            "machine-classes",
            ClusterScenario::heterogeneous(vec![
                MachineClass::new(60, 1.0),
                MachineClass::new(40, 0.5),
            ]),
            vec![LoadPoint::lambda(0.5)],
        ),
        (
            "slowdown",
            ClusterScenario::homogeneous().with_slowdown(SlowdownConfig::new(0.2, 3.0)),
            vec![LoadPoint::lambda(0.5)],
        ),
        (
            "bursty",
            ClusterScenario::homogeneous(),
            vec![LoadPoint::new("bursty0.5", 0.5, WorkloadConfig::bursty_paper(0.5, 3.0))],
        ),
    ];
    for (name, scenario, loads) in scenarios {
        let spec = equivalence_spec(name, scenario, loads, 2);
        let scan = csv_with_index(&spec, false);
        let indexed = csv_with_index(&spec, true);
        assert!(scan.lines().count() > spec.policies.len(), "{name}: empty sweep?");
        assert_eq!(
            indexed, scan,
            "{name}: indexed scheduling diverged from the naive-scan reference"
        );
    }
}

/// The equivalence must also be independent of the worker count on both
/// paths (index state is per-cluster, never shared across cells).
#[test]
fn indexed_sweep_identical_across_worker_counts() {
    let loads = vec![LoadPoint::lambda(0.6)];
    let reference = {
        let spec = equivalence_spec("wc", ClusterScenario::homogeneous(), loads.clone(), 1);
        csv_with_index(&spec, false)
    };
    for threads in [1, 4] {
        let spec = equivalence_spec("wc", ClusterScenario::homogeneous(), loads.clone(), threads);
        assert_eq!(
            csv_with_index(&spec, true),
            reference,
            "threads={threads}: indexed table diverged"
        );
    }
}

/// Policy patches apply per-cell without leaking into neighbours: the
/// unpatched SDA cells of one sweep match a sweep with no patched variants.
#[test]
fn patched_variants_do_not_leak() {
    let mut with_patch = ExperimentSpec::new("p", small_base());
    with_patch.policies = vec![
        PolicyVariant::kind(SchedulerKind::Sda),
        PolicyVariant::with_sigma(SchedulerKind::Sda, 4.0),
    ];
    with_patch.loads = vec![LoadPoint::lambda(0.4)];
    with_patch.seeds = vec![3];
    with_patch.threads = 4;
    let both = Runner::run(&with_patch).unwrap();

    let mut alone = ExperimentSpec::new("q", small_base());
    alone.policies = vec![PolicyVariant::kind(SchedulerKind::Sda)];
    alone.loads = vec![LoadPoint::lambda(0.4)];
    alone.seeds = vec![3];
    alone.threads = 1;
    let solo = Runner::run(&alone).unwrap();

    let a = &both.cell(0, 0, 0).result;
    let b = &solo.cell(0, 0, 0).result;
    assert_eq!(a.completed.len(), b.completed.len());
    assert_eq!(a.total_machine_time, b.total_machine_time);
    assert_eq!(a.speculative_launches, b.speculative_launches);
}
