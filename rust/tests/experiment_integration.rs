//! Integration tests for the experiment engine: determinism under
//! parallelism (the acceptance bar for every sweep the figures run) and
//! the scenario axes (heterogeneous machine speeds, bursty arrivals).

use specsim::cluster::machine::MachineClass;
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::experiment::{
    ClusterScenario, ExperimentSpec, LoadPoint, PolicyVariant, Runner,
};
use specsim::metrics::report;
use specsim::scheduler::SchedulerKind;

fn small_base() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.machines = 120;
    cfg.horizon = 120.0;
    cfg.use_runtime = false; // pure-rust everywhere: no artifact dependency
    cfg
}

fn grid_spec(threads: usize) -> ExperimentSpec {
    let mut spec = ExperimentSpec::new("det", small_base());
    spec.policies = vec![
        PolicyVariant::kind(SchedulerKind::Naive),
        PolicyVariant::kind(SchedulerKind::Sda),
        PolicyVariant::with_sigma(SchedulerKind::Ese, 1.7),
    ];
    spec.loads = vec![LoadPoint::lambda(0.3), LoadPoint::lambda(0.6)];
    spec.seeds = vec![1, 2];
    spec.threads = threads;
    spec
}

/// The tentpole guarantee: the serialized sweep table is byte-identical
/// whatever the worker count, because every cell's RNG streams depend only
/// on (config, workload, seed) and cells never share mutable state.
#[test]
fn sweep_rows_identical_across_worker_counts() {
    let reference = report::sweep_csv(&Runner::run(&grid_spec(1)).unwrap());
    assert!(reference.lines().count() > 12, "grid should have 12 cells + header");
    for threads in [2, 4, 8] {
        let parallel = report::sweep_csv(&Runner::run(&grid_spec(threads)).unwrap());
        assert_eq!(
            reference, parallel,
            "threads={threads} produced different rows than threads=1"
        );
    }
}

/// Same grid, bursty arrivals: parallel determinism must hold on the new
/// scenario axis too.
#[test]
fn bursty_sweep_deterministic_and_distinct_from_poisson() {
    // identical label/x for both arrival processes so the CSVs can only
    // differ through the simulated results themselves
    let bursty_spec = |threads| {
        let mut spec = grid_spec(threads);
        spec.loads = vec![LoadPoint::new(
            "load",
            0.6,
            WorkloadConfig::bursty_paper(0.6, 3.0),
        )];
        spec
    };
    let a = report::sweep_csv(&Runner::run(&bursty_spec(1)).unwrap());
    let b = report::sweep_csv(&Runner::run(&bursty_spec(4)).unwrap());
    assert_eq!(a, b);
    // and the bursty rows differ from the Poisson rows at the same rate
    let mut poisson_spec = grid_spec(1);
    poisson_spec.loads =
        vec![LoadPoint::new("load", 0.6, WorkloadConfig::paper(0.6))];
    let p = report::sweep_csv(&Runner::run(&poisson_spec).unwrap());
    assert_ne!(a, p, "bursty arrivals should change the results");
}

/// Heterogeneous machine speeds scale copy durations: a uniformly-2x
/// cluster halves the single job's flowtime and machine time exactly.
#[test]
fn heterogeneous_speeds_scale_copy_durations() {
    let run_at = |speed: f64| {
        let mut spec = ExperimentSpec::new("hetero", small_base());
        spec.base.horizon = 4000.0;
        spec.scenario =
            ClusterScenario::heterogeneous(vec![MachineClass::new(120, speed)]);
        spec.policies = vec![PolicyVariant::kind(SchedulerKind::Naive)];
        spec.loads = vec![LoadPoint::new(
            "single",
            1.0,
            WorkloadConfig::SingleJob { tasks: 120, mean: 1.0, alpha: 2.0 },
        )];
        spec.seeds = vec![9];
        spec.threads = 1;
        Runner::run(&spec).unwrap()
    };
    let slow = run_at(1.0).merged(0, 0);
    let fast = run_at(2.0).merged(0, 0);
    assert_eq!(slow.completed.len(), 1);
    assert_eq!(fast.completed.len(), 1);
    assert!(
        (fast.completed[0].flowtime - slow.completed[0].flowtime / 2.0).abs() < 1e-9,
        "2x cluster should halve the flowtime: {} vs {}",
        fast.completed[0].flowtime,
        slow.completed[0].flowtime
    );
    assert!(
        (fast.total_machine_time - slow.total_machine_time / 2.0).abs() < 1e-6,
        "2x cluster should halve machine time"
    );
}

/// A mixed cluster must sit strictly between all-slow and all-fast.
#[test]
fn mixed_cluster_between_homogeneous_extremes() {
    let run_with = |classes: Vec<MachineClass>| {
        let mut spec = ExperimentSpec::new("mix", small_base());
        spec.base.horizon = 4000.0;
        spec.scenario = ClusterScenario::heterogeneous(classes);
        spec.policies = vec![PolicyVariant::kind(SchedulerKind::Naive)];
        spec.loads = vec![LoadPoint::new(
            "single",
            1.0,
            WorkloadConfig::SingleJob { tasks: 120, mean: 1.0, alpha: 2.0 },
        )];
        spec.seeds = vec![9];
        spec.threads = 2;
        Runner::run(&spec).unwrap().merged(0, 0).total_machine_time
    };
    let slow = run_with(vec![MachineClass::new(120, 1.0)]);
    let fast = run_with(vec![MachineClass::new(120, 2.0)]);
    let mixed =
        run_with(vec![MachineClass::new(60, 1.0), MachineClass::new(60, 2.0)]);
    assert!(fast < mixed && mixed < slow, "fast {fast} < mixed {mixed} < slow {slow}");
}

/// Policy patches apply per-cell without leaking into neighbours: the
/// unpatched SDA cells of one sweep match a sweep with no patched variants.
#[test]
fn patched_variants_do_not_leak() {
    let mut with_patch = ExperimentSpec::new("p", small_base());
    with_patch.policies = vec![
        PolicyVariant::kind(SchedulerKind::Sda),
        PolicyVariant::with_sigma(SchedulerKind::Sda, 4.0),
    ];
    with_patch.loads = vec![LoadPoint::lambda(0.4)];
    with_patch.seeds = vec![3];
    with_patch.threads = 4;
    let both = Runner::run(&with_patch).unwrap();

    let mut alone = ExperimentSpec::new("q", small_base());
    alone.policies = vec![PolicyVariant::kind(SchedulerKind::Sda)];
    alone.loads = vec![LoadPoint::lambda(0.4)];
    alone.seeds = vec![3];
    alone.threads = 1;
    let solo = Runner::run(&alone).unwrap();

    let a = &both.cell(0, 0, 0).result;
    let b = &solo.cell(0, 0, 0).result;
    assert_eq!(a.completed.len(), b.completed.len());
    assert_eq!(a.total_machine_time, b.total_machine_time);
    assert_eq!(a.speculative_launches, b.speculative_launches);
}
