//! Integration tests over the PJRT runtime + real artifacts.
//!
//! These need `make artifacts` to have been run; each test skips (with a
//! loud message) when artifacts are absent so `cargo test` stays green on a
//! fresh checkout, while `make test` always exercises the real path.

use specsim::opt::gradient::{GradientSolver, P2Job, P2Problem};
use specsim::opt::pareto_math;
use specsim::runtime::solver::{sda_tables, sigma_curve, PjrtP2};
use specsim::runtime::Manifest;
use specsim::scheduler::budget::P2Backend;

const DIR: &str = "artifacts";

fn artifacts_present() -> bool {
    if Manifest::load(DIR).is_ok() {
        true
    } else {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts` for runtime coverage");
        false
    }
}

fn fig1_problem() -> P2Problem {
    P2Problem {
        jobs: vec![
            P2Job { mu: 1.0, m: 10.0, age: 0.0 },
            P2Job { mu: 2.0, m: 20.0, age: 0.0 },
            P2Job { mu: 1.0, m: 5.0, age: 0.0 },
            P2Job { mu: 2.0, m: 10.0, age: 0.0 },
        ],
        n_avail: 100.0,
        gamma: 0.01,
        r: 8.0,
        alpha: 2.0,
    }
}

#[test]
fn manifest_describes_all_artifacts() {
    if !artifacts_present() {
        return;
    }
    let m = Manifest::load(DIR).unwrap();
    for name in ["p2_solver", "p2_trace", "sigma_curve", "sda_opt"] {
        assert!(m.entry(name).is_some(), "{name} missing from manifest");
        assert!(m.hlo_path(name).is_ok(), "{name} HLO file missing");
    }
    assert_eq!(m.statics.c_grid.n, 64);
}

#[test]
fn pjrt_p2_matches_rust_solver_on_fig1() {
    if !artifacts_present() {
        return;
    }
    let mut pjrt = PjrtP2::load(DIR).expect("load p2_solver artifact");
    let p = fig1_problem();
    let c_pjrt = pjrt.solve(&p);
    let c_rust = GradientSolver::default().solve(&p).c;
    assert_eq!(c_pjrt.len(), 4);
    for (a, b) in c_pjrt.iter().zip(&c_rust) {
        assert!(
            (a - b).abs() < 0.5,
            "pjrt {c_pjrt:?} vs rust {c_rust:?} diverge"
        );
    }
    // feasibility of the continuous solution
    let used: f64 = c_pjrt.iter().zip(&p.jobs).map(|(c, j)| c * j.m).sum();
    assert!(used <= p.n_avail * 1.10, "used {used}");
    assert_eq!(pjrt.calls, 1);
}

#[test]
fn pjrt_p2_handles_single_job_and_full_batch() {
    if !artifacts_present() {
        return;
    }
    let mut pjrt = PjrtP2::load(DIR).expect("load");
    // single job
    let p1 = P2Problem {
        jobs: vec![P2Job { mu: 1.0, m: 4.0, age: 2.0 }],
        n_avail: 400.0,
        gamma: 1e-3,
        r: 8.0,
        alpha: 2.0,
    };
    let c = pjrt.solve(&p1);
    assert_eq!(c.len(), 1);
    assert!(c[0] >= 7.0, "ample capacity should clone aggressively: {c:?}");
    // full batch
    let jobs: Vec<P2Job> = (0..pjrt.max_batch())
        .map(|i| P2Job { mu: 1.0 + (i % 3) as f64 * 0.5, m: 5.0 + (i % 20) as f64, age: 0.0 })
        .collect();
    let total: f64 = jobs.iter().map(|j| j.m).sum();
    let p = P2Problem { jobs, n_avail: total * 2.0, gamma: 0.01, r: 8.0, alpha: 2.0 };
    let c = pjrt.solve(&p);
    assert_eq!(c.len(), pjrt.max_batch());
    for &x in &c {
        assert!((1.0..=8.0).contains(&x), "c = {x}");
    }
}

#[test]
fn sigma_curve_artifact_matches_rust_quadrature() {
    if !artifacts_present() {
        return;
    }
    for alpha in [2.0, 3.5] {
        let (sg, er) = sigma_curve(DIR, alpha).expect("sigma_curve artifact");
        assert_eq!(sg.len(), er.len());
        for (s, v) in sg.iter().zip(&er).step_by(8) {
            let rust = pareto_math::ese_resource(alpha, *s);
            assert!(
                (v - rust).abs() < 5e-3,
                "alpha={alpha} sigma={s}: pjrt {v} vs rust {rust}"
            );
        }
    }
}

#[test]
fn sda_tables_artifact_reproduces_theorem3() {
    if !artifacts_present() {
        return;
    }
    let (sigma, tau, resource, c_max) = sda_tables(DIR, 2.0, 0.1).expect("sda_opt artifact");
    let s_n = sigma.len();
    assert_eq!(tau.len(), s_n * c_max);
    assert_eq!(resource.len(), s_n * c_max);
    // c* = 2 for sigma > 1 (Theorem 3); sigma* ~ 1.707
    let mut best = (0usize, f64::INFINITY);
    for (i, &s) in sigma.iter().enumerate() {
        let row = &tau[i * c_max..(i + 1) * c_max];
        let cstar = row
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if s > 1.0 {
            assert_eq!(cstar, 1, "sigma={s}: c* should be 2 (index 1)");
        }
        let r = resource[i * c_max + cstar];
        if r < best.1 {
            best = (i, r);
        }
    }
    assert!(
        (sigma[best.0] - 1.707).abs() < 0.1,
        "sigma* = {} vs 1.707",
        sigma[best.0]
    );
}

#[test]
fn sca_uses_pjrt_backend_end_to_end() {
    if !artifacts_present() {
        return;
    }
    use specsim::cluster::generator::generate;
    use specsim::cluster::sim::Simulator;
    use specsim::config::{SimConfig, WorkloadConfig};

    let mut cfg = SimConfig::default();
    cfg.machines = 500;
    cfg.horizon = 60.0;
    cfg.use_runtime = true;
    cfg.artifacts_dir = DIR.to_string();
    cfg.scheduler = specsim::scheduler::SchedulerKind::Sca;
    let wl = WorkloadConfig::paper(0.5);
    let workload = generate(&wl, cfg.horizon, 1);
    let sched = specsim::scheduler::build(&cfg, &wl).unwrap();
    let res = Simulator::new(cfg, workload, sched).run();
    assert!(!res.completed.is_empty());
    assert!(res.speculative_launches > 0, "SCA via PJRT should clone");
}
