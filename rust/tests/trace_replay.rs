//! The streaming trace-replay acceptance suite (PR 9).
//!
//! The equivalence bar: a materialized workload frozen to a trace file and
//! replayed through the streaming `JobSource` path must serialize the
//! **byte-identical** sweep CSV — across both event-queue backends and
//! both slot-loop modes — because the streamed simulator replays the eager
//! constructor's RNG splits in the same dense-id order and admits each job
//! exactly where its `Arrival` event would have popped.
//!
//! Around that bar: `TraceReader` edge cases (CRLF, truncated final line,
//! empty file, rows wider than the 64 KiB chunk), structured error
//! positions, `GeneratorSource` bit-equivalence with `generator::generate`,
//! the scan-vs-`estimate_alpha` bitwise agreement the scheduler thresholds
//! rely on, and the `--max-resident-jobs` recycling mode's sketched
//! aggregates.

use specsim::cluster::event::EventQueueKind;
use specsim::cluster::generator::{estimate_alpha, generate};
use specsim::cluster::sim::Simulator;
use specsim::cluster::trace;
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner};
use specsim::metrics::report;
use specsim::scheduler::{self, SchedulerKind};
use specsim::workload::{
    scan, source_for, GeneratorSource, JobSource, StreamSource, TraceError, TraceFormat,
    TraceReader, CHUNK,
};

/// A per-test temp path (tests run concurrently; the name keeps them
/// from clobbering each other).
fn temp_trace(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("specsim_replay_{tag}_{}.csv", std::process::id()))
}

fn base_config() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.machines = 100;
    cfg.horizon = 100.0;
    cfg.use_runtime = false;
    cfg
}

/// The tentpole bar: freeze a generated workload to a trace file, then
/// sweep it twice — materialized up front (`materialize_traces = true`)
/// and streamed through the bounded lookahead window — and require the
/// two sweep CSVs byte-identical across {calendar, binary-heap} x
/// {wakeup planner, polled loop}.  A shrunken window (4 jobs resident)
/// must not change the bytes either: the window bounds memory, never
/// admission order.
#[test]
fn streamed_sweep_byte_identical_to_materialized_across_backends() {
    let path = temp_trace("sweep");
    let wl = generate(&WorkloadConfig::paper(1.0), 100.0, 7);
    assert!(wl.specs.len() > 20, "trace too small to be interesting");
    trace::save(&wl, &path).unwrap();
    let path_str = path.to_string_lossy().into_owned();

    let spec_with = |materialize: bool, window: usize| {
        let mut wl_cfg = WorkloadConfig::trace(path_str.clone());
        if let WorkloadConfig::Trace { window: w, .. } = &mut wl_cfg {
            *w = window;
        }
        let mut spec = ExperimentSpec::new("replay", base_config());
        spec.policies = vec![
            PolicyVariant::kind(SchedulerKind::Naive),
            PolicyVariant::kind(SchedulerKind::Sda),
            PolicyVariant::kind(SchedulerKind::Mantri),
            PolicyVariant::policy("est-srpt+sda").unwrap(),
        ];
        spec.loads = vec![LoadPoint::new("trace", 1.0, wl_cfg)];
        spec.seeds = vec![7];
        spec.threads = 2;
        spec.materialize_traces = materialize;
        spec
    };
    let run = |materialize: bool, window: usize, queue: EventQueueKind, wakeup: bool| {
        let mut spec = spec_with(materialize, window);
        spec.base.event_queue = queue;
        spec.base.wakeup = wakeup;
        report::sweep_csv(&Runner::run(&spec).unwrap())
    };

    for queue in [EventQueueKind::Calendar, EventQueueKind::BinaryHeap] {
        for wakeup in [true, false] {
            let materialized = run(true, 0, queue, wakeup);
            assert!(materialized.lines().count() > 4, "empty sweep?");
            let streamed = run(false, 0, queue, wakeup);
            assert_eq!(
                streamed, materialized,
                "{queue:?} wakeup={wakeup}: streaming replay diverged from the \
                 materialized workload"
            );
            let tiny_window = run(false, 4, queue, wakeup);
            assert_eq!(
                tiny_window, materialized,
                "{queue:?} wakeup={wakeup}: a 4-job lookahead window changed the bytes"
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// Reader edge cases: CRLF terminators, a truncated final line (no
/// trailing newline), an empty file, and a single native row whose
/// durations field is wider than the 64 KiB read chunk.
#[test]
fn reader_handles_crlf_truncated_tail_empty_and_oversized_rows() {
    // CRLF + truncated tail, simple format with header
    let bytes = b"arrival,duration,tasks\r\n0.5,1.0,2\r\n1.5,2.0,3";
    let rows: Vec<_> = TraceReader::new(&bytes[..], "mem", TraceFormat::Auto)
        .collect::<Result<Vec<_>, _>>()
        .unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!(rows[0].spec.arrival, 0.5);
    assert_eq!(rows[0].durations, vec![1.0, 1.0]);
    assert_eq!(rows[1].spec.arrival, 1.5);
    assert_eq!(rows[1].spec.num_tasks, 3);
    assert_eq!(rows[1].line, 3, "physical line numbers count the header");

    // blank interior lines are skipped, not errors
    let bytes = b"arrival,duration,tasks\n\n0.5,1.0,2\n\n";
    let rows: Vec<_> = TraceReader::new(&bytes[..], "mem", TraceFormat::Auto)
        .collect::<Result<Vec<_>, _>>()
        .unwrap();
    assert_eq!(rows.len(), 1);

    // empty file: a structured Empty error, then the iterator fuses
    let mut reader = TraceReader::new(&b""[..], "mem", TraceFormat::Auto);
    match reader.next() {
        Some(Err(TraceError::Empty { path })) => assert_eq!(path, "mem"),
        other => panic!("expected TraceError::Empty, got {other:?}"),
    }
    assert!(reader.next().is_none(), "the reader must fuse after an error");

    // jsonl rows expand the per-job mean to all task copies
    let bytes = br#"{"arrival":0.25,"duration":2.0,"tasks":3,"alpha":2.5}"#;
    let rows: Vec<_> = TraceReader::new(&bytes[..], "mem", TraceFormat::Auto)
        .collect::<Result<Vec<_>, _>>()
        .unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].durations, vec![2.0, 2.0, 2.0]);
    assert_eq!(rows[0].spec.dist.alpha, 2.5);

    // one native row wider than the read chunk: the carry buffer grows
    // until the newline arrives instead of splitting the line
    let n = CHUNK / 4 + 1024; // "1.5;" is 4 bytes per duration
    let mut text = String::from("job,arrival,mu,alpha,num_tasks,durations\n");
    text.push_str(&format!("0,0.0,3.0,2.0,{n},"));
    for i in 0..n {
        if i > 0 {
            text.push(';');
        }
        text.push_str("1.5");
    }
    text.push('\n');
    assert!(text.len() > CHUNK, "the row must actually cross a chunk boundary");
    let rows: Vec<_> = TraceReader::new(text.as_bytes(), "mem", TraceFormat::Auto)
        .collect::<Result<Vec<_>, _>>()
        .unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].durations.len(), n);
    assert_eq!(rows[0].durations[0], 1.5);
    assert_eq!(rows[0].durations[n - 1], 1.5);
}

/// Every parse failure carries the path, the 1-based physical line, and
/// the 1-based byte column of the offending field — and the iterator
/// fuses after reporting it.
#[test]
fn reader_errors_carry_path_line_and_column_and_fuse() {
    let bytes = b"arrival,duration,tasks\n0.0,1.0,2\n0.5,oops,2\n1.0,1.0,2\n";
    let mut reader = TraceReader::new(&bytes[..], "bad.csv", TraceFormat::Auto);
    assert!(reader.next().unwrap().is_ok());
    match reader.next() {
        Some(Err(TraceError::Parse { path, line, column, message })) => {
            assert_eq!(path, "bad.csv");
            assert_eq!(line, 3);
            assert_eq!(column, 5, "column points at the duration field");
            assert!(message.contains("duration"), "unhelpful message: {message}");
        }
        other => panic!("expected a Parse error, got {other:?}"),
    }
    assert!(reader.next().is_none(), "row 4 must not be yielded after the error");

    // native rows must carry dense ids
    let bytes = b"job,arrival,mu,alpha,num_tasks,durations\n5,0.0,3.0,2.0,1,1.0\n";
    let mut reader = TraceReader::new(&bytes[..], "dense.csv", TraceFormat::Native);
    match reader.next() {
        Some(Err(TraceError::Parse { line, message, .. })) => {
            assert_eq!(line, 2);
            assert!(message.contains("non-dense"), "{message}");
        }
        other => panic!("expected a dense-id error, got {other:?}"),
    }
}

/// `StreamSource` enforces the non-decreasing-arrival contract replay
/// depends on, and honors the `max_jobs` cap.
#[test]
fn stream_source_enforces_time_order_and_max_jobs() {
    let path = temp_trace("order");
    std::fs::write(&path, "arrival,duration,tasks\n5.0,1.0,1\n3.0,1.0,1\n").unwrap();
    let path_str = path.to_string_lossy().into_owned();
    let mut src = StreamSource::open(&path_str, TraceFormat::Auto, None).unwrap();
    assert!(src.next_arrival().unwrap().is_ok());
    match src.next_arrival() {
        Some(Err(TraceError::Parse { line, message, .. })) => {
            assert_eq!(line, 3);
            assert!(message.contains("time-ordered"), "{message}");
        }
        other => panic!("expected an out-of-order error, got {other:?}"),
    }

    let mut capped = StreamSource::open(&path_str, TraceFormat::Auto, Some(1)).unwrap();
    assert!(capped.next_arrival().unwrap().is_ok());
    assert!(capped.next_arrival().is_none(), "max_jobs = 1 must stop after one row");
    let _ = std::fs::remove_file(&path);
}

/// `GeneratorSource` replays the exact RNG draw order of
/// `generator::generate`: same ids, same arrivals, same distributions,
/// same first-copy durations, bit for bit, for every synthetic shape.
#[test]
fn generator_source_is_bit_identical_to_materialized_generation() {
    let shapes = [
        WorkloadConfig::paper(2.0),
        WorkloadConfig::bursty_paper(1.0, 3.0),
        WorkloadConfig::SingleJob { tasks: 12, mean: 1.5, alpha: 2.0 },
    ];
    for (si, wl_cfg) in shapes.iter().enumerate() {
        let (horizon, seed) = (50.0, 11);
        let wl = generate(wl_cfg, horizon, seed);
        assert!(!wl.specs.is_empty(), "shape {si} generated nothing");
        let mut src = GeneratorSource::new(wl_cfg, horizon, seed).unwrap();
        let mut n = 0usize;
        while let Some(next) = src.next_arrival() {
            let job = next.unwrap();
            let spec = &wl.specs[n];
            assert_eq!(job.spec.id.0, spec.id.0, "shape {si} job {n}");
            assert_eq!(job.spec.arrival.to_bits(), spec.arrival.to_bits(), "shape {si} job {n}");
            assert_eq!(job.spec.num_tasks, spec.num_tasks, "shape {si} job {n}");
            assert_eq!(job.spec.dist.mu.to_bits(), spec.dist.mu.to_bits(), "shape {si} job {n}");
            assert_eq!(job.durations.len(), wl.first_durations[n].len());
            for (a, b) in job.durations.iter().zip(&wl.first_durations[n]) {
                assert_eq!(a.to_bits(), b.to_bits(), "shape {si} job {n} duration");
            }
            n += 1;
        }
        assert_eq!(n, wl.specs.len(), "shape {si}: the source stopped early (or late)");
    }
}

/// The streaming pre-pass fits the tail index with the exact accumulation
/// `estimate_alpha` runs on the materialized workload — bitwise equal, so
/// SDA/ESE thresholds cannot drift between the two paths.  (Hinges on
/// `trace::save` writing shortest-round-trip floats.)
#[test]
fn scan_alpha_matches_estimate_alpha_bitwise() {
    let path = temp_trace("alpha");
    let wl = generate(&WorkloadConfig::paper(1.0), 80.0, 3);
    trace::save(&wl, &path).unwrap();
    let stats = scan(&path.to_string_lossy(), TraceFormat::Auto).unwrap();
    assert_eq!(stats.jobs as usize, wl.specs.len());
    assert_eq!(stats.alpha.to_bits(), estimate_alpha(&wl).to_bits());
    assert!(stats.tasks.mean() > 0.0);
    assert!(stats.duration.mean() > 0.0);
    let _ = std::fs::remove_file(&path);
}

/// `--max-resident-jobs`: recycling completed records into the streaming
/// sketches changes only where aggregates live, never the dynamics — the
/// capped run completes exactly the jobs the uncapped run does, holds no
/// materialized records at the end, and its Welford mean agrees with the
/// exact mean.
#[test]
fn capped_replay_sketches_every_completed_job() {
    let path = temp_trace("capped");
    let wl = generate(&WorkloadConfig::paper(1.0), 100.0, 7);
    trace::save(&wl, &path).unwrap();
    let wl_cfg = WorkloadConfig::trace(path.to_string_lossy().into_owned());
    let cfg = base_config();

    let run_streamed = |cap: Option<usize>| {
        let mut cfg = cfg.clone();
        cfg.max_resident_jobs = cap;
        let sched = scheduler::build(&cfg, &wl_cfg).unwrap();
        let source = source_for(&wl_cfg, cfg.horizon, cfg.seed).unwrap();
        Simulator::from_source(cfg, source, 0, sched).run()
    };
    let uncapped = run_streamed(None);
    assert!(uncapped.streamed.is_none());
    assert!(uncapped.completed.len() > 20);

    let capped = run_streamed(Some(8));
    let sink = capped.streamed.as_ref().expect("capped runs aggregate into sketches");
    assert!(capped.completed.is_empty(), "capped runs must not retain records");
    assert_eq!(sink.drained as usize, uncapped.completed.len());
    let exact = uncapped.mean_flowtime();
    let sketched = sink.flowtime.mean();
    assert!(
        (exact - sketched).abs() <= 1e-9 * exact.abs().max(1.0),
        "Welford mean {sketched} drifted from the exact mean {exact}"
    );
    assert!(sink.flow_p90.quantile() >= sink.flow_p80.quantile() - 1e-12);
    let _ = std::fs::remove_file(&path);
}
