//! Fig. 6: heavily loaded regime (lambda in {30, 40}, M = 3000) — CMFs of
//! flowtime and resource for ESE vs Mantri.  Paper headlines: ~18% lower
//! mean flowtime at lambda = 40 with matching resource; 80% of jobs finish
//! within ~10 units under ESE vs ~18 under Mantri.

use std::path::Path;

use crate::config::{SimConfig, WorkloadConfig};
use crate::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner, SweepResult};
use crate::metrics::report::{self, SummaryRow};
use crate::scheduler::SchedulerKind;

use super::Scale;

pub fn config(scale: Scale, lambda_full: f64) -> (SimConfig, WorkloadConfig) {
    let mut cfg = SimConfig::default();
    cfg.machines = scale.machines(3000);
    cfg.horizon = scale.horizon(1500.0);
    cfg.sigma = Some(1.7); // the paper's choice from the Fig. 4 analysis
    // like-for-like baseline: ESE is "an extension of Mantri", so the Fig. 6
    // Mantri shares the slotted SRPT structure and differs only in the
    // duplicate rule + small-job cloning (see DESIGN.md)
    cfg.mantri_srpt = true;
    let lambda = lambda_full * cfg.machines as f64 / 3000.0;
    (cfg, WorkloadConfig::paper(lambda))
}

/// Both arrival rates on the load axis, ESE vs Mantri on the policy axis.
pub fn spec(scale: Scale) -> ExperimentSpec {
    let (cfg, _) = config(scale, 30.0);
    let mut spec = ExperimentSpec::new("fig6", cfg);
    spec.policies = vec![
        PolicyVariant::kind(SchedulerKind::Ese),
        PolicyVariant::kind(SchedulerKind::Mantri),
    ];
    spec.loads = [30.0f64, 40.0]
        .into_iter()
        .map(|lambda_full| {
            let (_, wl) = config(scale, lambda_full);
            LoadPoint::new(format!("lambda{}", lambda_full as u32), lambda_full, wl)
        })
        .collect();
    spec.seeds = vec![1, 2, 3];
    spec
}

/// Per-lambda CMF CSVs + summary tables from a completed sweep.
pub fn write_outputs(sweep: &SweepResult, out_dir: &Path) -> Result<(), String> {
    for (li, (_, lambda_full)) in sweep.loads.iter().enumerate() {
        let mut rows = Vec::new();
        let mut flow_series = Vec::new();
        let mut res_series = Vec::new();
        for (pi, (label, _)) in sweep.policies.iter().enumerate() {
            let res = sweep.merged(pi, li);
            rows.push(SummaryRow::from_result(&res));
            flow_series.push((label.as_str(), res.flowtime_cdf()));
            res_series.push((label.as_str(), res.resource_cdf()));
        }
        let tag = *lambda_full as u32;
        report::write_file(
            out_dir.join(format!("fig6a_flowtime_cmf_lambda{tag}.csv")),
            &report::cmf_csv(&mut flow_series, 400),
        )
        .map_err(|e| e.to_string())?;
        report::write_file(
            out_dir.join(format!("fig6b_resource_cmf_lambda{tag}.csv")),
            &report::cmf_csv(&mut res_series, 400),
        )
        .map_err(|e| e.to_string())?;
        println!("fig6 (lambda_full={lambda_full}, M={}):", sweep.base.machines);
        print!("{}", report::summary_table(&rows));
        println!(
            "  ese vs mantri: flowtime {:+.1}% (paper: ~-18% at lambda=40), \
             resource {:+.1}% (paper: ~0%)",
            (rows[0].mean_flowtime / rows[1].mean_flowtime - 1.0) * 100.0,
            (rows[0].mean_resource / rows[1].mean_resource - 1.0) * 100.0,
        );
    }
    Ok(())
}

pub fn run(
    out_dir: &Path,
    artifacts_dir: &str,
    scale: Scale,
    threads: usize,
) -> Result<(), String> {
    let mut spec = spec(scale);
    spec.base.artifacts_dir = artifacts_dir.to_string();
    spec.threads = threads;
    let sweep = Runner::run(&spec)?;
    write_outputs(&sweep, out_dir)
}
