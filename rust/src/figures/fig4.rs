//! Fig. 4: the analytic per-task resource curve `E[R]/E[x]` against sigma for
//! alpha in {2,3,4,5} (Eq. 30-33).  Uses the AOT-compiled `sigma_curve`
//! artifact when present (exercising the Pallas kernel end-to-end) and the
//! f64 rust quadrature otherwise; when both are available the driver
//! cross-checks them.

use std::path::Path;

use crate::experiment::run_parallel;
use crate::metrics::report;
use crate::opt::pareto_math;
use crate::runtime::solver::sigma_curve;

use super::Scale;

pub const ALPHAS: [f64; 4] = [2.0, 3.0, 4.0, 5.0];

/// (sigma grid, curve) for one alpha, preferring the PJRT artifact.
pub fn curve(artifacts_dir: &str, alpha: f64) -> (Vec<f64>, Vec<f64>, &'static str) {
    match sigma_curve(artifacts_dir, alpha) {
        Ok((sg, er)) => (sg, er, "pjrt"),
        Err(_) => {
            let sg: Vec<f64> = (1..=120).map(|i| i as f64 * 0.05).collect();
            let er = sg.iter().map(|&s| pareto_math::ese_resource(alpha, s)).collect();
            (sg, er, "rust")
        }
    }
}

pub fn run(
    out_dir: &Path,
    artifacts_dir: &str,
    _scale: Scale,
    threads: usize,
) -> Result<(), String> {
    let mut series = Vec::new();
    println!("fig4 (E[R]/E[x] vs sigma):");
    // one curve per alpha in parallel; each worker loads its own PJRT
    // executor (thread-pinned) or falls back to the rust quadrature
    let curves = run_parallel(ALPHAS.len(), threads, |i| curve(artifacts_dir, ALPHAS[i]));
    for (alpha, (sg, er, backend)) in ALPHAS.into_iter().zip(curves) {
        let (mut best_s, mut best_v) = (0.0, f64::INFINITY);
        for (&s, &v) in sg.iter().zip(&er) {
            if v < best_v {
                best_v = v;
                best_s = s;
            }
        }
        println!(
            "  alpha={alpha}: sigma* = {best_s:.3}, E[R]* = {best_v:.4} [{backend}] \
             (paper: ~1.7 at alpha=2, ->2.0 for alpha>=3)"
        );
        if backend == "pjrt" {
            // cross-check the Pallas kernel against the f64 quadrature
            for (&s, &v) in sg.iter().zip(&er).step_by(16) {
                let rust = pareto_math::ese_resource(alpha, s);
                assert!(
                    (v - rust).abs() < 5e-3,
                    "pjrt/rust divergence at alpha={alpha}, sigma={s}: {v} vs {rust}"
                );
            }
        }
        series.push((
            format!("alpha_{alpha}"),
            sg.into_iter().zip(er).collect::<Vec<_>>(),
        ));
    }
    report::write_file(out_dir.join("fig4_sigma_curves.csv"), &report::xy_csv(&series))
        .map_err(|e| e.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_fallback_curves_have_interior_minimum() {
        for alpha in ALPHAS {
            let (sg, er, _) = curve("/nonexistent", alpha);
            let i = er
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert!(i > 0 && i < sg.len() - 1, "alpha={alpha}: boundary minimum");
            assert!((1.5..=2.2).contains(&sg[i]));
        }
    }
}
