//! Fig. 2: lightly loaded regime (lambda = 6, M = 3000, horizon 1500,
//! 3 seeds) — CMFs of job flowtime and resource for SCA and SDA against the
//! Mantri baseline.  Paper headlines: ~60% lower mean flowtime; SCA gets
//! 80%/90% of jobs under 6/9 time units vs 17/25 for Mantri; SCA spends
//! more resource (80th pct ~2 vs ~1.5 units).

use std::path::Path;

use crate::config::{SimConfig, WorkloadConfig};
use crate::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner, SweepResult};
use crate::metrics::report::{self, SummaryRow};
use crate::scheduler::SchedulerKind;

use super::Scale;

pub fn config(scale: Scale) -> (SimConfig, WorkloadConfig) {
    let mut cfg = SimConfig::default();
    cfg.machines = scale.machines(3000);
    cfg.horizon = scale.horizon(1500.0);
    // keep the offered load identical under scaling
    let lambda = 6.0 * cfg.machines as f64 / 3000.0;
    (cfg, WorkloadConfig::paper(lambda))
}

/// The experiment as a declaration: 3 policies x 1 load x 3 seeds (the
/// paper pools the ~27000 jobs of 3 replications).
pub fn spec(scale: Scale) -> ExperimentSpec {
    let (cfg, wl) = config(scale);
    let lambda = match &wl {
        WorkloadConfig::Poisson { lambda, .. } => *lambda,
        _ => unreachable!(),
    };
    let mut spec = ExperimentSpec::new("fig2", cfg);
    spec.policies = vec![
        PolicyVariant::kind(SchedulerKind::Sca),
        PolicyVariant::kind(SchedulerKind::Sda),
        PolicyVariant::kind(SchedulerKind::Mantri),
    ];
    spec.loads = vec![LoadPoint::new("paper", lambda, wl)];
    spec.seeds = (1..=3).collect();
    spec
}

/// Write the CMF CSVs and print the summary table from a completed sweep.
pub fn write_outputs(sweep: &SweepResult, out_dir: &Path) -> Result<(), String> {
    let mut rows = Vec::new();
    let mut flow_series = Vec::new();
    let mut res_series = Vec::new();
    for (pi, (label, _)) in sweep.policies.iter().enumerate() {
        let res = sweep.merged(pi, 0);
        rows.push(SummaryRow::from_result(&res));
        flow_series.push((label.as_str(), res.flowtime_cdf()));
        res_series.push((label.as_str(), res.resource_cdf()));
    }
    report::write_file(
        out_dir.join("fig2a_flowtime_cmf.csv"),
        &report::cmf_csv(&mut flow_series, 400),
    )
    .map_err(|e| e.to_string())?;
    report::write_file(
        out_dir.join("fig2b_resource_cmf.csv"),
        &report::cmf_csv(&mut res_series, 400),
    )
    .map_err(|e| e.to_string())?;
    println!("fig2 (lambda={:.2}, M={}):", sweep.loads[0].1, sweep.base.machines);
    print!("{}", report::summary_table(&rows));
    let mantri_ft = rows[2].mean_flowtime;
    for r in &rows[..2] {
        println!(
            "  {} vs mantri: flowtime {:+.1}% (paper: ~-60%)",
            r.scheduler,
            (r.mean_flowtime / mantri_ft - 1.0) * 100.0
        );
    }
    Ok(())
}

pub fn run(
    out_dir: &Path,
    artifacts_dir: &str,
    scale: Scale,
    threads: usize,
) -> Result<(), String> {
    let mut spec = spec(scale);
    spec.base.artifacts_dir = artifacts_dir.to_string();
    spec.threads = threads;
    let sweep = Runner::run(&spec)?;
    write_outputs(&sweep, out_dir)
}
