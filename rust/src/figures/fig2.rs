//! Fig. 2: lightly loaded regime (lambda = 6, M = 3000, horizon 1500,
//! 3 seeds) — CMFs of job flowtime and resource for SCA and SDA against the
//! Mantri baseline.  Paper headlines: ~60% lower mean flowtime; SCA gets
//! 80%/90% of jobs under 6/9 time units vs 17/25 for Mantri; SCA spends
//! more resource (80th pct ~2 vs ~1.5 units).

use std::path::Path;

use crate::cluster::generator::generate;
use crate::cluster::sim::{SimResult, Simulator};
use crate::config::{SimConfig, WorkloadConfig};
use crate::metrics::report::{self, SummaryRow};
use crate::scheduler::{self, SchedulerKind};

use super::Scale;

/// Run one scheduler over several seeds and merge the per-job records
/// (the paper repeats with 3 seeds and pools the ~27000 jobs).
pub fn run_seeds(cfg: &SimConfig, wl: &WorkloadConfig, seeds: &[u64]) -> SimResult {
    let mut merged: Option<SimResult> = None;
    for &seed in seeds {
        let mut c = cfg.clone();
        c.seed = seed;
        let workload = generate(wl, c.horizon, seed);
        let sched = scheduler::build(&c, wl).expect("scheduler build");
        let res = Simulator::new(c, workload, sched).run();
        merged = Some(match merged {
            None => res,
            Some(mut acc) => {
                acc.completed.extend(res.completed);
                acc.incomplete += res.incomplete;
                acc.total_machine_time += res.total_machine_time;
                acc.speculative_launches += res.speculative_launches;
                acc.utilization = (acc.utilization + res.utilization) / 2.0;
                acc
            }
        });
    }
    merged.expect("at least one seed")
}

pub fn config(scale: Scale) -> (SimConfig, WorkloadConfig) {
    let mut cfg = SimConfig::default();
    cfg.machines = scale.machines(3000);
    cfg.horizon = scale.horizon(1500.0);
    // keep the offered load identical under scaling
    let lambda = 6.0 * cfg.machines as f64 / 3000.0;
    (cfg, WorkloadConfig::paper(lambda))
}

pub fn run(out_dir: &Path, artifacts_dir: &str, scale: Scale) -> Result<(), String> {
    let (mut cfg, wl) = config(scale);
    cfg.artifacts_dir = artifacts_dir.to_string();
    let seeds: Vec<u64> = (1..=3).collect();
    let mut rows = Vec::new();
    let mut flow_series = Vec::new();
    let mut res_series = Vec::new();
    for kind in [SchedulerKind::Sca, SchedulerKind::Sda, SchedulerKind::Mantri] {
        cfg.scheduler = kind;
        let res = run_seeds(&cfg, &wl, &seeds);
        rows.push(SummaryRow::from_result(&res));
        flow_series.push((kind.as_str(), res.flowtime_cdf()));
        res_series.push((kind.as_str(), res.resource_cdf()));
    }
    report::write_file(
        out_dir.join("fig2a_flowtime_cmf.csv"),
        &report::cmf_csv(&mut flow_series, 400),
    )
    .map_err(|e| e.to_string())?;
    report::write_file(
        out_dir.join("fig2b_resource_cmf.csv"),
        &report::cmf_csv(&mut res_series, 400),
    )
    .map_err(|e| e.to_string())?;
    println!("fig2 (lambda={:.2}, M={}):", match wl {
        WorkloadConfig::Poisson { lambda, .. } => lambda,
        _ => unreachable!(),
    }, cfg.machines);
    print!("{}", report::summary_table(&rows));
    let mantri_ft = rows[2].mean_flowtime;
    for r in &rows[..2] {
        println!(
            "  {} vs mantri: flowtime {:+.1}% (paper: ~-60%)",
            r.scheduler,
            (r.mean_flowtime / mantri_ft - 1.0) * 100.0
        );
    }
    Ok(())
}
