//! One driver per paper figure (see DESIGN.md §5).  Shared by the CLI
//! (`specsim figure <id>`), the examples, and `cargo bench`.
//!
//! Every driver routes through the [`experiment`](crate::experiment)
//! engine: the simulation figures declare an `ExperimentSpec` grid and run
//! it on the parallel `Runner`; the solver/analytic figures (fig1, fig4)
//! fan their independent cells out with `run_parallel`.  `threads = 0`
//! means one worker per core; any N > 0 produces identical output.

pub mod churn;
pub mod crossover;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod threshold;

use std::path::Path;

/// Scale factor for quick runs: 1.0 reproduces the paper's full set-up,
/// smaller values shrink horizon/machines proportionally (benches use it).
#[derive(Clone, Copy, Debug)]
pub struct Scale(pub f64);

impl Scale {
    pub fn full() -> Self {
        Scale(1.0)
    }
    pub fn horizon(&self, full: f64) -> f64 {
        (full * self.0).max(20.0)
    }
    pub fn machines(&self, full: usize) -> usize {
        ((full as f64 * self.0) as usize).max(20)
    }
}

/// Run every figure driver, writing CSVs under `out_dir`.  `threads` is
/// each driver's worker count (0 = one per core).
pub fn run_all(
    out_dir: &Path,
    artifacts_dir: &str,
    scale: Scale,
    threads: usize,
) -> Result<(), String> {
    fig1::run(out_dir, artifacts_dir, scale, threads)?;
    fig2::run(out_dir, artifacts_dir, scale, threads)?;
    fig3::run(out_dir, artifacts_dir, scale, threads)?;
    fig4::run(out_dir, artifacts_dir, scale, threads)?;
    fig5::run(out_dir, artifacts_dir, scale, threads)?;
    fig6::run(out_dir, artifacts_dir, scale, threads)?;
    threshold::run(out_dir, artifacts_dir, scale, threads)?;
    crossover::run(out_dir, artifacts_dir, scale, threads)?;
    churn::run(out_dir, artifacts_dir, scale, threads)?;
    Ok(())
}
