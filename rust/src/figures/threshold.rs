//! The Sec. III-B cutoff experiment (no figure in the paper, but the
//! threshold is central to its story): compute lambda^U analytically
//! (Eq. 1-5) and validate it empirically by sweeping lambda across the
//! cutoff with the 2-copy cloning scheduler vs the naive baseline — below
//! the cutoff cloning wins on mean task delay, above it loses/destabilizes.

use std::path::Path;

use crate::analysis::threshold::{cutoff_lambda, delay_cloned, delay_no_spec};
use crate::cluster::sim::SimResult;
use crate::config::{SimConfig, WorkloadConfig};
use crate::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner};
use crate::metrics::report::{self, SummaryRow};
use crate::scheduler::SchedulerKind;

use super::Scale;

pub const FRACS: [f64; 5] = [0.3, 0.6, 0.9, 1.1, 1.3];

/// The paper's workload moments (`E[m] = 50.5`, `E[s] = 2.5`, alpha = 2) —
/// shared by the analytic header and the empirical sweep so the two can't
/// drift apart.
pub const MEAN_TASKS: f64 = 50.5;
pub const MEAN_DURATION: f64 = 2.5;
pub const TAIL_ALPHA: f64 = 2.0;

/// The empirical sweep: load axis = lambda as a fraction of the analytic
/// cutoff, policy axis = strict 2-copy cloning vs no speculation.
pub fn spec(scale: Scale) -> ExperimentSpec {
    let mut cfg = SimConfig::default();
    cfg.machines = scale.machines(600);
    cfg.horizon = scale.horizon(600.0);
    // strict cloning: the literal Sec. III scheme, so exceeding the
    // Theorem-1 bound actually destabilizes instead of degrading gracefully.
    // Past the bound the queue grows without bound; the completed-jobs CMF
    // is censored, so the instability shows up as a collapsing completion
    // ratio rather than an exploding mean.
    cfg.clone_strict = true;
    let rep = cutoff_lambda(cfg.machines, MEAN_TASKS, MEAN_DURATION, TAIL_ALPHA);
    let mut spec = ExperimentSpec::new("threshold", cfg);
    spec.policies = vec![
        PolicyVariant::kind(SchedulerKind::CloneAll),
        PolicyVariant::kind(SchedulerKind::Naive),
    ];
    spec.loads = FRACS
        .iter()
        .map(|&frac| {
            LoadPoint::new(
                format!("frac{frac}"),
                frac,
                WorkloadConfig::paper(rep.lambda_cutoff * frac),
            )
        })
        .collect();
    spec.seeds = vec![1];
    spec
}

fn completion_ratio(res: &SimResult) -> f64 {
    res.completed.len() as f64 / (res.completed.len() as f64 + res.incomplete as f64)
}

pub fn run(
    out_dir: &Path,
    artifacts_dir: &str,
    scale: Scale,
    threads: usize,
) -> Result<(), String> {
    // analytic curves over omega for a few alphas
    let mut series = Vec::new();
    for alpha in [2.0f64, 3.0, 4.0] {
        let mut no_spec = Vec::new();
        let mut cloned = Vec::new();
        for i in 1..=70 {
            let omega = i as f64 * 0.01;
            no_spec.push((omega, delay_no_spec(omega, 2.5, alpha)));
            cloned.push((omega, delay_cloned(omega, 2.5, alpha)));
        }
        series.push((format!("W_t_alpha{alpha}"), no_spec));
        series.push((format!("W_t_clone_alpha{alpha}"), cloned));
    }
    report::write_file(out_dir.join("threshold_analytic.csv"), &report::xy_csv(&series))
        .map_err(|e| e.to_string())?;

    // paper set-up cutoff
    let machines = scale.machines(3000);
    let rep = cutoff_lambda(machines, MEAN_TASKS, MEAN_DURATION, TAIL_ALPHA);
    println!(
        "threshold: omega_stability={:.3} omega_cutoff={:.3} lambda^U={:.2} (M={machines})",
        rep.omega_stability, rep.omega_cutoff, rep.lambda_cutoff
    );

    // empirical sweep around the cutoff with clone-all vs naive
    let mut spec = spec(scale);
    spec.base.artifacts_dir = artifacts_dir.to_string();
    spec.threads = threads;
    let rep_small = cutoff_lambda(spec.base.machines, MEAN_TASKS, MEAN_DURATION, TAIL_ALPHA);
    println!(
        "  empirical sweep (M={}, lambda^U={:.2}):",
        spec.base.machines, rep_small.lambda_cutoff
    );
    let sweep = Runner::run(&spec)?;
    let mut out = vec![
        ("clone_mean_flowtime".to_string(), Vec::new()),
        ("naive_mean_flowtime".to_string(), Vec::new()),
        ("clone_completion_ratio".to_string(), Vec::new()),
        ("naive_completion_ratio".to_string(), Vec::new()),
    ];
    for (li, (_, frac)) in sweep.loads.iter().enumerate() {
        let clone_res = sweep.merged(0, li);
        let naive_res = sweep.merged(1, li);
        let clone = SummaryRow::from_result(&clone_res).mean_flowtime;
        let naive = SummaryRow::from_result(&naive_res).mean_flowtime;
        let (clone_ratio, naive_ratio) =
            (completion_ratio(&clone_res), completion_ratio(&naive_res));
        out[0].1.push((*frac, clone));
        out[1].1.push((*frac, naive));
        out[2].1.push((*frac, clone_ratio));
        out[3].1.push((*frac, naive_ratio));
        println!(
            "    lambda/lambda^U={frac:.1}: clone ft={clone:.2} done={:.0}% | naive ft={naive:.2} done={:.0}% -> {}",
            clone_ratio * 100.0,
            naive_ratio * 100.0,
            if clone_ratio >= naive_ratio * 0.98 && clone < naive {
                "cloning wins"
            } else {
                "cloning loses"
            }
        );
    }
    report::write_file(out_dir.join("threshold_empirical.csv"), &report::xy_csv(&out))
        .map_err(|e| e.to_string())?;
    Ok(())
}
