//! The Sec. III-B cutoff experiment (no figure in the paper, but the
//! threshold is central to its story): compute lambda^U analytically
//! (Eq. 1-5) and validate it empirically by sweeping lambda across the
//! cutoff with the 2-copy cloning scheduler vs the naive baseline — below
//! the cutoff cloning wins on mean task delay, above it loses/destabilizes.

use std::path::Path;

use crate::analysis::threshold::{cutoff_lambda, delay_cloned, delay_no_spec};
use crate::config::{SimConfig, WorkloadConfig};
use crate::metrics::report::{self, SummaryRow};
use crate::scheduler::SchedulerKind;

use super::fig2::run_seeds;
use super::Scale;

pub fn run(out_dir: &Path, artifacts_dir: &str, scale: Scale) -> Result<(), String> {
    // analytic curves over omega for a few alphas
    let mut series = Vec::new();
    for alpha in [2.0f64, 3.0, 4.0] {
        let mut no_spec = Vec::new();
        let mut cloned = Vec::new();
        for i in 1..=70 {
            let omega = i as f64 * 0.01;
            no_spec.push((omega, delay_no_spec(omega, 2.5, alpha)));
            cloned.push((omega, delay_cloned(omega, 2.5, alpha)));
        }
        series.push((format!("W_t_alpha{alpha}"), no_spec));
        series.push((format!("W_t_clone_alpha{alpha}"), cloned));
    }
    report::write_file(out_dir.join("threshold_analytic.csv"), &report::xy_csv(&series))
        .map_err(|e| e.to_string())?;

    // paper set-up cutoff
    let machines = scale.machines(3000);
    let rep = cutoff_lambda(machines, 50.5, 2.5, 2.0);
    println!(
        "threshold: omega_stability={:.3} omega_cutoff={:.3} lambda^U={:.2} (M={machines})",
        rep.omega_stability, rep.omega_cutoff, rep.lambda_cutoff
    );

    // empirical sweep around the cutoff with clone-all vs naive
    let mut cfg = SimConfig::default();
    cfg.machines = scale.machines(600);
    cfg.horizon = scale.horizon(600.0);
    cfg.artifacts_dir = artifacts_dir.to_string();
    let rep_small = cutoff_lambda(cfg.machines, 50.5, 2.5, 2.0);
    let mut sweep = vec![
        ("clone_mean_flowtime".to_string(), Vec::new()),
        ("naive_mean_flowtime".to_string(), Vec::new()),
        ("clone_completion_ratio".to_string(), Vec::new()),
        ("naive_completion_ratio".to_string(), Vec::new()),
    ];
    println!("  empirical sweep (M={}, lambda^U={:.2}):", cfg.machines, rep_small.lambda_cutoff);
    // strict cloning: the literal Sec. III scheme, so exceeding the
    // Theorem-1 bound actually destabilizes instead of degrading gracefully.
    // Past the bound the queue grows without bound; the completed-jobs CMF
    // is censored, so the instability shows up as a collapsing completion
    // ratio rather than an exploding mean.
    cfg.clone_strict = true;
    for frac in [0.3, 0.6, 0.9, 1.1, 1.3] {
        let lambda = rep_small.lambda_cutoff * frac;
        let wl = WorkloadConfig::paper(lambda);
        let ratio = |res: &crate::cluster::sim::SimResult| {
            res.completed.len() as f64 / (res.completed.len() as f64 + res.incomplete as f64)
        };
        cfg.scheduler = SchedulerKind::CloneAll;
        let res = run_seeds(&cfg, &wl, &[1]);
        let (clone, clone_ratio) = (SummaryRow::from_result(&res).mean_flowtime, ratio(&res));
        cfg.scheduler = SchedulerKind::Naive;
        let res = run_seeds(&cfg, &wl, &[1]);
        let (naive, naive_ratio) = (SummaryRow::from_result(&res).mean_flowtime, ratio(&res));
        sweep[0].1.push((frac, clone));
        sweep[1].1.push((frac, naive));
        sweep[2].1.push((frac, clone_ratio));
        sweep[3].1.push((frac, naive_ratio));
        println!(
            "    lambda/lambda^U={frac:.1}: clone ft={clone:.2} done={:.0}% | naive ft={naive:.2} done={:.0}% -> {}",
            clone_ratio * 100.0,
            naive_ratio * 100.0,
            if clone_ratio >= naive_ratio * 0.98 && clone < naive {
                "cloning wins"
            } else {
                "cloning loses"
            }
        );
    }
    report::write_file(out_dir.join("threshold_empirical.csv"), &report::xy_csv(&sweep))
        .map_err(|e| e.to_string())?;
    Ok(())
}
