//! Fig. 3: SDA sensitivity to the detection threshold sigma_i — the
//! theoretical optimum 1 + sqrt(2)/2 ~ 1.707 (alpha = 2) should minimize
//! both flowtime and resource; smaller sigma over-clones, larger sigma
//! speculates too late.

use std::path::Path;

use crate::metrics::report::{self, SummaryRow};
use crate::scheduler::SchedulerKind;

use super::fig2::{config, run_seeds};
use super::Scale;

pub const SIGMAS: [f64; 5] = [1.2, 1.707, 2.2, 3.0, 4.0];

pub fn run(out_dir: &Path, artifacts_dir: &str, scale: Scale) -> Result<(), String> {
    let (mut cfg, wl) = config(scale);
    cfg.artifacts_dir = artifacts_dir.to_string();
    cfg.scheduler = SchedulerKind::Sda;
    let seeds = [1u64, 2];
    let mut rows = Vec::new();
    let mut series = vec![
        ("mean_flowtime".to_string(), Vec::new()),
        ("mean_resource".to_string(), Vec::new()),
    ];
    for sigma in SIGMAS {
        cfg.sigma = Some(sigma);
        let res = run_seeds(&cfg, &wl, &seeds);
        let row = SummaryRow::from_result(&res);
        series[0].1.push((sigma, row.mean_flowtime));
        series[1].1.push((sigma, row.mean_resource));
        rows.push(row);
    }
    report::write_file(out_dir.join("fig3_sda_sigma.csv"), &report::xy_csv(&series))
        .map_err(|e| e.to_string())?;
    println!("fig3 (SDA sigma sweep, paper optimum ~1.707):");
    for (sigma, row) in SIGMAS.iter().zip(&rows) {
        println!(
            "  sigma={sigma:<6} mean_flowtime={:.3} mean_resource={:.4}",
            row.mean_flowtime, row.mean_resource
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_grid_includes_theorem3_optimum() {
        assert!(SIGMAS.iter().any(|s| (s - 1.707).abs() < 1e-9));
    }
}
