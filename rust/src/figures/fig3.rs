//! Fig. 3: SDA sensitivity to the detection threshold sigma_i — the
//! theoretical optimum 1 + sqrt(2)/2 ~ 1.707 (alpha = 2) should minimize
//! both flowtime and resource; smaller sigma over-clones, larger sigma
//! speculates too late.

use std::path::Path;

use crate::config::WorkloadConfig;
use crate::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner};
use crate::metrics::report::{self, SummaryRow};
use crate::scheduler::SchedulerKind;

use super::fig2;
use super::Scale;

pub const SIGMAS: [f64; 5] = [1.2, 1.707, 2.2, 3.0, 4.0];

/// The sigma sweep as a policy axis: SDA at each threshold, same workload.
pub fn spec(scale: Scale) -> ExperimentSpec {
    let (cfg, wl) = fig2::config(scale);
    let lambda = match &wl {
        WorkloadConfig::Poisson { lambda, .. } => *lambda,
        _ => unreachable!(),
    };
    let mut spec = ExperimentSpec::new("fig3", cfg);
    spec.policies = SIGMAS
        .iter()
        .map(|&s| PolicyVariant::with_sigma(SchedulerKind::Sda, s))
        .collect();
    spec.loads = vec![LoadPoint::new("paper", lambda, wl)];
    spec.seeds = vec![1, 2];
    spec
}

pub fn run(
    out_dir: &Path,
    artifacts_dir: &str,
    scale: Scale,
    threads: usize,
) -> Result<(), String> {
    let mut spec = spec(scale);
    spec.base.artifacts_dir = artifacts_dir.to_string();
    spec.threads = threads;
    let sweep = Runner::run(&spec)?;
    let series = vec![
        ("mean_flowtime".to_string(), sweep.series_over_policies(0, |r| r.mean_flowtime())),
        ("mean_resource".to_string(), sweep.series_over_policies(0, |r| r.mean_resource())),
    ];
    report::write_file(out_dir.join("fig3_sda_sigma.csv"), &report::xy_csv(&series))
        .map_err(|e| e.to_string())?;
    println!("fig3 (SDA sigma sweep, paper optimum ~1.707):");
    for (pi, &sigma) in SIGMAS.iter().enumerate() {
        let row = SummaryRow::from_result(&sweep.merged(pi, 0));
        println!(
            "  sigma={sigma:<6} mean_flowtime={:.3} mean_resource={:.4}",
            row.mean_flowtime, row.mean_resource
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_grid_includes_theorem3_optimum() {
        assert!(SIGMAS.iter().any(|s| (s - 1.707).abs() < 1e-9));
    }

    #[test]
    fn spec_sweeps_sigma_on_the_policy_axis() {
        let s = spec(Scale(0.05));
        assert_eq!(s.policies.len(), SIGMAS.len());
        assert_eq!(s.policies[1].x, 1.707);
        assert_eq!(s.cell_count(), SIGMAS.len() * 2);
    }
}
