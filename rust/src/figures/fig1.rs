//! Fig. 1: convergence of the gradient-projection algorithm on the paper's
//! 4-job instance (m = 10/20/5/10, mu = 1/2/1/2, N = 100, r = 8).
//!
//! Regenerates the Cesaro-averaged clone-count iterates c_li(k) from both
//! the pure-rust solver and (when artifacts are present) the AOT-compiled
//! JAX `p2_trace` module, so the two implementations can be diffed.

use std::path::Path;

use crate::experiment::run_parallel;
use crate::metrics::report;
use crate::opt::gradient::{GradientSolver, P2Job, P2Problem};
use crate::runtime::{Manifest, PjrtExecutor};

use super::Scale;

pub fn paper_problem() -> P2Problem {
    P2Problem {
        jobs: vec![
            P2Job { mu: 1.0, m: 10.0, age: 0.0 },
            P2Job { mu: 2.0, m: 20.0, age: 0.0 },
            P2Job { mu: 1.0, m: 5.0, age: 0.0 },
            P2Job { mu: 2.0, m: 10.0, age: 0.0 },
        ],
        n_avail: 100.0,
        gamma: 0.01,
        r: 8.0,
        alpha: 2.0,
    }
}

/// Rust-solver trace: per-iteration averaged c for each of the 4 jobs.
pub fn rust_trace() -> Vec<Vec<f64>> {
    let mut solver = GradientSolver::default();
    let mut trace = Vec::new();
    solver.solve_traced(&paper_problem(), Some(&mut trace));
    trace
}

/// PJRT trace from the `p2_trace` artifact (iters x batch, only the first
/// 4 columns are live).
pub fn pjrt_trace(artifacts_dir: &str) -> Result<Vec<Vec<f64>>, String> {
    let manifest = Manifest::load(artifacts_dir)?;
    let entry = manifest.entry("p2_trace").ok_or("p2_trace not in manifest")?;
    let exec = PjrtExecutor::load(
        manifest.hlo_path("p2_trace")?,
        entry.inputs.iter().map(|t| t.shape.clone()).collect(),
        entry.outputs.iter().map(|t| t.shape.clone()).collect(),
    )?;
    let b = manifest.statics.batch;
    let p = paper_problem();
    let mut mu = vec![0.0f32; b];
    let mut m = vec![0.0f32; b];
    let age = vec![0.0f32; b];
    let mut mask = vec![0.0f32; b];
    for (i, j) in p.jobs.iter().enumerate() {
        mu[i] = j.mu as f32;
        m[i] = j.m as f32;
        mask[i] = 1.0;
    }
    let params = vec![p.n_avail as f32, p.gamma as f32, p.r as f32, p.alpha as f32];
    let outs = exec.run(&[mu, m, age, mask, params])?;
    let iters = manifest.statics.p2_iters;
    let mut trace = Vec::with_capacity(iters);
    for k in 0..iters {
        trace.push(
            (0..p.jobs.len())
                .map(|i| outs[0][k * b + i] as f64)
                .collect(),
        );
    }
    Ok(trace)
}

pub fn run(
    out_dir: &Path,
    artifacts_dir: &str,
    _scale: Scale,
    threads: usize,
) -> Result<(), String> {
    // both backends in parallel; each worker constructs its own solver /
    // PJRT executor in-thread (the executor is thread-pinned)
    let mut traces = run_parallel(2, threads, |i| match i {
        0 => Ok(rust_trace()),
        _ => pjrt_trace(artifacts_dir),
    });
    let pjrt = traces.pop().unwrap();
    let rust = traces.pop().unwrap().expect("rust trace is infallible");
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for j in 0..4 {
        series.push((
            format!("rust_c_l{}", j + 1),
            rust.iter()
                .enumerate()
                .map(|(k, c)| (k as f64, c[j]))
                .collect(),
        ));
    }
    match pjrt {
        Ok(pjrt) => {
            for j in 0..4 {
                series.push((
                    format!("pjrt_c_l{}", j + 1),
                    pjrt.iter()
                        .enumerate()
                        .map(|(k, c)| (k as f64, c[j]))
                        .collect(),
                ));
            }
        }
        Err(e) => eprintln!("fig1: pjrt trace unavailable ({e}); rust trace only"),
    }
    report::write_file(out_dir.join("fig1_convergence.csv"), &report::xy_csv(&series))
        .map_err(|e| e.to_string())?;
    let last = rust.last().unwrap();
    println!(
        "fig1: converged c = [{:.3}, {:.3}, {:.3}, {:.3}] (paper converges by ~iter 40)",
        last[0], last[1], last[2], last[3]
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_converges() {
        let tr = rust_trace();
        assert_eq!(tr[0].len(), 4);
        let (a, b) = (&tr[tr.len() - 1], &tr[tr.len() - 40]);
        for j in 0..4 {
            assert!((a[j] - b[j]).abs() < 0.05, "job {j} not settled");
        }
    }

    #[test]
    fn capacity_respected_at_convergence() {
        let tr = rust_trace();
        let last = tr.last().unwrap();
        let m = [10.0, 20.0, 5.0, 10.0];
        let used: f64 = last.iter().zip(m).map(|(c, m)| c * m).sum();
        assert!(used <= 105.0, "used {used}");
    }
}
