//! Estimator crossover under the ON/OFF Markov slowdown: mean flowtime of
//! the blind / advertised / observed estimator variants (all driving the
//! same SDA detection rule) as the flip rate grows.
//!
//! The three variants tease the scenario apart along both axes:
//!
//! * **blind** (`--no-speed-aware`) conflates class speed with
//!   straggling — the heterogeneous cluster separates it from the
//!   speed-aware pair at every flip rate, including zero;
//! * **advertised** (the default speed-aware estimator) trusts the
//!   revealed remaining wall, which a flip silently re-times — sound in
//!   the static regime, increasingly stale as hosts churn;
//! * **observed** (`--observed-speed`) projects the revealed wall by the
//!   host's measured lifetime throughput, distrusting hosts with a
//!   degraded track record (DESIGN.md §14).
//!
//! The zero-rate column doubles as the static anchor: observed and
//! advertised coincide there on healthy hosts, so any gap between the
//! curves is purchased entirely by the flip process.

use std::path::Path;

use crate::cluster::machine::{MachineClass, SlowdownConfig};
use crate::config::SimConfig;
use crate::experiment::{ClusterScenario, ExperimentSpec, LoadPoint, PolicyVariant, Runner};
use crate::metrics::report;
use crate::scheduler::SchedulerKind;

use super::Scale;

/// The swept ON rates (healthy -> degraded); the OFF rate is twice the ON
/// rate so the stationary degraded fraction stays at 1/3 while the churn
/// frequency grows — the axis isolates non-stationarity, not degradation
/// volume.
pub const FLIP_RATES: [f64; 4] = [0.0, 0.1, 0.4, 1.6];

/// Multiplier from ON rate to OFF rate (see [`FLIP_RATES`]).
pub const OFF_RATE_FACTOR: f64 = 2.0;

/// One flip-rate column of the sweep: the three estimator variants on the
/// identical heterogeneous, flip-degraded cluster and workload.
pub fn spec(scale: Scale, rate_on: f64) -> ExperimentSpec {
    let mut cfg = SimConfig::default();
    let m = scale.machines(300);
    cfg.horizon = scale.horizon(400.0);
    cfg.use_runtime = false;
    let mut spec = ExperimentSpec::new(format!("crossover@{rate_on}"), cfg);
    // two public speed classes separate blind from advertised; the hidden
    // ON/OFF process (3x degradation) separates advertised from observed
    spec.scenario = ClusterScenario::heterogeneous(vec![
        MachineClass::new(m - m / 3, 1.0),
        MachineClass::new(m / 3, 0.5),
    ])
    .with_slowdown(
        SlowdownConfig::new(1.0 / 3.0, 3.0).with_rates(rate_on, OFF_RATE_FACTOR * rate_on),
    );
    spec.policies = vec![
        PolicyVariant::patched("blind", SchedulerKind::Sda, |c| c.speed_aware = false),
        PolicyVariant::patched("advertised", SchedulerKind::Sda, |_| {}),
        PolicyVariant::patched("observed", SchedulerKind::Sda, |c| c.observed_speed = true),
    ];
    let lambda = 0.5 * m as f64 / 300.0;
    spec.loads = vec![LoadPoint::lambda(lambda)];
    spec.seeds = vec![1, 2, 3];
    spec
}

pub fn run(
    out_dir: &Path,
    artifacts_dir: &str,
    scale: Scale,
    threads: usize,
) -> Result<(), String> {
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for rate in FLIP_RATES {
        let mut spec = spec(scale, rate);
        spec.base.artifacts_dir = artifacts_dir.to_string();
        spec.threads = threads;
        let sweep = Runner::run(&spec)?;
        if series.is_empty() {
            series = sweep
                .policies
                .iter()
                .map(|(label, _)| (label.clone(), Vec::new()))
                .collect();
        }
        print!("crossover (rate_on={rate}):");
        for (pi, (label, _)) in sweep.policies.iter().enumerate() {
            let flow = sweep.merged(pi, 0).mean_flowtime();
            series[pi].1.push((rate, flow));
            print!("  {label} {flow:.3}");
        }
        println!();
    }
    // acceptance telemetry at the churn end of the axis: the observed
    // estimator should beat both rivals once hosts flip faster than the
    // advertised picture can stay true
    let at_max = |pi: usize| series[pi].1.last().map_or(f64::NAN, |&(_, y)| y);
    let (blind, advertised, observed) = (at_max(0), at_max(1), at_max(2));
    println!(
        "crossover at rate_on={}: observed {} (vs advertised {}, blind {}) — observed {}",
        FLIP_RATES[FLIP_RATES.len() - 1],
        observed,
        advertised,
        blind,
        if observed < advertised && observed < blind { "strictly best" } else { "NOT best" },
    );
    report::write_file(
        out_dir.join("crossover_flowtime_vs_fliprate.csv"),
        &report::xy_csv(&series),
    )
    .map_err(|e| e.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_builds_all_flip_columns() {
        for rate in FLIP_RATES {
            let spec = spec(Scale(0.1), rate);
            spec.validate().unwrap();
            assert_eq!(spec.policies.len(), 3);
            let sd = spec.scenario.slowdown.unwrap();
            assert_eq!(sd.rate_on, rate);
            assert_eq!(sd.rate_off, OFF_RATE_FACTOR * rate);
            assert_eq!(sd.flips_enabled(), rate > 0.0);
            // the variants differ only in the estimator configuration
            let cfgs: Vec<SimConfig> = spec
                .policies
                .iter()
                .map(|p| {
                    let mut c = spec.base.clone();
                    spec.scenario.apply(&mut c);
                    if let Some(patch) = &p.patch {
                        patch(&mut c);
                    }
                    c.validate().unwrap();
                    c
                })
                .collect();
            assert!(!cfgs[0].speed_aware);
            assert!(cfgs[1].speed_aware && !cfgs[1].observed_speed);
            assert!(cfgs[2].speed_aware && cfgs[2].observed_speed);
            assert_eq!(cfgs[0].machines, cfgs[1].machines);
            assert!(cfgs[0].machines >= 20);
        }
    }
}
