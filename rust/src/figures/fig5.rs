//! Fig. 5: single-job experiment — one 10000-task job on 100 machines,
//! `E[x] = 1`, ESE vs the no-backup naive baseline, sweeping sigma.  The
//! empirical optimum should match the Fig. 4 analysis (~1.7 at alpha = 2)
//! and the ESE advantage should fade as alpha grows.
//!
//! Grid: policy axis = naive + ESE@sigma (12 thresholds), load axis =
//! tail index alpha in {2, 3, 4}, seed axis = up to 50 replications — the
//! largest sweep in the figure set and the acceptance benchmark for the
//! parallel runner.

use std::path::Path;

use crate::config::{SimConfig, WorkloadConfig};
use crate::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner, SweepResult};
use crate::metrics::report;
use crate::scheduler::SchedulerKind;

use super::Scale;

pub fn config(scale: Scale) -> (SimConfig, WorkloadConfig) {
    let mut cfg = SimConfig::default();
    cfg.machines = 100;
    cfg.horizon = 1.0e4; // run the single job to completion
    cfg.slot_dt = 1.0;
    let tasks = (10_000.0 * scale.0).max(200.0) as u32;
    (cfg, WorkloadConfig::SingleJob { tasks, mean: 1.0, alpha: 2.0 })
}

pub fn sigmas() -> Vec<f64> {
    (1..=12).map(|i| i as f64 * 0.5).collect()
}

/// The full Fig. 5 grid as one declaration.
pub fn spec(scale: Scale) -> ExperimentSpec {
    let (cfg, wl) = config(scale);
    let tasks = match wl {
        WorkloadConfig::SingleJob { tasks, .. } => tasks,
        _ => unreachable!(),
    };
    let mut spec = ExperimentSpec::new("fig5", cfg);
    spec.policies = std::iter::once(PolicyVariant::kind(SchedulerKind::Naive))
        .chain(sigmas().into_iter().map(|s| PolicyVariant::with_sigma(SchedulerKind::Ese, s)))
        .collect();
    spec.loads = [2.0f64, 3.0, 4.0]
        .into_iter()
        .map(|alpha| {
            LoadPoint::new(
                format!("alpha{alpha}"),
                alpha,
                WorkloadConfig::SingleJob { tasks, mean: 1.0, alpha },
            )
        })
        .collect();
    // paper: 50 runs per point; scale that down with the workload
    let seeds = ((50.0 * scale.0) as u64).clamp(3, 50);
    spec.seeds = (1..=seeds).collect();
    spec
}

/// (total resource, job flowtime) for one (policy, load) pair, averaged
/// over the seed axis.  The single job may be censored by the horizon, so
/// flowtime falls back to the horizon like the paper's runs do.
fn measure(sweep: &SweepResult, pi: usize, li: usize) -> (f64, f64) {
    let cells = sweep.cells_for(pi, li);
    let gamma = sweep.base.gamma;
    let horizon = sweep.base.horizon;
    let (mut res_acc, mut flow_acc) = (0.0, 0.0);
    for c in cells {
        res_acc += c.result.total_machine_time * gamma;
        flow_acc += c.result.completed.first().map(|j| j.flowtime).unwrap_or(horizon);
    }
    (res_acc / cells.len() as f64, flow_acc / cells.len() as f64)
}

pub fn run(
    out_dir: &Path,
    _artifacts_dir: &str,
    scale: Scale,
    threads: usize,
) -> Result<(), String> {
    let mut spec = spec(scale);
    spec.threads = threads;
    let sweep = Runner::run(&spec)?;
    let sigma_grid = sigmas();
    let mut series = Vec::new();
    println!(
        "fig5 (single job, M = {}, {} runs/point, {} grid cells):",
        sweep.base.machines,
        sweep.seeds.len(),
        sweep.cells.len()
    );
    for (li, (_, alpha)) in sweep.loads.iter().enumerate() {
        let (naive_res, naive_flow) = measure(&sweep, 0, li);
        let mut res_pts = Vec::new();
        let mut flow_pts = Vec::new();
        let (mut best_sigma, mut best_res) = (0.0, f64::INFINITY);
        for (k, &sigma) in sigma_grid.iter().enumerate() {
            let (r, f) = measure(&sweep, k + 1, li);
            res_pts.push((sigma, r));
            flow_pts.push((sigma, f));
            if r < best_res {
                best_res = r;
                best_sigma = sigma;
            }
        }
        println!(
            "  alpha={alpha}: empirical sigma* = {best_sigma:.2} (analysis: ~1.7-2.0), \
             ESE res {best_res:.2} vs naive {naive_res:.2}, naive flow {naive_flow:.2}"
        );
        series.push((format!("ese_resource_alpha{alpha}"), res_pts));
        series.push((format!("ese_flowtime_alpha{alpha}"), flow_pts));
        series.push((
            format!("naive_resource_alpha{alpha}"),
            sigma_grid.iter().map(|&s| (s, naive_res)).collect(),
        ));
        series.push((
            format!("naive_flowtime_alpha{alpha}"),
            sigma_grid.iter().map(|&s| (s, naive_flow)).collect(),
        ));
    }
    report::write_file(out_dir.join("fig5_single_job.csv"), &report::xy_csv(&series))
        .map_err(|e| e.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_covers_the_paper_grid() {
        let s = spec(Scale(0.02));
        assert_eq!(s.policies.len(), 13); // naive + 12 sigmas
        assert_eq!(s.loads.len(), 3);
        assert_eq!(s.seeds.len(), 3);
        assert_eq!(s.cell_count(), 13 * 3 * 3);
        // the policy axis carries the sigma coordinate for the CSV series
        assert_eq!(s.policies[1].x, 0.5);
        assert_eq!(s.policies[12].x, 6.0);
    }
}
