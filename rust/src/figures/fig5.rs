//! Fig. 5: single-job experiment — one 10000-task job on 100 machines,
//! E[x] = 1, ESE vs the no-backup naive baseline, sweeping sigma.  The
//! empirical optimum should match the Fig. 4 analysis (~1.7 at alpha = 2)
//! and the ESE advantage should fade as alpha grows.

use std::path::Path;

use crate::cluster::generator::generate;
use crate::cluster::sim::Simulator;
use crate::config::{SimConfig, WorkloadConfig};
use crate::metrics::report;
use crate::scheduler::{self, SchedulerKind};

use super::Scale;

pub fn config(scale: Scale) -> (SimConfig, WorkloadConfig) {
    let mut cfg = SimConfig::default();
    cfg.machines = 100;
    cfg.horizon = 1.0e4; // run the single job to completion
    cfg.slot_dt = 1.0;
    let tasks = (10_000.0 * scale.0).max(200.0) as u32;
    (cfg, WorkloadConfig::SingleJob { tasks, mean: 1.0, alpha: 2.0 })
}

/// (total resource, job flowtime) averaged over `seeds` runs.
fn measure(
    cfg: &SimConfig,
    wl: &WorkloadConfig,
    kind: SchedulerKind,
    sigma: Option<f64>,
    seeds: u64,
) -> (f64, f64) {
    let (mut res_acc, mut flow_acc) = (0.0, 0.0);
    for seed in 0..seeds {
        let mut c = cfg.clone();
        c.scheduler = kind;
        c.sigma = sigma;
        c.seed = seed + 1;
        let workload = generate(wl, c.horizon, c.seed);
        let sched = scheduler::build(&c, wl).expect("build");
        let r = Simulator::new(c, workload, sched).run();
        // single job: total resource + its flowtime
        res_acc += r.total_machine_time * cfg.gamma;
        flow_acc += r
            .completed
            .first()
            .map(|j| j.flowtime)
            .unwrap_or(cfg.horizon);
    }
    (res_acc / seeds as f64, flow_acc / seeds as f64)
}

pub fn run(out_dir: &Path, _artifacts_dir: &str, scale: Scale) -> Result<(), String> {
    let (cfg, wl) = config(scale);
    // paper: 50 runs per point; scale that down with the workload
    let seeds = ((50.0 * scale.0) as u64).clamp(3, 50);
    let sigmas: Vec<f64> = (1..=12).map(|i| i as f64 * 0.5).collect();
    let mut series = Vec::new();
    println!("fig5 (single job, {} tasks, M = {}, {seeds} runs/point):", match wl {
        WorkloadConfig::SingleJob { tasks, .. } => tasks,
        _ => unreachable!(),
    }, cfg.machines);
    for alpha in [2.0f64, 3.0, 4.0] {
        let wl_a = match wl {
            WorkloadConfig::SingleJob { tasks, mean, .. } => {
                WorkloadConfig::SingleJob { tasks, mean, alpha }
            }
            _ => unreachable!(),
        };
        let (naive_res, naive_flow) = measure(&cfg, &wl_a, SchedulerKind::Naive, None, seeds);
        let mut res_pts = Vec::new();
        let mut flow_pts = Vec::new();
        let (mut best_sigma, mut best_res) = (0.0, f64::INFINITY);
        for &sigma in &sigmas {
            let (r, f) = measure(&cfg, &wl_a, SchedulerKind::Ese, Some(sigma), seeds);
            res_pts.push((sigma, r));
            flow_pts.push((sigma, f));
            if r < best_res {
                best_res = r;
                best_sigma = sigma;
            }
        }
        println!(
            "  alpha={alpha}: empirical sigma* = {best_sigma:.2} (analysis: ~1.7-2.0), \
             ESE res {best_res:.2} vs naive {naive_res:.2}, naive flow {naive_flow:.2}"
        );
        series.push((format!("ese_resource_alpha{alpha}"), res_pts));
        series.push((format!("ese_flowtime_alpha{alpha}"), flow_pts));
        series.push((
            format!("naive_resource_alpha{alpha}"),
            sigmas.iter().map(|&s| (s, naive_res)).collect(),
        ));
        series.push((
            format!("naive_flowtime_alpha{alpha}"),
            sigmas.iter().map(|&s| (s, naive_flow)).collect(),
        ));
    }
    report::write_file(out_dir.join("fig5_single_job.csv"), &report::xy_csv(&series))
        .map_err(|e| e.to_string())?;
    Ok(())
}
