//! Flowtime inflation under machine churn: mean flowtime of the seven
//! canonical policies as the machine MTTF shrinks (failures become more
//! frequent) at a fixed MTTR — the headline sweep for the crash/recovery
//! fault model (DESIGN.md §17).
//!
//! The infinite-MTTF column is the no-churn anchor (`churn` unset, so it
//! runs the bit-identical zero-churn path); every finite column loses the
//! work of each crashed copy and pays the restart-from-zero relaunch, so
//! the gap to the anchor is exactly the price of churn under each
//! speculation policy.  Speculative policies hold backup copies of
//! straggling tasks, which doubles as crash insurance — the sweep shows
//! how much of that insurance each policy buys.

use std::path::Path;

use crate::cluster::machine::ChurnConfig;
use crate::config::SimConfig;
use crate::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner};
use crate::metrics::report;
use crate::scheduler::SchedulerKind;

use super::Scale;

/// The MTTF axis (mean machine up-time, seconds).  `INFINITY` is the
/// no-churn anchor; finite values sweep from rare to frequent failure.
pub const MTTFS: [f64; 4] = [f64::INFINITY, 400.0, 150.0, 60.0];

/// Mean repair time, fixed across the axis so it isolates failure
/// frequency, not repair capacity.
pub const MTTR: f64 = 20.0;

/// One MTTF column: the seven canonical policies on the identical cluster,
/// workload, and (when finite) churn schedule.
pub fn spec(scale: Scale, mttf: f64) -> ExperimentSpec {
    let mut cfg = SimConfig::default();
    let m = scale.machines(200);
    cfg.machines = m;
    cfg.horizon = scale.horizon(300.0);
    cfg.use_runtime = false;
    if mttf.is_finite() {
        cfg.churn = Some(ChurnConfig::new(mttf, MTTR));
    }
    let mut spec = ExperimentSpec::new(format!("churn@{mttf}"), cfg);
    spec.policies = SchedulerKind::all().iter().map(|&k| PolicyVariant::kind(k)).collect();
    spec.loads = vec![LoadPoint::lambda(0.4 * m as f64 / 300.0)];
    spec.seeds = vec![1, 2, 3];
    spec
}

pub fn run(
    out_dir: &Path,
    artifacts_dir: &str,
    scale: Scale,
    threads: usize,
) -> Result<(), String> {
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut lost = Vec::new();
    for mttf in MTTFS {
        let mut spec = spec(scale, mttf);
        spec.base.artifacts_dir = artifacts_dir.to_string();
        spec.threads = threads;
        let sweep = Runner::run(&spec)?;
        if series.is_empty() {
            series = sweep
                .policies
                .iter()
                .map(|(label, _)| (label.clone(), Vec::new()))
                .collect();
        }
        print!("churn (mttf={mttf}):");
        let mut col_lost = 0u64;
        for (pi, (label, _)) in sweep.policies.iter().enumerate() {
            let merged = sweep.merged(pi, 0);
            series[pi].1.push((mttf, merged.mean_flowtime()));
            col_lost += merged.copies_lost;
            print!("  {label} {:.3}", merged.mean_flowtime());
        }
        println!();
        lost.push((mttf, col_lost));
    }
    // acceptance telemetry: the anchor must lose nothing, and the most
    // churned column must actually have killed copies for the inflation to
    // mean anything
    let anchor = lost.first().map_or(0, |&(_, n)| n);
    let worst = lost.last().map_or(0, |&(_, n)| n);
    println!(
        "churn sweep: copies lost at mttf=inf {anchor} (must be 0), \
         at mttf={} {worst} — churn {}",
        MTTFS[MTTFS.len() - 1],
        if anchor == 0 && worst > 0 { "active" } else { "NOT active" },
    );
    report::write_file(
        out_dir.join("churn_flowtime_vs_mttf.csv"),
        &report::xy_csv(&series),
    )
    .map_err(|e| e.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_builds_all_mttf_columns() {
        for mttf in MTTFS {
            let spec = spec(Scale(0.1), mttf);
            spec.validate().unwrap();
            assert_eq!(spec.policies.len(), 7, "the seven canonical policies");
            match spec.base.churn {
                None => assert!(mttf.is_infinite(), "anchor column runs the no-churn path"),
                Some(ch) => {
                    assert_eq!(ch.mttf, mttf);
                    assert_eq!(ch.mttr, MTTR);
                    assert!(ch.enabled());
                }
            }
        }
    }
}
