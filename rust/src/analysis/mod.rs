//! Queueing analysis behind Sec. III: the M/G/1 task-delay model and the
//! lightly/heavily loaded cutoff threshold lambda^U.

pub mod mg1;
pub mod threshold;

pub use threshold::{cutoff_lambda, cutoff_omega, CutoffReport};
