//! The cutoff workload threshold (Sec. III-B): the arrival rate lambda^U
//! below which cloning-based speculation beats no-speculation, separating
//! the lightly loaded (SCA/SDA) and heavily loaded (ESE) regimes.
//!
//! Per-machine model: tasks arrive at rate `lambda_m = lambda E[m]/M`.
//! Without speculation each machine is M/G/1 with Pareto(mu, alpha) service
//! (Eq. 1).  With 2-copy cloning, arrivals double and service becomes the
//! min of two copies, Pareto(mu, 2 alpha) — Eq. (3) in the paper, which the
//! test below re-derives from raw Pollaczek-Khinchine.
//!
//! `omega = lambda E[m] E[s] / M` is the offered utilization; the threshold
//! is the largest omega with W_t^c(omega) < W_t(omega), intersected with
//! the Theorem-1 stability bound omega < (2 alpha - 1)/(4 (alpha - 1)).

use super::mg1;

/// Everything the threshold computation derives, for reporting.
#[derive(Clone, Copy, Debug)]
pub struct CutoffReport {
    /// Theorem 1 stability bound on omega for 2-copy cloning.
    pub omega_stability: f64,
    /// Largest omega where cloning strictly reduces mean task delay.
    pub omega_cutoff: f64,
    /// lambda^U for the given cluster (Eq. 5).
    pub lambda_cutoff: f64,
}

/// Mean task delay without speculation at offered utilization omega
/// (infinite for alpha <= 2: Pareto second moment diverges, so cloning
/// wins at any stable load).
pub fn delay_no_spec(omega: f64, es: f64, alpha: f64) -> f64 {
    let mu = es * (alpha - 1.0) / alpha;
    let es2 = if alpha <= 2.0 {
        f64::INFINITY
    } else {
        mu * mu * alpha / (alpha - 2.0)
    };
    mg1::mean_delay(omega / es, es, es2)
}

/// Mean task delay with 2-copy cloning at offered utilization omega —
/// Eq. (3).  Arrival rate doubles; service is Pareto(mu, 2 alpha).
pub fn delay_cloned(omega: f64, es: f64, alpha: f64) -> f64 {
    let mu = es * (alpha - 1.0) / alpha;
    let beta = 2.0 * alpha;
    let es_c = mu * beta / (beta - 1.0);
    let es2_c = mu * mu * beta / (beta - 2.0);
    mg1::mean_delay(2.0 * omega / es, es_c, es2_c)
}

/// Theorem 1 bound: omega < (2 alpha - 1) / (4 (alpha - 1)).
pub fn omega_stability(alpha: f64) -> f64 {
    (2.0 * alpha - 1.0) / (4.0 * (alpha - 1.0))
}

/// Largest omega in (0, stability) where cloning strictly wins, found by
/// bisection on the continuous difference W_t - W_t^c.
pub fn cutoff_omega(es: f64, alpha: f64) -> f64 {
    let hi = omega_stability(alpha) - 1e-9;
    let wins = |om: f64| delay_cloned(om, es, alpha) < delay_no_spec(om, es, alpha);
    if wins(hi) {
        return hi; // cloning wins across the whole stable range
    }
    let (mut lo, mut hi) = (1e-9, hi);
    debug_assert!(wins(lo), "cloning must win at vanishing load");
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if wins(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Eq. (5): `lambda^U = omega^U * M / (E[m] E[s])`.
pub fn cutoff_lambda(machines: usize, mean_tasks: f64, es: f64, alpha: f64) -> CutoffReport {
    let omega_cutoff = cutoff_omega(es, alpha);
    CutoffReport {
        omega_stability: omega_stability(alpha),
        omega_cutoff,
        lambda_cutoff: omega_cutoff * machines as f64 / (mean_tasks * es),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_matches_paper_formula() {
        // the paper's closed form for W_t^c, cross-checked against our
        // raw Pollaczek-Khinchine composition
        let (es, alpha) = (2.5, 3.0);
        for omega in [0.1, 0.3, 0.5] {
            let a = alpha;
            let num = omega * (a - 1.0) * (1.0 - 4.0 * a * a + 4.0 * a) / (a * (2.0 * a - 1.0))
                + 2.0 * (a - 1.0);
            let den = 2.0 * a - 1.0 - 4.0 * omega * (a - 1.0);
            let paper = es * num / den;
            let ours = delay_cloned(omega, es, alpha);
            assert!((paper - ours).abs() / ours < 1e-9, "omega={omega}: {paper} vs {ours}");
        }
    }

    #[test]
    fn theorem1_bound() {
        assert!((omega_stability(2.0) - 0.75).abs() < 1e-12);
        // utilization with 2 copies at the bound equals 1
        let alpha = 2.0;
        let es = 1.0;
        let om = omega_stability(alpha);
        let mu = es * (alpha - 1.0) / alpha;
        let es_c = mu * 2.0 * alpha / (2.0 * alpha - 1.0);
        assert!((2.0 * om / es * es_c - 1.0).abs() < 1e-12);
    }

    #[test]
    fn alpha2_cloning_always_wins_when_stable() {
        // infinite variance without cloning: the cutoff is the stability bound
        let r = cutoff_lambda(3000, 50.5, 2.5, 2.0);
        assert!((r.omega_cutoff - r.omega_stability).abs() < 1e-6);
        // paper set-up: lambda^U = 0.75 * 3000 / (50.5 * 2.5) ~ 17.8:
        // lambda = 6 is lightly loaded, lambda in {30, 40} heavily loaded
        assert!((r.lambda_cutoff - 17.82).abs() < 0.1, "{}", r.lambda_cutoff);
    }

    #[test]
    fn light_tail_has_interior_cutoff() {
        // for alpha > 2 + enough load, monitoring-free cloning stops paying
        let r = cutoff_lambda(100, 10.0, 1.0, 4.0);
        assert!(r.omega_cutoff < r.omega_stability);
        assert!(r.omega_cutoff > 0.0);
        // below the cutoff cloning wins, above it loses
        let es = 1.0;
        let om = r.omega_cutoff;
        assert!(delay_cloned(om * 0.9, es, 4.0) < delay_no_spec(om * 0.9, es, 4.0));
        assert!(delay_cloned(om * 1.05, es, 4.0) > delay_no_spec(om * 1.05, es, 4.0));
    }

    #[test]
    fn delay_monotone_in_load() {
        let es = 1.0;
        let mut prev = 0.0;
        for i in 1..7 {
            let om = i as f64 * 0.1;
            let w = delay_cloned(om, es, 2.0);
            assert!(w > prev);
            prev = w;
        }
    }
}
