//! M/G/1 mean delay (Pollaczek-Khinchine), Eq. (1): the per-machine task
//! queue model each computing node is approximated by.

/// Mean time-in-system `W = lambda E[s^2] / (2 (1 - lambda E[s])) + E[s]`.
/// Returns `f64::INFINITY` when unstable (`lambda * E[s] >= 1`) or when the
/// service second moment is infinite (Pareto with alpha <= 2).
pub fn mean_delay(lambda: f64, es: f64, es2: f64) -> f64 {
    assert!(lambda >= 0.0 && es > 0.0);
    let rho = lambda * es;
    if rho >= 1.0 || !es2.is_finite() {
        return f64::INFINITY;
    }
    lambda * es2 / (2.0 * (1.0 - rho)) + es
}

/// Utilization `rho = lambda * E[s]`.
pub fn utilization(lambda: f64, es: f64) -> f64 {
    lambda * es
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{Pcg64};

    #[test]
    fn md1_closed_form() {
        // deterministic service: W = rho*Es/(2(1-rho)) + Es
        let (lambda, es) = (0.5, 1.0);
        let w = mean_delay(lambda, es, es * es);
        assert!((w - (0.25 / 0.5 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn mm1_closed_form() {
        // exponential service: E[s^2] = 2/mu^2, W = 1/(mu - lambda)
        let (lambda, mu) = (0.6, 1.0);
        let w = mean_delay(lambda, 1.0 / mu, 2.0 / (mu * mu));
        assert!((w - 1.0 / (mu - lambda)).abs() < 1e-9, "{w}");
    }

    #[test]
    fn unstable_is_infinite() {
        assert!(mean_delay(1.1, 1.0, 1.0).is_infinite());
        assert!(mean_delay(0.5, 1.0, f64::INFINITY).is_infinite());
    }

    #[test]
    fn mm1_matches_simulation() {
        // quick event simulation of an M/M/1 queue
        let (lambda, mu) = (0.5, 1.0);
        let mut rng = Pcg64::new(11, 0);
        let (mut clock, mut server_free, mut total, mut n) = (0.0, 0.0f64, 0.0, 0u64);
        for _ in 0..200_000 {
            clock += rng.exponential(lambda);
            let start = clock.max(server_free);
            let svc = rng.exponential(mu);
            server_free = start + svc;
            total += server_free - clock;
            n += 1;
        }
        let sim = total / n as f64;
        let w = mean_delay(lambda, 1.0 / mu, 2.0 / (mu * mu));
        assert!((sim - w).abs() / w < 0.05, "sim {sim} vs analytic {w}");
    }
}
