//! Generic duration distributions.  The paper's evaluation is pure Pareto,
//! but the generator and the estimator plumbing are distribution-agnostic so
//! the ablation benches can swap tails.

use super::pareto::Pareto;
use super::rng::Pcg64;

/// A positive random variable a task duration can be drawn from.
pub trait Distribution {
    fn sample(&self, rng: &mut Pcg64) -> f64;
    fn mean(&self) -> f64;
    /// Survival function P(x > t).
    fn sf(&self, t: f64) -> f64;
}

impl Distribution for Pareto {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        Pareto::sample(self, rng)
    }
    fn mean(&self) -> f64 {
        Pareto::mean(self)
    }
    fn sf(&self, t: f64) -> f64 {
        Pareto::sf(self, t)
    }
}

/// Uniform on [lo, hi].
#[derive(Clone, Copy, Debug)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(lo <= hi);
        Uniform { lo, hi }
    }
}

impl Distribution for Uniform {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        rng.uniform_f64(self.lo, self.hi)
    }
    fn mean(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }
    fn sf(&self, t: f64) -> f64 {
        if t <= self.lo {
            1.0
        } else if t >= self.hi {
            0.0
        } else {
            (self.hi - t) / (self.hi - self.lo)
        }
    }
}

/// Exponential with the given rate.
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub rate: f64,
}

impl Distribution for Exponential {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        rng.exponential(self.rate)
    }
    fn mean(&self) -> f64 {
        1.0 / self.rate
    }
    fn sf(&self, t: f64) -> f64 {
        (-self.rate * t.max(0.0)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mean_and_sf() {
        let u = Uniform::new(1.0, 4.0);
        assert_eq!(u.mean(), 2.5);
        assert_eq!(u.sf(0.0), 1.0);
        assert_eq!(u.sf(4.0), 0.0);
        assert!((u.sf(2.5) - 0.5).abs() < 1e-12);
        let mut rng = Pcg64::new(5, 0);
        for _ in 0..1000 {
            let x = u.sample(&mut rng);
            assert!((1.0..=4.0).contains(&x));
        }
    }

    #[test]
    fn exponential_sf() {
        let e = Exponential { rate: 2.0 };
        assert!((e.sf(0.5) - (-1.0f64).exp()).abs() < 1e-12);
        assert_eq!(e.mean(), 0.5);
    }

    #[test]
    fn pareto_through_trait() {
        let p: &dyn Distribution = &Pareto::new(1.0, 2.0);
        assert!((p.mean() - 2.0).abs() < 1e-12);
        assert_eq!(p.sf(0.5), 1.0);
    }
}
