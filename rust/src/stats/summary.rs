//! Streaming summaries and empirical CDFs — the accounting behind every
//! figure in the paper (all of Fig. 2/3/5/6 are CMFs of per-job metrics).

/// Streaming mean/variance/extremes (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { f64::NAN } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Empirical distribution over a recorded sample: quantiles, CDF evaluation,
/// and the fixed-grid CMF series the figure harness prints.
#[derive(Clone, Debug, Default)]
pub struct Cdf {
    values: Vec<f64>,
    sorted: bool,
}

impl Cdf {
    pub fn new() -> Self {
        Cdf { values: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.values.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        self.values.extend(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// q in [0, 1]; linear interpolation between order statistics.
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        self.ensure_sorted();
        if self.values.is_empty() {
            return f64::NAN;
        }
        let pos = q * (self.values.len() - 1) as f64;
        let i = pos.floor() as usize;
        let frac = pos - i as f64;
        if i + 1 < self.values.len() {
            self.values[i] * (1.0 - frac) + self.values[i + 1] * frac
        } else {
            self.values[i]
        }
    }

    /// P(X <= t).
    pub fn fraction_leq(&mut self, t: f64) -> f64 {
        self.ensure_sorted();
        if self.values.is_empty() {
            return f64::NAN;
        }
        let k = self.values.partition_point(|&v| v <= t);
        k as f64 / self.values.len() as f64
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// (x, F(x)) series on an `n`-point grid over [0, max] — the CMF the
    /// paper plots.
    pub fn cmf_series(&mut self, n: usize) -> Vec<(f64, f64)> {
        self.ensure_sorted();
        if self.values.is_empty() {
            return Vec::new();
        }
        let hi = *self.values.last().unwrap();
        (0..=n)
            .map(|i| {
                // note: hi * (i/n) so the last grid point is exactly hi
                let x = hi * (i as f64 / n as f64);
                (x, self.fraction_leq_sorted(x))
            })
            .collect()
    }

    fn fraction_leq_sorted(&self, t: f64) -> f64 {
        let k = self.values.partition_point(|&v| v <= t);
        k as f64 / self.values.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
    }

    #[test]
    fn cdf_quantiles() {
        let mut c = Cdf::new();
        c.extend((1..=100).map(|i| i as f64));
        assert!((c.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((c.quantile(1.0) - 100.0).abs() < 1e-12);
        assert!((c.quantile(0.5) - 50.5).abs() < 1e-9);
        assert!((c.fraction_leq(80.0) - 0.8).abs() < 1e-12);
        assert!((c.mean() - 50.5).abs() < 1e-12);
    }

    #[test]
    fn cdf_unsorted_input() {
        let mut c = Cdf::new();
        c.extend([5.0, 1.0, 3.0, 2.0, 4.0]);
        assert!((c.quantile(0.5) - 3.0).abs() < 1e-12);
        assert!((c.fraction_leq(2.5) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn cmf_series_monotone() {
        let mut c = Cdf::new();
        c.extend((0..1000).map(|i| (i as f64).sqrt()));
        let series = c.cmf_series(50);
        assert_eq!(series.len(), 51);
        for w in series.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 >= w[0].0);
        }
        assert!((series.last().unwrap().1 - 1.0).abs() < 1e-12);
    }
}
