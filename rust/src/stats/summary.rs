//! Streaming summaries and empirical CDFs — the accounting behind every
//! figure in the paper (all of Fig. 2/3/5/6 are CMFs of per-job metrics).

/// Streaming mean/variance/extremes (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { f64::NAN } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Empirical distribution over a recorded sample: quantiles, CDF evaluation,
/// and the fixed-grid CMF series the figure harness prints.
#[derive(Clone, Debug, Default)]
pub struct Cdf {
    values: Vec<f64>,
    sorted: bool,
}

impl Cdf {
    pub fn new() -> Self {
        Cdf { values: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.values.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        self.values.extend(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// q in [0, 1]; linear interpolation between order statistics.
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        self.ensure_sorted();
        if self.values.is_empty() {
            return f64::NAN;
        }
        let pos = q * (self.values.len() - 1) as f64;
        let i = pos.floor() as usize;
        let frac = pos - i as f64;
        if i + 1 < self.values.len() {
            self.values[i] * (1.0 - frac) + self.values[i + 1] * frac
        } else {
            self.values[i]
        }
    }

    /// P(X <= t).
    pub fn fraction_leq(&mut self, t: f64) -> f64 {
        self.ensure_sorted();
        if self.values.is_empty() {
            return f64::NAN;
        }
        let k = self.values.partition_point(|&v| v <= t);
        k as f64 / self.values.len() as f64
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// (x, F(x)) series on an `n`-point grid over [0, max] — the CMF the
    /// paper plots.
    pub fn cmf_series(&mut self, n: usize) -> Vec<(f64, f64)> {
        self.ensure_sorted();
        if self.values.is_empty() {
            return Vec::new();
        }
        let hi = *self.values.last().unwrap();
        (0..=n)
            .map(|i| {
                // note: hi * (i/n) so the last grid point is exactly hi
                let x = hi * (i as f64 / n as f64);
                (x, self.fraction_leq_sorted(x))
            })
            .collect()
    }

    fn fraction_leq_sorted(&self, t: f64) -> f64 {
        let k = self.values.partition_point(|&v| v <= t);
        k as f64 / self.values.len() as f64
    }
}

/// Streaming quantile estimator: the P² algorithm (Jain & Chlamtac 1985),
/// one five-marker sketch per target quantile in O(1) memory.
///
/// The middle marker tracks the `q`-quantile; its neighbours track `q/2`
/// and `(1+q)/2` plus the sample extremes, and each observation nudges the
/// interior markers toward their desired positions by a piecewise-parabolic
/// (falling back to linear) height update.  Below five samples the sketch
/// holds the raw values and [`P2Quantile::quantile`] is *exact*, using the
/// same order-statistic interpolation as [`Cdf::quantile`], so sketched and
/// retained percentiles agree bitwise on tiny runs.  The classic empirical
/// error bound is well under 1% of the sample spread for unimodal inputs;
/// the trade against `Cdf` is O(1) memory versus exactness.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    q: f64,
    n: u64,
    heights: [f64; 5],
    pos: [f64; 5],
    desired: [f64; 5],
    incr: [f64; 5],
}

impl P2Quantile {
    pub fn new(q: f64) -> Self {
        assert!((0.0..=1.0).contains(&q));
        P2Quantile {
            q,
            n: 0,
            heights: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            incr: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
        }
    }

    /// The target quantile this sketch tracks.
    pub fn q(&self) -> f64 {
        self.q
    }

    /// Observations absorbed so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn push(&mut self, x: f64) {
        if self.n < 5 {
            self.heights[self.n as usize] = x;
            self.n += 1;
            if self.n == 5 {
                self.heights.sort_by(|a, b| a.partial_cmp(b).unwrap());
            }
            return;
        }
        self.n += 1;
        let h = &mut self.heights;
        let k = if x < h[0] {
            h[0] = x;
            0
        } else if x < h[1] {
            0
        } else if x < h[2] {
            1
        } else if x < h[3] {
            2
        } else if x <= h[4] {
            3
        } else {
            h[4] = x;
            3
        };
        for p in self.pos.iter_mut().skip(k + 1) {
            *p += 1.0;
        }
        for (d, i) in self.desired.iter_mut().zip(self.incr) {
            *d += i;
        }
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let s = d.signum();
                let candidate = self.parabolic(i, s);
                self.heights[i] = if self.heights[i - 1] < candidate
                    && candidate < self.heights[i + 1]
                {
                    candidate
                } else {
                    self.linear(i, s)
                };
                self.pos[i] += s;
            }
        }
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let p = &self.pos;
        let h = &self.heights;
        h[i] + s / (p[i + 1] - p[i - 1])
            * ((p[i] - p[i - 1] + s) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
                + (p[i + 1] - p[i] - s) * (h[i] - h[i - 1]) / (p[i] - p[i - 1]))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.heights[i] + s * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate; NaN with no samples, exact below five.
    pub fn quantile(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        if self.n < 5 {
            let n = self.n as usize;
            let mut v = self.heights[..n].to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let pos = self.q * (n - 1) as f64;
            let i = pos.floor() as usize;
            let frac = pos - i as f64;
            return if i + 1 < n { v[i] * (1.0 - frac) + v[i + 1] * frac } else { v[i] };
        }
        self.heights[2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
    }

    #[test]
    fn cdf_quantiles() {
        let mut c = Cdf::new();
        c.extend((1..=100).map(|i| i as f64));
        assert!((c.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((c.quantile(1.0) - 100.0).abs() < 1e-12);
        assert!((c.quantile(0.5) - 50.5).abs() < 1e-9);
        assert!((c.fraction_leq(80.0) - 0.8).abs() < 1e-12);
        assert!((c.mean() - 50.5).abs() < 1e-12);
    }

    #[test]
    fn cdf_unsorted_input() {
        let mut c = Cdf::new();
        c.extend([5.0, 1.0, 3.0, 2.0, 4.0]);
        assert!((c.quantile(0.5) - 3.0).abs() < 1e-12);
        assert!((c.fraction_leq(2.5) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn p2_exact_below_five_samples() {
        let mut sketch = P2Quantile::new(0.8);
        let mut cdf = Cdf::new();
        for x in [4.0, 1.0, 3.0] {
            sketch.push(x);
            cdf.push(x);
        }
        assert_eq!(sketch.count(), 3);
        assert_eq!(sketch.quantile(), cdf.quantile(0.8));
    }

    #[test]
    fn p2_empty_is_nan() {
        assert!(P2Quantile::new(0.9).quantile().is_nan());
    }

    #[test]
    fn p2_tracks_uniform_quantiles() {
        // deterministic low-discrepancy stream over (0, 1)
        for &q in &[0.5, 0.8, 0.9] {
            let mut sketch = P2Quantile::new(q);
            let mut x = 0.5f64;
            for _ in 0..10_000 {
                x = (x + 0.618_033_988_749_894_9).fract();
                sketch.push(x);
            }
            assert!(
                (sketch.quantile() - q).abs() < 0.02,
                "q={q}: estimate {} too far off",
                sketch.quantile()
            );
        }
    }

    #[test]
    fn p2_tracks_pareto_tail() {
        // heavy-tailed input: Pareto(mu=1, alpha=2) via inverse transform
        let mut sketch = P2Quantile::new(0.9);
        let mut cdf = Cdf::new();
        let mut u = 0.5f64;
        for _ in 0..20_000 {
            u = (u + 0.618_033_988_749_894_9).fract();
            let x = (1.0 - u).powf(-0.5);
            sketch.push(x);
            cdf.push(x);
        }
        let exact = cdf.quantile(0.9);
        let est = sketch.quantile();
        assert!(
            (est - exact).abs() / exact < 0.05,
            "p90 estimate {est} vs exact {exact}"
        );
    }

    #[test]
    fn cmf_series_monotone() {
        let mut c = Cdf::new();
        c.extend((0..1000).map(|i| (i as f64).sqrt()));
        let series = c.cmf_series(50);
        assert_eq!(series.len(), 51);
        for w in series.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 >= w[0].0);
        }
        assert!((series.last().unwrap().1 - 1.0).abs() < 1e-12);
    }
}
