//! The paper's task-duration model: Pareto(mu, alpha) with
//! `F(t) = 1 - (mu/t)^alpha` for `t >= mu` (Sec. III-B).
//!
//! Everything a scheduler may legitimately know about a task's duration —
//! the distribution, conditional remaining-time statistics, the order
//! statistics used by the optimizers — lives here.

use super::rng::Pcg64;

/// Pareto distribution parameterized by scale `mu` and heavy-tail order
/// `alpha` (the paper uses `alpha = 2` throughout its evaluation).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pareto {
    pub mu: f64,
    pub alpha: f64,
}

impl Pareto {
    pub fn new(mu: f64, alpha: f64) -> Self {
        assert!(mu > 0.0 && alpha > 1.0, "need mu > 0, alpha > 1 (finite mean)");
        Pareto { mu, alpha }
    }

    /// Construct from a target mean: `mu = mean * (alpha - 1) / alpha`.
    pub fn from_mean(mean: f64, alpha: f64) -> Self {
        Pareto::new(mean * (alpha - 1.0) / alpha, alpha)
    }

    /// `E[x] = mu * alpha / (alpha - 1)`.
    #[inline]
    pub fn mean(&self) -> f64 {
        self.mu * self.alpha / (self.alpha - 1.0)
    }

    /// `E[x^2]` (infinite for `alpha <= 2`).
    #[inline]
    pub fn second_moment(&self) -> f64 {
        if self.alpha <= 2.0 {
            f64::INFINITY
        } else {
            self.mu * self.mu * self.alpha / (self.alpha - 2.0)
        }
    }

    /// Survival function P(x > t), defined on all of [0, inf).
    #[inline]
    pub fn sf(&self, t: f64) -> f64 {
        if t <= self.mu {
            1.0
        } else {
            (self.mu / t).powf(self.alpha)
        }
    }

    /// CDF.
    #[inline]
    pub fn cdf(&self, t: f64) -> f64 {
        1.0 - self.sf(t)
    }

    /// Inverse-CDF sampling.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        // x = mu * U^(-1/alpha), U in (0, 1]
        self.mu * rng.next_f64_open().powf(-1.0 / self.alpha)
    }

    /// P(x > e + a | x > e): probability the remaining time exceeds `a`
    /// given `e` units have elapsed.  This is the estimator Mantri-style
    /// rules use before the true duration is revealed.
    #[inline]
    pub fn sf_remaining(&self, elapsed: f64, a: f64) -> f64 {
        self.sf(elapsed + a) / self.sf(elapsed)
    }

    /// E[x - e | x > e]: conditional expected remaining time.
    #[inline]
    pub fn mean_remaining(&self, elapsed: f64) -> f64 {
        // E[x | x > e] = max(e, mu) * alpha / (alpha - 1)
        elapsed.max(self.mu) * self.alpha / (self.alpha - 1.0) - elapsed
    }

    /// Distribution of the minimum of `c` i.i.d. copies: Pareto(mu, c*alpha).
    #[inline]
    pub fn min_of(&self, c: f64) -> Pareto {
        Pareto { mu: self.mu, alpha: self.alpha * c }
    }

    /// `E[min of c copies] = mu * c*alpha / (c*alpha - 1)`  (Sec. III-B).
    #[inline]
    pub fn mean_min_of(&self, c: f64) -> f64 {
        let beta = self.alpha * c;
        self.mu * beta / (beta - 1.0)
    }

    /// Inverse of [`Pareto::sf_remaining`] in its increasing branch: the
    /// elapsed time `e*` at which `P(x > e + a | x > e)` equals `p`, i.e.
    /// the boundary past which the survival predicate `sf_remaining(e, a)
    /// > p` holds.  `None` when it can never hold (`p >= 1`).
    ///
    /// Used by the wakeup planner to answer "when does Mantri's duplicate
    /// test first flip, absent new events?".  Valid under the planner's
    /// precondition that the predicate is currently *false*: on `[0, mu]`
    /// the survival `sf(e + a)` is non-increasing in `e` and on
    /// `[mu, inf)` it is `(e / (e + a))^alpha`, strictly increasing — so
    /// a currently-false predicate stays false until exactly
    /// `e* = a q / (1 - q)` with `q = p^(1/alpha)` (which the
    /// precondition places in the increasing branch), and holds strictly
    /// after.
    #[inline]
    pub fn sf_remaining_flip(&self, a: f64, p: f64) -> Option<f64> {
        if p >= 1.0 {
            return None; // a survival probability never exceeds 1
        }
        let q = p.max(0.0).powf(1.0 / self.alpha);
        Some(a * q / (1.0 - q))
    }

    /// Inverse of [`Pareto::mean_remaining`] in its increasing branch: the
    /// elapsed time `e* = w (alpha - 1)` at which `E[x - e | x > e]`
    /// equals `w` — the boundary past which the threshold predicate
    /// `mean_remaining(e) > w` holds.
    ///
    /// Same planner precondition as [`Pareto::sf_remaining_flip`]: the
    /// conditional mean is non-increasing on `[0, mu]` (`mean - e`) and
    /// `e / (alpha - 1)` beyond, so a currently-false predicate first
    /// flips at `e*` exactly.
    #[inline]
    pub fn mean_remaining_flip(&self, w: f64) -> f64 {
        w * (self.alpha - 1.0)
    }

    /// Inverse of the LATE progress-rate denominator
    /// `e + mean_remaining(e) = max(e, mu) * alpha / (alpha - 1)`: the
    /// elapsed boundary `e*` past which the denominator strictly exceeds
    /// `d` — equivalently, past which the progress rate `1 / denom`
    /// drops strictly below `1 / d`.
    ///
    /// Same planner precondition as the other flips (the predicate is
    /// currently false, i.e. the denominator is `<= d` now, which forces
    /// `d >= E[x]`): the denominator is the constant `E[x]` on `[0, mu]`
    /// and strictly increasing beyond, so the crossing sits at
    /// `d (alpha - 1) / alpha`, clamped to `mu`.
    #[inline]
    pub fn rate_denom_flip(&self, d: f64) -> f64 {
        (d * (self.alpha - 1.0) / self.alpha).max(self.mu)
    }

    /// `E[min(x, cap)] = integral_0^cap S(t) dt`.
    #[inline]
    pub fn mean_capped(&self, cap: f64) -> f64 {
        if cap <= self.mu {
            return cap.max(0.0);
        }
        let a = self.alpha;
        self.mu + self.mu / (a - 1.0) * (1.0 - (self.mu / cap).powf(a - 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg64 {
        Pcg64::new(20140213, 0)
    }

    #[test]
    fn mean_matches_samples() {
        let p = Pareto::new(1.0, 2.0);
        let mut r = rng();
        let n = 400_000;
        let mean: f64 = (0..n).map(|_| p.sample(&mut r)).sum::<f64>() / n as f64;
        // alpha=2 has infinite variance: loose tolerance
        assert!((mean - p.mean()).abs() < 0.05, "mean={mean} vs {}", p.mean());
    }

    #[test]
    fn from_mean_roundtrip() {
        let p = Pareto::from_mean(2.5, 2.0);
        assert!((p.mean() - 2.5).abs() < 1e-12);
        assert!((p.mu - 1.25).abs() < 1e-12);
    }

    #[test]
    fn sf_cdf_consistency() {
        let p = Pareto::new(1.5, 2.5);
        for t in [0.0, 1.0, 1.5, 2.0, 10.0, 1e6] {
            assert!((p.sf(t) + p.cdf(t) - 1.0).abs() < 1e-12);
        }
        assert_eq!(p.sf(0.5), 1.0); // below scale: certain survival
    }

    #[test]
    fn samples_above_scale() {
        let p = Pareto::new(2.0, 3.0);
        let mut r = rng();
        for _ in 0..10_000 {
            assert!(p.sample(&mut r) >= p.mu);
        }
    }

    #[test]
    fn min_of_matches_simulation() {
        let p = Pareto::new(1.0, 2.0);
        let mut r = rng();
        let n = 200_000;
        let mean: f64 = (0..n)
            .map(|_| p.sample(&mut r).min(p.sample(&mut r)))
            .sum::<f64>()
            / n as f64;
        assert!((mean - p.mean_min_of(2.0)).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn mean_remaining_memory() {
        let p = Pareto::new(1.0, 2.0);
        // for e >= mu: E[x - e | x > e] = e/(alpha-1) = e (alpha = 2)
        assert!((p.mean_remaining(3.0) - 3.0).abs() < 1e-12);
        // below the scale the task is guaranteed to last until mu at least
        assert!(p.mean_remaining(0.0) >= p.mean() - 1e-12);
    }

    #[test]
    fn sf_remaining_heavy_tail_grows() {
        // heavy tail: the longer a task has run, the likelier it keeps running
        let p = Pareto::new(1.0, 2.0);
        let a = 2.0;
        assert!(p.sf_remaining(5.0, a) > p.sf_remaining(2.0, a));
    }

    #[test]
    fn mean_capped_limits() {
        let p = Pareto::new(1.0, 2.0);
        assert!((p.mean_capped(1e9) - p.mean()).abs() < 1e-3);
        assert!((p.mean_capped(0.5) - 0.5).abs() < 1e-12);
        assert_eq!(p.mean_capped(-1.0), 0.0);
    }

    /// The flip times are exact inverses of their predicates: just before
    /// the boundary the predicate is false, just after it is true — for
    /// several tail indices and thresholds.
    #[test]
    fn flip_times_invert_the_predicates() {
        for alpha in [1.5, 2.0, 3.0] {
            let p = Pareto::new(1.0, alpha);
            let a = 2.0 * p.mean();
            for delta in [0.1, 0.25, 0.5] {
                let e = p.sf_remaining_flip(a, delta).unwrap();
                assert!(e >= p.mu, "flip must sit in the increasing branch");
                assert!(p.sf_remaining(e * (1.0 - 1e-9), a) < delta);
                assert!(p.sf_remaining(e * (1.0 + 1e-9), a) > delta);
            }
            assert_eq!(p.sf_remaining_flip(a, 1.0), None);
            for w in [p.mean(), 1.7 * p.mean(), 4.0] {
                let e = p.mean_remaining_flip(w);
                assert!((p.mean_remaining(e) - w).abs() < 1e-9);
                assert!(p.mean_remaining(e * (1.0 + 1e-9)) > w);
            }
            let denom = |e: f64| e + p.mean_remaining(e);
            for d in [p.mean(), 1.3 * p.mean(), 5.0] {
                let e = p.rate_denom_flip(d);
                assert!(e >= p.mu);
                assert!(denom(e) <= d + 1e-9);
                assert!(denom(e * (1.0 + 1e-9)) > d);
            }
        }
    }

    #[test]
    fn second_moment() {
        assert!(Pareto::new(1.0, 2.0).second_moment().is_infinite());
        let p = Pareto::new(1.0, 3.0);
        assert!((p.second_moment() - 3.0).abs() < 1e-12);
    }
}
