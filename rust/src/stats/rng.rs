//! PCG64 (XSL-RR variant): small, fast, reproducible, and splittable into
//! independent streams — every simulator entity that needs randomness gets
//! its own stream so policy changes never perturb another entity's draws.

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id; distinct stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let initstate = (seed as u128) << 64 | (seed as u128 ^ 0x9e37_79b9_7f4a_7c15);
        let initseq = (stream as u128) << 1 | 1;
        let mut rng = Pcg64 { state: 0, inc: initseq };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(initstate);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child stream (e.g. one per job).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe to pass through `ln()`.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn uniform_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        // Lemire's method without the rejection refinement is fine here:
        // span << 2^64 so the bias is < 2^-40.
        lo + ((self.next_u64() as u128 * span as u128) >> 64) as u64
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponential variate with the given rate.
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.next_f64_open().ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_f64_in_range_and_mean() {
        let mut rng = Pcg64::new(42, 0);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn uniform_u64_bounds_inclusive() {
        let mut rng = Pcg64::new(1, 0);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = rng.uniform_u64(1, 100);
            assert!((1..=100).contains(&v));
            seen_lo |= v == 1;
            seen_hi |= v == 100;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(3, 0);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::new(9, 0);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
