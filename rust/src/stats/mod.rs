//! Random-variate substrate: seeded RNG streams, task-duration
//! distributions, and streaming summary statistics.

pub mod dist;
pub mod pareto;
pub mod rng;
pub mod summary;

pub use dist::{Distribution, Exponential, Uniform};
pub use pareto::Pareto;
pub use rng::Pcg64;
pub use summary::{Cdf, P2Quantile, Summary};
