//! `specsim` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   simulate   run one scheduler on one workload, print the summary
//!   compare    run several schedulers on the identical workload
//!   figure     regenerate a paper figure's data series (fig1..fig6,
//!              threshold, or `all`)
//!   threshold  print the analytic cutoff lambda^U for a cluster
//!   trace      generate a workload trace CSV
//!   serve      run the live master and feed it a Poisson client

use std::path::PathBuf;
use std::time::Duration;

use specsim::cluster::generator::generate;
use specsim::cluster::sim::Simulator;
use specsim::cluster::trace;
use specsim::config::{SimConfig, WorkloadConfig};
use specsim::coordinator::master::{Master, Submission};
use specsim::figures::{self, Scale};
use specsim::metrics::report::{self, SummaryRow};
use specsim::scheduler::{self, SchedulerKind};
use specsim::stats::Pcg64;
use specsim::util::cli::Args;

const USAGE: &str = "specsim — speculative execution for MapReduce-like clusters (Xu & Lau 2014)

USAGE: specsim <command> [flags]

COMMANDS
  simulate   --scheduler <kind> [--machines N] [--horizon T] [--lambda L]
             [--seed S] [--sigma X] [--config file.toml]
             [--artifacts-dir DIR] [--no-runtime]
  compare    [--schedulers a,b,c] [same flags as simulate]
  figure     <fig1|fig2|fig3|fig4|fig5|fig6|threshold|all>
             [--out-dir results] [--artifacts-dir DIR] [--scale 1.0]
  threshold  [--machines N] [--mean-tasks M] [--mean-duration S] [--alpha A]
  trace      --out FILE [--lambda L] [--horizon T] [--seed S]
  serve      [--machines N] [--rate R] [--jobs J] [--scheduler kind]
             [--artifacts-dir DIR]

scheduler kinds: naive clone_all mantri late sca sda ese";

fn build_common(args: &Args) -> Result<(SimConfig, WorkloadConfig), String> {
    let mut cfg = match args.str("config") {
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| e.to_string())?;
            SimConfig::from_toml(&text)?
        }
        None => {
            let mut c = SimConfig::default();
            c.machines = args.usize("machines", 3000)?;
            c.horizon = args.f64("horizon", 1500.0)?;
            c
        }
    };
    cfg.seed = args.u64("seed", cfg.seed)?;
    if let Some(sigma) = args.f64_opt("sigma")? {
        cfg.sigma = Some(sigma);
    }
    cfg.artifacts_dir = args.string("artifacts-dir", &cfg.artifacts_dir);
    if args.has("no-runtime") {
        cfg.use_runtime = false;
    }
    cfg.validate()?;
    let lambda = args.f64("lambda", 6.0)?;
    Ok((cfg, WorkloadConfig::paper(lambda)))
}

fn run_one(cfg: &SimConfig, wl: &WorkloadConfig, kind: SchedulerKind) -> Result<SummaryRow, String> {
    let mut c = cfg.clone();
    c.scheduler = kind;
    let workload = generate(wl, c.horizon, c.seed);
    let sched = scheduler::build(&c, wl)?;
    let res = Simulator::new(c, workload, sched).run();
    Ok(SummaryRow::from_result(&res))
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(rest, &["no-runtime", "help"])?;
    if args.has("help") {
        println!("{USAGE}");
        return Ok(());
    }
    match cmd.as_str() {
        "simulate" => {
            let (cfg, wl) = build_common(&args)?;
            let kind: SchedulerKind = args.string("scheduler", "sca").parse()?;
            let row = run_one(&cfg, &wl, kind)?;
            print!("{}", report::summary_table(&[row]));
        }
        "compare" => {
            let (cfg, wl) = build_common(&args)?;
            let kinds: Vec<SchedulerKind> = args
                .string("schedulers", "sca,sda,ese,mantri,naive")
                .split(',')
                .map(|s| s.trim().parse())
                .collect::<Result<_, _>>()?;
            let mut rows = Vec::new();
            for kind in kinds {
                rows.push(run_one(&cfg, &wl, kind)?);
            }
            print!("{}", report::summary_table(&rows));
        }
        "figure" => {
            let id = args
                .positional()
                .first()
                .ok_or("figure: which one? (fig1..fig6, threshold, all)")?
                .clone();
            let out_dir = PathBuf::from(args.string("out-dir", "results"));
            let artifacts_dir = args.string("artifacts-dir", "artifacts");
            let scale = Scale(args.f64("scale", 1.0)?);
            match id.as_str() {
                "fig1" => figures::fig1::run(&out_dir, &artifacts_dir, scale)?,
                "fig2" => figures::fig2::run(&out_dir, &artifacts_dir, scale)?,
                "fig3" => figures::fig3::run(&out_dir, &artifacts_dir, scale)?,
                "fig4" => figures::fig4::run(&out_dir, &artifacts_dir, scale)?,
                "fig5" => figures::fig5::run(&out_dir, &artifacts_dir, scale)?,
                "fig6" => figures::fig6::run(&out_dir, &artifacts_dir, scale)?,
                "threshold" => figures::threshold::run(&out_dir, &artifacts_dir, scale)?,
                "all" => figures::run_all(&out_dir, &artifacts_dir, scale)?,
                other => return Err(format!("unknown figure '{other}'")),
            }
            println!("wrote series under {}", out_dir.display());
        }
        "threshold" => {
            let rep = specsim::analysis::threshold::cutoff_lambda(
                args.usize("machines", 3000)?,
                args.f64("mean-tasks", 50.5)?,
                args.f64("mean-duration", 2.5)?,
                args.f64("alpha", 2.0)?,
            );
            println!(
                "omega_stability = {:.4}\nomega_cutoff    = {:.4}\nlambda^U        = {:.3} jobs/unit",
                rep.omega_stability, rep.omega_cutoff, rep.lambda_cutoff
            );
        }
        "trace" => {
            let out = PathBuf::from(args.str("out").ok_or("trace: --out FILE required")?);
            let wl = generate(
                &WorkloadConfig::paper(args.f64("lambda", 6.0)?),
                args.f64("horizon", 100.0)?,
                args.u64("seed", 1)?,
            );
            trace::save(&wl, &out)?;
            println!("wrote {} jobs to {}", wl.specs.len(), out.display());
        }
        "serve" => {
            let mut cfg = SimConfig::default();
            cfg.machines = args.usize("machines", 200)?;
            cfg.horizon = f64::INFINITY;
            cfg.scheduler = args.string("scheduler", "sda").parse()?;
            cfg.artifacts_dir = args.string("artifacts-dir", "artifacts");
            if args.has("no-runtime") {
                cfg.use_runtime = false;
            }
            let rate = args.f64("rate", 50.0)?;
            let jobs = args.u64("jobs", 500)?;
            let master = Master::new(cfg);
            let metrics = master.metrics.clone();
            let handle = master.spawn()?;
            let mut rng = Pcg64::new(42, 0);
            let mut accepted = 0u64;
            for _ in 0..jobs {
                std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
                let sub = Submission {
                    num_tasks: rng.uniform_u64(1, 100) as u32,
                    mean_duration: rng.uniform_f64(1.0, 4.0),
                    alpha: 2.0,
                };
                if handle.submit(sub)?.is_accepted() {
                    accepted += 1;
                }
            }
            let report = handle.shutdown()?;
            println!(
                "submitted {jobs}, accepted {accepted}, completed {}",
                report.completed.len()
            );
            let mean_flow = report.completed.iter().map(|r| r.flowtime).sum::<f64>()
                / report.completed.len().max(1) as f64;
            println!("mean flowtime (virtual units): {mean_flow:.3}");
            println!("--- metrics ---\n{}", metrics.render());
        }
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => return Err(format!("unknown command '{other}'\n\n{USAGE}")),
    }
    Ok(())
}
