//! `specsim` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   simulate   run one scheduler on one workload, print the summary
//!   compare    run several schedulers on the identical workload (in
//!              parallel, one worker per scheduler)
//!   sweep      run a scheduler x lambda x seed grid through the
//!              experiment engine and write the cell table as CSV
//!   replay     stream a recorded trace into the live serve plane
//!   figure     regenerate a paper figure's data series (fig1..fig6,
//!              threshold, crossover, or `all`)
//!   threshold  print the analytic cutoff lambda^U for a cluster
//!   trace      generate a workload trace CSV
//!   serve      run the live master and feed it a Poisson client

use std::path::PathBuf;
use std::time::Duration;

use specsim::cluster::machine;
use specsim::cluster::trace;
use specsim::config::{RoutePolicy, ServeConfig, SimConfig, WorkloadConfig};
use specsim::coordinator::master::Submission;
use specsim::coordinator::shard::ShardedMaster;
use specsim::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner};
use specsim::figures::{self, Scale};
use specsim::metrics::report::{self, SummaryRow};
use specsim::scheduler::SchedulerKind;
use specsim::stats::Pcg64;
use specsim::util::cli::Args;

const USAGE: &str = "specsim — speculative execution for MapReduce-like clusters (Xu & Lau 2014)

USAGE: specsim <command> [flags]

COMMANDS
  simulate   --policy <spec> [--machines N] [--horizon T] [--lambda L]
             [--seed S] [--sigma X] [--config file.toml]
             [--artifacts-dir DIR] [--no-runtime] [workload/cluster flags]
  compare    [--policies a,b,c] [--threads N] [same flags as simulate]
  sweep      [--policies a,b,c] [--lambdas 2,4,6] [--seeds 1,2,3]
             [--threads N] [--out FILE] [--rss-budget-mb MB]
             [same flags as simulate]; --rss-budget-mb fails the run
             when peak RSS (VmHWM) exceeds the budget — the CI memory
             gate for streamed trace replays
  figure     <fig1|fig2|fig3|fig4|fig5|fig6|threshold|crossover|churn|all>
             [--out-dir results] [--artifacts-dir DIR] [--scale 1.0]
             [--threads N]; churn sweeps mean flowtime of the seven
             canonical policies against the machine MTTF
  threshold  [--machines N] [--mean-tasks M] [--mean-duration S] [--alpha A]
  bench      [--quick] [--out FILE] [--md FILE] [--check-wakeup]
             [--check-scale] [--serve] [--check-serve] [--serve-csv FILE]
             standardized throughput suite: every policy (7 canonical +
             2 composed pipelines) x {light lambda=0.3, heavy
             lambda~0.9*lambda^U} x M in {500, 4000}, each cell on the
             SchedIndex hot path, the naive-scan reference, and the
             polled (--no-wakeup) loop; light cells run the fine
             slot grid (slot_dt = 0.001) the wakeup planner targets;
             then the (naive, light) scale cells M in {1e5, 1e6} timed
             per event-queue backend (calendar vs binary-heap) with
             peak RSS — --quick omits the M=1e6 cell; writes
             machine-readable JSON (default BENCH_sim.json at the
             cwd) and, with --md, the EXPERIMENTS.md-ready markdown
             tables; --check-wakeup fails unless the (naive, light,
             M=4000) cell skips >= 50% of slots at >= 2x wall speedup;
             --check-scale fails unless the calendar backend at least
             matches the heap on the (naive, light, M=1e5) cell;
             --serve adds the sharded-coordinator cells (sustained
             submissions/sec + submit latency at shards in {1, 2, 4},
             time-series CSV to --serve-csv, default serve_metrics.csv)
             and --check-serve fails unless 2 shards reach >= 1.4x the
             1-shard throughput
  trace      --out FILE [--lambda L] [--horizon T] [--seed S] [--jobs N]
             with --jobs the trace is synthesized *streaming*: exactly N
             jobs are generated and written through a buffered writer
             (horizon defaults to unbounded), so a 10^6-job trace never
             materializes in memory
  replay     --trace FILE [--trace-format F] [--speedup X]
             [--as-fast-as-possible] [--batch B] [--shards N]
             [--route hash|p2c] [--machines N] [--policy spec]
             [--route-seed S] [--sample-ms MS] [--serve-csv FILE]
             [--machine-events FILE] [--max-restarts N]
             [--shed-watermark N]
             pump a recorded trace through the sharded live masters,
             pacing batches by recorded inter-arrival gaps scaled by
             --speedup (default 1.0); --as-fast-as-possible drops the
             pacing entirely; --machine-events replays a recorded
             `timestamp,machine_id,event{ADD,REMOVE}` churn schedule
             into the shard clusters (global machine ids, split across
             the shard partitions)
  serve      [--shards N] [--route hash|p2c] [--machines N] [--rate R]
             [--jobs J] [--policy spec] [--route-seed S] [--sample-ms MS]
             [--serve-csv FILE] [--artifacts-dir DIR] [--max-restarts N]
             [--shed-watermark N]
             a crashed shard master respawns (up to --max-restarts
             times, default 8, capped exponential backoff) and replays
             its un-acked submissions; --shed-watermark sheds new load
             with a structured reject while a shard's backlog gauge
             sits past N

WORKLOAD / CLUSTER SCENARIO FLAGS
  --workload poisson|bursty|trace   arrival process (default poisson)
  --burst B --on-frac F --cycle C   bursty (MMPP) shape: ON rate = B*lambda,
                                    ON fraction F, mean cycle C time units
  --trace FILE                      trace replay (with --workload trace);
                                    streamed through a bounded lookahead
                                    window, never materialized
  --trace-format auto|native|simple|jsonl
                                    trace schema (default auto-detect;
                                    simple = arrival,duration,tasks[,alpha])
  --trace-window N                  streaming lookahead window in jobs
                                    (default 1024)
  --trace-max-jobs N                replay only the first N trace jobs
                                    (0 = all)
  --max-resident-jobs N             recycle completed job records into
                                    streaming sketches once N are resident,
                                    bounding memory for long replays
                                    (0 = keep every record; identical
                                    dynamics either way)
  --machine-classes \"2000x1.0,1000x0.5\"
                                    heterogeneous cluster: COUNTxSPEED groups
                                    (machine count is derived from the sum)
  --slowdown FRACxFACTOR            server-dependent slowdown: each machine
                                    degraded with prob FRAC runs FACTORx
                                    slower (hidden from schedulers)
  --churn MTTF,MTTR                 machine crash/recovery churn: each
                                    machine alternates exp(MTTF) up-time
                                    and exp(MTTR) repair; a crash kills the
                                    resident copy and a crashed-out task
                                    restarts from zero (0,0 disables —
                                    bit-identical to no churn)
  --slowdown-flip RATE_ON,RATE_OFF  ON/OFF Markov slowdown: healthy machines
                                    degrade at exp rate RATE_ON, degraded
                                    ones recover at RATE_OFF (needs a
                                    --slowdown base; running copies are
                                    re-timed in flight; a 0 rate makes that
                                    state absorbing)
  --observed-speed                  checkpoint-instrumented estimators
                                    project revealed remaining times by the
                                    host's measured lifetime throughput
                                    instead of its advertised speed
  --no-speed-aware                  estimators ignore advertised host speeds
                                    (the unit-naive homogeneous assumption)
  --no-sched-index                  slot hooks use the retained naive full
                                    scans instead of the incremental
                                    SchedIndex (equivalence reference; same
                                    decisions, slower)
  --slot-dt DT                      scheduling-slot length (> 0; default
                                    1.0 — the paper's slotted grid)
  --no-wakeup                       fire the scheduler at every slot-grid
                                    point (the retired polling loop)
                                    instead of demand-driven wakeups
                                    (equivalence reference; same decisions,
                                    slower on fine grids / light loads)
  --event-queue calendar|binary-heap
                                    event-queue backend (default calendar;
                                    binary-heap is the bit-identical
                                    equivalence reference)
  --clone-copies N                  clones per task for clone_all / the
                                    clone rule's fixed budget (default 2)

POLICY SPECS
  A policy is a canonical name — naive clone_all mantri late sca sda ese —
  or a composition 'ordering+rule[*budget]':
    orderings  fifo | srpt | est-srpt      (est-srpt = estimate-driven SRPT)
    rules      never | clone | mantri | late | sda | ese
    budgets    fixedK | capK | p2 | eq29   (K >= 2; omit for the default;
                                            p2 needs a cloning rule)
  e.g. srpt+mantri, fifo+sda, est-srpt+ese*cap2, srpt+clone*fixed3.
  (--scheduler/--schedulers are accepted as aliases of --policy/--policies.)

threads: 0 = one worker per core";

/// The arrival process selected by `--workload` at rate `lambda`.
fn build_workload(args: &Args, lambda: f64) -> Result<WorkloadConfig, String> {
    match args.string("workload", "poisson").as_str() {
        "poisson" => Ok(WorkloadConfig::paper(lambda)),
        "bursty" => {
            let burst = args.f64("burst", 3.0)?;
            let frac = args.f64("on-frac", 0.25)?;
            if !(0.0 < frac && frac < 1.0) {
                return Err("--on-frac must be in (0,1)".to_string());
            }
            if burst < 1.0 || burst * frac > 1.0 {
                return Err(format!(
                    "--burst must be in [1, 1/on-frac] = [1, {:.2}] so the mean rate stays \
                     reachable (got {burst})",
                    1.0 / frac
                ));
            }
            let mut wl = WorkloadConfig::bursty_paper(lambda, burst);
            if let WorkloadConfig::Bursty { on_frac, cycle, .. } = &mut wl {
                *on_frac = frac;
                *cycle = args.f64("cycle", 40.0)?;
            }
            Ok(wl)
        }
        "trace" => {
            let mut wl = WorkloadConfig::trace(
                args.str("trace")
                    .ok_or("--trace FILE required with --workload trace")?,
            );
            if let WorkloadConfig::Trace { format, window, max_jobs, .. } = &mut wl {
                *format = args.string("trace-format", "auto").parse()?;
                *window = args.usize("trace-window", *window)?;
                let cap = args.u64("trace-max-jobs", 0)?;
                *max_jobs = (cap > 0).then_some(cap);
            }
            Ok(wl)
        }
        other => Err(format!("unknown workload '{other}' (poisson|bursty|trace)")),
    }
}

/// Cluster scenario flags shared by the simulation commands and `serve`.
fn apply_scenario_flags(cfg: &mut SimConfig, args: &Args) -> Result<(), String> {
    if let Some(spec) = args.str("machine-classes") {
        cfg.set_machine_classes(machine::parse_classes(spec)?);
    }
    if let Some(spec) = args.str("slowdown") {
        cfg.slowdown = Some(machine::parse_slowdown(spec)?);
    }
    if let Some(spec) = args.str("churn") {
        cfg.churn = Some(machine::parse_churn(spec)?);
    }
    if let Some(spec) = args.str("slowdown-flip") {
        let rates: Vec<f64> = parse_list(spec, "--slowdown-flip")?;
        let [rate_on, rate_off] = rates[..] else {
            return Err("--slowdown-flip RATE_ON,RATE_OFF takes exactly two rates".to_string());
        };
        let base = cfg
            .slowdown
            .ok_or("--slowdown-flip needs a --slowdown (or TOML) base to flip")?;
        cfg.slowdown = Some(base.with_rates(rate_on, rate_off));
    }
    if args.has("observed-speed") {
        cfg.observed_speed = true;
    }
    if args.has("no-speed-aware") {
        cfg.speed_aware = false;
    }
    if args.has("no-sched-index") {
        cfg.sched_index = false;
    }
    if args.has("no-wakeup") {
        cfg.wakeup = false;
    }
    if let Some(q) = args.str("event-queue") {
        cfg.event_queue = q.parse()?;
    }
    if args.has("no-runtime") {
        cfg.use_runtime = false;
    }
    // the TOML key always existed; the flag finally reaches it (validated
    // > 0 by cfg.validate(), which every consumer runs)
    if let Some(dt) = args.f64_opt("slot-dt")? {
        cfg.slot_dt = dt;
    }
    cfg.clone_copies = args.usize("clone-copies", cfg.clone_copies as usize)? as u32;
    let cap = args.usize("max-resident-jobs", 0)?;
    if cap > 0 {
        cfg.max_resident_jobs = Some(cap);
    }
    Ok(())
}

/// Supervisor flags shared by `serve` and `replay`: the shard restart
/// budget and the optional shed watermark (DESIGN.md §17).
fn apply_supervisor_flags(sharded: &mut ShardedMaster, args: &Args) -> Result<(), String> {
    sharded.max_restarts = args.usize("max-restarts", sharded.max_restarts as usize)? as u32;
    if args.str("shed-watermark").is_some() {
        sharded.shed_watermark = Some(args.usize("shed-watermark", 0)?);
    }
    Ok(())
}

/// `--policy SPEC` with `--scheduler` as a legacy alias.
fn policy_arg(args: &Args, default: &str) -> String {
    args.string("policy", &args.string("scheduler", default))
}

/// `--policies a,b,c` with `--schedulers` as a legacy alias.
fn policies_arg(args: &Args, default: &str) -> Result<Vec<SchedulerKind>, String> {
    args.string("policies", &args.string("schedulers", default))
        .split(',')
        .map(|s| s.trim().parse())
        .collect()
}

fn build_common(args: &Args) -> Result<(SimConfig, WorkloadConfig), String> {
    let mut cfg = match args.str("config") {
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| e.to_string())?;
            SimConfig::from_toml(&text)?
        }
        None => {
            let mut c = SimConfig::default();
            c.machines = args.usize("machines", 3000)?;
            c.horizon = args.f64("horizon", 1500.0)?;
            c
        }
    };
    cfg.seed = args.u64("seed", cfg.seed)?;
    if let Some(sigma) = args.f64_opt("sigma")? {
        cfg.sigma = Some(sigma);
    }
    apply_scenario_flags(&mut cfg, args)?;
    cfg.artifacts_dir = args.string("artifacts-dir", &cfg.artifacts_dir);
    cfg.validate()?;
    let lambda = args.f64("lambda", 6.0)?;
    let wl = build_workload(args, lambda)?;
    Ok((cfg, wl))
}

/// Run `kinds` on the identical workload through the experiment engine.
fn run_kinds(
    cfg: &SimConfig,
    wl: &WorkloadConfig,
    kinds: Vec<SchedulerKind>,
    threads: usize,
) -> Result<Vec<SummaryRow>, String> {
    let mut spec = ExperimentSpec::new("cli", cfg.clone());
    spec.policies = kinds.into_iter().map(PolicyVariant::kind).collect();
    spec.loads = vec![LoadPoint::new("cli", f64::NAN, wl.clone())];
    spec.seeds = vec![cfg.seed];
    spec.threads = threads;
    let sweep = Runner::run(&spec)?;
    Ok((0..sweep.policies.len())
        .map(|pi| SummaryRow::from_result(&sweep.merged(pi, 0)))
        .collect())
}

/// How long `replay` should sleep before submitting the batch that starts
/// at recorded arrival `arrival`: the batch's wall-clock target is its
/// offset from the trace's first arrival divided by `speedup`, measured
/// from replay `start` — drift-free by construction.  `None` when pacing
/// is off or the target is already behind.
fn pacing_wait(
    afap: bool,
    arrival: f64,
    first_arrival: f64,
    speedup: f64,
    start: std::time::Instant,
) -> Option<Duration> {
    if afap || !first_arrival.is_finite() {
        return None;
    }
    Duration::from_secs_f64(((arrival - first_arrival) / speedup).max(0.0))
        .checked_sub(start.elapsed())
}

/// Submit one replay batch (after an optional pacing sleep) and count the
/// accepted jobs; clears the batch for reuse.
fn replay_flush(
    handle: &specsim::coordinator::shard::ShardedHandle,
    batch: &mut Vec<Submission>,
    wait: Option<Duration>,
) -> Result<u64, String> {
    if batch.is_empty() {
        return Ok(0);
    }
    if let Some(w) = wait {
        std::thread::sleep(w);
    }
    let results = handle.submit_batch(batch)?;
    batch.clear();
    Ok(results.iter().filter(|(_, r)| r.is_accepted()).count() as u64)
}

fn parse_list<T: std::str::FromStr>(s: &str, what: &str) -> Result<Vec<T>, String> {
    s.split(',')
        .map(|p| p.trim().parse().map_err(|_| format!("{what}: bad value '{p}'")))
        .collect()
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(
        rest,
        &[
            "no-runtime",
            "observed-speed",
            "no-speed-aware",
            "no-sched-index",
            "no-wakeup",
            "quick",
            "check-wakeup",
            "check-scale",
            "serve",
            "check-serve",
            "as-fast-as-possible",
            "help",
        ],
    )?;
    if args.has("help") {
        println!("{USAGE}");
        return Ok(());
    }
    match cmd.as_str() {
        "simulate" => {
            let (mut cfg, wl) = build_common(&args)?;
            cfg.scheduler = policy_arg(&args, "sca").parse()?;
            let rows = run_kinds(&cfg, &wl, vec![cfg.scheduler], 1)?;
            print!("{}", report::summary_table(&rows));
        }
        "compare" => {
            let (cfg, wl) = build_common(&args)?;
            let kinds = policies_arg(&args, "sca,sda,ese,mantri,naive")?;
            let threads = args.usize("threads", 0)?;
            let rows = run_kinds(&cfg, &wl, kinds, threads)?;
            print!("{}", report::summary_table(&rows));
        }
        "sweep" => {
            let (cfg, _) = build_common(&args)?;
            let kinds = policies_arg(&args, "sca,sda,ese,mantri,naive")?;
            let lambdas: Vec<f64> = parse_list(&args.string("lambdas", "2,4,6"), "--lambdas")?;
            let seeds: Vec<u64> = parse_list(&args.string("seeds", "1,2,3"), "--seeds")?;
            let mut spec = ExperimentSpec::new("sweep", cfg);
            spec.policies = kinds.into_iter().map(PolicyVariant::kind).collect();
            spec.loads = lambdas
                .iter()
                .map(|&l| {
                    build_workload(&args, l)
                        .map(|wl| LoadPoint::new(format!("lambda{l}"), l, wl))
                })
                .collect::<Result<_, _>>()?;
            spec.seeds = seeds;
            spec.threads = args.usize("threads", 0)?;
            let sweep = Runner::run(&spec)?;
            let out = args.string("out", "results/sweep.csv");
            report::write_file(&out, &report::sweep_csv(&sweep)).map_err(|e| e.to_string())?;
            println!("wrote {} cells to {out}", sweep.cells.len());
            if let Some(budget_mb) = args.f64_opt("rss-budget-mb")? {
                let peak = specsim::util::bench::peak_rss_bytes()
                    .ok_or("--rss-budget-mb: VmHWM not readable on this platform")?;
                let peak_mb = peak as f64 / (1024.0 * 1024.0);
                println!("peak RSS {peak_mb:.1} MiB (budget {budget_mb} MiB)");
                if peak_mb > budget_mb {
                    return Err(format!(
                        "peak RSS {peak_mb:.1} MiB exceeds the --rss-budget-mb {budget_mb} \
                         MiB budget"
                    ));
                }
            }
            for (label, pts) in sweep.series_over_loads(|r| r.mean_flowtime()) {
                let series: Vec<String> =
                    pts.iter().map(|(x, y)| format!("{x}:{y:.3}")).collect();
                println!("  {label:<10} mean_flowtime by lambda: {}", series.join("  "));
            }
        }
        "figure" => {
            let id = args
                .positional()
                .first()
                .ok_or("figure: which one? (fig1..fig6, threshold, crossover, churn, all)")?
                .clone();
            let out_dir = PathBuf::from(args.string("out-dir", "results"));
            let artifacts_dir = args.string("artifacts-dir", "artifacts");
            let scale = Scale(args.f64("scale", 1.0)?);
            let threads = args.usize("threads", 0)?;
            match id.as_str() {
                "fig1" => figures::fig1::run(&out_dir, &artifacts_dir, scale, threads)?,
                "fig2" => figures::fig2::run(&out_dir, &artifacts_dir, scale, threads)?,
                "fig3" => figures::fig3::run(&out_dir, &artifacts_dir, scale, threads)?,
                "fig4" => figures::fig4::run(&out_dir, &artifacts_dir, scale, threads)?,
                "fig5" => figures::fig5::run(&out_dir, &artifacts_dir, scale, threads)?,
                "fig6" => figures::fig6::run(&out_dir, &artifacts_dir, scale, threads)?,
                "threshold" => figures::threshold::run(&out_dir, &artifacts_dir, scale, threads)?,
                "crossover" => figures::crossover::run(&out_dir, &artifacts_dir, scale, threads)?,
                "churn" => figures::churn::run(&out_dir, &artifacts_dir, scale, threads)?,
                "all" => figures::run_all(&out_dir, &artifacts_dir, scale, threads)?,
                other => return Err(format!("unknown figure '{other}'")),
            }
            println!("wrote series under {}", out_dir.display());
        }
        "threshold" => {
            let rep = specsim::analysis::threshold::cutoff_lambda(
                args.usize("machines", 3000)?,
                args.f64("mean-tasks", 50.5)?,
                args.f64("mean-duration", 2.5)?,
                args.f64("alpha", 2.0)?,
            );
            println!(
                "omega_stability = {:.4}\nomega_cutoff    = {:.4}\nlambda^U        = {:.3} jobs/unit",
                rep.omega_stability, rep.omega_cutoff, rep.lambda_cutoff
            );
        }
        "bench" => {
            let quick = args.has("quick");
            let out = args.string("out", "BENCH_sim.json");
            println!(
                "specsim throughput suite ({}; horizon {}): policies x \
                 {{light, heavy}} x M in {:?}, indexed vs naive-scan vs polled",
                if quick { "quick" } else { "full" },
                specsim::util::bench::suite_horizon(quick),
                specsim::util::bench::SUITE_MACHINES,
            );
            println!(
                "{:<10} {:>5} {:>8} {:>7} {:>13} {:>13} {:>8} {:>6} {:>8}",
                "policy",
                "M",
                "lambda",
                "load",
                "indexed ev/s",
                "scan ev/s",
                "speedup",
                "skip",
                "wakeup"
            );
            let cells = specsim::util::bench::run_throughput_suite(quick, |c| {
                println!(
                    "{:<10} {:>5} {:>8.3} {:>7} {:>13.0} {:>13.0} {:>7.2}x {:>5.0}% {:>7.2}x",
                    c.policy,
                    c.machines,
                    c.lambda,
                    c.load,
                    c.indexed.events_per_sec,
                    c.scan.events_per_sec,
                    c.speedup(),
                    100.0 * c.indexed.skip_ratio(),
                    c.wakeup_speedup()
                );
            })?;
            println!(
                "scale cells (naive, light): M in {:?}{}, calendar vs binary-heap",
                specsim::util::bench::SCALE_MACHINES,
                if quick { " minus the M=1e6 cell (--quick)" } else { "" },
            );
            let scale = specsim::util::bench::run_scale_suite(quick, |c| {
                println!(
                    "{:<10} {:>8} {:>8.3} {:>7} {:>13.0} {:>13.0} {:>7.2}x  rss {}/{}",
                    c.policy,
                    c.machines,
                    c.lambda,
                    c.load,
                    c.calendar.events_per_sec,
                    c.heap.events_per_sec,
                    c.queue_speedup(),
                    c.calendar
                        .peak_rss_bytes
                        .map_or("n/a".into(), |b| format!("{}MiB", b >> 20)),
                    c.heap.peak_rss_bytes.map_or("n/a".into(), |b| format!("{}MiB", b >> 20)),
                );
            })?;
            println!("flip cell (sda, light): ON/OFF Markov flips vs static slowdown");
            let flips = specsim::util::bench::run_flip_suite(quick, |c| {
                println!(
                    "{:<10} {:>5} {:>8.3} {:>7} {:>13.0} {:>13.0} {:>7.2}x  ({})",
                    c.policy,
                    c.machines,
                    c.lambda,
                    c.load,
                    c.flips.events_per_sec,
                    c.static_run.events_per_sec,
                    c.overhead(),
                    c.slowdown,
                );
            })?;
            println!(
                "trace cell (naive, light): materialized vs streamed vs capped replay (cap {})",
                specsim::util::bench::TRACE_RESIDENT_CAP,
            );
            let trace_cells = specsim::util::bench::run_trace_suite(quick, |c| {
                println!(
                    "{:<10} {:>5} {:>8} jobs {:>13.0} {:>13.0} {:>13.0} ev/s  overhead {:>5.2}x",
                    c.policy,
                    c.machines,
                    c.jobs,
                    c.materialized.events_per_sec,
                    c.streamed.events_per_sec,
                    c.capped.events_per_sec,
                    c.stream_overhead(),
                );
            })?;
            println!("churn cell (sda, light): machine crash/recovery vs churn-free baseline");
            let churn_cells = specsim::util::bench::run_churn_suite(quick, |c| {
                println!(
                    "{:<10} {:>5} {:>8.3} {:>7} {:>13.0} {:>13.0} {:>7.2}x  ({})",
                    c.policy,
                    c.machines,
                    c.lambda,
                    c.load,
                    c.churned.events_per_sec,
                    c.baseline.events_per_sec,
                    c.overhead(),
                    c.churn,
                );
            })?;
            let mut serve_cells = Vec::new();
            let mut serve_csv = String::new();
            if args.has("serve") || args.has("check-serve") {
                println!(
                    "serve cells: shards in {:?}, hash routing, M={}, fixed workload",
                    specsim::util::bench::SERVE_SHARDS,
                    specsim::util::bench::SERVE_MACHINES,
                );
                let (sc, csv) = specsim::util::bench::run_serve_suite(quick, |c| {
                    println!(
                        "shards={:<2} {:>8} subs {:>12.0} subs/s  p50 {:>8.1}us  p99 {:>8.1}us",
                        c.shards,
                        c.submissions,
                        c.submissions_per_sec,
                        c.p50_submit_secs * 1e6,
                        c.p99_submit_secs * 1e6,
                    );
                })?;
                serve_cells = sc;
                serve_csv = csv;
            }
            let doc = specsim::util::bench::throughput_json(
                &cells,
                &scale,
                &flips,
                &serve_cells,
                &trace_cells,
                &churn_cells,
                quick,
            );
            report::write_file(&out, &format!("{doc}\n")).map_err(|e| e.to_string())?;
            if !serve_csv.is_empty() {
                let csv_path = args.string("serve-csv", "serve_metrics.csv");
                report::write_file(&csv_path, &serve_csv).map_err(|e| e.to_string())?;
                println!("wrote the serve metrics time series to {csv_path}");
            }
            if let Some(md) = args.str("md") {
                let mut table = specsim::util::bench::throughput_markdown(&cells);
                table.push('\n');
                table.push_str(&specsim::util::bench::scale_markdown(&scale));
                table.push('\n');
                table.push_str(&specsim::util::bench::flip_markdown(&flips));
                table.push('\n');
                table.push_str(&specsim::util::bench::trace_markdown(&trace_cells));
                table.push('\n');
                table.push_str(&specsim::util::bench::churn_markdown(&churn_cells));
                if !serve_cells.is_empty() {
                    table.push('\n');
                    table.push_str(&specsim::util::bench::serve_markdown(&serve_cells));
                }
                report::write_file(md, &table).map_err(|e| e.to_string())?;
                println!("wrote the EXPERIMENTS.md-ready tables to {md}");
            }
            println!(
                "wrote {} cells (+{} scale, +{} flip, +{} trace, +{} churn, +{} serve) to {out}",
                cells.len(),
                scale.len(),
                flips.len(),
                trace_cells.len(),
                churn_cells.len(),
                serve_cells.len(),
            );
            if args.has("check-wakeup") {
                specsim::util::bench::check_wakeup_gate(&cells)?;
                println!("wakeup gate passed: (naive, light, M=4000) skips >= 50% at >= 2x");
            }
            if args.has("check-scale") {
                specsim::util::bench::check_scale_gate(&scale)?;
                println!("scale gate passed: calendar >= heap on (naive, light, M=1e5)");
            }
            if args.has("check-serve") {
                specsim::util::bench::check_serve_gate(&serve_cells)?;
                println!("serve gate passed: 2-shard throughput >= 1.4x 1-shard");
            }
        }
        "trace" => {
            let out = PathBuf::from(args.str("out").ok_or("trace: --out FILE required")?);
            let wl_cfg = build_workload(&args, args.f64("lambda", 6.0)?)?;
            let seed = args.u64("seed", 1)?;
            let jobs = args.u64("jobs", 0)?;
            if jobs > 0 {
                // streaming synthesis: pull one job at a time from the
                // generator source and write it straight through a buffered
                // writer — the trace never materializes in memory, so the
                // CI's million-job input costs O(1) resident
                use specsim::workload::JobSource;
                use std::io::Write as _;
                let horizon = args.f64("horizon", f64::INFINITY)?;
                let mut src = specsim::workload::GeneratorSource::new(&wl_cfg, horizon, seed)?;
                let file = std::fs::File::create(&out).map_err(|e| e.to_string())?;
                let mut w = std::io::BufWriter::new(file);
                w.write_all(trace::HEADER.as_bytes()).map_err(|e| e.to_string())?;
                w.write_all(b"\n").map_err(|e| e.to_string())?;
                let mut row = String::new();
                let mut n = 0u64;
                while n < jobs {
                    match src.next_arrival() {
                        Some(Ok(job)) => {
                            row.clear();
                            trace::format_row(&job.spec, &job.durations, &mut row);
                            w.write_all(row.as_bytes()).map_err(|e| e.to_string())?;
                            n += 1;
                        }
                        Some(Err(e)) => return Err(e.to_string()),
                        None => break,
                    }
                }
                w.flush().map_err(|e| e.to_string())?;
                println!("wrote {n} jobs to {} (streaming)", out.display());
            } else {
                let wl = specsim::cluster::generator::generate(
                    &wl_cfg,
                    args.f64("horizon", 100.0)?,
                    seed,
                );
                trace::save(&wl, &out)?;
                println!("wrote {} jobs to {}", wl.specs.len(), out.display());
            }
        }
        "replay" => {
            use specsim::workload::{TraceFormat, TraceReader};
            let path = args.str("trace").ok_or("replay: --trace FILE required")?;
            let format: TraceFormat = args.string("trace-format", "auto").parse()?;
            let speedup = args.f64("speedup", 1.0)?;
            if !(speedup > 0.0) {
                return Err("--speedup must be > 0".to_string());
            }
            let afap = args.has("as-fast-as-possible");
            let batch_size = args.usize("batch", 256)?.max(1);
            let mut cfg = SimConfig::default();
            cfg.machines = args.usize("machines", 200)?;
            cfg.horizon = f64::INFINITY;
            cfg.scheduler = policy_arg(&args, "sda").parse()?;
            cfg.artifacts_dir = args.string("artifacts-dir", "artifacts");
            apply_scenario_flags(&mut cfg, &args)?;
            cfg.validate()?;
            let mut serve_cfg = ServeConfig::default();
            serve_cfg.shards = args.usize("shards", 1)?;
            serve_cfg.route = args.string("route", "hash").parse::<RoutePolicy>()?;
            serve_cfg.route_seed = args.u64("route-seed", serve_cfg.route_seed)?;
            serve_cfg.validate(cfg.machines)?;
            // scripted churn: validate the schedule against the deployment
            // size up-front so a bad file fails before any thread spawns
            let machine_events = match args.str("machine-events") {
                Some(p) => {
                    let events = specsim::workload::read_machine_events(p)?;
                    if let Some(max) = specsim::workload::max_machine(&events) {
                        if max as usize >= cfg.machines {
                            return Err(format!(
                                "--machine-events {p}: machine {max} out of range \
                                 (--machines {})",
                                cfg.machines
                            ));
                        }
                    }
                    println!(
                        "machine-events: replaying {} scripted churn events from {p}",
                        events.len()
                    );
                    events
                }
                None => Vec::new(),
            };
            let mut sharded = ShardedMaster::new(cfg, serve_cfg);
            sharded.machine_events = machine_events;
            apply_supervisor_flags(&mut sharded, &args)?;
            sharded.sample_every =
                Some(Duration::from_millis(args.u64("sample-ms", 250)?.max(1)));
            let handle = sharded.spawn()?;
            // Pump the trace through the serve plane in batches.  Pacing is
            // drift-free: each batch's wall-clock target is its first
            // recorded arrival (relative to the trace's first job) divided
            // by --speedup, measured from replay start.
            let reader = TraceReader::open(path, format).map_err(|e| e.to_string())?;
            let start = std::time::Instant::now();
            let mut first_arrival = f64::NAN;
            let mut batch: Vec<Submission> = Vec::with_capacity(batch_size);
            let mut batch_arrival = 0.0f64;
            let mut submitted = 0u64;
            let mut accepted = 0u64;
            for row in reader {
                let row = row.map_err(|e| e.to_string())?;
                if submitted == 0 {
                    first_arrival = row.spec.arrival;
                }
                if batch.is_empty() {
                    batch_arrival = row.spec.arrival;
                }
                batch.push(Submission {
                    num_tasks: row.spec.num_tasks,
                    mean_duration: row.spec.dist.mean(),
                    alpha: row.spec.dist.alpha,
                });
                submitted += 1;
                if batch.len() >= batch_size {
                    let wait =
                        pacing_wait(afap, batch_arrival, first_arrival, speedup, start);
                    accepted += replay_flush(&handle, &mut batch, wait)?;
                }
            }
            let wait = pacing_wait(afap, batch_arrival, first_arrival, speedup, start);
            accepted += replay_flush(&handle, &mut batch, wait)?;
            let wall = start.elapsed().as_secs_f64();
            let rep = handle.shutdown()?;
            println!(
                "replayed {submitted} jobs in {wall:.2}s wall across {} shard(s), \
                 accepted {accepted}, completed {}, rejected {}",
                rep.shards.len(),
                rep.completed(),
                rep.rejected(),
            );
            print!("{}", rep.table());
            if let Some(series) = &rep.series {
                if let Some(path) = args.str("serve-csv") {
                    report::write_file(path, &series.csv()).map_err(|e| e.to_string())?;
                    println!("wrote the metrics time series to {path}");
                }
            }
        }
        "serve" => {
            let mut cfg = SimConfig::default();
            cfg.machines = args.usize("machines", 200)?;
            cfg.horizon = f64::INFINITY;
            cfg.scheduler = policy_arg(&args, "sda").parse()?;
            cfg.artifacts_dir = args.string("artifacts-dir", "artifacts");
            apply_scenario_flags(&mut cfg, &args)?;
            cfg.validate()?;
            let rate = args.f64("rate", 50.0)?;
            let jobs = args.u64("jobs", 500)?;
            let mut serve_cfg = ServeConfig::default();
            serve_cfg.shards = args.usize("shards", 1)?;
            serve_cfg.route = args.string("route", "hash").parse::<RoutePolicy>()?;
            serve_cfg.route_seed = args.u64("route-seed", serve_cfg.route_seed)?;
            serve_cfg.validate(cfg.machines)?;
            let mut sharded = ShardedMaster::new(cfg, serve_cfg);
            apply_supervisor_flags(&mut sharded, &args)?;
            sharded.sample_every = Some(Duration::from_millis(args.u64("sample-ms", 250)?.max(1)));
            let handle = sharded.spawn()?;
            let mut rng = Pcg64::new(42, 0);
            let mut accepted = 0u64;
            for _ in 0..jobs {
                std::thread::sleep(Duration::from_secs_f64(rng.exponential(rate)));
                let sub = Submission {
                    num_tasks: rng.uniform_u64(1, 100) as u32,
                    mean_duration: rng.uniform_f64(1.0, 4.0),
                    alpha: 2.0,
                };
                let (_shard, result) = handle.submit(sub)?;
                if result.is_accepted() {
                    accepted += 1;
                }
            }
            let rep = handle.shutdown()?;
            println!(
                "submitted {jobs} across {} shard(s) ({} routing), accepted \
                 {accepted}, completed {}, rejected {}",
                rep.shards.len(),
                serve_cfg.route,
                rep.completed(),
                rep.rejected(),
            );
            let n_done: usize = rep.shards.iter().map(|r| r.completed.len()).sum();
            let mean_flow = rep
                .shards
                .iter()
                .flat_map(|r| r.completed.iter())
                .map(|r| r.flowtime)
                .sum::<f64>()
                / n_done.max(1) as f64;
            println!("mean flowtime (virtual units): {mean_flow:.3}");
            print!("{}", rep.table());
            if let Some(series) = &rep.series {
                if let Some(path) = args.str("serve-csv") {
                    report::write_file(path, &series.csv()).map_err(|e| e.to_string())?;
                    println!("wrote the metrics time series to {path}");
                }
                let agg = series.aggregate_latest();
                println!("--- aggregate metrics (latest sample per shard) ---");
                for (name, v) in &agg.counters {
                    println!("{name:<24} {v}");
                }
                for (name, v) in &agg.gauges {
                    println!("{name:<24} {v}");
                }
            }
        }
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => return Err(format!("unknown command '{other}'\n\n{USAGE}")),
    }
    Ok(())
}
