//! Chunked, zero-dependency trace reader.
//!
//! [`TraceReader`] pulls fixed-size chunks (64 KiB) from any [`Read`]
//! source, splits them into physical lines across chunk boundaries, and
//! parses each line into a [`TraceRow`] — a [`JobSpec`] plus its
//! pre-sampled first-copy durations.  Memory is bounded by the longest
//! single line, never by the trace length.
//!
//! Three on-disk formats are supported, autodetected from the first line
//! (see [`TraceFormat`]):
//!
//! | format   | shape                                              |
//! |----------|----------------------------------------------------|
//! | `native` | `job,arrival,mu,alpha,num_tasks,durations` header, then one CSV row per job with `;`-joined durations |
//! | `simple` | Google/Alibaba-style `arrival,duration,tasks[,alpha]` CSV (optional header) |
//! | `jsonl`  | one JSON object per line: `{"arrival":…,"duration":…,"tasks":…[,"alpha":…]}` |
//!
//! `simple` and `jsonl` rows carry one duration per job; the reader expands
//! it to all `tasks` copies and derives the Pareto parameters via
//! [`Pareto::from_mean`] (default tail index α = 2, the paper's baseline).
//! Every failure is a structured [`TraceError`] with path, 1-based line,
//! and the 1-based byte column of the offending field.

use std::fs::File;
use std::io::Read;
use std::path::Path;

use crate::cluster::job::{JobId, JobSpec};
use crate::cluster::trace::HEADER;
use crate::stats::Pareto;
use crate::util::Json;

use super::error::TraceError;

/// Chunk size for buffered reads.  A single row larger than this (e.g. a
/// wide `durations` field) is handled by growing the carry buffer until its
/// newline arrives.
pub const CHUNK: usize = 64 * 1024;

/// Tail index assumed for `simple`/`jsonl` rows that do not carry one.
pub const DEFAULT_ALPHA: f64 = 2.0;

/// On-disk trace format selector.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceFormat {
    /// Sniff the first line: the native header, a `{`-opening JSON object,
    /// or an `arrival,duration,tasks[,alpha]` header.
    #[default]
    Auto,
    /// The crate's own `trace::to_string` format (exact durations).
    Native,
    /// `arrival,duration,tasks[,alpha]` CSV; the header line is optional.
    Simple,
    /// One JSON object per line.
    Jsonl,
}

impl TraceFormat {
    /// Stable lowercase name (CLI value / `Display`).
    pub fn name(self) -> &'static str {
        match self {
            TraceFormat::Auto => "auto",
            TraceFormat::Native => "native",
            TraceFormat::Simple => "simple",
            TraceFormat::Jsonl => "jsonl",
        }
    }
}

impl std::fmt::Display for TraceFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for TraceFormat {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(TraceFormat::Auto),
            "native" => Ok(TraceFormat::Native),
            "simple" => Ok(TraceFormat::Simple),
            "jsonl" => Ok(TraceFormat::Jsonl),
            other => Err(format!("unknown trace format {other:?} (auto|native|simple|jsonl)")),
        }
    }
}

/// One parsed trace row: the job spec, its first-copy durations
/// (`spec.num_tasks` entries), and the physical line it came from.
#[derive(Clone, Debug)]
pub struct TraceRow {
    pub spec: JobSpec,
    pub durations: Vec<f64>,
    pub line: u64,
}

/// Streaming trace parser over any [`Read`] source.
///
/// Iterator of `Result<TraceRow, TraceError>`; fuses after the first error
/// (subsequent `next()` calls return `None`).  Job ids are dense: `native`
/// rows must carry `0, 1, 2, …` and the other formats assign them.
pub struct TraceReader<R: Read> {
    src: R,
    path: String,
    requested: TraceFormat,
    resolved: Option<TraceFormat>,
    buf: Vec<u8>,
    start: usize,
    eof: bool,
    line: u64,
    next_id: u32,
    started: bool,
    failed: bool,
}

impl TraceReader<File> {
    /// Open a trace file for streaming.
    pub fn open(path: impl AsRef<Path>, format: TraceFormat) -> Result<Self, TraceError> {
        let p = path.as_ref();
        let display = p.display().to_string();
        let file = File::open(p)
            .map_err(|e| TraceError::Io { path: display.clone(), message: e.to_string() })?;
        Ok(TraceReader::new(file, display, format))
    }
}

impl<R: Read> TraceReader<R> {
    /// Wrap an arbitrary byte source.  `path` labels error messages only.
    pub fn new(src: R, path: impl Into<String>, format: TraceFormat) -> Self {
        TraceReader {
            src,
            path: path.into(),
            requested: format,
            resolved: None,
            buf: Vec::new(),
            start: 0,
            eof: false,
            line: 0,
            next_id: 0,
            started: false,
            failed: false,
        }
    }

    /// The path label used in diagnostics.
    pub fn path(&self) -> &str {
        &self.path
    }

    /// The format actually in effect: the requested one, or the sniffed
    /// result once the first line has been read under [`TraceFormat::Auto`].
    pub fn format(&self) -> TraceFormat {
        self.resolved.unwrap_or(self.requested)
    }

    fn io_err(&self, e: std::io::Error) -> TraceError {
        TraceError::Io { path: self.path.clone(), message: e.to_string() }
    }

    /// Pull one more chunk into the carry buffer, compacting consumed bytes
    /// first so resident memory stays proportional to the longest line.
    fn fill(&mut self) -> Result<(), TraceError> {
        if self.start > 0 {
            self.buf.drain(..self.start);
            self.start = 0;
        }
        let old = self.buf.len();
        self.buf.resize(old + CHUNK, 0);
        let n = self.src.read(&mut self.buf[old..]).map_err(|e| self.io_err(e))?;
        self.buf.truncate(old + n);
        if n == 0 {
            self.eof = true;
        }
        Ok(())
    }

    fn take_line(&mut self, end: usize, consume: usize) -> Result<String, TraceError> {
        self.line += 1;
        let mut bytes = &self.buf[self.start..end];
        if bytes.last() == Some(&b'\r') {
            bytes = &bytes[..bytes.len() - 1];
        }
        let text = std::str::from_utf8(bytes)
            .map_err(|e| TraceError::Parse {
                path: self.path.clone(),
                line: self.line,
                column: e.valid_up_to() as u32 + 1,
                message: "invalid UTF-8".to_string(),
            })?
            .to_string();
        self.start = consume;
        Ok(text)
    }

    /// Next physical line with the terminator (LF or CRLF) stripped; a
    /// truncated final line (no trailing newline) is still returned.
    fn next_line(&mut self) -> Result<Option<String>, TraceError> {
        loop {
            if let Some(pos) = self.buf[self.start..].iter().position(|&b| b == b'\n') {
                let end = self.start + pos;
                return self.take_line(end, end + 1).map(Some);
            }
            if self.eof {
                if self.start >= self.buf.len() {
                    return Ok(None);
                }
                let end = self.buf.len();
                return self.take_line(end, end).map(Some);
            }
            self.fill()?;
        }
    }

    /// Consume the header (when the format has one) and fix `resolved`.
    /// Returns the first *data* line, if any arrived in the process.
    fn resolve(&mut self) -> Result<Option<String>, TraceError> {
        let Some(first) = self.next_line()? else {
            return Err(TraceError::Empty { path: self.path.clone() });
        };
        match self.requested {
            TraceFormat::Auto => {
                if first.trim() == HEADER {
                    self.resolved = Some(TraceFormat::Native);
                    Ok(None)
                } else if first.trim_start().starts_with('{') {
                    self.resolved = Some(TraceFormat::Jsonl);
                    Ok(Some(first))
                } else if is_simple_header(&first) {
                    self.resolved = Some(TraceFormat::Simple);
                    Ok(None)
                } else {
                    Err(TraceError::BadHeader { path: self.path.clone(), found: Some(first) })
                }
            }
            TraceFormat::Native => {
                if first.trim() == HEADER {
                    self.resolved = Some(TraceFormat::Native);
                    Ok(None)
                } else {
                    Err(TraceError::BadHeader { path: self.path.clone(), found: Some(first) })
                }
            }
            TraceFormat::Simple => {
                self.resolved = Some(TraceFormat::Simple);
                if is_simple_header(&first) { Ok(None) } else { Ok(Some(first)) }
            }
            TraceFormat::Jsonl => {
                self.resolved = Some(TraceFormat::Jsonl);
                Ok(Some(first))
            }
        }
    }

    fn advance(&mut self) -> Result<Option<TraceRow>, TraceError> {
        let mut pending: Option<String> = None;
        if !self.started {
            self.started = true;
            pending = self.resolve()?;
        }
        loop {
            let line = match pending.take() {
                Some(l) => l,
                None => match self.next_line()? {
                    Some(l) => l,
                    None => return Ok(None),
                },
            };
            if line.trim().is_empty() {
                continue;
            }
            let lineno = self.line;
            let row = match self.resolved.expect("format resolved before data rows") {
                TraceFormat::Native => self.parse_native(&line, lineno)?,
                TraceFormat::Simple => self.parse_simple(&line, lineno)?,
                TraceFormat::Jsonl => self.parse_jsonl(&line, lineno)?,
                TraceFormat::Auto => unreachable!("Auto is resolved on the first line"),
            };
            self.next_id += 1;
            return Ok(Some(row));
        }
    }

    fn parse_err(&self, line: u64, column: usize, message: String) -> TraceError {
        TraceError::Parse { path: self.path.clone(), line, column: column as u32, message }
    }

    /// `job,arrival,mu,alpha,num_tasks,dur;dur;…` — the exact row shape
    /// `trace::to_string` writes.
    fn parse_native(&self, line: &str, lineno: u64) -> Result<TraceRow, TraceError> {
        let mut fields: Vec<(usize, &str)> = Vec::with_capacity(6);
        let mut rest = line;
        let mut off = 0usize;
        for _ in 0..5 {
            match rest.find(',') {
                Some(i) => {
                    fields.push((off, &rest[..i]));
                    off += i + 1;
                    rest = &rest[i + 1..];
                }
                None => break,
            }
        }
        fields.push((off, rest));
        if fields.len() != 6 {
            return Err(self.parse_err(lineno, 1, "expected 6 fields".to_string()));
        }
        let num = |&(col, text): &(usize, &str), what: &str| -> Result<f64, TraceError> {
            text.parse::<f64>()
                .map_err(|e| self.parse_err(lineno, col + 1, format!("{what}: {e}")))
        };
        let id: u32 = fields[0]
            .1
            .parse()
            .map_err(|e| self.parse_err(lineno, fields[0].0 + 1, format!("job: {e}")))?;
        if id != self.next_id {
            return Err(self.parse_err(
                lineno,
                fields[0].0 + 1,
                format!("non-dense job id {id} (expected {})", self.next_id),
            ));
        }
        let arrival = num(&fields[1], "arrival")?;
        let mu = num(&fields[2], "mu")?;
        let alpha = num(&fields[3], "alpha")?;
        if !(mu > 0.0) {
            return Err(self.parse_err(lineno, fields[2].0 + 1, format!("mu must be > 0, got {mu}")));
        }
        if !(alpha > 1.0) {
            return Err(self.parse_err(
                lineno,
                fields[3].0 + 1,
                format!("alpha must be > 1, got {alpha}"),
            ));
        }
        let num_tasks: u32 = fields[4]
            .1
            .parse()
            .map_err(|e| self.parse_err(lineno, fields[4].0 + 1, format!("num_tasks: {e}")))?;
        let (dcol, dfield) = fields[5];
        let mut durations = Vec::with_capacity(num_tasks as usize);
        let mut doff = dcol;
        for part in dfield.split(';') {
            let d: f64 = part
                .parse()
                .map_err(|e| self.parse_err(lineno, doff + 1, format!("duration: {e}")))?;
            durations.push(d);
            doff += part.len() + 1;
        }
        if durations.len() != num_tasks as usize {
            return Err(self.parse_err(
                lineno,
                dcol + 1,
                format!("{} durations for {} tasks", durations.len(), num_tasks),
            ));
        }
        let spec = JobSpec {
            id: JobId(id),
            arrival,
            dist: Pareto::new(mu, alpha),
            num_tasks,
        };
        Ok(TraceRow { spec, durations, line: lineno })
    }

    /// `arrival,duration,tasks[,alpha]` — duration is the per-task mean;
    /// the row expands to `tasks` identical first-copy durations.
    fn parse_simple(&self, line: &str, lineno: u64) -> Result<TraceRow, TraceError> {
        let mut fields: Vec<(usize, &str)> = Vec::with_capacity(4);
        let mut off = 0usize;
        for part in line.split(',') {
            fields.push((off, part.trim()));
            off += part.len() + 1;
        }
        if !(3..=4).contains(&fields.len()) {
            return Err(self.parse_err(
                lineno,
                1,
                format!("expected 3 or 4 fields (arrival,duration,tasks[,alpha]), got {}", fields.len()),
            ));
        }
        let arrival: f64 = fields[0]
            .1
            .parse()
            .map_err(|e| self.parse_err(lineno, fields[0].0 + 1, format!("arrival: {e}")))?;
        let duration: f64 = fields[1]
            .1
            .parse()
            .map_err(|e| self.parse_err(lineno, fields[1].0 + 1, format!("duration: {e}")))?;
        let tasks: u32 = fields[2]
            .1
            .parse()
            .map_err(|e| self.parse_err(lineno, fields[2].0 + 1, format!("tasks: {e}")))?;
        let alpha = match fields.get(3) {
            None => DEFAULT_ALPHA,
            Some(&(col, text)) => text
                .parse::<f64>()
                .map_err(|e| self.parse_err(lineno, col + 1, format!("alpha: {e}")))?,
        };
        self.build_mean_row(lineno, arrival, duration, tasks, alpha, fields[1].0, fields[2].0)
    }

    /// `{"arrival":…,"duration":…,"tasks":…[,"alpha":…]}`.
    fn parse_jsonl(&self, line: &str, lineno: u64) -> Result<TraceRow, TraceError> {
        let v = Json::parse(line).map_err(|m| self.parse_err(lineno, 1, m))?;
        let field = |name: &str| -> Result<f64, TraceError> {
            v.get(name)
                .and_then(Json::as_f64)
                .ok_or_else(|| self.parse_err(lineno, 1, format!("missing numeric {name:?}")))
        };
        let arrival = field("arrival")?;
        let duration = field("duration")?;
        let tasks_f = field("tasks")?;
        if !(tasks_f >= 0.0) || tasks_f.fract() != 0.0 || tasks_f > u32::MAX as f64 {
            return Err(self.parse_err(lineno, 1, format!("tasks must be a non-negative integer, got {tasks_f}")));
        }
        let alpha = match v.get("alpha") {
            None => DEFAULT_ALPHA,
            Some(j) => j
                .as_f64()
                .ok_or_else(|| self.parse_err(lineno, 1, "alpha must be numeric".to_string()))?,
        };
        self.build_mean_row(lineno, arrival, duration, tasks_f as u32, alpha, 1, 1)
    }

    fn build_mean_row(
        &self,
        lineno: u64,
        arrival: f64,
        duration: f64,
        tasks: u32,
        alpha: f64,
        dur_col: usize,
        tasks_col: usize,
    ) -> Result<TraceRow, TraceError> {
        if !(duration > 0.0) {
            return Err(self.parse_err(
                lineno,
                dur_col + 1,
                format!("duration must be > 0, got {duration}"),
            ));
        }
        if tasks == 0 {
            return Err(self.parse_err(lineno, tasks_col + 1, "tasks must be >= 1".to_string()));
        }
        if !(alpha > 1.0) {
            return Err(self.parse_err(lineno, 1, format!("alpha must be > 1, got {alpha}")));
        }
        let spec = JobSpec {
            id: JobId(self.next_id),
            arrival,
            dist: Pareto::from_mean(duration, alpha),
            num_tasks: tasks,
        };
        Ok(TraceRow { spec, durations: vec![duration; tasks as usize], line: lineno })
    }
}

impl<R: Read> Iterator for TraceReader<R> {
    type Item = Result<TraceRow, TraceError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.failed {
            return None;
        }
        match self.advance() {
            Ok(row) => row.map(Ok),
            Err(e) => {
                self.failed = true;
                Some(Err(e))
            }
        }
    }
}

/// Recognize the `simple` header with whitespace/case slack.
fn is_simple_header(line: &str) -> bool {
    let norm: String =
        line.chars().filter(|c| !c.is_whitespace()).collect::<String>().to_ascii_lowercase();
    norm == "arrival,duration,tasks" || norm == "arrival,duration,tasks,alpha"
}
