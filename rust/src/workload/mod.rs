//! Streaming trace replay: feed million-job datacenter traces to the
//! simulator and the live serve plane without ever materializing the
//! workload.
//!
//! Three layers (DESIGN.md §16):
//!
//! * [`TraceReader`] — a zero-dependency chunked CSV/JSONL reader that
//!   yields one [`TraceRow`] per line, autodetects the on-disk schema
//!   ([`TraceFormat`]), and reports every failure as a structured
//!   [`TraceError`] with path, line, and column.
//! * [`JobSource`] — the pull-based `next_arrival()` interface unifying
//!   materialized workloads ([`MaterializedSource`]), the synthetic
//!   generators ([`GeneratorSource`], bit-identical to
//!   `generator::generate`), and streamed traces ([`StreamSource`]).
//! * [`Lookahead`] — the bounded buffer the simulator pulls arrivals
//!   through, capping resident un-admitted jobs at the configured window.
//!
//! [`scan`] is the single-pass moment pre-pass ([`TraceStats`]) that gives
//! trace workloads real `mean_tasks()`/`mean_duration()` values and the
//! schedulers their tail index, all in bounded memory.
//!
//! [`read_machine_events`] compiles a Google/Alibaba-style machine-events
//! table (`timestamp,machine_id,event{ADD,REMOVE}`) into the deterministic
//! churn schedule `replay --machine-events` injects in place of sampled
//! MTTF/MTTR (DESIGN.md §17).

mod error;
mod machine_events;
mod reader;
mod source;

pub use error::TraceError;
pub use machine_events::{
    max_machine, parse_machine_events, read_machine_events, MachineEvent,
};
pub use reader::{TraceFormat, TraceReader, TraceRow, CHUNK, DEFAULT_ALPHA};
pub use source::{
    scan, source_for, GeneratorSource, JobSource, Lookahead, MaterializedSource, SourcedJob,
    StreamSource, TraceStats, DEFAULT_WINDOW,
};
