//! Structured trace diagnostics.
//!
//! Every failure in the trace pipeline — whole-file loads in
//! [`crate::cluster::trace`], the streaming [`super::TraceReader`], and the
//! [`super::JobSource`] adapters — reports through one enum carrying the
//! file path plus, for parse failures, the 1-based line and column of the
//! offending field.  Both consumption paths therefore produce identical
//! messages for identical input, which the round-trip tests pin.

use std::fmt;

/// A trace read/parse failure with enough position information to open the
/// file at the offending byte.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceError {
    /// The underlying file could not be opened or read.
    Io { path: String, message: String },
    /// The first line is neither a recognized header nor (under
    /// autodetection) a recognizable data row.
    BadHeader { path: String, found: Option<String> },
    /// The file contains no lines at all.
    Empty { path: String },
    /// A data row failed validation.  `line` counts physical lines from 1
    /// (the header, when present, is line 1); `column` is the 1-based byte
    /// offset of the offending field within the line.
    Parse { path: String, line: u64, column: u32, message: String },
}

impl TraceError {
    /// The path of the trace the error was raised for.
    pub fn path(&self) -> &str {
        match self {
            TraceError::Io { path, .. }
            | TraceError::BadHeader { path, .. }
            | TraceError::Empty { path }
            | TraceError::Parse { path, .. } => path,
        }
    }

    /// The 1-based physical line number, when the error is positional.
    pub fn line(&self) -> Option<u64> {
        match self {
            TraceError::Parse { line, .. } => Some(*line),
            _ => None,
        }
    }
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TraceError::Io { path, message } => write!(f, "{path}: {message}"),
            TraceError::BadHeader { path, found } => {
                write!(f, "{path}: bad header: {found:?}")
            }
            TraceError::Empty { path } => write!(f, "{path}: empty trace"),
            TraceError::Parse { path, line, column, message } => {
                write!(f, "{path}: line {line}, column {column}: {message}")
            }
        }
    }
}

impl std::error::Error for TraceError {}

impl From<TraceError> for String {
    fn from(e: TraceError) -> String {
        e.to_string()
    }
}
