//! Google/Alibaba-style machine-events table: one `timestamp,machine_id,
//! event` row per cluster membership change, with `event` ∈ {`ADD`,
//! `REMOVE`} (case-insensitive).  The table compiles to a deterministic
//! churn schedule — sorted by timestamp, input order breaking ties — that
//! replays in place of sampled MTTF/MTTR via
//! `Cluster::inject_machine_event` (`replay --machine-events FILE`).
//!
//! Semantics match the sampled churn process (DESIGN.md §17): `REMOVE`
//! crashes the machine (resident copies lost, restart from zero), `ADD`
//! returns it to the allocatable pool.  Redundant events — `REMOVE` while
//! already down, `ADD` while already up — are no-ops, exactly as the
//! public traces contain them.  Every parse failure is a structured
//! [`TraceError`] with path, 1-based line, and 1-based byte column.

use std::fs;
use std::path::Path;

use super::error::TraceError;

/// One compiled machine membership change.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MachineEvent {
    /// Simulation time of the change (seconds, >= 0).
    pub time: f64,
    /// Machine id in `0..machines`.
    pub machine: u32,
    /// True for `REMOVE` (crash), false for `ADD` (recover/join).
    pub fail: bool,
}

/// Read and compile a machine-events file.
pub fn read_machine_events(path: impl AsRef<Path>) -> Result<Vec<MachineEvent>, TraceError> {
    let p = path.as_ref();
    let label = p.display().to_string();
    let text = fs::read_to_string(p)
        .map_err(|e| TraceError::Io { path: label.clone(), message: e.to_string() })?;
    parse_machine_events(&text, label)
}

/// Parse a machine-events table from text.  The header line
/// `timestamp,machine_id,event` is optional (matched with whitespace/case
/// slack); blank lines are skipped; the result is stably sorted by
/// timestamp so equal-time events fire in input order.
pub fn parse_machine_events(
    text: &str,
    path: impl Into<String>,
) -> Result<Vec<MachineEvent>, TraceError> {
    let path = path.into();
    let mut events = Vec::new();
    let mut saw_line = false;
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx as u64 + 1;
        let line = raw.trim_end_matches('\r');
        if line.trim().is_empty() {
            continue;
        }
        if !saw_line {
            saw_line = true;
            if is_header(line) {
                continue;
            }
        }
        events.push(parse_row(line, &path, lineno)?);
    }
    if !saw_line {
        return Err(TraceError::Empty { path });
    }
    // stable: equal timestamps keep input order, so the compiled schedule
    // is deterministic regardless of how the source interleaved machines
    events.sort_by(|a, b| a.time.partial_cmp(&b.time).expect("timestamps are finite"));
    Ok(events)
}

/// Highest machine id referenced, for validating against a cluster size.
pub fn max_machine(events: &[MachineEvent]) -> Option<u32> {
    events.iter().map(|e| e.machine).max()
}

fn is_header(line: &str) -> bool {
    let norm: String =
        line.chars().filter(|c| !c.is_whitespace()).collect::<String>().to_ascii_lowercase();
    norm == "timestamp,machine_id,event"
}

fn parse_row(line: &str, path: &str, lineno: u64) -> Result<MachineEvent, TraceError> {
    let err = |column: usize, message: String| TraceError::Parse {
        path: path.to_string(),
        line: lineno,
        column: column as u32 + 1,
        message,
    };
    let mut fields: Vec<(usize, &str)> = Vec::with_capacity(3);
    let mut off = 0usize;
    for part in line.split(',') {
        fields.push((off, part.trim()));
        off += part.len() + 1;
    }
    if fields.len() != 3 {
        return Err(err(
            0,
            format!("expected 3 fields (timestamp,machine_id,event), got {}", fields.len()),
        ));
    }
    let time: f64 = fields[0]
        .1
        .parse()
        .map_err(|e| err(fields[0].0, format!("timestamp: {e}")))?;
    if !(time >= 0.0) || !time.is_finite() {
        return Err(err(fields[0].0, format!("timestamp must be finite and >= 0, got {time}")));
    }
    let machine: u32 = fields[1]
        .1
        .parse()
        .map_err(|e| err(fields[1].0, format!("machine_id: {e}")))?;
    let fail = match fields[2].1.to_ascii_uppercase().as_str() {
        "REMOVE" => true,
        "ADD" => false,
        other => return Err(err(fields[2].0, format!("event must be ADD or REMOVE, got {other:?}"))),
    };
    Ok(MachineEvent { time, machine, fail })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sorts_and_keeps_tie_order() {
        let text = "timestamp,machine_id,event\n\
                    5.0,2,REMOVE\n\
                    \n\
                    1.5,0,remove\n\
                    5.0,1,Add\n\
                    2.5,0,ADD\n";
        let ev = parse_machine_events(text, "t.csv").unwrap();
        assert_eq!(
            ev,
            vec![
                MachineEvent { time: 1.5, machine: 0, fail: true },
                MachineEvent { time: 2.5, machine: 0, fail: false },
                MachineEvent { time: 5.0, machine: 2, fail: true },
                MachineEvent { time: 5.0, machine: 1, fail: false },
            ],
            "sorted by time, equal times in input order, tokens case-insensitive"
        );
        assert_eq!(max_machine(&ev), Some(2));
    }

    #[test]
    fn header_is_optional() {
        let ev = parse_machine_events("3.0,4,REMOVE\n", "t.csv").unwrap();
        assert_eq!(ev, vec![MachineEvent { time: 3.0, machine: 4, fail: true }]);
        assert_eq!(max_machine(&[]), None);
    }

    #[test]
    fn errors_carry_position() {
        let e = parse_machine_events("timestamp,machine_id,event\n1.0,3,EVICT\n", "m.csv")
            .unwrap_err();
        match e {
            TraceError::Parse { path, line, column, message } => {
                assert_eq!(path, "m.csv");
                assert_eq!(line, 2);
                assert_eq!(column, 7, "column points at the event field");
                assert!(message.contains("EVICT"));
            }
            other => panic!("unexpected {other:?}"),
        }
        let e = parse_machine_events("-1.0,3,ADD\n", "m.csv").unwrap_err();
        assert!(e.to_string().contains("timestamp must be finite"));
        let e = parse_machine_events("1.0,3\n", "m.csv").unwrap_err();
        assert!(e.to_string().contains("expected 3 fields"));
        let e = parse_machine_events("", "m.csv").unwrap_err();
        assert_eq!(e, TraceError::Empty { path: "m.csv".to_string() });
    }
}
