//! Pull-based workload sources.
//!
//! [`JobSource`] unifies the three ways a workload reaches the simulator —
//! pre-materialized [`Workload`]s, the synthetic generators, and streamed
//! trace files — behind one `next_arrival()` interface that yields jobs in
//! arrival order.  [`Lookahead`] wraps any source in a bounded buffer so
//! the simulator never holds more than `window` un-admitted jobs, and
//! [`scan`] runs the single streaming pre-pass that derives workload
//! moments (job count, task/duration means, tail index) without
//! materializing anything.
//!
//! [`GeneratorSource`] replays the exact RNG draw sequence of
//! [`crate::cluster::generator::generate`] — same seed streams, same draw
//! order — so pulling a generated workload one job at a time is
//! bit-identical to materializing it up front.

use std::collections::VecDeque;
use std::fs::File;

use crate::cluster::generator::Mmpp;
use crate::cluster::job::{JobId, JobSpec};
use crate::cluster::sim::Workload;
use crate::config::WorkloadConfig;
use crate::stats::{Pareto, Pcg64, Summary};

use super::error::TraceError;
use super::reader::{TraceFormat, TraceReader};

/// Default lookahead window (max un-admitted jobs resident in a streaming
/// run).
pub const DEFAULT_WINDOW: usize = 1024;

/// One job as delivered by a source: the spec plus its pre-sampled
/// first-copy durations (`spec.num_tasks` entries).
#[derive(Clone, Debug)]
pub struct SourcedJob {
    pub spec: JobSpec,
    pub durations: Vec<f64>,
}

/// A pull-based stream of jobs in non-decreasing arrival order with dense
/// ids `0, 1, 2, …`.  `None` means the source is exhausted; an `Err` is
/// terminal (implementations fuse after it).
pub trait JobSource {
    fn next_arrival(&mut self) -> Option<Result<SourcedJob, TraceError>>;
}

/// Drains a fully-materialized [`Workload`].
pub struct MaterializedSource {
    specs: std::vec::IntoIter<JobSpec>,
    durations: std::vec::IntoIter<Vec<f64>>,
}

impl MaterializedSource {
    pub fn new(wl: Workload) -> Self {
        MaterializedSource {
            specs: wl.specs.into_iter(),
            durations: wl.first_durations.into_iter(),
        }
    }
}

impl JobSource for MaterializedSource {
    fn next_arrival(&mut self) -> Option<Result<SourcedJob, TraceError>> {
        let spec = self.specs.next()?;
        let durations = self.durations.next().unwrap_or_default();
        Some(Ok(SourcedJob { spec, durations }))
    }
}

/// Streams a trace file through [`TraceReader`], enforcing the
/// non-decreasing-arrival contract replay depends on.
pub struct StreamSource {
    reader: TraceReader<File>,
    last_arrival: f64,
    yielded: u64,
    max_jobs: Option<u64>,
}

impl StreamSource {
    pub fn open(
        path: &str,
        format: TraceFormat,
        max_jobs: Option<u64>,
    ) -> Result<Self, TraceError> {
        Ok(StreamSource {
            reader: TraceReader::open(path, format)?,
            last_arrival: f64::NEG_INFINITY,
            yielded: 0,
            max_jobs,
        })
    }
}

impl JobSource for StreamSource {
    fn next_arrival(&mut self) -> Option<Result<SourcedJob, TraceError>> {
        if self.max_jobs.is_some_and(|cap| self.yielded >= cap) {
            return None;
        }
        let row = match self.reader.next()? {
            Ok(r) => r,
            Err(e) => return Some(Err(e)),
        };
        if row.spec.arrival < self.last_arrival {
            return Some(Err(TraceError::Parse {
                path: self.reader.path().to_string(),
                line: row.line,
                column: 1,
                message: format!(
                    "arrival {} is before the previous job's {} (streaming replay needs a time-ordered trace)",
                    row.spec.arrival, self.last_arrival
                ),
            }));
        }
        self.last_arrival = row.spec.arrival;
        self.yielded += 1;
        Some(Ok(SourcedJob { spec: row.spec, durations: row.durations }))
    }
}

/// Pull-based form of the synthetic generators.  The per-state RNGs are
/// constructed and advanced in exactly the order `generator::generate`
/// uses, so the emitted job sequence is bit-identical to the materialized
/// workload for the same `(cfg, horizon, seed)`.
pub struct GeneratorSource {
    state: GenState,
}

enum GenState {
    Poisson {
        arr_rng: Pcg64,
        job_rng: Pcg64,
        dur_rng: Pcg64,
        t: f64,
        horizon: f64,
        lambda: f64,
        m_lo: u32,
        m_hi: u32,
        mean_lo: f64,
        mean_hi: f64,
        alpha: f64,
        next_id: u32,
    },
    Bursty {
        arr_rng: Pcg64,
        job_rng: Pcg64,
        dur_rng: Pcg64,
        state_rng: Pcg64,
        t: f64,
        on: bool,
        phase_end: f64,
        horizon: f64,
        mmpp: Mmpp,
        m_lo: u32,
        m_hi: u32,
        mean_lo: f64,
        mean_hi: f64,
        alpha: f64,
        next_id: u32,
    },
    Single { tasks: u32, mean: f64, alpha: f64, seed: u64 },
    Done,
}

impl GeneratorSource {
    /// Build a pull-based generator for any synthetic [`WorkloadConfig`].
    /// Trace configs are not generators; route them to [`StreamSource`].
    pub fn new(cfg: &WorkloadConfig, horizon: f64, seed: u64) -> Result<Self, String> {
        let state = match cfg {
            WorkloadConfig::Poisson { lambda, m_lo, m_hi, mean_lo, mean_hi, alpha } => {
                GenState::Poisson {
                    arr_rng: Pcg64::new(seed, 101),
                    job_rng: Pcg64::new(seed, 202),
                    dur_rng: Pcg64::new(seed, 303),
                    t: 0.0,
                    horizon,
                    lambda: *lambda,
                    m_lo: *m_lo,
                    m_hi: *m_hi,
                    mean_lo: *mean_lo,
                    mean_hi: *mean_hi,
                    alpha: *alpha,
                    next_id: 0,
                }
            }
            WorkloadConfig::Bursty {
                lambda,
                burst,
                on_frac,
                cycle,
                m_lo,
                m_hi,
                mean_lo,
                mean_hi,
                alpha,
            } => {
                let mmpp = Mmpp::from_mean(*lambda, *burst, *on_frac, *cycle);
                let mut state_rng = Pcg64::new(seed, 404);
                let phase_end = state_rng.exponential(1.0 / mmpp.dwell_on);
                GenState::Bursty {
                    arr_rng: Pcg64::new(seed, 101),
                    job_rng: Pcg64::new(seed, 202),
                    dur_rng: Pcg64::new(seed, 303),
                    state_rng,
                    t: 0.0,
                    on: true,
                    phase_end,
                    horizon,
                    mmpp,
                    m_lo: *m_lo,
                    m_hi: *m_hi,
                    mean_lo: *mean_lo,
                    mean_hi: *mean_hi,
                    alpha: *alpha,
                    next_id: 0,
                }
            }
            WorkloadConfig::SingleJob { tasks, mean, alpha } => GenState::Single {
                tasks: *tasks,
                mean: *mean,
                alpha: *alpha,
                seed,
            },
            WorkloadConfig::Trace { path, .. } => {
                return Err(format!(
                    "trace workload '{path}' is not a generator; stream it with StreamSource"
                ));
            }
        };
        Ok(GeneratorSource { state })
    }
}

/// Draw one job at arrival `t` with the generators' shared draw order:
/// task count, mean, then `m` first-copy durations.
#[allow(clippy::too_many_arguments)]
fn draw_job(
    job_rng: &mut Pcg64,
    dur_rng: &mut Pcg64,
    id: u32,
    t: f64,
    m_lo: u32,
    m_hi: u32,
    mean_lo: f64,
    mean_hi: f64,
    alpha: f64,
) -> SourcedJob {
    let m = job_rng.uniform_u64(m_lo as u64, m_hi as u64) as u32;
    let mean = job_rng.uniform_f64(mean_lo, mean_hi);
    let dist = Pareto::from_mean(mean, alpha);
    let durations: Vec<f64> = (0..m).map(|_| dist.sample(dur_rng)).collect();
    SourcedJob {
        spec: JobSpec { id: JobId(id), arrival: t, dist, num_tasks: m },
        durations,
    }
}

impl JobSource for GeneratorSource {
    fn next_arrival(&mut self) -> Option<Result<SourcedJob, TraceError>> {
        match &mut self.state {
            GenState::Poisson {
                arr_rng,
                job_rng,
                dur_rng,
                t,
                horizon,
                lambda,
                m_lo,
                m_hi,
                mean_lo,
                mean_hi,
                alpha,
                next_id,
            } => {
                *t += arr_rng.exponential(*lambda);
                if *t > *horizon {
                    self.state = GenState::Done;
                    return None;
                }
                let job = draw_job(
                    job_rng, dur_rng, *next_id, *t, *m_lo, *m_hi, *mean_lo, *mean_hi, *alpha,
                );
                *next_id += 1;
                Some(Ok(job))
            }
            GenState::Bursty {
                arr_rng,
                job_rng,
                dur_rng,
                state_rng,
                t,
                on,
                phase_end,
                horizon,
                mmpp,
                m_lo,
                m_hi,
                mean_lo,
                mean_hi,
                alpha,
                next_id,
            } => {
                loop {
                    let rate = if *on { mmpp.rate_on } else { mmpp.rate_off };
                    let candidate =
                        if rate > 0.0 { *t + arr_rng.exponential(rate) } else { f64::INFINITY };
                    if candidate > *phase_end {
                        *t = *phase_end;
                        if *t > *horizon {
                            self.state = GenState::Done;
                            return None;
                        }
                        *on = !*on;
                        let dwell = if *on { mmpp.dwell_on } else { mmpp.dwell_off };
                        *phase_end = *t + state_rng.exponential(1.0 / dwell);
                        continue;
                    }
                    *t = candidate;
                    if *t > *horizon {
                        self.state = GenState::Done;
                        return None;
                    }
                    let job = draw_job(
                        job_rng, dur_rng, *next_id, *t, *m_lo, *m_hi, *mean_lo, *mean_hi, *alpha,
                    );
                    *next_id += 1;
                    return Some(Ok(job));
                }
            }
            GenState::Single { tasks, mean, alpha, seed } => {
                let mut dur_rng = Pcg64::new(*seed, 303);
                let dist = Pareto::from_mean(*mean, *alpha);
                let durations: Vec<f64> = (0..*tasks).map(|_| dist.sample(&mut dur_rng)).collect();
                let job = SourcedJob {
                    spec: JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: *tasks },
                    durations,
                };
                self.state = GenState::Done;
                Some(Ok(job))
            }
            GenState::Done => None,
        }
    }
}

/// Build the right source for a workload config: traces stream, everything
/// else generates on demand.
pub fn source_for(
    cfg: &WorkloadConfig,
    horizon: f64,
    seed: u64,
) -> Result<Box<dyn JobSource>, String> {
    match cfg {
        WorkloadConfig::Trace { path, format, max_jobs, .. } => {
            let src = StreamSource::open(path, *format, *max_jobs).map_err(|e| e.to_string())?;
            Ok(Box::new(src))
        }
        other => Ok(Box::new(GeneratorSource::new(other, horizon, seed)?)),
    }
}

/// Bounded lookahead buffer over any [`JobSource`].
///
/// At most `window` un-admitted jobs are resident at once; the buffer
/// refills only when it runs empty, so a streaming run's memory is
/// `O(window + resident jobs)` regardless of trace length.  A source error
/// is held back until every job buffered before it has been drained, then
/// surfaced via [`Lookahead::error`].
pub struct Lookahead {
    src: Box<dyn JobSource>,
    buf: VecDeque<SourcedJob>,
    window: usize,
    err: Option<TraceError>,
    exhausted: bool,
}

impl Lookahead {
    pub fn new(src: Box<dyn JobSource>, window: usize) -> Self {
        Lookahead {
            src,
            buf: VecDeque::new(),
            window: window.max(1),
            err: None,
            exhausted: false,
        }
    }

    fn refill(&mut self) {
        while !self.exhausted && self.err.is_none() && self.buf.len() < self.window {
            match self.src.next_arrival() {
                None => self.exhausted = true,
                Some(Ok(job)) => self.buf.push_back(job),
                Some(Err(e)) => self.err = Some(e),
            }
        }
    }

    /// Arrival time of the next pending job, if any.
    pub fn peek_arrival(&mut self) -> Option<f64> {
        if self.buf.is_empty() {
            self.refill();
        }
        self.buf.front().map(|j| j.spec.arrival)
    }

    /// Take the next pending job.
    pub fn take(&mut self) -> Option<SourcedJob> {
        if self.buf.is_empty() {
            self.refill();
        }
        self.buf.pop_front()
    }

    /// The terminal source error, visible once all jobs buffered before it
    /// have been drained.
    pub fn error(&self) -> Option<&TraceError> {
        if self.buf.is_empty() { self.err.as_ref() } else { None }
    }

    /// Jobs currently resident in the buffer.
    pub fn resident(&self) -> usize {
        self.buf.len()
    }

    /// The configured window.
    pub fn window(&self) -> usize {
        self.window
    }
}

/// Streaming workload moments from one pre-pass over a trace.
#[derive(Clone, Debug)]
pub struct TraceStats {
    /// Total jobs in the trace.
    pub jobs: u64,
    /// Per-job task counts.
    pub tasks: Summary,
    /// Per-job mean task durations (`dist.mean()`).
    pub duration: Summary,
    /// Pareto tail index fitted exactly as
    /// `generator::estimate_alpha` fits it on the materialized workload
    /// (same iteration order, same accumulator ops — bit-identical).
    pub alpha: f64,
    /// Latest arrival time seen.
    pub max_arrival: f64,
}

/// One bounded-memory pass over a trace: job count, task/duration moments,
/// and the MLE tail index.
pub fn scan(path: &str, format: TraceFormat) -> Result<TraceStats, TraceError> {
    let reader = TraceReader::open(path, format)?;
    let mut jobs = 0u64;
    let mut tasks = Summary::new();
    let mut duration = Summary::new();
    let mut max_arrival = 0.0f64;
    let mut log_sum = 0.0f64;
    let mut n = 0u64;
    for row in reader {
        let row = row?;
        jobs += 1;
        tasks.push(row.spec.num_tasks as f64);
        duration.push(row.spec.dist.mean());
        max_arrival = max_arrival.max(row.spec.arrival);
        // the exact accumulation `generator::estimate_alpha` runs on the
        // materialized workload, in the same order
        for &d in &row.durations {
            if row.spec.dist.mu > 0.0 && d > row.spec.dist.mu {
                log_sum += (d / row.spec.dist.mu).ln();
                n += 1;
            }
        }
    }
    let alpha = if n == 0 || log_sum <= 0.0 {
        2.0
    } else {
        (n as f64 / log_sum).clamp(1.1, 10.0)
    };
    Ok(TraceStats { jobs, tasks, duration, alpha, max_arrival })
}
