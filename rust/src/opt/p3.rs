//! The SDA solution of P3 (Sec. V-A): the optimal number of copies once a
//! straggler is detected, c*(sigma) via Eq. 27, and the optimal detection
//! threshold sigma* via Eq. 28.
//!
//! Theorem 3: under Pareto durations c* = 2 (one backup) and sigma* depends
//! only on the heavy-tail order alpha — for alpha = 2 it is 1 + sqrt(2)/2.
//! The solver below computes both *numerically* from the same expectations,
//! so the theorem is continuously re-verified by the test suite (and by a
//! debug assertion at scheduler construction).

use super::pareto_math::{sda_resource, sda_tau};

/// Numerical solution of P3 for one job class.
#[derive(Clone, Copy, Debug)]
pub struct SdaPolicy {
    /// Detection threshold multiplier: straggler iff `t_rem > sigma * E[x]`.
    pub sigma: f64,
    /// Total copies for a detected straggler (incl. the original).
    pub c_star: u32,
    /// Expected per-task resource (unit-mean) at the optimum.
    pub expected_resource: f64,
}

/// c*(sigma) = argmin_c tau(c, sigma) over c in {1..r} (Eq. 27).
pub fn c_star(alpha: f64, s: f64, sigma: f64, r: u32) -> u32 {
    let mut best = 1;
    let mut best_v = f64::INFINITY;
    for c in 1..=r {
        let v = sda_tau(alpha, s, sigma, c as f64);
        if v < best_v {
            best_v = v;
            best = c;
        }
    }
    best
}

/// sigma* = argmin_sigma E[R | c = c*(sigma)] (Eq. 28), grid-searched over
/// (0, 6] with local refinement.
pub fn solve(alpha: f64, s: f64, r: u32) -> SdaPolicy {
    let coarse: Vec<f64> = (1..=120).map(|i| i as f64 * 0.05).collect();
    let eval = |sigma: f64| {
        let c = c_star(alpha, s, sigma, r);
        (sda_resource(alpha, s, sigma, c as f64), c)
    };
    let (mut best_sigma, mut best) = (coarse[0], eval(coarse[0]));
    for &sig in &coarse[1..] {
        let v = eval(sig);
        if v.0 < best.0 {
            best = v;
            best_sigma = sig;
        }
    }
    // local refinement around the coarse optimum
    for k in 1..=20 {
        let step = 0.045 * k as f64 / 20.0;
        for sig in [best_sigma - step, best_sigma + step] {
            if sig > 0.0 {
                let v = eval(sig);
                if v.0 < best.0 {
                    best = v;
                    best_sigma = sig;
                }
            }
        }
    }
    SdaPolicy { sigma: best_sigma, c_star: best.1, expected_resource: best.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem3_alpha2() {
        let pol = solve(2.0, 0.1, 8);
        assert_eq!(pol.c_star, 2, "Theorem 3: one backup copy");
        assert!(
            (pol.sigma - (1.0 + 0.5 * 2.0f64.sqrt())).abs() < 0.08,
            "sigma* = {} vs 1.707",
            pol.sigma
        );
        assert!(pol.expected_resource < 1.0, "speculation saves resource");
    }

    #[test]
    fn sigma_star_independent_of_s() {
        let a = solve(2.0, 0.1, 8);
        let b = solve(2.0, 0.35, 8);
        assert!((a.sigma - b.sigma).abs() < 0.06, "{} vs {}", a.sigma, b.sigma);
    }

    #[test]
    fn sigma_star_grows_with_alpha() {
        let s2 = solve(2.0, 0.1, 8).sigma;
        let s3 = solve(3.0, 0.1, 8).sigma;
        assert!(s3 > s2, "{s3} vs {s2}");
        assert!((1.5..2.3).contains(&s3));
    }

    #[test]
    fn c_star_small_sigma_still_small() {
        // even aggressive thresholds never want more than 2 copies under
        // Pareto (the increasing-tau part of Theorem 3)
        for sigma in [1.1, 1.5, 2.0, 3.0] {
            assert!(c_star(2.0, 0.1, sigma, 8) <= 2);
        }
    }
}
