//! Order-statistic expectations under Pareto task durations — the f64 twin
//! of `python/compile/kernels/ref.py` (same integrals, same log-trapezoid
//! quadrature), used by the pure-rust P2/P3 solvers and unit-tested against
//! closed forms.
//!
//! Normalizations:
//! * `flow_integral(beta, m)`  = E[max of m mins] / mu with beta = alpha*c.
//! * `emin_coeff(beta)`        = E[min of c copies] / mu = beta/(beta-1).
//! * `sda_tau`, `sda_resource` and `ese_resource` are per-task expectations
//!   for a **unit-mean** Pareto (scale by `E[x]` at the call site).

/// Log-spaced trapezoid nodes/weights for `integral_{lo}^{hi} g(u) du`.
pub fn log_trap(lo: f64, hi: f64, n: usize) -> (Vec<f64>, Vec<f64>) {
    let (llo, lhi) = (lo.ln(), hi.ln());
    let dx = (lhi - llo) / (n - 1) as f64;
    let mut u = Vec::with_capacity(n);
    let mut w = Vec::with_capacity(n);
    for i in 0..n {
        let x = llo + dx * i as f64;
        let ui = x.exp();
        let wi = if i == 0 || i == n - 1 { 0.5 * dx } else { dx };
        u.push(ui);
        w.push(wi * ui);
    }
    (u, w)
}

/// `I(beta, m) = 1 + integral_1^inf (1 - (1 - u^-beta)^m) du`:
/// normalized expected job span E[max_{j<=m} min_{k<=c}]/mu, beta = alpha*c.
///
/// Hot path for the P2 solver's table build — the (log u, weight) grid is
/// computed once per process (EXPERIMENTS.md §Perf).
pub fn flow_integral(beta: f64, m: f64) -> f64 {
    use std::sync::OnceLock;
    static GRID: OnceLock<(Vec<f64>, Vec<f64>)> = OnceLock::new();
    let (lnu, w) = GRID.get_or_init(|| {
        let (u, w) = log_trap(1.0, 1.0e7, 1024);
        (u.iter().map(|x| x.ln()).collect(), w)
    });
    debug_assert!(beta > 1.0, "need alpha*c > 1 for a finite mean");
    let mut acc = 1.0;
    for (lui, wi) in lnu.iter().zip(w) {
        // stable 1 - (1-p)^m with p = u^-beta
        let p = (-beta * lui).exp().min(1.0);
        let integrand = -f64::exp_m1(m * f64::ln_1p(-p));
        acc += wi * integrand;
    }
    acc
}

/// E[min of c copies] / mu = beta / (beta - 1), beta = alpha*c.
#[inline]
pub fn emin_coeff(beta: f64) -> f64 {
    beta / (beta - 1.0)
}

/// Unit-mean Pareto survival: S(t) = min(1, (mu/t)^alpha), mu = (a-1)/a.
#[inline]
fn unit_sf(t: f64, alpha: f64) -> f64 {
    let mu = (alpha - 1.0) / alpha;
    if t <= mu {
        1.0
    } else {
        (mu / t).powf(alpha)
    }
}

/// `tau(c, sigma) = E[c * d | straggler detected]` for a unit-mean Pareto
/// (Eq. 26): d = min((1-s) t1, min of c-1 fresh copies) conditioned on
/// (1-s) t1 > sigma.
pub fn sda_tau(alpha: f64, s: f64, sigma: f64, c: f64) -> f64 {
    let mu = (alpha - 1.0) / alpha;
    let big_l = (sigma / (1.0 - s)).max(mu);
    let sf_l = unit_sf(big_l, alpha);
    let (t, w) = log_trap(1.0e-3, 1.0e5, 1024);
    let mut acc = 0.0;
    for (ti, wi) in t.iter().zip(&w) {
        let fresh = unit_sf(*ti, alpha).powf(c - 1.0);
        let orig = unit_sf((ti / (1.0 - s)).max(big_l), alpha) / sf_l;
        acc += wi * fresh * orig;
    }
    c * acc
}

/// Unconditional per-task resource `E[R]` for the SDA model (Eq. 21):
/// R = t1 if no straggler, s*t1 + c*d otherwise.  Unit-mean Pareto.
pub fn sda_resource(alpha: f64, s: f64, sigma: f64, c: f64) -> f64 {
    let mu = (alpha - 1.0) / alpha;
    let big_l = (sigma / (1.0 - s)).max(mu);
    let sf_l = unit_sf(big_l, alpha);
    // E[t1; t1 > L] = L * S(L) * alpha/(alpha-1)
    let e_tail = big_l * sf_l * alpha / (alpha - 1.0);
    let e_head = 1.0 - e_tail;
    s + (1.0 - s) * e_head + sf_l * sda_tau(alpha, s, sigma, c)
}

/// E[min(cap, x_new)] for a unit-mean Pareto = integral_0^cap S.
fn emin_fresh(cap: f64, alpha: f64) -> f64 {
    let mu = (alpha - 1.0) / alpha;
    if cap <= 0.0 {
        return 0.0;
    }
    if cap <= mu {
        return cap;
    }
    mu + mu / (alpha - 1.0) * (1.0 - (mu / cap).powf(alpha - 1.0))
}

/// `E[R](sigma) / E[x]` for the ESE asktime model (Eq. 30-33, Fig. 4).
pub fn ese_resource(alpha: f64, sigma: f64) -> f64 {
    let mu = (alpha - 1.0) / alpha;
    let l1 = sigma.max(mu);
    // term1: E[x; x <= sigma] (0 when sigma < mu)
    let term1 = if sigma >= mu {
        1.0 - l1 * unit_sf(l1, alpha) * alpha / (alpha - 1.0)
    } else {
        0.0
    };
    // term2: for x = t > l1, asktime uniform on [0, t]
    let (t, wt) = log_trap(1.0e-2, 1.0e5, 512);
    let nv = 128usize;
    let dv = 1.0 / (nv - 1) as f64;
    let mut term2 = 0.0;
    for (ti, wti) in t.iter().zip(&wt) {
        if *ti <= l1 {
            continue;
        }
        let span = ti - sigma;
        // inner integral over v in [0,1], x_ask = span * v
        let mut inner = 0.0;
        for k in 0..nv {
            let v = k as f64 * dv;
            let wv = if k == 0 || k == nv - 1 { 0.5 * dv } else { dv };
            let x_ask = span * v;
            let rem = ti - x_ask;
            inner += wv * (x_ask + 2.0 * emin_fresh(rem, alpha));
        }
        let cond = sigma + span / ti * inner;
        let f = alpha * mu.powf(alpha) * ti.powf(-alpha - 1.0);
        term2 += wti * cond * f;
    }
    term1 + term2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{Pareto, Pcg64};

    #[test]
    fn flow_integral_m1_closed_form() {
        for beta in [1.5, 2.0, 4.0, 8.0] {
            let got = flow_integral(beta, 1.0);
            let want = beta / (beta - 1.0);
            assert!((got - want).abs() / want < 1e-3, "beta={beta}: {got} vs {want}");
        }
    }

    #[test]
    fn flow_integral_m2_beta2_exact() {
        // E[max of 2 Pareto(1,2)] = 8/3
        let got = flow_integral(2.0, 2.0);
        assert!((got - 8.0 / 3.0).abs() < 2e-3, "{got}");
    }

    #[test]
    fn flow_integral_monotone() {
        let mut prev = f64::INFINITY;
        for c in [1.0, 2.0, 4.0, 8.0] {
            let v = flow_integral(2.0 * c, 50.0);
            assert!(v < prev);
            prev = v;
        }
        assert!(flow_integral(4.0, 100.0) > flow_integral(4.0, 10.0));
    }

    #[test]
    fn sda_tau_c1_closed_form() {
        let (alpha, s) = (2.0, 0.2);
        for sigma in [0.5f64, 1.0, 2.0] {
            let mu = 0.5f64;
            let l = (sigma / (1.0 - s)).max(mu);
            let want = (1.0 - s) * l * alpha / (alpha - 1.0);
            let got = sda_tau(alpha, s, sigma, 1.0);
            assert!((got - want).abs() / want < 2e-3, "sigma={sigma}: {got} vs {want}");
        }
    }

    #[test]
    fn sda_resource_large_sigma_is_mean() {
        // sigma -> inf: never duplicate, E[R] -> E[x] = 1
        let got = sda_resource(2.0, 0.1, 50.0, 2.0);
        assert!((got - 1.0).abs() < 0.01, "{got}");
    }

    #[test]
    fn theorem3_c_star_2_and_sigma_star() {
        // c = 2 minimizes tau for sigma > 1 (alpha = 2) and the optimal
        // sigma sits near 1 + sqrt(2)/2 = 1.707 independent of s
        for s in [0.1, 0.3] {
            for sigma in [1.2, 1.7, 2.5] {
                let t2 = sda_tau(2.0, s, sigma, 2.0);
                for c in [1.0, 3.0, 4.0, 8.0] {
                    assert!(t2 < sda_tau(2.0, s, sigma, c), "sigma={sigma} c={c}");
                }
            }
            let best = (0..110)
                .map(|i| 0.5 + i as f64 * 0.05)
                .min_by(|a, b| {
                    sda_resource(2.0, s, *a, 2.0)
                        .partial_cmp(&sda_resource(2.0, s, *b, 2.0))
                        .unwrap()
                })
                .unwrap();
            assert!((best - 1.707).abs() < 0.1, "s={s}: sigma*={best}");
        }
    }

    #[test]
    fn ese_resource_matches_monte_carlo() {
        let (alpha, sigma) = (2.0, 1.7);
        let p = Pareto::from_mean(1.0, alpha);
        let mut rng = Pcg64::new(99, 0);
        let n = 400_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let x = p.sample(&mut rng);
            let ask = rng.uniform_f64(0.0, x);
            let r = if x - ask > sigma {
                let t_new = p.sample(&mut rng);
                ask + 2.0 * (x - ask).min(t_new)
            } else {
                x
            };
            acc += r;
        }
        let mc = acc / n as f64;
        let got = ese_resource(alpha, sigma);
        assert!((got - mc).abs() < 0.02, "quad {got} vs mc {mc}");
    }

    #[test]
    fn ese_sigma_star_fig4() {
        // Fig. 4: minimum in [1.5, 2.2]; improvement shrinks with alpha
        let mut gains = Vec::new();
        for alpha in [2.0, 3.0, 4.0, 5.0] {
            let (mut best_s, mut best_v) = (0.0, f64::INFINITY);
            for i in 1..120 {
                let sig = i as f64 * 0.05;
                let v = ese_resource(alpha, sig);
                if v < best_v {
                    best_v = v;
                    best_s = sig;
                }
            }
            assert!((1.5..=2.2).contains(&best_s), "alpha={alpha}: sigma*={best_s}");
            gains.push(1.0 - best_v);
        }
        for w in gains.windows(2) {
            assert!(w[0] > w[1], "gain should shrink with alpha: {gains:?}");
        }
    }

    #[test]
    fn matches_python_oracle_spot_values() {
        // cross-language pin: values computed by compile/kernels/ref.py
        assert!((flow_integral(2.0, 20.0) - 7.9763).abs() < 0.03);
        assert!((flow_integral(4.0, 20.0) - 2.6036).abs() < 0.01);
        assert!((sda_tau(2.0, 0.2, 1.0, 2.0) - 1.6647).abs() < 0.01);
        assert!((ese_resource(2.0, 1.7) - 0.9570).abs() < 0.005);
    }
}
