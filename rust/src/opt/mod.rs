//! The paper's optimization machinery.
//!
//! * [`pareto_math`] — order-statistic expectations under Pareto durations
//!   (the same quadrature the Pallas kernels compute, in f64).
//! * [`gradient`] — the Sec. IV-A gradient-projection solver for P2
//!   (pure-rust twin of the AOT artifact; also the runtime fallback).
//! * [`p2`] — P2 problem assembly, integer rounding + capacity repair.
//! * [`p3`] — the SDA solution: c*(sigma) and sigma* (Eq. 26-28, Thm. 3).
//! * [`ese_sigma`] — the ESE analysis E[R](sigma) (Eq. 30-33) and the
//!   single-job cloning objective of Eq. 29.

pub mod ese_sigma;
pub mod gradient;
pub mod p2;
pub mod p3;
pub mod pareto_math;

pub use gradient::{GradientSolver, P2Problem, P2Solution};
pub use p2::round_and_repair;
