//! Pure-rust gradient-projection solver for P2 (Sec. IV-A) — the exact twin
//! of the AOT-compiled JAX graph (`python/compile/model.py::p2_solve`), used
//! as the runtime fallback and as the cross-check in integration tests.
//!
//! Dual updates (the paper's algorithm, with the capacity step scaled by
//! 1/N to keep the price increment O(eta1)):
//!   c_i    <- argmax_c  A_i(c) - (nu m_i + xi_i - h_i) c       (grid argmax)
//!   nu     <- [nu + eta1/N (sum_i m_i c_i - N)]+
//!   xi_i   <- [xi_i + eta2 (c_i - r)]+
//!   h_i    <- [h_i + eta3 (1 - c_i)]+
//! with A_i(c) = -(mu_i I(alpha c, m_i) + age_i) - gamma m_i c mu_i E_min(c)
//! and primal recovery from the tail-averaged multipliers.

use std::collections::HashMap;

use super::pareto_math::{emin_coeff, flow_integral};

/// The paper's Fig. 1 step sizes.
pub const ETAS: (f64, f64, f64) = (0.2, 0.3, 0.4);

/// One pending job in a P2 batch.
#[derive(Clone, Copy, Debug)]
pub struct P2Job {
    /// Pareto scale of the task-duration distribution.
    pub mu: f64,
    /// Number of tasks m_i.
    pub m: f64,
    /// Current queueing age l - a_i (constant in c; kept for the objective).
    pub age: f64,
}

/// A P2 instance for one scheduling slot.
#[derive(Clone, Debug)]
pub struct P2Problem {
    pub jobs: Vec<P2Job>,
    /// Idle machines N(l).
    pub n_avail: f64,
    pub gamma: f64,
    /// Per-task copy cap r.
    pub r: f64,
    /// Common heavy-tail order.
    pub alpha: f64,
}

/// Solver output: continuous clone counts (round with
/// [`super::p2::round_and_repair`]), the capacity price, and the primal
/// objective value at the recovered point.
#[derive(Clone, Debug)]
pub struct P2Solution {
    pub c: Vec<f64>,
    pub nu: f64,
    pub objective: f64,
    pub iterations: usize,
}

/// Grid-argmax gradient-projection solver.
#[derive(Clone, Debug)]
pub struct GradientSolver {
    /// Candidate clone grid (must start at 1.0).
    pub c_grid: Vec<f64>,
    pub iters: usize,
    /// Cache of the normalized flow integrals I(alpha c_g, m) keyed by
    /// (alpha bits, integer m): the quadrature is the solve's only
    /// expensive step and m is a small integer in practice.
    flow_cache: HashMap<(u64, u32), Vec<f64>>,
}

impl Default for GradientSolver {
    fn default() -> Self {
        // mirror of python/compile/kernels/grids.py: [1, 16], 64 points
        let n = 64;
        let c_grid = (0..n)
            .map(|i| 1.0 + 15.0 * i as f64 / (n - 1) as f64)
            .collect();
        GradientSolver { c_grid, iters: 400, flow_cache: HashMap::new() }
    }
}

impl GradientSolver {
    /// I(alpha c_g, m) over the grid, cached for integral m.
    fn flow_row(&mut self, alpha: f64, m: f64) -> Vec<f64> {
        let mi = m.round();
        let cacheable = (m - mi).abs() < 1e-9 && mi >= 1.0 && mi <= 1e6;
        if cacheable {
            let key = (alpha.to_bits(), mi as u32);
            if let Some(row) = self.flow_cache.get(&key) {
                return row.clone();
            }
            let row: Vec<f64> = self
                .c_grid
                .iter()
                .map(|&c| flow_integral(alpha * c, mi))
                .collect();
            self.flow_cache.insert(key, row.clone());
            row
        } else {
            self.c_grid
                .iter()
                .map(|&c| flow_integral(alpha * c, m.max(1.0)))
                .collect()
        }
    }

    /// Precompute `A[b][g]` for the batch.
    fn table(&mut self, p: &P2Problem) -> Vec<Vec<f64>> {
        let jobs = p.jobs.clone();
        jobs.iter()
            .map(|j| {
                let m = j.m.max(1.0);
                let flow = self.flow_row(p.alpha, m);
                self.c_grid
                    .iter()
                    .zip(&flow)
                    .map(|(&c, &fi)| {
                        let beta = p.alpha * c;
                        -(j.mu * fi + j.age) - p.gamma * m * c * j.mu * emin_coeff(beta)
                    })
                    .collect()
            })
            .collect()
    }

    fn argmax_row(&self, row: &[f64], price: f64, r: f64) -> usize {
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for (g, (&a, &c)) in row.iter().zip(&self.c_grid).enumerate() {
            if c > r {
                break; // grid is ascending; beyond r is infeasible
            }
            let v = a - price * c;
            if v > best_v {
                best_v = v;
                best = g;
            }
        }
        best
    }

    /// Warm-started hill-climb argmax: the score row `A(c) - price*c` is
    /// concave in c (Lemma 1), so from the previous iteration's optimum we
    /// only walk until the score stops improving — O(moved) instead of
    /// O(G) per job per dual iteration (EXPERIMENTS.md §Perf).
    #[inline]
    fn argmax_row_from(&self, row: &[f64], price: f64, g_max: usize, start: usize) -> usize {
        let score = |g: usize| row[g] - price * self.c_grid[g];
        let mut g = start.min(g_max);
        let mut s = score(g);
        // try ascending
        while g + 1 <= g_max {
            let s_next = score(g + 1);
            if s_next > s {
                g += 1;
                s = s_next;
            } else {
                break;
            }
        }
        // try descending (only one direction can improve under concavity)
        while g > 0 {
            let s_prev = score(g - 1);
            if s_prev > s {
                g -= 1;
                s = s_prev;
            } else {
                break;
            }
        }
        g
    }

    /// Largest grid index with c <= r.
    fn g_max(&self, r: f64) -> usize {
        match self.c_grid.iter().rposition(|&c| c <= r) {
            Some(g) => g,
            None => 0,
        }
    }

    /// Run the solver.  `trace`, when non-empty on return, holds the
    /// Cesaro-averaged primal iterates (what Fig. 1 plots).
    ///
    /// Early termination (hot-path optimization, EXPERIMENTS.md §Perf):
    /// once the primal point has not moved for `STABLE_PATIENCE` straight
    /// iterations (a fixed point of the dual dynamics on the grid) the
    /// remaining iterations cannot change anything — stop.  Tracing runs
    /// disable this so Fig. 1 shows the full trajectory.
    pub fn solve_traced(&mut self, p: &P2Problem, trace: Option<&mut Vec<Vec<f64>>>) -> P2Solution {
        const STABLE_PATIENCE: usize = 40;
        const MIN_ITERS: usize = 80;
        let b = p.jobs.len();
        let table = self.table(p);
        let (eta1, eta2, eta3) = ETAS;
        let eta1 = eta1 / p.n_avail.max(1.0);
        let mut nu = 0.1;
        let mut xi = vec![0.1; b];
        let mut h = vec![0.1; b];
        let mut c = vec![1.0; b];
        let mut g_cur = vec![0usize; b];
        let g_max = self.g_max(p.r);
        // dual histories (flat, preallocated): primal recovery averages the
        // tail half of however many iterations actually ran
        let mut nu_h = Vec::with_capacity(self.iters);
        let mut xi_h = vec![0.0f64; self.iters * b];
        let mut h_h = vec![0.0f64; self.iters * b];
        let mut c_sum = vec![0.0; b];
        let mut local_trace = Vec::new();
        let want_trace = trace.is_some();
        let mut stable = 0usize;
        let mut ran = 0usize;
        for k in 0..self.iters {
            ran = k + 1;
            let mut used = 0.0;
            let mut moved = false;
            for i in 0..b {
                let price = nu * p.jobs[i].m + xi[i] - h[i];
                let g = self.argmax_row_from(&table[i], price, g_max, g_cur[i]);
                moved |= g != g_cur[i];
                g_cur[i] = g;
                c[i] = self.c_grid[g];
                used += p.jobs[i].m * c[i];
            }
            nu = (nu + eta1 * (used - p.n_avail)).max(0.0);
            for i in 0..b {
                xi[i] = (xi[i] + eta2 * (c[i] - p.r)).max(0.0);
                h[i] = (h[i] + eta3 * (1.0 - c[i])).max(0.0);
            }
            nu_h.push(nu);
            xi_h[k * b..(k + 1) * b].copy_from_slice(&xi);
            h_h[k * b..(k + 1) * b].copy_from_slice(&h);
            if want_trace {
                for i in 0..b {
                    c_sum[i] += c[i];
                }
                local_trace
                    .push(c_sum.iter().map(|s| s / (k + 1) as f64).collect::<Vec<f64>>());
            } else {
                stable = if moved { 0 } else { stable + 1 };
                if stable >= STABLE_PATIENCE && ran >= MIN_ITERS {
                    break;
                }
            }
        }
        // primal recovery from tail-averaged duals
        let half = ran / 2;
        let n_acc = (ran - half) as f64;
        let nu_bar = nu_h[half..].iter().sum::<f64>() / n_acc;
        let mut objective = 0.0;
        for i in 0..b {
            let mut xi_bar = 0.0;
            let mut h_bar = 0.0;
            for k in half..ran {
                xi_bar += xi_h[k * b + i];
                h_bar += h_h[k * b + i];
            }
            let price = nu_bar * p.jobs[i].m + xi_bar / n_acc - h_bar / n_acc;
            let g = self.argmax_row(&table[i], price, p.r);
            c[i] = self.c_grid[g];
            objective += table[i][g];
        }
        if let Some(t) = trace {
            *t = local_trace;
        }
        P2Solution { c, nu: nu_bar, objective, iterations: ran }
    }

    pub fn solve(&mut self, p: &P2Problem) -> P2Solution {
        self.solve_traced(p, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 1 instance.
    pub fn fig1_problem() -> P2Problem {
        P2Problem {
            jobs: vec![
                P2Job { mu: 1.0, m: 10.0, age: 0.0 },
                P2Job { mu: 2.0, m: 20.0, age: 0.0 },
                P2Job { mu: 1.0, m: 5.0, age: 0.0 },
                P2Job { mu: 2.0, m: 10.0, age: 0.0 },
            ],
            n_avail: 100.0,
            gamma: 0.01,
            r: 8.0,
            alpha: 2.0,
        }
    }

    #[test]
    fn fig1_converges_and_feasible() {
        let mut solver = GradientSolver::default();
        let mut trace = Vec::new();
        let sol = solver.solve_traced(&fig1_problem(), Some(&mut trace));
        let p = fig1_problem();
        let used: f64 = sol.c.iter().zip(&p.jobs).map(|(c, j)| c * j.m).sum();
        assert!(used <= p.n_avail * 1.05, "used {used}");
        assert!(sol.nu > 0.0, "capacity should be binding");
        // averaged iterates settle
        let last = &trace[trace.len() - 1];
        let prev = &trace[trace.len() - 40];
        for (a, b) in last.iter().zip(prev) {
            assert!((a - b).abs() < 0.05);
        }
        for &c in &sol.c {
            assert!((1.0..=8.0).contains(&c));
        }
    }

    #[test]
    fn matches_jax_solver_fig1() {
        // pinned against python/compile/model.py::p2_solve on the same
        // instance (c* = [1.952, 2.190, 2.190, 2.429], nu = 0.0779)
        let sol = GradientSolver::default().solve(&fig1_problem());
        let want = [1.952, 2.190, 2.190, 2.429];
        for (got, want) in sol.c.iter().zip(want) {
            assert!((got - want).abs() < 0.25, "{:?} vs {want:?}", sol.c);
        }
        assert!((sol.nu - 0.0779).abs() < 0.03, "nu={}", sol.nu);
    }

    #[test]
    fn ample_capacity_maxes_out() {
        let p = P2Problem {
            jobs: vec![P2Job { mu: 1.0, m: 4.0, age: 0.0 }],
            n_avail: 4000.0,
            gamma: 1e-4,
            r: 8.0,
            alpha: 2.0,
        };
        let sol = GradientSolver::default().solve(&p);
        assert!(sol.c[0] >= 7.5, "c={:?}", sol.c);
    }

    #[test]
    fn expensive_resource_disables_cloning() {
        let p = P2Problem {
            jobs: vec![P2Job { mu: 1.0, m: 10.0, age: 0.0 }],
            n_avail: 1000.0,
            gamma: 100.0,
            r: 8.0,
            alpha: 2.0,
        };
        let sol = GradientSolver::default().solve(&p);
        assert_eq!(sol.c[0], 1.0);
    }

    #[test]
    fn age_does_not_change_allocation() {
        // age is constant in c: same argmax, shifted objective
        let mut p = fig1_problem();
        let a = GradientSolver::default().solve(&p);
        for j in &mut p.jobs {
            j.age = 5.0;
        }
        let b = GradientSolver::default().solve(&p);
        assert_eq!(a.c, b.c);
        assert!(b.objective < a.objective);
    }

    #[test]
    fn empty_batch() {
        let p = P2Problem { jobs: vec![], n_avail: 10.0, gamma: 0.01, r: 8.0, alpha: 2.0 };
        let sol = GradientSolver::default().solve(&p);
        assert!(sol.c.is_empty());
        assert_eq!(sol.objective, 0.0);
    }
}
