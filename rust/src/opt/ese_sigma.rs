//! ESE analysis (Sec. VI-B): the optimal duplicate threshold sigma* from
//! Eq. (30)-(33), and the Eq. (29) small-job cloning objective used by
//! Algorithm 2's third level.

use super::pareto_math::{emin_coeff, ese_resource, flow_integral};

/// `sigma* = argmin_sigma E[R](sigma)` for the given heavy-tail order
/// (Fig. 4: ~1.7-1.9 at alpha = 2, approaching ~2 for larger alpha).
pub fn sigma_star(alpha: f64) -> f64 {
    let mut best = (1.0, f64::INFINITY);
    for i in 1..=120 {
        let sigma = i as f64 * 0.05;
        let v = ese_resource(alpha, sigma);
        if v < best.1 {
            best = (sigma, v);
        }
    }
    // local refinement
    let (mut s, mut v) = best;
    let mut step = 0.025;
    for _ in 0..8 {
        for cand in [s - step, s + step] {
            if cand > 0.0 {
                let cv = ese_resource(alpha, cand);
                if cv < v {
                    s = cand;
                    v = cv;
                }
            }
        }
        step *= 0.5;
    }
    s
}

/// Eq. (29): optimal clone count for one small job scheduled in isolation —
/// `argmax_c U(E[t], m) - gamma sum_j c E[t_j]` with `U = -E[t]`, capped so the
/// job's clones fit the idle machines.
pub fn small_job_clones(
    mu: f64,
    m: f64,
    gamma: f64,
    alpha: f64,
    r: u32,
    n_avail: f64,
) -> u32 {
    let fit = (n_avail / m.max(1.0)).floor();
    let cap = (r as f64).min(fit).max(1.0) as u32;
    let mut best = (1u32, f64::NEG_INFINITY);
    for c in 1..=cap {
        let beta = alpha * c as f64;
        let obj = -(mu * flow_integral(beta, m)) - gamma * m * c as f64 * mu * emin_coeff(beta);
        if obj > best.1 {
            best = (c, obj);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_star_alpha2_near_paper() {
        let s = sigma_star(2.0);
        assert!((1.5..=2.0).contains(&s), "sigma* = {s}");
    }

    #[test]
    fn sigma_star_flattens_toward_2() {
        for alpha in [3.0, 4.0, 5.0] {
            let s = sigma_star(alpha);
            assert!((1.6..=2.2).contains(&s), "alpha={alpha}: {s}");
        }
    }

    #[test]
    fn small_job_clones_more_when_cheap() {
        let many = small_job_clones(0.5, 5.0, 1e-4, 2.0, 8, 1000.0);
        let few = small_job_clones(0.5, 5.0, 10.0, 2.0, 8, 1000.0);
        assert!(many > few, "{many} vs {few}");
        assert_eq!(few, 1);
    }

    #[test]
    fn small_job_clones_respects_capacity() {
        // 5 tasks, 12 idle machines -> at most 2 copies each
        let c = small_job_clones(0.5, 5.0, 1e-4, 2.0, 8, 12.0);
        assert!(c <= 2, "c = {c}");
        assert!(c >= 1);
    }

    #[test]
    fn small_job_clones_capped_at_r() {
        let c = small_job_clones(0.5, 2.0, 1e-6, 2.0, 4, 1e6);
        assert_eq!(c, 4);
    }
}
