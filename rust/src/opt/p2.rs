//! P2 post-processing: turn the solver's continuous clone counts into an
//! integer, capacity-feasible assignment.
//!
//! The solver (rust fallback or PJRT artifact) returns c in [1, r] per job;
//! the cluster needs integers with sum_i m_i c_i <= N(l).  We round to the
//! nearest integer, then shed copies (largest c first) while over capacity.
//!
//! Deliberately NO greedy filling of spare capacity: the optimizer already
//! balanced flowtime gain against the resource term, and pushing every job
//! to r whenever machines are idle drives sustained utilization past 1
//! (util grows ~ c^2/(2c-1) under Pareto min-of-c service) — the regression
//! that motivated this note showed SCA *losing* to Mantri that way.

/// Round + repair.  `m[i]` is each job's task count; returns integer copy
/// counts in [1, r] with `sum m_i c_i <= n_avail` (when feasible at c = 1;
/// otherwise everything is clamped to 1 and the caller's SRPT branch should
/// have been taken instead).
pub fn round_and_repair(c: &[f64], m: &[f64], n_avail: f64, r: u32) -> Vec<u32> {
    assert_eq!(c.len(), m.len());
    let mut ci: Vec<u32> = c
        .iter()
        .map(|&x| (x.round().max(1.0) as u32).min(r))
        .collect();
    let used = |ci: &[u32]| -> f64 {
        ci.iter().zip(m).map(|(&c, &mi)| c as f64 * mi).sum()
    };
    // shed copies while infeasible
    while used(&ci) > n_avail {
        // largest c first; among ties, the biggest m sheds the most
        let Some(i) = (0..ci.len())
            .filter(|&i| ci[i] > 1)
            .max_by(|&a, &b| {
                ci[a]
                    .cmp(&ci[b])
                    .then(m[a].partial_cmp(&m[b]).unwrap())
            })
        else {
            break; // all at 1: infeasible even without cloning
        };
        ci[i] -= 1;
    }
    ci
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_capacity() {
        let c = [3.7, 2.2, 5.9];
        let m = [10.0, 20.0, 5.0];
        let ci = round_and_repair(&c, &m, 100.0, 8);
        let used: f64 = ci.iter().zip(&m).map(|(&c, &mi)| c as f64 * mi).sum();
        assert!(used <= 100.0, "used {used}, ci {ci:?}");
        for &c in &ci {
            assert!((1..=8).contains(&c));
        }
    }

    #[test]
    fn no_greedy_fill_beyond_solution() {
        // spare capacity does NOT inflate the optimizer's answer
        let ci = round_and_repair(&[1.2], &[10.0], 85.0, 8);
        assert_eq!(ci, vec![1]);
        let ci = round_and_repair(&[3.6], &[10.0], 85.0, 8);
        assert_eq!(ci, vec![4]);
    }

    #[test]
    fn all_at_one_when_tight() {
        let ci = round_and_repair(&[4.0, 4.0], &[30.0, 30.0], 60.0, 8);
        assert_eq!(ci, vec![1, 1]);
    }

    #[test]
    fn infeasible_even_at_one_stays_one() {
        let ci = round_and_repair(&[2.0], &[100.0], 50.0, 8);
        assert_eq!(ci, vec![1]);
    }

    #[test]
    fn empty() {
        assert!(round_and_repair(&[], &[], 10.0, 8).is_empty());
    }

    #[test]
    fn caps_at_r() {
        let ci = round_and_repair(&[9.9], &[1.0], 1000.0, 8);
        assert_eq!(ci, vec![8]);
    }

    /// Property test (hand-rolled: proptest is unavailable offline): for
    /// random instances feasible at c = 1, repair always fits capacity and
    /// keeps every count in [1, r].
    #[test]
    fn prop_feasible_and_bounded() {
        let mut rng = crate::stats::Pcg64::new(0xbeef, 0);
        for case in 0..500 {
            let njobs = rng.uniform_u64(1, 40) as usize;
            let c: Vec<f64> = (0..njobs).map(|_| rng.uniform_f64(1.0, 8.0)).collect();
            let m: Vec<f64> = (0..njobs).map(|_| rng.uniform_f64(1.0, 100.0)).collect();
            let headroom = rng.uniform_f64(1.0, 4.0);
            let n = m.iter().sum::<f64>() * headroom;
            let ci = round_and_repair(&c, &m, n, 8);
            let used: f64 = ci.iter().zip(&m).map(|(&c, &mi)| c as f64 * mi).sum();
            assert!(used <= n + 1e-9, "case {case}: used {used} > {n}");
            for &x in &ci {
                assert!((1..=8).contains(&x), "case {case}: c = {x}");
            }
        }
    }
}
