//! Micro-bench harness (criterion is unavailable offline): warm-up + timed
//! iterations with mean/median/min reporting and a simple guard against
//! dead-code elimination.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<4} mean={:>12?} median={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.median, self.min
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters.max(1);
    Measurement {
        name: name.to_string(),
        iters,
        mean,
        median: samples[samples.len() / 2],
        min: samples[0],
    }
}

/// Run + print; returns the measurement for programmatic checks.
pub fn run<T>(name: &str, warmup: u32, iters: u32, f: impl FnMut() -> T) -> Measurement {
    let m = bench(name, warmup, iters, f);
    println!("{}", m.report());
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(m.iters, 5);
        assert!(m.min <= m.median && m.median <= m.mean * 5);
    }

    #[test]
    fn ordering_of_stats() {
        let mut x = 0u64;
        let m = bench("sum", 0, 9, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.min <= m.median);
    }
}
