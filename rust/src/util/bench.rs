//! Micro-bench harness (criterion is unavailable offline): warm-up + timed
//! iterations with mean/median/min reporting and a simple guard against
//! dead-code elimination — plus the standardized **simulator throughput
//! suite** behind the `bench` CLI subcommand, whose machine-readable
//! artifact (`BENCH_sim.json`) seeds the repo's perf trajectory.
//!
//! The suite runs every policy over {light λ = 0.3, heavy λ ≈ 0.9·λ^U} ×
//! M ∈ {500, 4000}, each cell **three times** on the identical
//! pre-sampled workload — `indexed` (the `SchedIndex` hot path, wakeup
//! planner on: the default), `scan` (the retained naive-scan reference),
//! and `polled` (indexed path with `wakeup = false`: the retired
//! fire-every-slot loop) — so one artifact carries the absolute
//! events/sec numbers, the index speedup *and* the wakeup speedup.
//! Light cells run on a fine slot grid ([`WAKEUP_SLOT_DT`]): the
//! polling-dominated regime the wakeup planner targets, where most grid
//! slots find no free machine and no threshold crossing; heavy
//! cells keep the paper's `slot_dt = 1`, where nearly every slot has
//! real work and the planner's job is to cost nothing.  Cells run
//! sequentially on purpose: concurrent cells would contaminate each
//! other's wall-clock.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::analysis::threshold;
use crate::cluster::event::EventQueueKind;
use crate::cluster::generator;
use crate::cluster::machine::{ChurnConfig, SlowdownConfig};
use crate::cluster::sim::{SimResult, Simulator, Workload};
use crate::config::{RoutePolicy, ServeConfig, SimConfig, WorkloadConfig};
use crate::coordinator::backpressure::Backpressure;
use crate::coordinator::shard::{ShardedHandle, ShardedMaster};
use crate::coordinator::Submission;
use crate::scheduler::{self, SchedulerKind};
use crate::stats::Pcg64;

use super::json::Json;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<4} mean={:>12?} median={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.median, self.min
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters.max(1);
    Measurement {
        name: name.to_string(),
        iters,
        mean,
        median: samples[samples.len() / 2],
        min: samples[0],
    }
}

/// Run + print; returns the measurement for programmatic checks.
pub fn run<T>(name: &str, warmup: u32, iters: u32, f: impl FnMut() -> T) -> Measurement {
    let m = bench(name, warmup, iters, f);
    println!("{}", m.report());
    m
}

// ----- the standardized simulator-throughput suite -----------------------

/// Schema tag written into `BENCH_sim.json` so downstream tooling can
/// detect format drift.  v2: per-cell `slot_dt`, the third (`polled`)
/// run, `wakeup_speedup`/`skip_ratio`, tick counters on every run, and
/// `events` no longer counts slot boundaries (they left the event heap).
/// v3: per-run `peak_rss_bytes` (Linux `VmHWM`, reset before each run;
/// `null` elsewhere) and the `scale_cells` array — the (naive, light)
/// M ∈ {10^5, 10^6} cells timed per event-queue backend
/// (calendar vs binary heap).
/// v4: the `flip_cells` array — the (sda, light, M = 4000) cell with the
/// ON/OFF Markov slowdown process enabled vs the static slowdown
/// scenario, pricing the `SlowdownFlip` kill/re-insert traffic.
/// v5: the `serve_cells` array — sustained submissions/sec and submit
/// latency percentiles of the sharded live coordinator at
/// shards ∈ {1, 2, 4} on a fixed submission workload (`bench --serve`).
/// v6: the `trace_cells` array — one frozen workload replayed three ways
/// (materialized up front, streamed through the bounded-window trace
/// reader, streamed with `max_resident_jobs` record recycling), all three
/// simulating bit-identical dynamics, with per-run peak RSS.
/// v7: the `churn_cells` array — the (sda, light, M = 4000) cell with the
/// machine crash/recovery process enabled vs the churn-free baseline,
/// pricing the fail/recover event traffic, stranded-copy settlement and
/// task re-execution.
pub const BENCH_SCHEMA: &str = "specsim-bench-v7";

/// The suite's machine-count axis.
pub const SUITE_MACHINES: [usize; 2] = [500, 4000];

/// The machine-count axis of the scale cells — the datacenter regime the
/// calendar queue and arena/SoA layout target (ROADMAP "Million-machine
/// raw speed").  Naive policy, light load: the point is that nothing in
/// the per-slot or per-event path scales with M.
pub const SCALE_MACHINES: [usize; 2] = [100_000, 1_000_000];

/// The suite's light-load arrival rate (jobs per time unit).
pub const LIGHT_LAMBDA: f64 = 0.3;

/// Slot grid for the light-load cells: 1000 decision slots per time unit.
/// This is the regime the wakeup planner targets — wall-clock of
/// the polled loop scales with `horizon / slot_dt` even when nothing
/// changes, so a fine grid makes the tick path's cost (and the planner's
/// elimination of it) visible instead of noise behind event handling.
/// Heavy cells keep the paper's `slot_dt = 1.0`: with real work at almost
/// every slot the planner can only show that skipping costs nothing.
pub const WAKEUP_SLOT_DT: f64 = 0.001;

/// Heavy-load arrival rate for `machines`: 90% of the analytic ESE cutoff
/// λ^U for the paper's job mix (Sec. III-B) — near-threshold load, the
/// regime where the naive scans blow up.
pub fn heavy_lambda(machines: usize) -> f64 {
    let mix = WorkloadConfig::paper(1.0);
    0.9 * threshold::cutoff_lambda(machines, mix.mean_tasks(), mix.mean_duration(), 2.0)
        .lambda_cutoff
}

/// Reset the kernel's peak-RSS high-water mark so each run's `VmHWM`
/// reading is its own, not an earlier cell's.  Best-effort: the write is
/// Linux-only and may be refused (e.g. in restricted sandboxes), in which
/// case later readings are monotone over the process lifetime.
fn reset_peak_rss() {
    let _ = std::fs::write("/proc/self/clear_refs", "5");
}

/// Peak resident set size (`VmHWM` from `/proc/self/status`) in bytes;
/// `None` off Linux or when the read/parse fails.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// One timed simulation of a suite cell (one query path × one wakeup
/// mode).
#[derive(Clone, Debug)]
pub struct ThroughputRun {
    /// Wall-clock for `Simulator::new` + `run`.
    pub wall_secs: f64,
    /// Events the run loop popped (slot boundaries are counted separately
    /// below — they no longer live in the event heap).
    pub events: u64,
    /// `events / wall_secs` — the headline throughput metric.
    pub events_per_sec: f64,
    /// Grid slots whose `on_slot` ran / slots the wakeup planner skipped.
    pub ticks_fired: u64,
    pub ticks_skipped: u64,
    /// Wall-clock inside the scheduler's `on_slot` hook.
    pub slot_hook_secs: f64,
    /// Event-heap high-water mark.
    pub peak_event_queue: usize,
    pub completed_jobs: usize,
    /// Peak resident set during the run (Linux `VmHWM`, reset per run;
    /// `None` elsewhere).
    pub peak_rss_bytes: Option<u64>,
}

impl ThroughputRun {
    fn from_result(res: &SimResult, wall_secs: f64, peak_rss_bytes: Option<u64>) -> Self {
        ThroughputRun {
            wall_secs,
            events: res.events_processed,
            events_per_sec: res.events_processed as f64 / wall_secs.max(1e-12),
            ticks_fired: res.ticks_fired,
            ticks_skipped: res.ticks_skipped,
            slot_hook_secs: res.slot_hook_secs,
            peak_event_queue: res.peak_event_queue,
            // capped runs recycle records into the streaming sketches;
            // count completions from there so the column stays honest
            completed_jobs: res
                .streamed
                .as_ref()
                .map_or(res.completed.len(), |s| s.drained as usize),
            peak_rss_bytes,
        }
    }

    /// `ticks_skipped / (ticks_fired + ticks_skipped)`; 0 on an empty grid.
    pub fn skip_ratio(&self) -> f64 {
        let total = self.ticks_fired + self.ticks_skipped;
        if total == 0 {
            0.0
        } else {
            self.ticks_skipped as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("wall_secs".into(), Json::Num(self.wall_secs));
        m.insert("events".into(), Json::Num(self.events as f64));
        m.insert("events_per_sec".into(), Json::Num(self.events_per_sec));
        m.insert("ticks_fired".into(), Json::Num(self.ticks_fired as f64));
        m.insert("ticks_skipped".into(), Json::Num(self.ticks_skipped as f64));
        m.insert("slot_hook_secs".into(), Json::Num(self.slot_hook_secs));
        m.insert("peak_event_queue".into(), Json::Num(self.peak_event_queue as f64));
        m.insert("completed_jobs".into(), Json::Num(self.completed_jobs as f64));
        m.insert(
            "peak_rss_bytes".into(),
            self.peak_rss_bytes.map_or(Json::Null, |b| Json::Num(b as f64)),
        );
        Json::Obj(m)
    }
}

/// One (policy, load, machines) grid cell, measured on both query paths
/// plus the polled (wakeup-off) reference.
#[derive(Clone, Debug)]
pub struct ThroughputCell {
    /// Policy label: a canonical name or a composition spec.
    pub policy: String,
    /// `"light"` or `"heavy"`.
    pub load: &'static str,
    pub lambda: f64,
    pub machines: usize,
    /// The decision grid the cell ran on ([`WAKEUP_SLOT_DT`] for light
    /// cells, the paper's 1.0 for heavy ones).
    pub slot_dt: f64,
    /// The `sched_index = true`, `wakeup = true` hot path (the default).
    pub indexed: ThroughputRun,
    /// The retained naive-scan reference (`sched_index = false`).
    pub scan: ThroughputRun,
    /// The retired polling loop (`wakeup = false`) on the indexed path.
    pub polled: ThroughputRun,
}

impl ThroughputCell {
    /// Index-path speedup over the scan reference (events/sec ratio).
    pub fn speedup(&self) -> f64 {
        self.indexed.events_per_sec / self.scan.events_per_sec.max(1e-12)
    }

    /// Wakeup-planner speedup over the polled loop (wall-clock ratio on
    /// the identical indexed path — events/sec would say the same thing,
    /// since both runs pop the identical events).
    pub fn wakeup_speedup(&self) -> f64 {
        self.polled.wall_secs / self.indexed.wall_secs.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("policy".into(), Json::Str(self.policy.clone()));
        m.insert("load".into(), Json::Str(self.load.to_string()));
        m.insert("lambda".into(), Json::Num(self.lambda));
        m.insert("machines".into(), Json::Num(self.machines as f64));
        m.insert("slot_dt".into(), Json::Num(self.slot_dt));
        m.insert("indexed".into(), self.indexed.to_json());
        m.insert("scan".into(), self.scan.to_json());
        m.insert("polled".into(), self.polled.to_json());
        m.insert("speedup".into(), Json::Num(self.speedup()));
        m.insert("wakeup_speedup".into(), Json::Num(self.wakeup_speedup()));
        m.insert("skip_ratio".into(), Json::Num(self.indexed.skip_ratio()));
        Json::Obj(m)
    }
}

/// Suite horizon: `--quick` (CI) keeps the whole suite under a couple of
/// minutes; the full setting is the EXPERIMENTS.md reference length.
pub fn suite_horizon(quick: bool) -> f64 {
    if quick {
        120.0
    } else {
        400.0
    }
}

/// One timed run of `kind` on `workload` with the given query path and
/// wakeup mode.
pub fn time_simulation(
    base: &SimConfig,
    wl_cfg: &WorkloadConfig,
    workload: Workload,
    kind: SchedulerKind,
    sched_index: bool,
    wakeup: bool,
) -> Result<ThroughputRun, String> {
    let mut cfg = base.clone();
    cfg.scheduler = kind;
    cfg.sched_index = sched_index;
    cfg.wakeup = wakeup;
    let sched = scheduler::build_for(&cfg, wl_cfg, Some(&workload))?;
    reset_peak_rss();
    let t0 = Instant::now();
    let res = Simulator::new(cfg, workload, sched).run();
    let wall = t0.elapsed().as_secs_f64();
    Ok(ThroughputRun::from_result(&res, wall, peak_rss_bytes()))
}

/// The suite's policy axis: the seven canonical policies plus two
/// composed pipelines, so the policy-pipeline layer (grammar dispatch,
/// est-srpt re-keying) is perf-tracked alongside the monolith-equivalent
/// compositions.
pub fn suite_policies() -> Vec<SchedulerKind> {
    let mut kinds: Vec<SchedulerKind> = SchedulerKind::all().to_vec();
    kinds.push("fifo+sda".parse().expect("valid composition"));
    kinds.push("est-srpt+mantri".parse().expect("valid composition"));
    kinds
}

/// Run the standardized suite, invoking `progress` after each finished
/// cell (the CLI prints a table row).  [`suite_policies`] × {light,
/// heavy} × [`SUITE_MACHINES`]; every cell shares its (load, M)
/// pre-sampled workload across policies and paths.
pub fn run_throughput_suite(
    quick: bool,
    mut progress: impl FnMut(&ThroughputCell),
) -> Result<Vec<ThroughputCell>, String> {
    let horizon = suite_horizon(quick);
    let mut cells = Vec::new();
    for machines in SUITE_MACHINES {
        for (load, lambda) in [("light", LIGHT_LAMBDA), ("heavy", heavy_lambda(machines))] {
            let mut base = SimConfig::default();
            base.machines = machines;
            base.horizon = horizon;
            base.use_runtime = false; // rust P2 twin: no artifact dependency
            // light cells stress the fine-grid polling regime the wakeup
            // planner targets; heavy cells keep the paper's slot grid
            base.slot_dt = if load == "light" { WAKEUP_SLOT_DT } else { 1.0 };
            let wl_cfg = WorkloadConfig::paper(lambda);
            let workload = generator::generate(&wl_cfg, horizon, base.seed);
            for kind in suite_policies() {
                let indexed = time_simulation(&base, &wl_cfg, workload.clone(), kind, true, true)?;
                let scan = time_simulation(&base, &wl_cfg, workload.clone(), kind, false, true)?;
                let polled = time_simulation(&base, &wl_cfg, workload.clone(), kind, true, false)?;
                let cell = ThroughputCell {
                    policy: kind.to_string(),
                    load,
                    lambda,
                    machines,
                    slot_dt: base.slot_dt,
                    indexed,
                    scan,
                    polled,
                };
                progress(&cell);
                cells.push(cell);
            }
        }
    }
    Ok(cells)
}

/// The wakeup acceptance gate CI enforces (`bench --check-wakeup`): on
/// the (naive, light, M = 4000) cell the planner must skip at least half
/// the grid slots and cut wall-clock at least 2× against the polled loop.
pub fn check_wakeup_gate(cells: &[ThroughputCell]) -> Result<(), String> {
    let cell = cells
        .iter()
        .find(|c| c.policy == "naive" && c.load == "light" && c.machines == 4000)
        .ok_or("wakeup gate: the (naive, light, M=4000) cell is missing")?;
    let ratio = cell.indexed.skip_ratio();
    let speedup = cell.wakeup_speedup();
    if ratio < 0.5 {
        return Err(format!(
            "wakeup gate: skip ratio {ratio:.3} < 0.5 on (naive, light, M=4000) — \
             {} fired / {} skipped",
            cell.indexed.ticks_fired, cell.indexed.ticks_skipped
        ));
    }
    if speedup < 2.0 {
        return Err(format!(
            "wakeup gate: wakeup_speedup {speedup:.2}x < 2x on (naive, light, M=4000) — \
             polled {:.3}s vs wakeup {:.3}s",
            cell.polled.wall_secs, cell.indexed.wall_secs
        ));
    }
    Ok(())
}

// ----- the million-machine scale cells ------------------------------------

/// One (naive, light, M) scale cell, timed per event-queue backend on the
/// identical pre-sampled workload.  Both backends pop the identical
/// `(time, seq)` event order (the equivalence property tests pin this),
/// so the events/sec ratio is a pure wall-clock comparison.
#[derive(Clone, Debug)]
pub struct ScaleCell {
    pub policy: String,
    pub load: &'static str,
    pub lambda: f64,
    pub machines: usize,
    pub slot_dt: f64,
    /// Best-of-N run on the calendar backend (the default hot path).
    pub calendar: ThroughputRun,
    /// Best-of-N run on the binary-heap reference.
    pub heap: ThroughputRun,
}

impl ScaleCell {
    /// Calendar-backend speedup over the heap (events/sec ratio; both
    /// runs pop identical events, so this is a wall-clock ratio).
    pub fn queue_speedup(&self) -> f64 {
        self.calendar.events_per_sec / self.heap.events_per_sec.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("policy".into(), Json::Str(self.policy.clone()));
        m.insert("load".into(), Json::Str(self.load.to_string()));
        m.insert("lambda".into(), Json::Num(self.lambda));
        m.insert("machines".into(), Json::Num(self.machines as f64));
        m.insert("slot_dt".into(), Json::Num(self.slot_dt));
        m.insert("calendar".into(), self.calendar.to_json());
        m.insert("heap".into(), self.heap.to_json());
        m.insert("queue_speedup".into(), Json::Num(self.queue_speedup()));
        Json::Obj(m)
    }
}

/// Best wall-clock of `passes` identical timed runs — the standard
/// min-of-N defence against scheduler noise on a gated comparison.
fn best_of(
    base: &SimConfig,
    wl_cfg: &WorkloadConfig,
    workload: &Workload,
    passes: u32,
) -> Result<ThroughputRun, String> {
    assert!(passes >= 1);
    let mut best: Option<ThroughputRun> = None;
    for _ in 0..passes {
        let run =
            time_simulation(base, wl_cfg, workload.clone(), SchedulerKind::Naive, true, true)?;
        best = Some(match best {
            Some(b) if b.wall_secs <= run.wall_secs => b,
            _ => run,
        });
    }
    Ok(best.expect("passes >= 1"))
}

/// Run the scale cells: (naive, light) × [`SCALE_MACHINES`], each timed
/// on both event-queue backends.  `--quick` (CI) skips the M = 10^6 cell
/// — it exists to prove the full suite completes at datacenter scale, not
/// to gate every push.  The M ≤ 10^5 cells are best-of-3 per backend
/// (they feed the [`check_scale_gate`] comparison); M = 10^6 runs once.
pub fn run_scale_suite(
    quick: bool,
    mut progress: impl FnMut(&ScaleCell),
) -> Result<Vec<ScaleCell>, String> {
    let horizon = suite_horizon(quick);
    let mut cells = Vec::new();
    for machines in SCALE_MACHINES {
        if quick && machines > 100_000 {
            continue; // CI quick-mode guard (see the bench CI job)
        }
        let mut base = SimConfig::default();
        base.machines = machines;
        base.horizon = horizon;
        base.use_runtime = false;
        base.slot_dt = WAKEUP_SLOT_DT;
        let wl_cfg = WorkloadConfig::paper(LIGHT_LAMBDA);
        let workload = generator::generate(&wl_cfg, horizon, base.seed);
        let passes = if machines > 100_000 { 1 } else { 3 };
        let mut cal_cfg = base.clone();
        cal_cfg.event_queue = EventQueueKind::Calendar;
        let calendar = best_of(&cal_cfg, &wl_cfg, &workload, passes)?;
        let mut heap_cfg = base;
        heap_cfg.event_queue = EventQueueKind::BinaryHeap;
        let heap = best_of(&heap_cfg, &wl_cfg, &workload, passes)?;
        let cell = ScaleCell {
            policy: SchedulerKind::Naive.to_string(),
            load: "light",
            lambda: LIGHT_LAMBDA,
            machines,
            slot_dt: WAKEUP_SLOT_DT,
            calendar,
            heap,
        };
        progress(&cell);
        cells.push(cell);
    }
    Ok(cells)
}

// ----- the flip-enabled cell ---------------------------------------------

/// The (sda, light) cell with the ON/OFF Markov slowdown process running
/// vs the static slowdown scenario on the identical pre-sampled workload
/// (PR 7).  Flip runs pop strictly more events (the `SlowdownFlip`
/// stream plus the re-inserted finishes/checkpoints it forces), so the
/// honest overhead metric is the wall-clock ratio, not events/sec.
#[derive(Clone, Debug)]
pub struct FlipCell {
    pub policy: String,
    pub load: &'static str,
    pub lambda: f64,
    pub machines: usize,
    pub slot_dt: f64,
    /// `frac x factor @ rate_on, rate_off` of the flip run's scenario.
    pub slowdown: String,
    /// Hot path (indexed + wakeup) with flips enabled.
    pub flips: ThroughputRun,
    /// The same scenario with zero transition rates (static degradation).
    pub static_run: ThroughputRun,
}

impl FlipCell {
    /// Wall-clock cost of the flip machinery: `flips / static` (1.0 = the
    /// non-stationary process is free; expect a modest premium — the flip
    /// run genuinely does more work).
    pub fn overhead(&self) -> f64 {
        self.flips.wall_secs / self.static_run.wall_secs.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("policy".into(), Json::Str(self.policy.clone()));
        m.insert("load".into(), Json::Str(self.load.to_string()));
        m.insert("lambda".into(), Json::Num(self.lambda));
        m.insert("machines".into(), Json::Num(self.machines as f64));
        m.insert("slot_dt".into(), Json::Num(self.slot_dt));
        m.insert("slowdown".into(), Json::Str(self.slowdown.clone()));
        m.insert("flips".into(), self.flips.to_json());
        m.insert("static".into(), self.static_run.to_json());
        m.insert("overhead".into(), Json::Num(self.overhead()));
        Json::Obj(m)
    }
}

/// Run the flip cell: (sda, light, M = 4000) under
/// `0.2x3.0 @ 0.5, 1.0` vs the rate-free `0.2x3.0` static scenario.
/// SDA on purpose — its reveal hook is what the flip handler re-fires,
/// so the cell prices the full in-flight rescheduling path, not just the
/// queue churn.
pub fn run_flip_suite(
    quick: bool,
    mut progress: impl FnMut(&FlipCell),
) -> Result<Vec<FlipCell>, String> {
    let horizon = suite_horizon(quick);
    let machines = SUITE_MACHINES[1];
    let mut base = SimConfig::default();
    base.machines = machines;
    base.horizon = horizon;
    base.use_runtime = false;
    base.slot_dt = WAKEUP_SLOT_DT;
    let wl_cfg = WorkloadConfig::paper(LIGHT_LAMBDA);
    let workload = generator::generate(&wl_cfg, horizon, base.seed);
    let sd = SlowdownConfig::new(0.2, 3.0).with_rates(0.5, 1.0);
    let mut flip_cfg = base.clone();
    flip_cfg.slowdown = Some(sd);
    let flips = time_simulation(&flip_cfg, &wl_cfg, workload.clone(), SchedulerKind::Sda, true, true)?;
    let mut static_cfg = base;
    static_cfg.slowdown = Some(SlowdownConfig::new(0.2, 3.0));
    let static_run =
        time_simulation(&static_cfg, &wl_cfg, workload, SchedulerKind::Sda, true, true)?;
    let cell = FlipCell {
        policy: SchedulerKind::Sda.to_string(),
        load: "light",
        lambda: LIGHT_LAMBDA,
        machines,
        slot_dt: WAKEUP_SLOT_DT,
        slowdown: crate::cluster::machine::format_slowdown(&sd),
        flips,
        static_run,
    };
    progress(&cell);
    Ok(vec![cell])
}

/// Render the flip cells as the EXPERIMENTS.md §Perf companion table.
pub fn flip_markdown(cells: &[FlipCell]) -> String {
    let mut out = String::from(
        "| policy | load | M | slowdown | flips ev/s | static ev/s | flip events \
         | static events | wall overhead |\n\
         |---|---|---|---|---|---|---|---|---|\n",
    );
    for c in cells {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {:.0} | {:.0} | {} | {} | {:.2}x |\n",
            c.policy,
            c.load,
            c.machines,
            c.slowdown,
            c.flips.events_per_sec,
            c.static_run.events_per_sec,
            c.flips.events,
            c.static_run.events,
            c.overhead()
        ));
    }
    out
}

// ----- the churn-enabled cell --------------------------------------------

/// The (sda, light) cell with the machine crash/recovery process running
/// vs the churn-free baseline on the identical pre-sampled workload
/// (PR 10).  Churn runs pop strictly more events (the fail/recover
/// stream plus the re-queued copies it forces), so the honest overhead
/// metric is the wall-clock ratio, not events/sec.
#[derive(Clone, Debug)]
pub struct ChurnCell {
    pub policy: String,
    pub load: &'static str,
    pub lambda: f64,
    pub machines: usize,
    pub slot_dt: f64,
    /// `MTTF,MTTR` of the churn run's scenario.
    pub churn: String,
    /// Hot path (indexed + wakeup) with churn enabled.
    pub churned: ThroughputRun,
    /// The same scenario with no churn process.
    pub baseline: ThroughputRun,
}

impl ChurnCell {
    /// Wall-clock cost of the churn machinery: `churned / baseline` (1.0 =
    /// fault injection is free; expect a premium — lost work really is
    /// re-executed).
    pub fn overhead(&self) -> f64 {
        self.churned.wall_secs / self.baseline.wall_secs.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("policy".into(), Json::Str(self.policy.clone()));
        m.insert("load".into(), Json::Str(self.load.to_string()));
        m.insert("lambda".into(), Json::Num(self.lambda));
        m.insert("machines".into(), Json::Num(self.machines as f64));
        m.insert("slot_dt".into(), Json::Num(self.slot_dt));
        m.insert("churn".into(), Json::Str(self.churn.clone()));
        m.insert("churned".into(), self.churned.to_json());
        m.insert("baseline".into(), self.baseline.to_json());
        m.insert("overhead".into(), Json::Num(self.overhead()));
        Json::Obj(m)
    }
}

/// Run the churn cell: (sda, light, M = 4000) under `40,10` machine
/// churn vs the churn-free baseline.  SDA on purpose — crashes strand
/// unrevealed primaries and force relaunches through its reveal hook, so
/// the cell prices the full settlement + re-execution path, not just the
/// extra queue traffic.
pub fn run_churn_suite(
    quick: bool,
    mut progress: impl FnMut(&ChurnCell),
) -> Result<Vec<ChurnCell>, String> {
    let horizon = suite_horizon(quick);
    let machines = SUITE_MACHINES[1];
    let mut base = SimConfig::default();
    base.machines = machines;
    base.horizon = horizon;
    base.use_runtime = false;
    base.slot_dt = WAKEUP_SLOT_DT;
    let wl_cfg = WorkloadConfig::paper(LIGHT_LAMBDA);
    let workload = generator::generate(&wl_cfg, horizon, base.seed);
    let ch = ChurnConfig::new(40.0, 10.0);
    let mut churn_cfg = base.clone();
    churn_cfg.churn = Some(ch);
    let churned =
        time_simulation(&churn_cfg, &wl_cfg, workload.clone(), SchedulerKind::Sda, true, true)?;
    let baseline = time_simulation(&base, &wl_cfg, workload, SchedulerKind::Sda, true, true)?;
    let cell = ChurnCell {
        policy: SchedulerKind::Sda.to_string(),
        load: "light",
        lambda: LIGHT_LAMBDA,
        machines,
        slot_dt: WAKEUP_SLOT_DT,
        churn: crate::cluster::machine::format_churn(&ch),
        churned,
        baseline,
    };
    progress(&cell);
    Ok(vec![cell])
}

/// Render the churn cells as the EXPERIMENTS.md §Perf companion table.
pub fn churn_markdown(cells: &[ChurnCell]) -> String {
    let mut out = String::from(
        "| policy | load | M | churn | churn ev/s | baseline ev/s | churn events \
         | baseline events | wall overhead |\n\
         |---|---|---|---|---|---|---|---|---|\n",
    );
    for c in cells {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {:.0} | {:.0} | {} | {} | {:.2}x |\n",
            c.policy,
            c.load,
            c.machines,
            c.churn,
            c.churned.events_per_sec,
            c.baseline.events_per_sec,
            c.churned.events,
            c.baseline.events,
            c.overhead()
        ));
    }
    out
}

/// The scale acceptance gate CI enforces (`bench --check-scale`): on the
/// (naive, light, M = 10^5) cell the calendar backend must at least match
/// the heap reference's throughput.
pub fn check_scale_gate(cells: &[ScaleCell]) -> Result<(), String> {
    let cell = cells
        .iter()
        .find(|c| c.policy == "naive" && c.load == "light" && c.machines == 100_000)
        .ok_or("scale gate: the (naive, light, M=100000) cell is missing")?;
    let speedup = cell.queue_speedup();
    if speedup < 1.0 {
        return Err(format!(
            "scale gate: calendar backend at {speedup:.3}x the heap on (naive, light, \
             M=100000) — calendar {:.3}s vs heap {:.3}s",
            cell.calendar.wall_secs, cell.heap.wall_secs
        ));
    }
    Ok(())
}

/// Render the scale cells as the EXPERIMENTS.md §Perf companion table.
pub fn scale_markdown(cells: &[ScaleCell]) -> String {
    let rss = |r: &ThroughputRun| match r.peak_rss_bytes {
        Some(b) => format!("{:.0} MiB", b as f64 / (1024.0 * 1024.0)),
        None => "n/a".to_string(),
    };
    let mut out = String::from(
        "| policy | load | M | slot_dt | calendar ev/s | heap ev/s | queue speedup \
         | calendar peak RSS | heap peak RSS |\n\
         |---|---|---|---|---|---|---|---|---|\n",
    );
    for c in cells {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {:.0} | {:.0} | {:.2}x | {} | {} |\n",
            c.policy,
            c.load,
            c.machines,
            c.slot_dt,
            c.calendar.events_per_sec,
            c.heap.events_per_sec,
            c.queue_speedup(),
            rss(&c.calendar),
            rss(&c.heap)
        ));
    }
    out
}

// ----- the trace-replay cells ---------------------------------------------

/// Resident-record cap for the capped trace run (PR 9): small enough that
/// the recycling path runs many times per suite, large enough that the
/// drain amortizes.
pub const TRACE_RESIDENT_CAP: usize = 256;

/// One frozen workload replayed three ways on the identical config: the
/// materialized reference (`Simulator::new` on the up-front workload), the
/// streamed bounded-window path (`Simulator::from_source`), and the
/// streamed path with `max_resident_jobs` record recycling.  All three
/// simulate bit-identical dynamics (`tests/trace_replay.rs` pins this), so
/// the columns compare pure wall-clock and peak RSS.
#[derive(Clone, Debug)]
pub struct TraceCell {
    pub policy: String,
    pub lambda: f64,
    pub machines: usize,
    /// Jobs in the frozen trace.
    pub jobs: usize,
    /// Streaming lookahead window (jobs).
    pub window: usize,
    /// `max_resident_jobs` of the capped run.
    pub resident_cap: usize,
    pub materialized: ThroughputRun,
    pub streamed: ThroughputRun,
    pub capped: ThroughputRun,
}

impl TraceCell {
    /// Wall-clock cost of streaming over materializing (1.0 = free).
    pub fn stream_overhead(&self) -> f64 {
        self.streamed.wall_secs / self.materialized.wall_secs.max(1e-12)
    }

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("policy".into(), Json::Str(self.policy.clone()));
        m.insert("lambda".into(), Json::Num(self.lambda));
        m.insert("machines".into(), Json::Num(self.machines as f64));
        m.insert("jobs".into(), Json::Num(self.jobs as f64));
        m.insert("window".into(), Json::Num(self.window as f64));
        m.insert("resident_cap".into(), Json::Num(self.resident_cap as f64));
        m.insert("materialized".into(), self.materialized.to_json());
        m.insert("streamed".into(), self.streamed.to_json());
        m.insert("capped".into(), self.capped.to_json());
        m.insert("stream_overhead".into(), Json::Num(self.stream_overhead()));
        Json::Obj(m)
    }
}

/// One timed streamed replay of a trace workload config; `cap` switches on
/// `max_resident_jobs` record recycling.
fn time_streamed(
    base: &SimConfig,
    wl_cfg: &WorkloadConfig,
    cap: Option<usize>,
) -> Result<ThroughputRun, String> {
    let mut cfg = base.clone();
    cfg.max_resident_jobs = cap;
    let sched = scheduler::build_for(&cfg, wl_cfg, None)?;
    let source = crate::workload::source_for(wl_cfg, cfg.horizon, cfg.seed)?;
    let window = match wl_cfg {
        WorkloadConfig::Trace { window, .. } => *window,
        _ => crate::workload::DEFAULT_WINDOW,
    };
    reset_peak_rss();
    let t0 = Instant::now();
    let res = Simulator::from_source(cfg, source, window, sched).run();
    let wall = t0.elapsed().as_secs_f64();
    Ok(ThroughputRun::from_result(&res, wall, peak_rss_bytes()))
}

/// Run the trace-replay cell: generate the (naive, light, M = 500)
/// workload once, freeze it to a temp trace file, and replay it through
/// all three paths.  The temp file is removed afterwards.
pub fn run_trace_suite(
    quick: bool,
    mut progress: impl FnMut(&TraceCell),
) -> Result<Vec<TraceCell>, String> {
    let horizon = suite_horizon(quick);
    let machines = SUITE_MACHINES[0];
    let mut base = SimConfig::default();
    base.machines = machines;
    base.horizon = horizon;
    base.use_runtime = false;
    base.scheduler = SchedulerKind::Naive;
    let gen_cfg = WorkloadConfig::paper(LIGHT_LAMBDA);
    let workload = generator::generate(&gen_cfg, horizon, base.seed);
    let jobs = workload.specs.len();
    let path = std::env::temp_dir()
        .join(format!("specsim_bench_trace_{}.csv", std::process::id()));
    crate::cluster::trace::save(&workload, &path)?;
    let wl_cfg = WorkloadConfig::trace(path.to_string_lossy().into_owned());
    let window = match &wl_cfg {
        WorkloadConfig::Trace { window, .. } => *window,
        _ => unreachable!(),
    };
    let materialized =
        time_simulation(&base, &wl_cfg, workload, SchedulerKind::Naive, true, true)?;
    let streamed = time_streamed(&base, &wl_cfg, None)?;
    let capped = time_streamed(&base, &wl_cfg, Some(TRACE_RESIDENT_CAP))?;
    let _ = std::fs::remove_file(&path);
    let cell = TraceCell {
        policy: SchedulerKind::Naive.to_string(),
        lambda: LIGHT_LAMBDA,
        machines,
        jobs,
        window,
        resident_cap: TRACE_RESIDENT_CAP,
        materialized,
        streamed,
        capped,
    };
    progress(&cell);
    Ok(vec![cell])
}

/// Render the trace cells as the EXPERIMENTS.md §Perf companion table.
pub fn trace_markdown(cells: &[TraceCell]) -> String {
    let rss = |r: &ThroughputRun| match r.peak_rss_bytes {
        Some(b) => format!("{:.0} MiB", b as f64 / (1024.0 * 1024.0)),
        None => "n/a".to_string(),
    };
    let mut out = String::from(
        "| policy | M | jobs | window | cap | materialized ev/s | streamed ev/s \
         | capped ev/s | stream overhead | capped peak RSS |\n\
         |---|---|---|---|---|---|---|---|---|---|\n",
    );
    for c in cells {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {:.0} | {:.0} | {:.0} | {:.2}x | {} |\n",
            c.policy,
            c.machines,
            c.jobs,
            c.window,
            c.resident_cap,
            c.materialized.events_per_sec,
            c.streamed.events_per_sec,
            c.capped.events_per_sec,
            c.stream_overhead(),
            rss(&c.capped)
        ));
    }
    out
}

// ----- the sharded serve-plane suite --------------------------------------

/// The serve suite's shard-count axis.
pub const SERVE_SHARDS: [usize; 3] = [1, 2, 4];

/// Machines per serve deployment (divisible by every shard count).
pub const SERVE_MACHINES: usize = 64;

/// One serve cell: a fresh N-shard deployment fed the fixed submission
/// workload through batched submits, timed client-side.
#[derive(Clone, Debug)]
pub struct ServeCell {
    pub shards: usize,
    /// Routing policy label (`"hash"` in the standard suite).
    pub route: String,
    pub machines: usize,
    /// Bulk submissions per pass.
    pub submissions: usize,
    /// Submissions per batched round trip.
    pub batch: usize,
    pub accepted: u64,
    pub rejected: u64,
    /// Best-of-N wall-clock of the bulk phase.
    pub wall_secs: f64,
    /// `submissions / wall_secs` — the headline serve-plane metric.
    pub submissions_per_sec: f64,
    /// Median single-submit round-trip latency (dedicated probe phase on
    /// an unloaded deployment).
    pub p50_submit_secs: f64,
    /// 99th-percentile single-submit round-trip latency.
    pub p99_submit_secs: f64,
    /// Jobs drained before the capped shutdown cut the drain short.
    pub completed_jobs: usize,
}

impl ServeCell {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("shards".into(), Json::Num(self.shards as f64));
        m.insert("route".into(), Json::Str(self.route.clone()));
        m.insert("machines".into(), Json::Num(self.machines as f64));
        m.insert("submissions".into(), Json::Num(self.submissions as f64));
        m.insert("batch".into(), Json::Num(self.batch as f64));
        m.insert("accepted".into(), Json::Num(self.accepted as f64));
        m.insert("rejected".into(), Json::Num(self.rejected as f64));
        m.insert("wall_secs".into(), Json::Num(self.wall_secs));
        m.insert("submissions_per_sec".into(), Json::Num(self.submissions_per_sec));
        m.insert("p50_submit_secs".into(), Json::Num(self.p50_submit_secs));
        m.insert("p99_submit_secs".into(), Json::Num(self.p99_submit_secs));
        m.insert("completed_jobs".into(), Json::Num(self.completed_jobs as f64));
        Json::Obj(m)
    }
}

/// The fixed serve workload: `n` submissions from a dedicated seeded
/// stream (task count ~ U{1..100}, mean duration ~ U[1, 4], α = 2 — the
/// paper's job mix), identical across shard counts so every cell admits
/// the same jobs.
fn serve_workload(n: usize, seed: u64) -> Vec<Submission> {
    let mut rng = Pcg64::new(seed, 0xbe9c);
    (0..n)
        .map(|_| Submission {
            num_tasks: rng.uniform_u64(1, 100) as u32,
            mean_duration: rng.uniform_f64(1.0, 4.0),
            alpha: 2.0,
        })
        .collect()
}

/// A fresh deployment for one serve measurement.  Hour-long tick: no slot
/// boundary fires during the measurement, so the cell times the pure
/// submission path (routing, channel, admission, `add_job`) rather than
/// racing the scheduler for the shard threads.  Watermarks sit far above
/// the bulk backlog so nothing rejects — a reject skips `add_job`, which
/// would let a rejecting cell look faster than an admitting one.  The
/// capped drain (`drain_slots`) keeps shutdown bounded despite the huge
/// undrained backlog.
fn spawn_serve_deployment(shards: usize, sample: bool) -> Result<ShardedHandle, String> {
    let mut cfg = SimConfig::default();
    cfg.machines = SERVE_MACHINES;
    cfg.horizon = f64::INFINITY;
    cfg.use_runtime = false;
    cfg.scheduler = SchedulerKind::Sda;
    let serve = ServeConfig { shards, route: RoutePolicy::Hash, ..Default::default() };
    let mut sm = ShardedMaster::new(cfg, serve);
    sm.tick = Duration::from_secs(3600);
    sm.drain_slots = 50;
    sm.backpressure = Some(Backpressure::new(usize::MAX / 4, usize::MAX / 2));
    if sample {
        sm.sample_every = Some(Duration::from_millis(20));
    }
    sm.spawn()
}

/// Measure one serve cell: a probe phase (single submits on a fresh,
/// unloaded deployment → p50/p99 round-trip latency), then `passes` bulk
/// phases on fresh deployments (batched submits, best wall-clock kept).
/// Returns the cell plus the best pass's sampled metrics CSV.
fn measure_serve_cell(
    shards: usize,
    subs: &[Submission],
    batch: usize,
    passes: u32,
    probes: usize,
) -> Result<(ServeCell, String), String> {
    assert!(passes >= 1 && probes >= 1);
    // latency probes: fresh deployment, no sampler, no backlog
    let mut lat = Vec::with_capacity(probes);
    {
        let handle = spawn_serve_deployment(shards, false)?;
        for sub in serve_workload(probes, 0x960be) {
            let t0 = Instant::now();
            handle.submit(sub)?;
            lat.push(t0.elapsed().as_secs_f64());
        }
        let _ = handle.shutdown()?;
    }
    lat.sort_by(f64::total_cmp);
    let p50 = lat[lat.len() / 2];
    let p99 = lat[(lat.len() * 99) / 100];
    // bulk passes: best-of-N against scheduler noise
    let mut best: Option<(f64, u64, u64, usize, String)> = None;
    for _ in 0..passes {
        let handle = spawn_serve_deployment(shards, true)?;
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        let t0 = Instant::now();
        for chunk in subs.chunks(batch) {
            for (_, r) in handle.submit_batch(chunk)? {
                if r.is_accepted() {
                    accepted += 1;
                } else {
                    rejected += 1;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let report = handle.shutdown()?;
        let csv = report.series.map(|s| s.csv()).unwrap_or_default();
        let completed = report.shards.iter().map(|r| r.completed.len()).sum();
        let better = match &best {
            None => true,
            Some((w, ..)) => wall < *w,
        };
        if better {
            best = Some((wall, accepted, rejected, completed, csv));
        }
    }
    let (wall, accepted, rejected, completed, csv) = best.expect("passes >= 1");
    let cell = ServeCell {
        shards,
        route: RoutePolicy::Hash.to_string(),
        machines: SERVE_MACHINES,
        submissions: subs.len(),
        batch,
        accepted,
        rejected,
        wall_secs: wall,
        submissions_per_sec: subs.len() as f64 / wall.max(1e-12),
        p50_submit_secs: p50,
        p99_submit_secs: p99,
        completed_jobs: completed,
    };
    Ok((cell, csv))
}

/// Run the serve suite: [`SERVE_SHARDS`] cells on the identical fixed
/// workload.  Returns the cells plus the concatenated per-cell metrics
/// time-series CSV (cells separated by `# serve cell:` comment lines).
pub fn run_serve_suite(
    quick: bool,
    mut progress: impl FnMut(&ServeCell),
) -> Result<(Vec<ServeCell>, String), String> {
    let submissions = if quick { 30_000 } else { 120_000 };
    let subs = serve_workload(submissions, 0x5e7e);
    let mut cells = Vec::new();
    let mut csv = String::new();
    for &shards in &SERVE_SHARDS {
        let (cell, cell_csv) = measure_serve_cell(shards, &subs, 256, 3, 200)?;
        csv.push_str(&format!("# serve cell: shards={} route={}\n", cell.shards, cell.route));
        csv.push_str(&cell_csv);
        progress(&cell);
        cells.push(cell);
    }
    Ok((cells, csv))
}

/// The serve acceptance gate CI enforces (`bench --serve --check-serve`):
/// 2-shard sustained throughput must reach at least 1.4× the 1-shard cell.
pub fn check_serve_gate(cells: &[ServeCell]) -> Result<(), String> {
    let find = |n: usize| {
        cells
            .iter()
            .find(|c| c.shards == n && c.route == "hash")
            .ok_or_else(|| format!("serve gate: the {n}-shard hash cell is missing"))
    };
    let one = find(1)?;
    let two = find(2)?;
    let ratio = two.submissions_per_sec / one.submissions_per_sec.max(1e-12);
    if ratio < 1.4 {
        return Err(format!(
            "serve gate: 2-shard throughput at {ratio:.2}x the 1-shard cell (< 1.4x) — \
             {:.0} vs {:.0} submissions/sec",
            two.submissions_per_sec, one.submissions_per_sec
        ));
    }
    Ok(())
}

/// Render the serve cells as the EXPERIMENTS.md §Perf companion table.
pub fn serve_markdown(cells: &[ServeCell]) -> String {
    let mut out = String::from(
        "| shards | route | M | submissions | batch | subs/sec | p50 submit | p99 submit \
         | rejected |\n\
         |---|---|---|---|---|---|---|---|---|\n",
    );
    for c in cells {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {:.0} | {:.1} µs | {:.1} µs | {} |\n",
            c.shards,
            c.route,
            c.machines,
            c.submissions,
            c.batch,
            c.submissions_per_sec,
            c.p50_submit_secs * 1e6,
            c.p99_submit_secs * 1e6,
            c.rejected
        ));
    }
    out
}

/// Render a finished suite as the EXPERIMENTS.md §Perf markdown table —
/// what CI appends to the job summary so the committed table can be
/// refreshed from a real measured artifact by copy-paste.
pub fn throughput_markdown(cells: &[ThroughputCell]) -> String {
    let mut out = String::from(
        "| policy | load | M | slot_dt | indexed ev/s | scan ev/s | speedup \
         | ticks fired/skipped | skip | wakeup speedup |\n\
         |---|---|---|---|---|---|---|---|---|---|\n",
    );
    for c in cells {
        out.push_str(&format!(
            "| {} | {} | {} | {} | {:.0} | {:.0} | {:.2}x | {}/{} | {:.0}% | {:.2}x |\n",
            c.policy,
            c.load,
            c.machines,
            c.slot_dt,
            c.indexed.events_per_sec,
            c.scan.events_per_sec,
            c.speedup(),
            c.indexed.ticks_fired,
            c.indexed.ticks_skipped,
            100.0 * c.indexed.skip_ratio(),
            c.wakeup_speedup()
        ));
    }
    out
}

/// Serialize a finished suite (throughput + scale + flip + serve + trace
/// + churn cells) to the `BENCH_sim.json` document.
pub fn throughput_json(
    cells: &[ThroughputCell],
    scale: &[ScaleCell],
    flips: &[FlipCell],
    serve: &[ServeCell],
    trace: &[TraceCell],
    churn: &[ChurnCell],
    quick: bool,
) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("schema".into(), Json::Str(BENCH_SCHEMA.to_string()));
    m.insert("suite".into(), Json::Str("throughput".to_string()));
    // distinguishes a real harness run from the committed schema seed
    // (which carries `"measured": false`)
    m.insert("measured".into(), Json::Bool(true));
    m.insert("quick".into(), Json::Bool(quick));
    m.insert("horizon".into(), Json::Num(suite_horizon(quick)));
    m.insert(
        "note".into(),
        Json::Str(
            "indexed = SchedIndex hot path, wakeup planner on (default); \
             scan = retained naive full-scan reference (sched_index = false); \
             polled = retired fire-every-slot loop (wakeup = false); \
             speedup = indexed/scan events_per_sec; wakeup_speedup = \
             polled/indexed wall_secs; skip_ratio = indexed ticks_skipped \
             over the grid. Light cells run slot_dt = 0.001 (the \
             polling-dominated regime), heavy cells 1.0. scale_cells time \
             the (naive, light) M in {1e5, 1e6} cells per event-queue \
             backend (calendar vs binary-heap; identical popped events); \
             quick runs omit M = 1e6. flip_cells (v4) time the (sda, \
             light, M=4000) cell with the ON/OFF Markov slowdown flips \
             running vs the static slowdown scenario; overhead = \
             flips/static wall_secs (flip runs pop strictly more events). \
             serve_cells (v5) time the sharded live coordinator: sustained \
             submissions/sec through batched submits and single-submit \
             p50/p99 round-trip latency at shards in {1, 2, 4}, hash \
             routing, on a fixed workload (empty unless bench ran with \
             --serve). trace_cells (v6) replay one frozen workload three \
             ways — materialized up front, streamed through the \
             bounded-window trace reader, and streamed with \
             max_resident_jobs record recycling — all three simulating \
             bit-identical dynamics; stream_overhead = streamed/\
             materialized wall_secs. churn_cells (v7) time the (sda, \
             light, M=4000) cell with the machine crash/recovery process \
             running (MTTF,MTTR = 40,10) vs the churn-free baseline; \
             overhead = churned/baseline wall_secs (churn runs pop \
             strictly more events and re-execute lost work). \
             peak_rss_bytes = Linux VmHWM, reset \
             per run; null elsewhere. Regenerate: \
             cargo run --release -- bench --serve"
                .to_string(),
        ),
    );
    m.insert("cells".into(), Json::Arr(cells.iter().map(|c| c.to_json()).collect()));
    m.insert("scale_cells".into(), Json::Arr(scale.iter().map(|c| c.to_json()).collect()));
    m.insert("flip_cells".into(), Json::Arr(flips.iter().map(|c| c.to_json()).collect()));
    m.insert("serve_cells".into(), Json::Arr(serve.iter().map(|c| c.to_json()).collect()));
    m.insert("trace_cells".into(), Json::Arr(trace.iter().map(|c| c.to_json()).collect()));
    m.insert("churn_cells".into(), Json::Arr(churn.iter().map(|c| c.to_json()).collect()));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(m.iters, 5);
        assert!(m.min <= m.median && m.median <= m.mean * 5);
    }

    #[test]
    fn ordering_of_stats() {
        let mut x = 0u64;
        let m = bench("sum", 0, 9, || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.min <= m.median);
    }

    #[test]
    fn throughput_cell_measures_and_serializes() {
        let mut base = SimConfig::default();
        base.machines = 40;
        base.horizon = 60.0;
        base.use_runtime = false;
        base.slot_dt = 0.1;
        let wl_cfg = WorkloadConfig::paper(0.3);
        let workload = generator::generate(&wl_cfg, base.horizon, 1);
        let indexed =
            time_simulation(&base, &wl_cfg, workload.clone(), SchedulerKind::Sda, true, true)
                .unwrap();
        let scan =
            time_simulation(&base, &wl_cfg, workload.clone(), SchedulerKind::Sda, false, true)
                .unwrap();
        let polled =
            time_simulation(&base, &wl_cfg, workload, SchedulerKind::Sda, true, false).unwrap();
        // all three runs simulate the identical system: same events
        // popped, same jobs completed, same heap high-water mark, same
        // slot grid — only the wall clock (and the fired/skipped split)
        // may differ
        assert_eq!(indexed.events, scan.events);
        assert_eq!(indexed.events, polled.events);
        assert_eq!(indexed.completed_jobs, scan.completed_jobs);
        assert_eq!(indexed.completed_jobs, polled.completed_jobs);
        assert_eq!(indexed.peak_event_queue, scan.peak_event_queue);
        assert_eq!(
            indexed.ticks_fired + indexed.ticks_skipped,
            polled.ticks_fired,
            "identical slot grid on both wakeup modes"
        );
        assert_eq!(polled.ticks_skipped, 0);
        assert!(indexed.ticks_skipped > 0, "light load must skip slots");
        assert!(indexed.skip_ratio() > 0.0 && indexed.skip_ratio() < 1.0);
        assert!(indexed.events > 0);
        assert!(indexed.events_per_sec > 0.0);
        let cell = ThroughputCell {
            policy: "sda".to_string(),
            load: "light",
            lambda: 0.3,
            machines: 40,
            slot_dt: 0.1,
            indexed,
            scan,
            polled,
        };
        assert!(cell.speedup() > 0.0);
        assert!(cell.wakeup_speedup() > 0.0);
        let md = throughput_markdown(std::slice::from_ref(&cell));
        assert!(md.starts_with("| policy |"));
        assert!(md.contains("| sda | light | 40 | 0.1 |"));
        let doc = throughput_json(&[cell], &[], &[], &[], &[], &[], true);
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back.get("schema").unwrap().as_str(), Some(BENCH_SCHEMA));
        assert_eq!(back.get("measured"), Some(&Json::Bool(true)));
        let cells = back.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].get("policy").unwrap().as_str(), Some("sda"));
        assert_eq!(cells[0].get("machines").unwrap().as_usize(), Some(40));
        assert!(cells[0].path(&["indexed", "events_per_sec"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(cells[0].path(&["polled", "ticks_fired"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(cells[0].get("wakeup_speedup").unwrap().as_f64().is_some());
        assert!(cells[0].get("skip_ratio").unwrap().as_f64().unwrap() > 0.0);
        // v3: the peak-RSS column round-trips (a number on Linux, null
        // elsewhere) and the scale_cells array is always present
        let rss = cells[0].path(&["indexed", "peak_rss_bytes"]).unwrap();
        if cfg!(target_os = "linux") {
            assert!(rss.as_f64().unwrap() > 0.0);
        } else {
            assert_eq!(rss, &Json::Null);
        }
        assert_eq!(back.get("scale_cells").unwrap().as_arr().unwrap().len(), 0);
        // v4: the flip_cells array is always present
        assert_eq!(back.get("flip_cells").unwrap().as_arr().unwrap().len(), 0);
        // v5: the serve_cells array is always present
        assert_eq!(back.get("serve_cells").unwrap().as_arr().unwrap().len(), 0);
        // v6: the trace_cells array is always present
        assert_eq!(back.get("trace_cells").unwrap().as_arr().unwrap().len(), 0);
        // v7: the churn_cells array is always present
        assert_eq!(back.get("churn_cells").unwrap().as_arr().unwrap().len(), 0);
    }

    /// The trace cell's three paths simulate the identical system — same
    /// events popped, same completions — and the JSON/markdown renderings
    /// carry the overhead ratio.  Runs on a tiny horizon via the same
    /// machinery the suite uses, minus the suite-scale workload.
    #[test]
    fn trace_cell_paths_agree_and_serialize() {
        let mut base = SimConfig::default();
        base.machines = 40;
        base.horizon = 60.0;
        base.use_runtime = false;
        base.scheduler = SchedulerKind::Naive;
        let gen_cfg = WorkloadConfig::paper(0.3);
        let workload = generator::generate(&gen_cfg, base.horizon, base.seed);
        let jobs = workload.specs.len();
        let path = std::env::temp_dir()
            .join(format!("specsim_trace_cell_test_{}.csv", std::process::id()));
        crate::cluster::trace::save(&workload, &path).unwrap();
        let wl_cfg = WorkloadConfig::trace(path.to_string_lossy().into_owned());
        let materialized =
            time_simulation(&base, &wl_cfg, workload, SchedulerKind::Naive, true, true).unwrap();
        let streamed = time_streamed(&base, &wl_cfg, None).unwrap();
        let capped = time_streamed(&base, &wl_cfg, Some(8)).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(materialized.events, streamed.events, "streaming is bit-identical");
        assert_eq!(materialized.events, capped.events, "recycling never changes dynamics");
        assert_eq!(materialized.completed_jobs, streamed.completed_jobs);
        assert_eq!(materialized.completed_jobs, capped.completed_jobs);
        // Eager mode pre-pushes every arrival into the heap; the streamed
        // path admits them outside it, so its peak can only be smaller.
        assert!(streamed.peak_event_queue <= materialized.peak_event_queue);
        let cell = TraceCell {
            policy: "naive".into(),
            lambda: 0.3,
            machines: 40,
            jobs,
            window: crate::workload::DEFAULT_WINDOW,
            resident_cap: 8,
            materialized,
            streamed,
            capped,
        };
        assert!(cell.stream_overhead() > 0.0);
        let j = cell.to_json();
        assert_eq!(j.get("machines").unwrap().as_usize(), Some(40));
        assert_eq!(j.get("jobs").unwrap().as_usize(), Some(jobs));
        assert!(j.path(&["streamed", "events_per_sec"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(j.path(&["capped", "completed_jobs"]).unwrap().as_usize().unwrap() > 0);
        let md = trace_markdown(std::slice::from_ref(&cell));
        assert!(md.starts_with("| policy |"));
        assert!(md.contains("| naive | 40 |"));
    }

    fn synthetic_serve_cell(shards: usize, sps: f64) -> ServeCell {
        ServeCell {
            shards,
            route: "hash".into(),
            machines: SERVE_MACHINES,
            submissions: 1000,
            batch: 256,
            accepted: 1000,
            rejected: 0,
            wall_secs: 1000.0 / sps,
            submissions_per_sec: sps,
            p50_submit_secs: 5e-6,
            p99_submit_secs: 40e-6,
            completed_jobs: 10,
        }
    }

    #[test]
    fn serve_cell_serializes_and_renders() {
        let cell = synthetic_serve_cell(2, 50_000.0);
        let j = cell.to_json();
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("route").unwrap().as_str(), Some("hash"));
        assert!(j.get("submissions_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("p99_submit_secs").unwrap().as_f64().unwrap() > 0.0);
        let md = serve_markdown(std::slice::from_ref(&cell));
        assert!(md.starts_with("| shards |"));
        assert!(md.contains("| 2 | hash | 64 | 1000 | 256 | 50000 |"));
    }

    #[test]
    fn serve_gate_compares_one_and_two_shard_cells() {
        let ok = vec![synthetic_serve_cell(1, 10_000.0), synthetic_serve_cell(2, 15_000.0)];
        check_serve_gate(&ok).unwrap();
        let flat = vec![synthetic_serve_cell(1, 10_000.0), synthetic_serve_cell(2, 12_000.0)];
        let err = check_serve_gate(&flat).unwrap_err();
        assert!(err.contains("serve gate"), "{err}");
        assert!(check_serve_gate(&[synthetic_serve_cell(1, 10_000.0)]).is_err());
        assert!(check_serve_gate(&[]).is_err());
    }

    /// A tiny end-to-end serve cell: the measurement machinery works
    /// (deployment spawns, probes and bulk batches flow, CSV comes back).
    /// Never asserts scaling — that's the CI gate's job on real hardware.
    #[test]
    fn measure_serve_cell_end_to_end() {
        let subs = serve_workload(100, 0x5e7e);
        let (cell, csv) = measure_serve_cell(2, &subs, 32, 1, 20).unwrap();
        assert_eq!(cell.shards, 2);
        assert_eq!(cell.submissions, 100);
        assert_eq!(cell.accepted + cell.rejected, 100);
        assert_eq!(cell.rejected, 0, "watermarks sit far above the bulk backlog");
        assert!(cell.submissions_per_sec > 0.0);
        assert!(cell.p50_submit_secs > 0.0 && cell.p50_submit_secs <= cell.p99_submit_secs);
        assert!(csv.starts_with("t_secs,shard,kind,name,value"));
        assert!(csv.contains("jobs_submitted"));
    }

    #[test]
    fn serve_workload_is_deterministic_and_in_range() {
        let a = serve_workload(50, 0x5e7e);
        let b = serve_workload(50, 0x5e7e);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.num_tasks, y.num_tasks);
            assert_eq!(x.mean_duration.to_bits(), y.mean_duration.to_bits());
        }
        for s in &a {
            assert!((1..=100).contains(&s.num_tasks));
            assert!((1.0..=4.0).contains(&s.mean_duration));
            assert_eq!(s.alpha, 2.0);
        }
    }

    /// The flip cell measures a genuinely different system from the
    /// static one (the `SlowdownFlip` stream adds events) and its JSON /
    /// markdown renderings carry the overhead ratio.
    #[test]
    fn flip_cell_measures_and_serializes() {
        let mut base = SimConfig::default();
        base.machines = 40;
        base.horizon = 60.0;
        base.use_runtime = false;
        base.slot_dt = 0.1;
        let wl_cfg = WorkloadConfig::paper(0.3);
        let workload = generator::generate(&wl_cfg, base.horizon, 1);
        let sd = SlowdownConfig::new(0.2, 3.0).with_rates(0.5, 1.0);
        let mut flip_cfg = base.clone();
        flip_cfg.slowdown = Some(sd);
        let flips =
            time_simulation(&flip_cfg, &wl_cfg, workload.clone(), SchedulerKind::Sda, true, true)
                .unwrap();
        let mut static_cfg = base;
        static_cfg.slowdown = Some(SlowdownConfig::new(0.2, 3.0));
        let static_run =
            time_simulation(&static_cfg, &wl_cfg, workload, SchedulerKind::Sda, true, true)
                .unwrap();
        assert!(
            flips.events > static_run.events,
            "the flip process must add events: {} vs {}",
            flips.events,
            static_run.events
        );
        let cell = FlipCell {
            policy: "sda".into(),
            load: "light",
            lambda: 0.3,
            machines: 40,
            slot_dt: 0.1,
            slowdown: crate::cluster::machine::format_slowdown(&sd),
            flips,
            static_run,
        };
        assert!(cell.overhead() > 0.0);
        let j = cell.to_json();
        assert_eq!(j.get("machines").unwrap().as_usize(), Some(40));
        assert!(j.path(&["flips", "events_per_sec"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(j.path(&["static", "events"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("overhead").unwrap().as_f64().is_some());
        assert_eq!(j.get("slowdown").unwrap().as_str(), Some("0.2x3.0@0.5,1.0"));
        let md = flip_markdown(std::slice::from_ref(&cell));
        assert!(md.starts_with("| policy |"));
        assert!(md.contains("| sda | light | 40 | 0.2x3.0@0.5,1.0 |"));
    }

    /// The churn cell measures a genuinely different system from the
    /// churn-free one (the crash/recovery stream adds events) and its
    /// JSON / markdown renderings carry the overhead ratio.
    #[test]
    fn churn_cell_measures_and_serializes() {
        let mut base = SimConfig::default();
        base.machines = 40;
        base.horizon = 60.0;
        base.use_runtime = false;
        base.slot_dt = 0.1;
        let wl_cfg = WorkloadConfig::paper(0.3);
        let workload = generator::generate(&wl_cfg, base.horizon, 1);
        let ch = ChurnConfig::new(20.0, 5.0);
        let mut churn_cfg = base.clone();
        churn_cfg.churn = Some(ch);
        let churned =
            time_simulation(&churn_cfg, &wl_cfg, workload.clone(), SchedulerKind::Sda, true, true)
                .unwrap();
        let baseline =
            time_simulation(&base, &wl_cfg, workload, SchedulerKind::Sda, true, true).unwrap();
        assert!(
            churned.events > baseline.events,
            "the churn process must add events: {} vs {}",
            churned.events,
            baseline.events
        );
        let cell = ChurnCell {
            policy: "sda".into(),
            load: "light",
            lambda: 0.3,
            machines: 40,
            slot_dt: 0.1,
            churn: crate::cluster::machine::format_churn(&ch),
            churned,
            baseline,
        };
        assert!(cell.overhead() > 0.0);
        let j = cell.to_json();
        assert_eq!(j.get("machines").unwrap().as_usize(), Some(40));
        assert!(j.path(&["churned", "events_per_sec"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(j.path(&["baseline", "events"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("overhead").unwrap().as_f64().is_some());
        assert_eq!(j.get("churn").unwrap().as_str(), Some("20.0,5.0"));
        let md = churn_markdown(std::slice::from_ref(&cell));
        assert!(md.starts_with("| policy |"));
        assert!(md.contains("| sda | light | 40 | 20.0,5.0 |"));
    }

    /// Both event-queue backends simulate the identical system at the
    /// bench layer: same events popped, same completions, same grid.
    #[test]
    fn scale_backends_pop_identical_events() {
        let mut base = SimConfig::default();
        base.machines = 40;
        base.horizon = 60.0;
        base.use_runtime = false;
        base.slot_dt = 0.1;
        let wl_cfg = WorkloadConfig::paper(0.3);
        let workload = generator::generate(&wl_cfg, base.horizon, 1);
        let mut cal_cfg = base.clone();
        cal_cfg.event_queue = EventQueueKind::Calendar;
        let calendar = best_of(&cal_cfg, &wl_cfg, &workload, 2).unwrap();
        let mut heap_cfg = base;
        heap_cfg.event_queue = EventQueueKind::BinaryHeap;
        let heap = best_of(&heap_cfg, &wl_cfg, &workload, 2).unwrap();
        assert_eq!(calendar.events, heap.events);
        assert_eq!(calendar.completed_jobs, heap.completed_jobs);
        assert_eq!(calendar.ticks_fired, heap.ticks_fired);
        assert_eq!(calendar.ticks_skipped, heap.ticks_skipped);
        let cell = ScaleCell {
            policy: "naive".into(),
            load: "light",
            lambda: 0.3,
            machines: 40,
            slot_dt: 0.1,
            calendar,
            heap,
        };
        assert!(cell.queue_speedup() > 0.0);
        let j = cell.to_json();
        assert_eq!(j.get("machines").unwrap().as_usize(), Some(40));
        assert!(j.path(&["calendar", "events_per_sec"]).unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("queue_speedup").unwrap().as_f64().is_some());
        let md = scale_markdown(std::slice::from_ref(&cell));
        assert!(md.starts_with("| policy |"));
        assert!(md.contains("| naive | light | 40 | 0.1 |"));
    }

    /// The scale gate reads the M = 10^5 cell and enforces the
    /// calendar-at-least-matches-heap bar.
    #[test]
    fn scale_gate_checks_the_m1e5_cell() {
        let run = |wall: f64| ThroughputRun {
            wall_secs: wall,
            events: 1000,
            events_per_sec: 1000.0 / wall,
            ticks_fired: 10,
            ticks_skipped: 90,
            slot_hook_secs: 0.0,
            peak_event_queue: 10,
            completed_jobs: 5,
            peak_rss_bytes: Some(1 << 20),
        };
        let cell = |cal_wall: f64, heap_wall: f64| ScaleCell {
            policy: "naive".into(),
            load: "light",
            lambda: LIGHT_LAMBDA,
            machines: 100_000,
            slot_dt: WAKEUP_SLOT_DT,
            calendar: run(cal_wall),
            heap: run(heap_wall),
        };
        assert!(check_scale_gate(&[cell(0.8, 1.0)]).is_ok());
        assert!(check_scale_gate(&[cell(1.0, 1.0)]).is_ok(), "matching the heap passes");
        let err = check_scale_gate(&[cell(1.2, 1.0)]).unwrap_err();
        assert!(err.contains("scale gate"), "{err}");
        assert!(check_scale_gate(&[]).is_err(), "missing cell must fail");
    }

    /// The CI gate logic reads the right cell and enforces both bars.
    #[test]
    fn wakeup_gate_checks_the_naive_light_cell() {
        let run = |wall: f64, fired: u64, skipped: u64| ThroughputRun {
            wall_secs: wall,
            events: 100,
            events_per_sec: 100.0 / wall,
            ticks_fired: fired,
            ticks_skipped: skipped,
            slot_hook_secs: 0.0,
            peak_event_queue: 10,
            completed_jobs: 5,
            peak_rss_bytes: None,
        };
        let cell = |wakeup_wall: f64, fired: u64, skipped: u64| ThroughputCell {
            policy: "naive".into(),
            load: "light",
            lambda: 0.3,
            machines: 4000,
            slot_dt: WAKEUP_SLOT_DT,
            indexed: run(wakeup_wall, fired, skipped),
            scan: run(1.0, fired, skipped),
            polled: run(1.0, fired + skipped, 0),
        };
        assert!(check_wakeup_gate(&[cell(0.4, 100, 900)]).is_ok());
        let err = check_wakeup_gate(&[cell(0.9, 100, 900)]).unwrap_err();
        assert!(err.contains("wakeup_speedup"), "{err}");
        let err = check_wakeup_gate(&[cell(0.4, 900, 100)]).unwrap_err();
        assert!(err.contains("skip ratio"), "{err}");
        assert!(check_wakeup_gate(&[]).is_err(), "missing cell must fail");
    }

    #[test]
    fn suite_covers_canonical_and_composed_policies() {
        let kinds = suite_policies();
        assert_eq!(kinds.len(), 9, "7 canonical + 2 composed");
        let labels: Vec<String> = kinds.iter().map(|k| k.to_string()).collect();
        assert!(labels.contains(&"fifo+sda".to_string()));
        assert!(labels.contains(&"est-srpt+mantri".to_string()));
    }

    #[test]
    fn heavy_lambda_tracks_cluster_size() {
        // λ^U is linear in M for a fixed job mix (Eq. 5)
        let (small, big) = (heavy_lambda(500), heavy_lambda(4000));
        assert!(small > 0.0);
        assert!((big / small - 8.0).abs() < 1e-9, "{big} vs {small}");
        // and the paper's M = 3000 set-up puts the cutoff near 17.8
        assert!((heavy_lambda(3000) / 0.9 - 17.82).abs() < 0.1);
    }
}
