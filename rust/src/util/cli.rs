//! Tiny CLI flag parser: `--flag value`, `--flag=value`, bare `--switch`,
//! and positional arguments, with typed accessors and a generated usage
//! line.  Enough for the `specsim` subcommands without external deps.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// `known_switches` lists flags that take no value.
    pub fn parse(
        argv: &[String],
        known_switches: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .ok_or_else(|| format!("--{name} needs a value"))?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn str(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number '{v}'")),
        }
    }

    pub fn f64_opt(&self, name: &str) -> Result<Option<f64>, String> {
        match self.flags.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: bad number '{v}'")),
        }
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn string(&self, name: &str, default: &str) -> String {
        self.flags
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn flags_and_positionals() {
        let a = Args::parse(&argv("fig2 --machines 300 --scale=0.5 --no-runtime"), &["no-runtime"])
            .unwrap();
        assert_eq!(a.positional(), &["fig2".to_string()]);
        assert_eq!(a.usize("machines", 0).unwrap(), 300);
        assert_eq!(a.f64("scale", 1.0).unwrap(), 0.5);
        assert!(a.has("no-runtime"));
        assert!(!a.has("other"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv(""), &[]).unwrap();
        assert_eq!(a.f64("lambda", 6.0).unwrap(), 6.0);
        assert_eq!(a.string("out", "results"), "results");
        assert_eq!(a.f64_opt("sigma").unwrap(), None);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv("--machines"), &[]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(&argv("--lambda abc"), &[]).unwrap();
        assert!(a.f64("lambda", 1.0).is_err());
    }
}
