//! Minimal JSON: a recursive-descent parser and a writer, sufficient for
//! `artifacts/manifest.json` (objects, arrays, strings, numbers, bools,
//! null; no \u escapes beyond BMP pass-through).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.get(path[0]).get(path[1])...`
    pub fn path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "statics": {"batch": 64, "etas": [0.2, 0.3, 0.4]},
            "artifacts": {"p2": {"file": "p2.hlo.txt", "ok": true, "x": null}}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.path(&["statics", "batch"]).unwrap().as_usize(), Some(64));
        let etas = j.path(&["statics", "etas"]).unwrap().as_arr().unwrap();
        assert_eq!(etas.len(), 3);
        assert_eq!(etas[1].as_f64(), Some(0.3));
        assert_eq!(
            j.path(&["artifacts", "p2", "file"]).unwrap().as_str(),
            Some("p2.hlo.txt")
        );
        assert_eq!(j.path(&["artifacts", "p2", "ok"]), Some(&Json::Bool(true)));
        assert_eq!(j.path(&["artifacts", "p2", "x"]), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":{"d":false}}"#;
        let j = Json::parse(text).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\": 1} extra").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-1.5", -1.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }
}
