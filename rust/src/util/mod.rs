//! In-tree substrates for functionality the offline build cannot pull from
//! crates.io: a JSON reader (artifact manifests), a TOML-subset reader
//! (config files), a CLI flag parser, and a micro-bench timing harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod toml_lite;

pub use json::Json;
