//! In-tree substrates for functionality the offline build cannot pull from
//! crates.io: a JSON reader (artifact manifests), a TOML-subset reader
//! (config files), a CLI flag parser, and the bench harness (micro-bench
//! timing plus the standardized simulator-throughput suite behind the
//! `bench` CLI subcommand).

pub mod bench;
pub mod cli;
pub mod json;
pub mod toml_lite;

pub use json::Json;

/// Parse an environment variable, falling back to `default` when the
/// variable is unset or malformed.  The examples and benches use this for
/// the SPECSIM_SCALE / SPECSIM_THREADS knobs.
pub fn env_or<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}
