//! A TOML subset sufficient for specsim config files: `key = value` pairs,
//! `[table]` headers (one level), strings, integers, floats, booleans and
//! comments.  No arrays-of-tables, no multi-line strings, no dotted keys.

use std::collections::BTreeMap;

/// A parsed scalar.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Flat document: top-level keys plus `table.key` entries.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (n, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad table header", n + 1))?;
                prefix = format!("{}.", name.trim());
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", n + 1))?;
            let key = format!("{prefix}{}", k.trim());
            entries.insert(key, parse_value(v.trim(), n + 1)?);
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }
    pub fn i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_i64)
    }
    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }
    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, String> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| format!("line {line}: unterminated string"))?;
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("line {line}: cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_config_shape() {
        let doc = Doc::parse(
            r#"
            # cluster
            machines = 3000
            horizon = 1500.0
            scheduler = "sca"   # policy
            use_runtime = true

            [workload]
            lambda = 6.0
            "#,
        )
        .unwrap();
        assert_eq!(doc.i64("machines"), Some(3000));
        assert_eq!(doc.f64("horizon"), Some(1500.0));
        assert_eq!(doc.str("scheduler"), Some("sca"));
        assert_eq!(doc.bool("use_runtime"), Some(true));
        assert_eq!(doc.f64("workload.lambda"), Some(6.0));
    }

    #[test]
    fn int_as_f64() {
        let doc = Doc::parse("x = 3").unwrap();
        assert_eq!(doc.f64("x"), Some(3.0));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Doc::parse("no equals here").is_err());
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("x = ???").is_err());
    }

    #[test]
    fn hash_inside_string() {
        let doc = Doc::parse(r#"x = "a#b" # real comment"#).unwrap();
        assert_eq!(doc.str("x"), Some("a#b"));
    }
}
