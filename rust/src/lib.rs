//! # specsim — speculative execution for MapReduce-like clusters
//!
//! Production-quality reproduction of *Optimization for Speculative Execution
//! of Multiple Jobs in a MapReduce-like Cluster* (Xu & Lau, 2014).
//!
//! The crate is organised as the paper's system is:
//!
//! * [`stats`] — random-variate substrate: seeded PCG64 streams, the Pareto
//!   task-duration model, empirical CDF/summary accounting.
//! * [`cluster`] — the MapReduce-like cluster: machines, jobs/tasks/copies,
//!   a discrete-event simulator with slotted scheduling decisions, workload
//!   generators and trace I/O.
//! * [`scheduler`] — speculative-execution policies as composable
//!   pipelines (`ordering+rule[*budget]`): the paper's SCA (Algorithm 1),
//!   SDA (Sec. V) and ESE (Algorithm 2) and the baselines they are
//!   evaluated against (naive, blind cloning, Mantri, LATE) are canonical
//!   compositions of a job ordering, a speculation rule and a copy
//!   budget.
//! * [`estimator`] — the remaining-time estimation contract every policy's
//!   speculation rule queries: blind (conditional Pareto), revealed
//!   (post-checkpoint truth, Sec. V) and speed-aware (divide by the
//!   running copy's advertised host speed) implementations.
//! * [`opt`] — the optimization machinery: Pareto order-statistic math,
//!   the P2 gradient-projection solver, the P3/Theorem-3 solution and the
//!   ESE sigma* analysis (Eq. 30–33).
//! * [`analysis`] — M/G/1 task-delay model and the lightly/heavily loaded
//!   cutoff threshold `lambda^U` (Sec. III-B).
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); python never runs on the request path.
//! * [`coordinator`] — async (tokio) streaming master: submission channel,
//!   slot loop, routing, backpressure and metrics export.
//! * [`metrics`] — per-job flowtime/resource accounting and the per-figure
//!   report writers used by the benchmark harness.
//! * [`workload`] — streaming trace replay: chunked zero-dep CSV/JSONL
//!   trace reading with structured diagnostics, the pull-based
//!   [`workload::JobSource`] contract unifying generators / materialized
//!   workloads / streamed traces, and the bounded lookahead window that
//!   lets million-job traces run in O(window) workload memory.
//! * [`experiment`] — the parallel sweep engine: declarative
//!   scheduler x load x seed grids on homogeneous or heterogeneous
//!   cluster scenarios, fanned out across scoped worker threads with a
//!   shared pre-sampled workload per grid point.
//! * [`figures`] — one driver per paper figure (Fig. 1–6 + the threshold
//!   experiment), all routed through the experiment engine; shared by the
//!   CLI, the examples and `cargo bench`.

pub mod analysis;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod estimator;
pub mod experiment;
pub mod figures;
pub mod metrics;
pub mod opt;
pub mod runtime;
pub mod scheduler;
pub mod stats;
pub mod util;
pub mod workload;

pub use config::{SimConfig, WorkloadConfig};
pub use cluster::sim::{SimResult, Simulator};
pub use estimator::RemainingTime;
pub use experiment::{ExperimentSpec, Runner, SweepResult};
pub use scheduler::SchedulerKind;
