//! No speculation at all: one copy per task, SRPT-ordered levels 2/3.
//! This is the "without backup" baseline of Fig. 5 and the service model
//! behind the no-speculation M/G/1 delay W_t (Eq. 1).
//!
//! **Retained monolith.**  Since the policy-pipeline redesign this is the
//! `legacy_sched` equivalence reference for the canonical composition
//! `srpt+never` (see `scheduler::pipeline`); `tests/pipeline_equivalence.rs`
//! proves byte-identical sweep CSVs, after which the monolith can go.

use crate::cluster::sim::Cluster;

use super::{srpt, Scheduler};

pub struct Naive;

impl Scheduler for Naive {
    fn name(&self) -> &str {
        "naive"
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        srpt::schedule_running(cl);
        srpt::schedule_queued_single(cl);
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};

    #[test]
    fn never_launches_backups() {
        let mut cfg = SimConfig::default();
        cfg.machines = 60;
        cfg.horizon = 300.0;
        let wl = generate(&WorkloadConfig::paper(0.5), cfg.horizon, 5);
        let res = Simulator::new(cfg, wl, Box::new(super::Naive)).run();
        assert_eq!(res.speculative_launches, 0);
        assert!(!res.completed.is_empty());
    }
}
