//! Straggler Detection Algorithm (Sec. V-B).
//!
//! Level 1 (event-driven, not slot-gated): when a task's first copy crosses
//! its detection checkpoint and the estimated remaining **work** exceeds
//! `sigma * E[x]`, launch `c* - 1` backups immediately on idle machines.
//! Theorem 3 gives c* = 2 under Pareto; we *compute* c* and sigma* from P3
//! (Eq. 27-28) at construction and debug-assert the theorem.
//!
//! The detection query routes through `estimator::for_policy` with
//! `instrumented = true`: SDA owns the paper's s_i monitoring, so at the
//! checkpoint the estimate is the revealed truth — speed-corrected by the
//! host's advertised class speed under the default `speed_aware = true`.
//! That correction is what separates a copy that is *behind* (degraded
//! host, genuinely long task) from one that merely sits on a slow machine
//! class: see the `estimator_slowdown` integration tests.
//!
//! Levels 2/3 (slotted): the shared smallest-remaining / smallest-workload
//! SRPT ordering, one copy per task — both served by the cluster's
//! incremental [`SchedIndex`](crate::cluster::index::SchedIndex) under the
//! default `sched_index = true` (SDA's own level 1 is event-driven and
//! O(1) per checkpoint already).
//!
//! **Retained monolith.**  Since the policy-pipeline redesign this is the
//! `legacy_sched` equivalence reference for the canonical composition
//! `srpt+sda` (see `scheduler::pipeline`); `tests/pipeline_equivalence.rs`
//! proves byte-identical sweep CSVs, after which the monolith can go.

use crate::cluster::job::TaskRef;
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::estimator::{self, RemainingTime};
use crate::opt::p3;

use super::{srpt, Scheduler};

pub struct Sda {
    /// Detection threshold multiplier (sigma_i).
    pub sigma: f64,
    /// Copies (incl. original) a detected straggler should end up with.
    pub c_star: u32,
    /// Stragglers detected / backups actually launched (diagnostics).
    pub detected: u64,
    pub backups: u64,
    /// Revealed estimator (checkpoint-instrumented), speed-aware per config.
    est: Box<dyn RemainingTime>,
}

impl Sda {
    pub fn new(cfg: &SimConfig, alpha: f64) -> Self {
        let policy = p3::solve(alpha, cfg.detect_frac, cfg.r_max);
        let sigma = cfg.sigma.unwrap_or(policy.sigma);
        // Theorem 3: one backup is optimal under Pareto
        debug_assert_eq!(policy.c_star, 2, "Theorem 3 violated: c* = {}", policy.c_star);
        Sda {
            sigma,
            c_star: policy.c_star,
            detected: 0,
            backups: 0,
            est: estimator::for_policy(cfg, true),
        }
    }
}

impl Scheduler for Sda {
    fn name(&self) -> &str {
        "sda"
    }

    fn on_reveal(&mut self, cl: &mut Cluster, t: TaskRef) {
        // only the original triggers detection, and only once
        if cl.task(t).copies.len() != 1 {
            return;
        }
        let mean = cl.job(t.job).spec.dist.mean();
        let remaining = self.est.copy_remaining_work(cl, t, 0);
        if remaining > self.sigma * mean {
            self.detected += 1;
            for _ in 1..self.c_star {
                if cl.idle() == 0 {
                    break;
                }
                if cl.launch_copy(t) {
                    self.backups += 1;
                }
            }
        }
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        srpt::schedule_running_by(cl, self.est.as_ref());
        srpt::schedule_queued_single(cl);
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.machines = 300;
        c.horizon = 300.0;
        c.scheduler = crate::scheduler::SchedulerKind::Sda;
        c
    }

    #[test]
    fn derives_theorem3_policy() {
        let s = super::Sda::new(&cfg(), 2.0);
        assert_eq!(s.c_star, 2);
        assert!((s.sigma - 1.707).abs() < 0.08, "sigma = {}", s.sigma);
    }

    #[test]
    fn sigma_override_respected() {
        let mut c = cfg();
        c.sigma = Some(3.0);
        let s = super::Sda::new(&c, 2.0);
        assert_eq!(s.sigma, 3.0);
    }

    #[test]
    fn speculates_and_completes() {
        let c = cfg();
        let wl = generate(&WorkloadConfig::paper(1.0), c.horizon, 5);
        let sched = crate::scheduler::build(&c, &WorkloadConfig::paper(1.0)).unwrap();
        let res = Simulator::new(c, wl, sched).run();
        assert!(res.speculative_launches > 0);
        assert!(!res.completed.is_empty());
    }

    #[test]
    fn beats_naive_flowtime() {
        let c = cfg();
        let wl = generate(&WorkloadConfig::paper(1.0), c.horizon, 5);
        let sched = crate::scheduler::build(&c, &WorkloadConfig::paper(1.0)).unwrap();
        let sda = Simulator::new(c.clone(), wl.clone(), sched).run();
        let naive = Simulator::new(c, wl, Box::new(crate::scheduler::naive::Naive)).run();
        assert!(
            sda.mean_flowtime() < naive.mean_flowtime(),
            "sda {} vs naive {}",
            sda.mean_flowtime(),
            naive.mean_flowtime()
        );
    }
}
