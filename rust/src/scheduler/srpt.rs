//! Shared scheduling levels (Sec. IV-B / V-B / VI-A): every policy schedules
//! (2) the remaining tasks of begun jobs smallest-remaining-workload first,
//! then (3) the queued jobs smallest-workload first — the SRPT-flavoured
//! ordering the paper adopts throughout.
//!
//! The level-2 ordering key is a remaining-time query, so it routes
//! through the [`RemainingTime`] trait: policies holding an estimator call
//! [`schedule_running_by`]; [`schedule_running`] is the plain mean-field
//! shorthand (identical key for every estimator — see
//! `RemainingTime::job_remaining_work`).
//!
//! With `cfg.sched_index` on (the default) every level snapshots its job
//! order from the cluster's incremental [`SchedIndex`](crate::cluster::index::SchedIndex)
//! into a reused scratch buffer — O(members) per slot, no re-keying, no
//! sort, no allocation.  The original collect+sort scans are retained
//! below as the `sched_index = false` equivalence reference; both paths
//! launch the same copies in the same order (the index orders by the very
//! `total_cmp` keys the scans stably sort by).

use crate::cluster::job::JobId;
use crate::cluster::sim::Cluster;
use crate::estimator::{Blind, RemainingTime};

/// Level 2: launch first copies for unlaunched tasks of running jobs,
/// smallest remaining workload first.  Returns copies launched.
pub fn schedule_running(cl: &mut Cluster) -> usize {
    schedule_running_by(cl, &Blind)
}

/// Level 2 with the ordering key supplied by `est` — the paper's
/// smallest-remaining-workload-first over `est.job_remaining_work`.  Ties
/// break by job id (arrival order): keys are computed up-front and sorted
/// stably over the id-ordered running set.
///
/// The indexed path replaces the per-slot collect+sort with the
/// incrementally-ordered level-2 set.  That is valid because the level-2
/// key is the mean-field remaining workload for *every* estimator (the
/// documented contract of [`RemainingTime::job_remaining_work`]); a debug
/// assertion re-checks the contract against `est` on every slot of a
/// debug build.
pub fn schedule_running_by(cl: &mut Cluster, est: &dyn RemainingTime) -> usize {
    let mut launched = 0;
    if cl.idle() == 0 {
        return 0;
    }
    if cl.cfg.sched_index {
        let mut buf = cl.index.take_scratch();
        buf.extend(cl.index.level2_jobs());
        #[cfg(debug_assertions)]
        for &id in &buf {
            debug_assert_eq!(
                est.job_remaining_work(cl, id).to_bits(),
                cl.job(id).remaining_workload().to_bits(),
                "level-2 index key must be the estimator's mean-field job key"
            );
        }
        for &id in &buf {
            let idle = cl.idle();
            if idle == 0 {
                break;
            }
            launched += cl.launch_unlaunched(id, idle);
        }
        cl.put_scratch(buf);
        return launched;
    }
    // naive-scan reference
    let mut keyed: Vec<(f64, JobId)> = cl
        .running
        .iter()
        .copied()
        .filter(|id| cl.job(*id).unlaunched() > 0)
        .map(|id| (est.job_remaining_work(cl, id), id))
        .collect();
    keyed.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (_, id) in keyed {
        let idle = cl.idle();
        if idle == 0 {
            break;
        }
        launched += cl.launch_unlaunched(id, idle);
    }
    launched
}

/// Level 2 under the estimate-driven ordering (`est-srpt`): smallest
/// *reveal-refined* remaining workload first — tasks whose first copy
/// crossed the detection checkpoint contribute their observed total work
/// instead of `E[x]` (see [`crate::estimator::revealed_job_workload`]).
///
/// The key is piecewise-constant between cluster mutations (it changes
/// only at reveal/kill/finish events), which is what lets the
/// [`SchedIndex`](crate::cluster::index::SchedIndex) maintain the
/// est-keyed level-2 twin via the re-key hooks at those mutation points;
/// the `sched_index = false` fallback recomputes the identical key per
/// slot (same values, same `total_cmp` stable order, bit-identical
/// decisions).  A debug assertion re-checks the re-key contract on every
/// slot of a debug build.
///
/// The scan is also the automatic fallback whenever the cluster's index
/// is not maintaining est keys (`SchedIndex::tracks_est`) — e.g. a
/// hand-built cluster whose config never named an est-srpt policy — so
/// the ordering can never silently read an empty twin.
pub fn schedule_running_est(cl: &mut Cluster) -> usize {
    let mut launched = 0;
    if cl.idle() == 0 {
        return 0;
    }
    if cl.cfg.sched_index && cl.index.tracks_est() {
        let mut buf = cl.index.take_scratch();
        buf.extend(cl.index.level2_jobs_est());
        #[cfg(debug_assertions)]
        for &id in &buf {
            debug_assert_eq!(
                cl.index.est_key(id).map(f64::to_bits),
                Some(crate::estimator::revealed_job_workload(cl, id).to_bits()),
                "est-srpt re-key contract violated for job {id:?}"
            );
        }
        for &id in &buf {
            let idle = cl.idle();
            if idle == 0 {
                break;
            }
            launched += cl.launch_unlaunched(id, idle);
        }
        cl.put_scratch(buf);
        return launched;
    }
    // naive-scan reference: recompute the reveal-refined key per job
    let mut keyed: Vec<(f64, JobId)> = cl
        .running
        .iter()
        .copied()
        .filter(|id| cl.job(*id).unlaunched() > 0)
        .map(|id| (crate::estimator::revealed_job_workload(cl, id), id))
        .collect();
    keyed.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (_, id) in keyed {
        let idle = cl.idle();
        if idle == 0 {
            break;
        }
        launched += cl.launch_unlaunched(id, idle);
    }
    launched
}

/// Level 3: launch queued jobs (one copy per task) smallest total workload
/// first.  Jobs may be partially launched when machines run out; the rest
/// is picked up by level 2 at the next slot.  Returns copies launched.
pub fn schedule_queued_single(cl: &mut Cluster) -> usize {
    let mut launched = 0;
    if cl.idle() == 0 {
        return 0;
    }
    let buf = cl.snapshot_queued();
    for &id in &buf {
        let idle = cl.idle();
        if idle == 0 {
            break;
        }
        launched += cl.launch_unlaunched(id, idle);
    }
    cl.put_scratch(buf);
    launched
}

/// FIFO variants for the Mantri/LATE baselines: Hadoop's and Dryad's stock
/// job schedulers ran jobs in arrival order, not SRPT — the paper's
/// algorithms layer the smallest-remaining orderings *on top of* their
/// speculation policies, so the baselines must not silently inherit them.
pub fn schedule_running_fifo(cl: &mut Cluster) -> usize {
    let mut launched = 0;
    if cl.idle() == 0 {
        return 0;
    }
    let mut buf = cl.index.take_scratch();
    if cl.cfg.sched_index {
        // same membership as level 2, kept in id (= arrival) order
        buf.extend(cl.index.level2_jobs_fifo());
    } else {
        // BTreeSet<JobId> iterates in id order == arrival order
        buf.extend(
            cl.running
                .iter()
                .copied()
                .filter(|id| cl.job(*id).unlaunched() > 0),
        );
    }
    for &id in &buf {
        let idle = cl.idle();
        if idle == 0 {
            break;
        }
        launched += cl.launch_unlaunched(id, idle);
    }
    cl.put_scratch(buf);
    launched
}

/// FIFO level 3 (arrival order).  `Cluster::queued` is already id-ordered
/// and O(|χ|) to walk, so both index modes share the same snapshot; the
/// scratch buffer just kills the per-slot allocation.
pub fn schedule_queued_fifo(cl: &mut Cluster) -> usize {
    let mut launched = 0;
    if cl.idle() == 0 {
        return 0;
    }
    let mut buf = cl.index.take_scratch();
    buf.extend(cl.queued.iter().copied());
    for &id in &buf {
        let idle = cl.idle();
        if idle == 0 {
            break;
        }
        launched += cl.launch_unlaunched(id, idle);
    }
    cl.put_scratch(buf);
    launched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::generator::generate;
    use crate::cluster::job::JobPhase;
    use crate::cluster::sim::{Cluster, Simulator};
    use crate::config::{SimConfig, WorkloadConfig};

    fn cluster_with(machines: usize, lambda: f64, horizon: f64) -> Cluster {
        let mut cfg = SimConfig::default();
        cfg.machines = machines;
        cfg.horizon = horizon;
        cfg.use_runtime = false;
        let wl_cfg = WorkloadConfig::paper(lambda);
        let wl = generate(&wl_cfg, horizon, 3);
        // build a simulator just to construct the cluster consistently
        // (default policy: naive — the srpt+never pipeline)
        let sched = crate::scheduler::build(&cfg, &wl_cfg).unwrap();
        let sim = Simulator::new(cfg, wl, sched);
        sim.cluster
    }

    #[test]
    fn queued_jobs_fill_idle_machines() {
        let mut cl = cluster_with(100, 2.0, 50.0);
        // force all arrivals into the queue "now" (through arrive(), so
        // the scheduler index sees them too)
        let ids: Vec<_> = (0..cl.jobs.len() as u32)
            .map(crate::cluster::job::JobId)
            .collect();
        for id in &ids[..4.min(ids.len())] {
            cl.arrive(*id);
        }
        let launched = schedule_queued_single(&mut cl);
        assert!(launched > 0);
        assert_eq!(launched, 100 - cl.idle());
    }

    #[test]
    fn smallest_workload_first() {
        // ample machines: ~2 * 50 * 50.5 ~ 5000 tasks << 40000 machines
        let mut cl = cluster_with(40_000, 2.0, 50.0);
        let ids: Vec<_> = (0..cl.jobs.len() as u32)
            .map(crate::cluster::job::JobId)
            .collect();
        for id in &ids {
            cl.arrive(*id);
        }
        schedule_queued_single(&mut cl);
        // with ample machines everything launches
        for j in &cl.jobs {
            assert_eq!(j.phase, JobPhase::Running);
            assert_eq!(j.unlaunched(), 0);
        }
    }

    #[test]
    fn level2_picks_up_partial_jobs() {
        let mut cl = cluster_with(5, 1.0, 60.0);
        let id = crate::cluster::job::JobId(0);
        cl.arrive(id);
        schedule_queued_single(&mut cl);
        if cl.jobs[0].spec.num_tasks > 5 {
            assert!(cl.jobs[0].unlaunched() > 0);
            assert_eq!(cl.idle(), 0);
            // free a machine artificially by completing nothing: level 2 on a
            // fresh slot with idle 0 launches nothing
            assert_eq!(schedule_running(&mut cl), 0);
        }
    }
}
