//! Microsoft Mantri's speculative execution (the paper's baseline, Sec. II):
//! duplicate a running task when `P(t_rem > 2 * t_new) > delta` (default
//! delta = 0.25) and a machine is available; at most one backup per task.
//!
//! The estimator is **blind** (`estimator::for_policy` with
//! `instrumented = false`): the conditional Pareto survival
//! `P(x > e + 2 E[x] | x > e)` from elapsed time only.  The s_i-checkpoint
//! that reveals a copy's true remaining time is the *paper's* monitoring
//! instrumentation (Eq. 18-19) — granting it to the baseline would make
//! Mantri implausibly strong (it roughly halved the paper's reported gaps
//! in early versions of this reproduction).  Class-speed awareness, by
//! contrast, is public hardware knowledge, so with the default
//! `speed_aware = true` Mantri gets `estimator::SpeedAware::blind` (a
//! no-op on the paper's homogeneous cluster).
//! With `mantri_kill` the scheduler also terminates an original whose
//! estimated remaining time exceeds both the restart threshold and what a
//! fresh copy would need (the paper mentions Mantri may terminate tasks).
//!
//! **Retained monolith.**  Since the policy-pipeline redesign this is the
//! `legacy_sched` equivalence reference for the canonical composition
//! `fifo+mantri` (see `scheduler::pipeline`); `tests/pipeline_equivalence.rs`
//! proves byte-identical sweep CSVs, after which the monolith can go.

use crate::cluster::job::{CopyPhase, TaskRef};
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::estimator::{self, RemainingTime};

use super::{srpt, Scheduler};

pub struct Mantri {
    delta: f64,
    kill: bool,
    /// Job ordering for levels 2/3: FIFO (the Dryad stock scheduler) or the
    /// paper's SRPT levels (the like-for-like Fig. 6 baseline).
    srpt: bool,
    /// Blind estimator (no checkpoint), speed-aware per config.
    est: Box<dyn RemainingTime>,
    /// Reused duplicate-candidate buffer (no per-slot allocation).
    cands: Vec<(f64, TaskRef)>,
}

impl Mantri {
    pub fn new(cfg: &SimConfig) -> Self {
        Mantri {
            delta: cfg.mantri_delta,
            kill: cfg.mantri_kill,
            srpt: cfg.mantri_srpt,
            est: estimator::for_policy(cfg, false),
            cands: Vec::new(),
        }
    }
}

impl Scheduler for Mantri {
    fn name(&self) -> &str {
        "mantri"
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        // 1. duplicates for outliers (resource-saving test), longest first
        self.cands.clear();
        if cl.cfg.sched_index {
            // O(active): only tasks whose sole copy is a running first
            // copy, in the same (job asc, task asc) order as the scan
            for id in cl.running.iter() {
                let job = cl.job(*id);
                let two_means = 2.0 * job.spec.dist.mean();
                for ti in cl.index.candidates(*id) {
                    let t = TaskRef { job: *id, task: ti };
                    if self.est.task_prob_exceeds(cl, t, two_means) > self.delta {
                        self.cands.push((self.est.task_remaining_work(cl, t), t));
                    }
                }
            }
        } else {
            // naive-scan reference: every task of every running job
            for id in cl.running.iter() {
                let job = cl.job(*id);
                let two_means = 2.0 * job.spec.dist.mean();
                for (ti, task) in job.tasks.iter().enumerate() {
                    if task.done || task.copies.len() != 1 {
                        continue;
                    }
                    if task.copies[0].phase != CopyPhase::Running {
                        continue;
                    }
                    let t = TaskRef { job: *id, task: ti as u32 };
                    if self.est.task_prob_exceeds(cl, t, two_means) > self.delta {
                        self.cands.push((self.est.task_remaining_work(cl, t), t));
                    }
                }
            }
        }
        // NaN-safe descending sort (total_cmp, not partial_cmp().unwrap())
        self.cands.sort_by(|a, b| b.0.total_cmp(&a.0));
        for &(rem, t) in &self.cands {
            // the restart rule frees its own machine, so it applies even
            // when the cluster is full (kill the hopeless original, then
            // relaunch afresh on the freed slot)
            if self.kill && rem > 3.0 * cl.job(t.job).spec.dist.mean() {
                cl.kill_copy(t, 0);
                cl.launch_copy(t);
                continue;
            }
            if cl.idle() == 0 {
                break;
            }
            cl.launch_copy(t);
        }
        // 2/3. job ordering per the configured baseline strength
        if self.srpt {
            srpt::schedule_running_by(cl, self.est.as_ref());
            srpt::schedule_queued_single(cl);
        } else {
            srpt::schedule_running_fifo(cl);
            srpt::schedule_queued_fifo(cl);
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};

    fn run(kill: bool) -> crate::cluster::sim::SimResult {
        let mut cfg = SimConfig::default();
        cfg.machines = 200;
        cfg.horizon = 300.0;
        cfg.mantri_kill = kill;
        cfg.scheduler = crate::scheduler::SchedulerKind::Mantri;
        let wl = generate(&WorkloadConfig::paper(1.0), cfg.horizon, 5);
        let sched = crate::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        Simulator::new(cfg, wl, sched).run()
    }

    #[test]
    fn speculates_on_stragglers() {
        let res = run(false);
        assert!(res.speculative_launches > 0);
        assert!(!res.completed.is_empty());
    }

    #[test]
    fn beats_naive_flowtime() {
        let mantri = run(false);
        let mut cfg = SimConfig::default();
        cfg.machines = 200;
        cfg.horizon = 300.0;
        let wl = generate(&WorkloadConfig::paper(1.0), cfg.horizon, 5);
        let naive = Simulator::new(cfg, wl, Box::new(crate::scheduler::naive::Naive)).run();
        assert!(
            mantri.mean_flowtime() < naive.mean_flowtime(),
            "mantri {} vs naive {}",
            mantri.mean_flowtime(),
            naive.mean_flowtime()
        );
    }

    #[test]
    fn kill_variant_runs() {
        let res = run(true);
        assert!(!res.completed.is_empty());
    }
}
