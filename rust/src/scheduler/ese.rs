//! Enhanced Speculative Execution (Algorithm 2, Sec. VI) — the heavy-load
//! policy: Mantri-style slot-gated backups with the analysis-derived
//! threshold sigma* (Eq. 30-33), plus opportunistic cloning of *small* jobs
//! (interactive, latency-sensitive) via the Eq. 29 objective.
//!
//! Per slot:
//! 1. D(l) = single-copy running tasks with `t_rem > sigma * E[x]`, sorted
//!    by decreasing t_rem; one backup each while machines remain.  The
//!    t_rem query is the estimator's remaining-work estimate
//!    (`estimator::for_policy` with `instrumented = true`: revealed
//!    post-checkpoint, speed-aware per config);
//! 2. unassigned tasks of running jobs, smallest remaining workload first;
//! 3. queued jobs smallest workload first; a job with
//!    `m < eta * N(l)/|chi(l)|` and `E[x] < xi` is cloned with the Eq. 29
//!    optimal count, everything else gets single copies.
//!
//! **Retained monolith.**  Since the policy-pipeline redesign this is the
//! `legacy_sched` equivalence reference for the canonical composition
//! `srpt+ese` (see `scheduler::pipeline`); `tests/pipeline_equivalence.rs`
//! proves byte-identical sweep CSVs, after which the monolith can go.

use crate::cluster::job::{CopyPhase, TaskRef};
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::estimator::{self, RemainingTime};
use crate::opt::ese_sigma;

use super::{srpt, Scheduler};

pub struct Ese {
    pub sigma: f64,
    eta: f64,
    xi: f64,
    gamma: f64,
    r_max: u32,
    alpha: f64,
    /// Revealed estimator (checkpoint-instrumented), speed-aware per config.
    est: Box<dyn RemainingTime>,
    /// Reused D(l) buffer (no per-slot allocation).
    d: Vec<(f64, TaskRef)>,
    /// Diagnostics.
    pub backups: u64,
    pub small_jobs_cloned: u64,
}

impl Ese {
    pub fn new(cfg: &SimConfig, alpha: f64) -> Self {
        let sigma = cfg.sigma.unwrap_or_else(|| ese_sigma::sigma_star(alpha));
        Ese {
            sigma,
            eta: cfg.eta_small,
            xi: cfg.xi_small,
            gamma: cfg.gamma,
            r_max: cfg.r_max,
            alpha,
            est: estimator::for_policy(cfg, true),
            d: Vec::new(),
            backups: 0,
            small_jobs_cloned: 0,
        }
    }
}

impl Scheduler for Ese {
    fn name(&self) -> &str {
        "ese"
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        // 1. backup candidates D(l), longest estimated remaining first
        self.d.clear();
        if cl.cfg.sched_index {
            // O(active): only single-running-first-copy tasks, same
            // (job asc, task asc) order as the scan
            for id in cl.running.iter() {
                let threshold = self.sigma * cl.job(*id).spec.dist.mean();
                for ti in cl.index.candidates(*id) {
                    let t = TaskRef { job: *id, task: ti };
                    let rem = self.est.task_remaining_work(cl, t);
                    if rem > threshold {
                        self.d.push((rem, t));
                    }
                }
            }
        } else {
            // naive-scan reference
            for id in cl.running.iter() {
                let job = cl.job(*id);
                let threshold = self.sigma * job.spec.dist.mean();
                for (ti, task) in job.tasks.iter().enumerate() {
                    if task.done || task.copies.len() != 1 {
                        continue;
                    }
                    if task.copies[0].phase != CopyPhase::Running {
                        continue;
                    }
                    let t = TaskRef { job: *id, task: ti as u32 };
                    let rem = self.est.task_remaining_work(cl, t);
                    if rem > threshold {
                        self.d.push((rem, t));
                    }
                }
            }
        }
        // NaN-safe descending sort (total_cmp, not partial_cmp().unwrap())
        self.d.sort_by(|a, b| b.0.total_cmp(&a.0));
        for &(_, t) in &self.d {
            if cl.idle() == 0 {
                return;
            }
            if cl.launch_copy(t) {
                self.backups += 1;
            }
        }
        // 2. remaining tasks of running jobs
        srpt::schedule_running_by(cl, self.est.as_ref());
        if cl.idle() == 0 {
            return;
        }
        // 3. queued jobs; clone the small ones per Eq. 29
        let chi = cl.snapshot_queued();
        let chi_len = chi.len().max(1) as f64;
        for &id in &chi {
            let idle = cl.idle();
            if idle == 0 {
                break;
            }
            let job = cl.job(id);
            let m = job.spec.num_tasks as f64;
            let mean = job.spec.dist.mean();
            let small = m < self.eta * idle as f64 / chi_len && mean < self.xi;
            if small {
                let c = ese_sigma::small_job_clones(
                    job.spec.dist.mu,
                    m,
                    self.gamma,
                    self.alpha,
                    self.r_max,
                    idle as f64,
                );
                if c > 1 {
                    self.small_jobs_cloned += 1;
                }
                cl.launch_job_cloned(id, c);
            } else {
                cl.launch_unlaunched(id, idle);
            }
        }
        cl.put_scratch(chi);
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.machines = 300;
        c.horizon = 300.0;
        c.scheduler = crate::scheduler::SchedulerKind::Ese;
        c
    }

    #[test]
    fn derives_sigma_from_analysis() {
        let e = super::Ese::new(&cfg(), 2.0);
        assert!((1.5..=2.0).contains(&e.sigma), "sigma = {}", e.sigma);
    }

    #[test]
    fn heavy_load_still_completes_jobs() {
        let c = cfg();
        // heavy relative to 300 machines
        let wl = generate(&WorkloadConfig::paper(4.0), c.horizon, 5);
        let sched = crate::scheduler::build(&c, &WorkloadConfig::paper(4.0)).unwrap();
        let res = Simulator::new(c, wl, sched).run();
        assert!(!res.completed.is_empty());
        assert!(res.speculative_launches > 0);
    }

    #[test]
    fn beats_mantri_under_heavy_load() {
        let mut c = cfg();
        c.mantri_srpt = true; // like-for-like baseline (see fig6.rs)
        let wl = generate(&WorkloadConfig::paper(4.0), c.horizon, 5);
        let sched = crate::scheduler::build(&c, &WorkloadConfig::paper(4.0)).unwrap();
        let ese = Simulator::new(c.clone(), wl.clone(), sched).run();
        c.scheduler = crate::scheduler::SchedulerKind::Mantri;
        let sched = crate::scheduler::build(&c, &WorkloadConfig::paper(4.0)).unwrap();
        let mantri = Simulator::new(c, wl, sched).run();
        // the paper's headline: lower flowtime at comparable resource
        assert!(
            ese.mean_flowtime() <= mantri.mean_flowtime() * 1.05,
            "ese {} vs mantri {}",
            ese.mean_flowtime(),
            mantri.mean_flowtime()
        );
    }
}
