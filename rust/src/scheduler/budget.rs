//! `CopyBudget` — the how-many-copies axis of the policy pipeline.
//!
//! A [`SpeculationRule`](super::rule::SpeculationRule) decides *when* to
//! act on a task or queued job; the budget decides *how many* copies the
//! target gets.  Backup phases read a per-task total-copy target
//! ([`CopyBudget::backup_copies`]); level 3 either pre-plans the whole
//! queued batch jointly ([`CopyBudget::plan_queued`] — SCA's P2 solve) or
//! answers per job during the walk ([`CopyBudget::queued_copies`] — the
//! current idle count matters, so the query happens at launch time
//! exactly like the monoliths did).

use crate::cluster::job::JobId;
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::opt::ese_sigma;
use crate::opt::gradient::{GradientSolver, P2Job, P2Problem};
use crate::opt::p2::round_and_repair;

/// Anything that can solve a P2 batch (continuous clone counts).
/// Not `Send`: the PJRT backend is thread-pinned (see `runtime::pjrt`).
/// (Moved here from the deleted `sca` monolith — the [`P2Budget`] is the
/// only remaining consumer.)
pub trait P2Backend {
    fn backend_name(&self) -> &'static str;
    fn solve(&mut self, p: &P2Problem) -> Vec<f64>;
    /// Largest batch the backend accepts (the AOT artifact has a static
    /// batch dimension; the rust solver is unbounded).
    fn max_batch(&self) -> usize {
        usize::MAX
    }
}

impl P2Backend for GradientSolver {
    fn backend_name(&self) -> &'static str {
        "rust-gradient"
    }
    fn solve(&mut self, p: &P2Problem) -> Vec<f64> {
        GradientSolver::solve(self, p).c
    }
}

/// The copy-count component of a [`Pipeline`](super::Pipeline).
pub trait CopyBudget {
    fn name(&self) -> &'static str;

    /// Total copies (including the original) a rule-flagged *running*
    /// task should reach — `2` means one backup.  Constant within a slot.
    fn backup_copies(&self, cl: &Cluster) -> u32;

    /// Wakeup-planner horizon, mirroring
    /// [`SpeculationRule::next_decision_time`](super::rule::SpeculationRule::next_decision_time):
    /// the earliest instant this budget's answers could change absent any
    /// cluster mutation; `None` = never.  A budget whose
    /// [`backup_copies`](Self::backup_copies) or queued planning reads
    /// the clock must override conservatively; the conservative default
    /// ("now") fires every slot.  All four in-tree budgets are provably
    /// mutation-driven and override to `None` (see each impl).
    fn next_decision_time(&self, cl: &Cluster) -> Option<f64> {
        Some(cl.clock)
    }

    /// Jointly plan the level-3 copy counts for the whole χ(l) snapshot.
    /// `Some(counts)` (parallel to `chi`) bypasses the rule's per-job
    /// clone gate — the batch solver owns the decision; `None` routes
    /// each job through the gate + [`queued_copies`](Self::queued_copies).
    fn plan_queued(&mut self, _cl: &Cluster, _chi: &[JobId]) -> Option<Vec<u32>> {
        None
    }

    /// Launch-time copy count for one rule-flagged queued job, queried at
    /// walk time (the current idle count is part of the decision).
    fn queued_copies(&mut self, cl: &Cluster, id: JobId) -> u32;
}

/// A plain per-task total-copy target with no room check — the
/// resource-capped budget (`cap2` = at most one backup, the Mantri/LATE
/// default and SDA's Theorem-3 `c* = 2`).
pub struct CapBudget {
    pub copies: u32,
}

impl CopyBudget for CapBudget {
    fn name(&self) -> &'static str {
        "cap"
    }

    fn backup_copies(&self, _cl: &Cluster) -> u32 {
        self.copies
    }

    /// Constant copy counts: nothing here reads the clock.
    fn next_decision_time(&self, _cl: &Cluster) -> Option<f64> {
        None
    }

    fn queued_copies(&mut self, _cl: &Cluster, _id: JobId) -> u32 {
        self.copies
    }
}

/// CloneAll's fixed-k budget (Sec. III): `k` clones per task when the
/// cluster has room, degrading to single copies when tight unless
/// `strict` (the literal Eq. 3 model the threshold experiment uses).
pub struct FixedBudget {
    pub copies: u32,
    pub strict: bool,
}

impl CopyBudget for FixedBudget {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn backup_copies(&self, _cl: &Cluster) -> u32 {
        self.copies
    }

    /// The room check reads the idle count (mutation-driven), never the
    /// clock, and is only consulted during the χ(l) walk — unreachable
    /// on a quiet cluster (non-empty χ after a fired slot implies no
    /// idle machines).
    fn next_decision_time(&self, _cl: &Cluster) -> Option<f64> {
        None
    }

    fn queued_copies(&mut self, cl: &Cluster, id: JobId) -> u32 {
        let m = cl.job(id).spec.num_tasks as usize;
        if self.strict || cl.idle() >= m * self.copies as usize {
            self.copies
        } else {
            1
        }
    }
}

/// SCA's P2 utility solver (Algorithm 1): when every queued job fits
/// (`sum m_i < N(l)`), solve P2 for the batch and launch each job with its
/// optimized clone count; otherwise fall back to single copies.  The
/// solve goes through a [`P2Backend`] — the PJRT executor when artifacts
/// are available, the pure-rust gradient-projection twin otherwise.
pub struct P2Budget {
    backend: Box<dyn P2Backend>,
    gamma: f64,
    r_max: u32,
    /// Batch cap (min of backend capacity and `cfg.p2_batch`).
    batch: usize,
    /// Diagnostics.
    pub p2_solves: u64,
    pub p2_jobs_solved: u64,
}

impl P2Budget {
    pub fn new(cfg: &SimConfig) -> Result<Self, String> {
        let backend: Box<dyn P2Backend> = if cfg.use_runtime {
            match crate::runtime::solver::PjrtP2::load(&cfg.artifacts_dir) {
                Ok(exec) => Box::new(exec),
                Err(e) => {
                    eprintln!(
                        "p2 budget: PJRT runtime unavailable ({e}); using the pure-rust solver"
                    );
                    Box::new(GradientSolver::default())
                }
            }
        } else {
            Box::new(GradientSolver::default())
        };
        let batch = cfg.p2_batch.min(backend.max_batch());
        Ok(P2Budget {
            backend,
            gamma: cfg.gamma,
            r_max: cfg.r_max,
            batch,
            p2_solves: 0,
            p2_jobs_solved: 0,
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.backend_name()
    }
}

impl CopyBudget for P2Budget {
    fn name(&self) -> &'static str {
        "p2"
    }

    fn backup_copies(&self, _cl: &Cluster) -> u32 {
        2
    }

    /// The P2 objective *does* read the clock (job ages enter the solve),
    /// but the solve is unreachable on a quiet cluster: `plan_queued`
    /// runs only when χ(l) is non-empty, which after a fired slot implies
    /// no idle machines, and then `total_tasks >= idle` short-circuits to
    /// `None` before the backend is touched.  Any state change that could
    /// re-enable the solve (arrival, machine release) is a mutation that
    /// forces the next slot anyway — so `None` is exact, not optimistic.
    fn next_decision_time(&self, _cl: &Cluster) -> Option<f64> {
        None
    }

    fn plan_queued(&mut self, cl: &Cluster, chi: &[JobId]) -> Option<Vec<u32>> {
        if chi.is_empty() {
            return None;
        }
        let total_tasks: u64 = chi.iter().map(|id| cl.job(*id).spec.num_tasks as u64).sum();
        // tight cluster: single copies, smallest workload first (the χ
        // order the snapshot already is) — no solve
        if (total_tasks as usize) >= cl.idle() {
            return None;
        }
        let n_avail = cl.idle() as f64;
        // the artifact batch is static: solve the `batch` smallest-workload
        // jobs through the backend, single-launch any overflow
        let (solved, overflow) = chi.split_at(chi.len().min(self.batch));
        let jobs: Vec<P2Job> = solved
            .iter()
            .map(|id| {
                let j = cl.job(*id);
                P2Job {
                    mu: j.spec.dist.mu,
                    m: j.spec.num_tasks as f64,
                    age: cl.clock - j.spec.arrival,
                }
            })
            .collect();
        let alpha = solved
            .first()
            .map(|id| cl.job(*id).spec.dist.alpha)
            .unwrap_or(2.0);
        let problem = P2Problem {
            jobs: jobs.clone(),
            n_avail,
            gamma: self.gamma,
            r: self.r_max as f64,
            alpha,
        };
        let c = self.backend.solve(&problem);
        self.p2_solves += 1;
        self.p2_jobs_solved += jobs.len() as u64;
        let m: Vec<f64> = jobs.iter().map(|j| j.m).collect();
        let mut counts = round_and_repair(&c, &m, n_avail, self.r_max);
        counts.extend(overflow.iter().map(|_| 1u32));
        Some(counts)
    }

    fn queued_copies(&mut self, _cl: &Cluster, _id: JobId) -> u32 {
        1
    }
}

/// ESE's Eq. 29 optimal clone count for gate-flagged small jobs.
pub struct Eq29Budget {
    gamma: f64,
    alpha: f64,
    r_max: u32,
    /// Diagnostics: gate-flagged jobs whose optimal count exceeded 1.
    pub small_jobs_cloned: u64,
}

impl Eq29Budget {
    pub fn new(cfg: &SimConfig, alpha: f64) -> Self {
        Eq29Budget { gamma: cfg.gamma, alpha, r_max: cfg.r_max, small_jobs_cloned: 0 }
    }
}

impl CopyBudget for Eq29Budget {
    fn name(&self) -> &'static str {
        "eq29"
    }

    fn backup_copies(&self, _cl: &Cluster) -> u32 {
        2
    }

    /// Eq. 29 reads job constants and the idle count (mutation-driven),
    /// never the clock; like every queued-copy query it is unreachable on
    /// a quiet cluster (see [`FixedBudget::next_decision_time`]).
    fn next_decision_time(&self, _cl: &Cluster) -> Option<f64> {
        None
    }

    fn queued_copies(&mut self, cl: &Cluster, id: JobId) -> u32 {
        let job = cl.job(id);
        let c = ese_sigma::small_job_clones(
            job.spec.dist.mu,
            job.spec.num_tasks as f64,
            self.gamma,
            self.alpha,
            self.r_max,
            cl.idle() as f64,
        );
        if c > 1 {
            self.small_jobs_cloned += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};
    use crate::scheduler::SchedulerKind;

    /// Ported from the deleted SCA monolith: on a light cluster the P2
    /// budget's cloning branch engages (`sum m_i < N(l)`), so SCA
    /// speculates; on a tight one it degrades to single copies and still
    /// completes jobs.
    #[test]
    fn p2_budget_clones_in_light_load_and_degrades_when_tight() {
        let run = |machines: usize, horizon: f64, lambda: f64| {
            let mut cfg = SimConfig::default();
            cfg.machines = machines;
            cfg.horizon = horizon;
            cfg.use_runtime = false;
            cfg.scheduler = SchedulerKind::Sca;
            let wl = WorkloadConfig::paper(lambda);
            let workload = generate(&wl, cfg.horizon, 5);
            let sched = crate::scheduler::build(&cfg, &wl).unwrap();
            Simulator::new(cfg, workload, sched).run()
        };
        let light = run(2000, 200.0, 0.5);
        assert!(light.speculative_launches > 0, "SCA should clone in light load");
        assert!(!light.completed.is_empty());
        let tight = run(30, 300.0, 1.0);
        assert!(!tight.completed.is_empty());
    }
}
