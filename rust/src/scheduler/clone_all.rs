//! Sec. III generalized cloning: every task of a newly scheduled job gets
//! `copies` (>= 2) clones up-front when the cluster has room, regardless of
//! job size — the indiscriminate strategy whose stability bound is
//! Theorem 1 and whose delay is W_t^c (Eq. 3).  Used by the threshold
//! experiment to locate lambda^U empirically.
//!
//! **Retained monolith.**  Since the policy-pipeline redesign this is the
//! `legacy_sched` equivalence reference for the canonical composition
//! `srpt+clone` (see `scheduler::pipeline`); `tests/pipeline_equivalence.rs`
//! proves byte-identical sweep CSVs, after which the monolith can go.

use crate::cluster::sim::Cluster;

use super::{srpt, Scheduler};

pub struct CloneAll {
    /// Clones per task (the Eq. 3 analysis uses 2).
    pub copies: u32,
    /// Strict mode: clone even when the cluster is tight (jobs queue rather
    /// than degrade to single copies).  This is the literal Sec. III model
    /// whose delay is Eq. (3) — the threshold experiment uses it to show
    /// cloning destabilizing past the Theorem-1 bound.  Non-strict (the
    /// default) degrades gracefully like a practical system would.
    pub strict: bool,
}

impl Scheduler for CloneAll {
    fn name(&self) -> &str {
        "clone_all"
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        // level 2 first: keep begun jobs moving (single copies)
        srpt::schedule_running(cl);
        // then clone whole queued jobs while room remains (χ(l) order via
        // the index snapshot; scan reference when sched_index is off)
        let chi = cl.snapshot_queued();
        for &id in &chi {
            if cl.idle() == 0 {
                break;
            }
            let m = cl.job(id).spec.num_tasks as usize;
            let copies = if self.strict || cl.idle() >= m * self.copies as usize {
                self.copies
            } else {
                1
            };
            cl.launch_job_cloned(id, copies);
        }
        cl.put_scratch(chi);
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};

    #[test]
    fn clones_when_room() {
        let mut cfg = SimConfig::default();
        cfg.machines = 2000;
        cfg.horizon = 100.0;
        let wl = generate(&WorkloadConfig::paper(0.5), cfg.horizon, 5);
        let res = Simulator::new(cfg, wl, Box::new(super::CloneAll { copies: 2, strict: false }))
            .run();
        assert!(res.speculative_launches > 0);
        // every completed job used >= 1 machine-time unit per task and
        // cloning means more resource than a naive run would use
        assert!(res.utilization > 0.0);
    }

    #[test]
    fn falls_back_when_tight() {
        let mut cfg = SimConfig::default();
        cfg.machines = 8; // too small to clone most jobs
        cfg.horizon = 300.0;
        let wl = generate(&WorkloadConfig::paper(0.05), cfg.horizon, 6);
        let res = Simulator::new(cfg, wl, Box::new(super::CloneAll { copies: 2, strict: false }))
            .run();
        assert!(!res.completed.is_empty());
    }
}
