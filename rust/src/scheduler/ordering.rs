//! `JobOrdering` — the level-2/3 job-ordering axis of the policy pipeline.
//!
//! The paper schedules (2) the remaining tasks of begun jobs and (3) the
//! queued jobs χ(l) in a policy-defined order; the monoliths hard-wired
//! FIFO or SRPT per scheduler.  This trait makes the ordering a
//! composable component with an **explicit level-2 key contract**:
//!
//! | ordering | level-2 key | indexable? |
//! |---|---|---|
//! | `fifo` | job id (arrival order) | yes — id-ordered FIFO twin |
//! | `srpt` | mean-field `#unfinished * E[x]` | yes — the [`SchedIndex`] level-2 set |
//! | `est-srpt` | reveal-refined workload ([`revealed_job_workload`]) | yes — the est-keyed twin, re-keyed at the reveal/kill/finish mutation points |
//!
//! **The re-key contract.**  An ordering's level-2 key must be
//! *piecewise-constant between cluster mutations* (so the incremental
//! [`SchedIndex`] can keep the ordered set current by re-keying at the
//! mutation points) and the scan reference must recompute exactly the
//! same value on demand (`sched_index = false` — the auto-fallback path —
//! must make bit-identical decisions).  A clock-decaying key (e.g. raw
//! remaining wall) is *not* admissible; `est-srpt` therefore refines the
//! mean-field key with the *revealed total work* of checkpointed copies,
//! which only changes at reveal/kill/finish events.  Debug builds
//! re-assert the contract on every slot (`srpt::schedule_running_by`,
//! `srpt::schedule_running_est`).
//!
//! [`SchedIndex`]: crate::cluster::index::SchedIndex
//! [`revealed_job_workload`]: crate::estimator::revealed_job_workload

use crate::cluster::job::{JobId, JobState};
use crate::cluster::sim::Cluster;
use crate::estimator::{self, RemainingTime};

use super::srpt;

/// The level-2/3 job-ordering component of a [`Pipeline`](super::Pipeline).
pub trait JobOrdering {
    fn name(&self) -> &'static str;

    /// The level-2 ordering key this ordering ranks `job` by — the
    /// documented re-key contract (see the module docs).  Exposed so the
    /// contract is testable, not just prose.
    fn level2_key(&self, cl: &Cluster, job: &JobState) -> f64;

    /// Level 2: launch first copies for unlaunched tasks of running jobs
    /// in this ordering's order.  Returns copies launched.
    fn schedule_running(&self, cl: &mut Cluster, est: &dyn RemainingTime) -> usize;

    /// χ(l) in this ordering's level-3 order, snapshotted into the
    /// cluster's reused scratch buffer (return with `Cluster::put_scratch`).
    fn snapshot_queued(&self, cl: &mut Cluster) -> Vec<JobId>;
}

/// Arrival (id) order — Hadoop/Dryad's stock job schedulers.
pub struct Fifo;

impl JobOrdering for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn level2_key(&self, _cl: &Cluster, job: &JobState) -> f64 {
        job.spec.id.0 as f64
    }

    fn schedule_running(&self, cl: &mut Cluster, _est: &dyn RemainingTime) -> usize {
        srpt::schedule_running_fifo(cl)
    }

    fn snapshot_queued(&self, cl: &mut Cluster) -> Vec<JobId> {
        let mut buf = cl.index.take_scratch();
        // BTreeSet<JobId> iterates in id order == arrival order
        buf.extend(cl.queued.iter().copied());
        buf
    }
}

/// The paper's smallest-remaining-workload-first levels (mean-field key).
pub struct Srpt;

impl JobOrdering for Srpt {
    fn name(&self) -> &'static str {
        "srpt"
    }

    fn level2_key(&self, _cl: &Cluster, job: &JobState) -> f64 {
        job.remaining_workload()
    }

    fn schedule_running(&self, cl: &mut Cluster, est: &dyn RemainingTime) -> usize {
        srpt::schedule_running_by(cl, est)
    }

    fn snapshot_queued(&self, cl: &mut Cluster) -> Vec<JobId> {
        cl.snapshot_queued()
    }
}

/// SRPT with the estimate-refined key: tasks whose first copy crossed the
/// detection checkpoint contribute their revealed total work instead of
/// `E[x]` — the estimate-driven level-2 ordering the ROADMAP's open item
/// asked for.  Queued jobs have revealed nothing, so the level-3 order is
/// identical to SRPT's workload order.
pub struct EstSrpt;

impl JobOrdering for EstSrpt {
    fn name(&self) -> &'static str {
        "est-srpt"
    }

    fn level2_key(&self, cl: &Cluster, job: &JobState) -> f64 {
        estimator::revealed_job_workload(cl, job.spec.id)
    }

    fn schedule_running(&self, cl: &mut Cluster, _est: &dyn RemainingTime) -> usize {
        srpt::schedule_running_est(cl)
    }

    fn snapshot_queued(&self, cl: &mut Cluster) -> Vec<JobId> {
        cl.snapshot_queued()
    }
}
