//! Smart Cloning Algorithm (Algorithm 1, Sec. IV-B).
//!
//! At each slot:
//! 1. schedule the unassigned tasks of running jobs, fewest remaining first;
//! 2. if every queued job fits (`sum m_i < N(l)`), solve P2 for the batch
//!    and launch each job with its optimized clone count;
//! 3. otherwise fall back to smallest-workload-first single-copy scheduling.
//!
//! The P2 solve goes through a [`P2Backend`]: the PJRT executor running the
//! AOT-compiled JAX/Pallas artifact on the hot path, or the pure-rust
//! gradient-projection twin when artifacts are unavailable.
//!
//! **Retained monolith.**  Since the policy-pipeline redesign this is the
//! `legacy_sched` equivalence reference for the canonical composition
//! `srpt+clone*p2` (see `scheduler::pipeline`); `tests/pipeline_equivalence.rs`
//! proves byte-identical sweep CSVs, after which the monolith can go.

use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::estimator::{self, RemainingTime};
use crate::opt::gradient::{GradientSolver, P2Job, P2Problem};
use crate::opt::p2::round_and_repair;

use super::{srpt, Scheduler};

/// Anything that can solve a P2 batch (continuous clone counts).
/// Not `Send`: the PJRT backend is thread-pinned (see `runtime::pjrt`).
pub trait P2Backend {
    fn backend_name(&self) -> &'static str;
    fn solve(&mut self, p: &P2Problem) -> Vec<f64>;
    /// Largest batch the backend accepts (the AOT artifact has a static
    /// batch dimension; the rust solver is unbounded).
    fn max_batch(&self) -> usize {
        usize::MAX
    }
}

impl P2Backend for GradientSolver {
    fn backend_name(&self) -> &'static str {
        "rust-gradient"
    }
    fn solve(&mut self, p: &P2Problem) -> Vec<f64> {
        GradientSolver::solve(self, p).c
    }
}

pub struct Sca {
    backend: Box<dyn P2Backend>,
    gamma: f64,
    r_max: u32,
    /// Batch cap (min of backend capacity and cfg.p2_batch).
    batch: usize,
    /// Level-2 ordering estimator (checkpoint-instrumented, speed-aware
    /// per config) — SCA's only remaining-time query; the P2 cloning
    /// decision concerns *queued* jobs, which have nothing to estimate.
    est: Box<dyn RemainingTime>,
    /// Counters exposed for diagnostics / perf accounting.
    pub p2_solves: u64,
    pub p2_jobs_solved: u64,
}

impl Sca {
    pub fn new(cfg: &SimConfig) -> Result<Self, String> {
        let backend: Box<dyn P2Backend> = if cfg.use_runtime {
            match crate::runtime::solver::PjrtP2::load(&cfg.artifacts_dir) {
                Ok(exec) => Box::new(exec),
                Err(e) => {
                    eprintln!(
                        "sca: PJRT runtime unavailable ({e}); using the pure-rust solver"
                    );
                    Box::new(GradientSolver::default())
                }
            }
        } else {
            Box::new(GradientSolver::default())
        };
        let batch = cfg.p2_batch.min(backend.max_batch());
        Ok(Sca {
            backend,
            gamma: cfg.gamma,
            r_max: cfg.r_max,
            batch,
            est: estimator::for_policy(cfg, true),
            p2_solves: 0,
            p2_jobs_solved: 0,
        })
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.backend_name()
    }

    /// Solve P2 for (a prefix of) the queued jobs and launch the clones.
    fn clone_by_p2(&mut self, cl: &mut Cluster, chi: &[crate::cluster::job::JobId]) {
        let n_avail = cl.idle() as f64;
        // the artifact batch is static: solve the `batch` smallest-workload
        // jobs through the backend, single-launch any overflow
        let (solved, overflow) = chi.split_at(chi.len().min(self.batch));
        let jobs: Vec<P2Job> = solved
            .iter()
            .map(|id| {
                let j = cl.job(*id);
                P2Job {
                    mu: j.spec.dist.mu,
                    m: j.spec.num_tasks as f64,
                    age: cl.clock - j.spec.arrival,
                }
            })
            .collect();
        let alpha = solved
            .first()
            .map(|id| cl.job(*id).spec.dist.alpha)
            .unwrap_or(2.0);
        let problem = P2Problem {
            jobs: jobs.clone(),
            n_avail,
            gamma: self.gamma,
            r: self.r_max as f64,
            alpha,
        };
        let c = self.backend.solve(&problem);
        self.p2_solves += 1;
        self.p2_jobs_solved += jobs.len() as u64;
        let m: Vec<f64> = jobs.iter().map(|j| j.m).collect();
        let ci = round_and_repair(&c, &m, n_avail, self.r_max);
        for (id, copies) in solved.iter().zip(ci) {
            if cl.idle() == 0 {
                break;
            }
            cl.launch_job_cloned(*id, copies);
        }
        for id in overflow {
            if cl.idle() == 0 {
                break;
            }
            let idle = cl.idle();
            cl.launch_unlaunched(*id, idle);
        }
    }
}

impl Scheduler for Sca {
    fn name(&self) -> &str {
        "sca"
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        // 1. remaining tasks of running jobs, fewest remaining first
        srpt::schedule_running_by(cl, self.est.as_ref());
        if cl.idle() == 0 {
            return;
        }
        // χ(l) in workload order from the index (scan reference when
        // sched_index is off), via the reused snapshot buffer
        let chi = cl.snapshot_queued();
        if chi.is_empty() {
            cl.put_scratch(chi);
            return;
        }
        let total_tasks: u64 = chi
            .iter()
            .map(|id| cl.job(*id).spec.num_tasks as u64)
            .sum();
        if (total_tasks as usize) < cl.idle() {
            // 2. room to clone: optimize
            self.clone_by_p2(cl, &chi);
        } else {
            // 3. tight: smallest workload first, one copy per task — the
            // snapshot *is* that order, so launch straight off it
            for &id in &chi {
                let idle = cl.idle();
                if idle == 0 {
                    break;
                }
                cl.launch_unlaunched(id, idle);
            }
        }
        cl.put_scratch(chi);
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};

    fn cfg(machines: usize, horizon: f64) -> SimConfig {
        let mut c = SimConfig::default();
        c.machines = machines;
        c.horizon = horizon;
        c.use_runtime = false;
        c.scheduler = crate::scheduler::SchedulerKind::Sca;
        c
    }

    #[test]
    fn clones_in_light_load() {
        let cfg = cfg(2000, 200.0);
        let wl = generate(&WorkloadConfig::paper(0.5), cfg.horizon, 5);
        let sched = crate::scheduler::build(&cfg, &WorkloadConfig::paper(0.5)).unwrap();
        let res = Simulator::new(cfg, wl, sched).run();
        assert!(res.speculative_launches > 0, "SCA should clone in light load");
        assert!(!res.completed.is_empty());
    }

    #[test]
    fn degrades_to_srpt_when_tight() {
        let cfg = cfg(30, 300.0);
        let wl = generate(&WorkloadConfig::paper(1.0), cfg.horizon, 5);
        let sched = crate::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let res = Simulator::new(cfg, wl, sched).run();
        // under severe pressure SCA behaves like SRPT: few/no clones
        assert!(!res.completed.is_empty());
    }

    #[test]
    fn beats_naive_in_light_load() {
        let c = cfg(2000, 300.0);
        let wl = generate(&WorkloadConfig::paper(0.5), c.horizon, 7);
        let sched = crate::scheduler::build(&c, &WorkloadConfig::paper(0.5)).unwrap();
        let sca = Simulator::new(c.clone(), wl.clone(), sched).run();
        let naive = Simulator::new(c, wl, Box::new(crate::scheduler::naive::Naive)).run();
        assert!(
            sca.mean_flowtime() < naive.mean_flowtime(),
            "sca {} vs naive {}",
            sca.mean_flowtime(),
            naive.mean_flowtime()
        );
    }
}
