//! The policy-spec grammar: `ordering+rule[*budget]`.
//!
//! The paper's decision model is layered — level-2/3 job ordering, a
//! per-task speculation rule, and a copy-count decision — and the grammar
//! names one choice per axis so sweeps can treat pipeline components as a
//! first-class dimension:
//!
//! ```text
//! spec     := ordering "+" rule [ "*" budget ]
//! ordering := "fifo" | "srpt" | "est-srpt"
//! rule     := "never" | "clone" | "mantri" | "late" | "sda" | "ese"
//! budget   := "fixed" K | "cap" K | "p2" | "eq29"        (K >= 2)
//! ```
//!
//! Examples: `srpt+mantri`, `fifo+sda`, `est-srpt+ese*cap2`,
//! `srpt+clone*fixed3`.  Omitting the budget selects the rule's canonical
//! default (see [`RuleKind::instrumented`] and `scheduler::pipeline`); the
//! seven legacy scheduler names are themselves canonical compositions —
//! [`SchedulerKind::canonical_spec`](crate::scheduler::SchedulerKind::canonical_spec)
//! maps them (the README carries the full table).
//!
//! Everything here is plain-old-data (`Copy`), so a parsed spec travels
//! through `SimConfig` → TOML → CLI → `ExperimentSpec` grids unchanged and
//! `Display`/`FromStr` round-trip exactly.

use std::fmt;
use std::str::FromStr;

/// Level-2/3 job ordering (the paper's layers 2 and 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrderingKind {
    /// Arrival (id) order — Hadoop/Dryad's stock job schedulers, the
    /// baseline ordering for Mantri/LATE.
    Fifo,
    /// The paper's smallest-remaining-workload-first levels, keyed by the
    /// mean-field `#unfinished * E[x]`.
    Srpt,
    /// SRPT with the estimate-refined key: revealed copies contribute
    /// their observed total work instead of `E[x]` (see
    /// `estimator::revealed_job_workload` and the re-key contract in
    /// `cluster::index`).
    EstSrpt,
}

impl OrderingKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            OrderingKind::Fifo => "fifo",
            OrderingKind::Srpt => "srpt",
            OrderingKind::EstSrpt => "est-srpt",
        }
    }
}

/// When to act on a task (the per-task speculation rule).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleKind {
    /// No speculation at all (the Fig. 5 "no backup" baseline).
    Never,
    /// Clone every queued job at launch time (Sec. III generalized
    /// cloning; the copy count is the budget's decision).
    Clone,
    /// Mantri's duplicate rule `P(t_rem > 2 E[x]) > delta` on running
    /// single-copy tasks (+ the optional kill/restart ablation).
    Mantri,
    /// LATE's progress-rate percentile rule under a speculative cap.
    Late,
    /// SDA's event-driven reveal test: remaining work > `sigma * E[x]` at
    /// the detection checkpoint (Sec. V, Theorem 3).
    Sda,
    /// ESE's slot-gated threshold backups plus the small-job cloning gate
    /// (Algorithm 2; the clone count is the budget's decision).
    Ese,
}

impl RuleKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            RuleKind::Never => "never",
            RuleKind::Clone => "clone",
            RuleKind::Mantri => "mantri",
            RuleKind::Late => "late",
            RuleKind::Sda => "sda",
            RuleKind::Ese => "ese",
        }
    }

    /// Whether the rule owns the paper's `s_i` detection checkpoint
    /// (selects the estimator via `estimator::for_policy`): SDA/ESE do
    /// (and Clone, whose SCA composition orders level 2 by the same
    /// instrumented estimator the monolith used); Mantri/LATE are blind
    /// baselines; Never performs no estimator queries at all.
    pub fn instrumented(&self) -> bool {
        matches!(self, RuleKind::Clone | RuleKind::Sda | RuleKind::Ese)
    }
}

/// How many copies a flagged task/job gets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BudgetKind {
    /// Exactly `k` copies per task at launch-time cloning, degrading to
    /// single copies when the cluster is tight unless `clone_strict`
    /// (CloneAll's Sec. III semantics); `k` total copies for backups.
    Fixed(u32),
    /// A plain per-task total-copy target of `k` for both phases, with no
    /// room check (resource-capped: `cap2` = at most one backup).
    Cap(u32),
    /// SCA's P2 utility solver over the queued batch (Algorithm 1); falls
    /// back to single copies when the batch does not fit.  Batch budgets
    /// own the queued-cloning decision, so `p2` pairs only with the
    /// cloning rules (`clone`, `ese`) — `scheduler::pipeline::build`
    /// rejects other pairings.
    P2,
    /// ESE's Eq. 29 optimal small-job clone count.
    Eq29,
}

impl fmt::Display for BudgetKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BudgetKind::Fixed(k) => write!(f, "fixed{k}"),
            BudgetKind::Cap(k) => write!(f, "cap{k}"),
            BudgetKind::P2 => write!(f, "p2"),
            BudgetKind::Eq29 => write!(f, "eq29"),
        }
    }
}

/// One composed policy: an ordering, a rule, and (optionally) an explicit
/// budget.  `budget = None` means the rule's canonical default — it is
/// not printed, so `Display`/`FromStr` round-trip exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PolicySpec {
    pub ordering: OrderingKind,
    pub rule: RuleKind,
    pub budget: Option<BudgetKind>,
}

impl PolicySpec {
    pub fn new(ordering: OrderingKind, rule: RuleKind, budget: Option<BudgetKind>) -> Self {
        PolicySpec { ordering, rule, budget }
    }
}

impl fmt::Display for PolicySpec {
    /// Prints `ordering+rule` plus `*budget` when the budget is explicit.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}+{}", self.ordering.as_str(), self.rule.as_str())?;
        if let Some(b) = self.budget {
            write!(f, "*{b}")?;
        }
        Ok(())
    }
}

impl FromStr for PolicySpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (ord, rest) = s.split_once('+').ok_or_else(|| grammar_err(s))?;
        let ordering = match ord {
            "fifo" => OrderingKind::Fifo,
            "srpt" => OrderingKind::Srpt,
            "est-srpt" => OrderingKind::EstSrpt,
            other => return Err(format!("unknown ordering '{other}' (fifo|srpt|est-srpt)")),
        };
        let (rule_s, budget_s) = match rest.split_once('*') {
            Some((r, b)) => (r, Some(b)),
            None => (rest, None),
        };
        let rule = match rule_s {
            "never" => RuleKind::Never,
            "clone" => RuleKind::Clone,
            "mantri" => RuleKind::Mantri,
            "late" => RuleKind::Late,
            "sda" => RuleKind::Sda,
            "ese" => RuleKind::Ese,
            other => {
                return Err(format!(
                    "unknown speculation rule '{other}' (never|clone|mantri|late|sda|ese)"
                ))
            }
        };
        let budget = budget_s.map(parse_budget).transpose()?;
        Ok(PolicySpec { ordering, rule, budget })
    }
}

fn parse_budget(s: &str) -> Result<BudgetKind, String> {
    if s == "p2" {
        return Ok(BudgetKind::P2);
    }
    if s == "eq29" {
        return Ok(BudgetKind::Eq29);
    }
    if let Some(k) = s.strip_prefix("fixed") {
        return parse_copies(k, s).map(BudgetKind::Fixed);
    }
    if let Some(k) = s.strip_prefix("cap") {
        return parse_copies(k, s).map(BudgetKind::Cap);
    }
    Err(format!("unknown copy budget '{s}' (fixedK|capK|p2|eq29, K >= 2)"))
}

fn parse_copies(k: &str, whole: &str) -> Result<u32, String> {
    let n: u32 = k.parse().map_err(|_| format!("budget '{whole}': bad copy count '{k}'"))?;
    if n < 2 {
        return Err(format!("budget '{whole}': copy count must be >= 2"));
    }
    Ok(n)
}

fn grammar_err(s: &str) -> String {
    format!(
        "unknown scheduler '{s}' (expected one of the canonical names \
         naive|clone_all|mantri|late|sca|sda|ese, or a composition \
         'ordering+rule[*budget]' — e.g. srpt+mantri, fifo+sda, \
         est-srpt+ese*cap2; orderings fifo|srpt|est-srpt, rules \
         never|clone|mantri|late|sda|ese, budgets fixedK|capK|p2|eq29)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_issue_examples() {
        let s: PolicySpec = "srpt+mantri".parse().unwrap();
        assert_eq!(s.ordering, OrderingKind::Srpt);
        assert_eq!(s.rule, RuleKind::Mantri);
        assert_eq!(s.budget, None);
        let s: PolicySpec = "fifo+sda".parse().unwrap();
        assert_eq!(s.ordering, OrderingKind::Fifo);
        assert_eq!(s.rule, RuleKind::Sda);
        let s: PolicySpec = "est-srpt+ese*cap2".parse().unwrap();
        assert_eq!(s.ordering, OrderingKind::EstSrpt);
        assert_eq!(s.rule, RuleKind::Ese);
        assert_eq!(s.budget, Some(BudgetKind::Cap(2)));
    }

    /// Property-style round-trip: every representable spec survives
    /// `Display` → `FromStr` unchanged.
    #[test]
    fn display_parse_roundtrip_over_the_full_grid() {
        let orderings = [OrderingKind::Fifo, OrderingKind::Srpt, OrderingKind::EstSrpt];
        let rules = [
            RuleKind::Never,
            RuleKind::Clone,
            RuleKind::Mantri,
            RuleKind::Late,
            RuleKind::Sda,
            RuleKind::Ese,
        ];
        let budgets = [
            None,
            Some(BudgetKind::Fixed(2)),
            Some(BudgetKind::Fixed(5)),
            Some(BudgetKind::Cap(2)),
            Some(BudgetKind::Cap(8)),
            Some(BudgetKind::P2),
            Some(BudgetKind::Eq29),
        ];
        let mut seen = std::collections::BTreeSet::new();
        for &ordering in &orderings {
            for &rule in &rules {
                for &budget in &budgets {
                    let spec = PolicySpec::new(ordering, rule, budget);
                    let text = spec.to_string();
                    let back: PolicySpec = text.parse().unwrap_or_else(|e| {
                        panic!("'{text}' failed to re-parse: {e}");
                    });
                    assert_eq!(back, spec, "round-trip changed '{text}'");
                    assert!(seen.insert(text.clone()), "'{text}' printed twice");
                }
            }
        }
        assert_eq!(seen.len(), orderings.len() * rules.len() * budgets.len());
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "srpt",
            "srpt+",
            "+mantri",
            "bogus+mantri",
            "srpt+bogus",
            "srpt+mantri*",
            "srpt+mantri*bogus",
            "srpt+mantri*cap1",
            "srpt+mantri*fixed0",
            "srpt+mantri*capx",
            "srpt+mantri*cap2*cap3",
        ] {
            assert!(bad.parse::<PolicySpec>().is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn instrumentation_follows_the_monolith_mapping() {
        assert!(!RuleKind::Never.instrumented());
        assert!(!RuleKind::Mantri.instrumented());
        assert!(!RuleKind::Late.instrumented());
        assert!(RuleKind::Clone.instrumented()); // SCA's composition
        assert!(RuleKind::Sda.instrumented());
        assert!(RuleKind::Ese.instrumented());
    }
}
