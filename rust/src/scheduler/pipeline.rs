//! `Pipeline` — the composition of the three policy axes into one
//! [`Scheduler`], owning the shared slot loop exactly once.
//!
//! Per slot: (1) the [`rule::SpeculationRule`]'s backup phase, (2) level 2 in
//! the [`JobOrdering`]'s order, (3) the χ(l) walk where the
//! [`CopyBudget`] (batch-planned or per job through the rule's clone
//! gate) decides launch-time copy counts.  `on_reveal` forwards to the
//! rule.  This is the structure every pre-redesign monolith shared; the
//! monoliths themselves are deleted (the byte-identical proof ran its
//! course) and `tests/pipeline_equivalence.rs` now pins the canonical
//! compositions against committed sweep-CSV snapshots, plus the wakeup
//! planner against the polled slot loop.
//!
//! [`SchedulerKind::canonical_spec`]: super::SchedulerKind::canonical_spec

use crate::cluster::job::TaskRef;
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::estimator::{self, RemainingTime};

use super::budget::{CapBudget, CopyBudget, Eq29Budget, FixedBudget, P2Budget};
use super::ordering::{EstSrpt, Fifo, JobOrdering, Srpt};
use super::policy::{BudgetKind, OrderingKind, RuleKind};
use super::{rule, Scheduler};

/// A composed policy: ordering × speculation rule × copy budget.
pub struct Pipeline {
    /// The policy-spec label (a canonical name or the grammar string) —
    /// what reports and sweep CSVs print.
    name: String,
    ordering: Box<dyn JobOrdering>,
    rule: Box<dyn rule::SpeculationRule>,
    budget: Box<dyn CopyBudget>,
    est: Box<dyn RemainingTime>,
}

impl Pipeline {
    pub fn ordering_name(&self) -> &'static str {
        self.ordering.name()
    }

    pub fn rule_name(&self) -> &'static str {
        self.rule.name()
    }

    pub fn budget_name(&self) -> &'static str {
        self.budget.name()
    }
}

impl Scheduler for Pipeline {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        // 1. the rule's slot-gated backup phase (Mantri/LATE/ESE; no-op
        // for never/clone/sda)
        self.rule.on_slot(cl, self.est.as_ref(), self.budget.as_ref());
        // 2. remaining tasks of begun jobs, in the ordering's order
        self.ordering.schedule_running(cl, self.est.as_ref());
        // 3. queued jobs χ(l): budget-planned (P2) or gate + per-job count
        let chi = self.ordering.snapshot_queued(cl);
        if chi.is_empty() {
            cl.put_scratch(chi);
            return;
        }
        let chi_len = chi.len();
        let plan = self.budget.plan_queued(cl, &chi);
        for (i, &id) in chi.iter().enumerate() {
            let idle = cl.idle();
            if idle == 0 {
                break;
            }
            let copies = match &plan {
                Some(counts) => counts[i],
                None if self.rule.clone_gate(cl, id, chi_len) => self.budget.queued_copies(cl, id),
                None => 1,
            };
            if copies > 1 {
                cl.launch_job_cloned(id, copies);
            } else {
                cl.launch_unlaunched(id, idle);
            }
        }
        cl.put_scratch(chi);
    }

    fn on_reveal(&mut self, cl: &mut Cluster, t: TaskRef) {
        self.rule.on_reveal(cl, self.est.as_ref(), self.budget.as_ref(), t);
    }

    /// The pipeline's wakeup horizon is the earlier of its rule's and its
    /// budget's.  The ordering axis contributes nothing: every admissible
    /// level-2/3 key is piecewise-constant between mutations (the re-key
    /// contract, [`ordering`](super::ordering)), and after a fired slot
    /// launchable work remains only on a full cluster, where any idle
    /// change is itself a mutation — so levels 2/3 can never act on an
    /// otherwise-quiet cluster.
    fn next_decision_time(&self, cl: &Cluster) -> Option<f64> {
        match (
            self.rule.next_decision_time(cl, self.est.as_ref()),
            self.budget.next_decision_time(cl),
        ) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

/// Assemble the pipeline for `cfg.scheduler` (canonical names resolve via
/// [`SchedulerKind::canonical_spec`](super::SchedulerKind::canonical_spec)).
/// `alpha` is the workload's Pareto tail index — the SDA/ESE thresholds
/// derive from it.
pub fn build(cfg: &SimConfig, alpha: f64) -> Result<Box<dyn Scheduler>, String> {
    Ok(Box::new(build_pipeline(cfg, alpha)?))
}

/// [`build`], returning the concrete [`Pipeline`] (component
/// introspection for tests and diagnostics).
pub fn build_pipeline(cfg: &SimConfig, alpha: f64) -> Result<Pipeline, String> {
    let spec = cfg.scheduler.canonical_spec(cfg);
    let est = estimator::for_policy(cfg, spec.rule.instrumented());
    let ordering: Box<dyn JobOrdering> = match spec.ordering {
        OrderingKind::Fifo => Box::new(Fifo),
        OrderingKind::Srpt => Box::new(Srpt),
        OrderingKind::EstSrpt => Box::new(EstSrpt),
    };
    let rule: Box<dyn rule::SpeculationRule> = match spec.rule {
        RuleKind::Never => Box::new(rule::Never),
        RuleKind::Clone => Box::new(rule::Clone),
        RuleKind::Mantri => Box::new(rule::Mantri::new(cfg)),
        RuleKind::Late => Box::new(rule::Late::new(cfg)),
        RuleKind::Sda => Box::new(rule::Sda::new(cfg, alpha)),
        RuleKind::Ese => Box::new(rule::Ese::new(cfg, alpha)),
    };
    // an omitted budget is the rule's canonical default — the counts the
    // monoliths hard-wired
    let kind = match spec.budget {
        Some(b) => b,
        None => match spec.rule {
            // Never flags nothing; the placeholder budget is never queried
            RuleKind::Never => BudgetKind::Cap(2),
            RuleKind::Clone => BudgetKind::Fixed(cfg.clone_copies),
            RuleKind::Mantri | RuleKind::Late => BudgetKind::Cap(2),
            RuleKind::Sda => {
                BudgetKind::Cap(crate::opt::p3::solve(alpha, cfg.detect_frac, cfg.r_max).c_star)
            }
            RuleKind::Ese => BudgetKind::Eq29,
        },
    };
    // P2 is a *batch* budget: it plans the whole χ(l) snapshot and
    // bypasses the rule's per-job clone gate, so pairing it with a rule
    // that never clones queued jobs would let the budget usurp the
    // rule's when-to-act axis.  Reject the contradiction loudly.
    if kind == BudgetKind::P2 && !matches!(spec.rule, RuleKind::Clone | RuleKind::Ese) {
        return Err(format!(
            "'{}': the p2 budget batch-plans queued-job cloning, which the '{}' rule \
             never performs — pair p2 with a cloning rule (clone|ese)",
            cfg.scheduler,
            spec.rule.as_str()
        ));
    }
    let budget: Box<dyn CopyBudget> = match kind {
        BudgetKind::Fixed(k) => Box::new(FixedBudget { copies: k, strict: cfg.clone_strict }),
        BudgetKind::Cap(k) => Box::new(CapBudget { copies: k }),
        BudgetKind::P2 => Box::new(P2Budget::new(cfg)?),
        BudgetKind::Eq29 => Box::new(Eq29Budget::new(cfg, alpha)),
    };
    Ok(Pipeline { name: cfg.scheduler.to_string(), ordering, rule, budget, est })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SchedulerKind;

    fn cfg_for(kind: SchedulerKind) -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.use_runtime = false;
        cfg.scheduler = kind;
        cfg
    }

    #[test]
    fn canonical_names_label_their_pipelines() {
        for kind in SchedulerKind::all() {
            let sched = build(&cfg_for(kind), 2.0).unwrap();
            assert_eq!(sched.name(), kind.to_string());
        }
    }

    #[test]
    fn canonical_compositions_pick_the_monolith_components() {
        let expect = [
            (SchedulerKind::Naive, "srpt", "never", "cap"),
            (SchedulerKind::CloneAll, "srpt", "clone", "fixed"),
            (SchedulerKind::Mantri, "fifo", "mantri", "cap"),
            (SchedulerKind::Late, "fifo", "late", "cap"),
            (SchedulerKind::Sca, "srpt", "clone", "p2"),
            (SchedulerKind::Sda, "srpt", "sda", "cap"),
            (SchedulerKind::Ese, "srpt", "ese", "eq29"),
        ];
        for (kind, ordering, rule, budget) in expect {
            let p = build_pipeline(&cfg_for(kind), 2.0).unwrap();
            assert_eq!(p.ordering_name(), ordering, "{kind}");
            assert_eq!(p.rule_name(), rule, "{kind}");
            assert_eq!(p.budget_name(), budget, "{kind}");
        }
        // the mantri_srpt ablation upgrades the ordering axis
        let mut cfg = cfg_for(SchedulerKind::Mantri);
        cfg.mantri_srpt = true;
        assert_eq!(build_pipeline(&cfg, 2.0).unwrap().ordering_name(), "srpt");
    }

    #[test]
    fn p2_budget_requires_a_cloning_rule() {
        // p2 batch-plans queued-job cloning; a rule that never clones
        // queued jobs must not be silently overridden by it
        for bad in ["srpt+never*p2", "fifo+mantri*p2", "srpt+sda*p2", "fifo+late*p2"] {
            let kind: SchedulerKind = bad.parse().unwrap();
            let err = match build(&cfg_for(kind), 2.0) {
                Ok(_) => panic!("'{bad}' should be rejected"),
                Err(e) => e,
            };
            assert!(err.contains("cloning rule"), "'{bad}': unhelpful error {err}");
        }
        for ok in ["fifo+clone*p2", "srpt+clone*p2", "est-srpt+ese*p2"] {
            let kind: SchedulerKind = ok.parse().unwrap();
            assert!(build(&cfg_for(kind), 2.0).is_ok(), "'{ok}' should build");
        }
    }

    #[test]
    fn composed_specs_label_their_pipelines() {
        for spec in ["fifo+sda", "est-srpt+mantri", "srpt+ese*cap2"] {
            let kind: SchedulerKind = spec.parse().unwrap();
            let p = build_pipeline(&cfg_for(kind), 2.0).unwrap();
            assert_eq!(p.name(), spec);
        }
        let kind: SchedulerKind = "est-srpt+ese*cap2".parse().unwrap();
        let p = build_pipeline(&cfg_for(kind), 2.0).unwrap();
        assert_eq!(p.ordering_name(), "est-srpt");
        assert_eq!(p.rule_name(), "ese");
        assert_eq!(p.budget_name(), "cap");
    }
}
