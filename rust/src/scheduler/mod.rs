//! Speculative-execution policies, decomposed into a composable pipeline.
//!
//! The paper's decision model is layered — level-2/3 job ordering, a
//! per-task speculation rule, and a copy-count decision — and since the
//! pipeline redesign each policy *is* a composition of those three axes
//! (see [`policy`] for the grammar `ordering+rule[*budget]`):
//!
//! * [`ordering`] — [`JobOrdering`](ordering::JobOrdering): FIFO / SRPT /
//!   estimate-driven SRPT (with the level-2 re-key contract made
//!   explicit);
//! * [`rule`] — [`SpeculationRule`](rule::SpeculationRule): never /
//!   always-clone / Mantri-δ / LATE progress-rate / SDA-reveal /
//!   ESE-threshold;
//! * [`budget`] — [`CopyBudget`](budget::CopyBudget): fixed-k / SCA's P2
//!   utility solver / resource-capped / ESE's Eq. 29;
//! * [`pipeline`] — the [`Pipeline`] composing them behind the
//!   [`Scheduler`] trait, owning the shared slot loop (χ allocation,
//!   backpressure, scratch buffers, `SchedIndex` queries) exactly once.
//!
//! The seven canonical policy names are themselves compositions
//! ([`SchedulerKind::canonical_spec`]):
//!
//! | name | composition | paper reference |
//! |---|---|---|
//! | `naive` | `srpt+never` | Fig. 5 "no backup" baseline |
//! | `clone_all` | `srpt+clone` (`fixed` k = `clone_copies`) | Sec. III generalized cloning |
//! | `mantri` | `fifo+mantri` (`srpt+mantri` with `mantri_srpt`) | Microsoft Mantri's δ-rule |
//! | `late` | `fifo+late` | Berkeley LATE |
//! | `sca` | `srpt+clone*p2` | Algorithm 1 (Smart Cloning) |
//! | `sda` | `srpt+sda` (`cap` c* from P3) | Sec. V, Theorem 3 |
//! | `ese` | `srpt+ese` (`eq29` small-job counts) | Algorithm 2 (Enhanced SE) |
//!
//! The pre-redesign monolithic implementations (and their `legacy_sched`
//! flag) are **gone**: the pipeline is the only implementation.  Their
//! equivalence role passed to `tests/pipeline_equivalence.rs`, which now
//! pins the pipeline against committed canonical sweep-CSV snapshots and
//! proves the wakeup planner (`wakeup = true`, the default) byte-identical
//! to the polled slot loop (`--no-wakeup`).
//!
//! ## Remaining-time queries
//!
//! No policy does its own remaining-time math: every speculation rule
//! queries a [`crate::estimator::RemainingTime`] built by
//! `estimator::for_policy(cfg, instrumented)` at construction, where
//! `instrumented` says whether the rule owns the paper's `s_i` detection
//! checkpoint ([`policy::RuleKind::instrumented`]): SDA/ESE (and SCA's
//! clone composition) do, the Mantri/LATE baselines do not.
//! `cfg.speed_aware` (default true) selects the class-speed-corrected
//! estimator variants — a no-op on the paper's homogeneous cluster; see
//! [`crate::estimator`] for the full observation contract.
//!
//! ## Hot paths
//!
//! With `cfg.sched_index` on (the default) every slot hook queries the
//! cluster's incremental [`SchedIndex`](crate::cluster::index::SchedIndex)
//! — speculation-candidate sets and pre-ordered job sets maintained at the
//! mutation points — so per-slot cost is O(what's actually active), and
//! reused scratch buffers keep the hooks allocation-free.  Setting
//! `sched_index = false` selects the retained naive full scans; both paths
//! make bit-identical decisions (the equivalence suite in
//! `tests/experiment_integration.rs` proves byte-identical sweep CSVs).
//! The estimate-driven ordering re-keys the index at the reveal/kill/
//! finish mutation points (the `est-srpt` re-key contract, [`ordering`]).

pub mod budget;
pub mod ordering;
pub mod pipeline;
pub mod policy;
pub mod rule;
pub mod srpt;

use std::fmt;
use std::str::FromStr;

pub use pipeline::Pipeline;
pub use policy::{BudgetKind, OrderingKind, PolicySpec, RuleKind};

use crate::cluster::job::TaskRef;
use crate::cluster::sim::{Cluster, Workload};
use crate::config::{SimConfig, WorkloadConfig};

/// A speculative-execution policy driven by the simulator.
/// Not `Send`: SCA's P2 budget may hold a thread-pinned PJRT executor; the
/// live master therefore constructs its scheduler on its own thread.
pub trait Scheduler {
    /// The policy label reports print — a canonical name (`"sda"`) or a
    /// composition spec (`"est-srpt+mantri"`).
    fn name(&self) -> &str;
    /// Slot-boundary decisions (the paper's slotted model).
    fn on_slot(&mut self, cl: &mut Cluster);
    /// A first copy crossed its detection checkpoint: its true remaining
    /// time just became visible (SDA acts here; others ignore it).
    fn on_reveal(&mut self, _cl: &mut Cluster, _t: TaskRef) {}
    /// Wakeup-planner horizon: the earliest simulated instant at which
    /// this scheduler's next `on_slot` could act differently from an
    /// immediate re-run, assuming **no cluster mutation** in between
    /// (mutations set [`Cluster::sched_dirty`] and independently force
    /// the next slot).  `None` = never — absent mutations, every future
    /// slot is a provable no-op.  Queried by the
    /// [`SlotGate`](crate::cluster::sim::SlotGate) at the first clean
    /// slot after a fired one (mutation-free since the fire, so the
    /// state is still the post-`on_slot` state — busy regimes never pay
    /// for it).  The conservative default — "now" — makes the planner fire
    /// every grid slot, reproducing the polled loop exactly; override
    /// only with a proven bound (DESIGN.md §12).
    fn next_decision_time(&self, cl: &Cluster) -> Option<f64> {
        Some(cl.clock)
    }
}

/// Which policy to run (CLI/TOML selectable): one of the seven canonical
/// names, or any composition from the [`policy`] grammar.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    Naive,
    CloneAll,
    Mantri,
    Late,
    Sca,
    Sda,
    Ese,
    /// A composed policy pipeline: `ordering+rule[*budget]`.
    Composed(PolicySpec),
}

impl SchedulerKind {
    /// The seven canonical policies (the paper's comparison set).
    pub fn all() -> [SchedulerKind; 7] {
        [
            SchedulerKind::Naive,
            SchedulerKind::CloneAll,
            SchedulerKind::Mantri,
            SchedulerKind::Late,
            SchedulerKind::Sca,
            SchedulerKind::Sda,
            SchedulerKind::Ese,
        ]
    }

    /// The composition this kind resolves to (`cfg` supplies the knobs
    /// folded into canonical specs: `mantri_srpt` upgrades Mantri's
    /// ordering axis; budget defaults resolve at build time).
    pub fn canonical_spec(&self, cfg: &SimConfig) -> PolicySpec {
        use self::policy::{BudgetKind as B, OrderingKind as O, RuleKind as R};
        match self {
            SchedulerKind::Naive => PolicySpec::new(O::Srpt, R::Never, None),
            SchedulerKind::CloneAll => PolicySpec::new(O::Srpt, R::Clone, None),
            SchedulerKind::Mantri => {
                let ord = if cfg.mantri_srpt { O::Srpt } else { O::Fifo };
                PolicySpec::new(ord, R::Mantri, None)
            }
            SchedulerKind::Late => PolicySpec::new(O::Fifo, R::Late, None),
            SchedulerKind::Sca => PolicySpec::new(O::Srpt, R::Clone, Some(B::P2)),
            SchedulerKind::Sda => PolicySpec::new(O::Srpt, R::Sda, None),
            SchedulerKind::Ese => PolicySpec::new(O::Srpt, R::Ese, None),
            SchedulerKind::Composed(spec) => *spec,
        }
    }

    /// Does this policy order level 2 by the estimate-driven key?  The
    /// cluster asks at construction to enable the `SchedIndex` est-keyed
    /// level-2 twin (no upkeep cost otherwise); no canonical policy does.
    pub fn uses_est_ordering(&self) -> bool {
        matches!(self, SchedulerKind::Composed(s) if s.ordering == OrderingKind::EstSrpt)
    }
}

impl fmt::Display for SchedulerKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SchedulerKind::Naive => f.write_str("naive"),
            SchedulerKind::CloneAll => f.write_str("clone_all"),
            SchedulerKind::Mantri => f.write_str("mantri"),
            SchedulerKind::Late => f.write_str("late"),
            SchedulerKind::Sca => f.write_str("sca"),
            SchedulerKind::Sda => f.write_str("sda"),
            SchedulerKind::Ese => f.write_str("ese"),
            SchedulerKind::Composed(spec) => write!(f, "{spec}"),
        }
    }
}

impl FromStr for SchedulerKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "naive" => Ok(SchedulerKind::Naive),
            "clone_all" => Ok(SchedulerKind::CloneAll),
            "mantri" => Ok(SchedulerKind::Mantri),
            "late" => Ok(SchedulerKind::Late),
            "sca" => Ok(SchedulerKind::Sca),
            "sda" => Ok(SchedulerKind::Sda),
            "ese" => Ok(SchedulerKind::Ese),
            other => other.parse::<PolicySpec>().map(SchedulerKind::Composed),
        }
    }
}

/// Instantiate the configured policy.  `workload` supplies the common
/// heavy-tail order for the rules that derive their thresholds from the
/// analysis (SDA's Theorem 3, ESE's Eq. 30-33).  For trace workloads the
/// tail index is estimated from the trace's own sampled durations (loading
/// the file if no pre-sampled [`Workload`] is at hand — prefer
/// [`build_for`] when one is).
pub fn build(
    cfg: &SimConfig,
    workload: &WorkloadConfig,
) -> Result<Box<dyn Scheduler>, String> {
    build_for(cfg, workload, None)
}

/// [`build`] with an optional pre-sampled workload, so trace replays derive
/// alpha from the durations already in memory instead of re-reading the
/// trace file.  The experiment runner calls this once per grid cell, inside
/// the worker thread (the `Scheduler` trait is `!Send`).
pub fn build_for(
    cfg: &SimConfig,
    workload: &WorkloadConfig,
    sampled: Option<&Workload>,
) -> Result<Box<dyn Scheduler>, String> {
    let alpha = tail_alpha(workload, sampled)?;
    pipeline::build(cfg, alpha)
}

/// The workload's Pareto tail index.  Trace workloads estimate it from
/// the pre-sampled durations when available; otherwise one streaming
/// pre-pass over the trace file fits it (`workload::scan` runs the exact
/// `estimate_alpha` accumulation, so both routes agree bitwise), and a
/// read failure is a hard error — a silently assumed alpha = 2.0 would
/// mis-derive every analysis threshold.
fn tail_alpha(workload: &WorkloadConfig, sampled: Option<&Workload>) -> Result<f64, String> {
    match workload {
        WorkloadConfig::Poisson { alpha, .. }
        | WorkloadConfig::Bursty { alpha, .. }
        | WorkloadConfig::SingleJob { alpha, .. } => Ok(*alpha),
        WorkloadConfig::Trace { path, format, .. } => match sampled {
            Some(wl) => Ok(crate::cluster::generator::estimate_alpha(wl)),
            None => crate::workload::scan(path, *format)
                .map(|stats| stats.alpha)
                .map_err(|e| format!("cannot derive the tail index from trace '{path}': {e}")),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_kinds() {
        let mut cfg = SimConfig::default();
        cfg.use_runtime = false; // no artifacts needed in unit tests
        let wl = WorkloadConfig::paper(6.0);
        for kind in SchedulerKind::all() {
            cfg.scheduler = kind;
            let s = build(&cfg, &wl).unwrap();
            assert_eq!(s.name(), kind.to_string());
        }
    }

    #[test]
    fn composed_kinds_build_pipelines() {
        let mut cfg = SimConfig::default();
        cfg.use_runtime = false;
        cfg.scheduler = "fifo+sda".parse().unwrap();
        let wl = WorkloadConfig::paper(6.0);
        assert_eq!(build(&cfg, &wl).unwrap().name(), "fifo+sda");
    }

    #[test]
    fn trace_alpha_estimated_from_sampled_workload() {
        let mut cfg = SimConfig::default();
        cfg.use_runtime = false;
        cfg.scheduler = SchedulerKind::Sda;
        let wl = crate::cluster::generator::generate(&WorkloadConfig::paper(2.0), 50.0, 3);
        // with a pre-sampled workload the trace file is never touched, so a
        // bogus path must not fail the build
        let trace_cfg = WorkloadConfig::trace("/nonexistent/trace.csv");
        let s = build_for(&cfg, &trace_cfg, Some(&wl)).unwrap();
        assert_eq!(s.name(), "sda");
        // without one, an unreadable trace is a hard error (satellite: no
        // silent alpha = 2.0 fallback), and the error names the path
        let err = match build_for(&cfg, &trace_cfg, None) {
            Ok(_) => panic!("unreadable trace must not silently fall back"),
            Err(e) => e,
        };
        assert!(err.contains("/nonexistent/trace.csv"), "unhelpful error: {err}");
    }

    #[test]
    fn kind_str_roundtrip() {
        for kind in SchedulerKind::all() {
            let back: SchedulerKind = kind.to_string().parse().unwrap();
            assert_eq!(kind, back);
        }
        for spec in ["srpt+mantri", "fifo+sda", "est-srpt+ese*cap2", "srpt+clone*fixed3"] {
            let kind: SchedulerKind = spec.parse().unwrap();
            assert_eq!(kind.to_string(), spec);
            assert!(matches!(kind, SchedulerKind::Composed(_)));
        }
        assert!("bogus".parse::<SchedulerKind>().is_err());
        assert!("srpt+bogus".parse::<SchedulerKind>().is_err());
    }

    #[test]
    fn est_ordering_detection() {
        assert!(!SchedulerKind::Sda.uses_est_ordering());
        let k: SchedulerKind = "srpt+sda".parse().unwrap();
        assert!(!k.uses_est_ordering());
        let k: SchedulerKind = "est-srpt+sda".parse().unwrap();
        assert!(k.uses_est_ordering());
    }
}
