//! Speculative-execution policies.
//!
//! All seven schedulers share the same slotted hook structure (the paper's
//! decision model) so the comparison isolates the *speculation policy*:
//!
//! * [`naive`]     — no speculation (the Fig. 5 "no backup" baseline).
//! * [`clone_all`] — Sec. III generalized cloning (>= 2 copies per task).
//! * [`mantri`]    — Microsoft Mantri's rule `P(t_rem > 2 t_new) > delta`.
//! * [`late`]      — Berkeley LATE (progress rate + speculativeCap).
//! * [`sca`]       — Smart Cloning Algorithm (Algorithm 1, P2 solver).
//! * [`sda`]       — Straggler Detection Algorithm (Sec. V, Theorem 3).
//! * [`ese`]       — Enhanced Speculative Execution (Algorithm 2).
//!
//! ## Remaining-time queries
//!
//! No policy does its own remaining-time math: every speculation rule
//! queries a [`crate::estimator::RemainingTime`] built by
//! `estimator::for_policy(cfg, instrumented)` at construction, where
//! `instrumented` says whether the policy owns the paper's `s_i`
//! detection checkpoint:
//!
//! | policy | instrumented | queries |
//! |---|---|---|
//! | Mantri | no (blind baseline) | `task_prob_exceeds` (its rule's `delta`), `task_remaining_work`, level-2 key |
//! | LATE | no (blind baseline) | `copy_remaining_wall` (time-to-end), level-2 key via FIFO |
//! | SCA | yes | level-2 ordering key (`job_remaining_work`) |
//! | SDA | yes | `copy_remaining_work` at the reveal (vs `sigma * E[x]`), level-2 key |
//! | ESE | yes | `task_remaining_work` per slot (vs `sigma * E[x]`), level-2 key |
//!
//! `cfg.speed_aware` (default true) selects the class-speed-corrected
//! estimator variants — a no-op on the paper's homogeneous cluster; see
//! [`crate::estimator`] for the full observation contract.
//!
//! ## Hot paths
//!
//! With `cfg.sched_index` on (the default) every slot hook queries the
//! cluster's incremental [`SchedIndex`](crate::cluster::index::SchedIndex)
//! — speculation-candidate sets and pre-ordered job sets maintained at the
//! mutation points — so per-slot cost is O(what's actually active), and
//! reused scratch buffers keep the hooks allocation-free.  Setting
//! `sched_index = false` selects the retained naive full scans; both paths
//! make bit-identical decisions (the equivalence suite in
//! `tests/experiment_integration.rs` proves byte-identical sweep CSVs).

pub mod clone_all;
pub mod ese;
pub mod late;
pub mod mantri;
pub mod naive;
pub mod sca;
pub mod sda;
pub mod srpt;

use std::str::FromStr;

use crate::cluster::job::TaskRef;
use crate::cluster::sim::{Cluster, Workload};
use crate::config::{SimConfig, WorkloadConfig};

/// A speculative-execution policy driven by the simulator.
/// Not `Send`: SCA may hold a thread-pinned PJRT executor; the live master
/// therefore constructs its scheduler on its own thread.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    /// Slot-boundary decisions (the paper's slotted model).
    fn on_slot(&mut self, cl: &mut Cluster);
    /// A first copy crossed its detection checkpoint: its true remaining
    /// time just became visible (SDA acts here; others ignore it).
    fn on_reveal(&mut self, _cl: &mut Cluster, _t: TaskRef) {}
}

/// Which policy to run (CLI/TOML selectable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    Naive,
    CloneAll,
    Mantri,
    Late,
    Sca,
    Sda,
    Ese,
}

impl SchedulerKind {
    pub fn all() -> [SchedulerKind; 7] {
        [
            SchedulerKind::Naive,
            SchedulerKind::CloneAll,
            SchedulerKind::Mantri,
            SchedulerKind::Late,
            SchedulerKind::Sca,
            SchedulerKind::Sda,
            SchedulerKind::Ese,
        ]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SchedulerKind::Naive => "naive",
            SchedulerKind::CloneAll => "clone_all",
            SchedulerKind::Mantri => "mantri",
            SchedulerKind::Late => "late",
            SchedulerKind::Sca => "sca",
            SchedulerKind::Sda => "sda",
            SchedulerKind::Ese => "ese",
        }
    }
}

impl FromStr for SchedulerKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        SchedulerKind::all()
            .into_iter()
            .find(|k| k.as_str() == s)
            .ok_or_else(|| {
                format!(
                    "unknown scheduler '{s}' (expected one of: {})",
                    SchedulerKind::all().map(|k| k.as_str()).join(", ")
                )
            })
    }
}

/// Instantiate the configured scheduler.  `workload` supplies the common
/// heavy-tail order for the policies that derive their thresholds from the
/// analysis (SDA's Theorem 3, ESE's Eq. 30-33).  For trace workloads the
/// tail index is estimated from the trace's own sampled durations (loading
/// the file if no pre-sampled [`Workload`] is at hand — prefer
/// [`build_for`] when one is).
pub fn build(
    cfg: &SimConfig,
    workload: &WorkloadConfig,
) -> Result<Box<dyn Scheduler>, String> {
    build_for(cfg, workload, None)
}

/// [`build`] with an optional pre-sampled workload, so trace replays derive
/// alpha from the durations already in memory instead of re-reading the
/// trace file.  The experiment runner calls this once per grid cell, inside
/// the worker thread (the `Scheduler` trait is `!Send`).
pub fn build_for(
    cfg: &SimConfig,
    workload: &WorkloadConfig,
    sampled: Option<&Workload>,
) -> Result<Box<dyn Scheduler>, String> {
    let alpha = match workload {
        WorkloadConfig::Poisson { alpha, .. }
        | WorkloadConfig::Bursty { alpha, .. }
        | WorkloadConfig::SingleJob { alpha, .. } => *alpha,
        WorkloadConfig::Trace { path } => match sampled {
            Some(wl) => crate::cluster::generator::estimate_alpha(wl),
            None => crate::cluster::trace::load(path)
                .map(|wl| crate::cluster::generator::estimate_alpha(&wl))
                .unwrap_or(2.0),
        },
    };
    Ok(match cfg.scheduler {
        SchedulerKind::Naive => Box::new(naive::Naive),
        SchedulerKind::CloneAll => {
            Box::new(clone_all::CloneAll { copies: 2, strict: cfg.clone_strict })
        }
        SchedulerKind::Mantri => Box::new(mantri::Mantri::new(cfg)),
        SchedulerKind::Late => Box::new(late::Late::new(cfg)),
        SchedulerKind::Sca => Box::new(sca::Sca::new(cfg)?),
        SchedulerKind::Sda => Box::new(sda::Sda::new(cfg, alpha)),
        SchedulerKind::Ese => Box::new(ese::Ese::new(cfg, alpha)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_kinds() {
        let mut cfg = SimConfig::default();
        cfg.use_runtime = false; // no artifacts needed in unit tests
        let wl = WorkloadConfig::paper(6.0);
        for kind in SchedulerKind::all() {
            cfg.scheduler = kind;
            let s = build(&cfg, &wl).unwrap();
            assert_eq!(s.name(), kind.as_str());
        }
    }

    #[test]
    fn trace_alpha_estimated_from_sampled_workload() {
        let mut cfg = SimConfig::default();
        cfg.use_runtime = false;
        cfg.scheduler = SchedulerKind::Sda;
        let wl = crate::cluster::generator::generate(&WorkloadConfig::paper(2.0), 50.0, 3);
        // with a pre-sampled workload the trace file is never touched, so a
        // bogus path must not fail the build
        let trace_cfg = WorkloadConfig::Trace { path: "/nonexistent/trace.csv".to_string() };
        let s = build_for(&cfg, &trace_cfg, Some(&wl)).unwrap();
        assert_eq!(s.name(), "sda");
        // without one, an unreadable trace falls back to the paper default
        let s = build_for(&cfg, &trace_cfg, None).unwrap();
        assert_eq!(s.name(), "sda");
    }

    #[test]
    fn kind_str_roundtrip() {
        for kind in SchedulerKind::all() {
            let back: SchedulerKind = kind.as_str().parse().unwrap();
            assert_eq!(kind, back);
        }
        assert!("bogus".parse::<SchedulerKind>().is_err());
    }
}
