//! `SpeculationRule` — the when-to-act axis of the policy pipeline.
//!
//! A rule decides *which* tasks (slot-gated or at the detection reveal)
//! and *which* queued jobs (the level-3 clone gate) deserve extra copies;
//! the [`CopyBudget`](super::budget::CopyBudget) decides *how many*.  The
//! six rules are the deleted monoliths' decision cores, extracted
//! verbatim during the pipeline redesign — same candidate iteration
//! (SchedIndex or naive scan per `cfg.sched_index`), same NaN-safe
//! `total_cmp` sorts, same idle-exhaustion breaks.  Each rule also
//! carries its wakeup horizon
//! ([`SpeculationRule::next_decision_time`]): the earliest instant its
//! time-dependent predicate can flip absent cluster mutations, which is
//! what lets the wakeup planner skip provably no-op slots (DESIGN.md
//! §12; equivalence pinned by `tests/pipeline_equivalence.rs`).

use crate::cluster::job::{CopyPhase, JobId, TaskRef};
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::estimator::RemainingTime;
use crate::opt::{ese_sigma, p3};

use super::budget::CopyBudget;

/// Enumerate the speculation-candidate set — tasks whose only copy is a
/// running *first* copy — exactly as the slot hooks do: through the
/// `SchedIndex` or the naive scan per `cfg.sched_index`, in the same
/// (job asc, task asc) order either way.  The wakeup-horizon methods
/// below share this one enumeration so the gate provably inspects the
/// same candidates `on_slot` would act on.
fn for_each_candidate(cl: &Cluster, mut f: impl FnMut(&Cluster, TaskRef)) {
    if cl.cfg.sched_index {
        for id in cl.running.iter() {
            for ti in cl.index.candidates(*id) {
                f(cl, TaskRef { job: *id, task: ti });
            }
        }
    } else {
        for id in cl.running.iter() {
            let job = cl.job(*id);
            for ti in 0..job.spec.num_tasks {
                let tid = job.tid(ti);
                if cl.arena.done(tid) || cl.arena.n_copies(tid) != 1 {
                    continue;
                }
                if cl.arena.phase(cl.arena.copy_id(tid, 0)) != CopyPhase::Running {
                    continue;
                }
                f(cl, TaskRef { job: *id, task: ti });
            }
        }
    }
}

/// The speculation-rule component of a [`Pipeline`](super::Pipeline).
pub trait SpeculationRule {
    fn name(&self) -> &'static str;

    /// Slot-gated backup phase: examine running tasks and launch backups
    /// (the budget supplies the per-task copy target).  Runs before the
    /// ordering's levels 2/3, exactly where the monoliths ran theirs.
    fn on_slot(&mut self, _cl: &mut Cluster, _est: &dyn RemainingTime, _budget: &dyn CopyBudget) {}

    /// Event-driven reveal hook: a first copy crossed its detection
    /// checkpoint (SDA acts here; others ignore it).
    fn on_reveal(
        &mut self,
        _cl: &mut Cluster,
        _est: &dyn RemainingTime,
        _budget: &dyn CopyBudget,
        _t: TaskRef,
    ) {
    }

    /// Level-3 clone gate: should this queued job be cloned at launch
    /// (count = the budget's decision)?  Called at walk time, so the
    /// current idle count is part of the decision; bypassed when the
    /// budget pre-plans the batch (SCA's P2).
    fn clone_gate(&self, _cl: &Cluster, _id: JobId, _chi_len: usize) -> bool {
        false
    }

    /// Wakeup-planner horizon: the earliest simulated instant at which
    /// this rule's slot-gated decisions could differ from an immediate
    /// re-run, assuming **no cluster mutation** in between (mutations set
    /// [`Cluster::sched_dirty`] and force a slot independently).  `None`
    /// = never: absent mutations, every future slot is a provable no-op
    /// for this rule.
    ///
    /// Called while the dirty flag is clear — i.e. on exactly the
    /// post-`on_slot` state of the last fired slot — so implementations
    /// may rely on the slot-loop quiescence invariant: any rule-flagged
    /// task has been served unless the cluster is full or the task is at
    /// its copy cap.  The conservative default — "now" — fires every
    /// slot, which is always correct; each impl documents its tightened
    /// bound (DESIGN.md §12).
    fn next_decision_time(&self, cl: &Cluster, _est: &dyn RemainingTime) -> Option<f64> {
        Some(cl.clock)
    }
}

/// No speculation at all (the Fig. 5 "no backup" baseline).
pub struct Never;

impl SpeculationRule for Never {
    fn name(&self) -> &'static str {
        "never"
    }

    /// No predicate at all, let alone a time-dependent one.
    fn next_decision_time(&self, _cl: &Cluster, _est: &dyn RemainingTime) -> Option<f64> {
        None
    }
}

/// Clone every queued job at launch time (Sec. III generalized cloning);
/// the budget decides the count — `fixed2` reproduces CloneAll, `p2`
/// reproduces SCA's Algorithm 1.
pub struct Clone;

impl SpeculationRule for Clone {
    fn name(&self) -> &'static str {
        "clone"
    }

    fn clone_gate(&self, _cl: &Cluster, _id: JobId, _chi_len: usize) -> bool {
        true
    }

    /// The gate is constant-true and consulted only during the χ(l) walk;
    /// after a fired slot a non-empty χ(l) implies a full cluster (the
    /// walk would have launched otherwise), and any idle-count change is
    /// a mutation — nothing here moves with the clock.
    fn next_decision_time(&self, _cl: &Cluster, _est: &dyn RemainingTime) -> Option<f64> {
        None
    }
}

/// Mantri's duplicate rule `P(t_rem > 2 E[x]) > delta` on running
/// single-copy tasks, longest estimated remaining first, plus the
/// optional kill/restart ablation (`mantri_kill`).
pub struct Mantri {
    delta: f64,
    kill: bool,
    /// Reused duplicate-candidate buffer (no per-slot allocation).
    cands: Vec<(f64, TaskRef)>,
}

impl Mantri {
    pub fn new(cfg: &SimConfig) -> Self {
        Mantri { delta: cfg.mantri_delta, kill: cfg.mantri_kill, cands: Vec::new() }
    }
}

impl SpeculationRule for Mantri {
    fn name(&self) -> &'static str {
        "mantri"
    }

    fn on_slot(&mut self, cl: &mut Cluster, est: &dyn RemainingTime, budget: &dyn CopyBudget) {
        self.cands.clear();
        // one shared enumeration with the wakeup horizon below — the
        // skip proof needs both to inspect the identical candidate set
        for_each_candidate(cl, |cl, t| {
            let two_means = 2.0 * cl.job(t.job).spec.dist.mean();
            if est.task_prob_exceeds(cl, t, two_means) > self.delta {
                self.cands.push((est.task_remaining_work(cl, t), t));
            }
        });
        // NaN-safe descending sort (total_cmp, not partial_cmp().unwrap())
        self.cands.sort_by(|a, b| b.0.total_cmp(&a.0));
        let target = budget.backup_copies(cl);
        'cands: for &(rem, t) in &self.cands {
            // the restart rule frees its own machine, so it applies even
            // when the cluster is full (kill the hopeless original, then
            // relaunch afresh on the freed slot)
            if self.kill && rem > 3.0 * cl.job(t.job).spec.dist.mean() {
                cl.kill_copy(t, 0);
                cl.launch_copy(t);
                continue;
            }
            for _ in 1..target {
                if cl.idle() == 0 {
                    break 'cands;
                }
                cl.launch_copy(t);
            }
        }
    }

    /// Earliest flip of the delta-gate `P(t_rem > 2 E[x]) > delta` over
    /// the current candidates, via the estimator's exact predicate
    /// inverse ([`RemainingTime::copy_prob_flip_time`]):
    ///
    /// * full cluster → `None` (no machine to duplicate onto, and any
    ///   release is a mutation);
    /// * an already-flagged candidate below its copy cap would act next
    ///   slot → "now" (unreachable right after `on_slot`, which serves
    ///   flagged candidates while idle machines remain — kept as a
    ///   defensive bound, never skipped past);
    /// * a flagged candidate *at* its copy cap can never launch — its
    ///   every future slot is a no-op, so it contributes nothing;
    /// * the kill/restart ablation acts even on a full cluster and its
    ///   3·E\[x\] gate moves with the clock — stay fully conservative.
    fn next_decision_time(&self, cl: &Cluster, est: &dyn RemainingTime) -> Option<f64> {
        if self.kill {
            return Some(cl.clock);
        }
        if cl.idle() == 0 {
            return None;
        }
        let r_max = cl.cfg.r_max;
        let mut next: Option<f64> = None;
        for_each_candidate(cl, |cl, t| {
            let two_means = 2.0 * cl.job(t.job).spec.dist.mean();
            if est.task_prob_exceeds(cl, t, two_means) > self.delta {
                if cl.n_copies(t) < r_max {
                    next = Some(cl.clock); // flagged and launchable: act now
                }
                return;
            }
            if let Some(flip) = est.copy_prob_flip_time(cl, t, 0, two_means, self.delta) {
                next = Some(next.map_or(flip, |n| n.min(flip)));
            }
        });
        next
    }
}

/// Berkeley LATE: speculate on tasks whose progress *rate* falls below
/// the slowTaskThreshold percentile, longest remaining first, under a
/// cluster-wide cap on outstanding speculative copies.
pub struct Late {
    speculative_cap: f64,
    slow_percentile: f64,
    /// Reused per-slot buffers (no allocation in the hot hook).
    rates: Vec<(f64, f64, TaskRef)>,
    sorted_rates: Vec<f64>,
    cands: Vec<(f64, TaskRef)>,
    /// Reused rate buffer for the wakeup horizon (`&self` there, hence
    /// the cell).
    flip_scratch: std::cell::RefCell<Vec<f64>>,
}

impl Late {
    pub fn new(cfg: &SimConfig) -> Self {
        Late {
            speculative_cap: cfg.late_speculative_cap,
            slow_percentile: cfg.late_slow_percentile,
            rates: Vec::new(),
            sorted_rates: Vec::new(),
            cands: Vec::new(),
            flip_scratch: std::cell::RefCell::new(Vec::new()),
        }
    }

    /// Estimated progress rate of a task's primary copy:
    /// `1 / (elapsed + estimated wall-clock remaining)`.
    fn progress_rate(
        &self,
        cl: &Cluster,
        est: &dyn RemainingTime,
        t: TaskRef,
    ) -> Option<(f64, f64)> {
        if cl.n_copies(t) == 0 {
            return None;
        }
        let c = cl.copy(t, 0);
        if c.phase != CopyPhase::Running {
            return None;
        }
        let elapsed = c.elapsed(cl.clock);
        if elapsed <= 0.0 {
            return None;
        }
        let rem = est.copy_remaining_wall(cl, t, 0);
        Some((1.0 / (elapsed + rem), rem))
    }
}

impl SpeculationRule for Late {
    fn name(&self) -> &'static str {
        "late"
    }

    fn on_slot(&mut self, cl: &mut Cluster, est: &dyn RemainingTime, budget: &dyn CopyBudget) {
        // gather progress rates of all single-copy running tasks — the
        // same shared enumeration the wakeup horizon counts below
        self.rates.clear();
        for_each_candidate(cl, |cl, t| {
            if let Some((rate, rem)) = self.progress_rate(cl, est, t) {
                self.rates.push((rate, rem, t));
            }
        });
        if self.rates.is_empty() {
            return;
        }
        // slowTaskThreshold: the `slow_percentile` quantile of rates
        // (NaN-safe total_cmp sorts throughout)
        self.sorted_rates.clear();
        self.sorted_rates.extend(self.rates.iter().map(|(r, _, _)| *r));
        self.sorted_rates.sort_by(|a, b| a.total_cmp(b));
        let idx = ((self.sorted_rates.len() as f64 * self.slow_percentile) as usize)
            .min(self.sorted_rates.len() - 1);
        let threshold = self.sorted_rates[idx];
        let cap = (self.speculative_cap * cl.machines.total() as f64) as usize;
        // longest remaining first among the slow ones
        self.cands.clear();
        self.cands.extend(
            self.rates
                .iter()
                .filter(|(r, _, _)| *r < threshold)
                .map(|&(_, rem, t)| (rem, t)),
        );
        self.cands.sort_by(|a, b| b.0.total_cmp(&a.0));
        let target = budget.backup_copies(cl);
        'cands: for &(_, t) in &self.cands {
            for _ in 1..target {
                if cl.idle() == 0 || cl.outstanding_backups >= cap {
                    break 'cands;
                }
                cl.launch_copy(t);
            }
        }
    }

    /// LATE's below-percentile set is a *relative* ranking of
    /// progress rates, but every estimator's rate `1/(elapsed + rem)` is
    /// non-increasing between mutations, which yields an exact flip bound
    /// (DESIGN.md §12):
    ///
    /// * full cluster, or speculative cap reached (`outstanding_backups`
    ///   only changes through mutations) → `None`;
    /// * fewer candidates than `1 / slow_percentile`: the percentile
    ///   index truncates to 0, the threshold is the *minimum* rate, and
    ///   the strict `rate < threshold` set is empty for any candidate
    ///   count up to the current one — `None`;
    /// * otherwise the quiescence invariant makes the strict-below set
    ///   empty right now, i.e. the bottom `idx + 1` rates are all tied at
    ///   the threshold `r*`.  The set can only become non-empty once some
    ///   candidate's rate strictly separates below a bottom-group
    ///   trajectory; because all rates are non-increasing, every such
    ///   separation is preceded (or met) by that candidate's rate
    ///   dropping strictly below the *static* value `r*` — so the minimum
    ///   of [`RemainingTime::copy_rate_flip_time`] over the candidates is
    ///   an early-or-exact bound.  Revealed copies have constant rates
    ///   (`None` from the estimator), so an all-revealed candidate set
    ///   skips forever.
    ///
    /// Defensive `Some(now)` cases, mirroring Mantri/ESE: a candidate
    /// with no progress rate yet (elapsed 0 — it joins the ranking next
    /// slot), or a strictly-below candidate that `on_slot` could not
    /// serve (a copy-budget of one launches nothing without breaking).
    fn next_decision_time(&self, cl: &Cluster, est: &dyn RemainingTime) -> Option<f64> {
        if cl.idle() == 0 {
            return None;
        }
        let cap = (self.speculative_cap * cl.machines.total() as f64) as usize;
        if cl.outstanding_backups >= cap {
            return None;
        }
        // gather the same rate set on_slot ranks (elapsed-0 copies have
        // no rate yet but join the ranking by the next slot)
        let mut n: usize = 0;
        let mut fresh = false;
        let mut rates = self.flip_scratch.borrow_mut();
        rates.clear();
        for_each_candidate(cl, |cl, t| {
            n += 1;
            match self.progress_rate(cl, est, t) {
                Some((rate, _)) => rates.push(rate),
                None => fresh = true,
            }
        });
        if (n as f64 * self.slow_percentile) as usize == 0 {
            return None;
        }
        if fresh {
            return Some(cl.clock);
        }
        rates.sort_by(|a, b| a.total_cmp(b));
        let idx = ((rates.len() as f64 * self.slow_percentile) as usize).min(rates.len() - 1);
        let threshold = rates[idx];
        if rates[0].total_cmp(&threshold).is_lt() {
            return Some(cl.clock); // strict-below candidate outstanding
        }
        drop(rates);
        let mut next: Option<f64> = None;
        for_each_candidate(cl, |cl, t| {
            if let Some(flip) = est.copy_rate_flip_time(cl, t, 0, threshold) {
                next = Some(next.map_or(flip, |x| x.min(flip)));
            }
        });
        next
    }
}

/// SDA's Straggler Detection (Sec. V-B): when a first copy crosses its
/// detection checkpoint with estimated remaining work > `sigma * E[x]`,
/// bring the task to the budget's copy target immediately (Theorem 3:
/// `c* = 2` under Pareto — the canonical default budget).
pub struct Sda {
    /// Detection threshold multiplier (sigma_i).
    pub sigma: f64,
    /// Stragglers detected / backups actually launched (diagnostics).
    pub detected: u64,
    pub backups: u64,
}

impl Sda {
    pub fn new(cfg: &SimConfig, alpha: f64) -> Self {
        let policy = p3::solve(alpha, cfg.detect_frac, cfg.r_max);
        let sigma = cfg.sigma.unwrap_or(policy.sigma);
        // Theorem 3: one backup is optimal under Pareto
        debug_assert_eq!(policy.c_star, 2, "Theorem 3 violated: c* = {}", policy.c_star);
        Sda { sigma, detected: 0, backups: 0 }
    }
}

impl SpeculationRule for Sda {
    fn name(&self) -> &'static str {
        "sda"
    }

    fn on_reveal(
        &mut self,
        cl: &mut Cluster,
        est: &dyn RemainingTime,
        budget: &dyn CopyBudget,
        t: TaskRef,
    ) {
        // only the original triggers detection, and only once
        if cl.n_copies(t) != 1 {
            return;
        }
        let mean = cl.job(t.job).spec.dist.mean();
        let remaining = est.copy_remaining_work(cl, t, 0);
        if remaining > self.sigma * mean {
            self.detected += 1;
            let target = budget.backup_copies(cl);
            for _ in 1..target {
                if cl.idle() == 0 {
                    break;
                }
                if cl.launch_copy(t) {
                    self.backups += 1;
                }
            }
        }
    }

    /// Purely event-driven: SDA acts only at the detection checkpoint,
    /// and every checkpoint reveal is a mutation that sets the dirty
    /// flag — its slot phase is empty, so no slot ever needs to fire for
    /// SDA's sake.
    fn next_decision_time(&self, _cl: &Cluster, _est: &dyn RemainingTime) -> Option<f64> {
        None
    }
}

/// ESE (Algorithm 2): slot-gated backups for running tasks with
/// `t_rem > sigma * E[x]` (longest first), plus the small-job clone gate
/// `m < eta * N(l)/|chi(l)|` and `E[x] < xi` at level 3 (the count is the
/// budget's decision — Eq. 29 by default).
pub struct Ese {
    pub sigma: f64,
    eta: f64,
    xi: f64,
    /// Reused D(l) buffer (no per-slot allocation).
    d: Vec<(f64, TaskRef)>,
    /// Diagnostics.
    pub backups: u64,
}

impl Ese {
    pub fn new(cfg: &SimConfig, alpha: f64) -> Self {
        let sigma = cfg.sigma.unwrap_or_else(|| ese_sigma::sigma_star(alpha));
        Ese { sigma, eta: cfg.eta_small, xi: cfg.xi_small, d: Vec::new(), backups: 0 }
    }
}

impl SpeculationRule for Ese {
    fn name(&self) -> &'static str {
        "ese"
    }

    fn on_slot(&mut self, cl: &mut Cluster, est: &dyn RemainingTime, budget: &dyn CopyBudget) {
        // backup candidates D(l), longest estimated remaining first —
        // the same shared enumeration the wakeup horizon walks below
        self.d.clear();
        for_each_candidate(cl, |cl, t| {
            let threshold = self.sigma * cl.job(t.job).spec.dist.mean();
            let rem = est.task_remaining_work(cl, t);
            if rem > threshold {
                self.d.push((rem, t));
            }
        });
        // NaN-safe descending sort (total_cmp, not partial_cmp().unwrap())
        self.d.sort_by(|a, b| b.0.total_cmp(&a.0));
        let target = budget.backup_copies(cl);
        'd: for &(_, t) in &self.d {
            for _ in 1..target {
                if cl.idle() == 0 {
                    break 'd;
                }
                if cl.launch_copy(t) {
                    self.backups += 1;
                }
            }
        }
    }

    fn clone_gate(&self, cl: &Cluster, id: JobId, chi_len: usize) -> bool {
        let job = cl.job(id);
        let m = job.spec.num_tasks as f64;
        let mean = job.spec.dist.mean();
        m < self.eta * cl.idle() as f64 / chi_len.max(1) as f64 && mean < self.xi
    }

    /// Earliest flip of the sigma-threshold `t_rem > sigma E[x]` over the
    /// current candidates, via the estimator's exact inverse
    /// ([`RemainingTime::copy_work_flip_time`]); the small-job clone gate
    /// reads only state (idle, |χ|, job constants), never the clock, and
    /// is unreachable on a quiet cluster (χ non-empty after a fired slot
    /// implies a full cluster).  Structure mirrors
    /// [`Mantri::next_decision_time`]: full cluster → `None`; flagged-
    /// at-cap candidates contribute nothing; flagged-and-launchable →
    /// "now" (defensive, unreachable post-`on_slot`).
    fn next_decision_time(&self, cl: &Cluster, est: &dyn RemainingTime) -> Option<f64> {
        if cl.idle() == 0 {
            return None;
        }
        let r_max = cl.cfg.r_max;
        let mut next: Option<f64> = None;
        for_each_candidate(cl, |cl, t| {
            let threshold = self.sigma * cl.job(t.job).spec.dist.mean();
            if est.task_remaining_work(cl, t) > threshold {
                if cl.n_copies(t) < r_max {
                    next = Some(cl.clock);
                }
                return;
            }
            if let Some(flip) = est.copy_work_flip_time(cl, t, 0, threshold) {
                next = Some(next.map_or(flip, |n| n.min(flip)));
            }
        });
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::generator::generate;
    use crate::cluster::sim::{SimResult, Simulator};
    use crate::config::WorkloadConfig;
    use crate::scheduler::SchedulerKind;

    /// Per-policy behavioral checks ported from the deleted monolith
    /// modules (the pipeline builds the same decision cores from these
    /// rules, so the assertions transfer verbatim).
    fn run_kind(kind: SchedulerKind, lambda: f64, patch: fn(&mut SimConfig)) -> SimResult {
        let mut cfg = SimConfig::default();
        cfg.machines = 200;
        cfg.horizon = 300.0;
        cfg.use_runtime = false;
        cfg.scheduler = kind;
        patch(&mut cfg);
        let wl = WorkloadConfig::paper(lambda);
        let workload = generate(&wl, cfg.horizon, 5);
        let sched = crate::scheduler::build(&cfg, &wl).unwrap();
        Simulator::new(cfg, workload, sched).run()
    }

    #[test]
    fn mantri_speculates_on_stragglers_and_kill_variant_runs() {
        let plain = run_kind(SchedulerKind::Mantri, 1.0, |_| {});
        assert!(plain.speculative_launches > 0);
        assert!(!plain.completed.is_empty());
        let kill = run_kind(SchedulerKind::Mantri, 1.0, |c| c.mantri_kill = true);
        assert!(!kill.completed.is_empty());
    }

    #[test]
    fn late_speculates_under_cap_and_zero_cap_disables() {
        let late = run_kind(SchedulerKind::Late, 1.0, |_| {});
        assert!(late.speculative_launches > 0);
        assert!(!late.completed.is_empty());
        let capped = run_kind(SchedulerKind::Late, 1.0, |c| c.late_speculative_cap = 0.0);
        assert_eq!(capped.speculative_launches, 0);
    }

    #[test]
    fn ese_derives_sigma_and_speculates_under_heavy_load() {
        let cfg = {
            let mut c = SimConfig::default();
            c.use_runtime = false;
            c
        };
        let e = Ese::new(&cfg, 2.0);
        assert!((1.5..=2.0).contains(&e.sigma), "sigma = {}", e.sigma);
        // heavy relative to 300 machines (the deleted ese.rs setting)
        let res = run_kind(SchedulerKind::Ese, 4.0, |c| c.machines = 300);
        assert!(!res.completed.is_empty());
        assert!(res.speculative_launches > 0);
    }

    #[test]
    fn sda_detects_and_backs_up_through_the_reveal_hook() {
        let res = run_kind(SchedulerKind::Sda, 1.0, |_| {});
        assert!(res.speculative_launches > 0, "SDA should launch backups at reveals");
        assert!(!res.completed.is_empty());
    }
}
