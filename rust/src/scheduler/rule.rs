//! `SpeculationRule` — the when-to-act axis of the policy pipeline.
//!
//! A rule decides *which* tasks (slot-gated or at the detection reveal)
//! and *which* queued jobs (the level-3 clone gate) deserve extra copies;
//! the [`CopyBudget`](super::budget::CopyBudget) decides *how many*.  The
//! six rules are the monoliths' decision cores extracted verbatim — same
//! candidate iteration (SchedIndex or naive scan per `cfg.sched_index`),
//! same NaN-safe `total_cmp` sorts, same idle-exhaustion breaks — so each
//! canonical composition is provably bit-identical to its retained
//! monolith (`tests/pipeline_equivalence.rs`).

use crate::cluster::job::{CopyPhase, JobId, TaskRef};
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::estimator::RemainingTime;
use crate::opt::{ese_sigma, p3};

use super::budget::CopyBudget;

/// The speculation-rule component of a [`Pipeline`](super::Pipeline).
pub trait SpeculationRule {
    fn name(&self) -> &'static str;

    /// Slot-gated backup phase: examine running tasks and launch backups
    /// (the budget supplies the per-task copy target).  Runs before the
    /// ordering's levels 2/3, exactly where the monoliths ran theirs.
    fn on_slot(&mut self, _cl: &mut Cluster, _est: &dyn RemainingTime, _budget: &dyn CopyBudget) {}

    /// Event-driven reveal hook: a first copy crossed its detection
    /// checkpoint (SDA acts here; others ignore it).
    fn on_reveal(
        &mut self,
        _cl: &mut Cluster,
        _est: &dyn RemainingTime,
        _budget: &dyn CopyBudget,
        _t: TaskRef,
    ) {
    }

    /// Level-3 clone gate: should this queued job be cloned at launch
    /// (count = the budget's decision)?  Called at walk time, so the
    /// current idle count is part of the decision; bypassed when the
    /// budget pre-plans the batch (SCA's P2).
    fn clone_gate(&self, _cl: &Cluster, _id: JobId, _chi_len: usize) -> bool {
        false
    }
}

/// No speculation at all (the Fig. 5 "no backup" baseline).
pub struct Never;

impl SpeculationRule for Never {
    fn name(&self) -> &'static str {
        "never"
    }
}

/// Clone every queued job at launch time (Sec. III generalized cloning);
/// the budget decides the count — `fixed2` reproduces CloneAll, `p2`
/// reproduces SCA's Algorithm 1.
pub struct Clone;

impl SpeculationRule for Clone {
    fn name(&self) -> &'static str {
        "clone"
    }

    fn clone_gate(&self, _cl: &Cluster, _id: JobId, _chi_len: usize) -> bool {
        true
    }
}

/// Mantri's duplicate rule `P(t_rem > 2 E[x]) > delta` on running
/// single-copy tasks, longest estimated remaining first, plus the
/// optional kill/restart ablation (`mantri_kill`).
pub struct Mantri {
    delta: f64,
    kill: bool,
    /// Reused duplicate-candidate buffer (no per-slot allocation).
    cands: Vec<(f64, TaskRef)>,
}

impl Mantri {
    pub fn new(cfg: &SimConfig) -> Self {
        Mantri { delta: cfg.mantri_delta, kill: cfg.mantri_kill, cands: Vec::new() }
    }
}

impl SpeculationRule for Mantri {
    fn name(&self) -> &'static str {
        "mantri"
    }

    fn on_slot(&mut self, cl: &mut Cluster, est: &dyn RemainingTime, budget: &dyn CopyBudget) {
        self.cands.clear();
        if cl.cfg.sched_index {
            // O(active): only tasks whose sole copy is a running first
            // copy, in the same (job asc, task asc) order as the scan
            for id in cl.running.iter() {
                let job = cl.job(*id);
                let two_means = 2.0 * job.spec.dist.mean();
                for ti in cl.index.candidates(*id) {
                    let t = TaskRef { job: *id, task: ti };
                    if est.task_prob_exceeds(cl, t, two_means) > self.delta {
                        self.cands.push((est.task_remaining_work(cl, t), t));
                    }
                }
            }
        } else {
            // naive-scan reference: every task of every running job
            for id in cl.running.iter() {
                let job = cl.job(*id);
                let two_means = 2.0 * job.spec.dist.mean();
                for (ti, task) in job.tasks.iter().enumerate() {
                    if task.done || task.copies.len() != 1 {
                        continue;
                    }
                    if task.copies[0].phase != CopyPhase::Running {
                        continue;
                    }
                    let t = TaskRef { job: *id, task: ti as u32 };
                    if est.task_prob_exceeds(cl, t, two_means) > self.delta {
                        self.cands.push((est.task_remaining_work(cl, t), t));
                    }
                }
            }
        }
        // NaN-safe descending sort (total_cmp, not partial_cmp().unwrap())
        self.cands.sort_by(|a, b| b.0.total_cmp(&a.0));
        let target = budget.backup_copies(cl);
        'cands: for &(rem, t) in &self.cands {
            // the restart rule frees its own machine, so it applies even
            // when the cluster is full (kill the hopeless original, then
            // relaunch afresh on the freed slot)
            if self.kill && rem > 3.0 * cl.job(t.job).spec.dist.mean() {
                cl.kill_copy(t, 0);
                cl.launch_copy(t);
                continue;
            }
            for _ in 1..target {
                if cl.idle() == 0 {
                    break 'cands;
                }
                cl.launch_copy(t);
            }
        }
    }
}

/// Berkeley LATE: speculate on tasks whose progress *rate* falls below
/// the slowTaskThreshold percentile, longest remaining first, under a
/// cluster-wide cap on outstanding speculative copies.
pub struct Late {
    speculative_cap: f64,
    slow_percentile: f64,
    /// Reused per-slot buffers (no allocation in the hot hook).
    rates: Vec<(f64, f64, TaskRef)>,
    sorted_rates: Vec<f64>,
    cands: Vec<(f64, TaskRef)>,
}

impl Late {
    pub fn new(cfg: &SimConfig) -> Self {
        Late {
            speculative_cap: cfg.late_speculative_cap,
            slow_percentile: cfg.late_slow_percentile,
            rates: Vec::new(),
            sorted_rates: Vec::new(),
            cands: Vec::new(),
        }
    }

    /// Estimated progress rate of a task's primary copy:
    /// `1 / (elapsed + estimated wall-clock remaining)`.
    fn progress_rate(
        &self,
        cl: &Cluster,
        est: &dyn RemainingTime,
        t: TaskRef,
    ) -> Option<(f64, f64)> {
        let task = cl.task(t);
        let c = task.copies.first()?;
        if c.phase != CopyPhase::Running {
            return None;
        }
        let elapsed = c.elapsed(cl.clock);
        if elapsed <= 0.0 {
            return None;
        }
        let rem = est.copy_remaining_wall(cl, t, 0);
        Some((1.0 / (elapsed + rem), rem))
    }
}

impl SpeculationRule for Late {
    fn name(&self) -> &'static str {
        "late"
    }

    fn on_slot(&mut self, cl: &mut Cluster, est: &dyn RemainingTime, budget: &dyn CopyBudget) {
        // gather progress rates of all single-copy running tasks
        self.rates.clear();
        if cl.cfg.sched_index {
            // O(active): the index yields exactly the single-running-first-
            // copy tasks, in the scan's (job asc, task asc) order
            for id in cl.running.iter() {
                for ti in cl.index.candidates(*id) {
                    let t = TaskRef { job: *id, task: ti };
                    if let Some((rate, rem)) = self.progress_rate(cl, est, t) {
                        self.rates.push((rate, rem, t));
                    }
                }
            }
        } else {
            // naive-scan reference (the phase filter mirrors the index's
            // candidate definition; progress_rate would reject non-running
            // copies anyway, so this is behavior-neutral symmetry)
            for id in cl.running.iter() {
                let job = cl.job(*id);
                for (ti, task) in job.tasks.iter().enumerate() {
                    if task.done || task.copies.len() != 1 {
                        continue;
                    }
                    if task.copies[0].phase != CopyPhase::Running {
                        continue;
                    }
                    let t = TaskRef { job: *id, task: ti as u32 };
                    if let Some((rate, rem)) = self.progress_rate(cl, est, t) {
                        self.rates.push((rate, rem, t));
                    }
                }
            }
        }
        if self.rates.is_empty() {
            return;
        }
        // slowTaskThreshold: the `slow_percentile` quantile of rates
        // (NaN-safe total_cmp sorts throughout)
        self.sorted_rates.clear();
        self.sorted_rates.extend(self.rates.iter().map(|(r, _, _)| *r));
        self.sorted_rates.sort_by(|a, b| a.total_cmp(b));
        let idx = ((self.sorted_rates.len() as f64 * self.slow_percentile) as usize)
            .min(self.sorted_rates.len() - 1);
        let threshold = self.sorted_rates[idx];
        let cap = (self.speculative_cap * cl.machines.total() as f64) as usize;
        // longest remaining first among the slow ones
        self.cands.clear();
        self.cands.extend(
            self.rates
                .iter()
                .filter(|(r, _, _)| *r < threshold)
                .map(|&(_, rem, t)| (rem, t)),
        );
        self.cands.sort_by(|a, b| b.0.total_cmp(&a.0));
        let target = budget.backup_copies(cl);
        'cands: for &(_, t) in &self.cands {
            for _ in 1..target {
                if cl.idle() == 0 || cl.outstanding_backups >= cap {
                    break 'cands;
                }
                cl.launch_copy(t);
            }
        }
    }
}

/// SDA's Straggler Detection (Sec. V-B): when a first copy crosses its
/// detection checkpoint with estimated remaining work > `sigma * E[x]`,
/// bring the task to the budget's copy target immediately (Theorem 3:
/// `c* = 2` under Pareto — the canonical default budget).
pub struct Sda {
    /// Detection threshold multiplier (sigma_i).
    pub sigma: f64,
    /// Stragglers detected / backups actually launched (diagnostics).
    pub detected: u64,
    pub backups: u64,
}

impl Sda {
    pub fn new(cfg: &SimConfig, alpha: f64) -> Self {
        let policy = p3::solve(alpha, cfg.detect_frac, cfg.r_max);
        let sigma = cfg.sigma.unwrap_or(policy.sigma);
        // Theorem 3: one backup is optimal under Pareto
        debug_assert_eq!(policy.c_star, 2, "Theorem 3 violated: c* = {}", policy.c_star);
        Sda { sigma, detected: 0, backups: 0 }
    }
}

impl SpeculationRule for Sda {
    fn name(&self) -> &'static str {
        "sda"
    }

    fn on_reveal(
        &mut self,
        cl: &mut Cluster,
        est: &dyn RemainingTime,
        budget: &dyn CopyBudget,
        t: TaskRef,
    ) {
        // only the original triggers detection, and only once
        if cl.task(t).copies.len() != 1 {
            return;
        }
        let mean = cl.job(t.job).spec.dist.mean();
        let remaining = est.copy_remaining_work(cl, t, 0);
        if remaining > self.sigma * mean {
            self.detected += 1;
            let target = budget.backup_copies(cl);
            for _ in 1..target {
                if cl.idle() == 0 {
                    break;
                }
                if cl.launch_copy(t) {
                    self.backups += 1;
                }
            }
        }
    }
}

/// ESE (Algorithm 2): slot-gated backups for running tasks with
/// `t_rem > sigma * E[x]` (longest first), plus the small-job clone gate
/// `m < eta * N(l)/|chi(l)|` and `E[x] < xi` at level 3 (the count is the
/// budget's decision — Eq. 29 by default).
pub struct Ese {
    pub sigma: f64,
    eta: f64,
    xi: f64,
    /// Reused D(l) buffer (no per-slot allocation).
    d: Vec<(f64, TaskRef)>,
    /// Diagnostics.
    pub backups: u64,
}

impl Ese {
    pub fn new(cfg: &SimConfig, alpha: f64) -> Self {
        let sigma = cfg.sigma.unwrap_or_else(|| ese_sigma::sigma_star(alpha));
        Ese { sigma, eta: cfg.eta_small, xi: cfg.xi_small, d: Vec::new(), backups: 0 }
    }
}

impl SpeculationRule for Ese {
    fn name(&self) -> &'static str {
        "ese"
    }

    fn on_slot(&mut self, cl: &mut Cluster, est: &dyn RemainingTime, budget: &dyn CopyBudget) {
        // backup candidates D(l), longest estimated remaining first
        self.d.clear();
        if cl.cfg.sched_index {
            // O(active): only single-running-first-copy tasks, same
            // (job asc, task asc) order as the scan
            for id in cl.running.iter() {
                let threshold = self.sigma * cl.job(*id).spec.dist.mean();
                for ti in cl.index.candidates(*id) {
                    let t = TaskRef { job: *id, task: ti };
                    let rem = est.task_remaining_work(cl, t);
                    if rem > threshold {
                        self.d.push((rem, t));
                    }
                }
            }
        } else {
            // naive-scan reference
            for id in cl.running.iter() {
                let job = cl.job(*id);
                let threshold = self.sigma * job.spec.dist.mean();
                for (ti, task) in job.tasks.iter().enumerate() {
                    if task.done || task.copies.len() != 1 {
                        continue;
                    }
                    if task.copies[0].phase != CopyPhase::Running {
                        continue;
                    }
                    let t = TaskRef { job: *id, task: ti as u32 };
                    let rem = est.task_remaining_work(cl, t);
                    if rem > threshold {
                        self.d.push((rem, t));
                    }
                }
            }
        }
        // NaN-safe descending sort (total_cmp, not partial_cmp().unwrap())
        self.d.sort_by(|a, b| b.0.total_cmp(&a.0));
        let target = budget.backup_copies(cl);
        'd: for &(_, t) in &self.d {
            for _ in 1..target {
                if cl.idle() == 0 {
                    break 'd;
                }
                if cl.launch_copy(t) {
                    self.backups += 1;
                }
            }
        }
    }

    fn clone_gate(&self, cl: &Cluster, id: JobId, chi_len: usize) -> bool {
        let job = cl.job(id);
        let m = job.spec.num_tasks as f64;
        let mean = job.spec.dist.mean();
        m < self.eta * cl.idle() as f64 / chi_len.max(1) as f64 && mean < self.xi
    }
}
