//! Berkeley LATE (Longest Approximate Time to End, Sec. II): speculate on
//! tasks whose progress *rate* falls below the slowTaskThreshold percentile,
//! choosing the longest-remaining first, subject to a cluster-wide cap on
//! outstanding speculative copies (speculativeCap).

use crate::cluster::job::{CopyPhase, TaskRef};
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;

use super::{srpt, Scheduler};

pub struct Late {
    speculative_cap: f64,
    slow_percentile: f64,
}

impl Late {
    pub fn new(cfg: &SimConfig) -> Self {
        Late {
            speculative_cap: cfg.late_speculative_cap,
            slow_percentile: cfg.late_slow_percentile,
        }
    }

    /// Estimated progress rate of a task's primary copy, from elapsed time
    /// only (blind — LATE has no access to the paper's s_i-checkpoint
    /// instrumentation; see mantri.rs).
    fn progress_rate(cl: &Cluster, t: TaskRef) -> Option<(f64, f64)> {
        let job = cl.job(t.job);
        let task = &job.tasks[t.task as usize];
        let c = task.copies.first()?;
        if c.phase != CopyPhase::Running {
            return None;
        }
        let elapsed = c.elapsed(cl.clock);
        if elapsed <= 0.0 {
            return None;
        }
        let rem = job.spec.dist.mean_remaining(elapsed);
        Some((1.0 / (elapsed + rem), rem))
    }
}

impl Scheduler for Late {
    fn name(&self) -> &'static str {
        "late"
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        // gather progress rates of all single-copy running tasks
        let mut rates = Vec::new();
        for id in cl.running.iter() {
            let job = cl.job(*id);
            for (ti, task) in job.tasks.iter().enumerate() {
                if task.done || task.copies.len() != 1 {
                    continue;
                }
                let t = TaskRef { job: *id, task: ti as u32 };
                if let Some((rate, rem)) = Self::progress_rate(cl, t) {
                    rates.push((rate, rem, t));
                }
            }
        }
        if !rates.is_empty() {
            // slowTaskThreshold: the `slow_percentile` quantile of rates
            let mut sorted: Vec<f64> = rates.iter().map(|(r, _, _)| *r).collect();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let idx = ((sorted.len() as f64 * self.slow_percentile) as usize)
                .min(sorted.len() - 1);
            let threshold = sorted[idx];
            let cap = (self.speculative_cap * cl.machines.total() as f64) as usize;
            // longest remaining first among the slow ones
            let mut cands: Vec<(f64, TaskRef)> = rates
                .into_iter()
                .filter(|(r, _, _)| *r < threshold)
                .map(|(_, rem, t)| (rem, t))
                .collect();
            cands.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            for (_, t) in cands {
                if cl.idle() == 0 || cl.outstanding_backups >= cap {
                    break;
                }
                cl.launch_copy(t);
            }
        }
        // FIFO job ordering: Hadoop's stock scheduler (see mantri.rs)
        srpt::schedule_running_fifo(cl);
        srpt::schedule_queued_fifo(cl);
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};

    #[test]
    fn speculates_under_cap() {
        let mut cfg = SimConfig::default();
        cfg.machines = 200;
        cfg.horizon = 300.0;
        cfg.scheduler = crate::scheduler::SchedulerKind::Late;
        let wl = generate(&WorkloadConfig::paper(1.0), cfg.horizon, 5);
        let sched = crate::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let res = Simulator::new(cfg, wl, sched).run();
        assert!(res.speculative_launches > 0);
        assert!(!res.completed.is_empty());
    }

    #[test]
    fn zero_cap_disables_speculation() {
        let mut cfg = SimConfig::default();
        cfg.machines = 200;
        cfg.horizon = 200.0;
        cfg.late_speculative_cap = 0.0;
        cfg.scheduler = crate::scheduler::SchedulerKind::Late;
        let wl = generate(&WorkloadConfig::paper(1.0), cfg.horizon, 5);
        let sched = crate::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let res = Simulator::new(cfg, wl, sched).run();
        assert_eq!(res.speculative_launches, 0);
    }
}
