//! Berkeley LATE (Longest Approximate Time to End, Sec. II): speculate on
//! tasks whose progress *rate* falls below the slowTaskThreshold percentile,
//! choosing the longest-remaining first, subject to a cluster-wide cap on
//! outstanding speculative copies (speculativeCap).
//!
//! Like Mantri, LATE is a **blind** baseline (`estimator::for_policy` with
//! `instrumented = false`): no access to the paper's s_i-checkpoint; its
//! time-to-end is the estimator's wall-clock remaining, which with the
//! default `speed_aware = true` accounts for the advertised class speed —
//! fitting, since LATE was designed for heterogeneous clusters.
//!
//! **Retained monolith.**  Since the policy-pipeline redesign this is the
//! `legacy_sched` equivalence reference for the canonical composition
//! `fifo+late` (see `scheduler::pipeline`); `tests/pipeline_equivalence.rs`
//! proves byte-identical sweep CSVs, after which the monolith can go.

use crate::cluster::job::{CopyPhase, TaskRef};
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::estimator::{self, RemainingTime};

use super::{srpt, Scheduler};

pub struct Late {
    speculative_cap: f64,
    slow_percentile: f64,
    /// Blind estimator (no checkpoint), speed-aware per config.
    est: Box<dyn RemainingTime>,
    /// Reused per-slot buffers (no allocation in the hot hook).
    rates: Vec<(f64, f64, TaskRef)>,
    sorted_rates: Vec<f64>,
    cands: Vec<(f64, TaskRef)>,
}

impl Late {
    pub fn new(cfg: &SimConfig) -> Self {
        Late {
            speculative_cap: cfg.late_speculative_cap,
            slow_percentile: cfg.late_slow_percentile,
            est: estimator::for_policy(cfg, false),
            rates: Vec::new(),
            sorted_rates: Vec::new(),
            cands: Vec::new(),
        }
    }

    /// Estimated progress rate of a task's primary copy:
    /// `1 / (elapsed + estimated wall-clock remaining)`.
    fn progress_rate(&self, cl: &Cluster, t: TaskRef) -> Option<(f64, f64)> {
        let task = cl.task(t);
        let c = task.copies.first()?;
        if c.phase != CopyPhase::Running {
            return None;
        }
        let elapsed = c.elapsed(cl.clock);
        if elapsed <= 0.0 {
            return None;
        }
        let rem = self.est.copy_remaining_wall(cl, t, 0);
        Some((1.0 / (elapsed + rem), rem))
    }
}

impl Scheduler for Late {
    fn name(&self) -> &str {
        "late"
    }

    fn on_slot(&mut self, cl: &mut Cluster) {
        // gather progress rates of all single-copy running tasks
        self.rates.clear();
        if cl.cfg.sched_index {
            // O(active): the index yields exactly the single-running-first-
            // copy tasks, in the scan's (job asc, task asc) order
            for id in cl.running.iter() {
                for ti in cl.index.candidates(*id) {
                    let t = TaskRef { job: *id, task: ti };
                    if let Some((rate, rem)) = self.progress_rate(cl, t) {
                        self.rates.push((rate, rem, t));
                    }
                }
            }
        } else {
            // naive-scan reference (the phase filter mirrors the index's
            // candidate definition; progress_rate would reject non-running
            // copies anyway, so this is behavior-neutral symmetry)
            for id in cl.running.iter() {
                let job = cl.job(*id);
                for (ti, task) in job.tasks.iter().enumerate() {
                    if task.done || task.copies.len() != 1 {
                        continue;
                    }
                    if task.copies[0].phase != CopyPhase::Running {
                        continue;
                    }
                    let t = TaskRef { job: *id, task: ti as u32 };
                    if let Some((rate, rem)) = self.progress_rate(cl, t) {
                        self.rates.push((rate, rem, t));
                    }
                }
            }
        }
        if !self.rates.is_empty() {
            // slowTaskThreshold: the `slow_percentile` quantile of rates
            // (NaN-safe total_cmp sorts throughout)
            self.sorted_rates.clear();
            self.sorted_rates.extend(self.rates.iter().map(|(r, _, _)| *r));
            self.sorted_rates.sort_by(|a, b| a.total_cmp(b));
            let idx = ((self.sorted_rates.len() as f64 * self.slow_percentile) as usize)
                .min(self.sorted_rates.len() - 1);
            let threshold = self.sorted_rates[idx];
            let cap = (self.speculative_cap * cl.machines.total() as f64) as usize;
            // longest remaining first among the slow ones
            self.cands.clear();
            self.cands.extend(
                self.rates
                    .iter()
                    .filter(|(r, _, _)| *r < threshold)
                    .map(|&(_, rem, t)| (rem, t)),
            );
            self.cands.sort_by(|a, b| b.0.total_cmp(&a.0));
            for &(_, t) in &self.cands {
                if cl.idle() == 0 || cl.outstanding_backups >= cap {
                    break;
                }
                cl.launch_copy(t);
            }
        }
        // FIFO job ordering: Hadoop's stock scheduler (see mantri.rs)
        srpt::schedule_running_fifo(cl);
        srpt::schedule_queued_fifo(cl);
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::generator::generate;
    use crate::cluster::sim::Simulator;
    use crate::config::{SimConfig, WorkloadConfig};

    #[test]
    fn speculates_under_cap() {
        let mut cfg = SimConfig::default();
        cfg.machines = 200;
        cfg.horizon = 300.0;
        cfg.scheduler = crate::scheduler::SchedulerKind::Late;
        let wl = generate(&WorkloadConfig::paper(1.0), cfg.horizon, 5);
        let sched = crate::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let res = Simulator::new(cfg, wl, sched).run();
        assert!(res.speculative_launches > 0);
        assert!(!res.completed.is_empty());
    }

    #[test]
    fn zero_cap_disables_speculation() {
        let mut cfg = SimConfig::default();
        cfg.machines = 200;
        cfg.horizon = 200.0;
        cfg.late_speculative_cap = 0.0;
        cfg.scheduler = crate::scheduler::SchedulerKind::Late;
        let wl = generate(&WorkloadConfig::paper(1.0), cfg.horizon, 5);
        let sched = crate::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let res = Simulator::new(cfg, wl, sched).run();
        assert_eq!(res.speculative_launches, 0);
    }
}
