//! The parallel sweep engine: fans grid cells out across scoped worker
//! threads.
//!
//! Two invariants make parallel runs reproducible:
//!
//! 1. **Schedulers are constructed inside the worker thread.**  The
//!    [`Scheduler`](crate::scheduler::Scheduler) trait is deliberately
//!    `!Send` — SCA may hold a thread-pinned PJRT executor — so a cell's
//!    scheduler never crosses a thread boundary.
//! 2. **Workloads are pre-sampled once per `(load, seed)` pair** and shared
//!    read-only by every policy, so all policies replay the identical
//!    arrivals and first-copy durations, and results are independent of the
//!    worker count and cell interleaving.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cluster::generator;
use crate::cluster::sim::{Simulator, Workload};
use crate::config::WorkloadConfig;
use crate::scheduler;
use crate::workload;

use super::result::{CellResult, SweepResult};
use super::spec::ExperimentSpec;

/// Run `f(0..n)` on up to `threads` scoped workers (0 = one per available
/// core) and return the results in index order.  The low-level primitive
/// under [`Runner::run`]; figure drivers with non-simulation cells (solver
/// traces, analytic curves) use it directly.
pub fn run_parallel<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = resolve_threads(threads).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                out.lock().unwrap()[i] = Some(v);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|v| v.expect("every cell filled"))
        .collect()
}

/// 0 = one worker per available core.
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Executes an [`ExperimentSpec`]'s grid and collects a [`SweepResult`].
pub struct Runner;

impl Runner {
    pub fn run(spec: &ExperimentSpec) -> Result<SweepResult, String> {
        spec.validate()?;
        let mut base = spec.base.clone();
        spec.scenario.apply(&mut base);
        base.validate()?;
        let (np, nl, ns) = (spec.policies.len(), spec.loads.len(), spec.seeds.len());

        // A trace load point streams through the bounded-window source
        // unless the spec asks for up-front materialization (the
        // equivalence-test reference path).  Both paths are bit-identical;
        // see `workload::source` and DESIGN.md §16.
        let streams = |li: usize| {
            matches!(spec.loads[li].workload, WorkloadConfig::Trace { .. })
                && !spec.materialize_traces
        };

        // Pre-sample each (load, seed) workload exactly once; generation is
        // itself seed-deterministic, so it parallelizes safely.  Streamed
        // trace load points get an empty placeholder: their jobs never
        // materialize in memory.
        let cache: Vec<Workload> = run_parallel(nl * ns, spec.threads, |i| {
            if streams(i / ns) {
                return Workload::default();
            }
            generator::generate(&spec.loads[i / ns].workload, base.horizon, spec.seeds[i % ns])
        });

        // Grid cells in policy-major order; the index fixes the output
        // order regardless of which worker finishes first.
        let cells: Vec<Result<CellResult, String>> =
            run_parallel(np * nl * ns, spec.threads, |i| {
                let (pi, li, si) = (i / (nl * ns), (i / ns) % nl, i % ns);
                let policy = &spec.policies[pi];
                let wl_cfg = &spec.loads[li].workload;
                let mut cfg = base.clone();
                cfg.scheduler = policy.scheduler;
                cfg.seed = spec.seeds[si];
                if let Some(patch) = &policy.patch {
                    patch(&mut cfg);
                }
                let result = if streams(li) {
                    // built here, inside the worker: Scheduler is !Send.
                    // With no sampled workload, build_for derives the tail
                    // index from the same single-pass trace scan the
                    // materialized path's estimator reproduces bit-for-bit.
                    let sched = scheduler::build_for(&cfg, wl_cfg, None)?;
                    let source = workload::source_for(wl_cfg, cfg.horizon, cfg.seed)?;
                    let window = match wl_cfg {
                        WorkloadConfig::Trace { window, .. } => *window,
                        _ => unreachable!("streams() only matches traces"),
                    };
                    Simulator::from_source(cfg, source, window, sched).run()
                } else {
                    let workload = cache[li * ns + si].clone();
                    let sched = scheduler::build_for(&cfg, wl_cfg, Some(&workload))?;
                    Simulator::new(cfg, workload, sched).run()
                };
                Ok(CellResult { policy: pi, load: li, seed: spec.seeds[si], result })
            });

        let mut out = Vec::with_capacity(cells.len());
        for cell in cells {
            out.push(cell?);
        }
        Ok(SweepResult::new(spec, base, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::experiment::spec::{LoadPoint, PolicyVariant};
    use crate::scheduler::SchedulerKind;

    #[test]
    fn run_parallel_preserves_index_order() {
        for threads in [1, 2, 7] {
            let v = run_parallel(23, threads, |i| i * i);
            assert_eq!(v, (0..23).map(|i| i * i).collect::<Vec<_>>());
        }
        assert!(run_parallel(0, 4, |i| i).is_empty());
    }

    fn tiny_spec(threads: usize) -> ExperimentSpec {
        let mut cfg = SimConfig::default();
        cfg.machines = 40;
        cfg.horizon = 60.0;
        cfg.use_runtime = false;
        let mut spec = ExperimentSpec::new("tiny", cfg);
        spec.policies = vec![
            PolicyVariant::kind(SchedulerKind::Naive),
            PolicyVariant::kind(SchedulerKind::CloneAll),
        ];
        spec.loads = vec![LoadPoint::lambda(0.2), LoadPoint::lambda(0.4)];
        spec.seeds = vec![1, 2];
        spec.threads = threads;
        spec
    }

    #[test]
    fn grid_is_complete_and_ordered() {
        let sweep = Runner::run(&tiny_spec(2)).unwrap();
        assert_eq!(sweep.cells.len(), 8);
        for (i, c) in sweep.cells.iter().enumerate() {
            assert_eq!(c.policy, i / 4);
            assert_eq!(c.load, (i / 2) % 2);
            assert_eq!(c.seed, [1, 2][i % 2]);
        }
    }

    #[test]
    fn policies_share_the_sampled_workload() {
        let sweep = Runner::run(&tiny_spec(3)).unwrap();
        // same (load, seed) cell under naive and clone_all: any job both
        // policies completed must have the identical arrival and task count
        let by_id = |r: &crate::cluster::sim::SimResult| {
            r.completed
                .iter()
                .map(|j| (j.job, (j.arrival, j.num_tasks)))
                .collect::<std::collections::BTreeMap<_, _>>()
        };
        let a = by_id(&sweep.cell(0, 0, 0).result);
        let b = by_id(&sweep.cell(1, 0, 0).result);
        let mut common = 0;
        for (id, meta) in &b {
            if let Some(meta_a) = a.get(id) {
                assert_eq!(meta, meta_a, "job {id} diverged between policies");
                common += 1;
            }
        }
        assert!(common > 0, "no overlapping completed jobs to compare");
    }
}
