//! Declarative description of a sweep: which policies, which load points,
//! which seeds, on which cluster scenario.  The [`Runner`](super::Runner)
//! turns the spec's cross product into grid cells and fans them out across
//! worker threads.

use std::fmt;
use std::sync::Arc;

use crate::cluster::machine::{MachineClass, SlowdownConfig};
use crate::config::{SimConfig, WorkloadConfig};
use crate::scheduler::SchedulerKind;

/// A deterministic tweak applied to the cell's config after the scheduler
/// kind and seed are set (e.g. an ablation flag).  Must be `Send + Sync`:
/// it is *called* inside worker threads, although the scheduler it
/// configures is still constructed in-thread.
pub type ConfigPatch = Arc<dyn Fn(&mut SimConfig) + Send + Sync>;

/// One point on the policy axis: a scheduler kind plus an optional config
/// patch, labelled for reports.  `x` is the variant's coordinate when the
/// policy axis is the swept dimension (e.g. a sigma sweep); NaN when the
/// axis is categorical.
#[derive(Clone)]
pub struct PolicyVariant {
    pub label: String,
    pub scheduler: SchedulerKind,
    pub x: f64,
    pub patch: Option<ConfigPatch>,
}

impl PolicyVariant {
    /// A plain scheduler with no overrides.  The label is the kind's
    /// canonical name or composition spec (`"sda"`, `"est-srpt+mantri"`),
    /// so composed pipelines appear as distinct rows in sweep CSVs.
    pub fn kind(k: SchedulerKind) -> Self {
        PolicyVariant { label: k.to_string(), scheduler: k, x: f64::NAN, patch: None }
    }

    /// A policy parsed from the grammar (canonical name or composition
    /// spec) — the string-friendly way to put pipeline components on the
    /// sweep's policy axis.
    pub fn policy(spec: &str) -> Result<Self, String> {
        spec.parse().map(PolicyVariant::kind)
    }

    /// A scheduler run at a fixed straggler threshold (the Fig. 3/5 sigma
    /// sweeps); `x` is set to sigma so series can plot against it.
    pub fn with_sigma(k: SchedulerKind, sigma: f64) -> Self {
        PolicyVariant {
            label: format!("{k}@sigma{sigma}"),
            scheduler: k,
            x: sigma,
            patch: Some(Arc::new(move |cfg: &mut SimConfig| cfg.sigma = Some(sigma))),
        }
    }

    /// A scheduler with an arbitrary config patch (ablation sweeps).
    pub fn patched(
        label: impl Into<String>,
        k: SchedulerKind,
        patch: impl Fn(&mut SimConfig) + Send + Sync + 'static,
    ) -> Self {
        PolicyVariant {
            label: label.into(),
            scheduler: k,
            x: f64::NAN,
            patch: Some(Arc::new(patch)),
        }
    }

    /// Set the variant's x-coordinate (for swept policy axes).
    pub fn at_x(mut self, x: f64) -> Self {
        self.x = x;
        self
    }
}

impl fmt::Debug for PolicyVariant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PolicyVariant")
            .field("label", &self.label)
            .field("scheduler", &self.scheduler)
            .field("x", &self.x)
            .field("patched", &self.patch.is_some())
            .finish()
    }
}

/// One point on the load axis: a labelled workload with an x-coordinate
/// (arrival rate, tail index, load fraction — whatever the sweep varies).
#[derive(Clone, Debug)]
pub struct LoadPoint {
    pub label: String,
    pub x: f64,
    pub workload: WorkloadConfig,
}

impl LoadPoint {
    pub fn new(label: impl Into<String>, x: f64, workload: WorkloadConfig) -> Self {
        LoadPoint { label: label.into(), x, workload }
    }

    /// The paper's multi-job workload at arrival rate `lambda`.
    pub fn lambda(lambda: f64) -> Self {
        LoadPoint::new(format!("lambda{lambda}"), lambda, WorkloadConfig::paper(lambda))
    }
}

/// The cluster scenario axis: which machines the sweep runs on.  The
/// default is the paper's homogeneous cluster (whatever `base.machines`
/// says); a heterogeneous scenario overrides both the class layout and the
/// machine count, and a slowdown scenario degrades a seed-deterministic
/// random subset of machines (see `cluster::machine::SlowdownConfig`).
#[derive(Clone, Debug, Default)]
pub struct ClusterScenario {
    pub machine_classes: Vec<MachineClass>,
    pub slowdown: Option<SlowdownConfig>,
}

impl ClusterScenario {
    /// The paper's homogeneous cluster (no override).
    pub fn homogeneous() -> Self {
        ClusterScenario::default()
    }

    /// A heterogeneous cluster built from speed classes.
    pub fn heterogeneous(classes: Vec<MachineClass>) -> Self {
        ClusterScenario { machine_classes: classes, slowdown: None }
    }

    /// Add server-dependent slowdown: each machine degraded with
    /// probability `sd.frac`, inflating its wall-clock by `sd.factor`.
    pub fn with_slowdown(mut self, sd: SlowdownConfig) -> Self {
        self.slowdown = Some(sd);
        self
    }

    pub(crate) fn apply(&self, cfg: &mut SimConfig) {
        if !self.machine_classes.is_empty() {
            cfg.set_machine_classes(self.machine_classes.clone());
        }
        if let Some(sd) = self.slowdown {
            cfg.slowdown = Some(sd);
        }
    }
}

/// A declarative sweep: the full grid is
/// `policies x loads x seeds` on `scenario`, every cell sharing the
/// pre-sampled workload of its `(load, seed)` pair.
///
/// # Example
///
/// A one-cell sweep, run through the parallel [`Runner`](super::Runner):
///
/// ```
/// use specsim::config::SimConfig;
/// use specsim::experiment::{ExperimentSpec, LoadPoint, PolicyVariant, Runner};
/// use specsim::scheduler::SchedulerKind;
///
/// let mut base = SimConfig::default();
/// base.machines = 50;
/// base.horizon = 80.0;
/// base.use_runtime = false;
/// let mut spec = ExperimentSpec::new("doc", base);
/// spec.policies = vec![PolicyVariant::kind(SchedulerKind::Naive)];
/// spec.loads = vec![LoadPoint::lambda(0.3)];
/// spec.seeds = vec![1];
/// spec.threads = 1;
/// assert_eq!(spec.cell_count(), 1);
///
/// let sweep = Runner::run(&spec).unwrap();
/// assert_eq!(sweep.cells.len(), 1);
/// assert!(!sweep.cell(0, 0, 0).result.completed.is_empty());
/// ```
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    /// Name for reports/logs.
    pub name: String,
    /// Common configuration; per-cell fields (scheduler, seed) and policy
    /// patches are applied on top of a clone.
    pub base: SimConfig,
    /// Cluster scenario applied to `base` before any cell runs.
    pub scenario: ClusterScenario,
    pub policies: Vec<PolicyVariant>,
    pub loads: Vec<LoadPoint>,
    pub seeds: Vec<u64>,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Materialize trace workloads up front (`cluster::trace::load`)
    /// instead of streaming them through the bounded-window
    /// `workload::StreamSource` path.  `false` — the default — keeps a
    /// million-job trace's resident footprint at the lookahead window;
    /// both settings produce byte-identical sweep CSVs (pinned by
    /// `tests/trace_replay.rs`).  Synthetic workloads are unaffected.
    pub materialize_traces: bool,
}

impl ExperimentSpec {
    pub fn new(name: impl Into<String>, base: SimConfig) -> Self {
        let seeds = vec![base.seed];
        ExperimentSpec {
            name: name.into(),
            base,
            scenario: ClusterScenario::default(),
            policies: Vec::new(),
            loads: Vec::new(),
            seeds,
            threads: 0,
            materialize_traces: false,
        }
    }

    /// Grid size.
    pub fn cell_count(&self) -> usize {
        self.policies.len() * self.loads.len() * self.seeds.len()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.policies.is_empty() {
            return Err(format!("experiment '{}': no policies", self.name));
        }
        if self.loads.is_empty() {
            return Err(format!("experiment '{}': no load points", self.name));
        }
        if self.seeds.is_empty() {
            return Err(format!("experiment '{}': no seeds", self.name));
        }
        self.base.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_validates_axes() {
        let mut spec = ExperimentSpec::new("t", SimConfig::default());
        assert!(spec.validate().is_err());
        spec.policies = vec![PolicyVariant::kind(SchedulerKind::Naive)];
        assert!(spec.validate().is_err());
        spec.loads = vec![LoadPoint::lambda(2.0)];
        spec.validate().unwrap();
        assert_eq!(spec.cell_count(), 1);
        spec.seeds = vec![1, 2, 3];
        assert_eq!(spec.cell_count(), 3);
    }

    #[test]
    fn sigma_variant_patches_config() {
        let v = PolicyVariant::with_sigma(SchedulerKind::Sda, 1.7);
        assert_eq!(v.x, 1.7);
        let mut cfg = SimConfig::default();
        (v.patch.unwrap())(&mut cfg);
        assert_eq!(cfg.sigma, Some(1.7));
    }

    #[test]
    fn scenario_applies_classes() {
        let sc = ClusterScenario::heterogeneous(vec![
            MachineClass::new(10, 1.0),
            MachineClass::new(5, 0.5),
        ]);
        let mut cfg = SimConfig::default();
        sc.apply(&mut cfg);
        assert_eq!(cfg.machines, 15);
        cfg.validate().unwrap();
        // homogeneous scenario leaves the base cluster untouched
        let mut cfg = SimConfig::default();
        ClusterScenario::homogeneous().apply(&mut cfg);
        assert_eq!(cfg.machines, 3000);
        assert!(cfg.machine_classes.is_empty());
        assert_eq!(cfg.slowdown, None);
    }

    #[test]
    fn scenario_applies_slowdown() {
        let sd = SlowdownConfig::new(0.2, 3.0);
        let sc = ClusterScenario::homogeneous().with_slowdown(sd);
        let mut cfg = SimConfig::default();
        sc.apply(&mut cfg);
        assert_eq!(cfg.slowdown, Some(sd));
        cfg.validate().unwrap();
        // composes with heterogeneous classes
        let sc = ClusterScenario::heterogeneous(vec![MachineClass::new(4, 2.0)]).with_slowdown(sd);
        let mut cfg = SimConfig::default();
        sc.apply(&mut cfg);
        assert_eq!(cfg.machines, 4);
        assert_eq!(cfg.slowdown, Some(sd));
    }
}
