//! The collected grid: one [`SimResult`] per cell, in a fixed
//! policy-major order, plus seed-pooling and series helpers.
//! `metrics::report::sweep_csv` serializes the table to the repo's
//! label/x/y CSV shapes.

use crate::cluster::sim::SimResult;
use crate::config::SimConfig;

use super::spec::ExperimentSpec;

/// One grid cell's outcome.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Index into [`SweepResult::policies`].
    pub policy: usize,
    /// Index into [`SweepResult::loads`].
    pub load: usize,
    pub seed: u64,
    pub result: SimResult,
}

/// All cells of one sweep.  Cells are ordered policy-major, then load,
/// then seed — the order is a function of the spec alone, never of worker
/// scheduling, so two runs of the same spec serialize byte-identically.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub name: String,
    /// The resolved base config (scenario applied) the cells ran under.
    pub base: SimConfig,
    /// Policy axis: (label, x-coordinate; NaN when categorical).
    pub policies: Vec<(String, f64)>,
    /// Load axis: (label, x-coordinate).
    pub loads: Vec<(String, f64)>,
    pub seeds: Vec<u64>,
    pub cells: Vec<CellResult>,
}

impl SweepResult {
    pub(crate) fn new(spec: &ExperimentSpec, base: SimConfig, cells: Vec<CellResult>) -> Self {
        SweepResult {
            name: spec.name.clone(),
            base,
            policies: spec.policies.iter().map(|p| (p.label.clone(), p.x)).collect(),
            loads: spec.loads.iter().map(|l| (l.label.clone(), l.x)).collect(),
            seeds: spec.seeds.clone(),
            cells,
        }
    }

    /// The cell at (policy, load, seed-index).
    pub fn cell(&self, pi: usize, li: usize, si: usize) -> &CellResult {
        &self.cells[(pi * self.loads.len() + li) * self.seeds.len() + si]
    }

    /// All seeds of one (policy, load) pair, in seed order.
    pub fn cells_for(&self, pi: usize, li: usize) -> &[CellResult] {
        let ns = self.seeds.len();
        let start = (pi * self.loads.len() + li) * ns;
        &self.cells[start..start + ns]
    }

    /// Pool one (policy, load) pair's per-job records across seeds — the
    /// paper repeats each experiment with a few seeds and pools the jobs.
    /// Utilization is averaged; counters are summed.
    pub fn merged(&self, pi: usize, li: usize) -> SimResult {
        let cells = self.cells_for(pi, li);
        let mut acc = cells[0].result.clone();
        for c in &cells[1..] {
            acc.completed.extend(c.result.completed.iter().cloned());
            acc.incomplete += c.result.incomplete;
            acc.total_machine_time += c.result.total_machine_time;
            acc.speculative_launches += c.result.speculative_launches;
            acc.events_processed += c.result.events_processed;
            acc.ticks_fired += c.result.ticks_fired;
            acc.ticks_skipped += c.result.ticks_skipped;
            acc.peak_event_queue = acc.peak_event_queue.max(c.result.peak_event_queue);
            acc.slot_hook_secs += c.result.slot_hook_secs;
            acc.copies_lost += c.result.copies_lost;
            acc.work_lost += c.result.work_lost;
            acc.machines_failed += c.result.machines_failed;
        }
        acc.utilization =
            cells.iter().map(|c| c.result.utilization).sum::<f64>() / cells.len() as f64;
        acc
    }

    /// One series per policy over the load axis: seed-pooled `metric`
    /// against each load's x.  Feeds `metrics::report::xy_csv`.
    pub fn series_over_loads(
        &self,
        metric: impl Fn(&SimResult) -> f64,
    ) -> Vec<(String, Vec<(f64, f64)>)> {
        self.policies
            .iter()
            .enumerate()
            .map(|(pi, (label, _))| {
                let pts = self
                    .loads
                    .iter()
                    .enumerate()
                    .map(|(li, (_, x))| (*x, metric(&self.merged(pi, li))))
                    .collect();
                (label.clone(), pts)
            })
            .collect()
    }

    /// One series over the policy axis for a fixed load: seed-pooled
    /// `metric` against each policy's x (a sigma sweep, say).
    pub fn series_over_policies(
        &self,
        li: usize,
        metric: impl Fn(&SimResult) -> f64,
    ) -> Vec<(f64, f64)> {
        self.policies
            .iter()
            .enumerate()
            .map(|(pi, (_, x))| (*x, metric(&self.merged(pi, li))))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::spec::{LoadPoint, PolicyVariant};
    use crate::experiment::Runner;
    use crate::scheduler::SchedulerKind;

    fn sweep() -> SweepResult {
        let mut cfg = SimConfig::default();
        cfg.machines = 30;
        cfg.horizon = 50.0;
        cfg.use_runtime = false;
        let mut spec = ExperimentSpec::new("t", cfg);
        spec.policies = vec![PolicyVariant::kind(SchedulerKind::Naive)];
        spec.loads = vec![LoadPoint::lambda(0.2), LoadPoint::lambda(0.3)];
        spec.seeds = vec![4, 5];
        spec.threads = 1;
        Runner::run(&spec).unwrap()
    }

    #[test]
    fn merged_pools_seeds() {
        let s = sweep();
        let merged = s.merged(0, 0);
        let per_seed: usize =
            s.cells_for(0, 0).iter().map(|c| c.result.completed.len()).sum();
        assert_eq!(merged.completed.len(), per_seed);
    }

    #[test]
    fn series_shapes_match_axes() {
        let s = sweep();
        let over_loads = s.series_over_loads(|r| r.mean_flowtime());
        assert_eq!(over_loads.len(), 1);
        assert_eq!(over_loads[0].1.len(), 2);
        assert_eq!(over_loads[0].1[0].0, 0.2);
        let over_policies = s.series_over_policies(1, |r| r.mean_flowtime());
        assert_eq!(over_policies.len(), 1);
    }
}
