//! The parallel experiment engine: declarative scenario sweeps over
//! scheduler x load x seed grids on a chosen cluster scenario.
//!
//! The paper's headline results are all sweeps, and every later scaling PR
//! wants to run bigger ones; this module gives them one shape:
//!
//! * [`ExperimentSpec`] declares the grid — [`PolicyVariant`]s (scheduler
//!   kind + optional config patch), [`LoadPoint`]s (labelled workloads),
//!   replication seeds, and a [`ClusterScenario`] (homogeneous or
//!   heterogeneous machine classes, with optional server-dependent
//!   slowdown).
//! * [`Runner`] executes the grid across `std::thread::scope` workers.
//!   Schedulers are constructed *inside* each worker (the `Scheduler`
//!   trait is `!Send`; SCA can pin a PJRT executor to its thread), and
//!   each `(load, seed)` workload is pre-sampled exactly once and shared
//!   read-only by every policy — so results are byte-identical whatever
//!   the worker count.
//! * [`SweepResult`] is the collected table, in spec order;
//!   `metrics::report::sweep_csv` serializes it, and its series helpers
//!   feed the existing `xy_csv`/`cmf_csv` shapes.
//!
//! All figure drivers, the sweep benches and the CLI `compare`/`sweep`
//! commands route through here.

pub mod result;
pub mod runner;
pub mod spec;

pub use result::{CellResult, SweepResult};
pub use runner::{resolve_threads, run_parallel, Runner};
pub use spec::{ClusterScenario, ConfigPatch, ExperimentSpec, LoadPoint, PolicyVariant};
