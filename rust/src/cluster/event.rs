//! Discrete-event queue.  Events are ordered by time (then by a sequence
//! number so simultaneous events process in insertion order, keeping runs
//! deterministic).
//!
//! Scheduling-slot boundaries do **not** live in this queue: since the
//! demand-driven wakeup planner retired the `SlotTick` polling loop, the
//! slot grid is interleaved with the queue by the run loops themselves
//! (`Simulator::run`, `coordinator::master`), with the defined tie
//! semantics that a slot at time `t` observes every event at `t` — see
//! [`crate::cluster::sim::SlotGate`] and DESIGN.md §12.
//!
//! ## Backends
//!
//! Two interchangeable backends implement the same `(time, seq)` total
//! order ([`EventQueueKind`], selected by `SimConfig::event_queue`):
//!
//! * **`binary-heap`** — the classic `BinaryHeap<Entry>`: O(log n) push
//!   and pop, no assumptions about push times.  Retained as the
//!   equivalence reference.
//! * **`calendar`** — a calendar queue keyed on the scheduling slot grid
//!   (bucket width = `slot_dt`, the same grid the wakeup planner
//!   quantizes to): O(1) push into the bucket of `floor(t / width)`,
//!   pops walk a cursor over an absolute in-window wheel of
//!   [`CALENDAR_DAYS`] buckets and lazily sort one bucket at a time.
//!   Events beyond the wheel's horizon wait in a sorted **overflow**
//!   min-heap and migrate into the wheel (each at most once) when the
//!   wheel drains and the window rebases forward.  The calendar assumes
//!   the simulator's push discipline — every push is at `clock + d`,
//!   `d > 0`, with `clock` at or after the last popped time — which keeps
//!   the window monotone (asserted in debug builds).  Within a bucket,
//!   entries sort by the *identical* `(time, seq)` comparison the heap
//!   uses, so the two backends pop bit-identical sequences.
//!
//! ## Stale-entry hygiene
//!
//! A killed copy leaves its `CopyFinish` (and possibly `Checkpoint`) entry
//! in the queue until its sampled time — harmless (the pop is a no-op) but
//! under heavy speculation the queue would otherwise track *copies ever
//! launched* instead of *copies alive*.  The cluster counts exactly those
//! dead entries via [`EventQueue::note_stale`]; once they outnumber the
//! live half of the queue, [`EventQueue::retain_live`] compacts in one
//! O(n) pass (amortized O(1) per kill).  Sequence numbers survive
//! compaction, so the pop order of the remaining events — and therefore
//! the simulation — is bit-identical with or without it, on either
//! backend.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::job::{JobId, TaskRef};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A job joins the master queue.
    Arrival(JobId),
    /// A task copy reaches the end of its sampled duration.  `epoch` is the
    /// copy's re-time generation at push: a `SlowdownFlip` on the copy's
    /// host bumps the arena epoch and re-pushes, so a popped entry whose
    /// epoch trails the arena's is stale (see `Cluster::flip_machine`).
    CopyFinish { task: TaskRef, copy: u32, epoch: u32 },
    /// A first copy crosses the detection fraction s_i: its true remaining
    /// time becomes visible to the scheduler (straggler checkpoint).
    /// Carries the same re-time `epoch` as `CopyFinish`.
    Checkpoint { task: TaskRef, copy: u32, epoch: u32 },
    /// Machine `machine`'s hidden ON/OFF slowdown state flips (degrades or
    /// recovers).  The handler re-times every running copy on the machine
    /// and schedules the next flip; never stale, never compacted away.
    SlowdownFlip { machine: u32 },
    /// Machine `machine` crashes: every resident copy is killed (work
    /// lost, the paper's restart-from-zero model), the machine leaves the
    /// allocatable pool, and tasks whose last running copy died are
    /// re-queued for re-execution.  Never stale, never compacted away
    /// (see `Cluster::fail_machine`).
    MachineFail { machine: u32 },
    /// Machine `machine` rejoins the pool after a crash.  Never stale,
    /// never compacted away (see `Cluster::recover_machine`).
    MachineRecover { machine: u32 },
}

/// Which data structure backs the [`EventQueue`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EventQueueKind {
    /// The classic binary heap — the equivalence reference.
    BinaryHeap,
    /// Slot-grid calendar queue — the default hot path.
    #[default]
    Calendar,
}

impl EventQueueKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventQueueKind::BinaryHeap => "binary-heap",
            EventQueueKind::Calendar => "calendar",
        }
    }
}

impl std::str::FromStr for EventQueueKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "binary-heap" | "heap" => Ok(EventQueueKind::BinaryHeap),
            "calendar" => Ok(EventQueueKind::Calendar),
            other => Err(format!(
                "unknown event queue '{other}' (expected binary-heap or calendar)"
            )),
        }
    }
}

impl std::fmt::Display for EventQueueKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first.  The
        // calendar's per-bucket sort uses this same comparison (popping
        // from the Vec's tail), so tie order — including the -0.0 == 0.0
        // semantics of partial_cmp — is identical across backends.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// In-window wheel size, in buckets (= scheduling slots).  At the bench's
/// light-load grid (`slot_dt = 0.001`) this covers 8.192 time units —
/// past the mean Pareto copy duration, so most `CopyFinish` pushes land
/// in-window; at the paper's `slot_dt = 1` it covers every event of a
/// standard run.  Empty buckets cost one `Vec` header each (~192 KiB
/// total), independent of machine count.
const CALENDAR_DAYS: usize = 8192;

/// Calendar-queue backend: an absolute-addressed window of
/// [`CALENDAR_DAYS`] buckets starting at bucket `epoch`, plus an overflow
/// min-heap for events at or beyond bucket `epoch + CALENDAR_DAYS`.
///
/// Invariants (debug-asserted where cheap):
/// * every bucket below `cursor` in the wheel is empty;
/// * wheel entries live in buckets `[epoch, epoch + CALENDAR_DAYS)`,
///   overflow entries at or beyond `epoch + CALENDAR_DAYS` — so every
///   wheel entry pops before any overflow entry, and equal times always
///   share a bucket (tie order is the bucket sort);
/// * pushes never land below `last_pop_bucket` (the simulator's push
///   discipline), so `epoch` only ever moves forward — it rebases to
///   `last_pop_bucket` when the wheel drains, at which point any overflow
///   prefix that fits the new window migrates in (each entry at most
///   once).
#[derive(Debug)]
struct Calendar {
    /// Bucket width: the run's `slot_dt` (guarded to a positive finite).
    width: f64,
    /// Absolute bucket index of `wheel[0]`.
    epoch: u64,
    /// Current wheel slot; all slots below it are empty.
    cursor: usize,
    /// Whether `wheel[cursor]` is sorted (descending by `Entry`'s reversed
    /// order, so the earliest entry is at the tail).
    cur_sorted: bool,
    wheel: Vec<Vec<Entry>>,
    /// Total entries across all wheel buckets.
    wheel_len: usize,
    /// Far-horizon entries, earliest-first (same `Entry` order).
    overflow: BinaryHeap<Entry>,
    /// Absolute bucket of the most recent pop — the floor for future
    /// pushes and the rebase target.
    last_pop_bucket: u64,
}

impl Calendar {
    fn new(width: f64) -> Self {
        let width = if width.is_finite() && width > 0.0 { width } else { 1.0 };
        Calendar {
            width,
            epoch: 0,
            cursor: 0,
            cur_sorted: true,
            wheel: (0..CALENDAR_DAYS).map(|_| Vec::new()).collect(),
            wheel_len: 0,
            overflow: BinaryHeap::new(),
            last_pop_bucket: 0,
        }
    }

    #[inline]
    fn bucket(&self, t: f64) -> u64 {
        let b = (t / self.width).floor();
        if b <= 0.0 {
            0
        } else {
            b as u64 // saturates for absurdly large t
        }
    }

    fn push(&mut self, e: Entry) {
        let b = self.bucket(e.time);
        debug_assert!(
            b >= self.last_pop_bucket,
            "calendar push into bucket {b} behind last pop bucket {} (t = {})",
            self.last_pop_bucket,
            e.time
        );
        let rel = b.saturating_sub(self.epoch);
        if rel >= CALENDAR_DAYS as u64 {
            self.overflow.push(e);
            return;
        }
        let i = rel as usize;
        self.wheel[i].push(e);
        self.wheel_len += 1;
        if i < self.cursor {
            // a slot fired between far-apart events and launched a short
            // copy: legal (still >= last_pop_bucket), walk the cursor back
            self.cursor = i;
            self.cur_sorted = false;
        } else if i == self.cursor {
            self.cur_sorted = false;
        }
    }

    /// Bring the queue to a poppable state: rebase + migrate if the wheel
    /// drained, then advance the cursor to the next non-empty bucket and
    /// sort it lazily.
    fn settle(&mut self) {
        if self.wheel_len == 0 {
            if self.overflow.is_empty() {
                return;
            }
            if self.last_pop_bucket > self.epoch {
                self.epoch = self.last_pop_bucket;
            }
            self.cursor = 0;
            self.cur_sorted = false;
            // migrate the overflow prefix that fits the rebased window;
            // time order == bucket order, so a peek/pop loop extracts
            // exactly the in-window entries
            let horizon = self.epoch.saturating_add(CALENDAR_DAYS as u64);
            while let Some(e) = self.overflow.peek() {
                if self.bucket(e.time) >= horizon {
                    break;
                }
                let e = self.overflow.pop().expect("peeked entry");
                let i = (self.bucket(e.time) - self.epoch) as usize;
                self.wheel[i].push(e);
                self.wheel_len += 1;
            }
            if self.wheel_len == 0 {
                return; // everything still beyond the window: pop overflow
            }
        }
        while self.wheel[self.cursor].is_empty() {
            self.cursor += 1;
            self.cur_sorted = false;
        }
        if !self.cur_sorted {
            self.wheel[self.cursor].sort_unstable();
            self.cur_sorted = true;
        }
    }

    fn pop(&mut self) -> Option<Entry> {
        self.settle();
        if self.wheel_len > 0 {
            let e = self.wheel[self.cursor].pop().expect("settled cursor bucket");
            self.wheel_len -= 1;
            self.last_pop_bucket = self.epoch + self.cursor as u64;
            Some(e)
        } else {
            let e = self.overflow.pop()?;
            self.last_pop_bucket = self.bucket(e.time);
            Some(e)
        }
    }

    fn peek(&mut self) -> Option<&Entry> {
        self.settle();
        if self.wheel_len > 0 {
            self.wheel[self.cursor].last()
        } else {
            self.overflow.peek()
        }
    }

    fn len(&self) -> usize {
        self.wheel_len + self.overflow.len()
    }

    fn retain(&mut self, mut is_live: impl FnMut(&Event) -> bool) {
        let mut removed = 0;
        for slot in self.wheel.iter_mut() {
            let before = slot.len();
            // Vec::retain preserves order, so a sorted cursor bucket stays
            // sorted
            slot.retain(|e| is_live(&e.event));
            removed += before - slot.len();
        }
        self.wheel_len -= removed;
        let kept: Vec<Entry> = std::mem::take(&mut self.overflow)
            .into_vec()
            .into_iter()
            .filter(|e| is_live(&e.event))
            .collect();
        self.overflow = BinaryHeap::from(kept);
    }
}

#[derive(Debug)]
enum Backend {
    Heap(BinaryHeap<Entry>),
    Calendar(Calendar),
}

/// Min-queue of timestamped events with stale-entry accounting, backed by
/// either a binary heap or a slot-grid calendar ([`EventQueueKind`]).
#[derive(Debug)]
pub struct EventQueue {
    backend: Backend,
    seq: u64,
    /// Entries known to be dead (their copy was killed / its task done);
    /// popped as no-ops unless compacted away first.
    stale: usize,
    /// High-water mark of `len()` over the queue's lifetime.
    peak: usize,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue {
            backend: Backend::Heap(BinaryHeap::new()),
            seq: 0,
            stale: 0,
            peak: 0,
        }
    }
}

/// Don't bother compacting tiny queues.
const COMPACT_MIN_STALE: usize = 64;

impl EventQueue {
    /// Binary-heap-backed queue (the reference backend).
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue backed by `kind`; the calendar's bucket width is the run's
    /// `slot_dt` (the wakeup planner's decision grid).
    pub fn with_kind(kind: EventQueueKind, slot_dt: f64) -> Self {
        match kind {
            EventQueueKind::BinaryHeap => Self::new(),
            EventQueueKind::Calendar => EventQueue {
                backend: Backend::Calendar(Calendar::new(slot_dt)),
                seq: 0,
                stale: 0,
                peak: 0,
            },
        }
    }

    pub fn kind(&self) -> EventQueueKind {
        match &self.backend {
            Backend::Heap(_) => EventQueueKind::BinaryHeap,
            Backend::Calendar(_) => EventQueueKind::Calendar,
        }
    }

    pub fn push(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite(), "event at non-finite time: {event:?}");
        self.seq += 1;
        let entry = Entry { time, seq: self.seq, event };
        match &mut self.backend {
            Backend::Heap(h) => h.push(entry),
            Backend::Calendar(c) => c.push(entry),
        }
        let n = self.len();
        if n > self.peak {
            self.peak = n;
        }
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let e = match &mut self.backend {
            Backend::Heap(h) => h.pop(),
            Backend::Calendar(c) => c.pop(),
        };
        e.map(|e| (e.time, e.event))
    }

    /// Time of the next event.  `&mut` because the calendar backend
    /// settles (rebases / sorts) lazily on observation.
    pub fn peek_time(&mut self) -> Option<f64> {
        match &mut self.backend {
            Backend::Heap(h) => h.peek().map(|e| e.time),
            Backend::Calendar(c) => c.peek().map(|e| e.time),
        }
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Calendar(c) => c.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Largest `len()` ever observed (perf-harness metric: queue growth
    /// must track active copies, not copies ever launched).
    pub fn peak_len(&self) -> usize {
        self.peak
    }

    /// Record that `n` already-pushed entries became dead (e.g. a killed
    /// copy's pending `CopyFinish`).  The caller is responsible for exact
    /// counting; see `Cluster::kill_copy`.
    pub fn note_stale(&mut self, n: usize) {
        self.stale += n;
    }

    /// A previously-noted stale entry just popped as a no-op (it outlived
    /// the compaction that would have removed it) — keep the count exact.
    pub fn note_stale_popped(&mut self) {
        self.stale = self.stale.saturating_sub(1);
    }

    /// Should the owner run a compaction pass?  True once at least half
    /// the queue is dead entries (so each O(n) pass removes ≥ n/2 of them —
    /// amortized O(1) per kill).
    pub fn should_compact(&self) -> bool {
        self.stale >= COMPACT_MIN_STALE && 2 * self.stale >= self.len()
    }

    /// Drop every entry whose event fails `is_live`, resetting the stale
    /// count.  Sequence numbers are preserved, so surviving events pop in
    /// the exact order they would have without compaction.
    pub fn retain_live(&mut self, mut is_live: impl FnMut(&Event) -> bool) {
        match &mut self.backend {
            Backend::Heap(h) => h.retain(|e| is_live(&e.event)),
            Backend::Calendar(c) => c.retain(is_live),
        }
        self.stale = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Pcg64;

    /// Run every black-box queue test against both backends.
    fn both(mut f: impl FnMut(EventQueue)) {
        f(EventQueue::new());
        f(EventQueue::with_kind(EventQueueKind::Calendar, 1.0));
    }

    #[test]
    fn kind_parses_and_roundtrips() {
        use std::str::FromStr;
        assert_eq!(EventQueueKind::from_str("binary-heap"), Ok(EventQueueKind::BinaryHeap));
        assert_eq!(EventQueueKind::from_str("heap"), Ok(EventQueueKind::BinaryHeap));
        assert_eq!(EventQueueKind::from_str("calendar"), Ok(EventQueueKind::Calendar));
        assert!(EventQueueKind::from_str("splay").is_err());
        for k in [EventQueueKind::BinaryHeap, EventQueueKind::Calendar] {
            assert_eq!(EventQueueKind::from_str(&k.to_string()), Ok(k));
        }
        assert_eq!(EventQueueKind::default(), EventQueueKind::Calendar);
        assert_eq!(EventQueue::new().kind(), EventQueueKind::BinaryHeap);
        assert_eq!(
            EventQueue::with_kind(EventQueueKind::Calendar, 0.5).kind(),
            EventQueueKind::Calendar
        );
    }

    #[test]
    fn pops_in_time_order() {
        both(|mut q| {
            q.push(3.0, Event::Arrival(JobId(3)));
            q.push(1.0, Event::Arrival(JobId(1)));
            q.push(2.0, Event::Arrival(JobId(2)));
            let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
            assert_eq!(times, vec![1.0, 2.0, 3.0]);
        });
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        both(|mut q| {
            q.push(1.0, Event::Arrival(JobId(10)));
            q.push(1.0, Event::Arrival(JobId(20)));
            match (q.pop().unwrap().1, q.pop().unwrap().1) {
                (Event::Arrival(a), Event::Arrival(b)) => {
                    assert_eq!(a, JobId(10));
                    assert_eq!(b, JobId(20));
                }
                other => panic!("unexpected {other:?}"),
            }
        });
    }

    /// Churn events order and tie-break like any other entry on both
    /// backends (they carry no epoch — never stale, never compacted).
    #[test]
    fn churn_events_pop_identically_on_both_backends() {
        both(|mut q| {
            q.push(2.0, Event::MachineFail { machine: 1 });
            q.push(2.0, Event::MachineRecover { machine: 2 }); // tie: insertion order
            q.push(0.5, Event::Arrival(JobId(0)));
            assert_eq!(q.pop().unwrap(), (0.5, Event::Arrival(JobId(0))));
            assert_eq!(q.pop().unwrap(), (2.0, Event::MachineFail { machine: 1 }));
            assert_eq!(q.pop().unwrap(), (2.0, Event::MachineRecover { machine: 2 }));
            assert!(q.pop().is_none());
        });
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        both(|mut q| {
            for i in 0..5 {
                q.push(i as f64, Event::Arrival(JobId(i)));
            }
            q.pop();
            q.pop();
            q.push(9.0, Event::Arrival(JobId(9)));
            assert_eq!(q.len(), 4);
            assert_eq!(q.peak_len(), 5);
        });
    }

    #[test]
    fn compaction_preserves_survivor_order() {
        both(|mut q| {
            // interleave live arrivals with stale-to-be copy finishes
            for i in 0..200u32 {
                q.push(i as f64, Event::Arrival(JobId(i)));
                q.push(
                    i as f64 + 0.5,
                    Event::CopyFinish {
                        task: TaskRef { job: JobId(i), task: 0 },
                        copy: 0,
                        epoch: 0,
                    },
                );
            }
            assert!(!q.should_compact());
            q.note_stale(200);
            assert!(q.should_compact());
            q.retain_live(|e| matches!(e, Event::Arrival(_)));
            assert!(!q.should_compact());
            assert_eq!(q.len(), 200);
            // survivors pop in the original (time, seq) order
            let mut prev = -1.0;
            while let Some((t, e)) = q.pop() {
                assert!(t > prev);
                prev = t;
                assert!(matches!(e, Event::Arrival(_)));
            }
        });
    }

    #[test]
    fn small_heaps_never_compact() {
        both(|mut q| {
            q.push(1.0, Event::Arrival(JobId(1)));
            q.note_stale(1);
            assert!(!q.should_compact(), "below the compaction floor");
        });
    }

    #[test]
    fn peek_matches_pop() {
        both(|mut q| {
            q.push(5.0, Event::Arrival(JobId(5)));
            q.push(4.0, Event::Arrival(JobId(4)));
            assert_eq!(q.peek_time(), Some(4.0));
            assert_eq!(q.pop().unwrap().0, 4.0);
            assert_eq!(q.len(), 1);
        });
    }

    /// The wheel rebases across many full windows without losing order.
    #[test]
    fn calendar_bucket_rollover_preserves_order() {
        let mut q = EventQueue::with_kind(EventQueueKind::Calendar, 1.0);
        // 5 windows' worth of events, pushed shuffled within a stride
        let span = (CALENDAR_DAYS * 5) as u32;
        for i in (0..span).step_by(7) {
            q.push(i as f64 + 0.25, Event::Arrival(JobId(i)));
        }
        let mut prev = -1.0;
        let mut popped = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t > prev, "out of order at t = {t}");
            prev = t;
            popped += 1;
        }
        assert_eq!(popped, span.div_ceil(7));
    }

    /// Far-horizon events wait in overflow and still pop in global order,
    /// including ties against in-window pushes that arrive later.
    #[test]
    fn calendar_far_horizon_overflow_order() {
        let far = (CALENDAR_DAYS as f64) * 3.0 + 0.5;
        let mut q = EventQueue::with_kind(EventQueueKind::Calendar, 1.0);
        q.push(far, Event::Arrival(JobId(1))); // straight to overflow
        q.push(2.5, Event::Arrival(JobId(2)));
        q.push(far, Event::Arrival(JobId(3))); // ties with the first by seq
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop().unwrap(), (2.5, Event::Arrival(JobId(2))));
        // wheel drained: rebase migrates the overflow pair in
        assert_eq!(q.pop().unwrap(), (far, Event::Arrival(JobId(1))));
        assert_eq!(q.pop().unwrap(), (far, Event::Arrival(JobId(3))));
        assert!(q.pop().is_none());
    }

    /// A push can land behind the cursor (a slot fired between far-apart
    /// events and launched a short copy); the cursor walks back.
    #[test]
    fn calendar_push_behind_cursor() {
        let mut q = EventQueue::with_kind(EventQueueKind::Calendar, 1.0);
        q.push(100.5, Event::Arrival(JobId(1)));
        q.push(0.5, Event::Arrival(JobId(2)));
        assert_eq!(q.pop().unwrap().0, 0.5);
        // cursor is now deep in the wheel; push an earlier (but still
        // post-pop) event behind it
        q.push(3.5, Event::Arrival(JobId(3)));
        assert_eq!(q.pop().unwrap().0, 3.5);
        assert_eq!(q.pop().unwrap().0, 100.5);
    }

    /// Overflow entries whose spacing exceeds the window pop directly from
    /// the overflow heap (the rebase migrates nothing).
    #[test]
    fn calendar_sparse_overflow_pops_directly() {
        let w = CALENDAR_DAYS as f64;
        let mut q = EventQueue::with_kind(EventQueueKind::Calendar, 1.0);
        for i in 1..=4u32 {
            q.push(w * 2.0 * i as f64, Event::Arrival(JobId(i)));
        }
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![w * 2.0, w * 4.0, w * 6.0, w * 8.0]);
    }

    /// Property test: random interleaved push/pop/kill/compact sequences
    /// through both backends pop identical `(time, seq)` streams and agree
    /// on every piece of stale bookkeeping.  Pushes follow the simulator's
    /// discipline (always at or after the last popped time).
    #[test]
    fn backends_pop_identically_under_random_ops() {
        for seed in 0..8u64 {
            let mut rng = Pcg64::new(seed, 0xca1e);
            let mut heap = EventQueue::new();
            let mut cal = EventQueue::with_kind(EventQueueKind::Calendar, 0.25);
            let mut clock = 0.0f64;
            let mut next_id = 0u32;
            // ids whose events are dead; both queues' retain predicate
            let mut killed = std::collections::HashSet::new();
            let mut live_ids = Vec::new();
            for _ in 0..4000 {
                match (rng.next_f64() * 10.0) as u32 {
                    // 40%: push at clock + d, d in (0, ~3 windows]
                    0..=3 => {
                        let d = rng.next_f64().powi(3) * 3.0 * 0.25 * CALENDAR_DAYS as f64;
                        let t = clock + d.max(1e-9);
                        let ev = Event::Arrival(JobId(next_id));
                        live_ids.push(next_id);
                        next_id += 1;
                        heap.push(t, ev);
                        cal.push(t, ev);
                    }
                    // 30%: pop and compare
                    4..=6 => {
                        let a = heap.pop();
                        let b = cal.pop();
                        assert_eq!(a, b, "divergent pop (seed {seed})");
                        if let Some((t, Event::Arrival(id))) = a {
                            assert!(t >= clock);
                            clock = t;
                            if killed.remove(&id.0) {
                                heap.note_stale_popped();
                                cal.note_stale_popped();
                            }
                            live_ids.retain(|&x| x != id.0);
                        }
                    }
                    // 20%: kill a random live entry
                    7..=8 => {
                        if !live_ids.is_empty() {
                            let i = (rng.next_f64() * live_ids.len() as f64) as usize;
                            let id = live_ids[i.min(live_ids.len() - 1)];
                            if killed.insert(id) {
                                heap.note_stale(1);
                                cal.note_stale(1);
                            }
                        }
                    }
                    // 10%: compact when due (same trigger on both)
                    _ => {
                        assert_eq!(heap.should_compact(), cal.should_compact());
                        if heap.should_compact() {
                            let k1 = killed.clone();
                            let k2 = killed.clone();
                            heap.retain_live(|e| {
                                !matches!(e, Event::Arrival(id) if k1.contains(&id.0))
                            });
                            cal.retain_live(|e| {
                                !matches!(e, Event::Arrival(id) if k2.contains(&id.0))
                            });
                            live_ids.retain(|x| !killed.contains(x));
                            killed.clear();
                        }
                    }
                }
                assert_eq!(heap.len(), cal.len(), "divergent len (seed {seed})");
            }
            // drain both to the end
            loop {
                let a = heap.pop();
                let b = cal.pop();
                assert_eq!(a, b, "divergent drain (seed {seed})");
                if a.is_none() {
                    break;
                }
            }
        }
    }

    /// Property test for the `SlowdownFlip` re-time protocol: random
    /// sequences of copy pushes, epoch-bumping re-times (the flip handler's
    /// kill/re-insert: mark the superseded entry stale, re-push the same
    /// copy at a new time with a bumped epoch), interleaved `SlowdownFlip`
    /// events, pops, and due-compactions — both backends pop the identical
    /// `(time, seq, event)` stream and agree on stale counts, compaction
    /// triggers, and post-compaction lengths.
    #[test]
    fn backends_agree_under_flip_retime_sequences() {
        use std::collections::HashMap;
        for seed in 0..8u64 {
            let mut rng = Pcg64::new(seed, 0xf11b);
            let mut heap = EventQueue::new();
            let mut cal = EventQueue::with_kind(EventQueueKind::Calendar, 0.25);
            let mut clock = 0.0f64;
            let mut next_id = 0u32;
            // current (live) epoch per copy id; absent = copy finished
            let mut cur: HashMap<u32, u32> = HashMap::new();
            let mut live_ids = Vec::new();
            let finish = |id: u32, epoch: u32| Event::CopyFinish {
                task: TaskRef { job: JobId(id), task: 0 },
                copy: 0,
                epoch,
            };
            for _ in 0..4000 {
                match (rng.next_f64() * 10.0) as u32 {
                    // 30%: launch a copy (epoch 0)
                    0..=2 => {
                        let d = rng.next_f64().powi(3) * 3.0 * 0.25 * CALENDAR_DAYS as f64;
                        let t = clock + d.max(1e-9);
                        let id = next_id;
                        next_id += 1;
                        cur.insert(id, 0);
                        live_ids.push(id);
                        heap.push(t, finish(id, 0));
                        cal.push(t, finish(id, 0));
                    }
                    // 10%: a machine flips (always-live event on both)
                    3 => {
                        let d = rng.next_f64() * 0.25 * CALENDAR_DAYS as f64;
                        let t = clock + d.max(1e-9);
                        let m = (rng.next_f64() * 16.0) as u32;
                        heap.push(t, Event::SlowdownFlip { machine: m });
                        cal.push(t, Event::SlowdownFlip { machine: m });
                    }
                    // 20%: re-time a random live copy — the flip handler's
                    // kill/re-insert: old entry goes stale, same copy
                    // re-pushed with a bumped epoch at a fresh time
                    4..=5 => {
                        if !live_ids.is_empty() {
                            let i = (rng.next_f64() * live_ids.len() as f64) as usize;
                            let id = live_ids[i.min(live_ids.len() - 1)];
                            let e = cur.get_mut(&id).expect("live id has an epoch");
                            *e += 1;
                            let epoch = *e;
                            heap.note_stale(1);
                            cal.note_stale(1);
                            let d = rng.next_f64().powi(3) * 0.5 * CALENDAR_DAYS as f64;
                            let t = clock + d.max(1e-9);
                            heap.push(t, finish(id, epoch));
                            cal.push(t, finish(id, epoch));
                        }
                    }
                    // 30%: pop and compare
                    6..=8 => {
                        let a = heap.pop();
                        let b = cal.pop();
                        assert_eq!(a, b, "divergent pop (seed {seed})");
                        if let Some((t, ev)) = a {
                            assert!(t >= clock);
                            clock = t;
                            if let Event::CopyFinish { task, epoch, .. } = ev {
                                let id = task.job.0;
                                match cur.get(&id) {
                                    // stale: superseded by a later re-time
                                    Some(&e) if e != epoch => {
                                        heap.note_stale_popped();
                                        cal.note_stale_popped();
                                    }
                                    // live: the copy finishes
                                    Some(_) => {
                                        cur.remove(&id);
                                        live_ids.retain(|&x| x != id);
                                    }
                                    // stale: the copy already finished — a
                                    // re-time can land *earlier* than the
                                    // entry it supersedes (speed went up),
                                    // so superseded entries may outlive the
                                    // finish
                                    None => {
                                        heap.note_stale_popped();
                                        cal.note_stale_popped();
                                    }
                                }
                            }
                        }
                    }
                    // 10%: compact when due — epoch-comparing predicate
                    _ => {
                        assert_eq!(
                            heap.should_compact(),
                            cal.should_compact(),
                            "divergent compaction trigger (seed {seed})"
                        );
                        if heap.should_compact() {
                            let c1 = cur.clone();
                            let c2 = cur.clone();
                            let pred = move |c: &HashMap<u32, u32>, e: &Event| match *e {
                                Event::CopyFinish { task, epoch, .. } => {
                                    c.get(&task.job.0) == Some(&epoch)
                                }
                                Event::SlowdownFlip { .. } => true,
                                _ => true,
                            };
                            heap.retain_live(|e| pred(&c1, e));
                            cal.retain_live(|e| pred(&c2, e));
                        }
                    }
                }
                assert_eq!(heap.len(), cal.len(), "divergent len (seed {seed})");
            }
            // drain both to the end
            loop {
                let a = heap.pop();
                let b = cal.pop();
                assert_eq!(a, b, "divergent drain (seed {seed})");
                if a.is_none() {
                    break;
                }
            }
        }
    }
}
