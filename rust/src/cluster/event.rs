//! Discrete-event queue.  Events are ordered by time (then by a sequence
//! number so simultaneous events process in insertion order, keeping runs
//! deterministic).
//!
//! Scheduling-slot boundaries do **not** live in this heap: since the
//! demand-driven wakeup planner retired the `SlotTick` polling loop, the
//! slot grid is interleaved with the heap by the run loops themselves
//! (`Simulator::run`, `coordinator::master`), with the defined tie
//! semantics that a slot at time `t` observes every event at `t` — see
//! [`crate::cluster::sim::SlotGate`] and DESIGN.md §12.
//!
//! ## Stale-entry hygiene
//!
//! A killed copy leaves its `CopyFinish` (and possibly `Checkpoint`) entry
//! in the heap until its sampled time — harmless (the pop is a no-op) but
//! under heavy speculation the heap would otherwise track *copies ever
//! launched* instead of *copies alive*.  The cluster counts exactly those
//! dead entries via [`EventQueue::note_stale`]; once they outnumber the
//! live half of the heap, [`EventQueue::retain_live`] compacts in one
//! O(n) pass (amortized O(1) per kill).  Sequence numbers survive
//! compaction, so the pop order of the remaining events — and therefore
//! the simulation — is bit-identical with or without it.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::job::{JobId, TaskRef};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A job joins the master queue.
    Arrival(JobId),
    /// A task copy reaches the end of its sampled duration.
    CopyFinish { task: TaskRef, copy: u32 },
    /// A first copy crosses the detection fraction s_i: its true remaining
    /// time becomes visible to the scheduler (straggler checkpoint).
    Checkpoint { task: TaskRef, copy: u32 },
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap of timestamped events with stale-entry accounting.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
    /// Entries known to be dead (their copy was killed / its task done);
    /// popped as no-ops unless compacted away first.
    stale: usize,
    /// High-water mark of `len()` over the queue's lifetime.
    peak: usize,
}

/// Don't bother compacting tiny heaps.
const COMPACT_MIN_STALE: usize = 64;

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite(), "event at non-finite time: {event:?}");
        self.seq += 1;
        self.heap.push(Entry { time, seq: self.seq, event });
        if self.heap.len() > self.peak {
            self.peak = self.heap.len();
        }
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Largest `len()` ever observed (perf-harness metric: heap growth
    /// must track active copies, not copies ever launched).
    pub fn peak_len(&self) -> usize {
        self.peak
    }

    /// Record that `n` already-pushed entries became dead (e.g. a killed
    /// copy's pending `CopyFinish`).  The caller is responsible for exact
    /// counting; see `Cluster::kill_copy`.
    pub fn note_stale(&mut self, n: usize) {
        self.stale += n;
    }

    /// A previously-noted stale entry just popped as a no-op (it outlived
    /// the compaction that would have removed it) — keep the count exact.
    pub fn note_stale_popped(&mut self) {
        self.stale = self.stale.saturating_sub(1);
    }

    /// Should the owner run a compaction pass?  True once at least half
    /// the heap is dead entries (so each O(n) pass removes ≥ n/2 of them —
    /// amortized O(1) per kill).
    pub fn should_compact(&self) -> bool {
        self.stale >= COMPACT_MIN_STALE && 2 * self.stale >= self.heap.len()
    }

    /// Drop every entry whose event fails `is_live`, resetting the stale
    /// count.  Sequence numbers are preserved, so surviving events pop in
    /// the exact order they would have without compaction.
    pub fn retain_live(&mut self, mut is_live: impl FnMut(&Event) -> bool) {
        self.heap.retain(|e| is_live(&e.event));
        self.stale = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::Arrival(JobId(3)));
        q.push(1.0, Event::Arrival(JobId(1)));
        q.push(2.0, Event::Arrival(JobId(2)));
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::Arrival(JobId(10)));
        q.push(1.0, Event::Arrival(JobId(20)));
        match (q.pop().unwrap().1, q.pop().unwrap().1) {
            (Event::Arrival(a), Event::Arrival(b)) => {
                assert_eq!(a, JobId(10));
                assert_eq!(b, JobId(20));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.push(i as f64, Event::Arrival(JobId(i)));
        }
        q.pop();
        q.pop();
        q.push(9.0, Event::Arrival(JobId(9)));
        assert_eq!(q.len(), 4);
        assert_eq!(q.peak_len(), 5);
    }

    #[test]
    fn compaction_preserves_survivor_order() {
        let mut q = EventQueue::new();
        // interleave live arrivals with stale-to-be copy finishes
        for i in 0..200u32 {
            q.push(i as f64, Event::Arrival(JobId(i)));
            q.push(
                i as f64 + 0.5,
                Event::CopyFinish { task: TaskRef { job: JobId(i), task: 0 }, copy: 0 },
            );
        }
        assert!(!q.should_compact());
        q.note_stale(200);
        assert!(q.should_compact());
        q.retain_live(|e| matches!(e, Event::Arrival(_)));
        assert!(!q.should_compact());
        assert_eq!(q.len(), 200);
        // survivors pop in the original (time, seq) order
        let mut prev = -1.0;
        while let Some((t, e)) = q.pop() {
            assert!(t > prev);
            prev = t;
            assert!(matches!(e, Event::Arrival(_)));
        }
    }

    #[test]
    fn small_heaps_never_compact() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::Arrival(JobId(1)));
        q.note_stale(1);
        assert!(!q.should_compact(), "below the compaction floor");
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(5.0, Event::Arrival(JobId(5)));
        q.push(4.0, Event::Arrival(JobId(4)));
        assert_eq!(q.peek_time(), Some(4.0));
        assert_eq!(q.pop().unwrap().0, 4.0);
        assert_eq!(q.len(), 1);
    }
}
