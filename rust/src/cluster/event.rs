//! Discrete-event queue.  Events are ordered by time (then by a sequence
//! number so simultaneous events process in insertion order, keeping runs
//! deterministic).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::job::{JobId, TaskRef};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A job joins the master queue.
    Arrival(JobId),
    /// A task copy reaches the end of its sampled duration.
    CopyFinish { task: TaskRef, copy: u32 },
    /// A first copy crosses the detection fraction s_i: its true remaining
    /// time becomes visible to the scheduler (straggler checkpoint).
    Checkpoint { task: TaskRef, copy: u32 },
    /// Slot boundary: the scheduler makes its slotted decisions.
    SlotTick,
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest-first
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap of timestamped events.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite(), "event at non-finite time: {event:?}");
        self.seq += 1;
        self.heap.push(Entry { time, seq: self.seq, event });
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::SlotTick);
        q.push(1.0, Event::Arrival(JobId(1)));
        q.push(2.0, Event::Arrival(JobId(2)));
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::Arrival(JobId(10)));
        q.push(1.0, Event::Arrival(JobId(20)));
        match (q.pop().unwrap().1, q.pop().unwrap().1) {
            (Event::Arrival(a), Event::Arrival(b)) => {
                assert_eq!(a, JobId(10));
                assert_eq!(b, JobId(20));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(5.0, Event::SlotTick);
        q.push(4.0, Event::SlotTick);
        assert_eq!(q.peek_time(), Some(4.0));
        assert_eq!(q.pop().unwrap().0, 4.0);
        assert_eq!(q.len(), 1);
    }
}
