//! The MapReduce-like cluster substrate: machines, jobs/tasks/copies, the
//! discrete-event simulator with slotted scheduling, the incrementally
//! maintained scheduler indices ([`index::SchedIndex`]), workload
//! generators and trace I/O.

pub mod event;
pub mod generator;
pub mod index;
pub mod job;
pub mod machine;
pub mod sim;
pub mod trace;

pub use event::{Event, EventQueue, EventQueueKind};
pub use generator::generate;
pub use index::SchedIndex;
pub use job::{CopyPhase, CopyState, JobId, JobPhase, JobSpec, JobState, TaskArena, TaskRef};
pub use machine::{ChurnConfig, MachineClass, MachinePool};
pub use sim::{Cluster, SimResult, Simulator};
