//! Jobs, tasks and task copies — the state machines the simulator drives.
//!
//! A job `J_i` arrives with `m_i` tasks; each task may run several copies
//! (clones or straggler backups); a task completes when its first copy
//! finishes, at which point sibling copies are killed and their machines
//! freed.  A job completes when all its tasks have (Sec. III).
//!
//! ## Arena / SoA storage
//!
//! Task and copy state live in one cluster-owned [`TaskArena`] of flat
//! parallel columns rather than per-job `Vec<TaskState>` allocations: a
//! job's tasks occupy the dense id range `base .. base + num_tasks`
//! (`base` is stored on [`JobState`]), and each task's copies form a
//! short sibling chain (`head`/`next`) through global copy columns
//! (`machine`/`start`/`duration`/`phase`/`revealed`).  Copy *indices*
//! within a task (the `copy: u32` the event queue and machine
//! assignments carry) are chain positions, so the public addressing —
//! `TaskRef` + copy index — is unchanged from the per-job layout.
//!
//! Id-stability invariants (DESIGN.md §13): a task id is stable for the
//! job's entire lifetime, and a copy id is stable for the copy's
//! lifetime; rows are recycled only through [`TaskArena::recycle_tasks`],
//! which the cluster calls only for a `Done` job with no event-queue
//! entries still referencing it (`JobState::stranded == 0`) — and only
//! on the live path, so batch runs are bit-identical to the per-job
//! layout by construction.

use std::collections::BTreeMap;

use crate::stats::Pareto;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

/// Task address: (job, index within job).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskRef {
    pub job: JobId,
    pub task: u32,
}

/// Immutable description of an arriving job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: JobId,
    pub arrival: f64,
    /// Task-duration distribution (common to all the job's tasks, Sec. III).
    pub dist: Pareto,
    pub num_tasks: u32,
}

impl JobSpec {
    /// Total expected workload m_i * E[x^i] — the SRPT ordering key.
    pub fn workload(&self) -> f64 {
        self.num_tasks as f64 * self.dist.mean()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    /// In chi(l): no task has been launched yet.
    Queued,
    /// At least one task launched, not all finished.
    Running,
    Done,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyPhase {
    Running,
    Finished,
    Killed,
}

/// One execution attempt of a task on one machine — a by-value view of
/// one copy row of the [`TaskArena`].
#[derive(Clone, Copy, Debug)]
pub struct CopyState {
    pub machine: u32,
    pub start: f64,
    /// True duration (hidden from schedulers until the detection checkpoint).
    pub duration: f64,
    pub phase: CopyPhase,
    /// Set once the copy has executed `detect_frac` of its work: the
    /// scheduler now knows the true remaining time (the paper's monitoring
    /// model, Eq. 18-19).
    pub revealed: bool,
}

impl CopyState {
    pub fn elapsed(&self, now: f64) -> f64 {
        (now - self.start).max(0.0)
    }

    /// True remaining time (simulator-side knowledge).
    pub fn true_remaining(&self, now: f64) -> f64 {
        (self.duration - self.elapsed(now)).max(0.0)
    }
}

/// Null link / missing row in the arena's chains.
const NONE: u32 = u32::MAX;

/// Cluster-wide structure-of-arrays storage for task and copy state.
///
/// Task columns are indexed by global task id (`JobState::base` + the
/// task's index within its job); copy columns by global copy id.  A
/// task's copies are a singly-linked sibling chain (`head` → `next`),
/// at most `r_max` long (8 in the paper), so positional walks are a few
/// hops through contiguous columns.
#[derive(Clone, Debug, Default)]
pub struct TaskArena {
    // task columns
    done: Vec<bool>,
    /// Completion time; NaN while unfinished.
    finish: Vec<f64>,
    /// First copy id, or `NONE` while unlaunched.
    head: Vec<u32>,
    /// Last copy id (O(1) chain append), or `NONE`.
    tail: Vec<u32>,
    n_copies: Vec<u32>,
    // copy columns
    machine: Vec<u32>,
    start: Vec<f64>,
    duration: Vec<f64>,
    /// Sampled work amount (units of `E[x]`).  `duration` is derived wall
    /// clock (`work / effective speed at launch`, re-timed by flips); the
    /// work itself is flip-invariant and anchors the re-time arithmetic.
    work: Vec<f64>,
    phase: Vec<CopyPhase>,
    revealed: Vec<bool>,
    /// Average delivered throughput (work per wall-clock unit) over the
    /// copy's lifetime, stamped at the detection checkpoint and refreshed
    /// whenever a `SlowdownFlip` re-times the copy; NaN until revealed.
    /// Piecewise-constant between cluster mutations by construction, which
    /// is what keeps the wakeup planner's horizon contract sound for the
    /// observed-speed estimator (DESIGN.md §14).
    obs_speed: Vec<f64>,
    /// Re-time generation: bumped by `Cluster::flip_machine` each time a
    /// `SlowdownFlip` re-times the copy, so older event-queue entries
    /// (which carry the epoch they were pushed with) are recognizably
    /// stale.  0 for copies never re-timed — the only value ever seen when
    /// ON/OFF flips are disabled.
    epoch: Vec<u32>,
    /// The task's authoritative (non-speculative) attempt: chain position 0
    /// at launch, and any relaunch pushed because a machine crash killed
    /// the task's last surviving copy (`Cluster::fail_machine`).  The
    /// "original vs backup" branch points (Mantri's stranded-entry rule,
    /// checkpoint re-pushes, LATE's outstanding-backup gauge) key on this,
    /// not on chain position — without churn the two are identical, which
    /// is the zero-churn bitwise-identity argument.
    primary: Vec<bool>,
    /// Next sibling copy id, or `NONE` at the chain tail.
    next: Vec<u32>,
    /// Recycled copy rows (filled by `recycle_tasks`).
    free_copies: Vec<u32>,
    /// Recycled task ranges, keyed by exact length (job task counts are
    /// small and repeat heavily, so exact-fit reuse suffices).
    free_ranges: BTreeMap<u32, Vec<u32>>,
}

impl TaskArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate `n` contiguous task rows; returns the base id.  Reuses an
    /// exact-length recycled range when one exists.
    pub fn alloc_tasks(&mut self, n: u32) -> u32 {
        if let Some(bases) = self.free_ranges.get_mut(&n) {
            let base = bases.pop().expect("free-range buckets are never empty");
            if bases.is_empty() {
                self.free_ranges.remove(&n);
            }
            return base;
        }
        let base = self.done.len() as u32;
        let nn = n as usize;
        self.done.resize(self.done.len() + nn, false);
        self.finish.resize(self.finish.len() + nn, f64::NAN);
        self.head.resize(self.head.len() + nn, NONE);
        self.tail.resize(self.tail.len() + nn, NONE);
        self.n_copies.resize(self.n_copies.len() + nn, 0);
        base
    }

    /// Return a job's task range (and its copy chains) to the free lists.
    /// The caller must guarantee nothing references these rows any more —
    /// see the id-stability invariants in the module docs.
    pub fn recycle_tasks(&mut self, base: u32, n: u32) {
        for tid in base..base + n {
            let i = tid as usize;
            let mut cid = self.head[i];
            while cid != NONE {
                let nxt = self.next[cid as usize];
                self.free_copies.push(cid);
                cid = nxt;
            }
            self.done[i] = false;
            self.finish[i] = f64::NAN;
            self.head[i] = NONE;
            self.tail[i] = NONE;
            self.n_copies[i] = 0;
        }
        if n > 0 {
            self.free_ranges.entry(n).or_default().push(base);
        }
    }

    /// Total task rows ever allocated (capacity metric).
    pub fn task_rows(&self) -> usize {
        self.done.len()
    }

    /// Total copy rows ever allocated (capacity metric).
    pub fn copy_rows(&self) -> usize {
        self.phase.len()
    }

    // ----- task queries ---------------------------------------------------

    #[inline]
    pub fn done(&self, tid: u32) -> bool {
        self.done[tid as usize]
    }

    /// Completion time, once done.
    pub fn finish(&self, tid: u32) -> Option<f64> {
        let f = self.finish[tid as usize];
        if f.is_nan() {
            None
        } else {
            Some(f)
        }
    }

    #[inline]
    pub fn launched(&self, tid: u32) -> bool {
        self.head[tid as usize] != NONE
    }

    #[inline]
    pub fn n_copies(&self, tid: u32) -> u32 {
        self.n_copies[tid as usize]
    }

    /// Global copy id of the task's `k`-th copy (chain position == the
    /// copy index carried by events and machine assignments).
    #[inline]
    pub fn copy_id(&self, tid: u32, k: u32) -> u32 {
        let mut cid = self.head[tid as usize];
        for _ in 0..k {
            cid = self.next[cid as usize];
        }
        cid
    }

    /// The task's copy ids in launch (chain) order.
    pub fn copies(&self, tid: u32) -> impl Iterator<Item = u32> + '_ {
        let mut cid = self.head[tid as usize];
        std::iter::from_fn(move || {
            if cid == NONE {
                None
            } else {
                let c = cid;
                cid = self.next[c as usize];
                Some(c)
            }
        })
    }

    pub fn running_copies(&self, tid: u32) -> usize {
        self.copies(tid).filter(|&c| self.phase[c as usize] == CopyPhase::Running).count()
    }

    // ----- task mutations -------------------------------------------------

    pub fn set_done(&mut self, tid: u32, now: f64) {
        self.done[tid as usize] = true;
        self.finish[tid as usize] = now;
    }

    /// Append a running copy to the task's chain; returns its copy index
    /// (chain position).
    pub fn push_copy(&mut self, tid: u32, machine: u32, start: f64, duration: f64, work: f64) -> u32 {
        let cid = match self.free_copies.pop() {
            Some(c) => {
                let i = c as usize;
                self.machine[i] = machine;
                self.start[i] = start;
                self.duration[i] = duration;
                self.work[i] = work;
                self.phase[i] = CopyPhase::Running;
                self.revealed[i] = false;
                self.obs_speed[i] = f64::NAN;
                self.epoch[i] = 0;
                self.primary[i] = false;
                self.next[i] = NONE;
                c
            }
            None => {
                let c = self.phase.len() as u32;
                self.machine.push(machine);
                self.start.push(start);
                self.duration.push(duration);
                self.work.push(work);
                self.phase.push(CopyPhase::Running);
                self.revealed.push(false);
                self.obs_speed.push(f64::NAN);
                self.epoch.push(0);
                self.primary.push(false);
                self.next.push(NONE);
                c
            }
        };
        let i = tid as usize;
        let k = self.n_copies[i];
        // chain position 0 is the task's original attempt; crash relaunches
        // (chain position > 0) re-mark themselves via `set_primary`
        self.primary[cid as usize] = k == 0;
        if self.head[i] == NONE {
            self.head[i] = cid;
        } else {
            self.next[self.tail[i] as usize] = cid;
        }
        self.tail[i] = cid;
        self.n_copies[i] = k + 1;
        k
    }

    // ----- copy accessors (by global copy id) ----------------------------

    /// By-value view of one copy row.
    #[inline]
    pub fn copy(&self, cid: u32) -> CopyState {
        let i = cid as usize;
        CopyState {
            machine: self.machine[i],
            start: self.start[i],
            duration: self.duration[i],
            phase: self.phase[i],
            revealed: self.revealed[i],
        }
    }

    /// By-value view of the task's `k`-th copy.
    #[inline]
    pub fn copy_at(&self, tid: u32, k: u32) -> CopyState {
        self.copy(self.copy_id(tid, k))
    }

    #[inline]
    pub fn phase(&self, cid: u32) -> CopyPhase {
        self.phase[cid as usize]
    }

    #[inline]
    pub fn set_phase(&mut self, cid: u32, phase: CopyPhase) {
        self.phase[cid as usize] = phase;
    }

    #[inline]
    pub fn revealed(&self, cid: u32) -> bool {
        self.revealed[cid as usize]
    }

    #[inline]
    pub fn set_revealed(&mut self, cid: u32) {
        self.revealed[cid as usize] = true;
    }

    #[inline]
    pub fn machine(&self, cid: u32) -> u32 {
        self.machine[cid as usize]
    }

    #[inline]
    pub fn duration(&self, cid: u32) -> f64 {
        self.duration[cid as usize]
    }

    /// Overwrite a copy's total wall-clock duration — the `SlowdownFlip`
    /// re-time mutation (`Cluster::flip_machine`).  The copy's `start` is
    /// unchanged; machine-time accounting stays consistent because
    /// `copy_finished` / `kill_copy` read this (re-timed) duration.
    #[inline]
    pub fn set_duration(&mut self, cid: u32, duration: f64) {
        self.duration[cid as usize] = duration;
    }

    #[inline]
    pub fn start(&self, cid: u32) -> f64 {
        self.start[cid as usize]
    }

    /// The copy's sampled work amount (flip-invariant; see the column doc).
    #[inline]
    pub fn work(&self, cid: u32) -> f64 {
        self.work[cid as usize]
    }

    /// Stamped lifetime-average throughput; NaN until revealed.
    #[inline]
    pub fn obs_speed(&self, cid: u32) -> f64 {
        self.obs_speed[cid as usize]
    }

    #[inline]
    pub fn set_obs_speed(&mut self, cid: u32, v: f64) {
        self.obs_speed[cid as usize] = v;
    }

    /// Current re-time generation of a copy (0 unless a `SlowdownFlip` has
    /// re-timed it).
    #[inline]
    pub fn epoch(&self, cid: u32) -> u32 {
        self.epoch[cid as usize]
    }

    /// Bump the copy's re-time generation, invalidating every event-queue
    /// entry pushed with the old epoch; returns the new epoch (the value to
    /// stamp on the re-inserted events).
    #[inline]
    pub fn bump_epoch(&mut self, cid: u32) -> u32 {
        let i = cid as usize;
        self.epoch[i] += 1;
        self.epoch[i]
    }

    /// Whether the copy is the task's authoritative attempt (see the
    /// `primary` column doc).  Without churn this is exactly "chain
    /// position 0".
    #[inline]
    pub fn primary(&self, cid: u32) -> bool {
        self.primary[cid as usize]
    }

    /// Mark a crash relaunch as the task's new authoritative attempt
    /// (`Cluster::fail_machine` relaunches after the last surviving copy
    /// died, so the new copy inherits original-attempt semantics).
    #[inline]
    pub fn set_primary(&mut self, cid: u32) {
        self.primary[cid as usize] = true;
    }
}

/// Mutable per-job state.  Task/copy state lives in the cluster's
/// [`TaskArena`]; the job carries only its `base` id into it.
#[derive(Clone, Debug)]
pub struct JobState {
    pub spec: JobSpec,
    pub phase: JobPhase,
    /// First row of this job's task range in the [`TaskArena`] (tasks
    /// occupy `base .. base + spec.num_tasks`).
    pub base: u32,
    /// Index of the first task with no copies yet (tasks launch in order).
    pub next_unlaunched: u32,
    /// Tasks not yet completed.
    pub unfinished: u32,
    /// Time the first task was launched (w_i in the paper).
    pub first_sched: Option<f64>,
    pub finish: Option<f64>,
    /// Machine-time consumed by all copies (resource, before gamma scaling).
    pub machine_time: f64,
    /// Dead event-queue entries (killed copies' pending `CopyFinish` /
    /// `Checkpoint`) still referencing this job's tasks — they leave by
    /// popping as no-ops or by compaction.  The arena-recycle guard: a
    /// `Done` job's rows may be reused only at zero.
    pub stranded: u32,
    /// Copies of this job's tasks killed by machine crashes
    /// (`Cluster::fail_machine`); 0 without churn.
    pub copies_lost: u32,
    /// Wall-clock already sunk into those crashed copies (the work the
    /// paper's restart-from-zero failure model throws away).  Counted into
    /// `machine_time` too — lost work still occupied a machine.
    pub work_lost: f64,
}

impl JobState {
    pub fn new(spec: JobSpec, base: u32) -> Self {
        JobState {
            phase: JobPhase::Queued,
            base,
            next_unlaunched: 0,
            unfinished: spec.num_tasks,
            first_sched: None,
            finish: None,
            machine_time: 0.0,
            stranded: 0,
            copies_lost: 0,
            work_lost: 0.0,
            spec,
        }
    }

    /// Global arena id of this job's `task`-th task.
    #[inline]
    pub fn tid(&self, task: u32) -> u32 {
        self.base + task
    }

    /// Tasks that still need a first copy.
    pub fn unlaunched(&self) -> u32 {
        self.spec.num_tasks - self.next_unlaunched
    }

    /// Remaining workload (`#unfinished tasks * E[x]`) — the priority key of
    /// the smallest-remaining-first levels in SCA/SDA/ESE.
    pub fn remaining_workload(&self) -> f64 {
        self.unfinished as f64 * self.spec.dist.mean()
    }

    pub fn flowtime(&self) -> Option<f64> {
        self.finish.map(|f| f - self.spec.arrival)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(id: u32, m: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            arrival: 1.0,
            dist: Pareto::from_mean(2.0, 2.0),
            num_tasks: m,
        }
    }

    #[test]
    fn new_job_is_queued() {
        let mut arena = TaskArena::new();
        let base = arena.alloc_tasks(5);
        let j = JobState::new(spec(0, 5), base);
        assert_eq!(j.phase, JobPhase::Queued);
        assert_eq!(j.unfinished, 5);
        assert_eq!(j.unlaunched(), 5);
        assert!(j.flowtime().is_none());
        for t in 0..5 {
            assert!(!arena.done(j.tid(t)));
            assert!(!arena.launched(j.tid(t)));
            assert_eq!(arena.finish(j.tid(t)), None);
        }
    }

    #[test]
    fn workload_key() {
        let j = JobState::new(spec(0, 10), 0);
        assert!((j.spec.workload() - 20.0).abs() < 1e-12);
        assert!((j.remaining_workload() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn copy_elapsed_remaining() {
        let c = CopyState {
            machine: 0,
            start: 2.0,
            duration: 5.0,
            phase: CopyPhase::Running,
            revealed: false,
        };
        assert_eq!(c.elapsed(4.0), 2.0);
        assert_eq!(c.true_remaining(4.0), 3.0);
        assert_eq!(c.true_remaining(100.0), 0.0);
    }

    #[test]
    fn arena_copy_chains_keep_launch_order() {
        let mut arena = TaskArena::new();
        let base = arena.alloc_tasks(2);
        assert_eq!(arena.push_copy(base, 7, 1.0, 5.0, 5.0), 0);
        assert_eq!(arena.push_copy(base + 1, 8, 1.5, 2.0, 2.0), 0);
        assert_eq!(arena.push_copy(base, 9, 2.0, 4.0, 4.0), 1);
        assert_eq!(arena.n_copies(base), 2);
        assert_eq!(arena.n_copies(base + 1), 1);
        let c0 = arena.copy_at(base, 0);
        let c1 = arena.copy_at(base, 1);
        assert_eq!((c0.machine, c0.start), (7, 1.0));
        assert_eq!((c1.machine, c1.start), (9, 2.0));
        assert_eq!(arena.copies(base).count(), 2);
        assert_eq!(arena.running_copies(base), 2);
        arena.set_phase(arena.copy_id(base, 1), CopyPhase::Killed);
        assert_eq!(arena.running_copies(base), 1);
        assert!(!arena.revealed(arena.copy_id(base, 0)));
        arena.set_revealed(arena.copy_id(base, 0));
        assert!(arena.copy_at(base, 0).revealed);
    }

    #[test]
    fn copy_epoch_and_duration_retime() {
        let mut arena = TaskArena::new();
        let base = arena.alloc_tasks(1);
        arena.push_copy(base, 3, 1.0, 5.0, 5.0);
        let cid = arena.copy_id(base, 0);
        assert_eq!(arena.epoch(cid), 0);
        assert_eq!(arena.bump_epoch(cid), 1);
        assert_eq!(arena.bump_epoch(cid), 2);
        assert_eq!(arena.epoch(cid), 2);
        arena.set_duration(cid, 9.0);
        assert_eq!(arena.duration(cid), 9.0);
        assert_eq!(arena.start(cid), 1.0, "re-time keeps the start");
        assert_eq!(arena.work(cid), 5.0, "re-time never touches the work");
        assert!(arena.obs_speed(cid).is_nan(), "no throughput stamp before reveal");
        arena.set_obs_speed(cid, 0.25);
        assert_eq!(arena.obs_speed(cid), 0.25);
    }

    #[test]
    fn primary_tracks_original_then_relaunch() {
        let mut arena = TaskArena::new();
        let base = arena.alloc_tasks(1);
        arena.push_copy(base, 0, 0.0, 5.0, 5.0);
        arena.push_copy(base, 1, 1.0, 5.0, 5.0);
        assert!(arena.primary(arena.copy_id(base, 0)), "chain head is the original");
        assert!(!arena.primary(arena.copy_id(base, 1)), "backups are speculative");
        // a crash relaunch is re-marked authoritative by the caller
        arena.push_copy(base, 2, 2.0, 5.0, 5.0);
        let relaunch = arena.copy_id(base, 2);
        assert!(!arena.primary(relaunch));
        arena.set_primary(relaunch);
        assert!(arena.primary(relaunch));
        // recycled rows never leak a stale primary mark
        arena.set_done(base, 3.0);
        arena.recycle_tasks(base, 1);
        let again = arena.alloc_tasks(1);
        assert_eq!(again, base);
        arena.push_copy(again, 3, 4.0, 1.0, 1.0);
        arena.push_copy(again, 4, 4.5, 1.0, 1.0);
        assert!(arena.primary(arena.copy_id(again, 0)));
        assert!(!arena.primary(arena.copy_id(again, 1)));
    }

    #[test]
    fn arena_done_and_finish() {
        let mut arena = TaskArena::new();
        let base = arena.alloc_tasks(1);
        assert_eq!(arena.finish(base), None);
        arena.set_done(base, 3.5);
        assert!(arena.done(base));
        assert_eq!(arena.finish(base), Some(3.5));
    }

    #[test]
    fn recycled_ranges_and_copies_are_reused() {
        let mut arena = TaskArena::new();
        let a = arena.alloc_tasks(3);
        let b = arena.alloc_tasks(5);
        arena.push_copy(a, 0, 0.0, 1.0, 1.0);
        arena.push_copy(a + 2, 1, 0.0, 1.0, 1.0);
        arena.bump_epoch(arena.copy_id(a, 0));
        arena.set_obs_speed(arena.copy_id(a, 0), 0.5);
        arena.set_done(a, 1.0);
        let rows = arena.task_rows();
        let copies = arena.copy_rows();
        arena.recycle_tasks(a, 3);
        // exact-length reuse, fully reset
        let c = arena.alloc_tasks(3);
        assert_eq!(c, a);
        assert_eq!(arena.task_rows(), rows, "no new task rows");
        for t in c..c + 3 {
            assert!(!arena.done(t));
            assert!(!arena.launched(t));
            assert_eq!(arena.n_copies(t), 0);
        }
        // recycled copy rows come back before new ones are grown
        arena.push_copy(c, 4, 2.0, 1.0, 1.0);
        arena.push_copy(c + 1, 5, 2.0, 1.0, 1.0);
        assert_eq!(arena.copy_rows(), copies, "no new copy rows");
        // reused rows come back at epoch 0 even if re-timed before recycling,
        // and without a stale throughput stamp
        assert_eq!(arena.epoch(arena.copy_id(c, 0)), 0);
        assert_eq!(arena.epoch(arena.copy_id(c + 1, 0)), 0);
        assert!(arena.obs_speed(arena.copy_id(c, 0)).is_nan());
        // a different length allocates fresh rows
        let d = arena.alloc_tasks(4);
        assert_eq!(d as usize, rows);
        let _ = b;
    }
}
