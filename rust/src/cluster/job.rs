//! Jobs, tasks and task copies — the state machines the simulator drives.
//!
//! A job `J_i` arrives with `m_i` tasks; each task may run several copies
//! (clones or straggler backups); a task completes when its first copy
//! finishes, at which point sibling copies are killed and their machines
//! freed.  A job completes when all its tasks have (Sec. III).

use crate::stats::Pareto;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

/// Task address: (job, index within job).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskRef {
    pub job: JobId,
    pub task: u32,
}

/// Immutable description of an arriving job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: JobId,
    pub arrival: f64,
    /// Task-duration distribution (common to all the job's tasks, Sec. III).
    pub dist: Pareto,
    pub num_tasks: u32,
}

impl JobSpec {
    /// Total expected workload m_i * E[x^i] — the SRPT ordering key.
    pub fn workload(&self) -> f64 {
        self.num_tasks as f64 * self.dist.mean()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    /// In chi(l): no task has been launched yet.
    Queued,
    /// At least one task launched, not all finished.
    Running,
    Done,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyPhase {
    Running,
    Finished,
    Killed,
}

/// One execution attempt of a task on one machine.
#[derive(Clone, Copy, Debug)]
pub struct CopyState {
    pub machine: u32,
    pub start: f64,
    /// True duration (hidden from schedulers until the detection checkpoint).
    pub duration: f64,
    pub phase: CopyPhase,
    /// Set once the copy has executed `detect_frac` of its work: the
    /// scheduler now knows the true remaining time (the paper's monitoring
    /// model, Eq. 18-19).
    pub revealed: bool,
}

impl CopyState {
    pub fn elapsed(&self, now: f64) -> f64 {
        (now - self.start).max(0.0)
    }

    /// True remaining time (simulator-side knowledge).
    pub fn true_remaining(&self, now: f64) -> f64 {
        (self.duration - self.elapsed(now)).max(0.0)
    }
}

/// Mutable per-task state.
#[derive(Clone, Debug, Default)]
pub struct TaskState {
    pub copies: Vec<CopyState>,
    pub done: bool,
    /// Completion time, once done.
    pub finish: Option<f64>,
}

impl TaskState {
    pub fn launched(&self) -> bool {
        !self.copies.is_empty()
    }

    pub fn running_copies(&self) -> usize {
        self.copies.iter().filter(|c| c.phase == CopyPhase::Running).count()
    }
}

/// Mutable per-job state.
#[derive(Clone, Debug)]
pub struct JobState {
    pub spec: JobSpec,
    pub phase: JobPhase,
    pub tasks: Vec<TaskState>,
    /// Index of the first task with no copies yet (tasks launch in order).
    pub next_unlaunched: u32,
    /// Tasks not yet completed.
    pub unfinished: u32,
    /// Time the first task was launched (w_i in the paper).
    pub first_sched: Option<f64>,
    pub finish: Option<f64>,
    /// Machine-time consumed by all copies (resource, before gamma scaling).
    pub machine_time: f64,
}

impl JobState {
    pub fn new(spec: JobSpec) -> Self {
        let n = spec.num_tasks as usize;
        JobState {
            phase: JobPhase::Queued,
            tasks: vec![TaskState::default(); n],
            next_unlaunched: 0,
            unfinished: spec.num_tasks,
            first_sched: None,
            finish: None,
            machine_time: 0.0,
            spec,
        }
    }

    /// Tasks that still need a first copy.
    pub fn unlaunched(&self) -> u32 {
        self.spec.num_tasks - self.next_unlaunched
    }

    /// Remaining workload (`#unfinished tasks * E[x]`) — the priority key of
    /// the smallest-remaining-first levels in SCA/SDA/ESE.
    pub fn remaining_workload(&self) -> f64 {
        self.unfinished as f64 * self.spec.dist.mean()
    }

    pub fn flowtime(&self) -> Option<f64> {
        self.finish.map(|f| f - self.spec.arrival)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(id: u32, m: u32) -> JobSpec {
        JobSpec {
            id: JobId(id),
            arrival: 1.0,
            dist: Pareto::from_mean(2.0, 2.0),
            num_tasks: m,
        }
    }

    #[test]
    fn new_job_is_queued() {
        let j = JobState::new(spec(0, 5));
        assert_eq!(j.phase, JobPhase::Queued);
        assert_eq!(j.unfinished, 5);
        assert_eq!(j.unlaunched(), 5);
        assert!(j.flowtime().is_none());
    }

    #[test]
    fn workload_key() {
        let j = JobState::new(spec(0, 10));
        assert!((j.spec.workload() - 20.0).abs() < 1e-12);
        assert!((j.remaining_workload() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn copy_elapsed_remaining() {
        let c = CopyState {
            machine: 0,
            start: 2.0,
            duration: 5.0,
            phase: CopyPhase::Running,
            revealed: false,
        };
        assert_eq!(c.elapsed(4.0), 2.0);
        assert_eq!(c.true_remaining(4.0), 3.0);
        assert_eq!(c.true_remaining(100.0), 0.0);
    }
}
