//! `SchedIndex` — incrementally-maintained scheduler indices, so every
//! slotted decision costs O(what changed), not O(everything running).
//!
//! The paper's regimes of interest (thousands of machines, λ near the ESE
//! threshold, long horizons) are exactly the expensive ones to simulate:
//! before this subsystem every slot re-scanned *all tasks of all running
//! jobs* (Mantri/LATE/ESE duplicate rules) and re-collected + re-sorted
//! the job orderings (`Cluster::chi_sorted`, SRPT level 2) from scratch.
//! The index keeps three structures current at the `Cluster` mutation
//! points instead:
//!
//! 1. **Speculation candidates** — per job, the tasks whose only copy is a
//!    running *first* copy, split into unrevealed / revealed (the `s_i`
//!    checkpoint state).  Mantri, LATE and ESE iterate only these; a task
//!    with a backup, a finished task, or an unlaunched task never appears.
//! 2. **Level-2 ordering** — the running jobs that still have unlaunched
//!    tasks, ordered by the paper's mean-field remaining workload
//!    `#unfinished · E[x]` (ties by `JobId`), plus the same membership in
//!    plain id order for the FIFO baselines.
//! 3. **Level-3 ordering** — the queued jobs χ(l) ordered by total
//!    workload `m_i · E[x]` (ties by `JobId`), plus a running total of
//!    queued tasks (the live master's backpressure signal).
//!
//! ## The bit-identical-behavior invariant
//!
//! Index-driven scheduling must make **exactly** the decisions the naive
//! scans make: the same copies launched in the same order with the same
//! tie-breaks.  Three facts deliver that:
//!
//! * candidate iteration yields ascending task indices per job (an
//!   allocation-free merge of the two disjoint sorted splits), and
//!   schedulers visit jobs in the same ascending-`JobId` order as before;
//! * the ordered job sets are [`SortedSet`]s of `(F64Key, JobId)` with
//!   [`f64::total_cmp`] key order — identical to a *stable* sort by
//!   `total_cmp` over an id-ordered collection, which is what the scan
//!   paths do (a sorted vec and a `BTreeSet` iterate the same `Ord`
//!   order, so swapping the container cannot change a decision);
//! * keys are recomputed from the same pure functions
//!   (`JobState::remaining_workload`, `JobSpec::workload`) at every
//!   mutation, and mutations only happen between queries (event handling
//!   and launches never interleave with an in-progress ordering scan —
//!   schedulers snapshot the order into a reused scratch buffer first).
//!
//! The scan implementations are **retained** (`SimConfig::sched_index =
//! false`) as the equivalence reference; `tests/experiment_integration.rs`
//! proves byte-identical `sweep_csv` output across every policy and
//! scenario axis.  See `rust/DESIGN.md` §10 for the full contract table
//! (which mutation updates which index).
//!
//! The same mutation points also raise the wakeup planner's
//! [`Cluster::sched_dirty`](super::sim::Cluster::sched_dirty) flag
//! (independently of `sched_index`, a bare bool store): the index makes
//! a fired slot cost O(active), the planner makes a quiet slot not fire
//! at all — see `rust/DESIGN.md` §12.

use std::cmp::Ordering;

use super::job::{CopyPhase, JobId, JobPhase, JobState, TaskArena, TaskRef};

/// An `f64` ordered by [`f64::total_cmp`] so it can key an ordered set.
/// Matches the NaN-safe `total_cmp` sorts used by the scan reference
/// paths, so index order and scan order agree on every input.  Equality
/// is defined through the same total order (NOT `f64::eq`: `-0.0` and
/// `0.0` are distinct keys, NaN equals itself) to keep the `Ord`
/// contract consistent.
#[derive(Clone, Copy, Debug)]
pub struct F64Key(pub f64);

impl PartialEq for F64Key {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for F64Key {}

impl Ord for F64Key {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl PartialOrd for F64Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// An ordered set backed by a flat sorted `Vec`: binary-search membership,
/// `memmove` insert/remove, ascending in-place iteration.  The measured
/// pass over `SchedIndex` churn (DESIGN.md §13) showed mutation rate
/// dominating lookups at bench scale, where a contiguous shift of a few
/// hundred small elements beats a `BTreeSet`'s node allocation and
/// pointer-chasing on every re-key — and iteration (the per-slot query
/// path) becomes a linear scan of one cache-friendly slice.  Iterates in
/// exactly the `Ord` order a `BTreeSet` would, which is what keeps the
/// container swap bit-identical.
#[derive(Clone, Debug)]
pub(crate) struct SortedSet<T: Ord> {
    items: Vec<T>,
}

impl<T: Ord> Default for SortedSet<T> {
    fn default() -> Self {
        SortedSet { items: Vec::new() }
    }
}

impl<T: Ord> SortedSet<T> {
    /// Insert, keeping sort order; false if already present.
    fn insert(&mut self, x: T) -> bool {
        match self.items.binary_search(&x) {
            Ok(_) => false,
            Err(i) => {
                self.items.insert(i, x);
                true
            }
        }
    }

    /// Remove; false if absent.
    fn remove(&mut self, x: &T) -> bool {
        match self.items.binary_search(x) {
            Ok(i) => {
                self.items.remove(i);
                true
            }
            Err(_) => false,
        }
    }

    fn iter(&self) -> std::slice::Iter<'_, T> {
        self.items.iter()
    }

    fn as_slice(&self) -> &[T] {
        &self.items
    }

    fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Ascending merge of two disjoint sorted `u32` slices — the union the
/// old `BTreeSet` layout got from `BTreeSet::union`, allocation-free.
struct MergeAsc<'a> {
    a: &'a [u32],
    b: &'a [u32],
}

impl Iterator for MergeAsc<'_> {
    type Item = u32;

    fn next(&mut self) -> Option<u32> {
        match (self.a.first(), self.b.first()) {
            (Some(&x), Some(&y)) if x <= y => {
                self.a = &self.a[1..];
                Some(x)
            }
            (_, Some(&y)) => {
                self.b = &self.b[1..];
                Some(y)
            }
            (Some(&x), None) => {
                self.a = &self.a[1..];
                Some(x)
            }
            (None, None) => None,
        }
    }
}

/// Per-job slice of the index.
#[derive(Clone, Debug, Default)]
struct JobIndex {
    /// Tasks whose only copy is a running first copy that has not crossed
    /// its detection checkpoint.  Disjoint from `revealed`.
    unrevealed: SortedSet<u32>,
    /// Tasks whose only copy is a running, checkpoint-revealed first copy.
    revealed: SortedSet<u32>,
    /// The key under which the job currently sits in the level-2 set
    /// (`None` = not a member).  Stored so a stale entry can be removed
    /// when the remaining workload changes.
    level2_key: Option<F64Key>,
    /// Membership in the queued-by-workload set (key is the static total
    /// workload, so it needs no stored copy).
    in_queued: bool,
    /// Per-task contributions to the estimate-driven level-2 key
    /// (`estimator::revealed_task_workload` values; empty unless the
    /// index tracks est keys).
    est_contrib: Vec<f64>,
    /// Ordered sum of `est_contrib` — the est-keyed level-2 key.  Always
    /// recomputed as the in-order sum so it is bit-identical to the scan
    /// path's fresh summation (float addition order matters).
    est_sum: f64,
    /// Membership key in the est-keyed level-2 twin (`None` = not a
    /// member).
    est_key: Option<F64Key>,
}

/// Incremental indices over one [`Cluster`](super::sim::Cluster)'s jobs.
/// Maintained by the cluster's mutation points — and, like the queries,
/// only when `SimConfig::sched_index` is on (the default), so the `false`
/// setting reproduces the true pre-index code: scans only, no upkeep.
/// The benchmark's indexed-vs-scan speedup is therefore measured against
/// a genuine baseline, not a scan that still pays maintenance.
#[derive(Clone, Debug, Default)]
pub struct SchedIndex {
    jobs: Vec<JobIndex>,
    /// Running jobs with unlaunched tasks, by (remaining workload, id) —
    /// the SRPT level-2 order.
    level2: SortedSet<(F64Key, JobId)>,
    /// Same membership as `level2`, in plain id (= arrival) order — the
    /// Mantri/LATE FIFO baselines.
    level2_fifo: SortedSet<JobId>,
    /// Same membership as `level2`, keyed by the estimate-driven
    /// reveal-refined workload (`estimator::revealed_job_workload`) — the
    /// `est-srpt` ordering.  Maintained only when [`track_est_keys`]
    /// enabled it (an est-srpt pipeline is active); zero upkeep otherwise.
    ///
    /// [`track_est_keys`]: Self::track_est_keys
    level2_est: SortedSet<(F64Key, JobId)>,
    /// Whether the est-keyed twin (and the per-job contribution vectors)
    /// are maintained.
    track_est: bool,
    /// Queued jobs by (total workload, id) — the χ(l) level-3 order.
    queued: SortedSet<(F64Key, JobId)>,
    /// Total unlaunched tasks over the queued jobs (backpressure signal).
    queued_tasks: usize,
    /// Reused job-id buffer for slot hooks (snapshot an ordering, then
    /// launch against it without re-allocating every slot).
    scratch: Vec<JobId>,
}

impl SchedIndex {
    /// An index for `n` not-yet-arrived jobs (batch mode pre-loads the
    /// whole trace; live mode starts at 0 and [`push_job`](Self::push_job)s).
    pub fn new(n: usize) -> Self {
        SchedIndex { jobs: vec![JobIndex::default(); n], ..SchedIndex::default() }
    }

    /// Register one more job slot (live-mode `Cluster::add_job`).
    pub fn push_job(&mut self) {
        self.jobs.push(JobIndex::default());
    }

    /// Enable the estimate-driven level-2 twin (the `est-srpt` ordering).
    /// Must be called before any job arrives; when off (the default) the
    /// est structures cost nothing.
    pub fn track_est_keys(&mut self) {
        debug_assert!(self.queued.is_empty() && self.level2.is_empty());
        self.track_est = true;
    }

    /// Is the est-keyed twin maintained?  The cluster's mutation points
    /// gate their re-key calls on this.
    pub fn tracks_est(&self) -> bool {
        self.track_est
    }

    // ----- mutation hooks (called by Cluster) ----------------------------

    /// The job joined χ(l) (its `Arrival` event fired / live submission).
    pub fn job_arrived(&mut self, job: &JobState) {
        let ji = &mut self.jobs[job.spec.id.0 as usize];
        debug_assert!(!ji.in_queued, "job {:?} arrived twice", job.spec.id);
        ji.in_queued = true;
        if self.track_est {
            // nothing launched, nothing revealed: every task contributes
            // E[x] (the same in-order sum the scan path computes)
            ji.est_contrib = vec![job.spec.dist.mean(); job.spec.num_tasks as usize];
            ji.est_sum = ji.est_contrib.iter().sum();
        }
        self.queued.insert((F64Key(job.spec.workload()), job.spec.id));
        self.queued_tasks += job.spec.num_tasks as usize;
    }

    /// Re-key hook for the estimate-driven ordering: task `t`'s
    /// contribution to the job's reveal-refined workload changed (a
    /// checkpoint reveal, a kill, a completion).  The cluster computes
    /// `contrib` via `estimator::revealed_task_workload` — the same pure
    /// function the scan path sums — and the stored per-task vector is
    /// re-summed **in task order** so index key and scan key are
    /// bit-identical.  No-op unless est tracking is on.
    pub fn set_est_contrib(&mut self, t: TaskRef, contrib: f64) {
        if !self.track_est {
            return;
        }
        let id = t.job;
        let ji = &mut self.jobs[id.0 as usize];
        // bit-equal contribution ⇒ identical sum: skip the O(m) re-sum.
        // Most mutations hit this (launches and kills of unrevealed
        // copies leave the task at E[x]), keeping est upkeep O(changes)
        // rather than O(m) per event.
        if ji.est_contrib[t.task as usize].to_bits() == contrib.to_bits() {
            return;
        }
        ji.est_contrib[t.task as usize] = contrib;
        ji.est_sum = ji.est_contrib.iter().sum();
        if let Some(old) = ji.est_key {
            let key = F64Key(ji.est_sum);
            if old != key {
                self.level2_est.remove(&(old, id));
                self.level2_est.insert((key, id));
                ji.est_key = Some(key);
            }
        }
    }

    /// Re-derive the task's speculation-candidate status from its arena
    /// state.  Call after any mutation of the task's copies (launch, kill,
    /// finish, checkpoint reveal).
    pub fn sync_task(&mut self, job: &JobState, arena: &TaskArena, t: TaskRef) {
        let tid = job.tid(t.task);
        let ji = &mut self.jobs[t.job.0 as usize];
        if !arena.done(tid) && arena.n_copies(tid) == 1 {
            let cid = arena.copy_id(tid, 0);
            if arena.phase(cid) == CopyPhase::Running {
                if arena.revealed(cid) {
                    ji.unrevealed.remove(&t.task);
                    ji.revealed.insert(t.task);
                } else {
                    ji.revealed.remove(&t.task);
                    ji.unrevealed.insert(t.task);
                }
                return;
            }
        }
        ji.unrevealed.remove(&t.task);
        ji.revealed.remove(&t.task);
    }

    /// Re-derive the job's membership in the ordered sets from its phase,
    /// launch progress and remaining workload.  Call after any mutation
    /// that can change them (first-copy launch, task completion).
    pub fn sync_job(&mut self, job: &JobState) {
        let id = job.spec.id;
        let ji = &mut self.jobs[id.0 as usize];
        // leave χ(l) when the first task launches
        if ji.in_queued && job.phase != JobPhase::Queued {
            ji.in_queued = false;
            self.queued.remove(&(F64Key(job.spec.workload()), id));
            self.queued_tasks -= job.spec.num_tasks as usize;
        }
        // level-2 membership: running with unlaunched tasks, keyed by the
        // mean-field remaining workload (see RemainingTime::job_remaining_work)
        let want = job.phase == JobPhase::Running && job.unlaunched() > 0;
        let key = F64Key(job.remaining_workload());
        match (ji.level2_key, want) {
            (Some(old), true) if old == key => {}
            (Some(old), true) => {
                self.level2.remove(&(old, id));
                self.level2.insert((key, id));
                ji.level2_key = Some(key);
            }
            (Some(old), false) => {
                self.level2.remove(&(old, id));
                self.level2_fifo.remove(&id);
                ji.level2_key = None;
            }
            (None, true) => {
                self.level2.insert((key, id));
                self.level2_fifo.insert(id);
                ji.level2_key = Some(key);
            }
            (None, false) => {}
        }
        // est-keyed twin: same membership, reveal-refined key (the key
        // itself is kept current by set_est_contrib)
        if self.track_est {
            let ji = &mut self.jobs[id.0 as usize];
            let key = F64Key(ji.est_sum);
            match (ji.est_key, want) {
                (Some(old), true) if old == key => {}
                (Some(old), true) => {
                    self.level2_est.remove(&(old, id));
                    self.level2_est.insert((key, id));
                    ji.est_key = Some(key);
                }
                (Some(old), false) => {
                    self.level2_est.remove(&(old, id));
                    ji.est_key = None;
                }
                (None, true) => {
                    self.level2_est.insert((key, id));
                    ji.est_key = Some(key);
                }
                (None, false) => {}
            }
        }
    }

    // ----- queries (the O(active) replacements for the scans) ------------

    /// The job's speculation candidates in ascending task order: tasks
    /// whose only copy is a running first copy (revealed or not).  This is
    /// exactly the set the Mantri/LATE/ESE duplicate rules filter out of a
    /// full task scan.
    pub fn candidates(&self, id: JobId) -> impl Iterator<Item = u32> + '_ {
        let ji = &self.jobs[id.0 as usize];
        MergeAsc { a: ji.unrevealed.as_slice(), b: ji.revealed.as_slice() }
    }

    /// The job's *revealed* candidates only (ascending) — the subset whose
    /// estimates are post-checkpoint truth.
    pub fn revealed_candidates(&self, id: JobId) -> impl Iterator<Item = u32> + '_ {
        self.jobs[id.0 as usize].revealed.iter().copied()
    }

    /// The job's *unrevealed* candidates only (ascending).
    pub fn unrevealed_candidates(&self, id: JobId) -> impl Iterator<Item = u32> + '_ {
        self.jobs[id.0 as usize].unrevealed.iter().copied()
    }

    /// Running jobs with unlaunched tasks, smallest remaining workload
    /// first (ties by id) — the incremental SRPT level-2 order.
    pub fn level2_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.level2.iter().map(|&(_, id)| id)
    }

    /// Same membership as [`level2_jobs`](Self::level2_jobs), in arrival
    /// (id) order — the FIFO baselines.
    pub fn level2_jobs_fifo(&self) -> impl Iterator<Item = JobId> + '_ {
        self.level2_fifo.iter().copied()
    }

    /// Same membership as [`level2_jobs`](Self::level2_jobs), smallest
    /// *reveal-refined* workload first (ties by id) — the `est-srpt`
    /// ordering.  Empty unless [`track_est_keys`](Self::track_est_keys)
    /// enabled the twin.
    pub fn level2_jobs_est(&self) -> impl Iterator<Item = JobId> + '_ {
        self.level2_est.iter().map(|&(_, id)| id)
    }

    /// The job's current est-keyed level-2 key, if it is a member — what
    /// the `schedule_running_est` debug assertion checks against the scan
    /// path's fresh recomputation (the re-key contract).
    pub fn est_key(&self, id: JobId) -> Option<f64> {
        self.jobs[id.0 as usize].est_key.map(|k| k.0)
    }

    /// Queued jobs χ(l), smallest total workload first (ties by id).
    pub fn queued_jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.queued.iter().map(|&(_, id)| id)
    }

    /// Total unlaunched tasks across χ(l) — the backpressure signal,
    /// maintained as a running counter.
    pub fn queued_task_count(&self) -> usize {
        self.queued_tasks
    }

    /// Borrow the reusable job-id scratch buffer (empty).  Slot hooks
    /// snapshot an ordering into it, launch against the snapshot, then
    /// hand it back with [`put_scratch`](Self::put_scratch) so the next
    /// slot allocates nothing.  Taking twice just yields a fresh buffer.
    pub fn take_scratch(&mut self) -> Vec<JobId> {
        let mut v = std::mem::take(&mut self.scratch);
        v.clear();
        v
    }

    /// Return the scratch buffer, keeping its capacity for the next slot.
    pub fn put_scratch(&mut self, v: Vec<JobId>) {
        if v.capacity() > self.scratch.capacity() {
            self.scratch = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::job::{JobSpec, JobState};
    use crate::stats::Pareto;

    fn job(arena: &mut TaskArena, id: u32, tasks: u32, mean: f64) -> JobState {
        let base = arena.alloc_tasks(tasks);
        JobState::new(
            JobSpec {
                id: JobId(id),
                arrival: 0.0,
                dist: Pareto::from_mean(mean, 2.0),
                num_tasks: tasks,
            },
            base,
        )
    }

    fn launch_first_copy(j: &mut JobState, arena: &mut TaskArena, task: u32, now: f64) {
        arena.push_copy(j.tid(task), 0, now, 1.0, 1.0);
        if task >= j.next_unlaunched {
            j.next_unlaunched = task + 1;
        }
        if j.phase == JobPhase::Queued {
            j.phase = JobPhase::Running;
        }
    }

    #[test]
    fn sorted_set_matches_btreeset_semantics() {
        let mut s: SortedSet<(F64Key, JobId)> = SortedSet::default();
        assert!(s.insert((F64Key(2.0), JobId(1))));
        assert!(s.insert((F64Key(1.0), JobId(9))));
        assert!(s.insert((F64Key(2.0), JobId(0))));
        assert!(!s.insert((F64Key(2.0), JobId(1)))); // duplicate
        let order: Vec<u32> = s.iter().map(|&(_, id)| id.0).collect();
        assert_eq!(order, vec![9, 0, 1]); // key order, ties by id
        assert!(s.remove(&(F64Key(2.0), JobId(0))));
        assert!(!s.remove(&(F64Key(2.0), JobId(0)))); // already gone
        let order: Vec<u32> = s.iter().map(|&(_, id)| id.0).collect();
        assert_eq!(order, vec![9, 1]);
    }

    #[test]
    fn merge_asc_interleaves_disjoint_slices() {
        let merged: Vec<u32> = MergeAsc { a: &[0, 3, 4], b: &[1, 2, 7] }.collect();
        assert_eq!(merged, vec![0, 1, 2, 3, 4, 7]);
        let left_only: Vec<u32> = MergeAsc { a: &[5, 6], b: &[] }.collect();
        assert_eq!(left_only, vec![5, 6]);
        let right_only: Vec<u32> = MergeAsc { a: &[], b: &[5, 6] }.collect();
        assert_eq!(right_only, vec![5, 6]);
    }

    #[test]
    fn f64key_orders_like_total_cmp() {
        let mut keys = [F64Key(2.0), F64Key(f64::NAN), F64Key(-0.0), F64Key(0.0), F64Key(-1.0)];
        keys.sort();
        let mut floats = [2.0, f64::NAN, -0.0, 0.0, -1.0];
        floats.sort_by(|a, b| a.total_cmp(b));
        for (k, f) in keys.iter().zip(floats) {
            assert_eq!(k.0.total_cmp(&f), Ordering::Equal);
        }
    }

    #[test]
    fn queued_order_is_workload_then_id() {
        let mut idx = SchedIndex::new(3);
        let mut arena = TaskArena::new();
        // equal workloads for 0 and 2 -> id breaks the tie
        let jobs = [
            job(&mut arena, 0, 4, 1.0),
            job(&mut arena, 1, 1, 1.0),
            job(&mut arena, 2, 2, 2.0),
        ];
        for j in &jobs {
            idx.job_arrived(j);
        }
        let order: Vec<u32> = idx.queued_jobs().map(|id| id.0).collect();
        assert_eq!(order, vec![1, 0, 2]); // workloads 1, 4, 4 (tie 0 < 2)
        assert_eq!(idx.queued_task_count(), 7);
    }

    #[test]
    fn job_leaves_queue_on_first_launch() {
        let mut idx = SchedIndex::new(1);
        let mut arena = TaskArena::new();
        let mut j = job(&mut arena, 0, 3, 1.0);
        idx.job_arrived(&j);
        assert_eq!(idx.queued_task_count(), 3);
        launch_first_copy(&mut j, &mut arena, 0, 0.0);
        idx.sync_task(&j, &arena, TaskRef { job: JobId(0), task: 0 });
        idx.sync_job(&j);
        assert_eq!(idx.queued_jobs().count(), 0);
        assert_eq!(idx.queued_task_count(), 0);
        // still has unlaunched tasks -> level 2 member, both orders
        assert_eq!(idx.level2_jobs().collect::<Vec<_>>(), vec![JobId(0)]);
        assert_eq!(idx.level2_jobs_fifo().collect::<Vec<_>>(), vec![JobId(0)]);
    }

    #[test]
    fn level2_leaves_when_fully_launched() {
        let mut idx = SchedIndex::new(1);
        let mut arena = TaskArena::new();
        let mut j = job(&mut arena, 0, 2, 1.0);
        idx.job_arrived(&j);
        launch_first_copy(&mut j, &mut arena, 0, 0.0);
        idx.sync_job(&j);
        assert_eq!(idx.level2_jobs().count(), 1);
        launch_first_copy(&mut j, &mut arena, 1, 0.0);
        idx.sync_job(&j);
        assert_eq!(idx.level2_jobs().count(), 0);
        assert_eq!(idx.level2_jobs_fifo().count(), 0);
    }

    #[test]
    fn level2_reorders_on_completion() {
        let mut idx = SchedIndex::new(2);
        // job 0: 3 tasks of mean 2 (remaining 6); job 1: 2 tasks of mean 2
        // (remaining 4) -> order [1, 0]; completing two of job 0's tasks
        // drops its remaining to 2 -> order flips to [0, 1]
        let mut arena = TaskArena::new();
        let mut j0 = job(&mut arena, 0, 3, 2.0);
        let mut j1 = job(&mut arena, 1, 2, 2.0);
        for j in [&mut j0, &mut j1] {
            idx.job_arrived(j);
            launch_first_copy(j, &mut arena, 0, 0.0);
            idx.sync_job(j);
        }
        let order: Vec<u32> = idx.level2_jobs().map(|id| id.0).collect();
        assert_eq!(order, vec![1, 0]);
        j0.unfinished -= 2;
        idx.sync_job(&j0);
        let order: Vec<u32> = idx.level2_jobs().map(|id| id.0).collect();
        assert_eq!(order, vec![0, 1]);
        // fifo order is id order regardless of keys
        let fifo: Vec<u32> = idx.level2_jobs_fifo().map(|id| id.0).collect();
        assert_eq!(fifo, vec![0, 1]);
    }

    #[test]
    fn candidates_track_copy_lifecycle() {
        let mut idx = SchedIndex::new(1);
        let mut arena = TaskArena::new();
        let mut j = job(&mut arena, 0, 3, 1.0);
        idx.job_arrived(&j);
        let t0 = TaskRef { job: JobId(0), task: 0 };
        let t1 = TaskRef { job: JobId(0), task: 1 };
        launch_first_copy(&mut j, &mut arena, 0, 0.0);
        launch_first_copy(&mut j, &mut arena, 1, 0.0);
        idx.sync_task(&j, &arena, t0);
        idx.sync_task(&j, &arena, t1);
        idx.sync_job(&j);
        assert_eq!(idx.candidates(JobId(0)).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(idx.unrevealed_candidates(JobId(0)).count(), 2);
        // reveal task 0: moves between the splits, union order unchanged
        arena.set_revealed(arena.copy_id(j.tid(0), 0));
        idx.sync_task(&j, &arena, t0);
        assert_eq!(idx.revealed_candidates(JobId(0)).collect::<Vec<_>>(), vec![0]);
        assert_eq!(idx.unrevealed_candidates(JobId(0)).collect::<Vec<_>>(), vec![1]);
        assert_eq!(idx.candidates(JobId(0)).collect::<Vec<_>>(), vec![0, 1]);
        // a backup on task 0 disqualifies it (no longer a single-copy task)
        arena.push_copy(j.tid(0), 0, 0.0, 1.0, 1.0);
        idx.sync_task(&j, &arena, t0);
        assert_eq!(idx.candidates(JobId(0)).collect::<Vec<_>>(), vec![1]);
        // task 1 finishes -> gone too
        arena.set_done(j.tid(1), 0.0);
        arena.set_phase(arena.copy_id(j.tid(1), 0), CopyPhase::Finished);
        idx.sync_task(&j, &arena, t1);
        assert_eq!(idx.candidates(JobId(0)).count(), 0);
        // a killed single copy (Mantri's restart) is not a candidate either
        arena.push_copy(j.tid(2), 1, 0.0, 1.0, 1.0);
        arena.set_phase(arena.copy_id(j.tid(2), 0), CopyPhase::Killed);
        idx.sync_task(&j, &arena, TaskRef { job: JobId(0), task: 2 });
        assert_eq!(idx.candidates(JobId(0)).count(), 0);
    }

    #[test]
    fn est_twin_tracks_reveals_and_reorders() {
        let mut idx = SchedIndex::new(2);
        idx.track_est_keys();
        assert!(idx.tracks_est());
        // two 2-task jobs, mean 2.0 each: est keys start at 4.0 apiece
        let mut arena = TaskArena::new();
        let mut j0 = job(&mut arena, 0, 2, 2.0);
        let mut j1 = job(&mut arena, 1, 2, 2.0);
        for j in [&mut j0, &mut j1] {
            idx.job_arrived(j);
            launch_first_copy(j, &mut arena, 0, 0.0);
            idx.sync_task(j, &arena, TaskRef { job: j.spec.id, task: 0 });
            idx.sync_job(j);
        }
        // tie on 4.0 -> id order
        let order: Vec<u32> = idx.level2_jobs_est().map(|id| id.0).collect();
        assert_eq!(order, vec![0, 1]);
        assert_eq!(idx.est_key(JobId(0)), Some(4.0));
        // job 0's first copy reveals a 9.0-work duration: its key jumps to
        // 9 + 2 = 11 and it sinks below job 1
        arena.set_revealed(arena.copy_id(j0.tid(0), 0));
        idx.sync_task(&j0, &arena, TaskRef { job: JobId(0), task: 0 });
        idx.set_est_contrib(TaskRef { job: JobId(0), task: 0 }, 9.0);
        assert_eq!(idx.est_key(JobId(0)), Some(11.0));
        let order: Vec<u32> = idx.level2_jobs_est().map(|id| id.0).collect();
        assert_eq!(order, vec![1, 0]);
        // the mean-field set is untouched by the reveal
        let mean_field: Vec<u32> = idx.level2_jobs().map(|id| id.0).collect();
        assert_eq!(mean_field, vec![0, 1]);
        // fully launching job 0 removes it from both twins
        launch_first_copy(&mut j0, &mut arena, 1, 0.0);
        idx.sync_job(&j0);
        assert_eq!(idx.level2_jobs_est().count(), 1);
        assert_eq!(idx.est_key(JobId(0)), None);
    }

    #[test]
    fn est_twin_off_by_default_costs_nothing() {
        let mut idx = SchedIndex::new(1);
        let mut arena = TaskArena::new();
        let mut j = job(&mut arena, 0, 3, 1.0);
        idx.job_arrived(&j);
        launch_first_copy(&mut j, &mut arena, 0, 0.0);
        idx.sync_job(&j);
        // no tracking: the twin stays empty and re-keys are no-ops
        assert!(!idx.tracks_est());
        assert_eq!(idx.level2_jobs_est().count(), 0);
        idx.set_est_contrib(TaskRef { job: JobId(0), task: 0 }, 7.0);
        assert_eq!(idx.est_key(JobId(0)), None);
        assert_eq!(idx.level2_jobs().count(), 1);
    }

    #[test]
    fn scratch_reuse_keeps_capacity() {
        let mut idx = SchedIndex::new(0);
        let mut v = idx.take_scratch();
        v.extend([JobId(1), JobId(2), JobId(3)]);
        let cap = v.capacity();
        idx.put_scratch(v);
        let v = idx.take_scratch();
        assert!(v.is_empty());
        assert_eq!(v.capacity(), cap);
        // taking while taken still works (fresh buffer)
        let w = idx.take_scratch();
        assert!(w.is_empty());
        idx.put_scratch(v);
        idx.put_scratch(w);
    }

    #[test]
    fn sync_is_idempotent() {
        let mut idx = SchedIndex::new(1);
        let mut arena = TaskArena::new();
        let mut j = job(&mut arena, 0, 2, 1.5);
        idx.job_arrived(&j);
        launch_first_copy(&mut j, &mut arena, 0, 0.0);
        let t0 = TaskRef { job: JobId(0), task: 0 };
        for _ in 0..3 {
            idx.sync_task(&j, &arena, t0);
            idx.sync_job(&j);
        }
        assert_eq!(idx.candidates(JobId(0)).collect::<Vec<_>>(), vec![0]);
        assert_eq!(idx.level2_jobs().count(), 1);
        assert_eq!(idx.queued_jobs().count(), 0);
    }
}
