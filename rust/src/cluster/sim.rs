//! The discrete-event cluster simulator with slotted scheduling decisions.
//!
//! Model (Sec. III): jobs arrive at a master queue; scheduling decisions are
//! made at slot boundaries; task copies occupy one machine each and complete
//! at their sampled Pareto duration; a task completes when its first copy
//! does (siblings are killed and their machines freed); the scheduler learns
//! a copy's true remaining time only after the copy has executed the
//! detection fraction `s_i` of its work (Eq. 18-19).
//!
//! First-copy durations are **pre-sampled by the generator** so that every
//! scheduling policy sees the identical workload; backup-copy durations are
//! drawn i.i.d. from the job's own RNG stream at launch time.
//!
//! Sampled durations are **work** amounts; a copy's wall-clock duration is
//! its work divided by the host's *effective* speed (advertised class
//! speed over hidden slowdown, see `cluster::machine`).  Schedulers do not
//! estimate remaining times here — that lives in [`crate::estimator`],
//! which defines exactly what a scheduler may observe about a copy.

use std::collections::BTreeSet;

use crate::config::SimConfig;
use crate::metrics::{JobRecord, StreamedJobStats};
use crate::scheduler::Scheduler;
use crate::stats::{Cdf, Pcg64};
use crate::workload::{JobSource, Lookahead, SourcedJob};

use super::event::{Event, EventQueue};
use super::index::SchedIndex;
use super::job::{CopyPhase, CopyState, JobId, JobPhase, JobSpec, JobState, TaskArena, TaskRef};
use super::machine::{Assignment, MachinePool, SlowdownConfig};

/// Pre-sampled workload: the job specs plus the first-copy duration of every
/// task (policy-independent).
#[derive(Clone, Debug, Default)]
pub struct Workload {
    pub specs: Vec<JobSpec>,
    pub first_durations: Vec<Vec<f64>>,
}

/// Everything the scheduler can see and touch.  Scheduler hooks receive
/// `&mut Cluster`; the event loop lives in [`Simulator`].
pub struct Cluster {
    pub cfg: SimConfig,
    pub clock: f64,
    pub machines: MachinePool,
    pub jobs: Vec<JobState>,
    /// Flat SoA task/copy storage; `jobs[i].base` keys each job's range.
    /// See [`TaskArena`] and DESIGN.md §13.
    pub arena: TaskArena,
    /// chi(l): arrived jobs with no task launched yet.
    pub queued: BTreeSet<JobId>,
    /// R(l): jobs with at least one launched task, not yet finished.
    pub running: BTreeSet<JobId>,
    /// Incremental scheduler indices (speculation candidates, SRPT level-2
    /// order, χ(l) order), kept current by every mutation below so slot
    /// hooks cost O(active) instead of O(everything).  Maintained and
    /// queried only when `cfg.sched_index` is on (the default); with it
    /// off the retained naive scans run instead, with no index upkeep —
    /// the true pre-index baseline.  See [`SchedIndex`].
    pub index: SchedIndex,
    /// Wakeup-planner dirty flag: set by every cluster mutation (arrival,
    /// launch, kill, finish, checkpoint reveal) and cleared when a
    /// scheduling slot fires, so "has anything changed since the last
    /// fired slot?" is an O(1) read.  A set flag forces the next grid
    /// slot; see [`SlotGate`] and DESIGN.md §12.  Maintained
    /// unconditionally (a bool store at mutation points — the `wakeup`
    /// toggle gates only the *skipping*, so `wakeup = false` reproduces
    /// the polled loop exactly).
    pub sched_dirty: bool,
    pub(crate) events: EventQueue,
    first_durations: Vec<Vec<f64>>,
    job_rngs: Vec<Pcg64>,
    /// Per-machine ON/OFF dwell streams for the Markov slowdown process;
    /// empty unless `cfg.slowdown` has flips enabled, so static-slowdown
    /// and healthy runs consume no draws and stay bit-identical.
    flip_rngs: Vec<Pcg64>,
    /// Per-machine up-time/repair streams for the crash/recovery churn
    /// process; empty unless `cfg.churn` is enabled, so churn-free runs
    /// (and scripted machine-events replays) consume no draws and stay
    /// bit-identical to pre-churn behavior.
    churn_rngs: Vec<Pcg64>,
    /// Tasks whose last surviving copy died in a machine crash, waiting to
    /// relaunch (the paper's restart-from-zero failure model).  Drained
    /// FIFO at the next fired slot (`SlotGate::slot`), ahead of the
    /// scheduler's own launches; counted into `queued_tasks` so
    /// backpressure sees the re-execution backlog.
    requeued: Vec<TaskRef>,
    /// Completed jobs whose arena rows are not yet reusable (waiting on
    /// `stranded == 0`); drained by the live path's `add_job`.
    pending_recycle: Vec<JobId>,
    /// Machine-time consumed so far across all jobs (utilization numerator).
    pub total_machine_time: f64,
    /// Copies beyond the first launched per task (speculation volume).
    pub speculative_launches: u64,
    /// Currently-running backup copies (LATE's speculativeCap accounting).
    pub outstanding_backups: usize,
    /// Copies killed by machine crashes across all jobs (churn volume).
    pub copies_lost: u64,
    /// Machine-time sunk into crashed copies — the work the
    /// restart-from-zero failure model throws away.  Also counted in
    /// `total_machine_time`: lost work still occupied a machine.
    pub work_lost: f64,
    /// Machine-crash events handled (recoveries are not counted).
    pub machines_failed: u64,
    pub completed: Vec<JobRecord>,
    pub incomplete: u64,
}

impl Cluster {
    fn new(cfg: SimConfig, workload: Workload, seed_stream: u64) -> Self {
        let mut root = Pcg64::new(cfg.seed, seed_stream);
        let job_rngs = workload
            .specs
            .iter()
            .map(|s| root.split(s.id.0 as u64 + 1))
            .collect();
        let mut arena = TaskArena::new();
        let jobs: Vec<JobState> = workload
            .specs
            .into_iter()
            .map(|s| {
                let base = arena.alloc_tasks(s.num_tasks);
                JobState::new(s, base)
            })
            .collect();
        let mut machines = if cfg.machine_classes.is_empty() {
            MachinePool::new(cfg.machines)
        } else {
            MachinePool::with_classes(&cfg.machine_classes)
        };
        if let Some(sd) = &cfg.slowdown {
            // dedicated stream: adding the slowdown axis must not perturb
            // the workload or backup-duration draws of existing scenarios
            let mut sd_rng = Pcg64::new(cfg.seed, 0x510d);
            machines.sample_slowdowns(sd, &mut sd_rng);
        }
        // ON/OFF flip dwells get their own root (enabling the flip axis
        // must not perturb any existing draw), split per machine so every
        // machine's dwell sequence is independent of the others' flip
        // counts
        let flip_rngs: Vec<Pcg64> = match &cfg.slowdown {
            Some(sd) if sd.flips_enabled() => {
                let mut root = Pcg64::new(cfg.seed, 0xf11f);
                (0..machines.total()).map(|m| root.split(m as u64 + 1)).collect()
            }
            _ => Vec::new(),
        };
        // crash/recovery draws get their own root too (enabling churn must
        // not perturb any existing draw), split per machine so each
        // machine's fail/repair sequence is independent of the others'
        let churn_rngs: Vec<Pcg64> = match &cfg.churn {
            Some(ch) if ch.enabled() => {
                let mut root = Pcg64::new(cfg.seed, 0xfa11);
                (0..machines.total()).map(|m| root.split(m as u64 + 1)).collect()
            }
            _ => Vec::new(),
        };
        let mut index = SchedIndex::new(jobs.len());
        if cfg.sched_index && cfg.scheduler.uses_est_ordering() {
            // an est-srpt pipeline is active: maintain the est-keyed
            // level-2 twin (re-keyed at the reveal/kill/finish mutation
            // points below); any other policy pays no upkeep
            index.track_est_keys();
        }
        let events = EventQueue::with_kind(cfg.event_queue, cfg.slot_dt);
        let mut cl = Cluster {
            machines,
            cfg,
            clock: 0.0,
            jobs,
            arena,
            queued: BTreeSet::new(),
            running: BTreeSet::new(),
            index,
            // dirty at birth: the first slot always fires (initial state
            // has never been scheduled)
            sched_dirty: true,
            events,
            first_durations: workload.first_durations,
            job_rngs,
            flip_rngs,
            churn_rngs,
            requeued: Vec::new(),
            pending_recycle: Vec::new(),
            total_machine_time: 0.0,
            speculative_launches: 0,
            outstanding_backups: 0,
            copies_lost: 0,
            work_lost: 0.0,
            machines_failed: 0,
            completed: Vec::new(),
            incomplete: 0,
        };
        // seed each machine's first flip from the dwell law of its
        // *initial* hidden state (degraded machines wait on `rate_off`,
        // healthy ones on `rate_on`; a zero exit rate is absorbing)
        if let Some(sd) = cl.cfg.slowdown {
            if sd.flips_enabled() {
                for m in 0..cl.machines.total() as u32 {
                    cl.schedule_flip(m, &sd);
                }
            }
        }
        // seed each machine's first crash from its up-time law (every
        // machine starts up); the fail handler then schedules the
        // recovery and the recovery the next crash
        if cl.cfg.churn.is_some_and(|ch| ch.enabled()) {
            for m in 0..cl.machines.total() as u32 {
                cl.schedule_fail(m);
            }
        }
        cl
    }

    /// Construct an empty cluster for live (coordinator-driven) operation.
    pub fn new_live(cfg: SimConfig) -> Self {
        Cluster::new(cfg, Workload { specs: Vec::new(), first_durations: Vec::new() }, 0x11fe)
    }

    /// Live mode: admit a job now.  Task first-copy durations are sampled
    /// immediately from the cluster RNG (there is no pre-generated trace).
    pub fn add_job(&mut self, mean_duration: f64, alpha: f64, num_tasks: u32) -> JobId {
        self.recycle_retired();
        let id = JobId(self.jobs.len() as u32);
        let dist = crate::stats::Pareto::from_mean(mean_duration, alpha);
        let mut rng = Pcg64::new(self.cfg.seed ^ 0xadd0b, id.0 as u64 + 1);
        let durs: Vec<f64> = (0..num_tasks).map(|_| dist.sample(&mut rng)).collect();
        self.first_durations.push(durs);
        self.job_rngs.push(rng.split(7));
        let base = self.arena.alloc_tasks(num_tasks);
        self.jobs.push(JobState::new(
            JobSpec { id, arrival: self.clock, dist, num_tasks },
            base,
        ));
        self.index.push_job();
        self.arrive(id);
        id
    }

    /// Streaming replay: admit a sourced job at its arrival instant.
    ///
    /// Mirrors the eager construction exactly — `root` is the same
    /// `Pcg64::new(seed, stream)` RNG `Cluster::new` splits per job, the
    /// splits happen in the same dense-id order, and arena rows allocate
    /// in the same order — so an uncapped streamed run is bit-identical
    /// to materializing the workload up front (DESIGN.md §16).
    pub(crate) fn admit_streamed(&mut self, job: SourcedJob, root: &mut Pcg64) {
        let id = JobId(self.jobs.len() as u32);
        debug_assert_eq!(job.spec.id, id, "streamed jobs must carry dense ids");
        self.job_rngs.push(root.split(id.0 as u64 + 1));
        let base = self.arena.alloc_tasks(job.spec.num_tasks);
        self.first_durations.push(job.durations);
        self.jobs.push(JobState::new(JobSpec { id, ..job.spec }, base));
        self.index.push_job();
        self.arrive(id);
    }

    /// Arena hygiene: reuse the task/copy rows (and drop the first-copy
    /// duration buffers) of completed jobs once no event-queue entry
    /// references them any more (`stranded == 0` — killed copies' dead
    /// entries either popped as no-ops or were compacted away).  Called by
    /// the live path's `add_job` and by `--max-resident-jobs`-capped batch
    /// runs; uncapped batch runs never call this, so the trace path keeps
    /// every row — and stays bit-identical to the per-job layout by
    /// construction.  (Recycling reorders only which arena rows back which
    /// tasks, never any sampled value or event order, so capped and
    /// uncapped runs simulate identical dynamics.)
    fn recycle_retired(&mut self) {
        let mut i = 0;
        while i < self.pending_recycle.len() {
            let id = self.pending_recycle[i];
            let job = &self.jobs[id.0 as usize];
            if job.stranded == 0 {
                self.arena.recycle_tasks(job.base, job.spec.num_tasks);
                self.first_durations[id.0 as usize] = Vec::new();
                self.pending_recycle.swap_remove(i);
            } else {
                i += 1;
            }
        }
    }

    /// Capped-mode record hygiene: once `completed` reaches
    /// `cfg.max_resident_jobs`, absorb every retained record into the
    /// streaming sketches and recycle the finished jobs' arena rows and
    /// duration buffers.  Memory then scales with the cap, not the
    /// workload.  No-op below the cap; panics if called uncapped.
    pub(crate) fn drain_completed_into(&mut self, sink: &mut StreamedJobStats) {
        let cap = self.cfg.max_resident_jobs.expect("drain only runs when capped");
        if self.completed.len() >= cap {
            for r in self.completed.drain(..) {
                sink.absorb(&r);
            }
            self.recycle_retired();
        }
    }

    /// A job joins χ(l) (its arrival event fired / a live submission).
    /// Crate-visible so unit tests can stage arrivals without running the
    /// event loop; external callers go through the simulator / `add_job`.
    ///
    /// Index maintenance (here and in the other mutation points) is gated
    /// on `cfg.sched_index`, so the `false` setting reproduces the true
    /// pre-index code — scans only, no index upkeep — which is what the
    /// bench suite's `scan` cells and the equivalence reference measure.
    pub(crate) fn arrive(&mut self, id: JobId) {
        self.queued.insert(id);
        self.sched_dirty = true;
        if self.cfg.sched_index {
            self.index.job_arrived(&self.jobs[id.0 as usize]);
        }
    }

    /// A first copy crossed its detection checkpoint.  Returns true when
    /// the reveal took effect (the copy is still running, its task not
    /// done, and the entry's re-time epoch is current) — the caller then
    /// fires the scheduler's `on_reveal` hook.
    fn reveal_copy(&mut self, t: TaskRef, copy: u32, epoch: u32) -> bool {
        let tid = self.tid(t);
        let cid = self.arena.copy_id(tid, copy);
        if self.arena.done(tid)
            || self.arena.phase(cid) != CopyPhase::Running
            || self.arena.epoch(cid) != epoch
        {
            // the copy was killed — or re-timed by a SlowdownFlip — before
            // its checkpoint fired: this entry was stale-counted at that
            // point (kills strand an unrevealed first copy's checkpoint;
            // re-times strand and replace it) — settle both ledgers
            self.events.note_stale_popped();
            self.jobs[t.job.0 as usize].stranded -= 1;
            return false;
        }
        self.arena.set_revealed(cid);
        self.stamp_obs_speed(cid);
        // a reveal can flip slot-gated threshold predicates (ESE's
        // sigma-test reads the revealed truth), so it dirties the planner
        self.sched_dirty = true;
        if self.cfg.sched_index {
            self.index.sync_task(&self.jobs[t.job.0 as usize], &self.arena, t);
            self.sync_est(t);
        }
        true
    }

    /// Stamp the copy's lifetime-average delivered throughput (work per
    /// wall-clock unit) — the observed-speed estimator's only input beyond
    /// the advertised class speed.  Called at the reveal and again at each
    /// `SlowdownFlip` re-time, so the stamp is piecewise-constant between
    /// cluster mutations: that is what keeps the wakeup planner's
    /// "revealed estimates never rise on their own" horizon argument sound
    /// for the observed variant too (DESIGN.md §14).  The remaining work
    /// converts exactly (`remaining wall x current effective speed` —
    /// the speed has been constant since the last re-time).
    fn stamp_obs_speed(&mut self, cid: u32) {
        let c = self.arena.copy(cid);
        let elapsed = c.elapsed(self.clock);
        if elapsed <= 0.0 {
            return;
        }
        let v_eff = self.machines.effective_speed(c.machine);
        let v = if self.arena.epoch(cid) == 0 {
            // never re-timed: the effective speed has been constant for
            // the copy's whole life, so the lifetime average *is* the
            // current speed — stamping it exactly (no round-trip through
            // work arithmetic) keeps the observed estimator bit-identical
            // to the advertised one whenever nothing ever flipped
            v_eff
        } else {
            (self.arena.work(cid) - c.true_remaining(self.clock) * v_eff).max(0.0) / elapsed
        };
        self.arena.set_obs_speed(cid, v);
    }

    /// Est-ordering re-key hook: task `t`'s contribution to the
    /// reveal-refined level-2 key may have changed (checkpoint reveal,
    /// kill, completion) — recompute it through the same pure function
    /// the scan path sums (`estimator::revealed_task_workload`), so the
    /// maintained key stays bit-identical to a fresh recomputation.
    /// No-op unless an est-srpt pipeline enabled tracking.
    fn sync_est(&mut self, t: TaskRef) {
        if self.index.tracks_est() {
            let contrib = crate::estimator::revealed_task_workload(
                &self.jobs[t.job.0 as usize],
                &self.arena,
                &self.machines,
                t.task,
            );
            self.index.set_est_contrib(t, contrib);
        }
    }

    /// Live mode: process all pending events up to (and including) time `t`
    /// and advance the clock to `t`.  Slot decisions are the caller's job
    /// (typically through a [`SlotGate`]).
    pub fn advance_to(&mut self, t: f64, sched: &mut dyn Scheduler) {
        while let Some(et) = self.events.peek_time() {
            if et > t {
                break;
            }
            let (time, event) = self.events.pop().unwrap();
            self.clock = time;
            match event {
                Event::Arrival(id) => self.arrive(id),
                Event::CopyFinish { task, copy, epoch } => self.copy_finished(task, copy, epoch),
                Event::Checkpoint { task, copy, epoch } => {
                    if self.reveal_copy(task, copy, epoch) {
                        sched.on_reveal(self, task);
                    }
                }
                Event::SlowdownFlip { machine } => {
                    if let Some(task) = self.flip_machine(machine) {
                        sched.on_reveal(self, task);
                    }
                }
                Event::MachineFail { machine } => self.fail_machine(machine),
                Event::MachineRecover { machine } => self.recover_machine(machine),
            }
        }
        self.clock = t;
    }

    /// Total queued (unlaunched) tasks — the backpressure signal.  O(1)
    /// from the index counter; the retained scan double-checks it in
    /// debug builds and serves as the `sched_index = false` reference.
    /// The crash-relaunch backlog counts too: a requeued task is queued
    /// work the cluster has yet to place.
    pub fn queued_tasks(&self) -> usize {
        let scan = || -> usize {
            self.queued
                .iter()
                .map(|id| self.job(*id).spec.num_tasks as usize)
                .sum()
        };
        let unlaunched = if self.cfg.sched_index {
            debug_assert_eq!(self.index.queued_task_count(), scan());
            self.index.queued_task_count()
        } else {
            scan()
        };
        unlaunched + self.requeued.len()
    }

    // ----- queries -------------------------------------------------------

    /// N(l): idle machines.
    #[inline]
    pub fn idle(&self) -> usize {
        self.machines.idle()
    }

    pub fn job(&self, id: JobId) -> &JobState {
        &self.jobs[id.0 as usize]
    }

    /// Global arena id of task `t` (see [`TaskArena`]).
    #[inline]
    pub fn tid(&self, t: TaskRef) -> u32 {
        self.jobs[t.job.0 as usize].base + t.task
    }

    #[inline]
    pub fn task_done(&self, t: TaskRef) -> bool {
        self.arena.done(self.tid(t))
    }

    /// Copies launched for task `t` so far (running, finished or killed).
    #[inline]
    pub fn n_copies(&self, t: TaskRef) -> u32 {
        self.arena.n_copies(self.tid(t))
    }

    /// By-value view of task `t`'s `k`-th copy.
    #[inline]
    pub fn copy(&self, t: TaskRef, k: u32) -> CopyState {
        self.arena.copy_at(self.tid(t), k)
    }

    /// chi(l) sorted by increasing total workload (SCA/SDA/ESE level 3).
    ///
    /// This is the **naive-scan reference**: O(|χ| log |χ|) per call.  The
    /// production path snapshots [`SchedIndex::queued_jobs`] into a reused
    /// scratch buffer instead (see [`Cluster::snapshot_queued`]); the two
    /// orders are identical — the index keys by `(workload, id)` under
    /// `total_cmp`, exactly this stable sort's order.
    pub fn chi_sorted(&self) -> Vec<JobId> {
        let mut v: Vec<JobId> = self.queued.iter().copied().collect();
        v.sort_by(|a, b| {
            self.job(*a)
                .spec
                .workload()
                .total_cmp(&self.job(*b).spec.workload())
        });
        v
    }

    /// χ(l) in workload order via the index (or the scan reference when
    /// `cfg.sched_index` is off), snapshotted into the index's reused
    /// scratch buffer.  Return it with [`Cluster::put_scratch`] when done.
    pub fn snapshot_queued(&mut self) -> Vec<JobId> {
        let mut buf = self.index.take_scratch();
        if self.cfg.sched_index {
            buf.extend(self.index.queued_jobs());
        } else {
            buf.extend(self.chi_sorted());
        }
        buf
    }

    /// Hand a snapshot buffer back for reuse by the next slot hook.
    pub fn put_scratch(&mut self, buf: Vec<JobId>) {
        self.index.put_scratch(buf);
    }

    // Remaining-time estimation used to live here as `est_remaining*` /
    // `prob_remaining_exceeds*` methods; it moved to `crate::estimator`,
    // which defines the observation contract (what a scheduler may read
    // about a copy) and the blind / revealed / speed-aware implementations.

    // ----- mutations -----------------------------------------------------

    /// Launch one copy of `t` on an idle machine.  The first copy of a task
    /// uses the pre-sampled duration; backups draw from the job's stream.
    /// Returns false when no machine is idle, the task is done, or the copy
    /// cap r_max is reached.
    pub fn launch_copy(&mut self, t: TaskRef) -> bool {
        let now = self.clock;
        let ji = t.job.0 as usize;
        let detect_frac = self.cfg.detect_frac;
        let r_max = self.cfg.r_max;
        let tid = self.tid(t);
        if self.arena.done(tid) {
            return false;
        }
        let n_copies = self.arena.n_copies(tid);
        if n_copies >= r_max {
            return false;
        }
        let work = if n_copies == 0 {
            self.first_durations[ji][t.task as usize]
        } else {
            self.jobs[ji].spec.dist.sample(&mut self.job_rngs[ji])
        };
        let copy_idx = n_copies;
        let Some(machine) = self.machines.alloc(Assignment { task: t, copy: copy_idx }) else {
            return false;
        };
        // sampled durations are work amounts; wall-clock scales by the
        // host's effective speed — advertised class speed (1.0 everywhere
        // in the paper's homogeneous cluster) over the hidden slowdown
        let duration = work / self.machines.effective_speed(machine);
        let k = self.arena.push_copy(tid, machine, now, duration, work);
        debug_assert_eq!(k, copy_idx);
        let job = &mut self.jobs[ji];
        self.events
            .push(now + duration, Event::CopyFinish { task: t, copy: copy_idx, epoch: 0 });
        // detection checkpoint on the first copy only (the paper monitors
        // the original; backups are already speculation)
        if copy_idx == 0 {
            self.events.push(
                now + detect_frac * duration,
                Event::Checkpoint { task: t, copy: 0, epoch: 0 },
            );
            if t.task >= job.next_unlaunched {
                job.next_unlaunched = t.task + 1;
            }
        } else {
            self.speculative_launches += 1;
            self.outstanding_backups += 1;
        }
        if job.phase == JobPhase::Queued {
            job.phase = JobPhase::Running;
            job.first_sched = Some(now);
            self.queued.remove(&t.job);
            self.running.insert(t.job);
        }
        self.sched_dirty = true;
        if self.cfg.sched_index {
            self.index.sync_task(&self.jobs[ji], &self.arena, t);
            self.sync_est(t);
            self.index.sync_job(&self.jobs[ji]);
        }
        true
    }

    /// Launch first copies for up to `limit` unlaunched tasks of a job
    /// (level-2/3 scheduling).  Returns how many were launched.
    pub fn launch_unlaunched(&mut self, id: JobId, limit: usize) -> usize {
        let mut launched = 0;
        while launched < limit {
            let next = self.jobs[id.0 as usize].next_unlaunched;
            if next >= self.jobs[id.0 as usize].spec.num_tasks {
                break;
            }
            if !self.launch_copy(TaskRef { job: id, task: next }) {
                break;
            }
            launched += 1;
        }
        launched
    }

    /// Launch every task of a queued job with `copies` copies each (the SCA
    /// cloning branch).  Stops early if machines run out.
    pub fn launch_job_cloned(&mut self, id: JobId, copies: u32) -> usize {
        let m = self.jobs[id.0 as usize].spec.num_tasks;
        let mut launched = 0;
        for task in 0..m {
            let t = TaskRef { job: id, task };
            for _ in 0..copies.max(1) {
                if !self.launch_copy(t) {
                    return launched;
                }
                launched += 1;
            }
        }
        launched
    }

    /// Kill a running copy (Mantri's restart ablation); frees its machine.
    pub fn kill_copy(&mut self, t: TaskRef, copy: u32) {
        let now = self.clock;
        let tid = self.tid(t);
        let cid = self.arena.copy_id(tid, copy);
        if self.arena.phase(cid) != CopyPhase::Running {
            return;
        }
        self.arena.set_phase(cid, CopyPhase::Killed);
        let c = self.arena.copy(cid);
        let used = c.elapsed(now).min(c.duration);
        // the kill strands this copy's pending CopyFinish in the heap, and
        // its Checkpoint too if it had not revealed yet (checkpoints fire
        // strictly before finishes, so unrevealed == checkpoint pending);
        // primary copies — chain head or crash relaunch — are the ones
        // carrying a checkpoint.  The job's `stranded` ledger mirrors the
        // queue's stale counter so arena rows are only recycled once no
        // queue entry references them
        let primary = self.arena.primary(cid);
        let stranded = if primary && !c.revealed { 2usize } else { 1 };
        let job = &mut self.jobs[t.job.0 as usize];
        job.machine_time += used;
        job.stranded += stranded as u32;
        self.total_machine_time += used;
        if !primary {
            self.outstanding_backups -= 1;
        }
        self.machines.release(c.machine);
        self.events.note_stale(stranded);
        self.sched_dirty = true;
        if self.cfg.sched_index {
            self.index.sync_task(&self.jobs[t.job.0 as usize], &self.arena, t);
            // killing a revealed copy reverts the task's est contribution
            self.sync_est(t);
        }
        self.maybe_compact_events();
    }

    /// Handle a `SlowdownFlip` event: toggle the machine's hidden ON/OFF
    /// slowdown state, re-time the copy it is running (if any) under the
    /// new effective speed, and schedule the machine's next flip.  Returns
    /// the re-timed copy's task when that copy had already revealed — the
    /// event loop then re-fires the scheduler's `on_reveal` hook, so
    /// detection rules see the jumped remaining time and can reschedule
    /// in flight.  Public so estimator and rule tests can stage mid-flight
    /// degradation deterministically without running the event loop.
    pub fn flip_machine(&mut self, machine: u32) -> Option<TaskRef> {
        let Some(sd) = self.cfg.slowdown else {
            debug_assert!(false, "SlowdownFlip without a slowdown config");
            return None;
        };
        let v_old = self.machines.effective_speed(machine);
        let degraded = self.machines.slowdown(machine) > 1.0;
        self.machines.set_slowdown(machine, if degraded { 1.0 } else { sd.factor });
        let v_new = self.machines.effective_speed(machine);
        let redetect = self
            .machines
            .assignment(machine)
            .and_then(|asg| self.retime_copy(asg, v_old, v_new));
        // a flip is a cluster mutation: revealed remaining times (and the
        // wall cost of anything launched here next) just moved, so any
        // cached `next_decision_time` horizon — computed from the
        // pre-flip state — must be invalidated; the dirty flag forces the
        // next slot to fire, which drops the SlotGate's hint
        self.sched_dirty = true;
        self.schedule_flip(machine, &sd);
        redetect
    }

    /// Re-time one running copy after its host's effective speed changed
    /// from `v_old` to `v_new`.  The remaining wall-clock under the old
    /// timeline converts to remaining *work* exactly (`x v_old` — the
    /// speed was constant since the last re-time), and that work at
    /// `v_new` fixes the new finish.  The superseded `CopyFinish` — and
    /// the superseded `Checkpoint` of an unrevealed first copy — are
    /// stale-counted through the same `note_stale` ledger a kill uses,
    /// and fresh entries carry the bumped epoch.  Returns the task when
    /// the copy had revealed (the caller's re-detect signal).
    fn retime_copy(&mut self, asg: Assignment, v_old: f64, v_new: f64) -> Option<TaskRef> {
        let t = asg.task;
        let now = self.clock;
        let tid = self.tid(t);
        let cid = self.arena.copy_id(tid, asg.copy);
        debug_assert_eq!(self.arena.phase(cid), CopyPhase::Running);
        let c = self.arena.copy(cid);
        let rem_work = c.true_remaining(now) * v_old;
        let finish = now + rem_work / v_new;
        self.arena.set_duration(cid, finish - c.start);
        let epoch = self.arena.bump_epoch(cid);
        // primary copies (chain head or crash relaunch) carry the pending
        // checkpoint; without churn "primary" is exactly `asg.copy == 0`
        let primary = self.arena.primary(cid);
        let superseded = if primary && !c.revealed { 2usize } else { 1 };
        self.jobs[t.job.0 as usize].stranded += superseded as u32;
        self.events.note_stale(superseded);
        self.events.push(finish, Event::CopyFinish { task: t, copy: asg.copy, epoch });
        if primary && !c.revealed {
            // the pending checkpoint moves to where the `detect_frac` work
            // point now lands: work done so far is flip-invariant, so the
            // instant derives from the re-timed finish and the stored
            // work; it is >= now exactly when the copy is unrevealed, and
            // <= finish always — the clamp only absorbs float round-off
            let w = self.arena.work(cid);
            let cp = finish - (1.0 - self.cfg.detect_frac) * w / v_new;
            self.events.push(cp.max(now), Event::Checkpoint { task: t, copy: asg.copy, epoch });
        }
        if c.revealed {
            // refresh the observed-throughput stamp at this mutation point
            // (the estimator may only see it move at mutation points)
            self.stamp_obs_speed(cid);
        }
        if self.cfg.sched_index {
            self.index.sync_task(&self.jobs[t.job.0 as usize], &self.arena, t);
            // a revealed copy's est-key contribution is duration x speed —
            // the re-timed duration just changed it
            self.sync_est(t);
        }
        self.maybe_compact_events();
        if c.revealed {
            Some(t)
        } else {
            None
        }
    }

    /// Draw the machine's next ON/OFF dwell from its dedicated stream and
    /// push the flip event.  A zero exit rate makes the current state
    /// absorbing (one-sided flip specs are legal); no stream exists when
    /// flips are disabled, so static runs push nothing and draw nothing.
    fn schedule_flip(&mut self, machine: u32, sd: &SlowdownConfig) {
        if self.flip_rngs.is_empty() {
            return;
        }
        let degraded = self.machines.slowdown(machine) > 1.0;
        let rate = if degraded { sd.rate_off } else { sd.rate_on };
        if rate > 0.0 {
            let dwell = self.flip_rngs[machine as usize].exponential(rate);
            self.events.push(self.clock + dwell, Event::SlowdownFlip { machine });
        }
    }

    /// Handle a `MachineFail` event: kill the resident copy (if any) as
    /// crash loss, take the machine out of the allocatable pool, and
    /// schedule its recovery.  A crashed-out task (no surviving copies)
    /// joins the relaunch backlog — restart from zero, the paper's
    /// failure model.  Tolerates a redundant crash of an already-down
    /// machine as a no-op (scripted machine-events traces contain them).
    /// Public so fault-injection tests can stage crashes deterministically
    /// without running the event loop.
    pub fn fail_machine(&mut self, machine: u32) {
        if !self.machines.is_up(machine) {
            return;
        }
        if let Some(asg) = self.machines.assignment(machine) {
            self.crash_copy(asg);
        }
        self.machines.mark_down(machine);
        self.machines_failed += 1;
        // capacity shrank: any cached wakeup horizon is stale, and the
        // scheduler must see the new idle count at the next slot
        self.sched_dirty = true;
        self.schedule_recover(machine);
    }

    /// Handle a `MachineRecover` event: the machine rejoins the
    /// allocatable pool and its next crash is scheduled.  Tolerates a
    /// redundant recovery of an up machine as a no-op (scripted traces).
    pub fn recover_machine(&mut self, machine: u32) {
        if self.machines.is_up(machine) {
            return;
        }
        self.machines.mark_up(machine);
        // capacity grew — queued work (including the relaunch backlog)
        // can place again at the next fired slot
        self.sched_dirty = true;
        self.schedule_fail(machine);
    }

    /// Kill one copy because its host crashed.  The stranded-ledger
    /// settlement is exactly `kill_copy`'s (the pending `CopyFinish` —
    /// and the `Checkpoint` of an unrevealed primary — pop later as
    /// settled no-ops or compact away); on top of it the loss is recorded
    /// (`copies_lost` / `work_lost`, per job and cluster-wide) and a task
    /// left with no surviving copy joins the relaunch backlog.
    fn crash_copy(&mut self, asg: Assignment) {
        let t = asg.task;
        let now = self.clock;
        let tid = self.tid(t);
        let cid = self.arena.copy_id(tid, asg.copy);
        debug_assert_eq!(self.arena.phase(cid), CopyPhase::Running);
        self.arena.set_phase(cid, CopyPhase::Killed);
        let c = self.arena.copy(cid);
        let used = c.elapsed(now).min(c.duration);
        let primary = self.arena.primary(cid);
        let stranded = if primary && !c.revealed { 2usize } else { 1 };
        let job = &mut self.jobs[t.job.0 as usize];
        job.machine_time += used;
        job.stranded += stranded as u32;
        job.copies_lost += 1;
        job.work_lost += used;
        self.total_machine_time += used;
        self.copies_lost += 1;
        self.work_lost += used;
        if !primary {
            self.outstanding_backups -= 1;
        }
        self.machines.release(c.machine);
        self.events.note_stale(stranded);
        self.sched_dirty = true;
        if self.cfg.sched_index {
            self.index.sync_task(&self.jobs[t.job.0 as usize], &self.arena, t);
            self.sync_est(t);
        }
        if !self.arena.done(tid) && self.arena.running_copies(tid) == 0 {
            self.requeued.push(t);
        }
        self.maybe_compact_events();
    }

    /// Re-launch a crashed-out task on an idle machine: restart from zero
    /// on the task's original sampled work (no RNG draw — churn never
    /// perturbs the backup-duration streams), as a new *primary* copy
    /// with its own detection checkpoint.  Re-execution is not
    /// speculation: exempt from `r_max` and counted in neither
    /// `speculative_launches` nor `outstanding_backups`; and because the
    /// task now has >= 2 copies it leaves every rule's candidate set (the
    /// scan and index paths agree on the `n_copies == 1` condition).
    /// Returns false when no machine is idle.
    fn relaunch_task(&mut self, t: TaskRef) -> bool {
        let now = self.clock;
        let ji = t.job.0 as usize;
        let tid = self.tid(t);
        if self.arena.done(tid) || self.arena.running_copies(tid) > 0 {
            return true; // settled while queued: nothing to re-execute
        }
        let copy_idx = self.arena.n_copies(tid);
        let Some(machine) = self.machines.alloc(Assignment { task: t, copy: copy_idx }) else {
            return false;
        };
        let work = self.first_durations[ji][t.task as usize];
        let duration = work / self.machines.effective_speed(machine);
        let k = self.arena.push_copy(tid, machine, now, duration, work);
        debug_assert_eq!(k, copy_idx);
        let cid = self.arena.copy_id(tid, copy_idx);
        self.arena.set_primary(cid);
        self.events
            .push(now + duration, Event::CopyFinish { task: t, copy: copy_idx, epoch: 0 });
        // the relaunch is the task's new original attempt, so the
        // monitoring model applies to it: a fresh detection checkpoint
        self.events.push(
            now + self.cfg.detect_frac * duration,
            Event::Checkpoint { task: t, copy: copy_idx, epoch: 0 },
        );
        self.sched_dirty = true;
        if self.cfg.sched_index {
            self.index.sync_task(&self.jobs[ji], &self.arena, t);
            self.sync_est(t);
        }
        true
    }

    /// Drain the crash-relaunch backlog onto idle machines, FIFO.  Called
    /// by [`SlotGate::slot`] just before a fired slot's `on_slot`, so
    /// re-execution takes priority over new launches and speculation at
    /// the same instant; whatever cannot place (no idle machine) stays
    /// queued for the next fired slot — and a slot always fires when
    /// capacity returns, because releases and recoveries set
    /// `sched_dirty`.
    pub(crate) fn drain_requeued(&mut self) {
        if self.requeued.is_empty() {
            return;
        }
        let mut w = 0;
        for r in 0..self.requeued.len() {
            let t = self.requeued[r];
            if !self.relaunch_task(t) {
                self.requeued[w] = t;
                w += 1;
            }
        }
        self.requeued.truncate(w);
    }

    /// Draw the machine's next up-time from its dedicated churn stream and
    /// push the crash event.  No stream exists when churn is disabled (or
    /// under a scripted machine-events replay), so those runs push
    /// nothing and draw nothing.
    fn schedule_fail(&mut self, machine: u32) {
        if self.churn_rngs.is_empty() {
            return;
        }
        let ch = self.cfg.churn.expect("churn streams exist only when configured");
        let up = self.churn_rngs[machine as usize].exponential(1.0 / ch.mttf);
        self.events.push(self.clock + up, Event::MachineFail { machine });
    }

    /// Draw the machine's repair time and push the recovery event (same
    /// stream discipline as [`Cluster::schedule_fail`]).
    fn schedule_recover(&mut self, machine: u32) {
        if self.churn_rngs.is_empty() {
            return;
        }
        let ch = self.cfg.churn.expect("churn streams exist only when configured");
        let repair = self.churn_rngs[machine as usize].exponential(1.0 / ch.mttr);
        self.events.push(self.clock + repair, Event::MachineRecover { machine });
    }

    /// Scripted churn (trace replay): push one machine-crash or -recovery
    /// event at an absolute instant.  `replay --machine-events` compiles a
    /// recorded ADD/REMOVE schedule through this in place of sampled
    /// MTTF/MTTR churn; with no `churn` config the handlers schedule no
    /// follow-up draws, so the script alone drives each machine's up/down
    /// trajectory.  Redundant events (REMOVE of a down machine, ADD of an
    /// up one) are tolerated as no-ops, as real traces contain them.
    pub fn inject_machine_event(&mut self, time: f64, machine: u32, fail: bool) {
        assert!(
            (machine as usize) < self.machines.total(),
            "machine {machine} out of range (cluster has {})",
            self.machines.total()
        );
        assert!(time >= self.clock, "machine event at {time} is in the past");
        let ev = if fail {
            Event::MachineFail { machine }
        } else {
            Event::MachineRecover { machine }
        };
        self.events.push(time, ev);
    }

    /// Compact the event heap once stale (killed-copy) entries outnumber
    /// live ones.  Removes only events that would pop as no-ops, so the
    /// simulation is bit-identical with or without compaction; the heap
    /// length, however, now tracks *active* copies rather than copies ever
    /// launched (see `EventQueue`).
    fn maybe_compact_events(&mut self) {
        if !self.events.should_compact() {
            return;
        }
        let Cluster { events, jobs, arena, .. } = self;
        // Liveness is the copy's phase alone — deliberately NOT `!done`:
        // when a completion's sibling-kill loop triggers compaction midway,
        // the not-yet-killed siblings (done task, still Running) must stay
        // in the heap, because their kill_copy calls will note_stale them
        // afterwards; removing them early would leave the stale counter
        // permanently overcounting.  A done task retains no other entries
        // (the finished copy's events have fired), so phase is exact.
        // Each removed dead entry also settles the owning job's `stranded`
        // ledger — compaction is the other place (besides a stale pop)
        // where a queue reference to an arena row disappears.
        events.retain_live(|ev| match *ev {
            Event::CopyFinish { task, copy, epoch } | Event::Checkpoint { task, copy, epoch } => {
                let job = &mut jobs[task.job.0 as usize];
                let cid = arena.copy_id(job.base + task.task, copy);
                // an entry superseded by a SlowdownFlip re-time (stale
                // epoch) is as dead as a killed copy's: both were
                // stale-counted when they were stranded
                let live =
                    arena.phase(cid) == CopyPhase::Running && arena.epoch(cid) == epoch;
                if !live {
                    job.stranded -= 1;
                }
                live
            }
            Event::Arrival(_)
            | Event::SlowdownFlip { .. }
            | Event::MachineFail { .. }
            | Event::MachineRecover { .. } => true,
        });
    }

    /// Handle a copy completing at the current clock.
    fn copy_finished(&mut self, t: TaskRef, copy: u32, epoch: u32) {
        let now = self.clock;
        let record_jobs = self.cfg.record_jobs;
        let gamma = self.cfg.gamma;
        let ji = t.job.0 as usize;
        let tid = self.tid(t);
        let cid = self.arena.copy_id(tid, copy);
        if self.arena.done(tid)
            || self.arena.phase(cid) != CopyPhase::Running
            || self.arena.epoch(cid) != epoch
        {
            // stale event (sibling finished first / copy killed / entry
            // superseded by a SlowdownFlip re-time) that outlived
            // compaction — settle the job's stranded ledger too
            self.events.note_stale_popped();
            self.jobs[ji].stranded -= 1;
            return;
        }
        self.arena.set_phase(cid, CopyPhase::Finished);
        let dur = self.arena.duration(cid);
        self.jobs[ji].machine_time += dur;
        self.total_machine_time += dur;
        self.arena.set_done(tid, now);
        self.sched_dirty = true;
        self.machines.release(self.arena.machine(cid));
        if !self.arena.primary(cid) {
            self.outstanding_backups -= 1;
        }
        // kill sibling copies and free their machines
        let n = self.arena.n_copies(tid);
        for k in 0..n {
            if k != copy {
                self.kill_copy(t, k);
            }
        }
        let job = &mut self.jobs[ji];
        job.unfinished -= 1;
        if job.unfinished == 0 {
            job.phase = JobPhase::Done;
            job.finish = Some(now);
            self.running.remove(&t.job);
            // arena rows become reusable once every stranded queue entry
            // referencing them has been settled; the live path checks that
            self.pending_recycle.push(t.job);
            if record_jobs {
                self.completed.push(JobRecord {
                    job: t.job.0,
                    arrival: job.spec.arrival,
                    num_tasks: job.spec.num_tasks,
                    mean_duration: job.spec.dist.mean(),
                    finish: now,
                    flowtime: now - job.spec.arrival,
                    resource: gamma * job.machine_time,
                    wait: job.first_sched.unwrap_or(now) - job.spec.arrival,
                });
            }
        }
        if self.cfg.sched_index {
            self.index.sync_task(&self.jobs[ji], &self.arena, t);
            // a finished task stops contributing to the est key
            self.sync_est(t);
            self.index.sync_job(&self.jobs[ji]);
        }
    }
}

/// Aggregated output of one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// The policy label — a canonical name or a composition spec string.
    pub scheduler: String,
    pub completed: Vec<JobRecord>,
    pub incomplete: u64,
    pub total_machine_time: f64,
    pub speculative_launches: u64,
    /// Copies killed by machine crashes (0 without churn).
    pub copies_lost: u64,
    /// Machine-time sunk into crashed copies — thrown-away work under the
    /// restart-from-zero failure model (0.0 without churn).
    pub work_lost: f64,
    /// Machine-crash events handled (0 without churn).
    pub machines_failed: u64,
    /// Machine-time / (M * horizon).
    pub utilization: f64,
    pub horizon: f64,
    /// Events popped by the run loop — the perf harness's throughput
    /// numerator (events/sec).  A pure function of the simulated system,
    /// identical across `sched_index` on/off *and* `wakeup` on/off (slot
    /// boundaries no longer live in the heap and are counted separately
    /// below).
    pub events_processed: u64,
    /// Grid slots whose `on_slot` actually ran.  With `wakeup = false`
    /// this is every grid point up to the horizon (the polled loop).
    pub ticks_fired: u64,
    /// Grid slots the wakeup planner proved to be no-ops and never ran.
    /// Always 0 with `wakeup = false`.
    pub ticks_skipped: u64,
    /// High-water mark of the event heap (must track active copies, not
    /// copies ever launched — see `EventQueue` hygiene).
    pub peak_event_queue: usize,
    /// Wall-clock spent inside the scheduler's `on_slot` hook — where the
    /// O(everything) scans used to live.  Timing only; never fed back
    /// into the simulation.
    pub slot_hook_secs: f64,
    /// Bounded-memory aggregation from a `--max-resident-jobs`-capped run:
    /// the records drained out of `completed` mid-run live on here as
    /// Welford moments + P² percentile sketches.  `None` on uncapped runs
    /// (every record retained in `completed`).
    pub streamed: Option<StreamedJobStats>,
}

impl SimResult {
    pub fn flowtime_cdf(&self) -> Cdf {
        let mut c = Cdf::new();
        c.extend(self.completed.iter().map(|r| r.flowtime));
        c
    }

    pub fn resource_cdf(&self) -> Cdf {
        let mut c = Cdf::new();
        c.extend(self.completed.iter().map(|r| r.resource));
        c
    }

    pub fn mean_flowtime(&self) -> f64 {
        self.flowtime_cdf().mean()
    }

    pub fn mean_resource(&self) -> f64 {
        self.resource_cdf().mean()
    }

    /// The paper's fairness metric: job utility minus resource consumption,
    /// with U = -flowtime.
    pub fn mean_net_utility(&self) -> f64 {
        if self.completed.is_empty() {
            return f64::NAN;
        }
        self.completed
            .iter()
            .map(|r| -r.flowtime - r.resource)
            .sum::<f64>()
            / self.completed.len() as f64
    }
}

/// The demand-driven wakeup planner's slot gate, shared by the batch run
/// loop ([`Simulator::run`]) and the live master (`coordinator::master`).
///
/// The slot grid itself is unchanged — decisions stay quantized to the
/// `slot_dt` chain — but a grid slot only *runs the scheduler* when one
/// of two wakeup conditions holds:
///
/// 1. **dirty** — some cluster mutation happened since the last fired
///    slot ([`Cluster::sched_dirty`]: arrival, launch, kill, finish,
///    checkpoint reveal — every point the `SchedIndex` already hooks);
/// 2. **a time-dependent predicate may have flipped** — the scheduler's
///    [`Scheduler::next_decision_time`] horizon (computed lazily at the
///    first clean slot after a fired one, from what is then still the
///    post-`on_slot` state) falls at or before this slot.
///
/// When neither holds the slot is a provable no-op: after a fired slot,
/// launchable work remains only when the cluster is full (any idle-count
/// change is a mutation), and the per-rule horizons bound exactly when
/// Mantri's delta-gate, LATE's progress-rate window or ESE's
/// sigma-threshold can next flip on their own (DESIGN.md §12 carries the
/// per-rule derivations).  Skipped slots therefore change nothing the
/// polled loop would have observed — pinned byte-identical by
/// `tests/pipeline_equivalence.rs`.
///
/// [`Scheduler::next_decision_time`]: crate::scheduler::Scheduler::next_decision_time
pub struct SlotGate {
    enabled: bool,
    /// The scheduler's wakeup horizon, computed **lazily** at the first
    /// clean (non-dirty) slot after a fired one: outer `None` = stale,
    /// `Some(inner)` = valid since the last fired slot, where the inner
    /// `None` means only a mutation can make a future slot act.  Busy
    /// regimes — where the dirty flag short-circuits every slot — never
    /// pay for a horizon they would discard.
    hint: Option<Option<f64>>,
    /// Slots that ran `on_slot` / slots proven no-ops and skipped.
    pub fired: u64,
    pub skipped: u64,
    /// Wall-clock spent inside fired slots (`Scheduler::on_slot`) — the
    /// [`SimResult::slot_hook_secs`] source.  Timed here, inside the
    /// fire branch, so a skipped slot never takes a timestamp: the skip
    /// path costs exactly the flag/hint check the design promises.
    pub hook: std::time::Duration,
}

impl SlotGate {
    /// `enabled = false` fires every slot — the retired polling loop,
    /// kept as the wakeup equivalence reference (`--no-wakeup`).
    pub fn new(enabled: bool) -> Self {
        SlotGate { enabled, hint: None, fired: 0, skipped: 0, hook: std::time::Duration::ZERO }
    }

    /// Must the slot at grid time `t` run the scheduler?  Deferring the
    /// horizon query to the first clean slot is exact, not approximate:
    /// with no mutations since the fired slot the cluster state is the
    /// post-`on_slot` state, and every horizon is either an absolute
    /// flip instant (the clock cancels out of `start + e*`) or "now"
    /// (`<= t` whenever it was `<=` the fired slot's time).
    fn due(&mut self, cl: &Cluster, sched: &dyn Scheduler, t: f64) -> bool {
        if !self.enabled || cl.sched_dirty {
            return true;
        }
        let hint = *self.hint.get_or_insert_with(|| sched.next_decision_time(cl));
        matches!(hint, Some(h) if h <= t)
    }

    /// Run the slot at grid time `t`: fire `on_slot` when due (clearing
    /// the dirty flag and invalidating the cached horizon), count it
    /// skipped otherwise.  Returns whether it fired.  The caller must
    /// have processed every event with time `<= t` first — a slot
    /// observes all simultaneous events (DESIGN.md §12).
    pub fn slot(&mut self, cl: &mut Cluster, sched: &mut dyn Scheduler, t: f64) -> bool {
        if self.due(cl, &*sched, t) {
            let t0 = std::time::Instant::now();
            cl.clock = t;
            // crash re-execution places first: a lost task is older work
            // than anything the scheduler would launch this slot
            cl.drain_requeued();
            sched.on_slot(cl);
            self.hook += t0.elapsed();
            cl.sched_dirty = false;
            self.hint = None; // recompute at the next clean slot
            self.fired += 1;
            true
        } else {
            self.skipped += 1;
            false
        }
    }
}

/// Drives the event loop: arrivals, copy completions, checkpoints, and
/// the slot grid (interleaved by the wakeup planner — slots no longer
/// live in the event heap).
pub struct Simulator {
    pub cluster: Cluster,
    scheduler: Box<dyn Scheduler>,
    /// Lazy arrival feed for streaming replay (`Simulator::from_source`):
    /// jobs are pulled through the bounded lookahead window and admitted
    /// exactly where the eager loop would pop their `Arrival` events.
    /// `None` = eager mode (every arrival pre-pushed into the queue).
    stream: Option<StreamFeed>,
}

struct StreamFeed {
    pending: Lookahead,
    /// The per-job RNG root `Cluster::new` would have split eagerly;
    /// `admit_streamed` splits it at admission time in the same order.
    root: Pcg64,
}

impl Simulator {
    pub fn new(cfg: SimConfig, workload: Workload, scheduler: Box<dyn Scheduler>) -> Self {
        let mut cluster = Cluster::new(cfg, workload, 0x5eed);
        for (i, job) in cluster.jobs.iter().enumerate() {
            let t = job.spec.arrival;
            cluster.events.push(t, Event::Arrival(JobId(i as u32)));
        }
        Simulator { cluster, scheduler, stream: None }
    }

    /// Streaming replay: pull arrivals lazily from `source` as the clock
    /// advances, holding at most `window` un-admitted jobs resident
    /// (`0` selects [`crate::workload::DEFAULT_WINDOW`]).
    ///
    /// An uncapped streamed run is bit-identical to `Simulator::new` over
    /// the materialized workload: the cluster starts from the same empty
    /// construction (same seed-stream RNG layout), and each admission
    /// replays the eager per-job RNG split in dense-id order.  The one
    /// measure-zero exception: a job arriving at the exact instant of a
    /// machine's *initial* `SlowdownFlip` event admits before the flip
    /// here but after it eagerly (DESIGN.md §16).
    pub fn from_source(
        cfg: SimConfig,
        source: Box<dyn JobSource>,
        window: usize,
        scheduler: Box<dyn Scheduler>,
    ) -> Self {
        let root = Pcg64::new(cfg.seed, 0x5eed);
        let cluster =
            Cluster::new(cfg, Workload { specs: Vec::new(), first_durations: Vec::new() }, 0x5eed);
        let window = if window == 0 { crate::workload::DEFAULT_WINDOW } else { window };
        Simulator {
            cluster,
            scheduler,
            stream: Some(StreamFeed { pending: Lookahead::new(source, window), root }),
        }
    }

    /// Run to the horizon and aggregate.
    ///
    /// The slot grid is the same repeated-addition chain the polled loop
    /// re-armed (`t += slot_dt`, bit-identical grid points), with the tie
    /// rule that events at exactly a grid time process *before* that
    /// slot; the [`SlotGate`] then decides fire vs skip per grid point.
    pub fn run(mut self) -> SimResult {
        let horizon = self.cluster.cfg.horizon;
        let slot_dt = self.cluster.cfg.slot_dt;
        let cap = self.cluster.cfg.max_resident_jobs;
        let mut sink = cap.map(|_| StreamedJobStats::new());
        let mut gate = SlotGate::new(self.cluster.cfg.wakeup);
        let mut next_slot = 0.0_f64;
        let mut events_processed: u64 = 0;
        loop {
            let slot_pending = next_slot <= horizon;
            // events strictly before the grid head — and at exactly the
            // grid head — go first (a slot observes its instant fully)
            let next_event = self.cluster.events.peek_time();
            // a streamed arrival is admitted exactly where the eager loop
            // would pop its Arrival event: it loses ties to nothing (the
            // eager event was pushed at t = 0 with the lowest sequence
            // numbers) and defers to the grid head like any event
            let next_arrival = self.stream.as_mut().and_then(|f| f.pending.peek_arrival());
            if let Some(feed) = &self.stream {
                if next_arrival.is_none() {
                    if let Some(e) = feed.pending.error() {
                        panic!("trace replay failed: {e}");
                    }
                }
            }
            let take_arrival = next_arrival.is_some_and(|at| {
                at <= horizon
                    && next_event.is_none_or(|et| at <= et)
                    && (!slot_pending || at <= next_slot)
            });
            if take_arrival {
                let feed = self.stream.as_mut().expect("arrival implies a stream");
                let job = feed.pending.take().expect("peeked arrival");
                self.cluster.clock = job.spec.arrival;
                events_processed += 1;
                self.cluster.admit_streamed(job, &mut feed.root);
                continue;
            }
            let take_event = next_event.is_some_and(|et| !slot_pending || et <= next_slot);
            if take_event {
                let (time, event) = self.cluster.events.pop().unwrap();
                if time > horizon {
                    break;
                }
                self.cluster.clock = time;
                events_processed += 1;
                match event {
                    Event::Arrival(id) => self.cluster.arrive(id),
                    Event::CopyFinish { task, copy, epoch } => {
                        self.cluster.copy_finished(task, copy, epoch);
                        if let Some(sink) = &mut sink {
                            self.cluster.drain_completed_into(sink);
                        }
                    }
                    Event::Checkpoint { task, copy, epoch } => {
                        if self.cluster.reveal_copy(task, copy, epoch) {
                            self.scheduler.on_reveal(&mut self.cluster, task);
                        }
                    }
                    Event::SlowdownFlip { machine } => {
                        if let Some(task) = self.cluster.flip_machine(machine) {
                            self.scheduler.on_reveal(&mut self.cluster, task);
                        }
                    }
                    Event::MachineFail { machine } => self.cluster.fail_machine(machine),
                    Event::MachineRecover { machine } => self.cluster.recover_machine(machine),
                }
            } else if slot_pending {
                gate.slot(&mut self.cluster, self.scheduler.as_mut(), next_slot);
                next_slot += slot_dt;
            } else {
                break; // no arrivals or events left, no slots within the horizon
            }
        }
        let mut cl = self.cluster;
        let incomplete = cl
            .jobs
            .iter()
            .filter(|j| j.spec.arrival <= horizon && j.phase != JobPhase::Done)
            .count() as u64;
        let streamed = sink.map(|mut s| {
            // final drain: sketch the records still resident so capped
            // aggregates cover every completed job
            for r in cl.completed.drain(..) {
                s.absorb(&r);
            }
            s
        });
        SimResult {
            scheduler: self.scheduler.name().to_string(),
            utilization: cl.total_machine_time / (cl.machines.total() as f64 * horizon),
            completed: cl.completed,
            incomplete,
            total_machine_time: cl.total_machine_time,
            speculative_launches: cl.speculative_launches,
            copies_lost: cl.copies_lost,
            work_lost: cl.work_lost,
            machines_failed: cl.machines_failed,
            horizon,
            events_processed,
            ticks_fired: gate.fired,
            ticks_skipped: gate.skipped,
            peak_event_queue: cl.events.peak_len(),
            slot_hook_secs: gate.hook.as_secs_f64(),
            streamed,
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::generator;
    use crate::config::WorkloadConfig;
    use crate::scheduler;

    fn small_cfg() -> SimConfig {
        SimConfig {
            machines: 50,
            horizon: 200.0,
            seed: 7,
            ..SimConfig::default()
        }
    }

    fn run_with(kind: scheduler::SchedulerKind) -> SimResult {
        let mut cfg = small_cfg();
        cfg.scheduler = kind;
        let wl = generator::generate(
            &WorkloadConfig::Poisson {
                lambda: 0.3,
                m_lo: 1,
                m_hi: 10,
                mean_lo: 1.0,
                mean_hi: 2.0,
                alpha: 2.0,
            },
            cfg.horizon,
            cfg.seed,
        );
        let sched = scheduler::build(&cfg, &WorkloadConfig::paper(0.3)).unwrap();
        Simulator::new(cfg, wl, sched).run()
    }

    #[test]
    fn naive_completes_jobs() {
        let res = run_with(scheduler::SchedulerKind::Naive);
        assert!(res.completed.len() > 20, "completed {}", res.completed.len());
        for r in &res.completed {
            assert!(r.flowtime > 0.0);
            assert!(r.resource > 0.0);
            assert!(r.finish <= res.horizon);
        }
    }

    #[test]
    fn machine_accounting_conserves() {
        let res = run_with(scheduler::SchedulerKind::Naive);
        // utilization must be a sane fraction
        assert!(res.utilization > 0.0 && res.utilization < 1.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_with(scheduler::SchedulerKind::Naive);
        let b = run_with(scheduler::SchedulerKind::Naive);
        assert_eq!(a.completed.len(), b.completed.len());
        assert_eq!(a.total_machine_time, b.total_machine_time);
    }

    #[test]
    fn run_reports_perf_instrumentation() {
        let res = run_with(scheduler::SchedulerKind::Sda);
        assert!(res.events_processed > 0, "run loop should count events");
        assert!(res.peak_event_queue > 0, "heap high-water mark should be set");
        assert!(res.slot_hook_secs >= 0.0);
        // events are a pure function of the simulated system, so the
        // count is identical across repeat runs
        assert_eq!(res.events_processed, run_with(scheduler::SchedulerKind::Sda).events_processed);
    }

    /// Mid-run spot check of the index ⇄ scan agreement: drive a live
    /// cluster with `advance_to` and compare the index's χ(l) order and
    /// queued-task counter against the naive scans at every step.
    #[test]
    fn index_matches_scans_under_advance_to() {
        let mut cfg = small_cfg();
        cfg.machines = 10;
        cfg.horizon = f64::INFINITY;
        cfg.scheduler = scheduler::SchedulerKind::Sda;
        cfg.use_runtime = false;
        let mut sched = scheduler::build(&cfg, &WorkloadConfig::paper(0.3)).unwrap();
        let mut cl = Cluster::new_live(cfg);
        let mut rng = crate::stats::Pcg64::new(9, 0);
        for step in 0..120u32 {
            if step % 3 == 0 {
                cl.add_job(1.0 + rng.next_f64(), 2.0, 1 + (step % 7));
            }
            let t = cl.clock + 0.5;
            cl.advance_to(t, sched.as_mut());
            sched.on_slot(&mut cl);
            let indexed: Vec<JobId> = cl.index.queued_jobs().collect();
            assert_eq!(indexed, cl.chi_sorted(), "χ(l) order diverged at step {step}");
            let scan_tasks: usize =
                cl.queued.iter().map(|id| cl.job(*id).spec.num_tasks as usize).sum();
            assert_eq!(cl.index.queued_task_count(), scan_tasks);
        }
        assert!(!cl.completed.is_empty(), "live cluster should complete jobs");
    }

    /// The wakeup planner's unit bar: at light load (λ = 0.3) most grid
    /// slots are provable no-ops and are skipped, while the planner-on
    /// and planner-off (polled) runs remain identical in every simulated
    /// quantity — same completions, same machine time, same event count.
    #[test]
    fn wakeup_skips_noop_slots_at_light_load() {
        let run_wakeup = |wakeup: bool, kind: scheduler::SchedulerKind| {
            let mut cfg = small_cfg();
            cfg.machines = 200;
            cfg.horizon = 120.0;
            // a fine grid: the polling-dominated regime the planner targets
            cfg.slot_dt = 0.1;
            cfg.scheduler = kind;
            cfg.wakeup = wakeup;
            cfg.use_runtime = false;
            let wl_cfg = WorkloadConfig::paper(0.3);
            let wl = generator::generate(&wl_cfg, cfg.horizon, cfg.seed);
            let sched = scheduler::build_for(&cfg, &wl_cfg, Some(&wl)).unwrap();
            Simulator::new(cfg, wl, sched).run()
        };
        for kind in scheduler::SchedulerKind::all() {
            let on = run_wakeup(true, kind);
            let off = run_wakeup(false, kind);
            // LATE's rate-flip bound collapses to "now" whenever a
            // candidate past the Pareto scale sits tied at the percentile
            // threshold (its denominator grows immediately), so steady
            // mixed-age stretches fire every slot and this workload need
            // not leave it any skips — see `late_skips_quiet_tail` for
            // the stretches it *must* skip; every other policy must skip
            // plenty here
            if kind != scheduler::SchedulerKind::Late {
                assert!(on.ticks_skipped > 0, "{kind:?}: no slots skipped at lambda = 0.3");
            }
            assert_eq!(off.ticks_skipped, 0, "{kind:?}: polled loop must fire every slot");
            assert_eq!(
                on.ticks_fired + on.ticks_skipped,
                off.ticks_fired,
                "{kind:?}: the slot grid itself must be identical"
            );
            assert_eq!(on.completed.len(), off.completed.len(), "{kind:?}");
            assert_eq!(on.total_machine_time, off.total_machine_time, "{kind:?}");
            assert_eq!(on.speculative_launches, off.speculative_launches, "{kind:?}");
            assert_eq!(on.events_processed, off.events_processed, "{kind:?}");
            for (a, b) in on.completed.iter().zip(&off.completed) {
                assert_eq!(a.job, b.job, "{kind:?}");
                assert_eq!(a.flowtime.to_bits(), b.flowtime.to_bits(), "{kind:?}");
                assert_eq!(a.resource.to_bits(), b.resource.to_bits(), "{kind:?}");
            }
        }
    }

    /// LATE does skip once the cluster goes quiet: a single early job on
    /// an ample cluster leaves a long tail of slots with no running
    /// single-copy task — all provable no-ops.
    #[test]
    fn late_skips_quiet_tail() {
        let mut cfg = small_cfg();
        cfg.machines = 50;
        cfg.horizon = 100.0;
        cfg.scheduler = scheduler::SchedulerKind::Late;
        cfg.use_runtime = false;
        let wl = generator::generate(
            &WorkloadConfig::SingleJob { tasks: 10, mean: 1.0, alpha: 2.0 },
            cfg.horizon,
            cfg.seed,
        );
        let sched = scheduler::build(&cfg, &WorkloadConfig::paper(0.3)).unwrap();
        let res = Simulator::new(cfg, wl, sched).run();
        assert_eq!(res.completed.len(), 1);
        assert!(
            res.ticks_skipped > 0,
            "LATE should skip the quiet tail after the job drains"
        );
    }

    /// Live-mode spot check: a [`SlotGate`]-driven `advance_to` loop makes
    /// the identical decisions as one that fires the scheduler on every
    /// slot, while actually skipping some.
    #[test]
    fn live_slot_gate_matches_always_firing() {
        let live = |gated: bool| {
            let mut cfg = small_cfg();
            cfg.machines = 30;
            cfg.horizon = f64::INFINITY;
            cfg.scheduler = scheduler::SchedulerKind::Sda;
            cfg.use_runtime = false;
            let mut sched = scheduler::build(&cfg, &WorkloadConfig::paper(0.3)).unwrap();
            let mut cl = Cluster::new_live(cfg);
            let mut gate = SlotGate::new(gated);
            let mut rng = crate::stats::Pcg64::new(17, 0);
            for step in 0..400u32 {
                if step % 9 == 0 {
                    cl.add_job(1.0 + rng.next_f64(), 2.0, 1 + (step % 5));
                }
                let t = cl.clock + 0.5;
                cl.advance_to(t, sched.as_mut());
                gate.slot(&mut cl, sched.as_mut(), t);
            }
            (cl, gate)
        };
        let (polled_cl, polled_gate) = live(false);
        let (gated_cl, gated_gate) = live(true);
        assert_eq!(polled_gate.skipped, 0);
        assert!(gated_gate.skipped > 0, "the live gate should skip quiet slots");
        assert!(!gated_cl.completed.is_empty());
        assert_eq!(gated_cl.completed.len(), polled_cl.completed.len());
        assert_eq!(gated_cl.total_machine_time, polled_cl.total_machine_time);
        assert_eq!(gated_cl.speculative_launches, polled_cl.speculative_launches);
    }

    #[test]
    fn speculation_counts_only_for_cloners() {
        let naive = run_with(scheduler::SchedulerKind::Naive);
        assert_eq!(naive.speculative_launches, 0);
        let clone = run_with(scheduler::SchedulerKind::CloneAll);
        assert!(clone.speculative_launches > 0);
    }

    #[test]
    fn machine_speed_scales_copy_durations() {
        use crate::cluster::machine::MachineClass;
        // identical single-job workload on a speed-1 and a speed-2 cluster:
        // with one machine per task and no queueing, every copy's wall-clock
        // (and hence the job's flowtime) halves exactly
        let run_at = |speed: f64| {
            let mut cfg = small_cfg();
            cfg.horizon = 5000.0;
            cfg.set_machine_classes(vec![MachineClass::new(50, speed)]);
            let wl = generator::generate(
                &WorkloadConfig::SingleJob { tasks: 50, mean: 1.0, alpha: 2.0 },
                cfg.horizon,
                cfg.seed,
            );
            let sched = scheduler::build(&cfg, &WorkloadConfig::paper(0.3)).unwrap();
            Simulator::new(cfg, wl, sched).run()
        };
        let slow = run_at(1.0);
        let fast = run_at(2.0);
        assert_eq!(slow.completed.len(), 1);
        assert_eq!(fast.completed.len(), 1);
        let (s, f) = (slow.completed[0].flowtime, fast.completed[0].flowtime);
        assert!(
            (f - s / 2.0).abs() < cfg_slot_slack(),
            "fast flowtime {f} vs half of slow {s}"
        );
        assert!(
            (fast.total_machine_time - slow.total_machine_time / 2.0).abs() < 1e-6,
            "machine time should halve: {} vs {}",
            fast.total_machine_time,
            slow.total_machine_time
        );
    }

    /// Flowtimes include up-to-one-slot launch quantization; durations halve
    /// exactly, so the tolerance is just numerical.
    fn cfg_slot_slack() -> f64 {
        1e-9
    }

    #[test]
    fn slowdown_inflates_wall_clock() {
        use crate::cluster::machine::SlowdownConfig;
        // frac = 1 degrades every machine deterministically: a uniform 3x
        // slowdown must exactly triple the single job's flowtime and the
        // machine-time it consumes
        let run_sd = |slowdown: Option<SlowdownConfig>| {
            let mut cfg = small_cfg();
            cfg.horizon = 5000.0;
            cfg.slowdown = slowdown;
            let wl = generator::generate(
                &WorkloadConfig::SingleJob { tasks: 50, mean: 1.0, alpha: 2.0 },
                cfg.horizon,
                cfg.seed,
            );
            let sched = scheduler::build(&cfg, &WorkloadConfig::paper(0.3)).unwrap();
            Simulator::new(cfg, wl, sched).run()
        };
        let healthy = run_sd(None);
        let degraded = run_sd(Some(SlowdownConfig::new(1.0, 3.0)));
        assert_eq!(healthy.completed.len(), 1);
        assert_eq!(degraded.completed.len(), 1);
        let (h, d) = (healthy.completed[0].flowtime, degraded.completed[0].flowtime);
        assert!((d - 3.0 * h).abs() < 1e-9, "3x slowdown should triple flowtime: {h} vs {d}");
        assert!(
            (degraded.total_machine_time - 3.0 * healthy.total_machine_time).abs() < 1e-6,
            "machine time should triple"
        );
    }

    /// Pin the `SlowdownFlip` re-time arithmetic end to end on one copy:
    /// degradation mid-flight stretches the duration and the pending
    /// checkpoint exactly, the reveal on the re-timed checkpoint stamps
    /// the observed throughput, recovery re-times again (returning the
    /// re-detect signal and refreshing the stamp), and the copy finishes
    /// at the final re-timed instant with every superseded queue entry
    /// settled against the stranded ledger.
    #[test]
    fn flip_retimes_running_copy_exactly() {
        use crate::cluster::machine::SlowdownConfig;
        let mut cfg = small_cfg();
        cfg.machines = 1;
        cfg.detect_frac = 0.25;
        cfg.scheduler = scheduler::SchedulerKind::Naive;
        cfg.use_runtime = false;
        // frac 0 + zero rates: no machine starts degraded and no dwell
        // streams exist — the test drives `flip_machine` by hand
        cfg.slowdown = Some(SlowdownConfig::new(0.0, 4.0));
        let dist = crate::stats::Pareto::from_mean(1.0, 2.0);
        let wl = Workload {
            specs: vec![JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: 1 }],
            first_durations: vec![vec![8.0]],
        };
        let sched = scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let mut driver = scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let mut cl = Simulator::new(cfg, wl, sched).cluster;
        let t = TaskRef { job: JobId(0), task: 0 };
        cl.advance_to(0.0, driver.as_mut()); // the arrival fires
        assert!(cl.launch_copy(t));
        assert_eq!(cl.copy(t, 0).duration, 8.0); // checkpoint pending at 2
        cl.advance_to(1.0, driver.as_mut());
        // healthy -> 4x degraded at t = 1: 7 remaining wall units are
        // 7 work units, now delivered at speed 1/4 — finish at 29, and
        // the 25%-work point (2 of 8) lands at 29 - 24 = 5
        assert_eq!(cl.flip_machine(0), None, "an unrevealed copy never re-detects");
        assert_eq!(cl.copy(t, 0).duration, 29.0);
        let cid = cl.arena.copy_id(cl.tid(t), 0);
        assert_eq!(cl.arena.epoch(cid), 1);
        assert_eq!(cl.job(JobId(0)).stranded, 2, "superseded CopyFinish + Checkpoint");
        // the superseded epoch-0 checkpoint (still at t = 2) pops as a
        // settled no-op: no reveal, one stranded entry retired
        cl.advance_to(4.9, driver.as_mut());
        assert!(!cl.copy(t, 0).revealed);
        assert_eq!(cl.job(JobId(0)).stranded, 1);
        // the re-timed checkpoint reveals at t = 5 and stamps the copy's
        // lifetime throughput: 2 work units over 5 wall units
        cl.advance_to(5.0, driver.as_mut());
        assert!(cl.copy(t, 0).revealed);
        assert_eq!(cl.arena.obs_speed(cid), 0.4);
        // recovery at t = 6: 23 remaining wall units at speed 1/4 are
        // 5.75 work units, delivered at full speed — finish at 11.75;
        // the revealed copy re-detects and the stamp refreshes to
        // 2.25 work units over 6 wall units
        cl.advance_to(6.0, driver.as_mut());
        assert_eq!(cl.flip_machine(0), Some(t), "a revealed copy re-detects");
        assert_eq!(cl.copy(t, 0).duration, 11.75);
        assert_eq!(cl.arena.epoch(cid), 2);
        assert_eq!(cl.arena.obs_speed(cid), 0.375);
        // both superseded CopyFinish entries (at 8 and 29) pop as no-ops
        // around the live finish at 11.75
        cl.advance_to(40.0, driver.as_mut());
        assert_eq!(cl.completed.len(), 1);
        assert_eq!(cl.completed[0].flowtime, 11.75);
        assert_eq!(cl.job(JobId(0)).stranded, 0, "every stale entry settled");
        assert_eq!(cl.machines.idle(), 1);
    }

    /// Pin the crash/recovery machinery end to end on one copy: the crash
    /// kills the resident copy (work lost, loss ledgers updated), the
    /// task joins the relaunch backlog (visible to backpressure), the
    /// next fired slot relaunches it from zero as a new primary copy on a
    /// surviving machine, and every crash-stranded queue entry settles.
    #[test]
    fn machine_crash_requeues_and_relaunches() {
        let mut cfg = small_cfg();
        cfg.machines = 2;
        cfg.detect_frac = 0.25;
        cfg.scheduler = scheduler::SchedulerKind::Naive;
        cfg.use_runtime = false;
        let dist = crate::stats::Pareto::from_mean(1.0, 2.0);
        let wl = Workload {
            specs: vec![JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: 1 }],
            first_durations: vec![vec![8.0]],
        };
        let sched = scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let mut driver = scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let mut cl = Simulator::new(cfg, wl, sched).cluster;
        let t = TaskRef { job: JobId(0), task: 0 };
        cl.advance_to(0.0, driver.as_mut()); // the arrival fires
        assert!(cl.launch_copy(t));
        assert_eq!(cl.copy(t, 0).machine, 0);
        // the machine crashes at t = 1: one wall unit of work is lost and
        // the task (no surviving copy) queues for re-execution
        cl.inject_machine_event(1.0, 0, true);
        cl.advance_to(1.5, driver.as_mut());
        assert!(!cl.machines.is_up(0));
        assert_eq!(cl.machines.down(), 1);
        assert_eq!(cl.idle(), 1, "the down machine is not idle capacity");
        assert_eq!(cl.job(JobId(0)).copies_lost, 1);
        assert_eq!(cl.job(JobId(0)).work_lost, 1.0);
        assert_eq!(cl.copies_lost, 1);
        assert_eq!(cl.work_lost, 1.0);
        assert_eq!(cl.machines_failed, 1);
        assert_eq!(cl.queued_tasks(), 1, "the relaunch backlog is queued work");
        assert_eq!(
            cl.job(JobId(0)).stranded,
            2,
            "crash strands the unrevealed primary's CopyFinish + Checkpoint"
        );
        assert!(cl.sched_dirty, "a crash must force the next slot");
        // the stranded epoch-0 checkpoint (still at t = 2) settles as a
        // no-op, then the slot at t = 2 drains the backlog before the
        // scheduler runs: restart from zero on the same sampled work
        cl.advance_to(2.0, driver.as_mut());
        assert_eq!(cl.job(JobId(0)).stranded, 1);
        let mut gate = SlotGate::new(true);
        assert!(gate.slot(&mut cl, driver.as_mut(), 2.0));
        assert_eq!(cl.n_copies(t), 2);
        let relaunch = cl.arena.copy_id(cl.tid(t), 1);
        assert!(cl.arena.primary(relaunch), "a relaunch is the task's new original");
        assert_eq!(cl.copy(t, 1).machine, 1, "a down machine is never allocated");
        assert_eq!(cl.copy(t, 1).duration, 8.0, "restart from zero, same work");
        assert_eq!(cl.queued_tasks(), 0);
        assert_eq!(cl.speculative_launches, 0, "re-execution is not speculation");
        assert_eq!(cl.outstanding_backups, 0);
        // recovery at t = 3 returns the machine to the pool; the relaunch
        // reveals at 2 + 0.25*8 = 4 and finishes at 2 + 8 = 10
        cl.inject_machine_event(3.0, 0, false);
        cl.advance_to(20.0, driver.as_mut());
        assert!(cl.machines.is_up(0));
        assert_eq!(cl.completed.len(), 1);
        assert_eq!(cl.completed[0].flowtime, 10.0);
        assert!(cl.copy(t, 1).revealed, "the relaunch carries its own checkpoint");
        assert_eq!(cl.job(JobId(0)).stranded, 0, "every crash-stranded entry settled");
        assert_eq!(cl.idle(), 2);
        // lost work still occupied a machine: 1 lost + 8 useful
        assert_eq!(cl.total_machine_time, 9.0);
    }

    /// Redundant scripted events are no-ops: a second REMOVE of a down
    /// machine and an ADD of an up machine change nothing (real
    /// machine-events traces contain both).
    #[test]
    fn redundant_machine_events_are_noops() {
        let mut cfg = small_cfg();
        cfg.machines = 2;
        cfg.scheduler = scheduler::SchedulerKind::Naive;
        cfg.use_runtime = false;
        let mut driver = scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let mut cl = Cluster::new_live(cfg);
        cl.inject_machine_event(1.0, 0, false); // ADD while up: no-op
        cl.inject_machine_event(2.0, 0, true);
        cl.inject_machine_event(3.0, 0, true); // REMOVE while down: no-op
        cl.inject_machine_event(4.0, 0, false);
        cl.advance_to(10.0, driver.as_mut());
        assert!(cl.machines.is_up(0));
        assert_eq!(cl.machines.down(), 0);
        assert_eq!(cl.machines_failed, 1, "only the first REMOVE counts");
        assert_eq!(cl.idle(), 2);
    }

    /// The churn axis is real and its zero point is exact: enabling
    /// crash/recovery adds events and loses work, while `None` and a
    /// zero-rate spec produce bit-identical runs (no churn stream even
    /// exists).
    #[test]
    fn churn_changes_the_run_and_zero_rates_do_not() {
        use crate::cluster::machine::ChurnConfig;
        let run = |churn: Option<ChurnConfig>| {
            let mut cfg = small_cfg();
            cfg.horizon = 120.0;
            cfg.scheduler = scheduler::SchedulerKind::Sda;
            cfg.use_runtime = false;
            cfg.churn = churn;
            let wl_cfg = WorkloadConfig::paper(0.3);
            let wl = generator::generate(&wl_cfg, cfg.horizon, cfg.seed);
            let sched = scheduler::build_for(&cfg, &wl_cfg, Some(&wl)).unwrap();
            Simulator::new(cfg, wl, sched).run()
        };
        let still = run(None);
        let zero = run(Some(ChurnConfig::new(0.0, 0.0)));
        let churning = run(Some(ChurnConfig::new(40.0, 10.0)));
        assert_eq!(still.copies_lost, 0);
        assert_eq!(still.work_lost, 0.0);
        assert_eq!(still.machines_failed, 0);
        assert!(churning.machines_failed > 0, "MTTF 40 over 120 units must crash machines");
        assert!(churning.copies_lost > 0, "crashes must catch running copies");
        assert!(churning.work_lost > 0.0);
        assert!(
            churning.events_processed > still.events_processed,
            "churn must add events: {} vs {}",
            churning.events_processed,
            still.events_processed
        );
        assert_ne!(
            churning.total_machine_time.to_bits(),
            still.total_machine_time.to_bits(),
            "churn must move machine time"
        );
        // zero rates ARE the churn-free scenario, bit for bit
        assert_eq!(zero.events_processed, still.events_processed);
        assert_eq!(zero.total_machine_time.to_bits(), still.total_machine_time.to_bits());
        assert_eq!(zero.completed.len(), still.completed.len());
        for (a, b) in zero.completed.iter().zip(&still.completed) {
            assert_eq!(a.flowtime.to_bits(), b.flowtime.to_bits());
            assert_eq!(a.resource.to_bits(), b.resource.to_bits());
        }
    }

    /// The equivalence matrix with churn enabled: crashes, repair draws
    /// and relaunches are a pure function of the simulated system, so
    /// every event-queue backend x wakeup x index combination produces
    /// the same run, bit for bit.
    #[test]
    fn churn_runs_identical_across_backends_wakeup_and_index() {
        use crate::cluster::event::EventQueueKind;
        use crate::cluster::machine::ChurnConfig;
        let run = |queue: EventQueueKind, wakeup: bool, sched_index: bool| {
            let mut cfg = small_cfg();
            cfg.horizon = 120.0;
            cfg.scheduler = scheduler::SchedulerKind::Sda;
            cfg.use_runtime = false;
            cfg.churn = Some(ChurnConfig::new(40.0, 10.0));
            cfg.event_queue = queue;
            cfg.wakeup = wakeup;
            cfg.sched_index = sched_index;
            let wl_cfg = WorkloadConfig::paper(0.3);
            let wl = generator::generate(&wl_cfg, cfg.horizon, cfg.seed);
            let sched = scheduler::build_for(&cfg, &wl_cfg, Some(&wl)).unwrap();
            Simulator::new(cfg, wl, sched).run()
        };
        let reference = run(EventQueueKind::Calendar, false, false);
        assert!(!reference.completed.is_empty());
        assert!(reference.copies_lost > 0, "the matrix must exercise crash kills");
        for queue in [EventQueueKind::Calendar, EventQueueKind::BinaryHeap] {
            for wakeup in [false, true] {
                for sched_index in [false, true] {
                    let res = run(queue, wakeup, sched_index);
                    let tag = format!("{queue:?}/wakeup={wakeup}/index={sched_index}");
                    assert_eq!(res.completed.len(), reference.completed.len(), "{tag}");
                    assert_eq!(res.events_processed, reference.events_processed, "{tag}");
                    assert_eq!(res.copies_lost, reference.copies_lost, "{tag}");
                    assert_eq!(res.work_lost.to_bits(), reference.work_lost.to_bits(), "{tag}");
                    assert_eq!(res.machines_failed, reference.machines_failed, "{tag}");
                    assert_eq!(
                        res.total_machine_time.to_bits(),
                        reference.total_machine_time.to_bits(),
                        "{tag}"
                    );
                    for (a, b) in res.completed.iter().zip(&reference.completed) {
                        assert_eq!(a.job, b.job, "{tag}");
                        assert_eq!(a.flowtime.to_bits(), b.flowtime.to_bits(), "{tag}");
                        assert_eq!(a.resource.to_bits(), b.resource.to_bits(), "{tag}");
                    }
                }
            }
        }
    }

    /// The equivalence matrix with the ON/OFF flip process enabled: the
    /// flips, dwell draws and re-times are a pure function of the
    /// simulated system, so every event-queue backend x wakeup x index
    /// combination produces the same run, bit for bit.
    #[test]
    fn flip_runs_identical_across_backends_wakeup_and_index() {
        use crate::cluster::event::EventQueueKind;
        use crate::cluster::machine::SlowdownConfig;
        let run = |queue: EventQueueKind, wakeup: bool, sched_index: bool| {
            let mut cfg = small_cfg();
            cfg.horizon = 120.0;
            cfg.scheduler = scheduler::SchedulerKind::Sda;
            cfg.use_runtime = false;
            cfg.slowdown = Some(SlowdownConfig::new(0.2, 3.0).with_rates(0.5, 1.0));
            cfg.event_queue = queue;
            cfg.wakeup = wakeup;
            cfg.sched_index = sched_index;
            let wl_cfg = WorkloadConfig::paper(0.3);
            let wl = generator::generate(&wl_cfg, cfg.horizon, cfg.seed);
            let sched = scheduler::build_for(&cfg, &wl_cfg, Some(&wl)).unwrap();
            Simulator::new(cfg, wl, sched).run()
        };
        let reference = run(EventQueueKind::Calendar, false, false);
        assert!(!reference.completed.is_empty());
        for queue in [EventQueueKind::Calendar, EventQueueKind::BinaryHeap] {
            for wakeup in [false, true] {
                for sched_index in [false, true] {
                    let res = run(queue, wakeup, sched_index);
                    let tag = format!("{queue:?}/wakeup={wakeup}/index={sched_index}");
                    assert_eq!(res.completed.len(), reference.completed.len(), "{tag}");
                    assert_eq!(res.events_processed, reference.events_processed, "{tag}");
                    assert_eq!(
                        res.speculative_launches, reference.speculative_launches,
                        "{tag}"
                    );
                    assert_eq!(
                        res.total_machine_time.to_bits(),
                        reference.total_machine_time.to_bits(),
                        "{tag}"
                    );
                    for (a, b) in res.completed.iter().zip(&reference.completed) {
                        assert_eq!(a.job, b.job, "{tag}");
                        assert_eq!(a.flowtime.to_bits(), b.flowtime.to_bits(), "{tag}");
                        assert_eq!(a.resource.to_bits(), b.resource.to_bits(), "{tag}");
                    }
                }
            }
        }
    }

    /// The flip axis is real and its zero point is exact: enabling
    /// ON/OFF transitions adds events (flips plus re-timed entries) and
    /// moves the simulated quantities, while zero rates reproduce the
    /// static-slowdown run bit for bit (no dwell stream even exists).
    #[test]
    fn flips_change_the_run_and_zero_rates_do_not() {
        use crate::cluster::machine::SlowdownConfig;
        let run = |rates: Option<(f64, f64)>| {
            let mut cfg = small_cfg();
            cfg.horizon = 120.0;
            cfg.scheduler = scheduler::SchedulerKind::Sda;
            cfg.use_runtime = false;
            let base = SlowdownConfig::new(0.2, 3.0);
            cfg.slowdown = Some(match rates {
                Some((on, off)) => base.with_rates(on, off),
                None => base,
            });
            let wl_cfg = WorkloadConfig::paper(0.3);
            let wl = generator::generate(&wl_cfg, cfg.horizon, cfg.seed);
            let sched = scheduler::build_for(&cfg, &wl_cfg, Some(&wl)).unwrap();
            Simulator::new(cfg, wl, sched).run()
        };
        let still = run(None);
        let zero = run(Some((0.0, 0.0)));
        let flipping = run(Some((0.5, 1.0)));
        assert!(
            flipping.events_processed > still.events_processed,
            "flips must add events: {} vs {}",
            flipping.events_processed,
            still.events_processed
        );
        assert_ne!(
            flipping.total_machine_time.to_bits(),
            still.total_machine_time.to_bits(),
            "flips must move machine time"
        );
        // zero rates ARE the static scenario
        assert_eq!(zero.events_processed, still.events_processed);
        assert_eq!(zero.total_machine_time.to_bits(), still.total_machine_time.to_bits());
        assert_eq!(zero.completed.len(), still.completed.len());
        for (a, b) in zero.completed.iter().zip(&still.completed) {
            assert_eq!(a.flowtime.to_bits(), b.flowtime.to_bits());
            assert_eq!(a.resource.to_bits(), b.resource.to_bits());
        }
    }
}
