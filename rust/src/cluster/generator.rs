//! Workload generators.  First-copy durations are pre-sampled here so every
//! scheduling policy replays the identical workload (see `sim.rs`).

use crate::config::WorkloadConfig;
use crate::stats::{Pareto, Pcg64};

use super::job::{JobId, JobSpec};
use super::sim::Workload;
use super::trace;

/// Generate the workload described by `cfg` over `[0, horizon]`.
pub fn generate(cfg: &WorkloadConfig, horizon: f64, seed: u64) -> Workload {
    match cfg {
        WorkloadConfig::Poisson { lambda, m_lo, m_hi, mean_lo, mean_hi, alpha } => {
            poisson(*lambda, *m_lo, *m_hi, *mean_lo, *mean_hi, *alpha, horizon, seed)
        }
        WorkloadConfig::SingleJob { tasks, mean, alpha } => single_job(*tasks, *mean, *alpha, seed),
        WorkloadConfig::Trace { path } => {
            trace::load(path).unwrap_or_else(|e| panic!("trace {path}: {e}"))
        }
    }
}

/// The paper's multi-job workload (Sec. IV-C): Poisson arrivals at rate
/// lambda, m ~ U{m_lo..m_hi}, per-job mean duration ~ U[mean_lo, mean_hi],
/// task durations Pareto(alpha) with that mean.
#[allow(clippy::too_many_arguments)]
fn poisson(
    lambda: f64,
    m_lo: u32,
    m_hi: u32,
    mean_lo: f64,
    mean_hi: f64,
    alpha: f64,
    horizon: f64,
    seed: u64,
) -> Workload {
    let mut arr_rng = Pcg64::new(seed, 101);
    let mut job_rng = Pcg64::new(seed, 202);
    let mut dur_rng = Pcg64::new(seed, 303);
    let mut specs = Vec::new();
    let mut first_durations = Vec::new();
    let mut t = 0.0;
    loop {
        t += arr_rng.exponential(lambda);
        if t > horizon {
            break;
        }
        let id = JobId(specs.len() as u32);
        let m = job_rng.uniform_u64(m_lo as u64, m_hi as u64) as u32;
        let mean = job_rng.uniform_f64(mean_lo, mean_hi);
        let dist = Pareto::from_mean(mean, alpha);
        first_durations.push((0..m).map(|_| dist.sample(&mut dur_rng)).collect());
        specs.push(JobSpec { id, arrival: t, dist, num_tasks: m });
    }
    Workload { specs, first_durations }
}

/// The Fig. 5 workload: a single job arriving at t = 0.
fn single_job(tasks: u32, mean: f64, alpha: f64, seed: u64) -> Workload {
    let mut dur_rng = Pcg64::new(seed, 303);
    let dist = Pareto::from_mean(mean, alpha);
    let first = (0..tasks).map(|_| dist.sample(&mut dur_rng)).collect();
    Workload {
        specs: vec![JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: tasks }],
        first_durations: vec![first],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let wl = generate(&WorkloadConfig::paper(6.0), 1000.0, 42);
        let n = wl.specs.len() as f64;
        assert!((n / 1000.0 - 6.0).abs() < 0.5, "rate {}", n / 1000.0);
        // arrivals ordered, ids dense
        for (i, s) in wl.specs.iter().enumerate() {
            assert_eq!(s.id.0 as usize, i);
        }
        for w in wl.specs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn task_counts_in_range() {
        let wl = generate(&WorkloadConfig::paper(6.0), 200.0, 1);
        for s in &wl.specs {
            assert!((1..=100).contains(&s.num_tasks));
            let mean = s.dist.mean();
            assert!((1.0..=4.0).contains(&mean), "mean {mean}");
        }
    }

    #[test]
    fn durations_match_spec_count() {
        let wl = generate(&WorkloadConfig::paper(3.0), 100.0, 9);
        assert_eq!(wl.specs.len(), wl.first_durations.len());
        for (s, d) in wl.specs.iter().zip(&wl.first_durations) {
            assert_eq!(s.num_tasks as usize, d.len());
            for &x in d {
                assert!(x >= s.dist.mu);
            }
        }
    }

    #[test]
    fn single_job_shape() {
        let wl = generate(
            &WorkloadConfig::SingleJob { tasks: 100, mean: 1.0, alpha: 2.0 },
            10.0,
            5,
        );
        assert_eq!(wl.specs.len(), 1);
        assert_eq!(wl.specs[0].num_tasks, 100);
        assert_eq!(wl.specs[0].arrival, 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&WorkloadConfig::paper(6.0), 100.0, 7);
        let b = generate(&WorkloadConfig::paper(6.0), 100.0, 7);
        assert_eq!(a.specs.len(), b.specs.len());
        assert_eq!(a.first_durations, b.first_durations);
        let c = generate(&WorkloadConfig::paper(6.0), 100.0, 8);
        assert_ne!(
            a.specs.iter().map(|s| s.arrival).collect::<Vec<_>>(),
            c.specs.iter().map(|s| s.arrival).collect::<Vec<_>>()
        );
    }
}
