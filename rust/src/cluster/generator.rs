//! Workload generators.  First-copy durations are pre-sampled here so every
//! scheduling policy replays the identical workload (see `sim.rs`).

use crate::config::WorkloadConfig;
use crate::stats::{Pareto, Pcg64};

use super::job::{JobId, JobSpec};
use super::sim::Workload;
use super::trace;

/// Generate the workload described by `cfg` over `[0, horizon]`.
pub fn generate(cfg: &WorkloadConfig, horizon: f64, seed: u64) -> Workload {
    match cfg {
        WorkloadConfig::Poisson { lambda, m_lo, m_hi, mean_lo, mean_hi, alpha } => {
            poisson(*lambda, *m_lo, *m_hi, *mean_lo, *mean_hi, *alpha, horizon, seed)
        }
        WorkloadConfig::Bursty {
            lambda,
            burst,
            on_frac,
            cycle,
            m_lo,
            m_hi,
            mean_lo,
            mean_hi,
            alpha,
        } => bursty(
            Mmpp::from_mean(*lambda, *burst, *on_frac, *cycle),
            *m_lo,
            *m_hi,
            *mean_lo,
            *mean_hi,
            *alpha,
            horizon,
            seed,
        ),
        WorkloadConfig::SingleJob { tasks, mean, alpha } => single_job(*tasks, *mean, *alpha, seed),
        WorkloadConfig::Trace { path, .. } => {
            trace::load(path).unwrap_or_else(|e| panic!("trace {path}: {e}"))
        }
    }
}

/// Pooled maximum-likelihood estimate of the Pareto tail index from a
/// workload's pre-sampled first-copy durations, using each job's own scale
/// `mu`: `alpha_hat = N / sum ln(d / mu)`.  Used to derive SDA/ESE
/// thresholds when the workload is a replayed trace rather than a
/// parametric model.  Clamped to a sane range; defaults to the paper's
/// alpha = 2 when the trace is empty or degenerate.
pub fn estimate_alpha(wl: &Workload) -> f64 {
    let mut n = 0u64;
    let mut log_sum = 0.0;
    for (spec, durs) in wl.specs.iter().zip(&wl.first_durations) {
        for &d in durs {
            // only samples strictly above the scale carry tail information;
            // counting d <= mu (possible in hand-edited traces) would bias
            // the estimate upward
            if spec.dist.mu > 0.0 && d > spec.dist.mu {
                log_sum += (d / spec.dist.mu).ln();
                n += 1;
            }
        }
    }
    if n == 0 || log_sum <= 0.0 {
        return 2.0;
    }
    (n as f64 / log_sum).clamp(1.1, 10.0)
}

/// Resolved 2-state MMPP parameters (rates + mean dwell times).
#[derive(Clone, Copy, Debug)]
pub struct Mmpp {
    pub rate_on: f64,
    pub rate_off: f64,
    pub dwell_on: f64,
    pub dwell_off: f64,
}

impl Mmpp {
    /// Derive ON/OFF rates from the long-run mean rate `lambda`, the ON
    /// multiplier `burst >= 1`, the stationary ON fraction and the mean
    /// cycle length: `rate_on = burst * lambda` and `rate_off` chosen so
    /// the stationary mean is exactly `lambda` (clamped at 0 when
    /// `burst * on_frac` approaches 1 — the fully-bursty limit).
    pub fn from_mean(lambda: f64, burst: f64, on_frac: f64, cycle: f64) -> Mmpp {
        assert!(lambda > 0.0 && burst >= 1.0 && cycle > 0.0, "bad MMPP parameters");
        assert!(0.0 < on_frac && on_frac < 1.0, "on_frac must be in (0,1)");
        // beyond burst * on_frac = 1 the OFF rate would have to be negative
        // and the realized mean would silently exceed lambda — reject it
        // (the CLI validates the same bound with a friendlier error)
        assert!(
            burst * on_frac <= 1.0 + 1e-9,
            "burst * on_frac = {} > 1: requested mean rate unreachable",
            burst * on_frac
        );
        let rate_on = burst * lambda;
        let rate_off = (lambda * (1.0 - burst * on_frac) / (1.0 - on_frac)).max(0.0);
        Mmpp {
            rate_on,
            rate_off,
            dwell_on: on_frac * cycle,
            dwell_off: (1.0 - on_frac) * cycle,
        }
    }

    /// Stationary mean arrival rate.
    pub fn mean_rate(&self) -> f64 {
        let pi_on = self.dwell_on / (self.dwell_on + self.dwell_off);
        self.rate_on * pi_on + self.rate_off * (1.0 - pi_on)
    }
}

/// Bursty multi-job workload: the paper's job mix arriving as a 2-state
/// MMPP.  State dwell times and arrival gaps come from independent streams
/// so the burst structure is stable across job-mix changes.
#[allow(clippy::too_many_arguments)]
fn bursty(
    mmpp: Mmpp,
    m_lo: u32,
    m_hi: u32,
    mean_lo: f64,
    mean_hi: f64,
    alpha: f64,
    horizon: f64,
    seed: u64,
) -> Workload {
    let mut arr_rng = Pcg64::new(seed, 101);
    let mut job_rng = Pcg64::new(seed, 202);
    let mut dur_rng = Pcg64::new(seed, 303);
    let mut state_rng = Pcg64::new(seed, 404);
    let mut specs = Vec::new();
    let mut first_durations = Vec::new();
    let mut t = 0.0;
    let mut on = true;
    let mut phase_end = state_rng.exponential(1.0 / mmpp.dwell_on);
    loop {
        let rate = if on { mmpp.rate_on } else { mmpp.rate_off };
        let candidate = if rate > 0.0 {
            t + arr_rng.exponential(rate)
        } else {
            f64::INFINITY
        };
        if candidate > phase_end {
            // no arrival before the state flips; restart from the boundary
            // (valid by memorylessness of the exponential gap)
            t = phase_end;
            if t > horizon {
                break;
            }
            on = !on;
            let dwell = if on { mmpp.dwell_on } else { mmpp.dwell_off };
            phase_end = t + state_rng.exponential(1.0 / dwell);
            continue;
        }
        t = candidate;
        if t > horizon {
            break;
        }
        let id = JobId(specs.len() as u32);
        let m = job_rng.uniform_u64(m_lo as u64, m_hi as u64) as u32;
        let mean = job_rng.uniform_f64(mean_lo, mean_hi);
        let dist = Pareto::from_mean(mean, alpha);
        first_durations.push((0..m).map(|_| dist.sample(&mut dur_rng)).collect());
        specs.push(JobSpec { id, arrival: t, dist, num_tasks: m });
    }
    Workload { specs, first_durations }
}

/// The paper's multi-job workload (Sec. IV-C): Poisson arrivals at rate
/// lambda, m ~ U{m_lo..m_hi}, per-job mean duration ~ U[mean_lo, mean_hi],
/// task durations Pareto(alpha) with that mean.
#[allow(clippy::too_many_arguments)]
fn poisson(
    lambda: f64,
    m_lo: u32,
    m_hi: u32,
    mean_lo: f64,
    mean_hi: f64,
    alpha: f64,
    horizon: f64,
    seed: u64,
) -> Workload {
    let mut arr_rng = Pcg64::new(seed, 101);
    let mut job_rng = Pcg64::new(seed, 202);
    let mut dur_rng = Pcg64::new(seed, 303);
    let mut specs = Vec::new();
    let mut first_durations = Vec::new();
    let mut t = 0.0;
    loop {
        t += arr_rng.exponential(lambda);
        if t > horizon {
            break;
        }
        let id = JobId(specs.len() as u32);
        let m = job_rng.uniform_u64(m_lo as u64, m_hi as u64) as u32;
        let mean = job_rng.uniform_f64(mean_lo, mean_hi);
        let dist = Pareto::from_mean(mean, alpha);
        first_durations.push((0..m).map(|_| dist.sample(&mut dur_rng)).collect());
        specs.push(JobSpec { id, arrival: t, dist, num_tasks: m });
    }
    Workload { specs, first_durations }
}

/// The Fig. 5 workload: a single job arriving at t = 0.
fn single_job(tasks: u32, mean: f64, alpha: f64, seed: u64) -> Workload {
    let mut dur_rng = Pcg64::new(seed, 303);
    let dist = Pareto::from_mean(mean, alpha);
    let first = (0..tasks).map(|_| dist.sample(&mut dur_rng)).collect();
    Workload {
        specs: vec![JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: tasks }],
        first_durations: vec![first],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let wl = generate(&WorkloadConfig::paper(6.0), 1000.0, 42);
        let n = wl.specs.len() as f64;
        assert!((n / 1000.0 - 6.0).abs() < 0.5, "rate {}", n / 1000.0);
        // arrivals ordered, ids dense
        for (i, s) in wl.specs.iter().enumerate() {
            assert_eq!(s.id.0 as usize, i);
        }
        for w in wl.specs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn task_counts_in_range() {
        let wl = generate(&WorkloadConfig::paper(6.0), 200.0, 1);
        for s in &wl.specs {
            assert!((1..=100).contains(&s.num_tasks));
            let mean = s.dist.mean();
            assert!((1.0..=4.0).contains(&mean), "mean {mean}");
        }
    }

    #[test]
    fn durations_match_spec_count() {
        let wl = generate(&WorkloadConfig::paper(3.0), 100.0, 9);
        assert_eq!(wl.specs.len(), wl.first_durations.len());
        for (s, d) in wl.specs.iter().zip(&wl.first_durations) {
            assert_eq!(s.num_tasks as usize, d.len());
            for &x in d {
                assert!(x >= s.dist.mu);
            }
        }
    }

    #[test]
    fn single_job_shape() {
        let wl = generate(
            &WorkloadConfig::SingleJob { tasks: 100, mean: 1.0, alpha: 2.0 },
            10.0,
            5,
        );
        assert_eq!(wl.specs.len(), 1);
        assert_eq!(wl.specs[0].num_tasks, 100);
        assert_eq!(wl.specs[0].arrival, 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(&WorkloadConfig::paper(6.0), 100.0, 7);
        let b = generate(&WorkloadConfig::paper(6.0), 100.0, 7);
        assert_eq!(a.specs.len(), b.specs.len());
        assert_eq!(a.first_durations, b.first_durations);
        let c = generate(&WorkloadConfig::paper(6.0), 100.0, 8);
        assert_ne!(
            a.specs.iter().map(|s| s.arrival).collect::<Vec<_>>(),
            c.specs.iter().map(|s| s.arrival).collect::<Vec<_>>()
        );
    }

    #[test]
    fn mmpp_rates_preserve_mean() {
        let m = Mmpp::from_mean(6.0, 3.0, 0.25, 40.0);
        assert!((m.rate_on - 18.0).abs() < 1e-12);
        assert!((m.mean_rate() - 6.0).abs() < 1e-12);
        // fully-bursty limit: all arrivals in the ON state
        let m = Mmpp::from_mean(6.0, 4.0, 0.25, 40.0);
        assert_eq!(m.rate_off, 0.0);
    }

    #[test]
    fn bursty_long_run_rate_matches_lambda() {
        let wl = generate(&WorkloadConfig::bursty_paper(6.0, 3.0), 4000.0, 11);
        let rate = wl.specs.len() as f64 / 4000.0;
        // MMPP counts are overdispersed, so the band is wider than the
        // Poisson test's — ~2.5 sigma at this horizon
        assert!((rate - 6.0).abs() < 1.0, "rate {rate}");
        for w in wl.specs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (i, s) in wl.specs.iter().enumerate() {
            assert_eq!(s.id.0 as usize, i);
        }
    }

    #[test]
    fn bursty_is_deterministic_and_burstier_than_poisson() {
        let cfg = WorkloadConfig::bursty_paper(6.0, 4.0);
        let a = generate(&cfg, 500.0, 3);
        let b = generate(&cfg, 500.0, 3);
        assert_eq!(a.first_durations, b.first_durations);
        // index-of-dispersion check on 10-unit bins: MMPP counts must be
        // overdispersed relative to Poisson (variance/mean > 1)
        let dispersion = |wl: &Workload| {
            let mut bins = vec![0.0f64; 50];
            for s in &wl.specs {
                let i = (s.arrival / 10.0) as usize;
                if i < bins.len() {
                    bins[i] += 1.0;
                }
            }
            let mean = bins.iter().sum::<f64>() / bins.len() as f64;
            let var =
                bins.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / bins.len() as f64;
            var / mean
        };
        let poisson = generate(&WorkloadConfig::paper(6.0), 500.0, 3);
        assert!(
            dispersion(&a) > 1.5 * dispersion(&poisson),
            "bursty {} vs poisson {}",
            dispersion(&a),
            dispersion(&poisson)
        );
    }

    #[test]
    fn alpha_estimate_recovers_generator_alpha() {
        for alpha in [1.5f64, 2.0, 3.0] {
            let wl = generate(
                &WorkloadConfig::Poisson {
                    lambda: 4.0,
                    m_lo: 50,
                    m_hi: 100,
                    mean_lo: 1.0,
                    mean_hi: 4.0,
                    alpha,
                },
                400.0,
                5,
            );
            let est = estimate_alpha(&wl);
            assert!((est - alpha).abs() < 0.1, "alpha {alpha}: estimated {est}");
        }
        // degenerate workload falls back to the paper's default
        assert_eq!(estimate_alpha(&Workload { specs: vec![], first_durations: vec![] }), 2.0);
    }
}
