//! Machine pool.  Each machine holds at most one task copy at a time (the
//! paper's model); allocation is O(1) via a free-list stack.
//!
//! The pool is homogeneous by default (every machine at speed 1.0, the
//! paper's set-up) but can be built from [`MachineClass`]es with per-class
//! speed factors, and each machine additionally carries a **slowdown
//! state** (cf. Anselmi & Walton's server-dependent slowdown): a copy's
//! wall-clock duration on a host is its sampled work amount divided by the
//! host's *effective* speed (`Cluster::launch_copy`).
//!
//! The two factors have different visibility, and the split is the
//! estimator contract (see [`crate::estimator`]):
//!
//! * [`MachinePool::speed`] is the **advertised class speed** — a public
//!   hardware fact the speed-aware estimators may read.
//! * [`MachinePool::slowdown`] is the **hidden degradation state**, sampled
//!   per machine from [`SlowdownConfig`]; only the simulator reads it (via
//!   [`MachinePool::effective_speed`]).  Schedulers can observe it only
//!   indirectly, through inflated revealed remaining times — which is what
//!   makes a degraded host a *detectable* straggler while a merely
//!   slow-class host is not.

use crate::stats::Pcg64;

use super::job::TaskRef;

/// A group of identical machines within a (possibly heterogeneous) cluster.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MachineClass {
    /// How many machines of this class.
    pub count: usize,
    /// Speed factor: wall-clock duration = sampled work / speed.  1.0 is
    /// the paper's homogeneous baseline; 0.5 models stragglers-by-hardware.
    pub speed: f64,
}

impl MachineClass {
    pub fn new(count: usize, speed: f64) -> Self {
        MachineClass { count, speed }
    }
}

/// Server-dependent slowdown scenario (cf. Anselmi & Walton): each machine
/// is independently degraded with probability `frac`; a degraded machine
/// multiplies every copy's wall-clock duration by `factor` (>= 1).  States
/// are sampled once per simulation from the run's seed, so the slowdown is
/// *correlated across tasks on the same server* — the regime where blind
/// speculation rules misfire.
///
/// With non-zero `rate_on`/`rate_off` the degradation becomes an ON/OFF
/// Markov process: a healthy machine degrades after Exp(`rate_on`) time and
/// a degraded machine recovers after Exp(`rate_off`) time, so `frac` is only
/// the *initial* state distribution.  Both rates zero (the default)
/// reproduces the static scenario bit-for-bit — no flip events are ever
/// scheduled and no extra RNG stream is consumed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlowdownConfig {
    /// Probability a machine is degraded (at t = 0 when flips are enabled).
    pub frac: f64,
    /// Wall-clock multiplier on a degraded machine (1.0 = no degradation).
    pub factor: f64,
    /// Exponential rate at which a healthy machine degrades (0 = never).
    pub rate_on: f64,
    /// Exponential rate at which a degraded machine recovers (0 = never).
    pub rate_off: f64,
}

impl SlowdownConfig {
    /// Static scenario (no ON/OFF flips) — the pre-flip constructor, kept
    /// two-arg so existing call sites and specs are unchanged.
    pub fn new(frac: f64, factor: f64) -> Self {
        SlowdownConfig { frac, factor, rate_on: 0.0, rate_off: 0.0 }
    }

    /// Add ON/OFF Markov transition rates to a static scenario.
    pub fn with_rates(self, rate_on: f64, rate_off: f64) -> Self {
        SlowdownConfig { rate_on, rate_off, ..self }
    }

    /// Whether the ON/OFF process is active (either rate positive).
    #[inline]
    pub fn flips_enabled(&self) -> bool {
        self.rate_on > 0.0 || self.rate_off > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..=1.0).contains(&self.frac) {
            return Err(format!("slowdown frac must be in [0,1], got {}", self.frac));
        }
        if !(self.factor >= 1.0) {
            return Err(format!("slowdown factor must be >= 1, got {}", self.factor));
        }
        if !(self.rate_on >= 0.0 && self.rate_on.is_finite()) {
            return Err(format!("slowdown rate_on must be finite and >= 0, got {}", self.rate_on));
        }
        if !(self.rate_off >= 0.0 && self.rate_off.is_finite()) {
            return Err(format!(
                "slowdown rate_off must be finite and >= 0, got {}",
                self.rate_off
            ));
        }
        Ok(())
    }
}

/// Parse a slowdown spec `FRACxFACTOR[@RATE_ON,RATE_OFF]`, e.g. `"0.1x4.0"`
/// (10% of machines run 4x slower, statically) or `"0.1x4.0@0.02,0.05"`
/// (same initial state, machines then degrade at rate 0.02 and recover at
/// rate 0.05).
pub fn parse_slowdown(s: &str) -> Result<SlowdownConfig, String> {
    let (static_s, rates_s) = match s.split_once('@') {
        Some((a, b)) => (a, Some(b)),
        None => (s, None),
    };
    let (frac_s, factor_s) = static_s
        .split_once('x')
        .ok_or_else(|| format!("slowdown '{s}': expected FRACxFACTOR, e.g. 0.1x4.0"))?;
    let frac: f64 = frac_s
        .trim()
        .parse()
        .map_err(|_| format!("slowdown '{s}': bad fraction '{frac_s}'"))?;
    let factor: f64 = factor_s
        .trim()
        .parse()
        .map_err(|_| format!("slowdown '{s}': bad factor '{factor_s}'"))?;
    let mut sd = SlowdownConfig::new(frac, factor);
    if let Some(rates_s) = rates_s {
        let (on_s, off_s) = rates_s.split_once(',').ok_or_else(|| {
            format!("slowdown '{s}': expected @RATE_ON,RATE_OFF after FRACxFACTOR")
        })?;
        let rate_on: f64 = on_s
            .trim()
            .parse()
            .map_err(|_| format!("slowdown '{s}': bad rate_on '{on_s}'"))?;
        let rate_off: f64 = off_s
            .trim()
            .parse()
            .map_err(|_| format!("slowdown '{s}': bad rate_off '{off_s}'"))?;
        sd = sd.with_rates(rate_on, rate_off);
    }
    sd.validate()?;
    Ok(sd)
}

/// Render a slowdown spec back to `FRACxFACTOR[@RATE_ON,RATE_OFF]`
/// (round-trips through [`parse_slowdown`]; the rate suffix is omitted when
/// flips are disabled so static configs print exactly as before).
pub fn format_slowdown(sd: &SlowdownConfig) -> String {
    if sd.flips_enabled() {
        format!("{:?}x{:?}@{:?},{:?}", sd.frac, sd.factor, sd.rate_on, sd.rate_off)
    } else {
        format!("{:?}x{:?}", sd.frac, sd.factor)
    }
}

/// Machine-churn scenario: machines crash and recover as independent
/// alternating renewal processes (the paper's opening premise — failures
/// are "the norm rather than the exception").  An up machine fails after
/// Exp(1/`mttf`) time, killing every resident copy (work lost, restart
/// from zero); a down machine rejoins after Exp(1/`mttr`) time.  Both
/// means zero (the default spec `0,0`) disables the process entirely —
/// no events scheduled, no RNG stream consumed — so zero-rate churn is
/// bit-identical to the pre-churn simulator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnConfig {
    /// Mean time to failure of an up machine (simulated time units).
    pub mttf: f64,
    /// Mean time to recovery of a down machine.
    pub mttr: f64,
}

impl ChurnConfig {
    pub fn new(mttf: f64, mttr: f64) -> Self {
        ChurnConfig { mttf, mttr }
    }

    /// Whether the churn process is active (a positive MTTF).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.mttf > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.mttf >= 0.0 && self.mttf.is_finite()) {
            return Err(format!("churn mttf must be finite and >= 0, got {}", self.mttf));
        }
        if !(self.mttr >= 0.0 && self.mttr.is_finite()) {
            return Err(format!("churn mttr must be finite and >= 0, got {}", self.mttr));
        }
        // a failing machine must be able to come back: a zero MTTR with a
        // positive MTTF would drain the cluster to nothing
        if self.enabled() && !(self.mttr > 0.0) {
            return Err(format!(
                "churn mttr must be > 0 when mttf is (got mttf={}, mttr={})",
                self.mttf, self.mttr
            ));
        }
        if !self.enabled() && self.mttr > 0.0 {
            return Err(format!(
                "churn mttf must be > 0 when mttr is (got mttf={}, mttr={})",
                self.mttf, self.mttr
            ));
        }
        Ok(())
    }
}

/// Parse a churn spec `MTTF,MTTR`, e.g. `"200,20"` (machines fail every
/// 200 time units on average and stay down for 20).  `"0,0"` disables.
pub fn parse_churn(s: &str) -> Result<ChurnConfig, String> {
    let (mttf_s, mttr_s) = s
        .split_once(',')
        .ok_or_else(|| format!("churn '{s}': expected MTTF,MTTR, e.g. 200,20"))?;
    let mttf: f64 = mttf_s
        .trim()
        .parse()
        .map_err(|_| format!("churn '{s}': bad mttf '{mttf_s}'"))?;
    let mttr: f64 = mttr_s
        .trim()
        .parse()
        .map_err(|_| format!("churn '{s}': bad mttr '{mttr_s}'"))?;
    let churn = ChurnConfig::new(mttf, mttr);
    churn.validate()?;
    Ok(churn)
}

/// Render a churn spec back to `MTTF,MTTR` (round-trips through
/// [`parse_churn`]).
pub fn format_churn(c: &ChurnConfig) -> String {
    format!("{:?},{:?}", c.mttf, c.mttr)
}

/// Parse a cluster scenario spec: comma-separated `COUNTxSPEED` groups,
/// e.g. `"2000x1.0,1000x0.5"`.  Bare `COUNT` means speed 1.0.
pub fn parse_classes(s: &str) -> Result<Vec<MachineClass>, String> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (count_s, speed_s) = match part.split_once('x') {
            Some((c, v)) => (c, v),
            None => (part, "1.0"),
        };
        let count: usize = count_s
            .trim()
            .parse()
            .map_err(|_| format!("machine class '{part}': bad count '{count_s}'"))?;
        let speed: f64 = speed_s
            .trim()
            .parse()
            .map_err(|_| format!("machine class '{part}': bad speed '{speed_s}'"))?;
        if count == 0 {
            return Err(format!("machine class '{part}': count must be > 0"));
        }
        if !(speed > 0.0) {
            return Err(format!("machine class '{part}': speed must be > 0"));
        }
        out.push(MachineClass { count, speed });
    }
    if out.is_empty() {
        return Err("machine classes: empty spec".to_string());
    }
    Ok(out)
}

/// Render classes back to the `COUNTxSPEED,...` spec (round-trips through
/// [`parse_classes`]).
pub fn format_classes(classes: &[MachineClass]) -> String {
    classes
        .iter()
        .map(|c| format!("{}x{:?}", c.count, c.speed))
        .collect::<Vec<_>>()
        .join(",")
}

/// What a busy machine is running.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub task: TaskRef,
    pub copy: u32,
}

/// Fixed-size pool of machines with per-machine speed factors and hidden
/// slowdown states.
#[derive(Clone, Debug)]
pub struct MachinePool {
    free: Vec<u32>,
    busy: Vec<Option<Assignment>>, // indexed by machine id
    speeds: Vec<f64>,              // indexed by machine id (advertised)
    slowdowns: Vec<f64>,           // indexed by machine id (hidden, >= 1)
    up: Vec<bool>,                 // indexed by machine id (churn state)
    down_count: usize,
}

impl MachinePool {
    /// Homogeneous pool (every machine at speed 1.0, the paper's model).
    pub fn new(n: usize) -> Self {
        MachinePool::with_classes(&[MachineClass { count: n, speed: 1.0 }])
    }

    /// Heterogeneous pool: machines are laid out class by class, so class 0
    /// occupies ids `0..classes[0].count` and is allocated first.
    pub fn with_classes(classes: &[MachineClass]) -> Self {
        let n: usize = classes.iter().map(|c| c.count).sum();
        let mut speeds = Vec::with_capacity(n);
        for c in classes {
            speeds.extend(std::iter::repeat(c.speed).take(c.count));
        }
        MachinePool {
            // LIFO free-list; reversed so machine 0 is allocated first
            free: (0..n as u32).rev().collect(),
            busy: vec![None; n],
            speeds,
            slowdowns: vec![1.0; n],
            up: vec![true; n],
            down_count: 0,
        }
    }

    /// Sample per-machine slowdown states: each machine is degraded (its
    /// slowdown set to `sd.factor`) independently with probability
    /// `sd.frac`.  Called once at cluster construction with a dedicated RNG
    /// stream derived from the run's seed, so the degraded set is a
    /// deterministic function of (config, seed).
    pub fn sample_slowdowns(&mut self, sd: &SlowdownConfig, rng: &mut Pcg64) {
        for s in self.slowdowns.iter_mut() {
            if rng.next_f64() < sd.frac {
                *s = sd.factor;
            }
        }
    }

    /// Advertised class speed of machine `id` — public hardware knowledge,
    /// readable by speed-aware estimators.
    #[inline]
    pub fn speed(&self, id: u32) -> f64 {
        self.speeds[id as usize]
    }

    /// Hidden slowdown state of machine `id` (1.0 = healthy).  Simulator
    /// ground truth; schedulers must not read it (see [`crate::estimator`]).
    #[inline]
    pub fn slowdown(&self, id: u32) -> f64 {
        self.slowdowns[id as usize]
    }

    /// Overwrite the hidden slowdown state of machine `id` — the ON/OFF flip
    /// mutation.  Only the simulator's `SlowdownFlip` handler calls this;
    /// running copies must be re-timed by the caller (`Cluster::flip_machine`)
    /// since their wall-clock durations were computed from the old state.
    #[inline]
    pub fn set_slowdown(&mut self, id: u32, s: f64) {
        debug_assert!(s >= 1.0, "slowdown must be >= 1, got {s}");
        self.slowdowns[id as usize] = s;
    }

    /// Effective speed of machine `id`: advertised speed divided by the
    /// hidden slowdown.  `Cluster::launch_copy` converts sampled work to
    /// wall-clock with this.
    #[inline]
    pub fn effective_speed(&self, id: u32) -> f64 {
        self.speeds[id as usize] / self.slowdowns[id as usize]
    }

    pub fn total(&self) -> usize {
        self.busy.len()
    }

    /// N(l): machines currently idle.
    #[inline]
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    #[inline]
    pub fn busy_count(&self) -> usize {
        self.busy.len() - self.free.len() - self.down_count
    }

    /// Allocate an idle machine for a task copy.  Down machines are never
    /// returned — `mark_down` removed them from the free list — which is
    /// what makes the estimators' down-host exclusion structural: no
    /// running copy can ever sit on a crashed machine.
    #[inline]
    pub fn alloc(&mut self, asg: Assignment) -> Option<u32> {
        let id = self.free.pop()?;
        debug_assert!(self.busy[id as usize].is_none());
        debug_assert!(self.up[id as usize], "allocated a down machine");
        self.busy[id as usize] = Some(asg);
        Some(id)
    }

    /// Release a machine back to the pool.
    #[inline]
    pub fn release(&mut self, id: u32) {
        debug_assert!(self.busy[id as usize].is_some(), "double free of machine {id}");
        self.busy[id as usize] = None;
        self.free.push(id);
    }

    /// What machine `id` is running, if anything.
    #[inline]
    pub fn assignment(&self, id: u32) -> Option<Assignment> {
        self.busy[id as usize]
    }

    /// Is machine `id` up (not crashed)?  Always true without churn.
    #[inline]
    pub fn is_up(&self, id: u32) -> bool {
        self.up[id as usize]
    }

    /// Machines currently down (crashed, awaiting recovery).
    #[inline]
    pub fn down(&self) -> usize {
        self.down_count
    }

    /// Crash machine `id`: it leaves the allocatable pool until
    /// [`mark_up`](Self::mark_up).  The caller (`Cluster::fail_machine`)
    /// must have killed and released any resident copy first, so the
    /// machine sits on the free list here; the removal preserves the free
    /// list's order so a zero-churn run's allocation sequence is untouched
    /// by the mere existence of this method.
    pub fn mark_down(&mut self, id: u32) {
        debug_assert!(self.up[id as usize], "machine {id} failed twice");
        debug_assert!(self.busy[id as usize].is_none(), "machine {id} failed while busy");
        self.up[id as usize] = false;
        self.down_count += 1;
        self.free.retain(|&m| m != id);
    }

    /// Recover machine `id`: push it back onto the LIFO free stack, so a
    /// freshly recovered machine is the next one allocated (deterministic
    /// and cache-friendly).
    pub fn mark_up(&mut self, id: u32) {
        debug_assert!(!self.up[id as usize], "machine {id} recovered while up");
        self.up[id as usize] = true;
        self.down_count -= 1;
        self.free.push(id);
    }

    /// Iterate over (machine, assignment) for all busy machines.
    pub fn busy_iter(&self) -> impl Iterator<Item = (u32, Assignment)> + '_ {
        self.busy
            .iter()
            .enumerate()
            .filter_map(|(i, a)| a.map(|a| (i as u32, a)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::job::{JobId, TaskRef};

    fn tref(j: u32, t: u32) -> TaskRef {
        TaskRef { job: JobId(j), task: t }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = MachinePool::new(3);
        assert_eq!(p.idle(), 3);
        let a = p.alloc(Assignment { task: tref(0, 0), copy: 0 }).unwrap();
        let b = p.alloc(Assignment { task: tref(0, 1), copy: 0 }).unwrap();
        assert_eq!(p.idle(), 1);
        assert_ne!(a, b);
        p.release(a);
        assert_eq!(p.idle(), 2);
        assert!(p.assignment(a).is_none());
        assert_eq!(p.assignment(b).unwrap().task, tref(0, 1));
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = MachinePool::new(1);
        assert!(p.alloc(Assignment { task: tref(0, 0), copy: 0 }).is_some());
        assert!(p.alloc(Assignment { task: tref(0, 1), copy: 0 }).is_none());
    }

    #[test]
    fn busy_iter_lists_all() {
        let mut p = MachinePool::new(4);
        p.alloc(Assignment { task: tref(1, 0), copy: 0 }).unwrap();
        p.alloc(Assignment { task: tref(1, 1), copy: 1 }).unwrap();
        assert_eq!(p.busy_iter().count(), 2);
        assert_eq!(p.busy_count(), 2);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn double_free_panics_in_debug() {
        let mut p = MachinePool::new(2);
        let a = p.alloc(Assignment { task: tref(0, 0), copy: 0 }).unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    fn homogeneous_pool_is_speed_one() {
        let p = MachinePool::new(3);
        for id in 0..3 {
            assert_eq!(p.speed(id), 1.0);
        }
    }

    #[test]
    fn class_layout_orders_speeds() {
        let p = MachinePool::with_classes(&[
            MachineClass::new(2, 2.0),
            MachineClass::new(3, 0.5),
        ]);
        assert_eq!(p.total(), 5);
        assert_eq!(p.idle(), 5);
        assert_eq!(p.speed(0), 2.0);
        assert_eq!(p.speed(1), 2.0);
        assert_eq!(p.speed(2), 0.5);
        assert_eq!(p.speed(4), 0.5);
    }

    #[test]
    fn first_class_allocated_first() {
        let mut p = MachinePool::with_classes(&[
            MachineClass::new(1, 4.0),
            MachineClass::new(1, 1.0),
        ]);
        let a = p.alloc(Assignment { task: tref(0, 0), copy: 0 }).unwrap();
        assert_eq!(a, 0);
        assert_eq!(p.speed(a), 4.0);
    }

    #[test]
    fn classes_spec_roundtrip() {
        let classes = parse_classes("2000x1.0,1000x0.5").unwrap();
        assert_eq!(classes, vec![MachineClass::new(2000, 1.0), MachineClass::new(1000, 0.5)]);
        let back = parse_classes(&format_classes(&classes)).unwrap();
        assert_eq!(back, classes);
        // bare count defaults to speed 1.0
        assert_eq!(parse_classes("50").unwrap(), vec![MachineClass::new(50, 1.0)]);
    }

    #[test]
    fn classes_spec_rejects_bad_input() {
        assert!(parse_classes("").is_err());
        assert!(parse_classes("0x1.0").is_err());
        assert!(parse_classes("10x0").is_err());
        assert!(parse_classes("abcx1.0").is_err());
        assert!(parse_classes("10xfast").is_err());
    }

    #[test]
    fn slowdown_spec_roundtrip_and_bounds() {
        let sd = parse_slowdown("0.1x4.0").unwrap();
        assert_eq!(sd, SlowdownConfig::new(0.1, 4.0));
        assert!(!sd.flips_enabled());
        assert_eq!(parse_slowdown(&format_slowdown(&sd)).unwrap(), sd);
        assert!(parse_slowdown("1.5x2.0").is_err()); // frac > 1
        assert!(parse_slowdown("0.5x0.5").is_err()); // factor < 1
        assert!(parse_slowdown("0.5").is_err());
        assert!(parse_slowdown("axb").is_err());
    }

    #[test]
    fn slowdown_flip_spec_roundtrip_and_bounds() {
        let sd = parse_slowdown("0.1x4.0@0.02,0.05").unwrap();
        assert_eq!(sd, SlowdownConfig::new(0.1, 4.0).with_rates(0.02, 0.05));
        assert!(sd.flips_enabled());
        assert_eq!(format_slowdown(&sd), "0.1x4.0@0.02,0.05");
        assert_eq!(parse_slowdown(&format_slowdown(&sd)).unwrap(), sd);
        // static spec stays the static format (no trailing @0.0,0.0)
        assert_eq!(format_slowdown(&SlowdownConfig::new(0.1, 4.0)), "0.1x4.0");
        // one-sided processes are legal (degrade-only / recover-only)
        assert!(parse_slowdown("0.0x4.0@0.1,0.0").unwrap().flips_enabled());
        assert!(parse_slowdown("1.0x4.0@0.0,0.1").unwrap().flips_enabled());
        // malformed or out-of-range rate suffixes are rejected
        assert!(parse_slowdown("0.1x4.0@0.02").is_err()); // missing rate_off
        assert!(parse_slowdown("0.1x4.0@a,b").is_err());
        assert!(parse_slowdown("0.1x4.0@-0.1,0.2").is_err());
        assert!(parse_slowdown("0.1x4.0@0.1,-0.2").is_err());
        assert!(SlowdownConfig::new(0.1, 4.0).with_rates(f64::NAN, 0.0).validate().is_err());
        assert!(SlowdownConfig::new(0.1, 4.0).with_rates(0.0, f64::INFINITY).validate().is_err());
    }

    #[test]
    fn set_slowdown_flips_effective_speed() {
        let mut p = MachinePool::with_classes(&[MachineClass::new(2, 2.0)]);
        assert_eq!(p.effective_speed(0), 2.0);
        p.set_slowdown(0, 4.0);
        assert_eq!(p.slowdown(0), 4.0);
        assert_eq!(p.effective_speed(0), 0.5);
        assert_eq!(p.speed(0), 2.0); // advertised speed is untouched
        assert_eq!(p.effective_speed(1), 2.0); // other machines untouched
        p.set_slowdown(0, 1.0);
        assert_eq!(p.effective_speed(0), 2.0);
    }

    #[test]
    fn slowdown_states_divide_effective_speed() {
        let mut p = MachinePool::with_classes(&[MachineClass::new(4, 2.0)]);
        // healthy pool: effective == advertised
        for id in 0..4 {
            assert_eq!(p.slowdown(id), 1.0);
            assert_eq!(p.effective_speed(id), 2.0);
        }
        // frac = 1: every machine degraded, advertised speed unchanged
        let mut rng = Pcg64::new(7, 0x510d);
        p.sample_slowdowns(&SlowdownConfig::new(1.0, 4.0), &mut rng);
        for id in 0..4 {
            assert_eq!(p.speed(id), 2.0);
            assert_eq!(p.slowdown(id), 4.0);
            assert_eq!(p.effective_speed(id), 0.5);
        }
        // frac = 0: nothing happens
        let mut p = MachinePool::new(3);
        let mut rng = Pcg64::new(7, 0x510d);
        p.sample_slowdowns(&SlowdownConfig::new(0.0, 4.0), &mut rng);
        for id in 0..3 {
            assert_eq!(p.effective_speed(id), 1.0);
        }
    }

    #[test]
    fn churn_spec_roundtrip_and_bounds() {
        let c = parse_churn("200,20").unwrap();
        assert_eq!(c, ChurnConfig::new(200.0, 20.0));
        assert!(c.enabled());
        assert_eq!(parse_churn(&format_churn(&c)).unwrap(), c);
        let off = parse_churn("0,0").unwrap();
        assert!(!off.enabled());
        assert_eq!(format_churn(&off), "0.0,0.0");
        assert!(parse_churn("200").is_err()); // missing mttr
        assert!(parse_churn("a,b").is_err());
        assert!(parse_churn("-1,5").is_err());
        assert!(parse_churn("200,0").is_err()); // fail without recovery
        assert!(parse_churn("0,20").is_err()); // recovery without failure
        assert!(ChurnConfig::new(f64::NAN, 1.0).validate().is_err());
        assert!(ChurnConfig::new(f64::INFINITY, 1.0).validate().is_err());
    }

    #[test]
    fn mark_down_removes_from_allocation_until_recovery() {
        let mut p = MachinePool::new(3);
        assert!(p.is_up(1));
        assert_eq!(p.down(), 0);
        p.mark_down(1);
        assert!(!p.is_up(1));
        assert_eq!(p.down(), 1);
        assert_eq!(p.idle(), 2);
        assert_eq!(p.busy_count(), 0, "a down machine is not busy");
        // the down machine is never allocated
        let a = p.alloc(Assignment { task: tref(0, 0), copy: 0 }).unwrap();
        let b = p.alloc(Assignment { task: tref(0, 1), copy: 0 }).unwrap();
        assert_ne!(a, 1);
        assert_ne!(b, 1);
        assert!(p.alloc(Assignment { task: tref(0, 2), copy: 0 }).is_none());
        // recovery pushes it to the top of the LIFO stack
        p.mark_up(1);
        assert!(p.is_up(1));
        assert_eq!(p.down(), 0);
        let c = p.alloc(Assignment { task: tref(0, 2), copy: 0 }).unwrap();
        assert_eq!(c, 1, "a recovered machine allocates next");
    }

    #[test]
    fn down_state_preserves_free_list_order() {
        // failing and recovering an idle machine must not reorder the
        // *other* machines' allocation sequence
        let mut p = MachinePool::new(4);
        p.mark_down(2);
        let a = p.alloc(Assignment { task: tref(0, 0), copy: 0 }).unwrap();
        let b = p.alloc(Assignment { task: tref(0, 1), copy: 0 }).unwrap();
        assert_eq!((a, b), (0, 1), "survivors keep their LIFO order");
    }

    #[test]
    fn slowdown_sampling_is_seed_deterministic() {
        let sample = |seed| {
            let mut p = MachinePool::new(64);
            let mut rng = Pcg64::new(seed, 0x510d);
            p.sample_slowdowns(&SlowdownConfig::new(0.5, 3.0), &mut rng);
            (0..64).map(|i| p.slowdown(i)).collect::<Vec<_>>()
        };
        assert_eq!(sample(11), sample(11));
        assert_ne!(sample(11), sample(12));
    }
}
