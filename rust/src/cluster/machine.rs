//! Homogeneous machine pool.  Each machine holds at most one task copy at a
//! time (the paper's model); allocation is O(1) via a free-list stack.

use super::job::TaskRef;

/// What a busy machine is running.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub task: TaskRef,
    pub copy: u32,
}

/// Fixed-size pool of identical machines.
#[derive(Clone, Debug)]
pub struct MachinePool {
    free: Vec<u32>,
    busy: Vec<Option<Assignment>>, // indexed by machine id
}

impl MachinePool {
    pub fn new(n: usize) -> Self {
        MachinePool {
            // LIFO free-list; reversed so machine 0 is allocated first
            free: (0..n as u32).rev().collect(),
            busy: vec![None; n],
        }
    }

    pub fn total(&self) -> usize {
        self.busy.len()
    }

    /// N(l): machines currently idle.
    #[inline]
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    #[inline]
    pub fn busy_count(&self) -> usize {
        self.busy.len() - self.free.len()
    }

    /// Allocate an idle machine for a task copy.
    #[inline]
    pub fn alloc(&mut self, asg: Assignment) -> Option<u32> {
        let id = self.free.pop()?;
        debug_assert!(self.busy[id as usize].is_none());
        self.busy[id as usize] = Some(asg);
        Some(id)
    }

    /// Release a machine back to the pool.
    #[inline]
    pub fn release(&mut self, id: u32) {
        debug_assert!(self.busy[id as usize].is_some(), "double free of machine {id}");
        self.busy[id as usize] = None;
        self.free.push(id);
    }

    /// What machine `id` is running, if anything.
    #[inline]
    pub fn assignment(&self, id: u32) -> Option<Assignment> {
        self.busy[id as usize]
    }

    /// Iterate over (machine, assignment) for all busy machines.
    pub fn busy_iter(&self) -> impl Iterator<Item = (u32, Assignment)> + '_ {
        self.busy
            .iter()
            .enumerate()
            .filter_map(|(i, a)| a.map(|a| (i as u32, a)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::job::{JobId, TaskRef};

    fn tref(j: u32, t: u32) -> TaskRef {
        TaskRef { job: JobId(j), task: t }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = MachinePool::new(3);
        assert_eq!(p.idle(), 3);
        let a = p.alloc(Assignment { task: tref(0, 0), copy: 0 }).unwrap();
        let b = p.alloc(Assignment { task: tref(0, 1), copy: 0 }).unwrap();
        assert_eq!(p.idle(), 1);
        assert_ne!(a, b);
        p.release(a);
        assert_eq!(p.idle(), 2);
        assert!(p.assignment(a).is_none());
        assert_eq!(p.assignment(b).unwrap().task, tref(0, 1));
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = MachinePool::new(1);
        assert!(p.alloc(Assignment { task: tref(0, 0), copy: 0 }).is_some());
        assert!(p.alloc(Assignment { task: tref(0, 1), copy: 0 }).is_none());
    }

    #[test]
    fn busy_iter_lists_all() {
        let mut p = MachinePool::new(4);
        p.alloc(Assignment { task: tref(1, 0), copy: 0 }).unwrap();
        p.alloc(Assignment { task: tref(1, 1), copy: 1 }).unwrap();
        assert_eq!(p.busy_iter().count(), 2);
        assert_eq!(p.busy_count(), 2);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn double_free_panics_in_debug() {
        let mut p = MachinePool::new(2);
        let a = p.alloc(Assignment { task: tref(0, 0), copy: 0 }).unwrap();
        p.release(a);
        p.release(a);
    }
}
