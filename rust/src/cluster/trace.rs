//! Workload trace I/O: CSV with one row per job plus its pre-sampled
//! first-copy durations.  Lets a generated workload be frozen to disk and
//! replayed exactly (e.g. to diff schedulers out-of-process, or to feed the
//! end-to-end example a fixed "production" trace).
//!
//! Format (header line, then one line per job):
//!   job,arrival,mu,alpha,num_tasks,durations...
//! where `durations...` is `num_tasks` semicolon-separated floats.
//!
//! Parsing delegates to the streaming [`TraceReader`] in
//! [`crate::workload`], so the whole-file and streaming paths share one
//! grammar and report identical [`TraceError`] diagnostics.  These loaders
//! still materialize the full [`Workload`]; for bounded-memory replay use
//! [`crate::workload::StreamSource`].

use std::fmt::Write as _;
use std::fs;
use std::io::Read;
use std::path::Path;

use crate::workload::{TraceError, TraceFormat, TraceReader};

use super::job::JobSpec;
use super::sim::Workload;

pub const HEADER: &str = "job,arrival,mu,alpha,num_tasks,durations";

/// Append one native-format row (no header) to `out` — the exact shape
/// [`TraceReader`] parses back.  Shared by [`to_string`] and the CLI's
/// streaming trace synthesis, which writes rows as it generates them.
pub fn format_row(spec: &JobSpec, durs: &[f64], out: &mut String) {
    let _ = write!(
        out,
        "{},{},{},{},{},",
        spec.id.0, spec.arrival, spec.dist.mu, spec.dist.alpha, spec.num_tasks
    );
    for (i, d) in durs.iter().enumerate() {
        if i > 0 {
            out.push(';');
        }
        let _ = write!(out, "{d}");
    }
    out.push('\n');
}

/// Serialize a workload to the trace format.
pub fn to_string(wl: &Workload) -> String {
    let mut out = String::with_capacity(wl.specs.len() * 64);
    out.push_str(HEADER);
    out.push('\n');
    for (spec, durs) in wl.specs.iter().zip(&wl.first_durations) {
        format_row(spec, durs, &mut out);
    }
    out
}

fn collect<R: Read>(reader: TraceReader<R>) -> Result<Workload, TraceError> {
    let mut specs = Vec::new();
    let mut first_durations = Vec::new();
    for row in reader {
        let row = row?;
        specs.push(row.spec);
        first_durations.push(row.durations);
    }
    Ok(Workload { specs, first_durations })
}

/// Parse the trace format (native schema, header required).
pub fn from_string(text: &str) -> Result<Workload, TraceError> {
    collect(TraceReader::new(text.as_bytes(), "<string>", TraceFormat::Native))
}

pub fn save(wl: &Workload, path: impl AsRef<Path>) -> Result<(), String> {
    fs::write(path.as_ref(), to_string(wl)).map_err(|e| e.to_string())
}

/// Materialize a whole trace file (any [`TraceFormat::Auto`]-detectable
/// schema) into memory.
pub fn load(path: impl AsRef<Path>) -> Result<Workload, TraceError> {
    collect(TraceReader::open(path, TraceFormat::Auto)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::generator::generate;
    use crate::config::WorkloadConfig;

    #[test]
    fn roundtrip() {
        let wl = generate(&WorkloadConfig::paper(2.0), 50.0, 3);
        let text = to_string(&wl);
        let back = from_string(&text).unwrap();
        assert_eq!(wl.specs.len(), back.specs.len());
        for (a, b) in wl.specs.iter().zip(&back.specs) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.dist, b.dist);
            assert_eq!(a.num_tasks, b.num_tasks);
        }
        assert_eq!(wl.first_durations, back.first_durations);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(from_string("nope\n").is_err());
    }

    #[test]
    fn rejects_duration_mismatch() {
        let text = format!("{HEADER}\n0,0.0,1.0,2.0,3,1.5;2.5\n");
        let err = from_string(&text).unwrap_err();
        assert!(err.to_string().contains("durations"), "{err}");
        assert_eq!(err.line(), Some(2));
    }

    #[test]
    fn rejects_non_dense_ids() {
        let text = format!("{HEADER}\n5,0.0,1.0,2.0,1,1.5\n");
        let err = from_string(&text).unwrap_err();
        assert!(err.to_string().contains("non-dense"), "{err}");
    }

    #[test]
    fn file_roundtrip() {
        let wl = generate(&WorkloadConfig::paper(1.0), 20.0, 4);
        let dir = std::env::temp_dir().join("specsim_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wl.csv");
        save(&wl, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.specs.len(), wl.specs.len());
    }
}
