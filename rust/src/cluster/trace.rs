//! Workload trace I/O: CSV with one row per job plus its pre-sampled
//! first-copy durations.  Lets a generated workload be frozen to disk and
//! replayed exactly (e.g. to diff schedulers out-of-process, or to feed the
//! end-to-end example a fixed "production" trace).
//!
//! Format (header line, then one line per job):
//!   job,arrival,mu,alpha,num_tasks,durations...
//! where `durations...` is `num_tasks` semicolon-separated floats.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::stats::Pareto;

use super::job::{JobId, JobSpec};
use super::sim::Workload;

pub const HEADER: &str = "job,arrival,mu,alpha,num_tasks,durations";

/// Serialize a workload to the trace format.
pub fn to_string(wl: &Workload) -> String {
    let mut out = String::with_capacity(wl.specs.len() * 64);
    out.push_str(HEADER);
    out.push('\n');
    for (spec, durs) in wl.specs.iter().zip(&wl.first_durations) {
        let _ = write!(
            out,
            "{},{},{},{},{},",
            spec.id.0, spec.arrival, spec.dist.mu, spec.dist.alpha, spec.num_tasks
        );
        for (i, d) in durs.iter().enumerate() {
            if i > 0 {
                out.push(';');
            }
            let _ = write!(out, "{d}");
        }
        out.push('\n');
    }
    out
}

/// Parse the trace format.
pub fn from_string(text: &str) -> Result<Workload, String> {
    let mut lines = text.lines();
    match lines.next() {
        Some(h) if h.trim() == HEADER => {}
        other => return Err(format!("bad header: {other:?}")),
    }
    let mut specs = Vec::new();
    let mut first_durations = Vec::new();
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.splitn(6, ',').collect();
        if fields.len() != 6 {
            return Err(format!("line {}: expected 6 fields", lineno + 2));
        }
        let parse = |s: &str| -> Result<f64, String> {
            s.parse().map_err(|e| format!("line {}: {e}", lineno + 2))
        };
        let id: u32 = fields[0]
            .parse()
            .map_err(|e| format!("line {}: {e}", lineno + 2))?;
        let arrival = parse(fields[1])?;
        let mu = parse(fields[2])?;
        let alpha = parse(fields[3])?;
        let num_tasks: u32 = fields[4]
            .parse()
            .map_err(|e| format!("line {}: {e}", lineno + 2))?;
        let durs: Result<Vec<f64>, String> = fields[5].split(';').map(parse).collect();
        let durs = durs?;
        if durs.len() != num_tasks as usize {
            return Err(format!(
                "line {}: {} durations for {} tasks",
                lineno + 2,
                durs.len(),
                num_tasks
            ));
        }
        if id as usize != specs.len() {
            return Err(format!("line {}: non-dense job id {id}", lineno + 2));
        }
        specs.push(JobSpec {
            id: JobId(id),
            arrival,
            dist: Pareto::new(mu, alpha),
            num_tasks,
        });
        first_durations.push(durs);
    }
    Ok(Workload { specs, first_durations })
}

pub fn save(wl: &Workload, path: impl AsRef<Path>) -> Result<(), String> {
    fs::write(path.as_ref(), to_string(wl)).map_err(|e| e.to_string())
}

pub fn load(path: impl AsRef<Path>) -> Result<Workload, String> {
    from_string(&fs::read_to_string(path.as_ref()).map_err(|e| e.to_string())?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::generator::generate;
    use crate::config::WorkloadConfig;

    #[test]
    fn roundtrip() {
        let wl = generate(&WorkloadConfig::paper(2.0), 50.0, 3);
        let text = to_string(&wl);
        let back = from_string(&text).unwrap();
        assert_eq!(wl.specs.len(), back.specs.len());
        for (a, b) in wl.specs.iter().zip(&back.specs) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.dist, b.dist);
            assert_eq!(a.num_tasks, b.num_tasks);
        }
        assert_eq!(wl.first_durations, back.first_durations);
    }

    #[test]
    fn rejects_bad_header() {
        assert!(from_string("nope\n").is_err());
    }

    #[test]
    fn rejects_duration_mismatch() {
        let text = format!("{HEADER}\n0,0.0,1.0,2.0,3,1.5;2.5\n");
        assert!(from_string(&text).unwrap_err().contains("durations"));
    }

    #[test]
    fn rejects_non_dense_ids() {
        let text = format!("{HEADER}\n5,0.0,1.0,2.0,1,1.5\n");
        assert!(from_string(&text).unwrap_err().contains("non-dense"));
    }

    #[test]
    fn file_roundtrip() {
        let wl = generate(&WorkloadConfig::paper(1.0), 20.0, 4);
        let dir = std::env::temp_dir().join("specsim_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("wl.csv");
        save(&wl, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.specs.len(), wl.specs.len());
    }
}
