//! Typed configuration for the cluster, workload and schedulers.
//!
//! Everything the paper's evaluation varies is a field here; `SimConfig`
//! deserializes from TOML (see `examples/*.toml` usage in the README) and
//! the CLI builds it from flags.  Defaults reproduce the paper's Sec. IV-C
//! simulation set-up.

use crate::cluster::event::EventQueueKind;
use crate::cluster::machine::{self, ChurnConfig, MachineClass, SlowdownConfig};
use crate::scheduler::SchedulerKind;
use crate::util::toml_lite;

/// Cluster + policy configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Number of machines M (paper: 3000 for the multi-job experiments).
    pub machines: usize,
    /// Heterogeneous cluster scenario: machine classes with speed factors
    /// (see `cluster::machine`).  Empty = the paper's homogeneous cluster of
    /// `machines` speed-1.0 hosts.  When non-empty, class counts must sum to
    /// `machines`.
    pub machine_classes: Vec<MachineClass>,
    /// Server-dependent slowdown scenario (cf. Anselmi & Walton): each
    /// machine is independently degraded with probability `frac`, inflating
    /// its copies' wall-clock by `factor`.  The state is hidden from
    /// schedulers (see `estimator`).  `None` = all machines healthy.
    pub slowdown: Option<SlowdownConfig>,
    /// Machine churn scenario ("failures are the norm rather than the
    /// exception"): each machine independently crashes after an
    /// Exp(1/MTTF) up-time — killing every resident copy (work lost,
    /// restart from zero) and leaving the pool — then rejoins after an
    /// Exp(1/MTTR) repair.  Spec string `MTTF,MTTR` (means, not rates);
    /// `None` or zero rates = no churn, bit-identical to pre-churn
    /// behavior (the dedicated seed stream is never touched).  See
    /// `cluster::machine::ChurnConfig` and DESIGN.md §17.
    pub churn: Option<ChurnConfig>,
    /// Let the schedulers' estimators divide by the running copy's
    /// advertised host speed (`estimator::SpeedAware`).  A no-op on
    /// homogeneous speed-1.0 clusters; `false` reproduces the unit-naive
    /// estimates that treat wall-clock as work (the paper's homogeneous
    /// assumption).
    pub speed_aware: bool,
    /// Speed-aware estimators use the copy's **observed** throughput
    /// (revealed work over elapsed wall, `estimator::SpeedAware::observed`)
    /// instead of the advertised class speed once the copy's checkpoint has
    /// revealed its true remaining time; pre-reveal both variants read the
    /// advertised speed, so this is a no-op unless slowdown states (or
    /// ON/OFF flips) make observed and advertised speeds diverge.  Ignored
    /// when `speed_aware` is false.
    pub observed_speed: bool,
    /// Simulation horizon in time units (paper: 1500).
    pub horizon: f64,
    /// Scheduling-slot length (the paper's slotted decision model).
    pub slot_dt: f64,
    /// RNG seed; every entity derives an independent stream from it.
    pub seed: u64,
    /// Resource cost per unit machine-time (paper: gamma = 0.01).
    pub gamma: f64,
    /// Fraction of work a copy must complete before the scheduler learns its
    /// true remaining time (the paper's s_i monitoring model, Sec. V).
    pub detect_frac: f64,
    /// Maximum copies per task r (paper: 8 in Fig. 1).
    pub r_max: u32,
    /// Straggler threshold multiplier sigma; `None` = derive the optimum
    /// from the analysis (Theorem 3 / Eq. 30-33).
    pub sigma: Option<f64>,
    /// Which speculative-execution policy to run.
    pub scheduler: SchedulerKind,
    /// ESE small-job gate: m_i < eta_small * N(l)/|chi(l)| (paper: 0.1).
    pub eta_small: f64,
    /// ESE small-job gate: `E[x] < xi_small` (paper: 1.0).
    pub xi_small: f64,
    /// Clones per task for the `clone_all` policy / the `clone` rule's
    /// default fixed budget (the Eq. 3 analysis uses 2; must be >= 2).
    pub clone_copies: u32,
    /// CloneAll in strict mode (always `clone_copies` clones; see Sec. III).
    pub clone_strict: bool,
    /// Mantri duplicate rule P(t_rem > 2 t_new) > delta (paper: 0.25).
    pub mantri_delta: f64,
    /// Also kill never-ending originals under Mantri (paper mentions Mantri
    /// may terminate tasks; off by default, ablation flag).
    pub mantri_kill: bool,
    /// Mantri job ordering: false = FIFO (Dryad's stock scheduler — the
    /// weak baseline the paper's Fig. 2 numbers imply), true = the same
    /// SRPT levels the paper's algorithms use (the like-for-like baseline
    /// its Fig. 6 numbers imply; ESE is "an extension of Mantri").
    pub mantri_srpt: bool,
    /// LATE: cap on outstanding speculative copies as a fraction of M.
    pub late_speculative_cap: f64,
    /// LATE: slow-task progress-rate percentile threshold.
    pub late_slow_percentile: f64,
    /// Use the PJRT runtime artifacts for SCA's P2 solve when available
    /// (falls back to the pure-rust solver otherwise).
    pub use_runtime: bool,
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: String,
    /// Cap on jobs per P2 batch (must match the artifact batch dimension).
    pub p2_batch: usize,
    /// Collect a per-job record stream (disable for huge sweeps).
    pub record_jobs: bool,
    /// Bounded-memory job accounting: once this many completed-job records
    /// are retained, the simulator drains them into streaming sketches
    /// (`metrics::StreamedJobStats` — Welford moments + P² percentile
    /// markers) and recycles their task-arena rows and duration buffers.
    /// `None` (the default) retains every record, the exact-percentile
    /// path.  With a cap, a million-job trace replays in O(cap) memory;
    /// the simulated dynamics are bit-identical either way — only the
    /// metric aggregation switches from exact to sketched.
    pub max_resident_jobs: Option<usize>,
    /// Demand-driven scheduler wakeups (the default): grid slots that are
    /// provably no-ops — no cluster mutation since the last fired slot
    /// and no time-dependent rule predicate due (`Scheduler::
    /// next_decision_time`) — never run the scheduler.  Decisions stay
    /// quantized to the `slot_dt` grid and are bit-identical to the
    /// polled loop; `false` (CLI `--no-wakeup`) fires every grid slot —
    /// the retired polling loop, kept as the equivalence reference.  See
    /// `cluster::sim::SlotGate` and DESIGN.md §12.
    pub wakeup: bool,
    /// Drive scheduler slot hooks from the incremental `SchedIndex`
    /// (O(active) queries — the default) instead of the retained naive
    /// full scans (O(everything) — the equivalence reference).  Both paths
    /// make bit-identical scheduling decisions; see `cluster::index` and
    /// the equivalence suite in `tests/experiment_integration.rs`.
    pub sched_index: bool,
    /// Event-queue backend: `calendar` (slot-grid calendar queue — the
    /// default, O(1) pushes at million-machine scale) or `binary-heap`
    /// (the classic heap, retained as the equivalence reference).  Both
    /// pop the identical `(time, seq)` event order; see
    /// `cluster::event::EventQueueKind` and DESIGN.md §13.
    pub event_queue: EventQueueKind,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            machines: 3000,
            machine_classes: Vec::new(),
            slowdown: None,
            churn: None,
            speed_aware: true,
            observed_speed: false,
            horizon: 1500.0,
            slot_dt: 1.0,
            seed: 1,
            gamma: 0.01,
            detect_frac: 0.1,
            r_max: 8,
            sigma: None,
            scheduler: SchedulerKind::Naive,
            eta_small: 0.1,
            xi_small: 1.0,
            clone_copies: 2,
            clone_strict: false,
            mantri_delta: 0.25,
            mantri_kill: false,
            mantri_srpt: false,
            late_speculative_cap: 0.1,
            late_slow_percentile: 0.25,
            use_runtime: true,
            artifacts_dir: "artifacts".to_string(),
            p2_batch: 64,
            record_jobs: true,
            max_resident_jobs: None,
            wakeup: true,
            sched_index: true,
            // SPECSIM_EVENT_QUEUE lets CI re-run the whole suite on the
            // binary-heap reference backend without touching any test;
            // both backends are bit-identical, so every pin (including
            // the committed sweep snapshot) must hold under either value
            event_queue: crate::util::env_or("SPECSIM_EVENT_QUEUE", EventQueueKind::default()),
        }
    }
}

impl SimConfig {
    /// Validate invariants; returns a human-readable error list.
    pub fn validate(&self) -> Result<(), String> {
        let mut errs = Vec::new();
        if self.machines == 0 {
            errs.push("machines must be > 0".to_string());
        }
        if !self.machine_classes.is_empty() {
            let total: usize = self.machine_classes.iter().map(|c| c.count).sum();
            if total != self.machines {
                errs.push(format!(
                    "machine_classes counts sum to {total} but machines = {}",
                    self.machines
                ));
            }
            for c in &self.machine_classes {
                if c.count == 0 {
                    errs.push("machine class count must be > 0".to_string());
                }
                if !(c.speed > 0.0) {
                    errs.push("machine class speed must be > 0".to_string());
                }
            }
        }
        if let Some(sd) = &self.slowdown {
            if let Err(e) = sd.validate() {
                errs.push(e);
            }
        }
        if let Some(ch) = &self.churn {
            if let Err(e) = ch.validate() {
                errs.push(e);
            }
        }
        if !(self.horizon > 0.0) {
            errs.push("horizon must be > 0".to_string());
        }
        if !(self.slot_dt > 0.0) {
            errs.push("slot_dt must be > 0".to_string());
        }
        if !(0.0 < self.detect_frac && self.detect_frac < 1.0) {
            errs.push("detect_frac must be in (0,1)".to_string());
        }
        if self.r_max < 1 {
            errs.push("r_max must be >= 1".to_string());
        }
        if let Some(s) = self.sigma {
            if !(s > 0.0) {
                errs.push("sigma must be > 0".to_string());
            }
        }
        if self.gamma < 0.0 {
            errs.push("gamma must be >= 0".to_string());
        }
        if self.clone_copies < 2 {
            errs.push("clone_copies must be >= 2 (cloning means extra copies)".to_string());
        }
        if self.max_resident_jobs == Some(0) {
            errs.push("max_resident_jobs must be > 0".to_string());
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }

    /// Install a heterogeneous cluster scenario, deriving `machines` from
    /// the class counts so the two stay consistent.
    pub fn set_machine_classes(&mut self, classes: Vec<MachineClass>) {
        self.machines = classes.iter().map(|c| c.count).sum();
        self.machine_classes = classes;
    }

    /// Parse from the TOML subset (see `util::toml_lite`); unknown keys are
    /// rejected so typos fail loudly, missing keys keep their defaults.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = toml_lite::Doc::parse(text)?;
        let mut cfg = SimConfig::default();
        let machines_explicit = doc.get("machines").is_some();
        for key in doc.keys() {
            match key {
                "machines" => cfg.machines = doc.i64(key).ok_or("machines: int")? as usize,
                "machine_classes" => {
                    cfg.machine_classes =
                        machine::parse_classes(doc.str(key).ok_or("machine_classes: string")?)?
                }
                "slowdown" => {
                    cfg.slowdown =
                        Some(machine::parse_slowdown(doc.str(key).ok_or("slowdown: string")?)?)
                }
                "churn" => {
                    cfg.churn = Some(machine::parse_churn(doc.str(key).ok_or("churn: string")?)?)
                }
                "speed_aware" => cfg.speed_aware = doc.bool(key).ok_or("speed_aware: bool")?,
                "observed_speed" => {
                    cfg.observed_speed = doc.bool(key).ok_or("observed_speed: bool")?
                }
                "horizon" => cfg.horizon = doc.f64(key).ok_or("horizon: float")?,
                "slot_dt" => cfg.slot_dt = doc.f64(key).ok_or("slot_dt: float")?,
                "seed" => cfg.seed = doc.i64(key).ok_or("seed: int")? as u64,
                "gamma" => cfg.gamma = doc.f64(key).ok_or("gamma: float")?,
                "detect_frac" => cfg.detect_frac = doc.f64(key).ok_or("detect_frac: float")?,
                "r_max" => cfg.r_max = doc.i64(key).ok_or("r_max: int")? as u32,
                "sigma" => cfg.sigma = Some(doc.f64(key).ok_or("sigma: float")?),
                "scheduler" => {
                    cfg.scheduler = doc
                        .str(key)
                        .ok_or("scheduler: string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                "eta_small" => cfg.eta_small = doc.f64(key).ok_or("eta_small: float")?,
                "xi_small" => cfg.xi_small = doc.f64(key).ok_or("xi_small: float")?,
                "clone_copies" => {
                    cfg.clone_copies = doc.i64(key).ok_or("clone_copies: int")? as u32
                }
                "clone_strict" => cfg.clone_strict = doc.bool(key).ok_or("clone_strict: bool")?,
                "mantri_delta" => cfg.mantri_delta = doc.f64(key).ok_or("mantri_delta: float")?,
                "mantri_kill" => cfg.mantri_kill = doc.bool(key).ok_or("mantri_kill: bool")?,
                "mantri_srpt" => cfg.mantri_srpt = doc.bool(key).ok_or("mantri_srpt: bool")?,
                "late_speculative_cap" => {
                    cfg.late_speculative_cap = doc.f64(key).ok_or("late_speculative_cap: float")?
                }
                "late_slow_percentile" => {
                    cfg.late_slow_percentile = doc.f64(key).ok_or("late_slow_percentile: float")?
                }
                "use_runtime" => cfg.use_runtime = doc.bool(key).ok_or("use_runtime: bool")?,
                "artifacts_dir" => {
                    cfg.artifacts_dir = doc.str(key).ok_or("artifacts_dir: string")?.to_string()
                }
                "p2_batch" => cfg.p2_batch = doc.i64(key).ok_or("p2_batch: int")? as usize,
                "record_jobs" => cfg.record_jobs = doc.bool(key).ok_or("record_jobs: bool")?,
                "max_resident_jobs" => {
                    cfg.max_resident_jobs =
                        Some(doc.i64(key).ok_or("max_resident_jobs: int")? as usize)
                }
                "wakeup" => cfg.wakeup = doc.bool(key).ok_or("wakeup: bool")?,
                "sched_index" => cfg.sched_index = doc.bool(key).ok_or("sched_index: bool")?,
                "event_queue" => {
                    cfg.event_queue = doc
                        .str(key)
                        .ok_or("event_queue: string")?
                        .parse()
                        .map_err(|e: String| e)?
                }
                other => return Err(format!("unknown config key '{other}'")),
            }
        }
        // like the CLI, derive the machine count from the class layout when
        // only machine_classes is given (an explicit machines key must agree
        // — validate() checks that)
        if !cfg.machine_classes.is_empty() && !machines_explicit {
            cfg.machines = cfg.machine_classes.iter().map(|c| c.count).sum();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Emit the TOML subset (round-trips through `from_toml`).
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        use std::fmt::Write as _;
        let _ = writeln!(s, "machines = {}", self.machines);
        if !self.machine_classes.is_empty() {
            let _ = writeln!(
                s,
                "machine_classes = \"{}\"",
                machine::format_classes(&self.machine_classes)
            );
        }
        if let Some(sd) = &self.slowdown {
            let _ = writeln!(s, "slowdown = \"{}\"", machine::format_slowdown(sd));
        }
        if let Some(ch) = &self.churn {
            let _ = writeln!(s, "churn = \"{}\"", machine::format_churn(ch));
        }
        let _ = writeln!(s, "speed_aware = {}", self.speed_aware);
        let _ = writeln!(s, "observed_speed = {}", self.observed_speed);
        let _ = writeln!(s, "horizon = {:?}", self.horizon);
        let _ = writeln!(s, "slot_dt = {:?}", self.slot_dt);
        let _ = writeln!(s, "seed = {}", self.seed);
        let _ = writeln!(s, "gamma = {:?}", self.gamma);
        let _ = writeln!(s, "detect_frac = {:?}", self.detect_frac);
        let _ = writeln!(s, "r_max = {}", self.r_max);
        if let Some(sig) = self.sigma {
            let _ = writeln!(s, "sigma = {sig:?}");
        }
        let _ = writeln!(s, "scheduler = \"{}\"", self.scheduler);
        let _ = writeln!(s, "eta_small = {:?}", self.eta_small);
        let _ = writeln!(s, "xi_small = {:?}", self.xi_small);
        let _ = writeln!(s, "clone_copies = {}", self.clone_copies);
        let _ = writeln!(s, "clone_strict = {}", self.clone_strict);
        let _ = writeln!(s, "mantri_delta = {:?}", self.mantri_delta);
        let _ = writeln!(s, "mantri_kill = {}", self.mantri_kill);
        let _ = writeln!(s, "mantri_srpt = {}", self.mantri_srpt);
        let _ = writeln!(s, "late_speculative_cap = {:?}", self.late_speculative_cap);
        let _ = writeln!(s, "late_slow_percentile = {:?}", self.late_slow_percentile);
        let _ = writeln!(s, "use_runtime = {}", self.use_runtime);
        let _ = writeln!(s, "artifacts_dir = \"{}\"", self.artifacts_dir);
        let _ = writeln!(s, "p2_batch = {}", self.p2_batch);
        let _ = writeln!(s, "record_jobs = {}", self.record_jobs);
        if let Some(cap) = self.max_resident_jobs {
            let _ = writeln!(s, "max_resident_jobs = {cap}");
        }
        let _ = writeln!(s, "wakeup = {}", self.wakeup);
        let _ = writeln!(s, "sched_index = {}", self.sched_index);
        let _ = writeln!(s, "event_queue = \"{}\"", self.event_queue);
        s
    }
}

/// How the sharded serve plane routes submissions across shard masters
/// (see `coordinator::shard`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Seeded modulo hash of the submission's shape: identical submissions
    /// always land on the same shard (deterministic, stateless).
    #[default]
    Hash,
    /// Power-of-two-choices on the per-shard `queued_tasks` gauge: draw two
    /// shards, send to the less loaded (spreads hot spots).
    P2c,
}

impl std::str::FromStr for RoutePolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "hash" => Ok(RoutePolicy::Hash),
            "p2c" => Ok(RoutePolicy::P2c),
            other => Err(format!("unknown route policy '{other}' (expected hash|p2c)")),
        }
    }
}

impl std::fmt::Display for RoutePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RoutePolicy::Hash => "hash",
            RoutePolicy::P2c => "p2c",
        })
    }
}

/// Sharded serve-plane configuration (`serve --shards N --route hash|p2c`).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Number of shard masters; each owns a disjoint machine partition.
    pub shards: usize,
    /// Submission routing policy across shards.
    pub route: RoutePolicy,
    /// Seed for the routing hash / p2c draws (independent of the
    /// simulation seed so routing never perturbs per-shard workloads).
    pub route_seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { shards: 1, route: RoutePolicy::Hash, route_seed: 0x5eed5 }
    }
}

impl ServeConfig {
    /// Validate against the deployment's machine count: every shard must
    /// own at least one machine.
    pub fn validate(&self, machines: usize) -> Result<(), String> {
        if self.shards == 0 {
            return Err("shards must be >= 1".to_string());
        }
        if self.shards > machines {
            return Err(format!(
                "shards = {} exceeds machines = {machines}: every shard needs >= 1 machine",
                self.shards
            ));
        }
        Ok(())
    }
}

/// What arrives at the cluster.
#[derive(Clone, Debug)]
pub enum WorkloadConfig {
    /// The paper's multi-job workload: Poisson(lambda) arrivals, task count
    /// ~ U{m_lo..m_hi}, per-job expected duration ~ U[mean_lo, mean_hi],
    /// Pareto(alpha) durations.
    Poisson {
        lambda: f64,
        m_lo: u32,
        m_hi: u32,
        mean_lo: f64,
        mean_hi: f64,
        alpha: f64,
    },
    /// Bursty arrivals: a 2-state MMPP (Markov-modulated Poisson process)
    /// alternating between an ON state at rate `burst * lambda` and a
    /// quieter OFF state, with exponential dwell times.  `lambda` is the
    /// long-run mean arrival rate, `on_frac` the stationary fraction of
    /// time spent ON, and `cycle` the mean ON+OFF cycle length.  The job
    /// mix (task counts, durations) matches the Poisson workload, so only
    /// the arrival correlation changes — the regime Anselmi & Walton show
    /// shifts where speculation pays off.
    Bursty {
        lambda: f64,
        burst: f64,
        on_frac: f64,
        cycle: f64,
        m_lo: u32,
        m_hi: u32,
        mean_lo: f64,
        mean_hi: f64,
        alpha: f64,
    },
    /// The Fig. 5 workload: one job with `tasks` tasks.
    SingleJob { tasks: u32, mean: f64, alpha: f64 },
    /// Replay a recorded trace — whole-file via `cluster::trace::load`, or
    /// streamed in bounded memory through `workload::StreamSource`.
    Trace {
        path: String,
        /// On-disk schema; `Auto` sniffs the first line (native header /
        /// JSONL object / `arrival,duration,tasks` CSV).
        format: crate::workload::TraceFormat,
        /// Streaming lookahead window: the max number of un-admitted jobs
        /// resident while the simulator pulls arrivals.
        window: usize,
        /// Stop after this many jobs (`None` = the whole trace).
        max_jobs: Option<u64>,
        /// Override for `mean_tasks()`; when `None` the moment is derived
        /// by a streaming pre-pass over the trace (`workload::scan`).
        mean_tasks_hint: Option<f64>,
        /// Override for `mean_duration()`; same pre-pass fallback.
        mean_duration_hint: Option<f64>,
    },
}

impl WorkloadConfig {
    /// The paper's Sec. IV-C settings with a caller-chosen arrival rate.
    pub fn paper(lambda: f64) -> Self {
        WorkloadConfig::Poisson {
            lambda,
            m_lo: 1,
            m_hi: 100,
            mean_lo: 1.0,
            mean_hi: 4.0,
            alpha: 2.0,
        }
    }

    /// The paper's job mix with bursty (MMPP) instead of Poisson arrivals.
    /// `burst` is the ON-state rate multiplier; the defaults (ON a quarter
    /// of the time, 40-unit cycles) keep tens of cycles inside the paper's
    /// 1500-unit horizon.  Requires `burst * on_frac <= 1` so the OFF rate
    /// stays non-negative.
    pub fn bursty_paper(lambda: f64, burst: f64) -> Self {
        WorkloadConfig::Bursty {
            lambda,
            burst,
            on_frac: 0.25,
            cycle: 40.0,
            m_lo: 1,
            m_hi: 100,
            mean_lo: 1.0,
            mean_hi: 4.0,
            alpha: 2.0,
        }
    }

    /// A trace workload with default streaming settings: autodetected
    /// format, the default lookahead window, no job cap, moments derived
    /// on demand from the pre-pass.
    pub fn trace(path: impl Into<String>) -> Self {
        WorkloadConfig::Trace {
            path: path.into(),
            format: crate::workload::TraceFormat::Auto,
            window: crate::workload::DEFAULT_WINDOW,
            max_jobs: None,
            mean_tasks_hint: None,
            mean_duration_hint: None,
        }
    }

    /// Mean tasks per job `E[m_i]`.
    ///
    /// For traces this is the explicit hint when present, otherwise one
    /// streaming pre-pass over the file (each call re-scans — cache the
    /// value or set the hint on hot paths); NaN only if the trace is
    /// unreadable.
    pub fn mean_tasks(&self) -> f64 {
        match self {
            WorkloadConfig::Poisson { m_lo, m_hi, .. }
            | WorkloadConfig::Bursty { m_lo, m_hi, .. } => 0.5 * (*m_lo as f64 + *m_hi as f64),
            WorkloadConfig::SingleJob { tasks, .. } => *tasks as f64,
            WorkloadConfig::Trace { path, format, mean_tasks_hint, .. } => mean_tasks_hint
                .unwrap_or_else(|| {
                    crate::workload::scan(path, *format)
                        .map(|s| s.tasks.mean())
                        .unwrap_or(f64::NAN)
                }),
        }
    }

    /// Mean task duration `E[s]`.
    ///
    /// Same hint-then-pre-pass contract as [`WorkloadConfig::mean_tasks`].
    pub fn mean_duration(&self) -> f64 {
        match self {
            WorkloadConfig::Poisson { mean_lo, mean_hi, .. }
            | WorkloadConfig::Bursty { mean_lo, mean_hi, .. } => 0.5 * (mean_lo + mean_hi),
            WorkloadConfig::SingleJob { mean, .. } => *mean,
            WorkloadConfig::Trace { path, format, mean_duration_hint, .. } => mean_duration_hint
                .unwrap_or_else(|| {
                    crate::workload::scan(path, *format)
                        .map(|s| s.duration.mean())
                        .unwrap_or(f64::NAN)
                }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_configs_rejected() {
        let mut c = SimConfig::default();
        c.machines = 0;
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.detect_frac = 1.5;
        assert!(c.validate().is_err());
        let mut c = SimConfig::default();
        c.sigma = Some(-1.0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn toml_roundtrip() {
        let mut cfg = SimConfig::default();
        cfg.sigma = Some(1.7);
        cfg.scheduler = SchedulerKind::Ese;
        let text = cfg.to_toml();
        let back = SimConfig::from_toml(&text).unwrap();
        assert_eq!(back.machines, cfg.machines);
        assert_eq!(back.scheduler, cfg.scheduler);
        assert_eq!(back.sigma, cfg.sigma);
        assert_eq!(back.artifacts_dir, cfg.artifacts_dir);
    }

    #[test]
    fn composed_scheduler_roundtrips_through_toml() {
        let mut cfg = SimConfig::default();
        cfg.scheduler = "est-srpt+ese*cap2".parse().unwrap();
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.scheduler, cfg.scheduler);
        assert_eq!(back.scheduler.to_string(), "est-srpt+ese*cap2");
        // the grammar is reachable straight from TOML text too
        let cfg = SimConfig::from_toml("scheduler = \"fifo+sda\"").unwrap();
        assert_eq!(cfg.scheduler.to_string(), "fifo+sda");
        assert!(SimConfig::from_toml("scheduler = \"fifo+bogus\"").is_err());
    }

    #[test]
    fn clone_copies_key_parses_and_validates() {
        assert_eq!(SimConfig::default().clone_copies, 2);
        let cfg = SimConfig::from_toml("clone_copies = 3").unwrap();
        assert_eq!(cfg.clone_copies, 3);
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.clone_copies, 3);
        assert!(SimConfig::from_toml("clone_copies = 1").is_err());
    }

    #[test]
    fn wakeup_flag_roundtrips() {
        assert!(SimConfig::default().wakeup, "demand-driven wakeups are the default");
        let cfg = SimConfig::from_toml("wakeup = false").unwrap();
        assert!(!cfg.wakeup);
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert!(!back.wakeup);
        // the policy-pipeline equivalence flag is gone with the monoliths
        assert!(SimConfig::from_toml("legacy_sched = true").is_err());
    }

    #[test]
    fn max_resident_jobs_roundtrips_and_validates() {
        assert_eq!(SimConfig::default().max_resident_jobs, None);
        let cfg = SimConfig::from_toml("max_resident_jobs = 4096").unwrap();
        assert_eq!(cfg.max_resident_jobs, Some(4096));
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.max_resident_jobs, Some(4096));
        assert!(SimConfig::from_toml("max_resident_jobs = 0").is_err());
    }

    #[test]
    fn trace_moments_use_hints_without_touching_disk() {
        let mut w = WorkloadConfig::trace("/nonexistent/trace.csv");
        // unreadable trace and no hints: NaN, but no panic
        assert!(w.mean_tasks().is_nan());
        assert!(w.mean_duration().is_nan());
        if let WorkloadConfig::Trace { mean_tasks_hint, mean_duration_hint, .. } = &mut w {
            *mean_tasks_hint = Some(50.5);
            *mean_duration_hint = Some(2.5);
        }
        assert!((w.mean_tasks() - 50.5).abs() < 1e-12);
        assert!((w.mean_duration() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn trace_moments_derive_from_pre_pass() {
        let dir = std::env::temp_dir().join("specsim_config_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("moments.csv");
        let text = "job,arrival,mu,alpha,num_tasks,durations\n\
                    0,0,1,2,2,1.5;2.5\n\
                    1,1,2,2,4,2;2;2;2\n";
        std::fs::write(&path, text).unwrap();
        let w = WorkloadConfig::trace(path.to_str().unwrap());
        assert!((w.mean_tasks() - 3.0).abs() < 1e-12);
        // mean_duration averages dist.mean() = mu * alpha / (alpha - 1)
        assert!((w.mean_duration() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(SimConfig::from_toml("machinez = 5").is_err());
    }

    #[test]
    fn toml_partial_uses_defaults() {
        let cfg = SimConfig::from_toml("machines = 100\nhorizon = 50.0").unwrap();
        assert_eq!(cfg.machines, 100);
        assert_eq!(cfg.slot_dt, 1.0);
    }

    #[test]
    fn paper_workload_moments() {
        let w = WorkloadConfig::paper(6.0);
        assert!((w.mean_tasks() - 50.5).abs() < 1e-12);
        assert!((w.mean_duration() - 2.5).abs() < 1e-12);
        // same job mix under bursty arrivals
        let b = WorkloadConfig::bursty_paper(6.0, 3.0);
        assert!((b.mean_tasks() - 50.5).abs() < 1e-12);
        assert!((b.mean_duration() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn machine_classes_validate_and_roundtrip() {
        let mut cfg = SimConfig::default();
        cfg.set_machine_classes(vec![
            MachineClass::new(2000, 1.0),
            MachineClass::new(1000, 0.5),
        ]);
        assert_eq!(cfg.machines, 3000);
        cfg.validate().unwrap();
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.machine_classes, cfg.machine_classes);
        // mismatched counts are rejected
        cfg.machines = 10;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn slowdown_validates_and_roundtrips() {
        let mut cfg = SimConfig::default();
        cfg.slowdown = Some(SlowdownConfig::new(0.1, 4.0));
        cfg.speed_aware = false;
        cfg.validate().unwrap();
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.slowdown, cfg.slowdown);
        assert!(!back.speed_aware);
        // defaults: no slowdown, speed-aware on
        let d = SimConfig::default();
        assert_eq!(d.slowdown, None);
        assert!(d.speed_aware);
        // out-of-range specs are rejected
        cfg.slowdown = Some(SlowdownConfig::new(2.0, 4.0));
        assert!(cfg.validate().is_err());
        cfg.slowdown = Some(SlowdownConfig::new(0.1, 0.5));
        assert!(cfg.validate().is_err());
        assert!(SimConfig::from_toml("slowdown = \"0.1x0.5\"").is_err());
        let cfg = SimConfig::from_toml("slowdown = \"0.25x3.0\"").unwrap();
        assert_eq!(cfg.slowdown, Some(SlowdownConfig::new(0.25, 3.0)));
    }

    #[test]
    fn slowdown_flip_rates_roundtrip_through_toml() {
        let mut cfg = SimConfig::default();
        cfg.slowdown = Some(SlowdownConfig::new(0.2, 3.0).with_rates(0.05, 0.1));
        cfg.validate().unwrap();
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.slowdown, cfg.slowdown);
        assert!(back.slowdown.unwrap().flips_enabled());
        // rate suffix is reachable straight from TOML text
        let cfg = SimConfig::from_toml("slowdown = \"0.2x3.0@0.05,0.1\"").unwrap();
        assert_eq!(cfg.slowdown, Some(SlowdownConfig::new(0.2, 3.0).with_rates(0.05, 0.1)));
        assert!(SimConfig::from_toml("slowdown = \"0.2x3.0@-1.0,0.1\"").is_err());
        // negative rates are rejected at validate() too
        let mut cfg = SimConfig::default();
        cfg.slowdown = Some(SlowdownConfig::new(0.2, 3.0).with_rates(-0.05, 0.1));
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn churn_key_roundtrips_and_validates() {
        assert_eq!(SimConfig::default().churn, None, "no churn by default");
        let mut cfg = SimConfig::default();
        cfg.churn = Some(ChurnConfig::new(200.0, 20.0));
        cfg.validate().unwrap();
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.churn, cfg.churn);
        assert!(back.churn.unwrap().enabled());
        // reachable straight from TOML text; zero rates parse but disable
        let cfg = SimConfig::from_toml("churn = \"100,10\"").unwrap();
        assert_eq!(cfg.churn, Some(ChurnConfig::new(100.0, 10.0)));
        let cfg = SimConfig::from_toml("churn = \"0,0\"").unwrap();
        assert!(!cfg.churn.unwrap().enabled());
        // malformed or one-sided specs fail loudly
        assert!(SimConfig::from_toml("churn = \"100\"").is_err());
        assert!(SimConfig::from_toml("churn = \"100,0\"").is_err());
        let mut cfg = SimConfig::default();
        cfg.churn = Some(ChurnConfig::new(-1.0, 10.0));
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn observed_speed_flag_roundtrips() {
        assert!(!SimConfig::default().observed_speed, "advertised speed is the default");
        let cfg = SimConfig::from_toml("observed_speed = true").unwrap();
        assert!(cfg.observed_speed);
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert!(back.observed_speed);
    }

    #[test]
    fn sched_index_flag_roundtrips() {
        assert!(SimConfig::default().sched_index, "index path is the default");
        let cfg = SimConfig::from_toml("sched_index = false").unwrap();
        assert!(!cfg.sched_index);
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert!(!back.sched_index);
    }

    #[test]
    fn event_queue_key_roundtrips() {
        // the default honors the SPECSIM_EVENT_QUEUE CI override (the
        // both-backends test pass); unset it is the calendar queue
        let expected = crate::util::env_or("SPECSIM_EVENT_QUEUE", EventQueueKind::Calendar);
        assert_eq!(SimConfig::default().event_queue, expected);
        if std::env::var_os("SPECSIM_EVENT_QUEUE").is_none() {
            assert_eq!(expected, EventQueueKind::Calendar, "calendar backend is the default");
        }
        let cfg = SimConfig::from_toml("event_queue = \"binary-heap\"").unwrap();
        assert_eq!(cfg.event_queue, EventQueueKind::BinaryHeap);
        let back = SimConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.event_queue, EventQueueKind::BinaryHeap);
        assert!(SimConfig::from_toml("event_queue = \"splay\"").is_err());
    }

    #[test]
    fn route_policy_parses_and_displays() {
        assert_eq!("hash".parse::<RoutePolicy>().unwrap(), RoutePolicy::Hash);
        assert_eq!("p2c".parse::<RoutePolicy>().unwrap(), RoutePolicy::P2c);
        assert!("rendezvous".parse::<RoutePolicy>().is_err());
        assert_eq!(RoutePolicy::Hash.to_string(), "hash");
        assert_eq!(RoutePolicy::P2c.to_string(), "p2c");
        assert_eq!(RoutePolicy::default(), RoutePolicy::Hash);
    }

    #[test]
    fn serve_config_validates_shard_bounds() {
        let d = ServeConfig::default();
        assert_eq!(d.shards, 1);
        d.validate(1).unwrap();
        let mut s = ServeConfig::default();
        s.shards = 0;
        assert!(s.validate(100).is_err());
        s.shards = 4;
        s.validate(4).unwrap();
        assert!(s.validate(3).is_err());
    }

    #[test]
    fn toml_machine_classes_alone_derive_machines() {
        let cfg = SimConfig::from_toml("machine_classes = \"100x1.0,50x0.5\"").unwrap();
        assert_eq!(cfg.machines, 150);
        // an explicit machines key must agree with the class counts
        assert!(
            SimConfig::from_toml("machines = 3000\nmachine_classes = \"100x1.0\"").is_err()
        );
        let cfg =
            SimConfig::from_toml("machines = 100\nmachine_classes = \"100x1.0\"").unwrap();
        assert_eq!(cfg.machines, 100);
    }
}
