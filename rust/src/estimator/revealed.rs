//! The revealed estimator: the paper's monitoring model (Sec. V).  Once a
//! copy has executed the detection fraction `s_i` of its work the
//! scheduler knows its true remaining time exactly; before
//! that it falls back to the blind conditional-Pareto estimate.
//!
//! Unit-naive like [`Blind`](super::Blind): revealed wall-clock remaining
//! is read as work, exact on the homogeneous speed-1.0 cluster and an
//! approximation elsewhere (use
//! [`SpeedAware::revealed`](super::SpeedAware::revealed) for the corrected
//! variant).

use crate::cluster::job::TaskRef;
use crate::cluster::sim::Cluster;

use super::{flip_guard, observe, RemainingTime};

/// Post-checkpoint truth, blind conditional estimates before it.
pub struct Revealed;

impl RemainingTime for Revealed {
    fn name(&self) -> &'static str {
        "revealed"
    }

    fn copy_remaining_work(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64 {
        let o = observe(cl, t, copy);
        if o.revealed {
            o.revealed_wall
        } else {
            o.dist.mean_remaining(o.elapsed)
        }
    }

    fn copy_remaining_wall(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64 {
        self.copy_remaining_work(cl, t, copy)
    }

    /// Degenerate 0/1 once revealed, conditional survival before.
    fn copy_prob_exceeds(&self, cl: &Cluster, t: TaskRef, copy: usize, a: f64) -> f64 {
        let o = observe(cl, t, copy);
        if o.revealed {
            if o.revealed_wall > a {
                1.0
            } else {
                0.0
            }
        } else {
            o.dist.sf_remaining(o.elapsed, a)
        }
    }

    /// A revealed copy's remaining time only *decays* with the clock, so a
    /// currently-false threshold predicate can never flip up on its own —
    /// `None`.  Unrevealed copies use the blind inverse; the reveal event
    /// itself is a mutation and forces a wakeup independently.
    fn copy_prob_flip_time(
        &self,
        cl: &Cluster,
        t: TaskRef,
        copy: usize,
        a: f64,
        p: f64,
    ) -> Option<f64> {
        let o = observe(cl, t, copy);
        if o.revealed {
            None
        } else {
            o.dist.sf_remaining_flip(a, p).map(|e| flip_guard(cl.clock + (e - o.elapsed)))
        }
    }

    /// Same decay argument as [`RemainingTime::copy_prob_flip_time`].
    fn copy_work_flip_time(&self, cl: &Cluster, t: TaskRef, copy: usize, w: f64) -> Option<f64> {
        let o = observe(cl, t, copy);
        if o.revealed {
            None
        } else {
            Some(flip_guard(cl.clock + (o.dist.mean_remaining_flip(w) - o.elapsed)))
        }
    }

    /// A revealed copy's rate denominator is `elapsed + true remaining`
    /// — its constant wall duration — so the rate never drops (`None`);
    /// unrevealed copies decay on the blind Pareto schedule.
    fn copy_rate_flip_time(&self, cl: &Cluster, t: TaskRef, copy: usize, rate: f64) -> Option<f64> {
        let o = observe(cl, t, copy);
        if o.revealed || !(rate > 0.0) {
            None
        } else {
            let e = o.dist.rate_denom_flip(1.0 / rate);
            Some(flip_guard(cl.clock + (e - o.elapsed)))
        }
    }
}
