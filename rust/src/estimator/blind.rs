//! The blind estimator: conditional Pareto statistics from elapsed time
//! only.  This is all a scheduler *without* the paper's `s_i`-checkpoint
//! instrumentation (the Mantri/LATE baselines) can know — granting them
//! the revealed truth would make the baselines implausibly strong (it
//! roughly halved the paper's reported gaps in early versions of this
//! reproduction).
//!
//! Unit-naive: wall-clock elapsed time is fed to the work-unit
//! distribution unchanged, exact on the paper's homogeneous speed-1.0
//! cluster and an approximation elsewhere (use
//! [`SpeedAware::blind`](super::SpeedAware::blind) for the corrected
//! variant).

use crate::cluster::job::TaskRef;
use crate::cluster::sim::Cluster;

use super::{flip_guard, observe, RemainingTime};

/// Conditional-mean / conditional-survival estimates given elapsed time
/// only; never the revealed truth, never the host speed.
pub struct Blind;

impl RemainingTime for Blind {
    fn name(&self) -> &'static str {
        "blind"
    }

    /// `E[x - e | x > e]` with wall-clock elapsed `e` read as work.
    fn copy_remaining_work(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64 {
        let o = observe(cl, t, copy);
        o.dist.mean_remaining(o.elapsed)
    }

    /// Identical to the work estimate (speed assumed 1).
    fn copy_remaining_wall(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64 {
        self.copy_remaining_work(cl, t, copy)
    }

    /// `P(x > e + a | x > e)` — the conditional Pareto survival Mantri's
    /// duplicate rule tests against its `delta`.
    fn copy_prob_exceeds(&self, cl: &Cluster, t: TaskRef, copy: usize, a: f64) -> f64 {
        let o = observe(cl, t, copy);
        o.dist.sf_remaining(o.elapsed, a)
    }

    /// Exact inverse of the survival predicate above: elapsed time is the
    /// only moving part, so the predicate first flips when wall-clock
    /// elapsed reaches `sf_remaining_flip(a, p)` (work read as wall).
    fn copy_prob_flip_time(
        &self,
        cl: &Cluster,
        t: TaskRef,
        copy: usize,
        a: f64,
        p: f64,
    ) -> Option<f64> {
        let o = observe(cl, t, copy);
        o.dist.sf_remaining_flip(a, p).map(|e| flip_guard(cl.clock + (e - o.elapsed)))
    }

    /// Exact inverse of the conditional-mean estimate (same unit-naive
    /// elapsed-as-work reading as the forward query).
    fn copy_work_flip_time(&self, cl: &Cluster, t: TaskRef, copy: usize, w: f64) -> Option<f64> {
        let o = observe(cl, t, copy);
        Some(flip_guard(cl.clock + (o.dist.mean_remaining_flip(w) - o.elapsed)))
    }

    /// Exact inverse of the LATE progress-rate denominator
    /// `e + mean_remaining(e)` (elapsed read as work, like the forward
    /// queries).
    fn copy_rate_flip_time(&self, cl: &Cluster, t: TaskRef, copy: usize, rate: f64) -> Option<f64> {
        if !(rate > 0.0) {
            return None; // a positive rate never drops below zero
        }
        let o = observe(cl, t, copy);
        let e = o.dist.rate_denom_flip(1.0 / rate);
        Some(flip_guard(cl.clock + (e - o.elapsed)))
    }
}
