//! # Remaining-time estimation — the scheduler ⇄ simulator contract
//!
//! Every speculation decision in the paper reduces to a remaining-time
//! query: Mantri duplicates when `P(t_rem > 2 E[x]) > delta` (its rule's
//! `delta`), SDA/ESE declare a straggler when the remaining time exceeds
//! `sigma * E[x]` (Sec. V–VI), LATE ranks tasks by progress rate.  This
//! module centralizes those queries behind one trait so that (a) every
//! scheduler states exactly *what it is allowed to know*, and (b) the
//! heterogeneous-cluster and server-slowdown scenario axes can be handled
//! once, correctly, instead of ad hoc in each policy.
//!
//! ## Observation contract
//!
//! The simulator measures copies in **work units** (samples of the job's
//! Pareto task-duration distribution, the paper's `x` with tail index
//! `alpha`) but runs them in **wall-clock**: a copy of work `w` on host
//! `h` finishes after `w / effective_speed(h)` wall-clock units, where
//! `effective_speed = advertised class speed / hidden slowdown` (see
//! [`crate::cluster::machine`]).  An estimator may read, per copy (via
//! [`CopyObs`]):
//!
//! * the job's duration distribution (the paper's per-job Pareto);
//! * the copy's wall-clock elapsed time;
//! * whether the copy passed its detection checkpoint (the paper's `s_i`
//!   monitoring fraction, Sec. V) and, if so, its true remaining
//!   *wall-clock* time;
//! * the **advertised class speed** of the copy's host — public hardware
//!   knowledge.
//!
//! It may *not* read an unrevealed copy's true duration, nor the host's
//! hidden slowdown state.  A degraded host is therefore only detectable
//! through the inflated remaining times it reveals — which is precisely
//! what makes it a legitimate straggler — while a merely slow-*class* host
//! inflates nothing once the class speed is accounted for.
//!
//! ## Implementations
//!
//! | estimator | checkpoint (`s_i`) | class speed | who uses it |
//! |---|---|---|---|
//! | [`Blind`] | no | no | Mantri, LATE (baselines, `speed_aware = false`) |
//! | [`Revealed`] | yes | no | SCA/SDA/ESE with `speed_aware = false` |
//! | [`SpeedAware::blind`] | no | yes | Mantri, LATE (default) |
//! | [`SpeedAware::revealed`] | yes | yes | SCA/SDA/ESE (default) |
//! | [`SpeedAware::observed`] | yes | yes + measured | SCA/SDA/ESE with `observed_speed` |
//!
//! [`for_policy`] maps a config to the right row.  On the paper's
//! homogeneous speed-1.0 cluster every row of a column is identical, so
//! the default (`speed_aware = true`) reproduces the paper's numbers
//! exactly while remaining correct under heterogeneity.  The observed
//! variant additionally distrusts that a host will keep its advertised
//! speed: it projects a revealed copy's remaining wall by the host's
//! *measured* lifetime throughput ([`CopyObs::observed`]), which is what
//! reacts to ON/OFF slowdown flips; with no slowdown it measures exactly
//! the advertised speed and collapses to [`SpeedAware::revealed`].
//!
//! ## Units
//!
//! Queries come in two unit systems and the trait names them explicitly:
//!
//! * `*_work` — work units, the units of `E[x]`; thresholds like
//!   `sigma * E[x]` (SDA/ESE) and `2 E[x]` (Mantri) compare against these.
//! * `*_wall` — wall-clock on the copy's host; sorting by urgency and
//!   LATE's time-to-end use these.
//!
//! `Cluster::launch_copy` and the estimators agree on the conversion
//! (divide work by advertised speed), which is the invariant the
//! `speed2_host_halves_actual_and_estimated_remaining` regression test
//! pins down.
//!
//! ## Example
//!
//! ```
//! use specsim::cluster::job::{JobId, JobSpec, TaskRef};
//! use specsim::cluster::machine::MachineClass;
//! use specsim::cluster::sim::{Simulator, Workload};
//! use specsim::config::{SimConfig, WorkloadConfig};
//! use specsim::estimator::{RemainingTime, SpeedAware};
//! use specsim::stats::Pareto;
//!
//! // one 3-work-unit task on a single 2x-speed host
//! let mut cfg = SimConfig::default();
//! cfg.set_machine_classes(vec![MachineClass::new(1, 2.0)]);
//! cfg.use_runtime = false;
//! let dist = Pareto::from_mean(1.0, 2.0);
//! let wl = Workload {
//!     specs: vec![JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: 1 }],
//!     first_durations: vec![vec![3.0]],
//! };
//! // default policy: naive (the srpt+never pipeline) — a do-nothing driver
//! let sched = specsim::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
//! let mut sim = Simulator::new(cfg, wl, sched);
//! let t = TaskRef { job: JobId(0), task: 0 };
//! assert!(sim.cluster.launch_copy(t));
//!
//! // the 2x host turns 3 work units into 1.5 wall-clock units, and the
//! // speed-aware estimator prices a fresh copy consistently: E[x] work
//! // remaining, E[x] / speed wall-clock remaining
//! let est = SpeedAware::blind();
//! assert_eq!(sim.cluster.copy(t, 0).duration, 1.5);
//! assert_eq!(est.task_remaining_work(&sim.cluster, t), 1.0);
//! assert_eq!(est.task_remaining_wall(&sim.cluster, t), 0.5);
//! ```

pub mod blind;
pub mod revealed;
pub mod speed_aware;

pub use blind::Blind;
pub use revealed::Revealed;
pub use speed_aware::SpeedAware;

use crate::cluster::job::{CopyPhase, JobId, TaskRef};
use crate::cluster::sim::Cluster;
use crate::config::SimConfig;
use crate::stats::Pareto;

/// Everything an estimator is allowed to observe about one running copy.
/// This struct *is* the information boundary: the hidden slowdown state and
/// an unrevealed copy's true duration are deliberately absent.
#[derive(Clone, Copy, Debug)]
pub struct CopyObs<'a> {
    /// The job's task-duration distribution (work units).
    pub dist: &'a Pareto,
    /// Wall-clock time since the copy started.
    pub elapsed: f64,
    /// Did the copy pass its `s_i` detection checkpoint?
    pub revealed: bool,
    /// True remaining wall-clock time — only meaningful when `revealed`.
    pub revealed_wall: f64,
    /// Advertised class speed of the copy's host (public hardware fact).
    pub speed: f64,
    /// The copy's lifetime-average delivered throughput (work per
    /// wall-clock unit), stamped by the simulator at the detection
    /// checkpoint and refreshed at `SlowdownFlip` re-times; NaN until
    /// revealed.  This is the observable a real master reads from a
    /// task's progress counters (progress score over elapsed — exactly
    /// LATE's measurement), so it sits inside the information boundary
    /// even though the simulator computes it from its own ground truth;
    /// it is piecewise-constant between cluster mutations by
    /// construction (DESIGN.md §14).
    pub observed: f64,
}

/// Observe copy `copy` of task `t` under the contract above.
pub fn observe(cl: &Cluster, t: TaskRef, copy: usize) -> CopyObs<'_> {
    let job = cl.job(t.job);
    let cid = cl.arena.copy_id(cl.tid(t), copy as u32);
    let c = cl.arena.copy(cid);
    CopyObs {
        dist: &job.spec.dist,
        elapsed: c.elapsed(cl.clock),
        revealed: c.revealed,
        revealed_wall: if c.revealed { c.true_remaining(cl.clock) } else { f64::NAN },
        speed: cl.machines.speed(c.machine),
        observed: cl.arena.obs_speed(cid),
    }
}

/// One task's contribution to the estimate-driven level-2 key: the
/// *revealed total work* of its running first copy once the `s_i`
/// checkpoint passed (wall-clock duration × advertised class speed — all
/// observable facts), `E[x]` before that, `0` once the task is done.
///
/// Deliberately **not** a remaining-time estimate: remaining times decay
/// with the clock, but an ordering key must be piecewise-constant between
/// cluster mutations so the incremental
/// [`SchedIndex`](crate::cluster::index::SchedIndex) can maintain the
/// est-keyed level-2 set by re-keying at the reveal/kill/finish mutation
/// points (the `est-srpt` re-key contract; see
/// `scheduler::ordering`).  Under a hidden slowdown the revealed work is
/// inflated by the unexplained factor — exactly the straggler signal the
/// estimate-driven ordering should rank by.
pub fn revealed_task_workload(
    job: &crate::cluster::job::JobState,
    arena: &crate::cluster::job::TaskArena,
    machines: &crate::cluster::machine::MachinePool,
    task: u32,
) -> f64 {
    let tid = job.tid(task);
    if arena.done(tid) {
        return 0.0;
    }
    for cid in arena.copies(tid) {
        if arena.phase(cid) == CopyPhase::Running && arena.revealed(cid) {
            return arena.duration(cid) * machines.speed(arena.machine(cid));
        }
    }
    job.spec.dist.mean()
}

/// The estimate-driven level-2 job key: the sum of
/// [`revealed_task_workload`] over the job's tasks, **in task order** —
/// the index maintains the identical ordered sum incrementally, so both
/// query paths produce bit-identical keys (float addition order matters).
pub fn revealed_job_workload(cl: &Cluster, id: JobId) -> f64 {
    let job = cl.job(id);
    let mut sum = 0.0;
    for task in 0..job.spec.num_tasks {
        sum += revealed_task_workload(job, &cl.arena, &cl.machines, task);
    }
    sum
}

/// Shave a hair off a computed predicate-flip instant so floating-point
/// error in the closed-form inverses (`powf` round-trips) can only make
/// the wakeup planner fire *early* — a harmless extra no-op slot — never
/// late, which would skip a slot the polled loop acts on.  The margin is
/// far below any slot grid, so it costs at most one extra fired slot per
/// flip.
pub(crate) fn flip_guard(t: f64) -> f64 {
    t - 1e-9 * (1.0 + t.abs())
}

/// Minimum of `per_copy` over the running copies of `t` — the task-level
/// fold shared by every query (a task finishes when its first copy does).
/// Infinite when nothing runs.
fn min_over_running(cl: &Cluster, t: TaskRef, mut per_copy: impl FnMut(usize) -> f64) -> f64 {
    let tid = cl.tid(t);
    let mut best = f64::INFINITY;
    for (i, cid) in cl.arena.copies(tid).enumerate() {
        if cl.arena.phase(cid) == CopyPhase::Running {
            best = best.min(per_copy(i));
        }
    }
    best
}

/// A remaining-time estimator: the single interface every scheduler's
/// speculation rule queries.  Implementations differ only in which parts
/// of the [`CopyObs`] observation they use.
pub trait RemainingTime {
    fn name(&self) -> &'static str;

    /// Estimated remaining **work** of copy `copy` of task `t`, in the
    /// units of `E[x]` — the units speculation thresholds live in
    /// (`sigma * E[x]`, `2 E[x]`).
    fn copy_remaining_work(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64;

    /// Estimated remaining **wall-clock** time of copy `copy` on its host.
    fn copy_remaining_wall(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64;

    /// Estimated probability that the remaining *work* of copy `copy`
    /// exceeds `a` (Mantri's duplicate rule compares this to its `delta`).
    fn copy_prob_exceeds(&self, cl: &Cluster, t: TaskRef, copy: usize, a: f64) -> f64;

    /// Wakeup-planner query: the earliest simulated instant at which
    /// `copy_prob_exceeds(cl, t, copy, a) > p` could *first become true*,
    /// assuming the predicate is currently false and no cluster mutation
    /// happens in between.  `None` = it can never flip on its own.
    ///
    /// The conservative default — "now" — forces the planner to fire
    /// every slot, which is always correct; implementations override it
    /// with the exact inverse of their own estimate (see
    /// [`Pareto::sf_remaining_flip`]).
    fn copy_prob_flip_time(
        &self,
        cl: &Cluster,
        _t: TaskRef,
        _copy: usize,
        _a: f64,
        _p: f64,
    ) -> Option<f64> {
        Some(cl.clock)
    }

    /// Wakeup-planner query: the earliest simulated instant at which
    /// `copy_remaining_work(cl, t, copy) > w` could first become true,
    /// under the same contract as [`RemainingTime::copy_prob_flip_time`]
    /// (currently false, no mutations; `None` = never; the default forces
    /// every slot).  See [`Pareto::mean_remaining_flip`].
    fn copy_work_flip_time(&self, cl: &Cluster, _t: TaskRef, _copy: usize, _w: f64) -> Option<f64> {
        Some(cl.clock)
    }

    /// Wakeup-planner query for LATE's relative ranking: the earliest
    /// simulated instant at which this copy's progress rate
    /// `1 / (elapsed + copy_remaining_wall)` could first drop *strictly
    /// below* `rate`, under the same contract as the other flips
    /// (currently `>= rate`, no mutations in between; `None` = never;
    /// the default forces every slot).  Every estimator's rate is
    /// non-increasing between mutations: a revealed copy's denominator is
    /// its constant wall duration (`None`), an unrevealed one's grows on
    /// the conditional-Pareto schedule inverted by
    /// [`Pareto::rate_denom_flip`].
    fn copy_rate_flip_time(
        &self,
        cl: &Cluster,
        _t: TaskRef,
        _copy: usize,
        _rate: f64,
    ) -> Option<f64> {
        Some(cl.clock)
    }

    /// Task-level remaining work: the minimum over running copies.
    fn task_remaining_work(&self, cl: &Cluster, t: TaskRef) -> f64 {
        min_over_running(cl, t, |i| self.copy_remaining_work(cl, t, i))
    }

    /// Task-level remaining wall-clock: minimum over running copies.
    fn task_remaining_wall(&self, cl: &Cluster, t: TaskRef) -> f64 {
        min_over_running(cl, t, |i| self.copy_remaining_wall(cl, t, i))
    }

    /// Task-level `P(remaining work > a)`: minimum over running copies
    /// (any copy finishing within `a` finishes the task).
    fn task_prob_exceeds(&self, cl: &Cluster, t: TaskRef, a: f64) -> f64 {
        min_over_running(cl, t, |i| self.copy_prob_exceeds(cl, t, i, a))
    }

    /// Job-level remaining workload — the SRPT ordering key of the
    /// paper's level-2 scheduling (`#unfinished tasks * E[x]`).  Kept
    /// mean-field for every estimator: at ordering time the scheduler does
    /// not know which hosts future copies will land on, so per-host
    /// corrections have no defined target; this also keeps the job order
    /// identical to the paper's on every scenario.
    fn job_remaining_work(&self, cl: &Cluster, id: JobId) -> f64 {
        cl.job(id).remaining_workload()
    }
}

/// The estimator a policy should run with under `cfg`:
/// `instrumented` = the policy owns the paper's `s_i` checkpoint
/// instrumentation (SCA/SDA/ESE — true) or is a blind baseline
/// (Mantri/LATE — false); `cfg.speed_aware` selects the class-speed-aware
/// variant (the default; a no-op on homogeneous speed-1.0 clusters), and
/// `cfg.observed_speed` additionally swaps the revealed conversion to the
/// measured-throughput projection ([`SpeedAware::observed`]).  The
/// observed flag has no uninstrumented row — throughput is only measured
/// at the checkpoint, which blind baselines do not own — so Mantri/LATE
/// keep [`SpeedAware::blind`].
pub fn for_policy(cfg: &SimConfig, instrumented: bool) -> Box<dyn RemainingTime> {
    match (instrumented, cfg.speed_aware) {
        (false, false) => Box::new(Blind),
        (false, true) => Box::new(SpeedAware::blind()),
        (true, false) => Box::new(Revealed),
        (true, true) if cfg.observed_speed => Box::new(SpeedAware::observed()),
        (true, true) => Box::new(SpeedAware::revealed()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::job::JobSpec;
    use crate::cluster::machine::MachineClass;
    use crate::cluster::sim::{Simulator, Workload};
    use crate::config::WorkloadConfig;

    fn task0() -> TaskRef {
        TaskRef { job: JobId(0), task: 0 }
    }

    /// Flip the reveal bit on the first copy of task 0 (the arena is the
    /// single source of truth for copy state).
    fn reveal0(cl: &mut Cluster) {
        let cid = cl.arena.copy_id(cl.tid(task0()), 0);
        cl.arena.set_revealed(cid);
    }

    /// One job, one task with a controlled first-copy work amount, on the
    /// given machine classes; the copy is launched at t = 0.
    fn cluster_with(classes: Vec<MachineClass>, work: f64) -> Cluster {
        let mut cfg = SimConfig::default();
        cfg.set_machine_classes(classes);
        cfg.horizon = 100.0;
        cfg.use_runtime = false;
        let dist = Pareto::from_mean(1.0, 2.0);
        let wl = Workload {
            specs: vec![JobSpec { id: JobId(0), arrival: 0.0, dist, num_tasks: 1 }],
            first_durations: vec![vec![work]],
        };
        let sched = crate::scheduler::build(&cfg, &WorkloadConfig::paper(1.0)).unwrap();
        let mut sim = Simulator::new(cfg, wl, sched);
        assert!(sim.cluster.launch_copy(task0()));
        sim.cluster
    }

    /// Satellite regression: `Cluster::launch_copy` wall-clock scaling and
    /// the estimators agree on units — a 2x-speed host halves both the
    /// actual and the estimated remaining time, while the remaining *work*
    /// estimate is host-invariant.
    #[test]
    fn speed2_host_halves_actual_and_estimated_remaining() {
        let slow = cluster_with(vec![MachineClass::new(1, 1.0)], 3.0);
        let fast = cluster_with(vec![MachineClass::new(1, 2.0)], 3.0);
        // actual wall-clock halves
        let d_slow = slow.copy(task0(), 0).duration;
        let d_fast = fast.copy(task0(), 0).duration;
        assert_eq!(d_slow, 3.0);
        assert_eq!(d_fast, 1.5);
        // blind speed-aware estimate at launch: E[x] work on both hosts,
        // wall-clock halves with the speed
        let est = SpeedAware::blind();
        assert_eq!(
            est.task_remaining_work(&slow, task0()),
            est.task_remaining_work(&fast, task0())
        );
        let w_slow = est.task_remaining_wall(&slow, task0());
        let w_fast = est.task_remaining_wall(&fast, task0());
        assert!((w_fast - w_slow / 2.0).abs() < 1e-12, "wall {w_fast} vs half of {w_slow}");
        // once revealed, the speed-aware estimate *is* the simulator's
        // wall-clock truth on both hosts
        let est = SpeedAware::revealed();
        let mut both = [slow, fast];
        for cl in both.iter_mut() {
            cl.clock = 0.25;
            reveal0(cl);
            let truth = cl.copy(task0(), 0).true_remaining(0.25);
            assert_eq!(est.task_remaining_wall(cl, task0()), truth);
        }
    }

    /// On unit-speed hosts the speed-aware estimators are *exactly* the
    /// naive ones — the paper's homogeneous numbers are untouched.
    #[test]
    fn speed_aware_is_identity_at_unit_speed() {
        let mut cl = cluster_with(vec![MachineClass::new(1, 1.0)], 2.5);
        cl.clock = 0.8;
        let t = task0();
        assert_eq!(
            Blind.task_remaining_work(&cl, t),
            SpeedAware::blind().task_remaining_work(&cl, t)
        );
        assert_eq!(
            Blind.task_remaining_wall(&cl, t),
            SpeedAware::blind().task_remaining_wall(&cl, t)
        );
        assert_eq!(
            Blind.task_prob_exceeds(&cl, t, 2.0),
            SpeedAware::blind().task_prob_exceeds(&cl, t, 2.0)
        );
        reveal0(&mut cl);
        assert_eq!(
            Revealed.task_remaining_work(&cl, t),
            SpeedAware::revealed().task_remaining_work(&cl, t)
        );
        assert_eq!(
            Revealed.task_prob_exceeds(&cl, t, 1.0),
            SpeedAware::revealed().task_prob_exceeds(&cl, t, 1.0)
        );
    }

    /// The blind estimator never sees the revealed truth; the revealed one
    /// switches to it at the checkpoint.
    #[test]
    fn reveal_switches_revealed_but_not_blind() {
        let mut cl = cluster_with(vec![MachineClass::new(1, 1.0)], 4.0);
        cl.clock = 1.0;
        let t = task0();
        let blind_before = Blind.task_remaining_work(&cl, t);
        assert_eq!(Revealed.task_remaining_work(&cl, t), blind_before);
        reveal0(&mut cl);
        assert_eq!(Blind.task_remaining_work(&cl, t), blind_before);
        assert_eq!(Revealed.task_remaining_work(&cl, t), 3.0); // 4 - 1 elapsed
        assert_eq!(Revealed.task_prob_exceeds(&cl, t, 2.0), 1.0);
        assert_eq!(Revealed.task_prob_exceeds(&cl, t, 3.5), 0.0);
    }

    /// No running copies => infinite estimates (nothing to wait for is a
    /// caller bug, not a panic).
    #[test]
    fn no_running_copies_is_infinite() {
        let mut cl = cluster_with(vec![MachineClass::new(2, 1.0)], 1.0);
        let t = task0();
        cl.kill_copy(t, 0); // the only copy
        assert!(Blind.task_remaining_work(&cl, t).is_infinite());
        assert!(SpeedAware::revealed().task_remaining_wall(&cl, t).is_infinite());
    }

    /// `job_remaining_work` is the paper's mean-field key for every
    /// estimator, so the level-2 job order is scenario-independent.
    #[test]
    fn job_key_is_mean_field_for_all() {
        let cl = cluster_with(vec![MachineClass::new(1, 2.0)], 3.0);
        let id = JobId(0);
        let expect = cl.job(id).remaining_workload();
        assert_eq!(Blind.job_remaining_work(&cl, id), expect);
        assert_eq!(Revealed.job_remaining_work(&cl, id), expect);
        assert_eq!(SpeedAware::revealed().job_remaining_work(&cl, id), expect);
    }

    /// The estimate-driven level-2 key: `E[x]` per task until a reveal,
    /// the revealed total work (speed-corrected) after, `0` once done —
    /// and it only moves at those mutation points, never with the clock.
    #[test]
    fn revealed_job_workload_refines_at_mutation_points_only() {
        let mut cl = cluster_with(vec![MachineClass::new(2, 2.0)], 3.0);
        let id = JobId(0);
        let mean = cl.job(id).spec.dist.mean();
        assert_eq!(revealed_job_workload(&cl, id), mean);
        // the clock alone must not move the key (piecewise-constant)
        cl.clock = 0.9;
        assert_eq!(revealed_job_workload(&cl, id), mean);
        // reveal: the task now contributes its observed total work —
        // wall duration (3 work / 2x speed = 1.5) x advertised speed 2
        reveal0(&mut cl);
        assert_eq!(revealed_job_workload(&cl, id), 3.0);
        cl.clock = 1.2;
        assert_eq!(revealed_job_workload(&cl, id), 3.0);
        // killing the revealed copy reverts the task to E[x]
        cl.kill_copy(task0(), 0);
        assert_eq!(revealed_job_workload(&cl, id), mean);
        // a finished task contributes nothing
        let tid = cl.tid(task0());
        cl.arena.set_done(tid, cl.clock);
        assert_eq!(revealed_job_workload(&cl, id), 0.0);
    }

    /// The wakeup-planner flip queries invert the forward predicates per
    /// estimator: advancing the clock to just past the returned instant
    /// flips the predicate, and the early-bias guard means the returned
    /// instant itself is never *after* the true flip.
    #[test]
    fn flip_times_invert_forward_predicates() {
        // 2x-speed host so the speed conversion is exercised too
        let mut cl = cluster_with(vec![MachineClass::new(2, 2.0)], 30.0);
        cl.clock = 0.25;
        let t = task0();
        let mean = cl.job(JobId(0)).spec.dist.mean();
        let (a, delta) = (2.0 * mean, 0.25);
        let est = SpeedAware::blind();
        assert!(est.task_prob_exceeds(&cl, t, a) <= delta, "test premise: currently false");
        let flip = est.copy_prob_flip_time(&cl, t, 0, a, delta).unwrap();
        assert!(flip > cl.clock);
        // just before: still false; just after: flipped
        let mut before = cluster_with(vec![MachineClass::new(2, 2.0)], 30.0);
        before.clock = flip - 1e-6;
        assert!(est.task_prob_exceeds(&before, t, a) <= delta);
        let mut after = cluster_with(vec![MachineClass::new(2, 2.0)], 30.0);
        after.clock = flip + 1e-6;
        assert!(est.task_prob_exceeds(&after, t, a) > delta);
        // the sigma-threshold work flip behaves the same way
        let w = 1.7 * mean;
        assert!(est.task_remaining_work(&cl, t) <= w);
        let wflip = est.copy_work_flip_time(&cl, t, 0, w).unwrap();
        let mut after = cluster_with(vec![MachineClass::new(2, 2.0)], 30.0);
        after.clock = wflip + 1e-6;
        assert!(est.task_remaining_work(&after, t) > w);
        // a revealed copy's estimate decays: it can never flip up
        reveal0(&mut cl);
        let est = SpeedAware::revealed();
        assert_eq!(est.copy_prob_flip_time(&cl, t, 0, a, delta), None);
        assert_eq!(est.copy_work_flip_time(&cl, t, 0, w), None);
        assert_eq!(Revealed.copy_work_flip_time(&cl, t, 0, w), None);
        // blind estimators ignore the reveal and still report a flip
        assert!(Blind.copy_prob_flip_time(&cl, t, 0, a, delta).is_some());
    }

    /// Satellite: the LATE progress-rate flip inverts the rate predicate.
    /// On the 2x host at clock 0.25 the copy's work-elapsed is exactly
    /// `mu = 0.5`, so the rate is `1 / (0.25 + mean_remaining(0.5)/2) = 2`;
    /// a target of `1.6` puts the crossing at work-elapsed
    /// `rate_denom_flip(2/1.6) = 0.625`, i.e. clock `0.3125`.
    #[test]
    fn rate_flip_time_inverts_the_progress_rate() {
        let mut cl = cluster_with(vec![MachineClass::new(2, 2.0)], 30.0);
        cl.clock = 0.25;
        let t = task0();
        let est = SpeedAware::blind();
        // LATE's rate: copy started at 0, so elapsed == clock
        let rate_at = |cl: &Cluster| 1.0 / (cl.clock + est.copy_remaining_wall(cl, t, 0));
        let now = rate_at(&cl);
        assert!((now - 2.0).abs() < 1e-12);
        let target = 0.8 * now;
        let flip = est.copy_rate_flip_time(&cl, t, 0, target).unwrap();
        assert!((flip - 0.3125).abs() < 1e-8);
        // before the flip the rate still meets the target...
        let mut before = cluster_with(vec![MachineClass::new(2, 2.0)], 30.0);
        before.clock = 0.3;
        assert!(rate_at(&before) >= target);
        // ...just after it sits strictly below
        let mut after = cluster_with(vec![MachineClass::new(2, 2.0)], 30.0);
        after.clock = flip + 1e-6;
        assert!(rate_at(&after) < target);
        // a positive rate never drops below a non-positive target
        assert_eq!(est.copy_rate_flip_time(&cl, t, 0, 0.0), None);
        // a revealed copy's denominator is its constant wall duration:
        // the rate can never drop on its own
        reveal0(&mut cl);
        assert_eq!(SpeedAware::revealed().copy_rate_flip_time(&cl, t, 0, target), None);
        assert_eq!(Revealed.copy_rate_flip_time(&cl, t, 0, target), None);
        // blind estimators ignore the reveal and still report a flip
        assert!(Blind.copy_rate_flip_time(&cl, t, 0, target).is_some());
    }

    /// The observed-speed variant is the advertised one until a throughput
    /// stamp exists (or when the stamp says the host kept its advertised
    /// speed), and inflates every revealed estimate by `1/eta` once the
    /// stamp reports a degraded host.
    #[test]
    fn observed_variant_discounts_by_stamped_throughput() {
        let mut cl = cluster_with(vec![MachineClass::new(1, 1.0)], 4.0);
        cl.clock = 1.0;
        let t = task0();
        let adv = SpeedAware::revealed();
        let obs = SpeedAware::observed();
        // pre-reveal: both fall back to the conditional-Pareto branch
        assert_eq!(obs.task_remaining_work(&cl, t), adv.task_remaining_work(&cl, t));
        reveal0(&mut cl);
        // revealed but no stamp (NaN): efficiency falls back to 1
        let cid = cl.arena.copy_id(cl.tid(t), 0);
        assert!(cl.arena.obs_speed(cid).is_nan());
        assert_eq!(obs.task_remaining_wall(&cl, t), adv.task_remaining_wall(&cl, t));
        // a stamp at the advertised speed is the identity...
        cl.arena.set_obs_speed(cid, 1.0);
        assert_eq!(obs.task_remaining_work(&cl, t), adv.task_remaining_work(&cl, t));
        assert_eq!(obs.task_prob_exceeds(&cl, t, 3.5), adv.task_prob_exceeds(&cl, t, 3.5));
        // ...and a stamp above it clamps to 1 (slowdowns never speed up)
        cl.arena.set_obs_speed(cid, 2.0);
        assert_eq!(obs.task_remaining_wall(&cl, t), adv.task_remaining_wall(&cl, t));
        // a host measured at half speed doubles both projections:
        // advertised sees 3 remaining (4 - 1 elapsed), observed sees 6
        cl.arena.set_obs_speed(cid, 0.5);
        assert_eq!(adv.task_remaining_work(&cl, t), 3.0);
        assert_eq!(obs.task_remaining_work(&cl, t), 6.0);
        assert_eq!(obs.task_remaining_wall(&cl, t), 6.0);
        // the threshold predicate trips where the advertised one does not
        assert_eq!(adv.task_prob_exceeds(&cl, t, 4.0), 0.0);
        assert_eq!(obs.task_prob_exceeds(&cl, t, 4.0), 1.0);
        // revealed flip queries stay `None`: the stamp only moves at
        // cluster mutations, so the inflated estimate still decays
        assert_eq!(obs.copy_prob_flip_time(&cl, t, 0, 4.0, 0.25), None);
        assert_eq!(obs.copy_work_flip_time(&cl, t, 0, 4.0), None);
        assert_eq!(obs.copy_rate_flip_time(&cl, t, 0, 0.5), None);
    }

    #[test]
    fn for_policy_maps_config() {
        let mut cfg = SimConfig::default();
        assert!(cfg.speed_aware);
        assert!(!cfg.observed_speed);
        assert_eq!(for_policy(&cfg, true).name(), "speed_aware");
        assert_eq!(for_policy(&cfg, false).name(), "speed_aware_blind");
        cfg.observed_speed = true;
        assert_eq!(for_policy(&cfg, true).name(), "speed_aware_observed");
        assert_eq!(
            for_policy(&cfg, false).name(),
            "speed_aware_blind",
            "uninstrumented rules never measure throughput"
        );
        cfg.speed_aware = false;
        // observed is a refinement of speed-aware: without the base flag
        // the naive estimators run, observed or not
        assert_eq!(for_policy(&cfg, true).name(), "revealed");
        assert_eq!(for_policy(&cfg, false).name(), "blind");
    }
}
