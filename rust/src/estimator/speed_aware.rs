//! The speed-aware estimators: divide by the running copy's advertised
//! host speed, so work-unit thresholds (`sigma * E[x]`, `2 E[x]`) and
//! wall-clock observations stop being conflated on heterogeneous clusters.
//!
//! With class speed `v` (a public hardware fact):
//!
//! * blind branch — wall-clock elapsed `e` corresponds to `e * v` work
//!   executed; condition the Pareto on that, and convert the remaining
//!   work back to wall-clock by dividing by `v`;
//! * revealed branch — the checkpoint reveals the true remaining
//!   *wall-clock* `r`; the copy's remaining work is `r * v`.
//!
//! The revealed conversion is where server-dependent slowdown (cf.
//! Anselmi & Walton) becomes detectable: on a host whose hidden slowdown
//! is `k`, `r` is `k`x inflated, so the estimated remaining work is `k`x
//! the truth — a *legitimate* straggler signal that trips the SDA/ESE
//! threshold.  On a merely slow-*class* host (`v < 1`, no slowdown) the
//! division removes the inflation entirely, suppressing the false positive
//! a unit-naive estimator would raise.  See the `estimator_slowdown`
//! integration tests.
//!
//! ## The observed-speed refinement
//!
//! Under an ON/OFF Markov slowdown (`SlowdownFlip` events) the revealed
//! remaining wall `r` is only the truth *if the host keeps its current
//! speed* — the simulator re-times it at every flip.  [`SpeedAware::observed`]
//! therefore discounts `r` by the host's observed efficiency: the ratio of
//! its measured lifetime throughput ([`CopyObs::observed`](super::CopyObs),
//! stamped at the checkpoint and refreshed at re-times) to its advertised
//! speed.  A host that has delivered half its advertised speed is
//! projected to keep doing so, inflating both the wall and the work
//! estimate by 2x.  The efficiency is clamped to `(0, 1]` (slowdowns
//! never speed a host up) and is exactly 1 whenever nothing ever flipped,
//! so the variant is bit-identical to [`SpeedAware::revealed`] on every
//! static scenario with healthy hosts.  Because the stamp only moves at
//! cluster mutations, the revealed estimate still decays between
//! mutations and the `None` wakeup-horizon arguments below stay sound.

use crate::cluster::job::TaskRef;
use crate::cluster::sim::Cluster;

use super::{flip_guard, observe, CopyObs, RemainingTime};

/// Class-speed-corrected estimator; `reveal` selects whether the paper's
/// `s_i`-checkpoint revelation is used (SCA/SDA/ESE) or not (a
/// speed-aware Mantri/LATE baseline); `observed` additionally projects
/// revealed remaining times by the host's measured throughput.
pub struct SpeedAware {
    reveal: bool,
    observed: bool,
}

impl SpeedAware {
    /// Speed-corrected conditional-Pareto estimates only (baselines).
    pub fn blind() -> Self {
        SpeedAware { reveal: false, observed: false }
    }

    /// Speed-corrected with post-checkpoint truth (the paper's algorithms).
    pub fn revealed() -> Self {
        SpeedAware { reveal: true, observed: false }
    }

    /// Like [`SpeedAware::revealed`], but the revealed remaining wall is
    /// projected by the host's *measured* lifetime throughput instead of
    /// trusting the advertised speed to persist (see the module docs).
    pub fn observed() -> Self {
        SpeedAware { reveal: true, observed: true }
    }

    /// Observed efficiency of the copy's host in `(0, 1]`: measured
    /// lifetime throughput over advertised speed.  1 unless this is the
    /// observed variant and a usable stamp exists; clamped at 1 because a
    /// slowdown can only ever slow a host down.
    fn efficiency(&self, o: &CopyObs) -> f64 {
        if !self.observed {
            return 1.0;
        }
        let eta = o.observed / o.speed;
        if eta.is_finite() && eta > 0.0 {
            eta.min(1.0)
        } else {
            1.0
        }
    }
}

impl RemainingTime for SpeedAware {
    fn name(&self) -> &'static str {
        if self.observed {
            "speed_aware_observed"
        } else if self.reveal {
            "speed_aware"
        } else {
            "speed_aware_blind"
        }
    }

    fn copy_remaining_work(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64 {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            o.revealed_wall * o.speed / self.efficiency(&o)
        } else {
            o.dist.mean_remaining(o.elapsed * o.speed)
        }
    }

    fn copy_remaining_wall(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64 {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            o.revealed_wall / self.efficiency(&o)
        } else {
            o.dist.mean_remaining(o.elapsed * o.speed) / o.speed
        }
    }

    fn copy_prob_exceeds(&self, cl: &Cluster, t: TaskRef, copy: usize, a: f64) -> f64 {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            if o.revealed_wall * o.speed / self.efficiency(&o) > a {
                1.0
            } else {
                0.0
            }
        } else {
            o.dist.sf_remaining(o.elapsed * o.speed, a)
        }
    }

    /// Exact inverse of the speed-corrected survival predicate: the flip
    /// sits at work-equivalent elapsed `e*`, i.e. `(e* - elapsed·v) / v`
    /// wall-clock from now on a class-speed-`v` host.  Revealed copies
    /// (with `reveal`) decay and never flip up — `None`, same argument as
    /// [`Revealed`](super::Revealed).
    fn copy_prob_flip_time(
        &self,
        cl: &Cluster,
        t: TaskRef,
        copy: usize,
        a: f64,
        p: f64,
    ) -> Option<f64> {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            None
        } else {
            o.dist
                .sf_remaining_flip(a, p)
                .map(|e| flip_guard(cl.clock + (e - o.elapsed * o.speed) / o.speed))
        }
    }

    fn copy_work_flip_time(&self, cl: &Cluster, t: TaskRef, copy: usize, w: f64) -> Option<f64> {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            None
        } else {
            Some(flip_guard(
                cl.clock + (o.dist.mean_remaining_flip(w) - o.elapsed * o.speed) / o.speed,
            ))
        }
    }

    /// The wall denominator on a class-speed-`v` host is
    /// `d_work(e·v) / v`, so the rate drops below `rate` once the
    /// work-equivalent elapsed crosses `rate_denom_flip(v / rate)`;
    /// revealed copies (with `reveal`) hold a constant rate — `None`.
    fn copy_rate_flip_time(&self, cl: &Cluster, t: TaskRef, copy: usize, rate: f64) -> Option<f64> {
        let o = observe(cl, t, copy);
        if (self.reveal && o.revealed) || !(rate > 0.0) {
            None
        } else {
            let e = o.dist.rate_denom_flip(o.speed / rate);
            Some(flip_guard(cl.clock + (e - o.elapsed * o.speed) / o.speed))
        }
    }
}
