//! The speed-aware estimators: divide by the running copy's advertised
//! host speed, so work-unit thresholds (`sigma * E[x]`, `2 E[x]`) and
//! wall-clock observations stop being conflated on heterogeneous clusters.
//!
//! With class speed `v` (a public hardware fact):
//!
//! * blind branch — wall-clock elapsed `e` corresponds to `e * v` work
//!   executed; condition the Pareto on that, and convert the remaining
//!   work back to wall-clock by dividing by `v`;
//! * revealed branch — the checkpoint reveals the true remaining
//!   *wall-clock* `r`; the copy's remaining work is `r * v`.
//!
//! The revealed conversion is where server-dependent slowdown (cf.
//! Anselmi & Walton) becomes detectable: on a host whose hidden slowdown
//! is `k`, `r` is `k`x inflated, so the estimated remaining work is `k`x
//! the truth — a *legitimate* straggler signal that trips the SDA/ESE
//! threshold.  On a merely slow-*class* host (`v < 1`, no slowdown) the
//! division removes the inflation entirely, suppressing the false positive
//! a unit-naive estimator would raise.  See the `estimator_slowdown`
//! integration tests.

use crate::cluster::job::TaskRef;
use crate::cluster::sim::Cluster;

use super::{flip_guard, observe, RemainingTime};

/// Class-speed-corrected estimator; `reveal` selects whether the paper's
/// `s_i`-checkpoint revelation is used (SCA/SDA/ESE) or not (a
/// speed-aware Mantri/LATE baseline).
pub struct SpeedAware {
    reveal: bool,
}

impl SpeedAware {
    /// Speed-corrected conditional-Pareto estimates only (baselines).
    pub fn blind() -> Self {
        SpeedAware { reveal: false }
    }

    /// Speed-corrected with post-checkpoint truth (the paper's algorithms).
    pub fn revealed() -> Self {
        SpeedAware { reveal: true }
    }
}

impl RemainingTime for SpeedAware {
    fn name(&self) -> &'static str {
        if self.reveal {
            "speed_aware"
        } else {
            "speed_aware_blind"
        }
    }

    fn copy_remaining_work(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64 {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            o.revealed_wall * o.speed
        } else {
            o.dist.mean_remaining(o.elapsed * o.speed)
        }
    }

    fn copy_remaining_wall(&self, cl: &Cluster, t: TaskRef, copy: usize) -> f64 {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            o.revealed_wall
        } else {
            o.dist.mean_remaining(o.elapsed * o.speed) / o.speed
        }
    }

    fn copy_prob_exceeds(&self, cl: &Cluster, t: TaskRef, copy: usize, a: f64) -> f64 {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            if o.revealed_wall * o.speed > a {
                1.0
            } else {
                0.0
            }
        } else {
            o.dist.sf_remaining(o.elapsed * o.speed, a)
        }
    }

    /// Exact inverse of the speed-corrected survival predicate: the flip
    /// sits at work-equivalent elapsed `e*`, i.e. `(e* - elapsed·v) / v`
    /// wall-clock from now on a class-speed-`v` host.  Revealed copies
    /// (with `reveal`) decay and never flip up — `None`, same argument as
    /// [`Revealed`](super::Revealed).
    fn copy_prob_flip_time(
        &self,
        cl: &Cluster,
        t: TaskRef,
        copy: usize,
        a: f64,
        p: f64,
    ) -> Option<f64> {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            None
        } else {
            o.dist
                .sf_remaining_flip(a, p)
                .map(|e| flip_guard(cl.clock + (e - o.elapsed * o.speed) / o.speed))
        }
    }

    fn copy_work_flip_time(&self, cl: &Cluster, t: TaskRef, copy: usize, w: f64) -> Option<f64> {
        let o = observe(cl, t, copy);
        if self.reveal && o.revealed {
            None
        } else {
            Some(flip_guard(
                cl.clock + (o.dist.mean_remaining_flip(w) - o.elapsed * o.speed) / o.speed,
            ))
        }
    }

    /// The wall denominator on a class-speed-`v` host is
    /// `d_work(e·v) / v`, so the rate drops below `rate` once the
    /// work-equivalent elapsed crosses `rate_denom_flip(v / rate)`;
    /// revealed copies (with `reveal`) hold a constant rate — `None`.
    fn copy_rate_flip_time(&self, cl: &Cluster, t: TaskRef, copy: usize, rate: f64) -> Option<f64> {
        let o = observe(cl, t, copy);
        if (self.reveal && o.revealed) || !(rate > 0.0) {
            None
        } else {
            let e = o.dist.rate_denom_flip(o.speed / rate);
            Some(flip_guard(cl.clock + (e - o.elapsed * o.speed) / o.speed))
        }
    }
}
