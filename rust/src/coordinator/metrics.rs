//! Lightweight metrics registry for the live master: atomic counters and
//! gauges with a Prometheus-style text exposition (no external deps), plus
//! the time-series sampler the sharded serve plane uses to turn per-shard
//! registries into dashboard-ready CSV.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Shared registry handle.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
}

#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut map = self.inner.counters.lock().unwrap();
        Counter(map.entry(name.to_string()).or_default().clone())
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut map = self.inner.gauges.lock().unwrap();
        Gauge(map.entry(name.to_string()).or_default().clone())
    }

    /// A point-in-time copy of every counter and gauge.  Reads are Relaxed
    /// (same as the live accessors): the snapshot is a dashboard sample,
    /// not a consistency barrier.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        MetricsSnapshot { counters, gauges }
    }

    /// Prometheus-style text exposition.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, v) in self.inner.counters.lock().unwrap().iter() {
            out.push_str(&format!(
                "# TYPE {name} counter\n{name} {}\n",
                v.load(Ordering::Relaxed)
            ));
        }
        for (name, v) in self.inner.gauges.lock().unwrap().iter() {
            out.push_str(&format!(
                "# TYPE {name} gauge\n{name} {}\n",
                v.load(Ordering::Relaxed)
            ));
        }
        out
    }
}

/// A point-in-time copy of a registry's counters and gauges.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
}

/// One sampled point: which shard's registry, when (seconds since the
/// sampler started), and what it read.
#[derive(Clone, Debug)]
pub struct SamplePoint {
    pub t_secs: f64,
    pub shard: usize,
    pub snap: MetricsSnapshot,
}

/// A bounded ring of [`SamplePoint`]s — the fixed-interval snapshot history
/// the serve plane aggregates and serializes.  Pushing past `cap` evicts
/// the oldest point, so a long-running deployment holds a sliding window.
#[derive(Clone, Debug)]
pub struct TimeSeries {
    cap: usize,
    points: VecDeque<SamplePoint>,
}

impl TimeSeries {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "time series capacity must be > 0");
        TimeSeries { cap, points: VecDeque::with_capacity(cap.min(1024)) }
    }

    pub fn push(&mut self, point: SamplePoint) {
        if self.points.len() == self.cap {
            self.points.pop_front();
        }
        self.points.push_back(point);
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn points(&self) -> impl Iterator<Item = &SamplePoint> {
        self.points.iter()
    }

    /// Long-format CSV: `t_secs,shard,kind,name,value` — one row per metric
    /// per sample, trivially pivotable by any dashboard tool.
    pub fn csv(&self) -> String {
        let mut out = String::from("t_secs,shard,kind,name,value\n");
        for p in &self.points {
            for (name, v) in &p.snap.counters {
                out.push_str(&format!("{:.6},{},counter,{name},{v}\n", p.t_secs, p.shard));
            }
            for (name, v) in &p.snap.gauges {
                out.push_str(&format!("{:.6},{},gauge,{name},{v}\n", p.t_secs, p.shard));
            }
        }
        out
    }

    /// Merge the latest sample of every shard into one aggregate snapshot
    /// (counters and gauges summed across shards) — the cross-shard totals
    /// a `ServeReport` exposes.
    pub fn aggregate_latest(&self) -> MetricsSnapshot {
        let mut latest: BTreeMap<usize, &SamplePoint> = BTreeMap::new();
        for p in &self.points {
            latest.insert(p.shard, p); // iteration is oldest-first: last write wins
        }
        let mut agg = MetricsSnapshot::default();
        for p in latest.values() {
            for (name, v) in &p.snap.counters {
                *agg.counters.entry(name.clone()).or_insert(0) += v;
            }
            for (name, v) in &p.snap.gauges {
                *agg.gauges.entry(name.clone()).or_insert(0) += v;
            }
        }
        agg
    }
}

/// A background thread sampling a set of registries (one per shard) at a
/// fixed interval into a bounded [`TimeSeries`].  `stop()` joins the thread
/// and returns the series with one final sample per registry appended, so
/// even a sampler stopped before its first interval yields a deterministic,
/// non-empty series.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    series: Arc<Mutex<TimeSeries>>,
    registries: Vec<MetricsRegistry>,
    t0: Instant,
    join: thread::JoinHandle<()>,
}

impl Sampler {
    pub fn spawn(
        registries: Vec<MetricsRegistry>,
        every: Duration,
        cap: usize,
    ) -> Result<Sampler, String> {
        let stop = Arc::new(AtomicBool::new(false));
        let series = Arc::new(Mutex::new(TimeSeries::new(cap)));
        let t0 = Instant::now();
        let thread_stop = stop.clone();
        let thread_series = series.clone();
        let thread_regs = registries.clone();
        let join = thread::Builder::new()
            .name("specsim-metrics-sampler".into())
            .spawn(move || {
                let mut next = every;
                // short sleeps bound stop() latency regardless of interval
                let nap = every.min(Duration::from_millis(10));
                while !thread_stop.load(Ordering::Relaxed) {
                    let elapsed = t0.elapsed();
                    if elapsed >= next {
                        let t_secs = elapsed.as_secs_f64();
                        let mut s = thread_series.lock().unwrap();
                        for (shard, reg) in thread_regs.iter().enumerate() {
                            s.push(SamplePoint { t_secs, shard, snap: reg.snapshot() });
                        }
                        next = elapsed + every;
                    }
                    thread::sleep(nap);
                }
            })
            .map_err(|e| e.to_string())?;
        Ok(Sampler { stop, series, registries, t0, join })
    }

    /// Stop sampling, join the thread, and return the series with a final
    /// sample of every registry appended.
    pub fn stop(self) -> TimeSeries {
        self.stop.store(true, Ordering::Relaxed);
        let _ = self.join.join();
        let mut series = self.series.lock().unwrap().clone();
        let t_secs = self.t0.elapsed().as_secs_f64();
        for (shard, reg) in self.registries.iter().enumerate() {
            series.push(SamplePoint { t_secs, shard, snap: reg.snapshot() });
        }
        series
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("jobs_completed");
        c.inc();
        c.add(4);
        assert_eq!(reg.counter("jobs_completed").get(), 5);
    }

    #[test]
    fn gauges_set() {
        let reg = MetricsRegistry::new();
        reg.gauge("queue_depth").set(42);
        assert_eq!(reg.gauge("queue_depth").get(), 42);
        reg.gauge("queue_depth").set(-1);
        assert_eq!(reg.gauge("queue_depth").get(), -1);
    }

    #[test]
    fn render_lists_everything() {
        let reg = MetricsRegistry::new();
        reg.counter("a").inc();
        reg.gauge("b").set(2);
        let text = reg.render();
        assert!(text.contains("a 1"));
        assert!(text.contains("b 2"));
        assert!(text.contains("# TYPE a counter"));
    }

    #[test]
    fn shared_across_clones() {
        let reg = MetricsRegistry::new();
        let reg2 = reg.clone();
        reg.counter("x").inc();
        assert_eq!(reg2.counter("x").get(), 1);
    }

    #[test]
    fn snapshot_copies_current_values() {
        let reg = MetricsRegistry::new();
        reg.counter("jobs").add(3);
        reg.gauge("depth").set(-7);
        let snap = reg.snapshot();
        assert_eq!(snap.counters.get("jobs"), Some(&3));
        assert_eq!(snap.gauges.get("depth"), Some(&-7));
        // later mutation doesn't retroactively change the snapshot
        reg.counter("jobs").inc();
        assert_eq!(snap.counters.get("jobs"), Some(&3));
    }

    fn point(t_secs: f64, shard: usize, jobs: u64, depth: i64) -> SamplePoint {
        let mut snap = MetricsSnapshot::default();
        snap.counters.insert("jobs".to_string(), jobs);
        snap.gauges.insert("depth".to_string(), depth);
        SamplePoint { t_secs, shard, snap }
    }

    #[test]
    fn time_series_ring_evicts_oldest() {
        let mut ts = TimeSeries::new(2);
        assert!(ts.is_empty());
        ts.push(point(0.0, 0, 1, 0));
        ts.push(point(1.0, 0, 2, 0));
        ts.push(point(2.0, 0, 3, 0));
        assert_eq!(ts.len(), 2);
        let times: Vec<f64> = ts.points().map(|p| p.t_secs).collect();
        assert_eq!(times, vec![1.0, 2.0]);
    }

    #[test]
    fn time_series_csv_long_format() {
        let mut ts = TimeSeries::new(8);
        ts.push(point(0.5, 1, 10, -2));
        let csv = ts.csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("t_secs,shard,kind,name,value"));
        assert_eq!(lines.next(), Some("0.500000,1,counter,jobs,10"));
        assert_eq!(lines.next(), Some("0.500000,1,gauge,depth,-2"));
        assert_eq!(lines.next(), None);
    }

    #[test]
    fn aggregate_latest_sums_newest_point_per_shard() {
        let mut ts = TimeSeries::new(8);
        ts.push(point(0.0, 0, 1, 5));
        ts.push(point(0.0, 1, 2, 7));
        ts.push(point(1.0, 0, 4, 3)); // supersedes shard 0's first point
        let agg = ts.aggregate_latest();
        assert_eq!(agg.counters.get("jobs"), Some(&6)); // 4 + 2
        assert_eq!(agg.gauges.get("depth"), Some(&10)); // 3 + 7
    }

    #[test]
    fn sampler_final_sample_always_present() {
        let reg_a = MetricsRegistry::new();
        let reg_b = MetricsRegistry::new();
        reg_a.counter("jobs").add(2);
        reg_b.counter("jobs").add(5);
        // hour-long interval: only the stop() sample can fire
        let sampler = Sampler::spawn(
            vec![reg_a.clone(), reg_b.clone()],
            Duration::from_secs(3600),
            16,
        )
        .unwrap();
        reg_b.counter("jobs").inc();
        let series = sampler.stop();
        assert_eq!(series.len(), 2, "one final sample per registry");
        let agg = series.aggregate_latest();
        assert_eq!(agg.counters.get("jobs"), Some(&8)); // 2 + 6
    }
}
