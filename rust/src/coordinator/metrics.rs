//! Lightweight metrics registry for the live master: atomic counters and
//! gauges with a Prometheus-style text exposition (no external deps).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shared registry handle.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
}

#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut map = self.inner.counters.lock().unwrap();
        Counter(map.entry(name.to_string()).or_default().clone())
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut map = self.inner.gauges.lock().unwrap();
        Gauge(map.entry(name.to_string()).or_default().clone())
    }

    /// Prometheus-style text exposition.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, v) in self.inner.counters.lock().unwrap().iter() {
            out.push_str(&format!(
                "# TYPE {name} counter\n{name} {}\n",
                v.load(Ordering::Relaxed)
            ));
        }
        for (name, v) in self.inner.gauges.lock().unwrap().iter() {
            out.push_str(&format!(
                "# TYPE {name} gauge\n{name} {}\n",
                v.load(Ordering::Relaxed)
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("jobs_completed");
        c.inc();
        c.add(4);
        assert_eq!(reg.counter("jobs_completed").get(), 5);
    }

    #[test]
    fn gauges_set() {
        let reg = MetricsRegistry::new();
        reg.gauge("queue_depth").set(42);
        assert_eq!(reg.gauge("queue_depth").get(), 42);
        reg.gauge("queue_depth").set(-1);
        assert_eq!(reg.gauge("queue_depth").get(), -1);
    }

    #[test]
    fn render_lists_everything() {
        let reg = MetricsRegistry::new();
        reg.counter("a").inc();
        reg.gauge("b").set(2);
        let text = reg.render();
        assert!(text.contains("a 1"));
        assert!(text.contains("b 2"));
        assert!(text.contains("# TYPE a counter"));
    }

    #[test]
    fn shared_across_clones() {
        let reg = MetricsRegistry::new();
        let reg2 = reg.clone();
        reg.counter("x").inc();
        assert_eq!(reg2.counter("x").get(), 1);
    }
}
