//! Placement policies for task copies.  The paper's cluster is homogeneous
//! so placement cannot change completion times; the router exists so the
//! live master (and future heterogeneous extensions) has a seam: it decides
//! *which* idle machine a copy lands on and enforces anti-affinity between
//! copies of the same task (a backup on the original's machine is useless).

use crate::cluster::job::TaskRef;
use crate::stats::Pcg64;

/// Placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Pop the free-list (the simulator's default; fastest).
    FirstFree,
    /// Uniform over idle machines (the paper's "randomly chosen").
    Random,
    /// Cycle through machine ids (spreads load for live dashboards).
    RoundRobin,
}

/// Chooses among idle machine ids.
#[derive(Clone, Debug)]
pub struct Router {
    policy: Policy,
    rng: Pcg64,
    next: usize,
}

impl Router {
    pub fn new(policy: Policy, seed: u64) -> Self {
        Router { policy, rng: Pcg64::new(seed, 0x7011), next: 0 }
    }

    /// Pick an index into `idle` (a slice of idle machine ids) for a copy of
    /// `task`, avoiding `exclude` (machines already running copies of it)
    /// when possible.
    ///
    /// Allocation-free: a counting pass sizes the viable pool, the policy
    /// picks a rank into it, and a second pass walks to that rank — the
    /// same choices the old `Vec<usize>`-materializing implementation made
    /// (identical RNG draws and cursor motion), pinned by the
    /// `alloc_free_pick_matches_reference_sequence` test.
    pub fn pick(&mut self, idle: &[u32], exclude: &[u32], _task: TaskRef) -> Option<usize> {
        if idle.is_empty() {
            return None;
        }
        let viable = idle.iter().filter(|m| !exclude.contains(m)).count();
        if viable == 0 {
            // anti-affinity impossible; fall back to any idle machine
            return Some(match self.policy {
                Policy::FirstFree => idle.len() - 1,
                Policy::Random => self.rng.uniform_u64(0, idle.len() as u64 - 1) as usize,
                Policy::RoundRobin => {
                    self.next = (self.next + 1) % idle.len();
                    self.next
                }
            });
        }
        let k = match self.policy {
            Policy::FirstFree => viable - 1,
            Policy::Random => self.rng.uniform_u64(0, viable as u64 - 1) as usize,
            Policy::RoundRobin => {
                self.next = (self.next + 1) % viable;
                self.next
            }
        };
        // k < viable, so the walk always yields Some
        (0..idle.len()).filter(|&i| !exclude.contains(&idle[i])).nth(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::job::JobId;

    fn t() -> TaskRef {
        TaskRef { job: JobId(0), task: 0 }
    }

    #[test]
    fn empty_pool_none() {
        let mut r = Router::new(Policy::Random, 1);
        assert_eq!(r.pick(&[], &[], t()), None);
    }

    #[test]
    fn respects_anti_affinity() {
        let mut r = Router::new(Policy::Random, 1);
        let idle = [1, 2, 3];
        for _ in 0..100 {
            let i = r.pick(&idle, &[2], t()).unwrap();
            assert_ne!(idle[i], 2);
        }
    }

    #[test]
    fn falls_back_when_all_excluded() {
        let mut r = Router::new(Policy::FirstFree, 1);
        let idle = [5];
        assert!(r.pick(&idle, &[5], t()).is_some());
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(Policy::RoundRobin, 1);
        let idle = [1, 2, 3];
        let picks: Vec<usize> = (0..6).map(|_| r.pick(&idle, &[], t()).unwrap()).collect();
        assert_eq!(picks, vec![1, 2, 0, 1, 2, 0]);
    }

    /// The pre-optimization implementation, kept verbatim as the oracle:
    /// it materializes the viable pool as a `Vec<usize>` on every call.
    struct ReferenceRouter {
        policy: Policy,
        rng: Pcg64,
        next: usize,
    }

    impl ReferenceRouter {
        fn new(policy: Policy, seed: u64) -> Self {
            ReferenceRouter { policy, rng: Pcg64::new(seed, 0x7011), next: 0 }
        }

        fn pick(&mut self, idle: &[u32], exclude: &[u32]) -> Option<usize> {
            if idle.is_empty() {
                return None;
            }
            let viable: Vec<usize> =
                (0..idle.len()).filter(|&i| !exclude.contains(&idle[i])).collect();
            let pool: &[usize] = if viable.is_empty() {
                return Some(match self.policy {
                    Policy::FirstFree => idle.len() - 1,
                    Policy::Random => self.rng.uniform_u64(0, idle.len() as u64 - 1) as usize,
                    Policy::RoundRobin => {
                        self.next = (self.next + 1) % idle.len();
                        self.next
                    }
                });
            } else {
                &viable
            };
            Some(match self.policy {
                Policy::FirstFree => pool[pool.len() - 1],
                Policy::Random => pool[self.rng.uniform_u64(0, pool.len() as u64 - 1) as usize],
                Policy::RoundRobin => {
                    self.next = (self.next + 1) % pool.len();
                    pool[self.next]
                }
            })
        }
    }

    #[test]
    fn alloc_free_pick_matches_reference_sequence() {
        for policy in [Policy::FirstFree, Policy::Random, Policy::RoundRobin] {
            let mut new = Router::new(policy, 99);
            let mut oracle = ReferenceRouter::new(policy, 99);
            let mut seq = Pcg64::new(7, 1234);
            for _ in 0..500 {
                let n = seq.uniform_u64(0, 8) as usize;
                let idle: Vec<u32> = (0..n).map(|_| seq.uniform_u64(0, 9) as u32).collect();
                let n_ex = seq.uniform_u64(0, 4) as usize;
                let exclude: Vec<u32> =
                    (0..n_ex).map(|_| seq.uniform_u64(0, 9) as u32).collect();
                assert_eq!(
                    new.pick(&idle, &exclude, t()),
                    oracle.pick(&idle, &exclude),
                    "pick diverged for idle={idle:?} exclude={exclude:?}"
                );
            }
        }
    }
}
